"""HLO analyzer: trip-count-aware FLOPs/collectives on known programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze_hlo, _shape_bytes


def _hlo(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_shape_bytes():
    assert _shape_bytes("bf16[8,128]{1,0}") == 8 * 128 * 2
    assert _shape_bytes("f32[16]") == 64
    assert _shape_bytes("(f32[2], s32[4])") == 8 + 16
    assert _shape_bytes("pred[10]") == 10


def test_plain_matmul_flops():
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    st = analyze_hlo(_hlo(lambda x, y: x @ y, a, b))
    assert st.n_dots == 1
    assert st.flops == pytest.approx(2 * 128 * 256 * 64, rel=1e-6)


def test_scan_trip_count_scaling():
    """A matmul inside lax.scan must count trip_count times."""
    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((10, 64, 64), jnp.float32)

    def f(x, ws):
        def body(h, wi):
            return h @ wi, None
        out, _ = jax.lax.scan(body, x, ws)
        return out

    st = analyze_hlo(_hlo(f, a, w))
    expected = 10 * 2 * 64 * 64 * 64
    assert st.flops == pytest.approx(expected, rel=0.01), \
        f"{st.flops} vs {expected}"


def test_nested_scan_multiplies():
    a = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((4, 3, 32, 32), jnp.float32)

    def f(x, ws):
        def outer(h, wrow):
            def inner(hh, wi):
                return hh @ wi, None
            h2, _ = jax.lax.scan(inner, h, wrow)
            return h2, None
        out, _ = jax.lax.scan(outer, x, ws)
        return out

    st = analyze_hlo(_hlo(f, a, w))
    expected = 12 * 2 * 32 ** 3
    assert st.flops == pytest.approx(expected, rel=0.01)


def test_collective_bytes_counted():
    """psum in shard_map (1-device mesh still emits all-reduce)."""
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1,), ("x",))
    try:
        from jax.experimental.shard_map import shard_map
    except ImportError:
        shard_map = jax.shard_map
    from jax.sharding import PartitionSpec as P

    def f(v):
        return jax.lax.psum(v, "x")

    g = shard_map(f, mesh=mesh, in_specs=P("x"), out_specs=P())
    hlo = jax.jit(g).lower(
        jax.ShapeDtypeStruct((64,), jnp.float32)).compile().as_text()
    st = analyze_hlo(hlo)
    # all-reduce may be optimised away on 1 device; accept either but the
    # parser must not crash and must return finite numbers
    assert np.isfinite(st.collective_bytes)
