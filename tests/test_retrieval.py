"""Integration: Algorithm 2 end-to-end — QoI tolerances are guaranteed met
on every representation, byte accounting is monotone, masks work."""
import numpy as np
import pytest

from repro.core import ge
from repro.core.qoi import Prod, Var
from repro.core.refactor import refactor_variables
from repro.core.retrieval import QoIRequest, assign_eb, retrieve_qoi_controlled
from repro.data.synthetic import ge_like_fields, s3d_like_fields

N = 1 << 12


@pytest.fixture(scope="module")
def fields():
    return ge_like_fields(n=N, seed=0)


@pytest.fixture(scope="module")
def archives(fields):
    return {m: refactor_variables(fields, method=m, nbits=40, n_snapshots=8)
            for m in ("hb", "ob", "psz3", "psz3_delta")}


def _check_actual_errors(qois, fields, res):
    orig = {k: np.asarray(v) for k, v in fields.items()}
    for name, expr in qois.items():
        truth = np.asarray(expr.value(orig))
        approx = np.asarray(expr.value(res.values))
        actual = np.abs(truth - approx).max()
        est = res.est_errors[name]
        assert actual <= est * (1 + 1e-9), \
            f"{name}: actual {actual} exceeds estimate {est}"
        assert actual <= res.tau_abs[name] * (1 + 1e-9), \
            f"{name}: actual {actual} exceeds tolerance {res.tau_abs[name]}"


@pytest.mark.parametrize("method", ["hb", "ob", "psz3", "psz3_delta"])
def test_qoi_control_all_methods(fields, archives, method):
    qois = ge.all_qois()
    reqs = [QoIRequest(name=k, expr=e, tau_rel=1e-3) for k, e in qois.items()]
    res = retrieve_qoi_controlled(archives[method].open(), reqs)
    assert res.converged
    _check_actual_errors(qois, fields, res)
    assert 0 < res.bitrate < 64  # must beat raw f64


@pytest.mark.parametrize("tau", [1e-2, 1e-4, 1e-6])
def test_progressive_tolerances_hb(fields, archives, tau):
    qois = {"VTOT": ge.v_total(), "PT": ge.total_pressure()}
    reqs = [QoIRequest(name=k, expr=e, tau_rel=tau) for k, e in qois.items()]
    res = retrieve_qoi_controlled(archives["hb"].open(), reqs)
    assert res.converged
    _check_actual_errors(qois, fields, res)


def test_progressive_session_reuse_is_incremental(fields, archives):
    """Successively tighter requests on ONE session only add bytes —
    Definition 1's incremental-recomposition contract."""
    qois = {"VTOT": ge.v_total()}
    session = archives["hb"].open()
    last_bytes = 0
    bitrates = []
    for tau in [1e-1, 1e-3, 1e-5]:
        reqs = [QoIRequest("VTOT", qois["VTOT"], tau)]
        res = retrieve_qoi_controlled(session, reqs)
        assert res.converged
        assert res.bytes_retrieved >= last_bytes
        last_bytes = res.bytes_retrieved
        bitrates.append(res.bitrate)
    assert bitrates[0] < bitrates[-1]


def test_outlier_mask_prevents_divergence(fields, archives):
    """The zero-velocity wall region must not force full-precision retrieval
    (paper §V-A): VTOT converges with finite estimates despite sqrt(0)."""
    res = retrieve_qoi_controlled(
        archives["hb"].open(), [QoIRequest("VTOT", ge.v_total(), 1e-4)])
    assert res.converged
    assert np.isfinite(res.est_errors["VTOT"])


def test_s3d_multiplication_qois():
    fields = s3d_like_fields(shape=(17, 9, 9))
    sub = {k: fields[k] for k in ("x0", "x1", "x3", "x4")}
    arch = refactor_variables(sub, method="hb", nbits=40,
                              mask_zero_velocity=False)
    qois = {"x1x3": Prod(Var("x1"), Var("x3")),
            "x0x4": Prod(Var("x0"), Var("x4"))}
    reqs = [QoIRequest(k, e, 1e-4) for k, e in qois.items()]
    res = retrieve_qoi_controlled(arch.open(), reqs)
    assert res.converged
    _check_actual_errors(qois, sub, res)


def test_assign_eb_minimum_rule():
    """Alg 3: a variable used by several QoIs gets the tightest tolerance."""
    reqs = [QoIRequest("a", ge.v_total(), 1e-2),
            QoIRequest("b", ge.mach(), 1e-5)]
    eps = assign_eb(reqs, {v: 10.0 for v in
                           ("Vx", "Vy", "Vz", "P", "D")})
    assert eps["Vx"] == pytest.approx(1e-5 * 10.0)  # Mach is tighter
    assert eps["P"] == pytest.approx(1e-5 * 10.0)


def test_estimated_always_upper_bounds_actual_across_bitrates(fields, archives):
    """Fig 4 invariant: est >= actual at every progressive stage."""
    session = archives["hb"].open()
    expr = ge.total_pressure()
    orig = {k: np.asarray(v) for k, v in fields.items()}
    truth = np.asarray(expr.value(orig))
    for tau in [1e-1, 1e-2, 1e-3, 1e-4, 1e-5]:
        res = retrieve_qoi_controlled(session, [QoIRequest("PT", expr, tau)])
        approx = np.asarray(expr.value(res.values))
        actual = np.abs(truth - approx).max()
        assert actual <= res.est_errors["PT"] * (1 + 1e-9)
