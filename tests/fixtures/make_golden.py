"""Regenerate the golden v1/v2 archive fixtures.

    PYTHONPATH=src python tests/fixtures/make_golden.py

Writes, next to this script:

    golden_v1.prs        format-v1 single-file container
    golden_v2/           format-v2 sharded container (manifest.json + *.seg)
    golden_expected.npz  reconstructions + byte accounting the fixtures
                         must keep producing, recorded at generation time

The fixtures freeze the *legacy* on-disk dialects so the codec registry's
compatibility paths can never silently rot:

  * v1/v2 plane segments tagged ``b"R"`` (raw words) / ``b"Z"`` (zlib),
    gated on the legacy 0.45-0.55 density band;
  * sign segments as bare (untagged) zlib streams;
  * v1 manifests with 3-tuple ``(offset, size, crc)`` segment entries and
    no ``blobs`` key; v2 manifests with 4-tuple ``(blob, offset, size,
    crc)`` entries.

The current encoder no longer *writes* any of this, so the fixtures are
produced by transcoding a freshly refactored archive plane-by-plane into
the legacy dialect (bit-exact raw words in, legacy entropy stage out),
then downgrading the manifest.  Committed fixtures are the contract —
regeneration is only needed if the *synthetic input* (ge_like_fields) or
the quantizer ever changes, and such a change must be deliberate.
"""
from __future__ import annotations

import json
import os
import struct
import sys
import zlib

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                os.pardir, os.pardir, "src"))

from repro.bitplane.codecs import decode_sign_blob, decode_tagged  # noqa: E402
from repro.bitplane.encoder import LevelBitplanes  # noqa: E402
from repro.core.refactor import refactor_variables  # noqa: E402
from repro.data.synthetic import ge_like_fields  # noqa: E402
from repro.store.container import MAGIC, build_container, \
    build_sharded_container  # noqa: E402

HERE = os.path.dirname(os.path.abspath(__file__))
N = 1 << 10
EPS_LADDER = (1e-2, 1e-5, 1e-15)   # coarse, tight, full-precision pull

_RAW_BAND = (0.45, 0.55)


def _legacy_plane(words: np.ndarray, count: int) -> bytes:
    """The pre-registry entropy stage, bit-for-bit: density-gated raw,
    else zlib-if-it-shrinks."""
    buf = words.tobytes()
    if hasattr(np, "bitwise_count"):
        density = int(np.bitwise_count(words).sum()) / count
    else:
        density = int(np.unpackbits(words.view(np.uint8)).sum()) / count
    if _RAW_BAND[0] <= density <= _RAW_BAND[1]:
        return b"R" + buf
    z = zlib.compress(buf, 1)
    return b"Z" + z if len(z) < len(buf) else b"R" + buf


def _transcode_group(g: LevelBitplanes) -> LevelBitplanes:
    if g.exponent is None:
        return LevelBitplanes(count=g.count, exponent=None, nbits=g.nbits,
                              planes=[], plane_raw_bits=g.plane_raw_bits,
                              signs=b"")
    nwords = (g.count + 31) // 32
    planes = []
    for blob in g.planes:
        words = np.frombuffer(decode_tagged(blob, 4 * nwords),
                              dtype=np.uint32, count=nwords)
        planes.append(_legacy_plane(words, g.count))
    sign_bits = decode_sign_blob(g.signs, (g.count + 7) // 8)
    return LevelBitplanes(count=g.count, exponent=g.exponent, nbits=g.nbits,
                          planes=planes, plane_raw_bits=g.plane_raw_bits,
                          signs=zlib.compress(sign_bits, 1))


def _transcode_archive(arch):
    for var in arch.variables.values():
        var.groups = [_transcode_group(g) for g in var.groups]
    return arch


def write_v1(arch, path: str) -> None:
    manifest, payload = build_container(arch)
    manifest["version"] = 1
    manifest.pop("blobs", None)
    segments = {}
    for key, entry in manifest["segments"].items():
        blob, off, size, crc = entry[:4]
        assert blob == ""
        segments[key] = [off, size, crc]
    manifest["segments"] = segments
    blob = json.dumps(manifest, sort_keys=True).encode("utf-8")
    with open(path, "wb") as fh:
        fh.write(MAGIC)
        fh.write(struct.pack("<Q", len(blob)))
        fh.write(blob)
        fh.write(payload)


def write_v2(arch, directory: str) -> None:
    manifest, payloads = build_sharded_container(arch, shard_by="variable")
    manifest["version"] = 2
    manifest["segments"] = {key: list(entry[:4])
                            for key, entry in manifest["segments"].items()}
    os.makedirs(directory, exist_ok=True)
    for blob, data in payloads.items():
        with open(os.path.join(directory, blob), "wb") as fh:
            fh.write(data)
    with open(os.path.join(directory, "manifest.json"), "wb") as fh:
        fh.write(json.dumps(manifest, sort_keys=True, indent=1
                            ).encode("utf-8"))


def main() -> None:
    fields = ge_like_fields(n=N, seed=0)
    vel = {k: fields[k] for k in ("Vx", "Vy", "Vz")}
    arch = _transcode_archive(refactor_variables(vel, method="hb"))

    write_v1(arch, os.path.join(HERE, "golden_v1.prs"))
    write_v2(arch, os.path.join(HERE, "golden_v2"))

    expected = {}
    session = arch.open()
    for eps_i, eps in enumerate(EPS_LADDER):
        for v in vel:
            data, bound = session.reconstruct(v, eps)
            expected[f"{v}__eps{eps_i}"] = data
            expected[f"{v}__bound{eps_i}"] = np.float64(bound)
    expected["eps_ladder"] = np.asarray(EPS_LADDER)
    expected["bytes_retrieved"] = np.int64(session.bytes_retrieved)
    np.savez_compressed(os.path.join(HERE, "golden_expected.npz"), **expected)

    total = sum(os.path.getsize(os.path.join(HERE, f))
                for f in ("golden_v1.prs",))
    total += sum(os.path.getsize(os.path.join(HERE, "golden_v2", f))
                 for f in os.listdir(os.path.join(HERE, "golden_v2")))
    print(f"wrote golden fixtures under {HERE} "
          f"({total / 1024:.1f} KiB containers, "
          f"bytes_retrieved={session.bytes_retrieved})")


if __name__ == "__main__":
    main()
