"""Regenerate the golden archive fixtures (formats v1-v4).

    PYTHONPATH=src python tests/fixtures/make_golden.py            # all
    PYTHONPATH=src python tests/fixtures/make_golden.py --only v34 # v3+v4

Writes, next to this script:

    golden_v1.prs        format-v1 single-file container
    golden_v2/           format-v2 sharded container (manifest.json + *.seg)
    golden_v3/           format-v3 sharded container — the CURRENT static
                         encoder's output, frozen (codec-tagged planes)
    golden_v4/           format-v4 live journaled archive — base manifest +
                         journal.jsonl + per-timestep blobs, left UNSEALED
                         so opening it exercises journal replay forever
    golden_expected.npz  reconstructions + byte accounting the v1/v2
                         fixtures must keep producing (v3 values are the
                         same by cross-generation bit identity)
    golden_v34_expected.npz
                         v3 byte accounting + v4 per-timestep values,
                         bounds, and byte accounting
    golden_ip/           sharded container carrying method="ip"
                         (interpolation-predicted) variables — freezes the
                         closed-loop prediction contract (pred_planes
                         metadata + fixed-order contribution sum)
    golden_ip_expected.npz
                         ip reconstructions, bounds, byte accounting

The fixtures freeze the *legacy* on-disk dialects so the codec registry's
compatibility paths can never silently rot:

  * v1/v2 plane segments tagged ``b"R"`` (raw words) / ``b"Z"`` (zlib),
    gated on the legacy 0.45-0.55 density band;
  * sign segments as bare (untagged) zlib streams;
  * v1 manifests with 3-tuple ``(offset, size, crc)`` segment entries and
    no ``blobs`` key; v2 manifests with 4-tuple ``(blob, offset, size,
    crc)`` entries.

The current encoder no longer *writes* any of this, so the fixtures are
produced by transcoding a freshly refactored archive plane-by-plane into
the legacy dialect (bit-exact raw words in, legacy entropy stage out),
then downgrading the manifest.  Committed fixtures are the contract —
regeneration is only needed if the *synthetic input* (ge_like_fields) or
the quantizer ever changes, and such a change must be deliberate.
"""
from __future__ import annotations

import json
import os
import struct
import sys
import zlib

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                os.pardir, os.pardir, "src"))

from repro.bitplane.codecs import decode_sign_blob, decode_tagged  # noqa: E402
from repro.bitplane.encoder import LevelBitplanes  # noqa: E402
from repro.core.refactor import refactor_variables  # noqa: E402
from repro.data.synthetic import ge_like_fields  # noqa: E402
from repro.store.container import MAGIC, build_container, \
    build_sharded_container, open_archive, save_sharded_archive  # noqa: E402
from repro.store.writer import ArchiveWriter  # noqa: E402

HERE = os.path.dirname(os.path.abspath(__file__))
N = 1 << 10
EPS_LADDER = (1e-2, 1e-5, 1e-15)   # coarse, tight, full-precision pull

_RAW_BAND = (0.45, 0.55)


def _legacy_plane(words: np.ndarray, count: int) -> bytes:
    """The pre-registry entropy stage, bit-for-bit: density-gated raw,
    else zlib-if-it-shrinks."""
    buf = words.tobytes()
    if hasattr(np, "bitwise_count"):
        density = int(np.bitwise_count(words).sum()) / count
    else:
        density = int(np.unpackbits(words.view(np.uint8)).sum()) / count
    if _RAW_BAND[0] <= density <= _RAW_BAND[1]:
        return b"R" + buf
    z = zlib.compress(buf, 1)
    return b"Z" + z if len(z) < len(buf) else b"R" + buf


def _transcode_group(g: LevelBitplanes) -> LevelBitplanes:
    if g.exponent is None:
        return LevelBitplanes(count=g.count, exponent=None, nbits=g.nbits,
                              planes=[], plane_raw_bits=g.plane_raw_bits,
                              signs=b"")
    nwords = (g.count + 31) // 32
    planes = []
    for blob in g.planes:
        words = np.frombuffer(decode_tagged(blob, 4 * nwords),
                              dtype=np.uint32, count=nwords)
        planes.append(_legacy_plane(words, g.count))
    sign_bits = decode_sign_blob(g.signs, (g.count + 7) // 8)
    return LevelBitplanes(count=g.count, exponent=g.exponent, nbits=g.nbits,
                          planes=planes, plane_raw_bits=g.plane_raw_bits,
                          signs=zlib.compress(sign_bits, 1))


def _transcode_archive(arch):
    for var in arch.variables.values():
        var.groups = [_transcode_group(g) for g in var.groups]
    return arch


def write_v1(arch, path: str) -> None:
    manifest, payload = build_container(arch)
    manifest["version"] = 1
    manifest.pop("blobs", None)
    segments = {}
    for key, entry in manifest["segments"].items():
        blob, off, size, crc = entry[:4]
        assert blob == ""
        segments[key] = [off, size, crc]
    manifest["segments"] = segments
    blob = json.dumps(manifest, sort_keys=True).encode("utf-8")
    with open(path, "wb") as fh:
        fh.write(MAGIC)
        fh.write(struct.pack("<Q", len(blob)))
        fh.write(blob)
        fh.write(payload)


def write_v2(arch, directory: str) -> None:
    manifest, payloads = build_sharded_container(arch, shard_by="variable")
    manifest["version"] = 2
    manifest["segments"] = {key: list(entry[:4])
                            for key, entry in manifest["segments"].items()}
    os.makedirs(directory, exist_ok=True)
    for blob, data in payloads.items():
        with open(os.path.join(directory, blob), "wb") as fh:
            fh.write(data)
    with open(os.path.join(directory, "manifest.json"), "wb") as fh:
        fh.write(json.dumps(manifest, sort_keys=True, indent=1
                            ).encode("utf-8"))


V4_T = 6                 # timesteps in the journaled fixture
V4_KEYFRAME = 3          # two keyframe→delta chains: t0-t2, t3-t5
V4_EPS = 1e-3


def _v4_frames(base: np.ndarray):
    """Deterministic drifting timeseries off the synthetic Vx field — close
    enough frame-to-frame that deltas genuinely beat keyframes."""
    return [np.asarray(base * (1.0 + 0.05 * k) + 0.01 * np.cos(7.0 * k),
                       dtype=base.dtype)
            for k in range(V4_T)]


def write_v3(directory: str) -> None:
    """Format v3: the *current* static encoder's sharded output, verbatim —
    codec-tagged 5-tuple segments.  Frozen so the registry's tagged
    decode paths can never silently rot either."""
    fields = ge_like_fields(n=N, seed=0)
    vel = {k: fields[k] for k in ("Vx", "Vy", "Vz")}
    arch = refactor_variables(vel, method="hb")
    save_sharded_archive(arch, directory, shard_by="variable")


def write_v4(directory: str) -> None:
    """Format v4: a live journaled archive — base manifest + journal.jsonl
    + one ``.t<k>.seg`` blob per timestep, deliberately left UNSEALED so
    every open of the fixture replays the journal."""
    base = ge_like_fields(n=N, seed=0)["Vx"]
    # context exit closes the journal WITHOUT sealing — live on purpose
    with ArchiveWriter.create(directory,
                              keyframe_interval=V4_KEYFRAME) as writer:
        for frame in _v4_frames(base):
            writer.append({"T": frame}, eps=V4_EPS)


def record_v34_expected() -> None:
    """Replay both new fixtures through the public reader and freeze what
    they must keep producing: values, certified bounds, byte accounting."""
    expected = {}

    a3 = open_archive(os.path.join(HERE, "golden_v3"))
    s3 = a3.open()
    for eps_i, eps in enumerate(EPS_LADDER):
        for v in ("Vx", "Vy", "Vz"):
            data, bound = s3.reconstruct(v, eps)
            expected[f"v3__{v}__eps{eps_i}"] = data
            expected[f"v3__{v}__bound{eps_i}"] = np.float64(bound)
    expected["v3__bytes_retrieved"] = np.int64(s3.bytes_retrieved)

    a4 = open_archive(os.path.join(HERE, "golden_v4"))
    s4 = a4.open()
    reader = s4.reader("T")
    for t in range(V4_T):
        data, bound = reader.read(t)
        expected[f"v4__t{t}"] = data
        expected[f"v4__bound{t}"] = np.float64(bound)
    expected["v4__bytes_retrieved"] = np.int64(s4.bytes_retrieved)
    expected["v4__eps"] = np.float64(V4_EPS)
    np.savez_compressed(os.path.join(HERE, "golden_v34_expected.npz"),
                        **expected)
    print(f"v3 bytes_retrieved={s3.bytes_retrieved} "
          f"v4 bytes_retrieved={s4.bytes_retrieved}")


IP_VARS = ("S", "Vx")


def _ip_fields():
    """A smooth multi-octave field (where the interpolation predictor
    genuinely bites) plus a rough synthetic one (where it must still
    round-trip) — both deterministic."""
    from repro.data.synthetic import smooth_field
    return {"S": smooth_field((257,), seed=5, lo=-3.0, hi=9.0),
            "Vx": ge_like_fields(n=N, seed=0)["Vx"]}


def write_ip(directory: str) -> None:
    """method="ip" fixture: the current encoder's sharded output, frozen.
    Pins the closed-loop prediction contract — per-group ``pred_planes``
    metadata and the fixed-order contribution sum the decoder replays —
    so no refactor of the predictor can silently re-encode old archives."""
    arch = refactor_variables(_ip_fields(), method="ip")
    save_sharded_archive(arch, directory, shard_by="variable")


def record_ip_expected() -> None:
    expected = {}
    sa = open_archive(os.path.join(HERE, "golden_ip"))
    session = sa.open()
    for eps_i, eps in enumerate(EPS_LADDER):
        for v in IP_VARS:
            data, bound = session.reconstruct(v, eps)
            expected[f"ip__{v}__eps{eps_i}"] = data
            expected[f"ip__{v}__bound{eps_i}"] = np.float64(bound)
    expected["ip__eps_ladder"] = np.asarray(EPS_LADDER)
    expected["ip__bytes_retrieved"] = np.int64(session.bytes_retrieved)
    np.savez_compressed(os.path.join(HERE, "golden_ip_expected.npz"),
                        **expected)
    print(f"ip bytes_retrieved={session.bytes_retrieved}")


def main(only: str = "all") -> None:
    if only in ("all", "v12"):
        fields = ge_like_fields(n=N, seed=0)
        vel = {k: fields[k] for k in ("Vx", "Vy", "Vz")}
        arch = _transcode_archive(refactor_variables(vel, method="hb"))

        write_v1(arch, os.path.join(HERE, "golden_v1.prs"))
        write_v2(arch, os.path.join(HERE, "golden_v2"))

        expected = {}
        session = arch.open()
        for eps_i, eps in enumerate(EPS_LADDER):
            for v in vel:
                data, bound = session.reconstruct(v, eps)
                expected[f"{v}__eps{eps_i}"] = data
                expected[f"{v}__bound{eps_i}"] = np.float64(bound)
        expected["eps_ladder"] = np.asarray(EPS_LADDER)
        expected["bytes_retrieved"] = np.int64(session.bytes_retrieved)
        np.savez_compressed(os.path.join(HERE, "golden_expected.npz"),
                            **expected)
        print(f"wrote v1/v2 fixtures "
              f"(bytes_retrieved={session.bytes_retrieved})")

    if only in ("all", "v34"):
        write_v3(os.path.join(HERE, "golden_v3"))
        write_v4(os.path.join(HERE, "golden_v4"))
        record_v34_expected()
        print(f"wrote v3/v4 fixtures under {HERE}")

    if only in ("all", "ip"):
        write_ip(os.path.join(HERE, "golden_ip"))
        record_ip_expected()
        print(f"wrote ip fixture under {HERE}")


if __name__ == "__main__":
    arg = "all"
    if len(sys.argv) > 2 and sys.argv[1] == "--only":
        arg = sys.argv[2]
    main(arg)
