"""Training substrate: optimizers train, progressive checkpoints round-trip
with guaranteed bounds, gradient compression keeps convergence, restart and
elastic re-mesh work."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data.batches import make_train_batch
from repro.models import transformer as T
from repro.train.checkpoint import (
    AsyncCheckpointer, restore_checkpoint, save_checkpoint,
)
from repro.train.fault import FailureInjector, elastic_restore, run_with_failures
from repro.train.grad_compress import (
    compress_decompress, payload_bytes, zeros_like_feedback,
)
from repro.train.optimizer import (
    adafactor_init, adafactor_update, adamw_init, adamw_update,
    clip_by_global_norm,
)
from repro.train.train_step import make_train_step

CFG = configs.get_reduced("internlm2-1.8b")


@pytest.fixture(scope="module")
def setup():
    params = T.init_params(jax.random.PRNGKey(0), CFG)
    batch = make_train_batch(CFG, batch=2, seq=16)
    return params, batch


def _loss(params, batch):
    return float(T.loss_fn(params, CFG, batch)[0])


@pytest.mark.parametrize("opt", ["adamw", "adafactor"])
def test_optimizer_reduces_loss(setup, opt):
    params, batch = setup
    cfg = CFG.replace(optimizer=opt)
    opt_init, step_fn = make_train_step(cfg, lr=3e-3)
    step_fn = jax.jit(step_fn)
    opt_state = opt_init(params)
    l0 = _loss(params, batch)
    p = params
    for _ in range(10):
        p, opt_state, m = step_fn(p, opt_state, batch)
    l1 = float(m["loss"])
    assert np.isfinite(l1) and l1 < l0, (l0, l1)


def test_clip_by_global_norm(setup):
    params, batch = setup
    g = jax.grad(lambda p: T.loss_fn(p, CFG, batch)[0])(params)
    clipped, gn = clip_by_global_norm(g, 1e-3)
    cn = np.sqrt(sum(float(jnp.sum(x.astype(jnp.float32) ** 2))
                     for x in jax.tree.leaves(clipped)))
    assert cn <= 1e-3 * (1 + 1e-5)


# ------------------------------------------------------------ checkpoints --

def test_checkpoint_exact_roundtrip(tmp_path, setup):
    params, _ = setup
    save_checkpoint(str(tmp_path), params, step=7)
    restored, report = restore_checkpoint(str(tmp_path))
    assert report.step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a, np.float64),
                                   np.asarray(b, np.float64),
                                   rtol=0, atol=1e-12)


def test_checkpoint_progressive_restore_bounds(tmp_path, setup):
    """Progressive restore: fewer bytes, guaranteed per-tensor L-inf and
    RMS-QoI bounds hold against the saved state."""
    params, _ = setup
    save_checkpoint(str(tmp_path), params, step=1)
    exact, rep_full = restore_checkpoint(str(tmp_path), tau_rel=0.0)
    approx, rep = restore_checkpoint(str(tmp_path), tau_rel=1e-3)
    assert rep.bytes_moved < rep_full.bytes_moved
    for i, (a, b) in enumerate(zip(jax.tree.leaves(exact),
                                   jax.tree.leaves(approx))):
        a64 = np.asarray(a, np.float64)
        b64 = np.asarray(b, np.float64)
        err = np.abs(a64 - b64).max() if a64.size else 0.0
        assert err <= rep.tensor_bounds[i] * (1 + 1e-12)
        # RMS QoI bound
        rms_a = np.sqrt(np.mean(a64 ** 2)) if a64.size else 0.0
        rms_b = np.sqrt(np.mean(b64 ** 2)) if b64.size else 0.0
        assert abs(rms_a - rms_b) <= rep.rms_bounds[i] * (1 + 1e-9) + 1e-30


def test_checkpoint_bytes_scale_with_tau(tmp_path, setup):
    params, _ = setup
    save_checkpoint(str(tmp_path), params, step=1)
    sizes = []
    for tau in [1e-1, 1e-3, 1e-6, 0.0]:
        _, rep = restore_checkpoint(str(tmp_path), tau_rel=tau)
        sizes.append(rep.bytes_moved)
    assert sizes == sorted(sizes), sizes
    assert sizes[0] < 0.5 * sizes[-1]


# --------------------------------------------------------- grad compress --

@pytest.mark.slow  # ~60s: 24 full train steps; nightly (tier-1 time budget)
def test_grad_compression_convergence_parity(setup):
    """Error feedback keeps training on track: 12 steps with 8-plane
    compression reach a loss close to the uncompressed run."""
    params, batch = setup
    opt_init, _ = make_train_step(CFG, lr=3e-3)

    def run(k_planes):
        p = params
        opt_state = opt_init(p)
        fb = None
        step_base = make_train_step(CFG, lr=3e-3)[1]
        for _ in range(12):
            if k_planes:
                g = jax.grad(lambda q: T.loss_fn(q, CFG, batch)[0])(p)
                if fb is None:
                    fb = zeros_like_feedback(g)
                g, fb = compress_decompress(g, fb, k_planes)
                from repro.train.optimizer import adamw_update, clip_by_global_norm
                g, _ = clip_by_global_norm(g, 1.0)
                p, opt_state = adamw_update(p, g, opt_state, lr=3e-3)
            else:
                p, opt_state, m = step_base(p, opt_state, batch)
        return _loss(p, batch)

    l_full = run(0)
    l_comp = run(8)
    assert l_comp < _loss(params, batch)         # actually trained
    assert abs(l_comp - l_full) < 0.35 * abs(l_full) + 0.5


def test_payload_bytes():
    g = {"a": jnp.zeros((1000,)), "b": jnp.zeros((24,))}
    assert payload_bytes(g, 7) == (1024 * 8 + 7) // 8  # 1 byte/elem at k=7


def test_sum_safe_wire_dtype():
    from repro.train.grad_compress import sum_safe_int_dtype
    assert sum_safe_int_dtype(2, 16) == jnp.int8    # 2+4+1 = 7 bits
    assert sum_safe_int_dtype(8, 16) == jnp.int16   # 13 bits
    assert sum_safe_int_dtype(12, 16) == jnp.int32  # 17 bits
    assert sum_safe_int_dtype(4, 512) == jnp.int16  # 4+9+1 = 14 bits


def test_compressed_psum_matches_mean():
    """shard_map compressed psum ≈ plain mean within the quantisation
    bound, and exact when feedback accumulates over two steps."""
    try:
        from jax.experimental.shard_map import shard_map
    except ImportError:
        shard_map = jax.shard_map
    from jax.sharding import PartitionSpec as P
    from repro.train.grad_compress import compressed_psum
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1,), ("data",))
    g = {"w": jnp.asarray(np.random.default_rng(0).standard_normal(64),
                          jnp.float32)}
    fb = {"w": jnp.zeros(64, jnp.float32)}

    def f(grads, fbk):
        return compressed_psum(grads, fbk, 8, "data", n_ranks=1)

    sm = shard_map(f, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
                   check_rep=False)
    mean, new_fb = jax.jit(sm)(g, fb)
    scale = 2.0 ** np.ceil(np.log2(np.abs(np.asarray(g["w"])).max()))
    np.testing.assert_allclose(np.asarray(mean["w"]), np.asarray(g["w"]),
                               atol=scale / 2 ** 8)
    # residual = exactly what was lost
    np.testing.assert_allclose(np.asarray(new_fb["w"]),
                               np.asarray(g["w"]) - np.asarray(mean["w"]),
                               atol=1e-6)


# ------------------------------------------------------------------ fault --

def test_restart_resumes_and_matches(tmp_path, setup):
    """Injected failure at step 7: the run restarts from step 5's checkpoint
    and finishes; the final loss matches a failure-free run exactly (CPU
    determinism + bit-exact restore)."""
    params, batch = setup
    opt_init, step_fn = make_train_step(CFG, lr=1e-3)
    step_jit = jax.jit(step_fn)

    def make_loop():
        def loop(step, state):
            p, o = state
            p, o, m = step_jit(p, o, batch)
            return (p, o), m["loss"]
        return loop

    def final_loss(inject):
        ckpt = AsyncCheckpointer(str(tmp_path / ("f" if inject else "n")))
        injector = FailureInjector(fail_at=[7] if inject else [])
        state, log = run_with_failures(make_loop(), (params, opt_init(params)),
                                       n_steps=10, ckpt=ckpt,
                                       injector=injector, ckpt_every=5)
        ckpt.close()
        return _loss(state[0], batch), log

    l_plain, log_plain = final_loss(False)
    l_fail, log_fail = final_loss(True)
    assert log_fail["restarts"] == 1 and log_plain["restarts"] == 0
    np.testing.assert_allclose(l_fail, l_plain, rtol=1e-5)


def test_straggler_policy_skips_slow_shards():
    import time
    from repro.train.fault import StragglerPolicy

    def fast():
        return np.ones(4)

    def slow():
        time.sleep(0.3)
        return np.ones(4)

    pol = StragglerPolicy(deadline_s=0.15)
    out = pol.gather([fast, slow, fast])
    # the slow fetch blew the deadline; later fetchers were skipped
    assert pol.skipped >= 1
    assert 1 <= len(out) < 3


def test_elastic_remesh_restore(tmp_path, setup):
    """The same checkpoint restores onto different mesh shapes (elastic
    scaling) with identical values."""
    from repro.train.sharding import param_pspecs
    params, batch = setup
    save_checkpoint(str(tmp_path), params, step=0)
    devs = jax.devices()
    mesh1 = jax.make_mesh((1, 1), ("data", "model"), devices=devs[:1])
    pspecs = param_pspecs(CFG, params, mesh1)
    placed, rep = elastic_restore(str(tmp_path), mesh1, pspecs)
    l_before = _loss(params, batch)
    l_after = _loss(jax.tree.map(
        lambda x, p: jnp.asarray(np.asarray(x), np.asarray(p).dtype),
        placed, params), batch)
    np.testing.assert_allclose(l_after, l_before, rtol=1e-6)


def test_checkpoint_restore_routes_shared_decode_entry(tmp_path, setup):
    """Regression: restore_checkpoint used to call the decode_magnitudes ->
    decode_values pair directly, bypassing the shared decode entry point —
    so device decode never covered checkpoint restore.  It must now route
    through decode_prefix, i.e. honor the decode-path knob with
    bit-identical restores on every path."""
    from repro.kernels import ops

    params, _ = setup
    save_checkpoint(str(tmp_path), params, step=2)
    restored = {}
    prev = ops.decode_path()
    try:
        for path in ("host", "kernel", "fused"):
            ops.set_decode_path(path)
            restored[path], rep = restore_checkpoint(str(tmp_path),
                                                     tau_rel=1e-4)
            assert rep.bytes_moved < rep.bytes_full
    finally:
        ops.set_decode_path(prev)
    ref = jax.tree.leaves(restored["host"])
    for path in ("kernel", "fused"):
        for a, b in zip(ref, jax.tree.leaves(restored[path])):
            assert np.array_equal(
                np.asarray(a, np.float64).view(np.uint64),
                np.asarray(b, np.float64).view(np.uint64)), path
