"""Composite QoI expressions: bound validity under random perturbations
(Theorems 7-9, Lemmas 1-2) on the full GE QoI set."""
import numpy as np
import pytest

from repro.core import ge
from repro.core.qoi import (
    Const, IntPow, Prod, Quot, Sqrt, Sum, Var, frac_pow, magnitude, square,
)
from repro.data.synthetic import ge_like_fields

N = 512


@pytest.fixture(scope="module")
def fields():
    f = ge_like_fields(n=N, seed=3, zero_fraction=0.0)
    return {k: np.asarray(v) for k, v in f.items()}


def _perturb(fields, ebs, seed):
    rng = np.random.default_rng(seed)
    return {k: v + rng.uniform(-1, 1, size=v.shape) * ebs[k]
            for k, v in fields.items()}


@pytest.mark.parametrize("qoi_name", ["VTOT", "T", "C", "Mach", "PT", "mu"])
@pytest.mark.parametrize("rel_eps", [1e-3, 1e-6])
def test_ge_qoi_bounds_hold(fields, qoi_name, rel_eps):
    """eval() on perturbed-as-original data never exceeds the bound computed
    from the (reconstructed, eps) pair."""
    expr = ge.all_qois()[qoi_name]
    ebs = {k: rel_eps * (v.max() - v.min()) * np.ones_like(v)
           for k, v in fields.items()}
    recon = _perturb(fields, ebs, seed=1)  # pretend this is the reconstruction
    val, bound = expr.eval(recon, ebs)
    val, bound = np.asarray(val), np.asarray(bound)
    assert not np.isnan(bound).any()
    for trial in range(5):
        # "original" data = any point within the eps-box around recon
        orig = _perturb(recon, ebs, seed=100 + trial)
        truth = np.asarray(expr.value(orig))
        finite = np.isfinite(bound)
        assert finite.mean() > 0.95, f"too many inf bounds for {qoi_name}"
        err = np.abs(truth - val)
        assert np.all(err[finite] <= bound[finite] * (1 + 1e-9) + 1e-300), \
            f"{qoi_name}: bound violated by {np.max(err[finite] - bound[finite])}"


def test_operator_sugar_matches_nodes(fields):
    vx = Var("Vx")
    e1 = vx * vx + 2.0 * vx - 1.0
    e2 = Sum([Prod(vx, vx), Sum([vx], coeffs=[2.0]), Const(-1.0)])
    ebs = {k: 0.1 * np.ones_like(v) for k, v in fields.items()}
    v1, b1 = e1.eval(fields, ebs)
    v2, b2 = e2.eval(fields, ebs)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2))
    np.testing.assert_allclose(np.asarray(b1), np.asarray(b2))


def test_frac_pow_decomposition(fields):
    """x^3.5 == x^3 * sqrt(x) on positive values."""
    p = Var("P")
    e = frac_pow(p, 3.5)
    val = np.asarray(e.value(fields))
    np.testing.assert_allclose(val, fields["P"] ** 3.5, rtol=1e-12)


def test_variables_tracking():
    assert ge.v_total().variables() == frozenset({"Vx", "Vy", "Vz"})
    assert ge.mach().variables() == frozenset({"Vx", "Vy", "Vz", "P", "D"})
    assert ge.viscosity().variables() == frozenset({"P", "D"})


def test_tight_sqrt_no_looser(fields):
    """Beyond-paper tight estimator is never looser than the paper's."""
    ebs = {k: 1e-3 * (v.max() - v.min()) * np.ones_like(v)
           for k, v in fields.items()}
    _, b_paper = ge.v_total(tight=False).eval(fields, ebs)
    _, b_tight = ge.v_total(tight=True).eval(fields, ebs)
    b_paper, b_tight = np.asarray(b_paper), np.asarray(b_tight)
    assert np.all(b_tight <= b_paper * (1 + 1e-12))
