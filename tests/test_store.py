"""Segment store subsystem: container round-trips, checksum verification,
prefetch equivalence, and transport accounting."""
import os
import struct

import numpy as np
import pytest

from repro.core import ge
from repro.core.refactor import METHODS, refactor_variables
from repro.core.retrieval import QoIRequest, retrieve_qoi_controlled
from repro.data.synthetic import ge_like_fields
from repro.options import OpenOptions
from repro.store import (
    ChecksumError,
    FileByteStore,
    MemoryByteStore,
    RemoteByteStore,
    RetryPolicy,
    crc32c,
    memory_store_archive,
    open_archive,
    save_archive,
)
from repro.store.container import MAGIC


def _vel_fields(n=1 << 12, seed=0):
    fields = ge_like_fields(n=n, seed=seed)
    return {k: fields[k] for k in ("Vx", "Vy", "Vz")}


# ------------------------------------------------------------------ crc32c --


def test_crc32c_vectors():
    # RFC 3720 / iSCSI test vectors
    assert crc32c(b"") == 0
    assert crc32c(b"123456789") == 0xE3069283
    assert crc32c(bytes(32)) == 0x8A9136AA
    assert crc32c(b"\xff" * 32) == 0x62A8AB43


def test_crc32c_fast_path_matches_scalar_and_chains():
    rng = np.random.default_rng(0)
    for size in (1, 7, 8, 1023, 1024, 1031, 4099, 70000):
        buf = rng.integers(0, 256, size, dtype=np.uint8).tobytes()
        whole = crc32c(buf)
        # chaining across an arbitrary split must equal the one-shot hash
        # (and exercises both the vectorized and scalar code paths)
        cut = size // 3
        assert crc32c(buf[cut:], crc32c(buf[:cut])) == whole


# ------------------------------------------------------- container format --


@pytest.mark.parametrize("method", METHODS)
def test_file_roundtrip_bit_identical(method, tmp_path):
    """A reopened file-backed archive reconstructs bit-identically to the
    in-memory session at every bound, with identical achieved bounds and
    byte accounting — for all four progressive methods."""
    vel = _vel_fields()
    arch = refactor_variables(vel, method=method)
    path = str(tmp_path / "a.prs")
    save_archive(arch, path)
    mem = arch.open()
    with open_archive(path) as store_arch:
        st = store_arch.open()
        for eps in (1e-1, 1e-3, 1e-6):
            for v in vel:
                a, ba = mem.reconstruct(v, eps)
                b, bb = st.reconstruct(v, eps)
                np.testing.assert_array_equal(a, b)
                assert ba == bb
        assert mem.bytes_retrieved == st.bytes_retrieved
        assert mem.bitrate(list(vel)) == st.bitrate(list(vel))


def test_roundtrip_metadata_and_masks(tmp_path):
    vel = _vel_fields()
    arch = refactor_variables(vel, method="hb")
    path = str(tmp_path / "a.prs")
    save_archive(arch, path)
    with open_archive(path) as sa:
        assert sa.method == "hb"
        assert sa.shapes == arch.shapes
        assert sa.ranges == arch.ranges      # exact float round-trip
        for name, mask in arch.masks.items():
            loaded = sa.masks[name]
            np.testing.assert_array_equal(loaded.mask, mask.mask)
            np.testing.assert_array_equal(loaded.values, mask.values)
            assert loaded.nbytes == mask.nbytes


def test_memory_store_matches_file_store(tmp_path):
    vel = _vel_fields()
    arch = refactor_variables(vel, method="hb")
    path = str(tmp_path / "a.prs")
    save_archive(arch, path)
    with open_archive(path) as fa:
        ma = memory_store_archive(arch)
        f, m = fa.open(), ma.open()
        for v in vel:
            a, _ = f.reconstruct(v, 1e-5)
            b, _ = m.reconstruct(v, 1e-5)
            np.testing.assert_array_equal(a, b)


def test_resolution_progression_through_store(tmp_path):
    vel = _vel_fields()
    arch = refactor_variables(vel, method="hb")
    path = str(tmp_path / "a.prs")
    save_archive(arch, path)
    mem = arch.open()
    with open_archive(path) as sa:
        st = sa.open()
        a, ba = mem.reconstruct_at_resolution("Vx", 2, 1e-4)
        b, bb = st.reconstruct_at_resolution("Vx", 2, 1e-4)
        np.testing.assert_array_equal(a, b)
        assert ba == bb


def test_open_rejects_bad_magic(tmp_path):
    path = str(tmp_path / "junk.prs")
    with open(path, "wb") as fh:
        fh.write(b"NOTASTORE" + bytes(64))
    with pytest.raises(ValueError, match="magic"):
        open_archive(path)


# ------------------------------------------------------------- checksums --


def test_checksum_corruption_detected(tmp_path):
    vel = _vel_fields()
    arch = refactor_variables(vel, method="hb")
    path = str(tmp_path / "a.prs")
    save_archive(arch, path)
    # largest segment: most likely to actually be consumed by a request
    with open_archive(path) as sa:
        key, entry = max(sa.fetcher.index.items(), key=lambda kv: kv[1].size)
    with open(path, "r+b") as fh:
        fh.seek(entry.offset + entry.size // 2)
        b = fh.read(1)
        fh.seek(entry.offset + entry.size // 2)
        fh.write(bytes([b[0] ^ 0x40]))
    with open_archive(path) as sa:
        with pytest.raises(ChecksumError, match="crc32c"):
            sa.fetcher.fetch(key)
    # verify=False trusts the transport (decode may still fail downstream,
    # but the fetch itself must not raise)
    with open_archive(path, OpenOptions.unverified()) as sa:
        sa.fetcher.fetch(key)


def test_corruption_surfaces_through_retrieval(tmp_path):
    vel = _vel_fields()
    arch = refactor_variables(vel, method="hb")
    path = str(tmp_path / "a.prs")
    save_archive(arch, path)
    with open(path, "rb") as fh:
        head = fh.read(len(MAGIC) + 8)
    (mlen,) = struct.unpack("<Q", head[len(MAGIC):])
    payload_start = len(MAGIC) + 8 + mlen
    size = os.path.getsize(path)
    with open(path, "r+b") as fh:   # flip one payload bit mid-file
        pos = payload_start + (size - payload_start) // 2
        fh.seek(pos)
        b = fh.read(1)
        fh.seek(pos)
        fh.write(bytes([b[0] ^ 0x01]))
    # persistent corruption no longer aborts the session: the retry budget
    # is spent (the crc failure re-surfaces each attempt), then the stream
    # pins at the deepest verified plane prefix and the session reports a
    # certified degraded result instead of raising mid-reconstruct.
    with open_archive(path,
                      OpenOptions(retry_policy=RetryPolicy.none())) as sa:
        st = sa.open()
        for v in vel:                # full-precision pull touches everything
            data, ach = st.reconstruct(v, 1e-15)
            assert np.max(np.abs(vel[v] - data)) <= ach * (1 + 1e-12)
        assert st.degraded
        avail = st.availability()
        assert avail and all(a.pinned for a in avail.values())
        assert all(np.isfinite(a.floor) for a in avail.values())
        # the pinned cause is the original checksum failure
        assert any("crc32c" in a.detail for a in avail.values())


# ------------------------------------------------------------- prefetch --


def test_prefetch_equals_no_prefetch_on_arbitrary_schedule(tmp_path):
    """Any interleaved fetch schedule with prefetch hints lands on the same
    bits and the same consumed-byte accounting as the plain path."""
    vel = _vel_fields()
    arch = refactor_variables(vel, method="hb")
    path = str(tmp_path / "a.prs")
    save_archive(arch, path)
    rng = np.random.default_rng(7)
    schedule = [(str(rng.choice(list(vel))), float(10.0 ** -rng.integers(1, 8)))
                for _ in range(24)]
    with open_archive(path, OpenOptions(prefetch_workers=0)) as plain_arch, \
            open_archive(path, OpenOptions(prefetch_workers=3)) as pf_arch:
        plain, pf = plain_arch.open(), pf_arch.open()
        for name, eps in schedule:
            # over-eager hints: future eps the schedule may never request
            pf.prefetch(name, eps / 10.0)
            a, ba = plain.reconstruct(name, eps)
            b, bb = pf.reconstruct(name, eps)
            np.testing.assert_array_equal(a, b)
            assert ba == bb
            assert plain.bytes_retrieved == pf.bytes_retrieved
        assert pf_arch.fetcher.stats.prefetch_hits > 0


def test_qoi_retrieval_store_vs_memory_with_prefetch(tmp_path):
    vel = _vel_fields()
    arch = refactor_variables(vel, method="hb")
    path = str(tmp_path / "a.prs")
    save_archive(arch, path)
    reqs = [QoIRequest("VTOT", ge.v_total(), 1e-4)]
    ref = retrieve_qoi_controlled(arch.open(), reqs)
    with open_archive(path, OpenOptions(prefetch_workers=2)) as sa:
        res = retrieve_qoi_controlled(sa.open(), reqs)
        for v in vel:
            np.testing.assert_array_equal(ref.values[v], res.values[v])
        assert ref.bytes_retrieved == res.bytes_retrieved
        assert ref.est_errors == res.est_errors
        assert res.converged


def test_snapshot_prefetch_respects_never_go_backwards(tmp_path):
    """A certain hint at a LOOSER eps than an already-decoded snapshot must
    not move a coarser snapshot request() will never decode (psz3 snapshots
    are independent; request reuses the cached tighter one)."""
    vel = _vel_fields()
    arch = refactor_variables(vel, method="psz3")
    path = str(tmp_path / "a.prs")
    save_archive(arch, path)
    with open_archive(path, OpenOptions(prefetch_workers=2)) as sa:
        st = sa.open()
        st.reconstruct("Vx", 1e-6)          # tight snapshot decoded
        moved = sa.fetcher.stats.bytes_fetched
        st.prefetch("Vx", 1e-2)             # looser: must be a no-op
        sa.fetcher.drain()
        assert sa.fetcher.stats.bytes_fetched == moved
        a, _ = st.reconstruct("Vx", 1e-2)   # served from the cached decode
        assert sa.fetcher.stats.bytes_fetched == moved


@pytest.mark.parametrize("method", ("psz3", "psz3_delta"))
def test_snapshot_prefetch_hint(method, tmp_path):
    vel = _vel_fields()
    arch = refactor_variables(vel, method=method)
    path = str(tmp_path / "a.prs")
    save_archive(arch, path)
    with open_archive(path, OpenOptions(prefetch_workers=2)) as sa:
        st = sa.open()
        st.prefetch("Vx", 1e-4)
        sa.fetcher.drain()
        issued = sa.fetcher.stats.prefetch_issued
        assert issued > 0
        a, _ = st.reconstruct("Vx", 1e-4)
        assert sa.fetcher.stats.prefetch_hits == issued   # nothing wasted
        b, _ = arch.open().reconstruct("Vx", 1e-4)
        np.testing.assert_array_equal(a, b)


# ------------------------------------------------------------ bytestores --


def test_remote_store_accounting_and_equality(tmp_path):
    vel = _vel_fields(n=1 << 10)
    arch = refactor_variables(vel, method="hb")
    path = str(tmp_path / "a.prs")
    save_archive(arch, path)
    remote = RemoteByteStore(FileByteStore(path), latency_s=1e-5,
                             bandwidth_bps=1e9)
    with open_archive(remote) as sa:
        st = sa.open()
        a, _ = st.reconstruct("Vx", 1e-4)
        b, _ = arch.open().reconstruct("Vx", 1e-4)
        np.testing.assert_array_equal(a, b)
        assert remote.stats.requests > 0
        assert remote.stats.busy_s > 0
        # every segment byte the fetcher saw crossed the simulated link
        # (plus the container header + manifest reads)
        assert remote.stats.bytes_moved >= sa.fetcher.stats.bytes_fetched


def test_bytestore_bounds_checking(tmp_path):
    ms = MemoryByteStore(b"0123456789")
    assert ms.read(2, 3) == b"234"
    with pytest.raises(EOFError):
        ms.read(8, 5)
    path = str(tmp_path / "f.bin")
    with open(path, "wb") as fh:
        fh.write(b"abcdef")
    with FileByteStore(path) as fs:
        assert fs.read(1, 3) == b"bcd"
        assert fs.size == 6
        with pytest.raises(EOFError):
            fs.read(4, 4)


def test_bytestore_rejects_negative_length(tmp_path):
    """A negative length is a caller bug, not an EOF condition: every
    backend must raise instead of silently returning a truncated slice."""
    path = str(tmp_path / "f.bin")
    with open(path, "wb") as fh:
        fh.write(b"0123456789")
    stores = [MemoryByteStore(b"0123456789"), FileByteStore(path),
              RemoteByteStore(MemoryByteStore(b"0123456789"),
                              latency_s=0.0, bandwidth_bps=1e12)]
    try:
        for store in stores:
            with pytest.raises(ValueError, match="negative"):
                store.read(2, -1)
            with pytest.raises(ValueError, match="negative"):
                store.read_batch([(0, 4), (2, -3)])
            with pytest.raises(EOFError):
                store.read(-1, 2)
            assert store.read(4, 0) == b""
            assert store.read_batch([(1, 3), (0, 2)]) == [b"123", b"01"]
    finally:
        for store in stores:
            store.close()


# ------------------------------------------------------ fetcher lifecycle --


def _tiny_fetcher(tmp_path, n_segments=24, seg_size=4096, latency_s=2e-3,
                  workers=2, wrap=None, **kw):
    from repro.store import SegmentEntry, SegmentFetcher
    rng = np.random.default_rng(3)
    payload = rng.integers(0, 256, n_segments * seg_size,
                           dtype=np.uint8).tobytes()
    index = {}
    for i in range(n_segments):
        seg = payload[i * seg_size:(i + 1) * seg_size]
        index[f"seg{i}"] = SegmentEntry(offset=i * seg_size, size=seg_size,
                                        crc=crc32c(seg))
    store = RemoteByteStore(MemoryByteStore(payload), latency_s=latency_s,
                            bandwidth_bps=1e9)
    if wrap is not None:
        store = wrap(store)
    return SegmentFetcher(index, store, prefetch_workers=workers,
                          **kw), payload, seg_size


def test_fetcher_close_with_outstanding_prefetches(tmp_path):
    """close() with prefetches still in flight must complete them (no
    leaked threads, no exceptions), and demand fetches must keep working
    afterwards — just without a pool."""
    fetcher, payload, seg = _tiny_fetcher(tmp_path)
    fetcher.prefetch([f"seg{i}" for i in range(24)])
    assert fetcher.outstanding > 0
    fetcher.close()                      # waits for the pool, does not raise
    assert fetcher.fetch("seg3") == payload[3 * seg:4 * seg]
    fetcher.close()                      # idempotent


def test_fetcher_drain_after_failed_read(tmp_path):
    """A failed background read must not poison drain(); the error surfaces
    on the consuming fetch, and other keys stay retrievable."""
    fetcher, payload, seg = _tiny_fetcher(tmp_path, latency_s=0.0)
    bad = fetcher.index["seg5"]
    fetcher.index["seg5"] = type(bad)(offset=bad.offset, size=bad.size,
                                      crc=bad.crc ^ 0xDEAD, blob=bad.blob)
    fetcher.prefetch(["seg5", "seg6"])
    fetcher.drain()                      # swallows the worker's failure
    with pytest.raises(ChecksumError):
        fetcher.fetch("seg5")
    assert fetcher.fetch("seg6") == payload[6 * seg:7 * seg]
    fetcher.close()


def test_fetcher_concurrent_fetch_many_two_threads(tmp_path):
    """Two threads pulling overlapping fetch_many sets through ONE shared
    link-modelled store: both must see correct bytes, with no deadlock and
    sane accounting."""
    import threading
    fetcher, payload, seg = _tiny_fetcher(tmp_path, latency_s=5e-4,
                                          workers=3)
    keys_a = [f"seg{i}" for i in range(0, 16)]
    keys_b = [f"seg{i}" for i in range(8, 24)]
    results = {}

    def worker(name, keys):
        results[name] = fetcher.fetch_many(keys)

    ta = threading.Thread(target=worker, args=("a", keys_a))
    tb = threading.Thread(target=worker, args=("b", keys_b))
    ta.start(); tb.start()
    ta.join(timeout=30); tb.join(timeout=30)
    assert not ta.is_alive() and not tb.is_alive()
    for name, keys in (("a", keys_a), ("b", keys_b)):
        for k, buf in zip(keys, results[name]):
            i = int(k[3:])
            assert buf == payload[i * seg:(i + 1) * seg]
    st = fetcher.stats
    served = st.demand_fetches + st.pipelined_hits + st.prefetch_hits
    assert served == len(keys_a) + len(keys_b)
    # overlapping keys are read once per consumer at most (the store saw
    # each key at least once, and never more than the consumption count)
    assert 24 <= st.store_reads <= served
    fetcher.close()


# ------------------------------------------------- fetcher failure paths --


def test_prefetch_failure_surfaces_original_exception_once(tmp_path):
    """A failed prefetch future surfaces its ORIGINAL exception at the one
    consuming fetch — not at drain, not duplicated, not rewrapped."""
    from repro.store import FaultInjectingByteStore, FaultPlan

    plan = FaultPlan(rate=1.0, max_faults_per_range=1)
    fetcher, payload, seg = _tiny_fetcher(
        tmp_path, latency_s=0.0,
        wrap=lambda s: FaultInjectingByteStore(s, plan, seed=7))
    fetcher.prefetch(["seg4"])
    fetcher.drain()                      # failure does NOT surface here
    with pytest.raises(IOError, match="injected transient fault"):
        fetcher.fetch("seg4")
    # the failed future was consumed: the key is no longer in flight and a
    # fresh demand read succeeds (the per-range fault budget is spent)
    assert fetcher.outstanding == 0
    assert fetcher.fetch("seg4") == payload[4 * seg:5 * seg]
    fetcher.close()


def test_refetch_after_transient_failure_succeeds(tmp_path):
    """Without any retry policy (legacy behaviour) a transient fault
    surfaces, and simply calling fetch again delivers verified bytes."""
    from repro.store import FaultInjectingByteStore, FaultPlan

    plan = FaultPlan(rate=1.0, max_faults_per_range=1)
    fetcher, payload, seg = _tiny_fetcher(
        tmp_path, workers=0, latency_s=0.0,
        wrap=lambda s: FaultInjectingByteStore(s, plan, seed=11))
    with pytest.raises(IOError):
        fetcher.fetch("seg0")
    assert fetcher.fetch("seg0") == payload[0:seg]
    st = fetcher.stats
    assert st.retries == 0 and st.faults_absorbed == 0   # nothing hidden


def test_retry_policy_absorbs_transient_faults(tmp_path):
    """With a RetryPolicy whose budget exceeds the per-range fault cap,
    every fetch succeeds and the stats report the absorbed faults."""
    from repro.store import FaultInjectingByteStore, FaultPlan, RetryPolicy

    plan = FaultPlan(rate=1.0, max_faults_per_range=2)
    fetcher, payload, seg = _tiny_fetcher(
        tmp_path, workers=0, latency_s=0.0,
        wrap=lambda s: FaultInjectingByteStore(s, plan, seed=13),
        retry_policy=RetryPolicy(max_attempts=4, backoff_s=1e-4))
    for i in range(6):
        assert fetcher.fetch(f"seg{i}") == payload[i * seg:(i + 1) * seg]
    st = fetcher.stats
    assert st.faults_absorbed == 2 * 6       # cap faults per range, all hidden
    assert st.retries >= st.faults_absorbed
    assert st.quarantined_blobs == 0


def test_quarantine_opens_after_consecutive_failures_and_reprobes(tmp_path):
    """K consecutive failures quarantine the blob; after the cooldown the
    circuit half-opens, a single probe read runs, and a healed store closes
    the circuit again."""
    from repro.store import BlobQuarantine, FaultInjectingByteStore, FaultPlan

    plan = FaultPlan(rate=1.0, max_faults_per_range=2)
    q = BlobQuarantine(threshold=2, cooldown_s=0.01)
    fetcher, payload, seg = _tiny_fetcher(
        tmp_path, workers=0, latency_s=0.0,
        wrap=lambda s: FaultInjectingByteStore(s, plan, seed=17),
        quarantine=q)
    # no retry policy: each fetch spends one attempt -> two failures open
    with pytest.raises(IOError):
        fetcher.fetch("seg0")
    assert not q.is_quarantined("")
    with pytest.raises(IOError):
        fetcher.fetch("seg0")
    assert q.is_quarantined("")
    assert fetcher.stats.quarantined_blobs == 1
    # next fetch waits out the cooldown, probes, and the (healed: fault cap
    # spent) read closes the circuit and delivers verified bytes
    assert fetcher.fetch("seg0") == payload[0:seg]
    assert not q.is_quarantined("")


def test_fetch_prefix_returns_longest_deliverable_prefix(tmp_path):
    """fetch_prefix stops at the first undeliverable key, reports the
    cause, and forgets the moot tail's in-flight entries."""
    from repro.store import FaultInjectingByteStore, FaultPlan

    plan = FaultPlan(rate=0.0, error_weight=1.0,
                     dead_ranges=((2 * 4096, 4096),))
    fetcher, payload, seg = _tiny_fetcher(
        tmp_path, latency_s=0.0,
        wrap=lambda s: FaultInjectingByteStore(s, plan, seed=0))
    keys = [f"seg{i}" for i in range(5)]
    bufs, err = fetcher.fetch_prefix(keys)
    assert bufs == [payload[i * seg:(i + 1) * seg] for i in range(2)]
    assert isinstance(err, IOError) and "permanent loss" in str(err)
    assert fetcher.outstanding == 0          # moot tail was forgotten
    # an unrelated healthy prefix still delivers in full
    bufs, err = fetcher.fetch_prefix(["seg6", "seg7"])
    assert err is None and len(bufs) == 2
    fetcher.close()
