"""Property tests: every estimator is a true upper bound (Theorems 1-6).

For each basis function we sample reconstructed values x, bounds eps, and
perturbations |xi| <= eps, and assert |f(x + xi) - f(x)| <= Delta(f, x, eps).
"""
import numpy as np
import pytest
from _hypothesis_shim import given, settings, strategies as st

from repro.core import estimators as est

FLOATS = st.floats(min_value=-1e6, max_value=1e6, allow_nan=False,
                   allow_infinity=False)
POS = st.floats(min_value=1e-12, max_value=1e4, allow_nan=False,
                allow_infinity=False)
UNIT = st.floats(min_value=-1.0, max_value=1.0, allow_nan=False)

# rounding slack: the bound math itself runs in f64
RTOL = 1e-9
ULP = np.finfo(np.float64).eps


def _le(actual, bound, scale=0.0):
    """actual <= bound, modulo f64 rounding: RTOL on the bound plus a few
    ulps of the function-value scale (the test's |f(x')-f(x)| subtraction
    cancels catastrophically when eps << |f|)."""
    return actual <= bound * (1 + RTOL) + 8 * ULP * abs(scale) + 1e-300


@settings(max_examples=50, deadline=None)
@given(x=FLOATS, eps=POS, t=UNIT, n=st.integers(min_value=1, max_value=6))
def test_intpow_bound(x, eps, t, n):
    xi = t * eps
    actual = abs((x + xi) ** n - x ** n)
    bound = float(est.bound_intpow(np.float64(x), np.float64(eps), n))
    assert _le(actual, bound, scale=abs(x) ** n)


@settings(max_examples=50, deadline=None)
@given(x=st.floats(min_value=0.0, max_value=1e8), eps=POS, t=UNIT,
       tight=st.booleans())
def test_sqrt_bound(x, eps, t, tight):
    xi = t * eps
    xprime = max(x + xi, 0.0)  # original values are >= 0 in-domain
    actual = abs(np.sqrt(xprime) - np.sqrt(x))
    bound = float(est.bound_sqrt(np.float64(x), np.float64(eps), tight=tight))
    assert _le(actual, bound, scale=np.sqrt(x))


@settings(max_examples=50, deadline=None)
@given(x=FLOATS, eps=POS, t=UNIT, c=FLOATS)
def test_radical_bound(x, eps, t, c):
    if abs(x + c) <= eps * 1.0000001 or abs(x + c) < 1e-10:
        return  # guard region: estimator returns inf (checked separately)
    xi = t * eps
    actual = abs(1.0 / (x + xi + c) - 1.0 / (x + c))
    bound = float(est.bound_radical(np.float64(x), np.float64(eps), c))
    assert _le(actual, bound, scale=1.0 / abs(x + c))


def test_radical_guard_returns_inf():
    assert np.isinf(est.bound_radical(np.float64(1.0), np.float64(2.0), 0.0))
    assert np.isinf(est.bound_radical(np.float64(-0.5), np.float64(1.0), 0.5))


@settings(max_examples=50, deadline=None)
@given(data=st.data(), n=st.integers(min_value=1, max_value=5))
def test_sum_bound(data, n):
    xs = [data.draw(FLOATS) for _ in range(n)]
    eps = [data.draw(POS) for _ in range(n)]
    coeffs = [data.draw(FLOATS) for _ in range(n)]
    xis = [data.draw(UNIT) * e for e in eps]
    actual = abs(sum(a * xi for a, xi in zip(coeffs, xis)))
    bound = float(est.bound_sum(coeffs, [np.float64(e) for e in eps]))
    assert _le(actual, bound, scale=sum(abs(a) for a in coeffs))


@settings(max_examples=50, deadline=None)
@given(x1=FLOATS, x2=FLOATS, e1=POS, e2=POS, t1=UNIT, t2=UNIT)
def test_prod_bound(x1, x2, e1, e2, t1, t2):
    actual = abs((x1 + t1 * e1) * (x2 + t2 * e2) - x1 * x2)
    bound = float(est.bound_prod(np.float64(x1), np.float64(e1),
                                 np.float64(x2), np.float64(e2)))
    assert _le(actual, bound, scale=abs(x1 * x2))


@settings(max_examples=50, deadline=None)
@given(x1=FLOATS, x2=FLOATS, e1=POS, e2=POS, t1=UNIT, t2=UNIT)
def test_quot_bound(x1, x2, e1, e2, t1, t2):
    if abs(x2) <= e2 * 1.0000001 or abs(x2) < 1e-10:
        return
    actual = abs((x1 + t1 * e1) / (x2 + t2 * e2) - x1 / x2)
    bound = float(est.bound_quot(np.float64(x1), np.float64(e1),
                                 np.float64(x2), np.float64(e2)))
    assert _le(actual, bound, scale=abs(x1 / x2))


def test_quot_guard_returns_inf():
    b = est.bound_quot(np.float64(1.0), np.float64(0.1),
                       np.float64(0.5), np.float64(1.0))
    assert np.isinf(b)


def test_zero_eps_is_zero_bound():
    """Exact inputs (masked points) must give exactly-zero bounds, even at
    singular values like sqrt(0)."""
    z = np.float64(0.0)
    assert float(est.bound_sqrt(z, z)) == 0.0
    assert float(est.bound_intpow(z, z, 3)) == 0.0
    assert float(est.bound_prod(z, z, z, z)) == 0.0


@settings(max_examples=50, deadline=None)
@given(x=st.floats(min_value=1e-10, max_value=1e8), eps=POS, t=UNIT)
def test_log_bound(x, eps, t):
    """Beyond-paper Log basis: valid upper bound when eps < x."""
    if eps >= x * 0.999999:
        assert np.isinf(est.bound_log(np.float64(x), np.float64(eps)))
        return
    xi = t * eps
    actual = abs(np.log(x + xi) - np.log(x))
    bound = float(est.bound_log(np.float64(x), np.float64(eps)))
    assert _le(actual, bound, scale=abs(np.log(x)))


def test_log_qoi_retrieval():
    """Log composes through the retrieval loop with guaranteed control."""
    from repro.core.qoi import Log, Var
    from repro.core.refactor import refactor_variables
    from repro.core.retrieval import QoIRequest, retrieve_qoi_controlled
    from repro.data.synthetic import smooth_field
    data = {"P": smooth_field((2049,), 3, lo=1e3, hi=1e5)}
    arch = refactor_variables(data, method="hb", mask_zero_velocity=False)
    expr = Log(Var("P"))
    res = retrieve_qoi_controlled(arch.open(), [QoIRequest("logP", expr, 1e-4)])
    assert res.converged
    truth = np.log(data["P"])
    approx = np.asarray(expr.value({"P": res.values["P"]}))
    assert np.abs(truth - approx).max() <= res.est_errors["logP"] * (1 + 1e-9)


def test_inf_propagates_without_nan():
    inf = np.float64(np.inf)
    z = np.float64(0.0)
    assert np.isinf(est.bound_prod(z, inf, z, z))
    assert np.isinf(est.bound_intpow(z, inf, 2))
    assert np.isinf(est.bound_quot(z, z, np.float64(1.0), inf))
    assert not np.isnan(est.bound_sqrt(np.float64(4.0), inf))
