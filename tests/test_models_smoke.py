"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + no NaNs (full configs are exercised only via
the dry-run's ShapeDtypeStruct lowering)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data.batches import make_train_batch
from repro.models import transformer as T

ARCHS = configs.names()
B, S = 2, 32

# The largest reduced configs dominate tier-1 wall-clock (7-20s each just
# for jit + one train step).  Their *train* legs run nightly under -m slow;
# every arch keeps its decode_step smoke in tier-1, so family coverage
# (attention/SSM/MoE/encdec) never leaves the PR gate.  Budget asserted in
# tests/test_ci_config.py::test_tier1_time_budget_structure.
_HEAVY_TRAIN = {"zamba2-2.7b", "seamless-m4t-medium", "mamba2-780m",
                "llama4-maverick-400b-a17b"}
TRAIN_ARCHS = [pytest.param(a, marks=pytest.mark.slow)
               if a in _HEAVY_TRAIN else a for a in ARCHS]


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


def _decode_state(cfg, batch, max_seq):
    state = T.init_decode_state(cfg, batch=batch, max_seq=max_seq)
    if cfg.family == "encdec":
        state["enc_out"] = jnp.zeros((batch, max_seq, cfg.d_model),
                                     jnp.dtype(cfg.dtype))
    return state


@pytest.mark.parametrize("arch", TRAIN_ARCHS)
def test_train_step_shapes_and_finiteness(arch, key):
    cfg = configs.get_reduced(arch)
    params = T.init_params(key, cfg)
    batch = make_train_batch(cfg, batch=B, seq=S)
    logits, aux = T.forward(params, cfg, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    loss, metrics = T.loss_fn(params, cfg, batch)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: T.loss_fn(p, cfg, batch)[0])(params)
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch, key):
    cfg = configs.get_reduced(arch)
    params = T.init_params(key, cfg)
    state = _decode_state(cfg, B, S)
    token = jnp.zeros((B, 1), jnp.int32)
    logits, state2 = T.decode_step(params, cfg, state, token)
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert int(state2["pos"]) == 1
    # a second step must advance and stay finite
    logits3, state3 = T.decode_step(params, cfg, state2, token)
    assert int(state3["pos"]) == 2
    assert np.isfinite(np.asarray(logits3, np.float32)).all()


@pytest.mark.parametrize("arch", [
    pytest.param("qwen2.5-14b", marks=pytest.mark.slow),  # heaviest prefill
    "mamba2-780m", "olmoe-1b-7b", "gemma3-1b"])
def test_decode_matches_prefill(arch, key):
    """Greedy decode logits must match teacher-forced prefill logits —
    validates cache/state correctness for attention, SSM, MoE, local-window
    families."""
    cfg = configs.get_reduced(arch)
    params = T.init_params(key, cfg)
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, 8)), jnp.int32)
    full_logits, _ = T.forward(params, cfg, {"tokens": toks})
    state = _decode_state(cfg, 1, 8)
    outs = []
    for t in range(8):
        lg, state = T.decode_step(params, cfg, state, toks[:, t:t + 1])
        outs.append(np.asarray(lg[:, 0], np.float32))
    dec = np.stack(outs, axis=1)
    np.testing.assert_allclose(dec, np.asarray(full_logits, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_int8_kv_cache_decode_close_to_prefill(key):
    """Opt-in int8 KV cache (§Perf decode iter 2): logits within ~1% of the
    full-precision teacher-forced prefill."""
    cfg_ref = configs.get_reduced("qwen2.5-14b")
    cfg = cfg_ref.replace(kv_cache_dtype="int8")
    params = T.init_params(key, cfg_ref)
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, 8)), jnp.int32)
    full, _ = T.forward(params, cfg_ref, {"tokens": toks})
    state = T.init_decode_state(cfg, batch=1, max_seq=8)
    assert state["k"].dtype == jnp.int8
    outs = []
    for t in range(8):
        lg, state = T.decode_step(params, cfg, state, toks[:, t:t + 1])
        outs.append(np.asarray(lg[:, 0], np.float32))
    dec = np.stack(outs, axis=1)
    rel = np.abs(dec - np.asarray(full, np.float32)).max() / \
        np.abs(np.asarray(full)).max()
    assert rel < 0.05, rel


def test_ssd_chunked_equals_naive_recurrence():
    from repro.models.ssm import _ssd_chunked
    cfg = configs.get_reduced("mamba2-780m").replace(ssm_chunk=8)
    rng = np.random.default_rng(0)
    b, s, h, p, n, g = 2, 32, 4, 16, 16, 1
    xh = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.5, (b, s, h)), jnp.float32)
    a = jnp.asarray(rng.uniform(0.1, 2.0, (h,)), jnp.float32)
    bm = jnp.asarray(rng.standard_normal((b, s, g, n)), jnp.float32)
    cm = jnp.asarray(rng.standard_normal((b, s, g, n)), jnp.float32)
    y, final = _ssd_chunked(cfg, xh, dt, a, bm, cm)
    hstate = np.zeros((b, h, n, p))
    ys = np.zeros((b, s, h, p))
    xh_, dt_, a_, bm_, cm_ = map(np.asarray, (xh, dt, a, bm, cm))
    for t in range(s):
        dec = np.exp(-dt_[:, t] * a_[None, :])
        bh = np.repeat(bm_[:, t], h // g, axis=1)
        ch = np.repeat(cm_[:, t], h // g, axis=1)
        hstate = hstate * dec[..., None, None] \
            + dt_[:, t, :, None, None] * bh[..., None] * xh_[:, t, :, None, :]
        ys[:, t] = np.einsum("bhn,bhnp->bhp", ch, hstate)
    np.testing.assert_allclose(np.asarray(y), ys, atol=1e-4)
    np.testing.assert_allclose(np.asarray(final), hstate, atol=1e-4)


def test_moe_dispatch_variants_agree():
    """Sort-based dispatch (§Perf variant) == one-hot dispatch."""
    from repro.models.moe import init_moe, moe_block
    cfg = configs.get_reduced("olmoe-1b-7b").replace(capacity_factor=8.0)
    p = init_moe(jax.random.PRNGKey(1), cfg)
    x = jnp.asarray(np.random.default_rng(2).standard_normal(
        (2, 16, cfg.d_model)), jnp.float32)
    y1, _ = moe_block(p, cfg, x, dispatch="onehot")
    y2, _ = moe_block(p, cfg, x, dispatch="sort")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-5)


def test_full_configs_have_assigned_numbers():
    """The public configs carry the exact assigned hyperparameters."""
    c = configs.get("qwen2.5-14b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (48, 5120, 40, 8, 13824, 152064)
    c = configs.get("gemma3-1b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (26, 1152, 4, 1, 6912, 262144)
    assert c.local_global_period == 6
    c = configs.get("llama4-maverick-400b-a17b")
    assert (c.n_experts, c.top_k, c.d_ff, c.vocab) == (128, 1, 8192, 202048)
    c = configs.get("olmoe-1b-7b")
    assert (c.n_experts, c.top_k) == (64, 8)
    c = configs.get("mamba2-780m")
    assert (c.n_layers, c.d_model, c.ssm_state) == (48, 1536, 128)
    c = configs.get("zamba2-2.7b")
    assert (c.n_layers, c.d_model, c.ssm_state, c.d_ff) == (54, 2560, 64, 10240)
    c = configs.get("glm4-9b")
    assert (c.n_layers, c.d_model, c.n_kv_heads, c.d_ff) == (40, 4096, 2, 13696)
    c = configs.get("internlm2-1.8b")
    assert (c.n_layers, c.d_model, c.n_kv_heads, c.vocab) == (24, 2048, 8, 92544)
    c = configs.get("seamless-m4t-medium")
    assert (c.n_layers, c.n_encoder_layers, c.d_model, c.vocab) == (12, 12, 1024, 256206)
    c = configs.get("phi-3-vision-4.2b")
    assert (c.n_layers, c.d_model, c.n_heads, c.vocab) == (32, 3072, 32, 32064)
