"""HTTP transport path: ranged-GET ByteStore against an in-process server,
retry/backoff under injected faults, read coalescing, sharded containers
with mixed/per-shard backends, and the cross-session segment cache."""
import os

import numpy as np
import pytest

from repro.core.refactor import METHODS, refactor_variables
from repro.data.synthetic import ge_like_fields
from repro.options import OpenOptions
from repro.store import (
    FileByteStore,
    HTTPByteStore,
    MemoryByteStore,
    SegmentCache,
    open_archive,
    save_archive,
    save_sharded_archive,
)
from repro.store.httpd import StoreHTTPServer, parse_range, transient_faults


def _vel_fields(n=1 << 10, seed=0):
    fields = ge_like_fields(n=n, seed=seed)
    return {k: fields[k] for k in ("Vx", "Vy", "Vz")}


@pytest.fixture(scope="module")
def vel():
    return _vel_fields()


@pytest.fixture(scope="module")
def hb_archive(vel):
    return refactor_variables(vel, method="hb")


@pytest.fixture()
def served_prs(hb_archive, tmp_path):
    """A single-file container served over loopback HTTP."""
    path = str(tmp_path / "a.prs")
    save_archive(hb_archive, path)
    with StoreHTTPServer(path) as srv:
        yield srv, path


# -------------------------------------------------------------- raw store --


def test_http_store_range_reads(served_prs):
    srv, path = served_prs
    with open(path, "rb") as fh:
        raw = fh.read()
    with HTTPByteStore(srv.url) as hs:
        assert hs.size == len(raw)
        assert hs.read(0, 16) == raw[:16]
        assert hs.read(100, 333) == raw[100:433]
        assert hs.read(len(raw) - 5, 5) == raw[-5:]
        assert hs.read(7, 0) == b""
        with pytest.raises(ValueError, match="negative"):
            hs.read(0, -1)
        with pytest.raises(EOFError):
            hs.read(len(raw) - 2, 5)


def test_http_read_batch_coalesces_adjacent(served_prs):
    srv, path = served_prs
    with open(path, "rb") as fh:
        raw = fh.read()
    with HTTPByteStore(srv.url, coalesce_gap=64) as hs:
        assert hs.size == len(raw)          # force the lazy HEAD probe
        before = hs.stats.requests
        got = hs.read_batch([(0, 10), (10, 20), (35, 5), (4000, 8)])
        # first three ranges are adjacent/within-gap -> one GET; the distant
        # one gets its own
        assert hs.stats.requests - before == 2
        assert hs.stats.coalesced_ranges == 2
        assert hs.stats.wasted_bytes == 5          # the [30, 35) gap
        assert got == [raw[0:10], raw[10:30], raw[35:40], raw[4000:4008]]
        # call order is preserved even when offsets are unsorted
        got = hs.read_batch([(50, 4), (0, 4), (54, 4)])
        assert got == [raw[50:54], raw[0:4], raw[54:58]]


@pytest.mark.parametrize("method", METHODS)
def test_http_roundtrip_bit_identical(method, vel, tmp_path):
    """All four methods reconstruct bit-identically through HTTPByteStore
    against an in-process HTTP server — including a transient 500 absorbed
    by the retry path — with identical achieved bounds and accounting."""
    arch = refactor_variables(vel, method=method)
    path = str(tmp_path / "a.prs")
    save_archive(arch, path)
    mem = arch.open()
    with StoreHTTPServer(path,
                         fault_injector=transient_faults(1)) as srv:
        hs = HTTPByteStore(srv.url, backoff_s=0.01)
        with open_archive(hs) as sa:
            st = sa.open()
            for eps in (1e-2, 1e-5):
                for v in vel:
                    a, ba = mem.reconstruct(v, eps)
                    b, bb = st.reconstruct(v, eps)
                    np.testing.assert_array_equal(a, b)
                    assert ba == bb
            assert mem.bytes_retrieved == st.bytes_retrieved
        assert hs.stats.retries >= 1            # the injected 500 was absorbed
        assert srv.stats["faults"] >= 1
        assert srv.stats["range_requests"] > 0  # ranged GETs, not full reads


def test_http_store_rejects_io_after_close(served_prs):
    srv, _ = served_prs
    hs = HTTPByteStore(srv.url)
    hs.read(0, 8)
    hs.close()
    with pytest.raises(ValueError, match="closed"):
        hs.read(0, 8)
    with pytest.raises(ValueError, match="closed"):
        hs.read_batch([(0, 8)])


def test_batched_prefetch_attributes_corruption_to_its_segment(served_prs):
    """One corrupt segment in a coalesced HTTP batch must fail ONLY its own
    key (with its own name in the error); batch-mates still deliver."""
    from repro.store import ChecksumError
    srv, _ = served_prs
    with open_archive(HTTPByteStore(srv.url),
                      OpenOptions(prefetch_workers=2)) as sa:
        keys = sorted(sa.fetcher.index)[:6]
        bad = keys[2]
        entry = sa.fetcher.index[bad]
        sa.fetcher.index[bad] = type(entry)(
            offset=entry.offset, size=entry.size,
            crc=entry.crc ^ 0xBEEF, blob=entry.blob)
        sa.fetcher.prefetch(keys)           # one _run_batch over the blob
        sa.fetcher.drain()
        for k in keys:
            if k == bad:
                with pytest.raises(ChecksumError, match=repr(bad)):
                    sa.fetcher.fetch(k)
            else:
                assert len(sa.fetcher.fetch(k)) == sa.fetcher.index[k].size


def test_http_retry_gives_up_on_persistent_errors(served_prs):
    srv, _ = served_prs
    srv.fault_injector = transient_faults(10 ** 6)
    try:
        hs = HTTPByteStore(srv.url, max_retries=2, backoff_s=0.001)
        with pytest.raises(IOError, match="giving up"):
            hs.read(0, 4)
    finally:
        srv.fault_injector = None


def test_http_size_is_lazy_and_manifest_fetch_is_one_get(vel, hb_archive,
                                                         tmp_path):
    """Opening a store never HEAD-probes when the size is already known:
    the sharded manifest arrives in ONE plain GET, and each shard store
    gets its size from manifest['blobs'] instead of a HEAD round-trip."""
    d = str(tmp_path / "shards")
    save_sharded_archive(hb_archive, d, shard_by="variable")
    with StoreHTTPServer(d) as srv:
        with open_archive(srv.url_for("manifest.json")) as sa:
            sa.open().reconstruct("Vx", 1e-4)
            n_req = srv.stats["requests"]
            # every server request past the manifest GET was a ranged read
            assert srv.stats["range_requests"] == n_req - 1


def test_http_manifest_url_with_query_string(vel, hb_archive, tmp_path):
    """Signed/parameterized manifest URLs (query after the filename) must
    still hit the sharded-manifest branch."""
    d = str(tmp_path / "shards")
    save_sharded_archive(hb_archive, d, shard_by="variable")
    mem = hb_archive.open()
    with StoreHTTPServer(d) as srv:
        url = srv.url_for("manifest.json") + "?X-Sig=abc123&expires=9"
        with open_archive(url) as sa:
            a, _ = sa.open().reconstruct("Vy", 1e-4)
            b, _ = mem.reconstruct("Vy", 1e-4)
            np.testing.assert_array_equal(a, b)


def test_http_store_matches_remote_link_model(hb_archive, served_prs):
    """The real HTTP backend and the modelled RemoteByteStore deliver the
    same bytes for the same session; HTTP moves no more payload than the
    link model says (it may move *fewer* requests, via coalescing)."""
    from repro.store import RemoteByteStore
    srv, path = served_prs
    remote = RemoteByteStore(FileByteStore(path), latency_s=1e-6,
                             bandwidth_bps=1e10)
    with open_archive(remote) as ra, \
            open_archive(HTTPByteStore(srv.url)) as ha:
        r, h = ra.open(), ha.open()
        for eps in (1e-2, 1e-6):
            a, _ = r.reconstruct("Vx", eps)
            b, _ = h.reconstruct("Vx", eps)
            np.testing.assert_array_equal(a, b)
        assert ra.fetcher.stats.bytes_fetched == ha.fetcher.stats.bytes_fetched
        http_store = ha.fetcher.store
        assert http_store.stats.requests <= remote.stats.requests
        assert http_store.stats.bytes_moved - http_store.stats.wasted_bytes \
            <= remote.stats.bytes_moved


def test_parse_range_forms():
    assert parse_range("bytes=0-9", 100) == (0, 9)
    assert parse_range("bytes=10-", 100) == (10, 99)
    assert parse_range("bytes=-7", 100) == (93, 99)
    assert parse_range("bytes=0-1000", 100) == (0, 99)   # clamped
    assert parse_range("bytes=0-0,5-9", 100) is None     # multi-range -> 200
    with pytest.raises(ValueError):
        parse_range("bytes=100-", 100)                   # start past EOF
    with pytest.raises(ValueError):
        parse_range("bytes=9-3", 100)                    # inverted


def test_parse_range_zero_length_resource():
    """Any range on an empty resource is unsatisfiable (RFC 9110): the
    suffix form used to come back as the invalid pair (0, -1)."""
    for header in ("bytes=-7", "bytes=-1", "bytes=0-", "bytes=0-0"):
        with pytest.raises(ValueError):
            parse_range(header, 0)
    assert parse_range("bytes=-", 0) is None             # malformed -> 200


def test_http_conditional_get_etag_lists(served_prs):
    """RFC 9110 §13.1.2 ``If-None-Match`` handling: comma-separated
    candidate lists, ``W/`` weak prefixes (on either side of the compare),
    and commas *inside* quoted entity-tags (legal ``etagc``) must all
    revalidate correctly — a naive ``split(",")`` mis-parses the last."""
    import http.client

    srv, _ = served_prs
    host, port = srv.server_address[:2]
    conn = http.client.HTTPConnection(host, port, timeout=10)
    try:
        # learn the real (quoted, strong) etag from an unconditional GET
        conn.request("GET", "/a.prs", headers={"Range": "bytes=0-0"})
        resp = conn.getresponse()
        resp.read()
        etag = resp.getheader("ETag")
        assert etag and etag.startswith('"')

        def probe(inm):
            conn.request("GET", "/a.prs", headers={"If-None-Match": inm})
            r = conn.getresponse()
            r.read()
            return r.status

        before = srv.stats["not_modified"]
        # multi-candidate list containing the current etag
        assert probe(f'"stale-1", {etag}, "stale-2"') == 304
        # weak candidate: weak comparison ignores W/ on the client side
        assert probe(f"W/{etag}") == 304
        # a candidate with a comma INSIDE its quotes must not split the
        # list and hide the real etag behind it
        assert probe(f'"sha,256-abc", {etag}') == 304
        # ...nor may the comma-carrying stale tag spuriously match
        assert probe('"sha,256-abc", "stale"') == 200
        assert probe('"nope"') == 200
        assert probe("*") == 304
        assert srv.stats["not_modified"] - before == 4
    finally:
        conn.close()


def test_http_416_on_zero_length_resource(tmp_path):
    """End to end: a suffix Range against an empty file answers 416 with an
    empty body and a ``bytes */0`` Content-Range, not a hung/garbage 206."""
    import http.client

    path = str(tmp_path / "empty.seg")
    with open(path, "wb"):
        pass
    with StoreHTTPServer(path) as srv:
        host, port = srv.server_address[:2]
        conn = http.client.HTTPConnection(host, port, timeout=10)
        try:
            conn.request("GET", "/empty.seg",
                         headers={"Range": "bytes=-16"})
            resp = conn.getresponse()
            body = resp.read()
            assert resp.status == 416
            assert body == b""
            assert resp.getheader("Content-Range") == "bytes */0"
            # plain GET of the empty resource still answers 200/empty
            conn.request("GET", "/empty.seg")
            resp = conn.getresponse()
            assert resp.status == 200 and resp.read() == b""
        finally:
            conn.close()


# ------------------------------------------------------- sharded archives --


@pytest.mark.parametrize("shard_by", ("variable", "group"))
def test_sharded_dir_roundtrip(shard_by, vel, hb_archive, tmp_path):
    d = str(tmp_path / "shards")
    save_sharded_archive(hb_archive, d, shard_by=shard_by)
    names = set(os.listdir(d))
    assert "manifest.json" in names
    if shard_by == "variable":
        assert {"Vx.seg", "Vy.seg", "Vz.seg"} <= names
    mem = hb_archive.open()
    with open_archive(d) as sa:
        st = sa.open()
        for v in vel:
            a, ba = mem.reconstruct(v, 1e-5)
            b, bb = st.reconstruct(v, 1e-5)
            np.testing.assert_array_equal(a, b)
            assert ba == bb
        assert mem.bytes_retrieved == st.bytes_retrieved


def test_sharded_http_manifest_url(vel, hb_archive, tmp_path):
    d = str(tmp_path / "shards")
    save_sharded_archive(hb_archive, d, shard_by="variable")
    mem = hb_archive.open()
    with StoreHTTPServer(d) as srv:
        with open_archive(srv.url_for("manifest.json")) as sa:
            st = sa.open()
            for v in vel:
                a, _ = mem.reconstruct(v, 1e-4)
                b, _ = st.reconstruct(v, 1e-4)
                np.testing.assert_array_equal(a, b)
        assert srv.stats["range_requests"] > 0


def test_sharded_mixed_backends_per_shard(vel, hb_archive, tmp_path):
    """One shard from RAM, one from a local file, one over HTTP — the
    blob-resolver decides per shard; reconstruction is bit-identical."""
    d = str(tmp_path / "shards")
    save_sharded_archive(hb_archive, d, shard_by="variable")
    with open(os.path.join(d, "Vx.seg"), "rb") as fh:
        vx_bytes = fh.read()
    mem = hb_archive.open()
    with StoreHTTPServer(d) as srv:
        def resolver(blob):
            if blob == "Vx.seg":
                return MemoryByteStore(vx_bytes)
            if blob == "Vy.seg":
                return HTTPByteStore(srv.url_for(blob))
            return FileByteStore(os.path.join(d, blob))

        with open_archive(os.path.join(d, "manifest.json"),
                          OpenOptions(blob_resolver=resolver)) as sa:
            st = sa.open()
            for v in vel:
                a, _ = mem.reconstruct(v, 1e-5)
                b, _ = st.reconstruct(v, 1e-5)
                np.testing.assert_array_equal(a, b)


def test_dropped_shard_only_degrades_its_variable(vel, hb_archive, tmp_path):
    d = str(tmp_path / "shards")
    save_sharded_archive(hb_archive, d, shard_by="variable")
    os.unlink(os.path.join(d, "Vz.seg"))
    mem = hb_archive.open()
    with open_archive(d, OpenOptions(prefetch_workers=0)) as sa:
        st = sa.open()
        a, _ = st.reconstruct("Vx", 1e-5)       # untouched shards still serve
        b, _ = mem.reconstruct("Vx", 1e-5)
        np.testing.assert_array_equal(a, b)
        # the lost shard's variable degrades instead of raising: the session
        # pins it at zero deliverable planes and certifies the (loose) bound
        data, ach = st.reconstruct("Vz", 1e-5)
        assert np.max(np.abs(vel["Vz"] - data)) <= ach * (1 + 1e-12)
        avail = st.availability()
        assert set(avail) == {"Vz"} and st.degraded
        assert avail["Vz"].pinned and np.isfinite(avail["Vz"].floor)
        # untouched variables stay healthy and un-pinned
        assert not mem.degraded


# ------------------------------------------------------ cross-session cache --


def test_cross_session_cache_drops_store_fetches(hb_archive, tmp_path):
    """Two sequential sessions over the same variable on a served store:
    the second session's store-level fetch count collapses — its segments
    are served from the shared SegmentCache."""
    path = str(tmp_path / "a.prs")
    save_archive(hb_archive, path)
    with StoreHTTPServer(path) as srv:
        cache = SegmentCache(max_bytes=64 << 20)
        with open_archive(HTTPByteStore(srv.url),
                          OpenOptions(cache=cache)) as sa:
            s1 = sa.open()
            a, _ = s1.reconstruct("Vx", 1e-6)
            reads_1 = sa.fetcher.stats.store_reads
            assert reads_1 > 0
            s2 = sa.open()
            b, _ = s2.reconstruct("Vx", 1e-6)
            reads_2 = sa.fetcher.stats.store_reads - reads_1
            np.testing.assert_array_equal(a, b)
            assert s1.bytes_retrieved == s2.bytes_retrieved
            # "drops measurably": second session reads (almost) nothing from
            # the store — everything shared comes out of the cache
            assert reads_2 <= reads_1 // 10
            assert sa.fetcher.stats.cache_hits > 0
            assert cache.stats.hits >= sa.fetcher.stats.cache_hits


def test_cache_is_shared_across_archive_opens(hb_archive, tmp_path):
    """The cache outlives a StoreArchive: a fresh open_archive over the same
    container (a new client process connecting to the same store) reuses it,
    keyed by segment crc."""
    path = str(tmp_path / "a.prs")
    save_archive(hb_archive, path)
    cache = SegmentCache()
    with open_archive(path, OpenOptions(cache=cache)) as sa:
        sa.open().reconstruct("Vy", 1e-5)
        first_reads = sa.fetcher.stats.store_reads
    with open_archive(path, OpenOptions(cache=cache)) as sa:
        sa.open().reconstruct("Vy", 1e-5)
        assert sa.fetcher.stats.store_reads <= first_reads // 10
        assert sa.fetcher.stats.cache_hits > 0


def test_unverified_fetcher_never_populates_shared_cache(hb_archive,
                                                         tmp_path):
    """verify=False trusts the transport for ITS OWN session, but must not
    publish unverified bytes to a cache whose hits skip re-hashing."""
    path = str(tmp_path / "a.prs")
    save_archive(hb_archive, path)
    cache = SegmentCache()
    with open_archive(path, OpenOptions(verify=False, cache=cache)) as sa:
        sa.open().reconstruct("Vx", 1e-4)
        assert cache.stats.insertions == 0
        assert len(cache) == 0
    with open_archive(path, OpenOptions(verify=True, cache=cache)) as sa:
        sa.open().reconstruct("Vx", 1e-4)
        assert cache.stats.insertions > 0


def test_cache_lru_eviction_bounds_memory():
    cache = SegmentCache(max_bytes=1000)
    for i in range(20):
        cache.put(("k", i), bytes(100))
    assert cache.nbytes <= 1000
    assert len(cache) == 10
    assert cache.stats.evictions == 10
    assert cache.get(("k", 19)) is not None     # newest survives
    assert cache.get(("k", 0)) is None          # oldest evicted
    # oversized entries are refused rather than wiping the cache
    cache.put(("big", 0), bytes(2000))
    assert ("big", 0) not in cache
    with pytest.raises(ValueError):
        SegmentCache(max_bytes=0)
