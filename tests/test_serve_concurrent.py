"""Concurrent multi-tenant serve plane: worker pool + per-session locks +
load shedding (repro.serve.pool), cross-session request coalescing
(repro.serve.coalesce), the server-wide pooled contribution budget
(repro.serve.budget), thread-safety of the shared stats sinks and the
SegmentCache, idempotent archive creation, and the /health + /metrics +
ETag surface of repro.store.httpd.

The load-bearing contracts:

  * coalesced duplicate tighten requests perform at most ONE store fetch
    per shared segment, and every concurrent result is bit-identical to a
    sequential single-client retrieval at the same tolerance;
  * pooled-budget denials/reclaims only ever cost recompute — never
    correctness — and every lease is returned on session close;
  * shared mutable stats (FetchStats/ContribStats) and the SegmentCache
    lose no updates under thread races, and cache floors hold while
    archives race;
  * two servers booting on the same missing --store path refactor once
    and never publish a half-written container.
"""
import json
import os
import threading
import time
import urllib.request

import numpy as np
import pytest

from repro.core.refactor import ContribStats, refactor_variables
from repro.data.synthetic import ge_like_fields
from repro.launch.serve import Request, RetrievalServer, ensure_archive
from repro.options import OpenOptions, SessionOptions
from repro.serve import (ContribBudgetPool, LatencyHistogram,
                         ReconstructCoalescer, ServePlane,
                         ServerOverloadedError, render_metrics)
from repro.store import (MemoryByteStore, SegmentCache, memory_store_archive,
                         open_archive, save_archive)
from repro.store.bytestore import HTTPByteStore
from repro.store.fetcher import FetchStats
from repro.store.httpd import StoreHTTPServer


def _vel_fields(n=1 << 10, seed=0):
    fields = ge_like_fields(n=n, seed=seed)
    return {k: fields[k] for k in ("Vx", "Vy", "Vz")}


@pytest.fixture(scope="module")
def vel():
    return _vel_fields()


@pytest.fixture(scope="module")
def hb_archive(vel):
    return refactor_variables(vel, method="hb")


class _GatedStore(MemoryByteStore):
    """A ByteStore whose reads can be blocked on demand — pins a leader
    flight inside its first fetch so waiters deterministically join it.
    The gate starts open (archive/session setup reads pass through)."""

    def __init__(self, data: bytes):
        super().__init__(data)
        self.gate = threading.Event()
        self.gate.set()

    def read(self, offset: int, length: int) -> bytes:
        if not self.gate.wait(30):
            raise TimeoutError("gated store never released")
        return super().read(offset, length)


# ------------------------------------------------------------- coalescing --


def test_coalesced_duplicates_fetch_each_segment_once(vel, hb_archive):
    """N concurrent identical tighten requests: one leader flight, N-1
    adoptions, and the store sees EXACTLY the reads a single session
    would issue — at most one fetch per shared segment."""
    n_dup, var, eps = 5, "Vx", 1e-5
    # baseline: the store reads one session alone needs (prediction off so
    # the count is deterministic)
    with memory_store_archive(hb_archive) as sa:
        s = sa.open(SessionOptions(prefetch_depth=0))
        s.reconstruct(var, eps)
        baseline_reads = sa.fetcher.stats.store_reads

    from repro.store.container import build_sharded_container, StoreArchive
    manifest, payloads = build_sharded_container(hb_archive,
                                                 shard_by="single")
    manifest = json.loads(json.dumps(manifest))
    store = _GatedStore(payloads[""])
    # the shared cache is what makes waiter advances byte-free: the
    # leader's fetch populates it, waiters hit it instead of the store
    sa = StoreArchive(manifest, store, prefetch_workers=2,
                      cache=SegmentCache())
    coal = ReconstructCoalescer()
    sessions = []
    for _ in range(n_dup):
        s = sa.open(SessionOptions(prefetch_depth=0))
        s.coalescer = coal
        sessions.append(s)
    store.gate.clear()          # now pin the leader's first fetch
    results, errors = [None] * n_dup, []

    def worker(i):
        try:
            results[i] = sessions[i].reconstruct(var, eps)
        except BaseException as exc:   # noqa: BLE001 - surfaced below
            errors.append(exc)

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(n_dup)]
    threads[0].start()
    # leader is pinned inside its first store read; wait for its flight
    deadline = time.monotonic() + 30
    while coal.metrics()["inflight"] < 1:
        assert time.monotonic() < deadline, "leader flight never appeared"
        time.sleep(0.002)
    for t in threads[1:]:
        t.start()
    while coal.stats.hits < n_dup - 1:   # all waiters joined the flight
        assert time.monotonic() < deadline, "waiters never joined"
        time.sleep(0.002)
    store.gate.set()
    for t in threads:
        t.join(timeout=30)
    assert not errors, errors
    assert coal.stats.leaders == 1
    assert coal.stats.adoptions == n_dup - 1
    assert coal.stats.fallbacks == 0
    # <= 1 store fetch per shared segment: the waiters' advances were all
    # cache hits, so the store saw only the single-session read count
    assert sa.fetcher.stats.store_reads == baseline_reads
    ref, ref_bound = results[0]
    for data, bound in results[1:]:
        assert np.array_equal(ref, data)
        assert bound == ref_bound
    sa.close()


def test_concurrent_results_bit_identical_to_sequential(vel, hb_archive):
    """16 clients (mixed vars/eps, duplicates included) through the worker
    pool + coalescer reconstruct exactly what fresh sequential
    single-client sessions produce."""
    ladder = (1e-2, 1e-6)
    reqs = [(f"c{i}", v, eps) for i, (v, eps) in enumerate(
        (v, e) for e in ladder for v in sorted(vel) for _ in range(3))]
    with memory_store_archive(hb_archive,
                              OpenOptions(cache=SegmentCache())) as sa:
        coal = ReconstructCoalescer()
        sessions = {}
        mu = threading.Lock()

        def handle(req):
            client, var, eps = req
            with mu:
                s = sessions.get(client)
                if s is None:
                    s = sa.open()
                    s.coalescer = coal
                    sessions[client] = s
            return s.reconstruct(var, eps)

        with ServePlane(handle, workers=6, queue_depth=64,
                        session_key=lambda r: r[0]) as plane:
            futs = [plane.submit(r) for r in reqs]
            got = [f.result() for f in futs]

    seq = hb_archive.open()
    for (client, var, eps), (data, bound) in zip(reqs, got):
        want, want_bound = seq.reconstruct(var, eps)
        assert np.array_equal(want, data), (client, var, eps)
        assert want_bound == bound


def test_coalescer_falls_back_without_serve_hooks(hb_archive):
    """Readers lacking the serve hooks (no state_signature/adopt) still
    work through a coalescer-attached session — counted uncoalescable."""
    coal = ReconstructCoalescer()
    session = hb_archive.open()
    session.coalescer = coal
    reader = session.readers["Vx"]
    # simulate a legacy reader: hide the hooks behind a wrapper
    class _Legacy:
        def __init__(self, inner):
            self._inner = inner

        def request(self, eps):
            return self._inner.request(eps)
    session.readers["Vx"] = _Legacy(reader)
    data, bound = session.reconstruct("Vx", 1e-3)
    assert coal.stats.uncoalescable == 1
    want, _ = hb_archive.open().reconstruct("Vx", 1e-3)
    assert np.array_equal(want, data)


# ---------------------------------------------------- pool + load shedding --


def test_load_shedding_past_high_water():
    gate = threading.Event()
    plane = ServePlane(lambda req: gate.wait(10), workers=1, queue_depth=2)
    try:
        f1 = plane.submit("a")
        f2 = plane.submit("b")
        with pytest.raises(ServerOverloadedError) as ei:
            plane.submit("c")
        assert ei.value.retry_after_s >= 1.0
        assert ei.value.pending == 2 and ei.value.queue_depth == 2
        health = plane.health()
        assert health["ok"] is False and health["retry_after_s"] >= 1.0
        gate.set()
        assert f1.result(10) and f2.result(10)
        m = plane.metrics()
        assert m["shed_total"] == 1 and m["requests_total"] == 2
        assert m["errors_total"] == 0
        assert plane.health()["ok"] is True
    finally:
        plane.shutdown()


def test_per_session_serialization_and_cross_session_parallelism():
    """Same-session requests must serialize; different sessions overlap."""
    active = {"n": 0, "max": 0, "overlap_same": False}
    mu = threading.Lock()

    def handler(req):
        session, _ = req
        with mu:
            active["n"] += 1
            active["max"] = max(active["max"], active["n"])
            active.setdefault(session, 0)
            active[session] += 1
            if active[session] > 1:
                active["overlap_same"] = True
        time.sleep(0.02)
        with mu:
            active["n"] -= 1
            active[session] -= 1

    with ServePlane(handler, workers=4, queue_depth=64,
                    session_key=lambda r: r[0]) as plane:
        futs = [plane.submit((f"s{j % 2}", j)) for j in range(8)]
        for f in futs:
            f.result(10)
    assert not active["overlap_same"], \
        "two requests of one session ran concurrently"
    assert active["max"] >= 2, "distinct sessions never overlapped"


def test_plane_rejects_after_shutdown_and_counts_errors():
    plane = ServePlane(lambda req: 1 / 0, workers=1, queue_depth=4)
    fut = plane.submit("x")
    with pytest.raises(ZeroDivisionError):
        fut.result(10)
    assert plane.metrics()["errors_total"] == 1
    plane.shutdown()
    with pytest.raises(RuntimeError):
        plane.submit("y")


def test_latency_histogram_quantiles_and_render():
    h = LatencyHistogram()
    for ms in (1, 1, 1, 1, 2, 2, 5, 5, 20, 400):
        h.observe(ms / 1e3)
    snap = h.snapshot()
    assert snap["count"] == 10
    assert 0.5 <= snap["p50_ms"] <= 3.0
    assert snap["p99_ms"] >= 100
    assert snap["max_ms"] >= 400
    text = render_metrics({"b_total": 2.0, "a_total": 1.0})
    assert text.splitlines() == ["a_total 1", "b_total 2"]


# ------------------------------------------------------ pooled contribution --


class _Owner:
    """Stand-in for a pooled bitplane reader: slot dict + the pool's
    deposit/clear callback."""

    def __init__(self):
        self.slots = {}

    def _pool_set_contrib(self, slot, value):
        if value is None:
            self.slots.pop(slot, None)
        else:
            self.slots[slot] = value


def test_pool_grant_touch_release_accounting():
    pool = ContribBudgetPool(total_bytes=100)
    a = _Owner()
    assert pool.retain(a, slot=0, level=0, nbytes=60, value="x")
    assert a.slots[0] == "x" and pool.holds(a, 0)
    assert pool.borrowed_bytes == 60
    assert pool.retain(a, slot=0, level=0, nbytes=60, value="x2")  # touch
    assert a.slots[0] == "x2" and pool.borrowed_bytes == 60
    assert pool.stats.touches == 1 and pool.stats.grants == 1
    pool.release(a, 0)
    assert not pool.holds(a, 0) and pool.borrowed_bytes == 0
    assert 0 not in a.slots
    # oversize request: denied outright
    assert not pool.retain(a, slot=1, level=0, nbytes=101, value="y")
    assert pool.stats.denials == 1


def test_pool_reclaims_strictly_worse_scored_leases():
    pool = ContribBudgetPool(total_bytes=100, depth_weight=4.0)
    coarse, fine = _Owner(), _Owner()
    # two coarse (deep-level) holdings fill the pool
    assert pool.retain(coarse, slot=5, level=5, nbytes=50, value="c5")
    assert pool.retain(coarse, slot=6, level=6, nbytes=50, value="c6")
    # a fine-level request reclaims them (worse depth-weighted scores)
    assert pool.retain(fine, slot=0, level=0, nbytes=80, value="f0")
    assert fine.slots[0] == "f0"
    assert not pool.holds(coarse, 6) and 6 not in coarse.slots
    assert pool.stats.reclaims >= 1
    assert pool.borrowed_bytes <= 100


def test_pool_grant_reclaims_multiple_victims_atomically():
    """A fresh request may reclaim SEVERAL strictly-worse-scored leases in
    one shot; every victim's slot is cleared under the pool lock."""
    pool = ContribBudgetPool(total_bytes=100, depth_weight=0.0)
    a, b, c = _Owner(), _Owner(), _Owner()
    assert pool.retain(a, slot=0, level=0, nbytes=40, value="a")
    assert pool.retain(b, slot=0, level=0, nbytes=60, value="b")
    # needs both resident leases (strictly staler ticks) reclaimed
    assert pool.retain(c, slot=0, level=0, nbytes=95, value="c")
    assert c.slots[0] == "c"
    assert not pool.holds(a, 0) and not pool.holds(b, 0)
    assert a.slots == {} and b.slots == {}
    assert pool.borrowed_bytes == 95
    assert pool.stats.reclaims == 2


def test_pool_denial_never_partially_evicts():
    """When even reclaiming every worse-scored lease cannot make room, the
    pool denies WITHOUT evicting anyone — a denied request must not churn
    other readers' caches."""
    pool = ContribBudgetPool(total_bytes=100, depth_weight=10.0)
    owners = [_Owner() for _ in range(2)]
    assert pool.retain(owners[0], slot=0, level=0, nbytes=50, value="a")
    assert pool.retain(owners[1], slot=0, level=0, nbytes=50, value="b")
    # a deep-level requester scores BELOW both fine-level residents:
    # no strictly-worse victims exist, so it is denied outright
    deep = _Owner()
    assert not pool.retain(deep, slot=0, level=9, nbytes=50, value="c")
    assert pool.holds(owners[0], 0) and pool.holds(owners[1], 0)
    assert owners[0].slots[0] == "a" and owners[1].slots[0] == "b"
    assert deep.slots == {}
    assert pool.stats.denials == 1
    assert pool.stats.reclaims == 0


def test_pooled_budget_bit_identical_and_released_on_close(vel, hb_archive):
    """A tiny shared pool forces spills/reclaims across sessions, yet every
    reconstruction matches the unbounded reader bit for bit; closing the
    sessions returns every lease."""
    unbounded = hb_archive.open()
    pool = ContribBudgetPool(total_bytes=64 << 10, depth_weight=4.0)
    with memory_store_archive(hb_archive) as sa:
        s1 = sa.open(SessionOptions.pooled(pool))
        s2 = sa.open(SessionOptions.pooled(pool))
        for eps in (1e-2, 1e-4, 1e-6):
            for v in sorted(vel):
                want, want_bound = unbounded.reconstruct(v, eps)
                for s in (s1, s2):
                    got, bound = s.reconstruct(v, eps)
                    assert np.array_equal(want, got), (v, eps)
                    assert bound == want_bound
                assert pool.borrowed_bytes <= pool.total_bytes
        st = sa.fetcher.stats
        assert st.contrib_spills + pool.stats.grants > 0
        s1.close()
        s2.close()
    assert pool.borrowed_bytes == 0
    assert pool.metrics()["leases"] == 0


# ------------------------------------------------- shared stats thread-safety --


@pytest.mark.parametrize("stats_cls", [FetchStats, ContribStats])
def test_contrib_stats_hammer_loses_no_updates(stats_cls):
    """The shared contrib sink (one FetchStats per fetcher serves EVERY
    session's readers) under 8 threads of racing read-modify-write: totals
    must be exact, not approximately right."""
    st = stats_cls()
    n_threads, n_ops = 8, 2000
    start = threading.Barrier(n_threads)

    def worker():
        start.wait()
        for _ in range(n_ops):
            st.contrib_note(delta_bytes=3, spills=1, recomputes=1)
            st.contrib_note(delta_bytes=-1)

    threads = [threading.Thread(target=worker) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    resident, peak, spills, recomputes = st.contrib_snapshot()
    assert resident == n_threads * n_ops * 2
    assert spills == n_threads * n_ops
    assert recomputes == n_threads * n_ops
    assert peak >= resident


def test_one_fetcher_many_threads_bit_identical(vel, hb_archive):
    """Many sessions hammering ONE fetcher (the --store serving shape:
    shared FetchStats sink, shared cache) from concurrent threads — every
    result bit-identical, accounting self-consistent."""
    with memory_store_archive(hb_archive,
                              OpenOptions(cache=SegmentCache())) as sa:
        want = {(v, e): hb_archive.open().reconstruct(v, e)
                for v in sorted(vel) for e in (1e-3, 1e-6)}
        errors = []

        def worker(seed):
            rng = np.random.default_rng(seed)
            s = sa.open()
            names = sorted(vel)
            rng.shuffle(names)
            # per session, eps tightens monotonically (progressive-session
            # semantics: a looser re-request returns the current state)
            for e in (1e-3, 1e-6):
                for v in names:
                    got, bound = s.reconstruct(v, e)
                    ref, ref_bound = want[(v, e)]
                    if not np.array_equal(ref, got) or bound != ref_bound:
                        errors.append((v, e))

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors, errors
        st = sa.fetcher.stats
        resident, peak, _, _ = st.contrib_snapshot()
        assert peak >= resident >= 0


# ------------------------------------------------------ cache thread-safety --


def test_segment_cache_threaded_stress_accounting_balances():
    """Seeded multi-threaded put/get storm: no lost inserts (every put is
    either resident, evicted, or admission-skipped), byte accounting
    balances exactly, and the global bound holds."""
    for admission in (False, True):
        cache = SegmentCache(max_bytes=64_000, depth_weight=8.0,
                             admission_control=admission)
        n_threads, n_ops = 8, 400
        start = threading.Barrier(n_threads)

        def worker(tid, cache=cache):
            rng = np.random.default_rng(1000 + tid)
            start.wait()
            for i in range(n_ops):
                key = (tid, i)                      # unique -> no re-puts
                size = int(rng.integers(100, 1500))
                depth = int(rng.integers(0, 12))
                arch = ("A", "B")[int(rng.integers(0, 2))]
                cache.put(key, bytes(size), depth=depth, archive=arch)
                cache.get((int(rng.integers(0, n_threads)),
                           int(rng.integers(0, n_ops))))

        threads = [threading.Thread(target=worker, args=(t,))
                   for t in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        st = cache.stats
        puts = n_threads * n_ops
        assert st.insertions + st.admission_skips == puts
        assert st.insertions - st.evictions == len(cache)
        assert cache.nbytes <= 64_000
        with cache._lock:
            by_hand = sum(len(e.data) for e in cache._entries.values())
            assert by_hand == cache._nbytes
            for name in list(cache._archives):
                per_arch = sum(len(e.data)
                               for e in cache._entries.values()
                               if e.archive == name)
                assert per_arch == cache._archives[name].nbytes
        if not admission:
            assert st.admission_skips == 0


def test_cache_floor_holds_under_racing_archives():
    """Archive A is filled to its floor, then threads hammer archive B:
    external pressure must never take A below archive_floor_bytes."""
    floor = 8_000
    cache = SegmentCache(max_bytes=32_000, depth_weight=0.0,
                         archive_floor_bytes=floor)
    for i in range(10):                      # 10 KiB resident for A
        cache.put(("A", i), bytes(1_000), depth=0, archive="A")
    assert cache.archive_nbytes("A") >= floor
    start = threading.Barrier(4)

    def worker(tid):
        start.wait()
        for i in range(300):
            cache.put(("B", tid, i), bytes(900), depth=0, archive="B")

    threads = [threading.Thread(target=worker, args=(t,)) for t in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert cache.archive_nbytes("A") >= floor
    assert cache.nbytes <= 32_000


def test_admission_control_skips_colder_than_resident():
    """Under pressure a deep-LSB newcomer is refused instead of evicting
    the hot MSB working set (single-threaded semantics check)."""
    cache = SegmentCache(max_bytes=3_000, depth_weight=100.0,
                         admission_control=True)
    for i in range(3):
        cache.put(("msb", i), bytes(1_000), depth=0)
        cache.get(("msb", i))
    cache.put(("lsb", 0), bytes(1_000), depth=40)
    assert cache.stats.admission_skips == 1
    assert ("lsb", 0) not in cache and len(cache) == 3
    # a hot-depth insert still displaces normally
    cache.put(("msb", 99), bytes(1_000), depth=0)
    assert ("msb", 99) in cache
    assert cache.stats.evictions >= 1
    # re-putting a resident key is a refresh, never admission-checked
    cache.put(("msb", 99), bytes(1_000), depth=0)
    assert ("msb", 99) in cache


# -------------------------------------------------- idempotent archive boot --


def test_ensure_archive_races_refactor_exactly_once(tmp_path):
    """Six racing boots on one missing store path: the refactor runs once,
    exactly one caller reports having created, and the published container
    opens clean (no lock/tmp debris)."""
    vel = _vel_fields(n=1 << 8)
    path = str(tmp_path / "ge.prs")
    calls = []
    mu = threading.Lock()

    def builder():
        with mu:
            calls.append(1)
        return refactor_variables(vel, method="hb")

    created = []
    start = threading.Barrier(6)

    def worker():
        start.wait()
        created.append(ensure_archive(path, builder))

    threads = [threading.Thread(target=worker) for _ in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert sum(calls) == 1
    assert created.count(True) == 1 and created.count(False) == 5
    assert not os.path.exists(path + ".lock")
    assert not any(f.startswith("ge.prs.tmp")
                   for f in os.listdir(tmp_path))
    with open_archive(path) as sa:
        data, bound = sa.open().reconstruct("Vx", 1e-3)
        want, _ = refactor_variables(vel, method="hb") \
            .open().reconstruct("Vx", 1e-3)
        assert np.array_equal(want, data)


def test_ensure_archive_existing_and_stale_lock(tmp_path):
    vel = _vel_fields(n=1 << 8)
    path = str(tmp_path / "ge.prs")
    # existing container: no builder call, returns False
    save_archive(refactor_variables(vel, method="hb"), path)
    assert ensure_archive(path, builder=lambda: pytest.fail(
        "builder must not run for an existing container")) is False
    # stale lock from a crashed creator: broken and creation proceeds
    path2 = str(tmp_path / "ge2.prs")
    lock = path2 + ".lock"
    with open(lock, "w") as fh:
        fh.write("999999\n")
    os.utime(lock, (time.time() - 3600, time.time() - 3600))
    assert ensure_archive(path2,
                          lambda: refactor_variables(vel, method="hb"),
                          stale_lock_s=60.0) is True
    assert os.path.exists(path2) and not os.path.exists(lock)
    # a LIVE lock makes waiters time out rather than corrupt
    path3 = str(tmp_path / "ge3.prs")
    with open(path3 + ".lock", "w") as fh:
        fh.write("1\n")
    with pytest.raises(TimeoutError):
        ensure_archive(path3, builder=lambda: pytest.fail("must not build"),
                       wait_timeout_s=0.2, poll_s=0.02)
    os.unlink(path3 + ".lock")


# ------------------------------------------------- /health /metrics + ETag --


def _get(url, headers=None, method="GET"):
    req = urllib.request.Request(url, headers=headers or {}, method=method)
    try:
        with urllib.request.urlopen(req, timeout=10) as resp:
            return resp.status, dict(resp.headers), resp.read()
    except urllib.error.HTTPError as e:
        return e.code, dict(e.headers), e.read()


def test_health_and_metrics_endpoints_under_concurrency(tmp_path):
    """Tier-1 smoke: boot a concurrent RetrievalServer over a real store
    path, expose /health + /metrics over repro.store.httpd, and drive 8
    concurrent clients — endpoints answer throughout, counters land."""
    fields = ge_like_fields(n=1 << 10, seed=0)
    path = str(tmp_path / "ge.prs")
    server = RetrievalServer(fields, method="hb", store_path=path,
                             workers=4, queue_depth=32,
                             contrib_pool_bytes=1 << 20,
                             cache_admission=True)
    httpd = StoreHTTPServer(path, metrics_source=server.metrics,
                            health_source=server.health).start()
    try:
        status, _, body = _get(httpd.url_for("health"))
        assert status == 200 and body == b"ok\n"
        results, errors = [], []

        def client(i):
            try:
                results.append(server.handle(
                    Request(client=f"c{i}", qois=["T"], tau=1e-2)))
            except BaseException as exc:   # noqa: BLE001 - surfaced below
                errors.append(exc)

        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(8)]
        for t in threads:
            t.start()
        status, _, _ = _get(httpd.url_for("health"))
        assert status in (200, 503)        # alive while under load
        for t in threads:
            t.join()
        assert not errors, errors
        assert len(results) == 8
        assert all(r["guaranteed"] for r in results)
        status, headers, body = _get(httpd.url_for("metrics"))
        assert status == 200
        assert headers["Content-Type"].startswith("text/plain")
        metrics = {}
        for line in body.decode().splitlines():
            name, value = line.rsplit(" ", 1)
            metrics[name] = float(value)
        assert metrics["serve_requests_total"] == 8.0
        assert metrics["serve_shed_total"] == 0.0
        assert metrics["serve_latency_count"] == 8.0
        assert metrics["serve_latency_p99_ms"] >= \
            metrics["serve_latency_p50_ms"] > 0
        for key in ("serve_workers", "coalesce_leaders_total",
                    "pool_total_bytes", "cache_hits_total",
                    "fetch_store_reads_total", "contrib_peak_bytes"):
            assert key in metrics, key
        # names are unique and sorted (parseable plaintext contract)
        names = [ln.rsplit(" ", 1)[0] for ln in body.decode().splitlines()]
        assert names == sorted(names) and len(names) == len(set(names))
    finally:
        httpd.stop()
        server.close()


def test_httpd_etag_conditional_get_and_head(vel, hb_archive, tmp_path):
    path = str(tmp_path / "a.prs")
    save_archive(hb_archive, path)
    with StoreHTTPServer(path) as srv:
        status, headers, body = _get(srv.url)
        assert status == 200 and len(body) == os.path.getsize(path)
        etag = headers["ETag"]
        assert etag.startswith('"') and etag.endswith('"')
        # HEAD: same validator, no body
        status, headers, head_body = _get(srv.url, method="HEAD")
        assert status == 200 and head_body == b""
        assert headers["ETag"] == etag
        assert int(headers["Content-Length"]) == os.path.getsize(path)
        # conditional GET: matching validator -> 304, nothing re-sent
        for match in (etag, f'W/{etag}', f'"zzz", {etag}', "*"):
            status, headers, body = _get(srv.url,
                                         {"If-None-Match": match})
            assert status == 304 and body == b"", match
            assert headers["ETag"] == etag
        assert srv.stats["not_modified"] == 4
        # stale validator -> full 200
        status, _, body = _get(srv.url, {"If-None-Match": '"0-0"'})
        assert status == 200 and len(body) == os.path.getsize(path)
        # ranged reads still carry the validator
        status, headers, _ = _get(srv.url, {"Range": "bytes=0-15"})
        assert status == 206 and headers["ETag"] == etag


def test_http_bytestore_revalidates_with_if_none_match(vel, hb_archive,
                                                       tmp_path):
    path = str(tmp_path / "a.prs")
    save_archive(hb_archive, path)
    with StoreHTTPServer(path) as srv:
        with HTTPByteStore(srv.url) as hs:
            first = hs.read_all()
            assert hs.stats.not_modified == 0
            moved = hs.stats.bytes_moved
            again = hs.read_all()          # revalidation: 304, cached body
            assert again == first
            assert hs.stats.not_modified == 1
            assert hs.stats.bytes_moved == moved   # no body re-transfer
            # rewrite -> new ETag -> fresh body (never a stale mix)
            with open(path, "rb") as fh:
                data = fh.read()
            with open(path, "wb") as fh:
                fh.write(data + b"x")
            os.utime(path, (time.time() + 2, time.time() + 2))
            fresh = hs.read_all()
            assert fresh == data + b"x"
            assert hs.stats.not_modified == 1
        assert srv.stats["not_modified"] == 1


# --------------------------------------------------- batched decode ticks --


def test_batched_tick_bit_identical_to_per_reader(vel):
    """N concurrent sessions flushing their fused decodes through ONE
    shared DecodeBatcher (the batched serve tick) reconstruct exactly what
    per-reader dispatches produce — including a straggler variable whose
    unique shape matches no bucket and must take the fallback path — and
    the batcher's counters prove both routes actually ran."""
    from repro.kernels import ops
    from repro.serve import DecodeBatcher

    prev = ops.set_decode_path("fused")
    try:
        fields = dict(vel)                          # Vx/Vy/Vz, same shape
        rng = np.random.default_rng(3)
        # 2x the element count of every other variable: its finest-level
        # group has a word width (W=32) nothing else has, so its decode
        # flush is a guaranteed singleton bucket -> per-reader fallback
        fields["Wodd"] = rng.standard_normal(1 << 11)
        archive = refactor_variables(fields, method="hb")
        eps = 1e-6
        reqs = [("c0", ("Vx", "Vy", "Vz")), ("c1", ("Vx", "Vy", "Vz")),
                ("c2", ("Vx", "Vy", "Vz")), ("c3", ("Wodd",))]
        bat = DecodeBatcher(window_ms=50.0)
        barrier = threading.Barrier(len(reqs))
        with memory_store_archive(archive) as sa:
            sessions = {c: sa.open(SessionOptions(prefetch_depth=0,
                                                  decode_batcher=bat))
                        for c, _ in reqs}

            def handle(req):
                client, names = req
                barrier.wait(10)        # align: one tick, every session
                return [sessions[client].reconstruct(v, eps)
                        for v in names]

            with ServePlane(handle, workers=len(reqs), queue_depth=16,
                            session_key=lambda r: r[0],
                            decode_batcher=bat) as plane:
                futs = [plane.submit(r) for r in reqs]
                got = {r[0]: f.result(120) for r, f in zip(reqs, futs)}
                pm = plane.metrics()
        st = bat.stats.as_dict()
        assert st["decode_batched"] >= 2       # same-shape groups coalesced
        # the straggler's unique-shape groups fell back to solo dispatches
        assert st["decode_items"] > st["decode_batched"]
        assert st["decode_dispatches"] < st["decode_items"]
        assert pm["batch_decode_items"] == st["decode_items"]
        # per-reader reference: fresh fused sessions WITHOUT a batcher issue
        # one dispatch per group flush; results must match bit-for-bit
        for client, names in reqs:
            ref = archive.open()
            for (data, bound), v in zip(got[client], names):
                want, want_bound = ref.reconstruct(v, eps)
                assert np.array_equal(want.view(np.uint64),
                                      data.view(np.uint64)), (client, v)
                assert want_bound == bound
    finally:
        ops.set_decode_path(prev)


def test_batcher_straggler_shapes_dispatch_solo():
    """Deterministic fallback accounting: two concurrent submissions with
    unmatchable shapes produce two solo dispatches and zero batched items;
    two with equal shapes produce one vmapped dispatch covering both."""
    from repro.bitplane.encoder import (encode_level, inflate_planes,
                                        sign_plane_bytes)
    from repro.serve import DecodeBatcher

    def job(bat, lbp, k, out, i):
        words, shifts = inflate_planes(lbp.count, lbp.nbits,
                                       lbp.planes[:k], 0)
        sb = sign_plane_bytes(lbp.count, lbp.signs)
        scale = np.float64(2.0) ** (lbp.exponent - lbp.nbits)
        t = bat.submit_decode(words, shifts, None, sb, scale, lbp.count)
        out[i] = np.asarray(t.result()[1])

    rng = np.random.default_rng(5)
    small = encode_level(rng.standard_normal(40))
    big = encode_level(rng.standard_normal(400))
    for pair, want_batched, want_dispatches in (
            ((small, big), 0, 2),        # straggler shapes: solo fallbacks
            ((big, big), 2, 1)):         # equal shapes: one vmapped call
        bat = DecodeBatcher(window_ms=25.0)
        out = [None, None]
        threads = [threading.Thread(target=job,
                                    args=(bat, lbp, 17, out, i))
                   for i, lbp in enumerate(pair)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(30)
        st = bat.stats.as_dict()
        assert st["decode_batched"] == want_batched
        assert st["decode_dispatches"] == want_dispatches
        for lbp, vals in zip(pair, out):
            from repro.bitplane.encoder import decode_magnitudes, \
                decode_values
            want = decode_values(lbp, decode_magnitudes(lbp, 17))
            assert np.array_equal(want.view(np.uint64),
                                  vals.view(np.uint64))
