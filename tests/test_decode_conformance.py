"""Differential decode conformance: every decode path agrees bit-for-bit.

The codebase now carries THREE independent decode implementations —

  * "host"   — the numpy byte-plane fallback (reference),
  * "kernel" — the interpret-mode Pallas ``bitplane_unpack`` kernel feeding
               the host sign/scale stage,
  * "fused"  — the device-resident fused unpack + sign + scale
               (``kernels/ops.decode_values_fused``, one jit dispatch) —

selected by ``ops.set_decode_path``.  Progressive retrieval is only
trustworthy if the choice is *unobservable*: identical values (down to the
sign of zero), identical certified bounds, and identical FetchStats byte
accounting on every method, at every plane count, on both sides of the
hi/lo uint32 split (nbits=48 > 32 forces split words), for all-negative and
all-nonnegative sign planes, and across empty refinements.  This suite
pins exactly that, property-based via tests/_hypothesis_shim (the real
hypothesis package when installed, a deterministic seeded sweep otherwise).

Tier-1 by design: no ``slow`` marker — a decode-path divergence must fail
the default gate, not a nightly.
"""
import numpy as np
import pytest

from _hypothesis_shim import given, settings, strategies as st

from repro.bitplane.encoder import (DEFAULT_NBITS, decode_magnitudes,
                                    decode_prefix, decode_values,
                                    encode_level, plane_bound)
from repro.bitplane.segments import LevelStream
from repro.core.refactor import METHODS, refactor_variables
from repro.kernels import ops
from repro.options import SessionOptions
from repro.store import memory_store_archive

PATHS = ("host", "kernel", "fused")
# {0, 1} = degenerate prefixes, {47, 48} = deepest planes, {15..17, 31..33}
# = both sides of the hi/lo uint32 word split (planes 0..15 shift the hi
# word, 16..47 the lo word) and of the 32-plane mark
PLANE_COUNTS = (0, 1, 15, 16, 17, 31, 32, 33, 47, 48)


@pytest.fixture()
def restore_decode_path():
    prev = ops.decode_path()
    yield
    ops.set_decode_path(prev)


def _bits(a):
    return np.asarray(a, dtype=np.float64).view(np.uint64)


def _coeffs(n, seed, sign_mode):
    rng = np.random.default_rng(seed)
    c = rng.standard_normal(n) * np.exp(rng.uniform(-6, 6, size=n))
    if sign_mode == "all_neg":
        c = -np.abs(c) - 1e-9
    elif sign_mode == "all_nonneg":
        c = np.abs(c)
    else:
        c[rng.integers(0, 2, size=n).astype(bool)] *= -1.0
    return c


def _decode_all_paths(lbp, k):
    out = {}
    prev = ops.decode_path()
    try:
        for path in PATHS:
            ops.set_decode_path(path)
            out[path] = decode_prefix(lbp, k)
    finally:
        ops.set_decode_path(prev)
    return out


# ----------------------------------------------------- prefix decode level --


@pytest.mark.parametrize("k", PLANE_COUNTS)
@pytest.mark.parametrize("sign_mode", ("mixed", "all_neg", "all_nonneg"))
def test_prefix_decode_paths_bit_identical(k, sign_mode):
    """The plane-count x sign-plane grid: every path, every prefix depth,
    both sides of the hi/lo split, all-negative and all-nonnegative signs."""
    lbp = encode_level(_coeffs(700, seed=k * 7 + 1, sign_mode=sign_mode))
    vals = _decode_all_paths(lbp, k)
    for path in PATHS[1:]:
        assert np.array_equal(_bits(vals["host"]), _bits(vals[path])), \
            f"path {path!r} diverged from host at k={k} ({sign_mode})"
    # the certified bound is decode-path independent by construction (it is
    # metadata arithmetic) — pin it anyway so a refactor cannot couple them
    assert plane_bound(lbp, k) == plane_bound(lbp, min(k, lbp.nbits))


@settings(max_examples=15, deadline=None)
@given(data=st.data())
def test_prefix_decode_paths_bit_identical_property(data):
    """Property form: random sizes (crossing uint32-word boundaries), random
    magnitudes spanning ~12 decades, random prefix depth."""
    n = data.draw(st.sampled_from([1, 31, 32, 33, 257, 700, 1024]))
    k = data.draw(st.integers(min_value=0, max_value=DEFAULT_NBITS))
    seed = data.draw(st.integers(min_value=0, max_value=2 ** 16))
    sign_mode = data.draw(st.sampled_from(["mixed", "all_neg", "all_nonneg"]))
    lbp = encode_level(_coeffs(n, seed=seed, sign_mode=sign_mode))
    vals = _decode_all_paths(lbp, k)
    for path in PATHS[1:]:
        assert np.array_equal(_bits(vals["host"]), _bits(vals[path]))


def test_all_zero_group_every_path(restore_decode_path):
    """exponent=None groups decode to exact zeros on every path."""
    lbp = encode_level(np.zeros(100))
    assert lbp.exponent is None
    for path in PATHS:
        ops.set_decode_path(path)
        v = decode_prefix(lbp, 48)
        assert v.shape == (100,) and not v.any()


def test_shared_entry_matches_legacy_pair(restore_decode_path):
    """decode_prefix is the one decode entry point (train/checkpoint.py
    restores through it): on every path it must equal the legacy
    decode_magnitudes -> decode_values pair bit-for-bit."""
    lbp = encode_level(_coeffs(513, seed=3, sign_mode="mixed"))
    for k in (0, 1, 17, 48):
        legacy = decode_values(lbp, decode_magnitudes(lbp, k))
        for path in PATHS:
            ops.set_decode_path(path)
            assert np.array_equal(_bits(decode_prefix(lbp, k)),
                                  _bits(legacy)), (path, k)


# ------------------------------------------------ streams and refinements --


def _stream_schedule(lbp, schedule, path):
    prev = ops.set_decode_path(path)
    try:
        s = LevelStream(lbp)
        trace = []
        for k in schedule:
            moved = s.fetch_to_planes(k)
            trace.append((moved, s.bytes_fetched, s.fetched, s.bound,
                          _bits(s.values()).copy()))
        return trace
    finally:
        ops.set_decode_path(prev)


@pytest.mark.parametrize("schedule", [
    (0, 1, 1, 17, 17, 48),     # empty refinements interleaved with real ones
    (16, 16, 32, 32, 48, 48),  # refine exactly at the hi/lo boundary
    (48, 48),                  # one-shot then a no-op refinement
    (0, 0, 0),                 # nothing ever moves
])
def test_stream_refinement_schedules_identical_across_paths(schedule):
    """A LevelStream walked through any refinement schedule — including
    empty refinements (repeat requests at an already-fetched depth) — must
    report identical per-step moved bytes, cumulative bytes, plane counts,
    bounds, and decoded bits on every path.  The fused path defers its
    decode to flush time, which must never leak into the accounting."""
    lbp = encode_level(_coeffs(700, seed=11, sign_mode="mixed"))
    ref = _stream_schedule(lbp, schedule, "host")
    for path in PATHS[1:]:
        got = _stream_schedule(lbp, schedule, path)
        for step, (r, g) in enumerate(zip(ref, got)):
            assert r[:4] == g[:4], (path, step)       # bytes/counts/bound
            assert np.array_equal(r[4], g[4]), (path, step)


def test_fused_values_device_matches_host_values(restore_decode_path):
    """values_device() (the recompose feed) and values() expose the same
    bits; on the host path values_device() is absent (None)."""
    lbp = encode_level(_coeffs(700, seed=5, sign_mode="mixed"))
    ops.set_decode_path("fused")
    s = LevelStream(lbp)
    s.fetch_to_planes(33)
    dev = s.values_device()
    assert dev is not None
    assert np.array_equal(_bits(np.asarray(dev)), _bits(s.values()))
    ops.set_decode_path("host")
    s2 = LevelStream(lbp)
    s2.fetch_to_planes(33)
    assert s2.values_device() is None
    assert np.array_equal(_bits(s2.values()), _bits(s.values()))


# ------------------------------------------------------ zero-plane flushes --


def _fused_inputs(lbp, k):
    from repro.bitplane.encoder import inflate_planes, sign_plane_bytes
    m = lbp.meta()
    words, shifts = inflate_planes(m.count, m.nbits, lbp.planes[:k], 0)
    sb = sign_plane_bytes(m.count, lbp.signs)
    scale = np.float64(2.0) ** (m.exponent - m.nbits)
    return words, shifts, sb, scale


def test_zero_plane_fused_flush_is_noop():
    """A flush with ZERO new planes (e.g. a follow-mode refresh that moved
    nothing) must pass the magnitude state through untouched and decode the
    same bits — for both degenerate word layouts, (0,) and (0, 0) — and
    ``prepare_fused_decode`` must keep the group's TRUE word width for
    them, not collapse state/signs to zero-width arrays."""
    lbp = encode_level(_coeffs(700, seed=21, sign_mode="mixed"))
    m = lbp.meta()
    words, shifts, sb, scale = _fused_inputs(lbp, 17)
    mag, vals = ops.decode_values_fused(words, shifts, None, sb, scale,
                                        m.count)
    ref = _bits(np.asarray(vals)).copy()
    for empty in (np.zeros((0,), np.uint32), np.zeros((0, 0), np.uint32)):
        mag2, vals2 = ops.decode_values_fused(empty,
                                              np.zeros(0, np.uint64),
                                              mag, sb, scale, m.count)
        assert np.array_equal(np.asarray(mag2), np.asarray(mag)), empty.shape
        assert vals2.shape == (m.count,)
        assert np.array_equal(_bits(np.asarray(vals2)), ref), empty.shape
    nwords = (m.count + 31) // 32
    w, sh, st, sbp = ops.prepare_fused_decode(np.zeros((0,), np.uint32),
                                              np.zeros(0, np.uint64),
                                              mag, sb, m.count)
    assert w.shape[1] == nwords
    assert st.shape[0] == nwords * 32 and sbp.shape[0] == nwords * 4
    assert not w.any() and not sh.any()          # pure no-op planes


def test_batched_zero_plane_ticket_bit_identical(restore_decode_path):
    """A DecodeBatcher bucket containing a zero-plane item: the empty item
    keeps its group's word width (so it SHARES the bucket with a real
    same-width flush instead of forcing a stray dispatch), comes back
    shaped (count,), and matches the solo fused dispatch bit-for-bit —
    without disturbing its batch-mate."""
    from repro.serve.batch import DecodeBatcher

    ops.set_decode_path("fused")
    lbp = encode_level(_coeffs(700, seed=22, sign_mode="mixed"))
    m = lbp.meta()
    words, shifts, sb, scale = _fused_inputs(lbp, 17)
    empty_w = np.zeros((0,), np.uint32)
    empty_s = np.zeros(0, np.uint64)
    mag_a, vals_a = ops.decode_values_fused(words, shifts, None, sb, scale,
                                            m.count)
    state = np.asarray(mag_a)
    mag_b, vals_b = ops.decode_values_fused(empty_w, empty_s, state, sb,
                                            scale, m.count)
    batcher = DecodeBatcher(window_ms=0.0)
    t_real = batcher.submit_decode(words, shifts, None, sb, scale, m.count)
    t_zero = batcher.submit_decode(empty_w, empty_s, state, sb, scale,
                                   m.count)
    assert t_real.key == t_zero.key          # one shared vmapped bucket
    batcher.flush()
    got_mag_z, got_vals_z = t_zero.result()
    _, got_vals_r = t_real.result()
    stats = batcher.stats.as_dict()
    assert stats["decode_dispatches"] == 1 and stats["decode_batched"] == 2
    assert got_vals_z.shape == (m.count,)
    assert np.array_equal(_bits(np.asarray(got_vals_r)),
                          _bits(np.asarray(vals_a)))
    assert np.array_equal(_bits(np.asarray(got_vals_z)),
                          _bits(np.asarray(vals_b)))
    assert np.array_equal(np.asarray(got_mag_z), np.asarray(mag_b))


# -------------------------------------------- sessions across all methods --


def _session_run(archive, path, eps_ladder=(1e-2, 1e-5)):
    prev = ops.set_decode_path(path)
    try:
        with memory_store_archive(archive) as sa:
            session = sa.open(SessionOptions(prefetch_depth=0))
            out = []
            for eps in eps_ladder:
                for name in archive.variables:
                    data, achieved = session.reconstruct(name, eps)
                    out.append((name, eps, achieved, _bits(data).copy()))
            stats = sa.fetcher.stats
            return out, session.bytes_retrieved, stats.bytes_fetched, \
                stats.store_reads
    finally:
        ops.set_decode_path(prev)


@pytest.mark.parametrize("method", METHODS)
def test_session_paths_bit_identical_all_methods(method):
    """Store-backed progressive sessions under every method (hb / ob /
    psz3 / psz3_delta): reconstructions, certified bounds, session byte
    accounting AND the fetcher's FetchStats (bytes_fetched, store_reads)
    must not depend on the decode path."""
    rng = np.random.default_rng(2)
    fields = {"u": rng.standard_normal((33, 17)),
              "v": np.abs(rng.standard_normal(400))}    # all-nonneg signs
    archive = refactor_variables(fields, method=method)
    ref, ref_bytes, ref_fetched, ref_reads = _session_run(archive, "host")
    for path in PATHS[1:]:
        got, got_bytes, got_fetched, got_reads = _session_run(archive, path)
        assert got_bytes == ref_bytes, path
        assert got_fetched == ref_fetched, path
        assert got_reads == ref_reads, path
        for (rn, re_, rb, rv), (gn, ge_, gb, gv) in zip(ref, got):
            assert (rn, re_) == (gn, ge_)
            assert rb == gb, (path, rn, re_)
            assert np.array_equal(rv, gv), (path, rn, re_)


def test_incremental_tighten_equals_fresh_session_fused(restore_decode_path):
    """Fused path, progressive tightening: a session walked down an eps
    ladder ends bit-identical (data AND bytes) to a fresh fused session at
    the final eps — deferred flushes compose across refinements."""
    rng = np.random.default_rng(7)
    fields = {"w": rng.standard_normal((65,))}
    archive = refactor_variables(fields, method="hb")
    ops.set_decode_path("fused")
    walked = archive.open()
    for eps in (1e-1, 1e-3, 1e-6):
        data_w, _ = walked.reconstruct("w", eps)
    fresh = archive.open()
    data_f, _ = fresh.reconstruct("w", 1e-6)
    assert np.array_equal(_bits(data_w), _bits(data_f))
    assert walked.bytes_retrieved == fresh.bytes_retrieved
    # and the host reference agrees
    ops.set_decode_path("host")
    data_h, _ = archive.open().reconstruct("w", 1e-6)
    assert np.array_equal(_bits(data_h), _bits(data_f))


# ------------------------------------------------- device scatter+recompose --


def test_scatter_recompose_matches_host_scatter():
    """Device scatter+partial-recompose (the fused contribution path) is
    bit-identical to the host scatter feeding recompose_hb_from, for every
    level including the base group, and under the vmapped batch variant."""
    import jax.numpy as jnp

    from repro.transform.hierarchical import (recompose_hb_from,
                                              scatter_recompose_from,
                                              scatter_recompose_from_batch)
    rng = np.random.default_rng(9)
    field = rng.standard_normal((33, 33))
    archive = refactor_variables({"f": field}, method="hb")
    var = archive.variables["f"]
    shape, levels = var.padded_shape, var.levels
    session = archive.open()
    session.reconstruct("f", 1e-4)
    reader = session.readers["f"]
    singles, idx_b, vals_b = [], [], []
    for l in range(levels + 1):
        vals = reader.streams[l].values()
        idx = var.group_indices[l]
        start = min(l, levels - 1)
        flat = np.zeros(int(np.prod(shape)))
        flat[idx] = vals
        host = np.asarray(recompose_hb_from(flat.reshape(shape), levels,
                                            start))
        dev = np.asarray(scatter_recompose_from(jnp.asarray(idx),
                                                jnp.asarray(vals), shape,
                                                levels, start))
        assert np.array_equal(_bits(host), _bits(dev)), l
        singles.append((start, host))
    # batch variant: duplicate one level's scatter across a batch axis
    start, host = singles[0]
    idx0 = jnp.asarray(var.group_indices[0])
    vals0 = jnp.asarray(reader.streams[0].values())
    out = scatter_recompose_from_batch(jnp.stack([idx0, idx0]),
                                       jnp.stack([vals0, vals0]), shape,
                                       levels, start)
    for b in range(2):
        assert np.array_equal(_bits(np.asarray(out[b])), _bits(host))
