"""Progression in resolution (paper §II, PMGARD-HB's second axis): the
strided sub-grid reconstructs with a guaranteed bound while the finest
detail segments never move."""
import numpy as np
import pytest

from repro.core.refactor import refactor_variables
from repro.data.synthetic import smooth_field


@pytest.mark.parametrize("shape", [(257,), (33, 33)])
@pytest.mark.parametrize("coarsen", [1, 2])
def test_resolution_progression_bound(shape, coarsen):
    data = {"F": smooth_field(shape, 5, lo=-3.0, hi=9.0)}
    arch = refactor_variables(data, method="hb", mask_zero_velocity=False)
    session = arch.open()
    eps = 1e-6 * arch.ranges["F"]
    coarse, achieved = session.reconstruct_at_resolution("F", coarsen, eps)
    stride = tuple(slice(None, None, 1 << coarsen) for _ in shape)
    truth = data["F"][stride]
    assert coarse.shape == truth.shape
    assert np.abs(coarse - truth).max() <= achieved * (1 + 1e-12)
    assert achieved <= eps * (1 + 1e-12)


def test_resolution_skips_fine_bytes():
    """Coarse requests must move strictly fewer bytes than full-resolution
    requests at the same precision."""
    data = {"F": smooth_field((1025,), 7, lo=0.0, hi=1.0)}
    arch = refactor_variables(data, method="hb", mask_zero_velocity=False)
    s_coarse = arch.open()
    s_coarse.reconstruct_at_resolution("F", 2, 1e-8)
    s_full = arch.open()
    s_full.reconstruct("F", 1e-8)
    assert s_coarse.bytes_retrieved < s_full.bytes_retrieved


def test_resolution_requires_hb():
    data = {"F": smooth_field((129,), 1)}
    arch = refactor_variables(data, method="ob", mask_zero_velocity=False)
    with pytest.raises(ValueError):
        arch.open().reconstruct_at_resolution("F", 1, 1e-4)
