"""Bitplane codec invariants: error bounds per retrieved prefix, incremental
decode consistency, and byte accounting."""
import numpy as np
import pytest
from _hypothesis_shim import given, settings, strategies as st

from repro.bitplane.encoder import (
    decode_magnitudes, decode_values, encode_level, plane_bound, planes_needed,
)
from repro.bitplane.segments import LevelStream


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000),
       scale=st.floats(min_value=1e-12, max_value=1e12),
       k=st.integers(0, 48))
def test_prefix_error_bound(seed, scale, k):
    rng = np.random.default_rng(seed)
    c = rng.standard_normal(257) * scale
    lbp = encode_level(c, nbits=48)
    v = decode_values(lbp, decode_magnitudes(lbp, k))
    assert np.abs(v - c).max() <= plane_bound(lbp, k) * (1 + 1e-12)


def test_planes_needed_meets_eps():
    c = np.random.default_rng(1).standard_normal(1000) * 3.7
    lbp = encode_level(c)
    for eps in [1.0, 1e-2, 1e-6, 1e-12]:
        k = planes_needed(lbp, eps)
        v = decode_values(lbp, decode_magnitudes(lbp, k))
        assert np.abs(v - c).max() <= eps or k == lbp.nbits


def test_incremental_equals_batch():
    c = np.random.default_rng(2).standard_normal(333) * 11
    lbp = encode_level(c)
    mag = None
    for k in [3, 7, 20, 41]:
        mag = decode_magnitudes(lbp, k, state=mag,
                                start=0 if mag is None else prev)  # noqa: F821
        prev = k
    batch = decode_magnitudes(lbp, 41)
    np.testing.assert_array_equal(mag, batch)


def test_level_stream_byte_accounting():
    c = np.random.default_rng(3).standard_normal(4096) * 5
    lbp = encode_level(c)
    s = LevelStream(lbp)
    assert s.bytes_fetched == 0
    b1 = s.fetch_to_planes(4)
    assert b1 > 0 and s.bytes_fetched == b1
    b2 = s.fetch_to_planes(4)   # idempotent
    assert b2 == 0
    b3 = s.fetch_to_planes(10)  # only pays for the new planes
    expected = sum(lbp.plane_nbytes(b) for b in range(4, 10))
    assert b3 == expected
    # values reflect 10 planes
    v = s.values()
    assert np.abs(v - c).max() <= plane_bound(lbp, 10) * (1 + 1e-12)


def test_all_zero_group():
    lbp = encode_level(np.zeros(100))
    assert lbp.exponent is None and lbp.total_nbytes == 0
    s = LevelStream(lbp)
    assert s.fetch_to_eps(1e-9) == 0
    assert s.bound == 0.0
    np.testing.assert_array_equal(s.values(), np.zeros(100))


def test_exact_power_of_two_values():
    c = np.array([4.0, -4.0, 2.0, 1.0, 0.5])
    lbp = encode_level(c)
    v = decode_values(lbp, decode_magnitudes(lbp, lbp.nbits))
    assert np.abs(v - c).max() <= plane_bound(lbp, lbp.nbits) * (1 + 1e-12)
