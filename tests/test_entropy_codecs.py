"""Plane-codec conformance suite: every registered codec must round-trip
bit-identically on planes of every shape the encoder can produce (and some
it can't), the registry must reject unknown ids, and corrupted payloads
must raise — never decode to garbage.

Property-based via tests/_hypothesis_shim (real hypothesis when installed,
a seeded deterministic sampler otherwise).
"""
import zlib

import numpy as np
import pytest

from repro.bitplane import codecs as C
from repro.bitplane.encoder import encode_level, decode_magnitudes, \
    decode_values
from repro.options import OpenOptions
from repro.store import ChecksumError

from tests._hypothesis_shim import given, settings, strategies as st

ALL_CODECS = sorted(C.registered_codecs())


def _plane_bytes(pattern: str, n: int, density: float, seed: int) -> bytes:
    """Packed plane bytes across the densities that matter: all-zero
    (MSB of smooth data), all-one, bernoulli(density), and adversarial
    bit-alternating planes that defeat run-length coding."""
    rng = np.random.default_rng(seed)
    if pattern == "zeros":
        bits = np.zeros(n * 8, dtype=bool)
    elif pattern == "ones":
        bits = np.ones(n * 8, dtype=bool)
    elif pattern == "random":
        bits = rng.random(n * 8) < density
    elif pattern == "alternating":
        bits = (np.arange(n * 8) % 2).astype(bool)
    else:  # "bursty": zero stretches broken by dense bursts
        bits = np.zeros(n * 8, dtype=bool)
        for _ in range(max(1, n // 64)):
            s = int(rng.integers(0, max(1, n * 8 - 32)))
            bits[s:s + 32] = rng.random(32) < 0.8
    return np.packbits(bits).tobytes()


PATTERNS = ("zeros", "ones", "random", "alternating", "bursty")


# ---------------------------------------------------------- round-trips --


@settings(max_examples=40, deadline=None)
@given(pattern=st.sampled_from(PATTERNS),
       n=st.integers(min_value=0, max_value=2048),
       density=st.floats(min_value=0.0, max_value=1.0),
       seed=st.integers(min_value=0, max_value=2 ** 16))
def test_every_codec_roundtrips_bit_identically(pattern, n, density, seed):
    data = _plane_bytes(pattern, n, density, seed)
    for name in ALL_CODECS:
        codec = C.registered_codecs()[name]
        payload = codec.encode(data)
        assert codec.decode(payload, len(data)) == data, (name, pattern, n)


@settings(max_examples=40, deadline=None)
@given(pattern=st.sampled_from(PATTERNS),
       n=st.integers(min_value=0, max_value=2048),
       density=st.floats(min_value=0.0, max_value=1.0),
       seed=st.integers(min_value=0, max_value=2 ** 16))
def test_cost_model_roundtrips_and_never_beats_raw_plus_tag(pattern, n,
                                                            density, seed):
    data = _plane_bytes(pattern, n, density, seed)
    blob = C.encode_tagged(data)
    assert C.decode_tagged(blob, len(data)) == data
    # raw is always a candidate: a plane never costs more than 1 + len(raw)
    assert len(blob) <= 1 + len(data)
    # the id byte is a registered codec
    if data:
        assert C.get_codec(blob[0]) is not None


@settings(max_examples=25, deadline=None)
@given(n=st.integers(min_value=0, max_value=1024),
       density=st.floats(min_value=0.0, max_value=1.0),
       seed=st.integers(min_value=0, max_value=2 ** 16))
def test_legacy_tags_and_bare_zlib_signs_decode(n, density, seed):
    """v1/v2 dialects: b"R"+raw, b"Z"+zlib planes, untagged zlib signs."""
    data = _plane_bytes("random", n, density, seed)
    assert C.decode_tagged(b"R" + data, len(data)) == data
    assert C.decode_tagged(b"Z" + zlib.compress(data, 1), len(data)) == data
    assert C.decode_sign_blob(zlib.compress(data, 1), len(data)) == data
    assert C.decode_sign_blob(C.encode_tagged(data), len(data)) == data


def test_rans_lane_boundaries_roundtrip():
    """Exact sizes around every lane-count step in RansCodec._lanes_for —
    the interleave layout's off-by-one surface."""
    rng = np.random.default_rng(0)
    for edge in (63, 64, 1 << 8, 1 << 11, 1 << 13, 1 << 16):
        for n in (edge - 1, edge, edge + 1):
            data = rng.integers(0, 7, n, dtype=np.uint8).tobytes()
            assert C.RANS.decode(C.RANS.encode(data), n) == data


# ------------------------------------------------------------- registry --


def test_registry_rejects_unknown_ids():
    for bad in (4, 17, 63, 0x40, 200, 255):
        if bad in {c.codec_id for c in C.registered_codecs().values()}:
            continue
        with pytest.raises(C.CodecError, match="unknown codec"):
            C.get_codec(bad)
        with pytest.raises(C.CodecError):
            C.decode_tagged(bytes([bad]) + b"payload", 7)
    with pytest.raises(C.CodecError, match="empty"):
        C.decode_tagged(b"", 0)


def test_register_rejects_collisions_and_reserved_ids():
    class Dup(C.PlaneCodec):
        codec_id = C.RLE.codec_id
        name = "dup"

    with pytest.raises(ValueError, match="already registered"):
        C.register(Dup())

    class LegacyClash(C.PlaneCodec):
        codec_id = 0x52          # b"R" — must stay un-registrable
        name = "legacy-clash"

    with pytest.raises(ValueError, match="reserved range"):
        C.register(LegacyClash())


def test_default_candidates_knob_roundtrips():
    prev = C.set_default_candidates(["zlib"])
    try:
        assert C.DEFAULT_CANDIDATES == ("zlib",)
        data = np.packbits(np.zeros(512, dtype=bool)).tobytes()
        assert C.encode_tagged(data)[0] in (C.RAW.codec_id,
                                            C.ZLIB.codec_id)
        with pytest.raises(ValueError, match="unknown codec"):
            C.set_default_candidates(["lzma"])
    finally:
        C.set_default_candidates(prev)


# -------------------------------------------------------- corruption fuzz --


@settings(max_examples=30, deadline=None)
@given(pattern=st.sampled_from(PATTERNS),
       n=st.integers(min_value=16, max_value=1024),
       seed=st.integers(min_value=0, max_value=2 ** 16),
       data=st.data())
def test_truncated_payloads_never_return_garbage(pattern, n, seed, data):
    """Any truncation of any codec's payload must raise CodecError — the
    decoder validates lengths/state and can never hand back a wrong-sized
    plane."""
    buf = _plane_bytes(pattern, n, 0.02, seed)
    for name in ALL_CODECS:
        codec = C.registered_codecs()[name]
        payload = codec.encode(buf)
        if not payload:
            continue
        cut = data.draw(st.integers(min_value=0,
                                    max_value=len(payload) - 1),
                        label=f"cut:{name}")
        with pytest.raises(C.CodecError):
            codec.decode(payload[:cut], len(buf))


@settings(max_examples=30, deadline=None)
@given(pattern=st.sampled_from(PATTERNS),
       n=st.integers(min_value=16, max_value=1024),
       seed=st.integers(min_value=0, max_value=2 ** 16),
       data=st.data())
def test_bitflipped_payloads_raise_or_stay_sized(pattern, n, seed, data):
    """Without the store's crc a decoder cannot detect every flipped bit
    (raw provably can't), but it must either raise CodecError or return a
    buffer of exactly the requested size — never a short/long plane that
    would corrupt the magnitude state silently."""
    buf = _plane_bytes(pattern, n, 0.02, seed)
    blob = C.encode_tagged(buf)
    pos = data.draw(st.integers(min_value=1, max_value=len(blob) - 1),
                    label="pos")
    bit = data.draw(st.integers(min_value=0, max_value=7), label="bit")
    corrupt = bytearray(blob)
    corrupt[pos] ^= 1 << bit
    try:
        out = C.decode_tagged(bytes(corrupt), len(buf))
    except C.CodecError:
        return
    assert len(out) == len(buf)


def test_rle_huge_zero_run_raises_before_allocating():
    """Regression: a corrupt varint encoding a petabyte zero run must be
    bounds-checked against out_len BEFORE the run is materialised —
    CodecError, not MemoryError, for a network-delivered payload."""
    payload = bytearray()
    v = 1 << 50
    while v >= 0x80:                      # varint(2^50)
        payload.append((v & 0x7F) | 0x80)
        v >>= 7
    payload.append(v)
    payload.append(0)                     # literal_len = 0
    with pytest.raises(C.CodecError):
        C.RLE.decode(bytes(payload), 512)


def test_raw_plane_decode_is_zero_copy():
    """Raw is ~96% of archived bytes: its decode must return a view into
    the fetched blob, not a per-plane copy."""
    blob = C.encode_tagged(np.random.default_rng(0).integers(
        0, 256, 4096, dtype=np.uint8).tobytes(), density=0.5)
    assert blob[0] == C.RAW.codec_id
    out = C.decode_tagged(blob, 4096)
    assert isinstance(out, memoryview)
    assert out.obj is blob                # view over the original buffer


def test_wrong_codec_id_raises():
    """Re-tagging a payload with a different (registered) codec id must
    fail decode — each payload dialect is self-checking enough that no
    other codec accepts it."""
    rng = np.random.default_rng(1)
    buf = np.packbits(rng.random(8 * 512) < 0.02).tobytes()
    for name in ALL_CODECS:
        codec = C.registered_codecs()[name]
        payload = codec.encode(buf)
        if len(payload) == len(buf):
            continue                      # raw-sized: skip the raw swap
        for other in ALL_CODECS:
            oc = C.registered_codecs()[other]
            if oc.codec_id == codec.codec_id:
                continue
            with pytest.raises(C.CodecError):
                oc.decode(payload, len(buf))


def test_corruption_through_store_raises_integrity_error(tmp_path):
    """The full contract: a truncated or bit-flipped segment, pulled
    through the real store path, surfaces as the store's integrity error
    (crc mismatch or decode failure) — garbage values can never reach the
    reconstruction."""
    from repro.core.refactor import refactor_variables
    from repro.data.synthetic import ge_like_fields
    from repro.store import open_archive, save_archive

    fields = ge_like_fields(n=1 << 10, seed=0)
    vel = {k: fields[k] for k in ("Vx",)}
    arch = refactor_variables(vel, method="hb")
    path = str(tmp_path / "a.prs")
    save_archive(arch, path)

    with open_archive(path) as sa:
        plane_keys = sorted(k for k in sa.fetcher.index if "/p" in k)
        victims = [(k, sa.fetcher.index[k]) for k in plane_keys[:8]]

    rng = np.random.default_rng(3)
    for key, entry in victims:
        with open(path, "rb") as fh:
            original = fh.read()
        corrupt = bytearray(original)
        pos = entry.offset + int(rng.integers(0, entry.size))
        corrupt[pos] ^= 1 << int(rng.integers(0, 8))
        with open(path, "wb") as fh:
            fh.write(bytes(corrupt))
        # verified path: crc catches it before any decode runs
        with open_archive(path) as sa:
            with pytest.raises(ChecksumError):
                sa.fetcher.fetch(key)
        # unverified path (trusted transport): the codec layer must still
        # raise or produce an exactly-sized plane — never a short/long
        # buffer (raw payloads' flipped bits are undetectable without crc)
        with open_archive(path, OpenOptions.unverified()) as sa:
            blob = sa.fetcher.fetch(key)
            want = _plane_len(sa, key)
            try:
                out = C.decode_tagged(blob, want)
            except C.CodecError:
                out = None
            if out is not None:
                assert len(out) == want
        with open(path, "wb") as fh:
            fh.write(original)


def _plane_len(sa, key: str) -> int:
    """Decoded byte length of a bitplane segment: 4 * ceil32(count)."""
    var, group, _ = key.split("/")
    spec = sa.manifest["variables"][var]["groups"][int(group[1:])]
    return 4 * ((spec["count"] + 31) // 32)


# ------------------------------------------------ sign-blob codec routing --


def test_signs_route_through_codec_stage_not_unconditional_zlib():
    """Regression (the old encoder zlib'd signs unconditionally): an
    all-non-negative group's sign plane is all-zero bytes and must collapse
    through the codec stage to a handful of bytes, well under zlib's
    ~11-byte empty-stream floor, while still decoding bit-identically."""
    rng = np.random.default_rng(0)
    vals = np.abs(rng.standard_normal(4096)) + 0.5      # strictly positive
    lbp = encode_level(vals, nbits=32)
    zlib_cost = len(zlib.compress(
        np.packbits(vals < 0).tobytes(), 1))
    assert len(lbp.signs) < zlib_cost
    assert lbp.signs[0] != 0x78           # tagged, not a bare zlib stream
    mag = decode_magnitudes(lbp, lbp.nbits)
    out = decode_values(lbp, mag)
    assert (out >= 0).all()
    np.testing.assert_allclose(out, vals, atol=2.0 ** (lbp.exponent - 31))


def test_mixed_sign_group_roundtrips_through_tagged_signs():
    rng = np.random.default_rng(1)
    vals = rng.standard_normal(2048)
    lbp = encode_level(vals, nbits=40)
    mag = decode_magnitudes(lbp, lbp.nbits)
    out = decode_values(lbp, mag)
    np.testing.assert_array_equal(np.signbit(out)[vals != 0.0],
                                  np.signbit(vals)[vals != 0.0])
    np.testing.assert_allclose(out, vals, atol=2.0 ** (lbp.exponent - 39))
