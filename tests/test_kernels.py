"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.bitplane_pack import bitplane_pack
from repro.kernels.hier_level import hier_level_surplus
from repro.kernels.qoi_vtotal import qoi_vtotal_fused


# ---------------------------------------------------------------- bitplane --
@pytest.mark.parametrize("n", [1024, 4096, 8192])
@pytest.mark.parametrize("nbits", [8, 16, 30])
def test_bitplane_pack_matches_ref(n, nbits):
    rng = np.random.default_rng(n + nbits)
    mag = jnp.asarray(rng.integers(0, 2 ** nbits, size=n), jnp.int32)
    out = bitplane_pack(mag, nbits=nbits, interpret=True)
    expect = ref.bitplane_pack_ref(mag, nbits=nbits)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


@pytest.mark.parametrize("n", [100, 1000, 5000])  # non-aligned lengths
def test_pack_bitplanes_wrapper_pads(n):
    rng = np.random.default_rng(n)
    mag = rng.integers(0, 2 ** 20, size=n)
    out = np.asarray(ops.pack_bitplanes(jnp.asarray(mag, jnp.int32), nbits=20))
    expect = np.asarray(ref.bitplane_pack_ref(
        jnp.asarray(np.pad(mag, (0, (-n) % 32)), jnp.int32), nbits=20))
    np.testing.assert_array_equal(out, expect)


def test_bitplane_pack_roundtrip_bits():
    """Unpacking the packed planes recovers every magnitude bit."""
    rng = np.random.default_rng(9)
    n, nbits = 2048, 24
    mag = rng.integers(0, 2 ** nbits, size=n)
    out = np.asarray(ops.pack_bitplanes(jnp.asarray(mag, jnp.int32),
                                        nbits=nbits))
    rebuilt = np.zeros(n, dtype=np.int64)
    for b in range(nbits):
        words = out[b]
        bits = (words[:, None] >> np.arange(32)[None, :]) & 1
        rebuilt |= bits.ravel()[:n].astype(np.int64) << (nbits - 1 - b)
    np.testing.assert_array_equal(rebuilt, mag)


# ------------------------------------------------------------- hier level --
@pytest.mark.parametrize("batch,m", [(8, 128), (16, 256), (8, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_hier_level_matches_ref(batch, m, dtype):
    rng = np.random.default_rng(batch + m)
    even = jnp.asarray(rng.standard_normal((batch, m + 1)), dtype)
    odd = jnp.asarray(rng.standard_normal((batch, m)), dtype)
    out = hier_level_surplus(even, odd, interpret=True)
    expect = ref.hier_level_surplus_ref(even, odd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-6)


def test_level_surplus_wrapper_row_pad():
    rng = np.random.default_rng(3)
    even = jnp.asarray(rng.standard_normal((5, 65)), jnp.float32)
    odd = jnp.asarray(rng.standard_normal((5, 64)), jnp.float32)
    out = ops.level_surplus(even, odd)
    expect = ref.hier_level_surplus_ref(even, odd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-6)


def test_hier_level_agrees_with_transform():
    """Kernel output == the surpluses decompose_hb computes at the finest
    level of a 1D grid (deinterleaved layout equivalence)."""
    from repro.transform.hierarchical import decompose_hb, level_map
    rng = np.random.default_rng(11)
    n = 257
    x = rng.standard_normal(n)
    c = np.asarray(decompose_hb(jnp.asarray(x), 1))
    lm = level_map((n,), 1)
    even = jnp.asarray(x[0::2][None, :])
    odd = jnp.asarray(x[1::2][None, :])
    out = np.asarray(ops.level_surplus(even, odd))[0]
    np.testing.assert_allclose(out, c[lm == 0], rtol=1e-12)


# ------------------------------------------------------------- qoi vtotal --
@pytest.mark.parametrize("n", [1024, 4096])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_qoi_vtotal_matches_ref(n, dtype):
    rng = np.random.default_rng(n)
    vx = jnp.asarray(rng.standard_normal(n) * 100, dtype)
    vy = jnp.asarray(rng.standard_normal(n) * 80, dtype)
    vz = jnp.asarray(rng.standard_normal(n) * 50, dtype)
    eps = jnp.asarray([0.5, 0.3, 0.1], dtype)
    val, bound = qoi_vtotal_fused(vx, vy, vz, eps, interpret=True)
    ev, eb = ref.qoi_vtotal_ref(vx, vy, vz, eps)
    np.testing.assert_allclose(np.asarray(val), np.asarray(ev), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(bound), np.asarray(eb), rtol=1e-6)


def test_qoi_vtotal_matches_expression():
    """Kernel == the composable AST estimator (core.qoi) for Vtotal."""
    from repro.core import ge
    rng = np.random.default_rng(17)
    n = 2048
    fields = {"Vx": rng.standard_normal(n) * 10,
              "Vy": rng.standard_normal(n) * 10,
              "Vz": rng.standard_normal(n) * 10}
    eps = {"Vx": 0.02, "Vy": 0.05, "Vz": 0.01}
    expr = ge.v_total()
    ev, eb = expr.eval({k: jnp.asarray(v) for k, v in fields.items()},
                       {k: jnp.full(n, e) for k, e in eps.items()})
    val, bound = ops.vtotal_with_bound(
        jnp.asarray(fields["Vx"]), jnp.asarray(fields["Vy"]),
        jnp.asarray(fields["Vz"]),
        jnp.asarray([eps["Vx"], eps["Vy"], eps["Vz"]]))
    np.testing.assert_allclose(np.asarray(val), np.asarray(ev), rtol=1e-12)
    np.testing.assert_allclose(np.asarray(bound), np.asarray(eb), rtol=1e-12)


def test_qoi_vtotal_bound_validity():
    """Kernel bound is a true upper bound under admissible perturbations."""
    rng = np.random.default_rng(23)
    n = 1024
    vx, vy, vz = (rng.standard_normal(n) for _ in range(3))
    eps = np.array([0.05, 0.02, 0.04])
    val, bound = ops.vtotal_with_bound(
        jnp.asarray(vx), jnp.asarray(vy), jnp.asarray(vz), jnp.asarray(eps))
    val, bound = np.asarray(val), np.asarray(bound)
    for trial in range(5):
        px = vx + rng.uniform(-1, 1, n) * eps[0]
        py = vy + rng.uniform(-1, 1, n) * eps[1]
        pz = vz + rng.uniform(-1, 1, n) * eps[2]
        truth = np.sqrt(px ** 2 + py ** 2 + pz ** 2)
        finite = np.isfinite(bound)
        assert np.all(np.abs(truth - val)[finite] <=
                      bound[finite] * (1 + 1e-9) + 1e-12)
