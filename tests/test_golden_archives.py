"""Golden-archive compatibility: committed v1/v2 containers must keep
opening and decoding bit-identically forever.

The fixtures under ``tests/fixtures/`` (see ``make_golden.py`` there) were
written in the *legacy* on-disk dialects — v1 single-file / v2 sharded
manifests, planes tagged ``b"R"``/``b"Z"``, sign planes as bare zlib
streams — which the current encoder no longer produces.  These tests are
the contract that manifest v3 (and any future codec work) can never
silently break an old archive: reconstructions must match both the values
recorded at fixture-generation time AND a fresh in-memory refactor (the
cross-generation bit-identity invariant), with the legacy byte accounting
intact.
"""
import json
import os
import struct

import numpy as np
import pytest

from repro.core.refactor import refactor_variables
from repro.data.synthetic import ge_like_fields
from repro.store import open_archive
from repro.store.container import MAGIC

FIXTURES = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "fixtures")
V1_PATH = os.path.join(FIXTURES, "golden_v1.prs")
V2_DIR = os.path.join(FIXTURES, "golden_v2")
VARS = ("Vx", "Vy", "Vz")


@pytest.fixture(scope="module")
def expected():
    with np.load(os.path.join(FIXTURES, "golden_expected.npz")) as z:
        return {k: z[k] for k in z.files}


@pytest.fixture(scope="module")
def fresh_archive():
    """A freshly refactored in-memory archive over the same fields — the
    cross-generation reference every fixture must stay bit-identical to."""
    fields = ge_like_fields(n=1 << 10, seed=0)
    vel = {k: fields[k] for k in VARS}
    return refactor_variables(vel, method="hb")


@pytest.fixture
def fresh_session(fresh_archive):
    # sessions are stateful (never-go-backwards plane counts), so each test
    # gets its own — a shared one would answer loose eps with tight values
    return fresh_archive.open()


def _manifest_version(source):
    if os.path.isdir(source):
        with open(os.path.join(source, "manifest.json"), "rb") as fh:
            return json.loads(fh.read())["version"]
    with open(source, "rb") as fh:
        head = fh.read(len(MAGIC) + 8)
        (mlen,) = struct.unpack("<Q", head[len(MAGIC):])
        return json.loads(fh.read(mlen))["version"]


@pytest.mark.parametrize("source", [V1_PATH, V2_DIR],
                         ids=["v1-single-file", "v2-sharded"])
def test_fixture_is_really_legacy_format(source):
    """Guard the guard: if regeneration ever writes current-format
    fixtures, the compatibility tests would be testing nothing."""
    version = _manifest_version(source)
    assert version == (1 if source.endswith(".prs") else 2)


@pytest.mark.parametrize("source", [V1_PATH, V2_DIR],
                         ids=["v1-single-file", "v2-sharded"])
def test_golden_archive_decodes_bit_identically(source, expected,
                                                fresh_session):
    eps_ladder = expected["eps_ladder"]
    with open_archive(source) as sa:
        st = sa.open()
        for eps_i, eps in enumerate(eps_ladder):
            for v in VARS:
                data, bound = st.reconstruct(v, float(eps))
                np.testing.assert_array_equal(
                    data, expected[f"{v}__eps{eps_i}"],
                    err_msg=f"{source}: {v} at eps={eps} drifted from the "
                            f"recorded golden values")
                assert bound == float(expected[f"{v}__bound{eps_i}"])
                ref, ref_bound = fresh_session.reconstruct(v, float(eps))
                np.testing.assert_array_equal(
                    data, ref,
                    err_msg=f"{source}: {v} at eps={eps} drifted from a "
                            f"fresh refactor — cross-generation bit "
                            f"identity broken")
                assert bound == ref_bound
        # legacy byte accounting is part of the contract: segment sizes in
        # a committed archive can never change
        assert st.bytes_retrieved == int(expected["bytes_retrieved"])


def test_golden_archive_reports_untagged_codecs(expected):
    """v1/v2 manifests predate the codec field: every segment must surface
    as 'untagged' in the codec accounting, and fetching must bucket the
    moved bytes there (not misattribute them to a registered codec)."""
    with open_archive(V1_PATH) as sa:
        assert set(sa.codec_bytes()) == {"untagged"}
        st = sa.open()
        st.reconstruct("Vx", 1e-5)
        stats = sa.fetcher.stats
        assert set(stats.codec_bytes) == {"untagged"}
        assert stats.codec_bytes["untagged"] == stats.bytes_fetched


def test_golden_full_retrieval_exhausts_archive(expected):
    """A full-precision pull through a legacy archive consumes every plane
    of the requested variables — the deepest compatibility exercise (all
    48 planes x all groups x legacy sign decode)."""
    with open_archive(V2_DIR) as sa:
        st = sa.open()
        for v in VARS:
            data, bound = st.reconstruct(v, 1e-15)
            assert np.isfinite(data).all()
            # all 48 planes consumed: only the quantization floor remains
            assert bound < 1e-10
