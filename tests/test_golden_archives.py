"""Golden-archive compatibility: committed v1-v4 containers must keep
opening and decoding bit-identically forever.

The fixtures under ``tests/fixtures/`` (see ``make_golden.py`` there) span
every manifest dialect the reader has ever promised to serve:

  v1  single-file container, 3-tuple segments, untagged entropy streams
  v2  sharded container, 4-tuple segments, untagged entropy streams
  v3  sharded container, 5-tuple codec-tagged segments (current static
      encoder output, frozen)
  v4  live journaled archive — base manifest + journal.jsonl + per-
      timestep delta blobs, committed UNSEALED so every open replays
      the journal

These tests are the contract that no codec/format work can silently break
an old archive: reconstructions must match both the values recorded at
fixture-generation time AND (for the static formats) a fresh in-memory
refactor — the cross-generation bit-identity invariant — with byte
accounting and codec attribution intact.
"""
import json
import os
import struct

import numpy as np
import pytest

from repro.core.refactor import refactor_variables
from repro.data.synthetic import ge_like_fields
from repro.store import open_archive
from repro.store.container import MAGIC

FIXTURES = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "fixtures")
V1_PATH = os.path.join(FIXTURES, "golden_v1.prs")
V2_DIR = os.path.join(FIXTURES, "golden_v2")
V3_DIR = os.path.join(FIXTURES, "golden_v3")
V4_DIR = os.path.join(FIXTURES, "golden_v4")
IP_DIR = os.path.join(FIXTURES, "golden_ip")
VARS = ("Vx", "Vy", "Vz")
IP_VARS = ("S", "Vx")
V4_T = 6


@pytest.fixture(scope="module")
def expected():
    with np.load(os.path.join(FIXTURES, "golden_expected.npz")) as z:
        return {k: z[k] for k in z.files}


@pytest.fixture(scope="module")
def expected_v34():
    with np.load(os.path.join(FIXTURES, "golden_v34_expected.npz")) as z:
        return {k: z[k] for k in z.files}


@pytest.fixture(scope="module")
def fresh_archive():
    """A freshly refactored in-memory archive over the same fields — the
    cross-generation reference every fixture must stay bit-identical to."""
    fields = ge_like_fields(n=1 << 10, seed=0)
    vel = {k: fields[k] for k in VARS}
    return refactor_variables(vel, method="hb")


@pytest.fixture
def fresh_session(fresh_archive):
    # sessions are stateful (never-go-backwards plane counts), so each test
    # gets its own — a shared one would answer loose eps with tight values
    return fresh_archive.open()


def _manifest_version(source):
    if os.path.isdir(source):
        with open(os.path.join(source, "manifest.json"), "rb") as fh:
            return json.loads(fh.read())["version"]
    with open(source, "rb") as fh:
        head = fh.read(len(MAGIC) + 8)
        (mlen,) = struct.unpack("<Q", head[len(MAGIC):])
        return json.loads(fh.read(mlen))["version"]


@pytest.mark.parametrize("source,version",
                         [(V1_PATH, 1), (V2_DIR, 2), (V3_DIR, 3),
                          (V4_DIR, 4)],
                         ids=["v1-single-file", "v2-sharded",
                              "v3-codec-tagged", "v4-journaled"])
def test_fixture_is_really_its_format(source, version):
    """Guard the guard: if regeneration ever writes a different-format
    fixture, the compatibility matrix would be testing nothing."""
    assert _manifest_version(source) == version


def test_v4_fixture_is_really_live():
    """The journaled fixture must stay UNSEALED with a non-trivial journal
    — a sealed (or journal-less) fixture would never exercise replay."""
    with open(os.path.join(V4_DIR, "manifest.json"), "rb") as fh:
        manifest = json.loads(fh.read())
    assert manifest.get("journal") is True
    assert not manifest.get("sealed")
    with open(os.path.join(V4_DIR, "journal.jsonl"), "rb") as fh:
        records = [json.loads(line) for line in fh.read().splitlines()]
    assert all(r["op"] != "seal" for r in records)
    assert sum(1 for r in records if r["op"] == "timestep") == V4_T


@pytest.mark.parametrize("source", [V1_PATH, V2_DIR],
                         ids=["v1-single-file", "v2-sharded"])
def test_golden_archive_decodes_bit_identically(source, expected,
                                                fresh_session):
    eps_ladder = expected["eps_ladder"]
    with open_archive(source) as sa:
        st = sa.open()
        for eps_i, eps in enumerate(eps_ladder):
            for v in VARS:
                data, bound = st.reconstruct(v, float(eps))
                np.testing.assert_array_equal(
                    data, expected[f"{v}__eps{eps_i}"],
                    err_msg=f"{source}: {v} at eps={eps} drifted from the "
                            f"recorded golden values")
                assert bound == float(expected[f"{v}__bound{eps_i}"])
                ref, ref_bound = fresh_session.reconstruct(v, float(eps))
                np.testing.assert_array_equal(
                    data, ref,
                    err_msg=f"{source}: {v} at eps={eps} drifted from a "
                            f"fresh refactor — cross-generation bit "
                            f"identity broken")
                assert bound == ref_bound
        # legacy byte accounting is part of the contract: segment sizes in
        # a committed archive can never change
        assert st.bytes_retrieved == int(expected["bytes_retrieved"])


def test_golden_v3_decodes_bit_identically(expected, expected_v34,
                                           fresh_session):
    """The frozen current-encoder output: values and bounds must match the
    recorded v3 expectations, the legacy fixtures' recorded values (the
    same fields — cross-format identity), and a fresh refactor; byte
    accounting is v3's own (codec-tagged streams are smaller)."""
    eps_ladder = expected["eps_ladder"]
    with open_archive(V3_DIR) as sa:
        st = sa.open()
        for eps_i, eps in enumerate(eps_ladder):
            for v in VARS:
                data, bound = st.reconstruct(v, float(eps))
                np.testing.assert_array_equal(
                    data, expected_v34[f"v3__{v}__eps{eps_i}"],
                    err_msg=f"v3 {v} at eps={eps} drifted from recorded")
                np.testing.assert_array_equal(
                    data, expected[f"{v}__eps{eps_i}"],
                    err_msg=f"v3 {v} at eps={eps} diverged from the legacy "
                            f"fixtures over the same fields")
                assert bound == float(expected_v34[f"v3__{v}__bound{eps_i}"])
                ref, ref_bound = fresh_session.reconstruct(v, float(eps))
                np.testing.assert_array_equal(data, ref)
                assert bound == ref_bound
        assert st.bytes_retrieved == int(expected_v34["v3__bytes_retrieved"])


def test_golden_v4_replays_bit_identically(expected_v34):
    """Journal replay contract: opening the committed live archive and
    walking its timesteps in order reproduces the recorded values, bounds,
    and byte accounting exactly — keyframes AND delta chains."""
    with open_archive(V4_DIR) as sa:
        st = sa.open()
        reader = st.reader("T")
        for t in range(V4_T):
            data, bound = reader.read(t)
            np.testing.assert_array_equal(
                data, expected_v34[f"v4__t{t}"],
                err_msg=f"v4 timestep {t} drifted from recorded values")
            assert bound == float(expected_v34[f"v4__bound{t}"])
        assert st.bytes_retrieved == int(expected_v34["v4__bytes_retrieved"])
        # fully replayed: nothing left for refresh to apply
        assert sa.refresh() == 0


@pytest.fixture(scope="module")
def expected_ip():
    with np.load(os.path.join(FIXTURES, "golden_ip_expected.npz")) as z:
        return {k: z[k] for k in z.files}


def test_golden_ip_decodes_bit_identically(expected_ip):
    """The committed method="ip" archive: reconstructions, certified
    bounds, and byte accounting must match both the recorded expectations
    and a fresh in-memory refactor — freezing the closed-loop prediction
    contract (pred_planes metadata + fixed-order contribution sum) so no
    predictor refactor can silently re-encode old ip archives."""
    from repro.data.synthetic import smooth_field
    assert _manifest_version(IP_DIR) == 3      # no new format version
    fields = ge_like_fields(n=1 << 10, seed=0)
    fresh = refactor_variables(
        {"S": smooth_field((257,), seed=5, lo=-3.0, hi=9.0),
         "Vx": fields["Vx"]}, method="ip").open()
    with open_archive(IP_DIR) as sa:
        assert all(v.method == "ip" for v in sa.variables.values())
        st = sa.open()
        for eps_i, eps in enumerate(expected_ip["ip__eps_ladder"]):
            for v in IP_VARS:
                data, bound = st.reconstruct(v, float(eps))
                np.testing.assert_array_equal(
                    data, expected_ip[f"ip__{v}__eps{eps_i}"],
                    err_msg=f"ip {v} at eps={eps} drifted from recorded")
                assert bound == float(expected_ip[f"ip__{v}__bound{eps_i}"])
                ref, ref_bound = fresh.reconstruct(v, float(eps))
                np.testing.assert_array_equal(
                    data, ref,
                    err_msg=f"ip {v} at eps={eps} drifted from a fresh "
                            f"refactor — cross-generation bit identity "
                            f"broken")
                assert bound == ref_bound
        assert st.bytes_retrieved == int(expected_ip["ip__bytes_retrieved"])


def test_golden_v4_delta_blobs_beat_keyframes():
    """The reason v4 exists: consecutive timesteps delta-encode measurably
    smaller than keyframes.  Byte accounting straight from the committed
    manifest+journal (keyframes at t0/t3, deltas elsewhere)."""
    with open_archive(V4_DIR) as sa:
        var = sa.variables["T"]
        key_bytes, delta_bytes = [], []
        for t in range(V4_T):
            h = var.handle(t)
            (key_bytes if h.keyframe else delta_bytes).append(h.nbytes)
        assert key_bytes and delta_bytes
        assert max(delta_bytes) < 0.75 * min(key_bytes)


def test_golden_v3_codec_attribution(expected_v34):
    """v3 segments carry codec tags: attribution must bucket real codec
    names (no 'untagged' leakage from tagged planes) and the per-codec
    sizes must sum to the manifest's total payload bytes."""
    with open_archive(V3_DIR) as sa:
        by_codec = sa.codec_bytes()
        assert set(by_codec) - {"untagged"}, \
            "v3 fixture reports no tagged codecs — encoder regressed?"
        assert sum(by_codec.values()) == \
            sum(e.size for e in sa.fetcher.index.values())


def test_golden_archive_reports_untagged_codecs(expected):
    """v1/v2 manifests predate the codec field: every segment must surface
    as 'untagged' in the codec accounting, and fetching must bucket the
    moved bytes there (not misattribute them to a registered codec)."""
    with open_archive(V1_PATH) as sa:
        assert set(sa.codec_bytes()) == {"untagged"}
        st = sa.open()
        st.reconstruct("Vx", 1e-5)
        stats = sa.fetcher.stats
        assert set(stats.codec_bytes) == {"untagged"}
        assert stats.codec_bytes["untagged"] == stats.bytes_fetched


def test_golden_full_retrieval_exhausts_archive(expected):
    """A full-precision pull through a legacy archive consumes every plane
    of the requested variables — the deepest compatibility exercise (all
    48 planes x all groups x legacy sign decode)."""
    with open_archive(V2_DIR) as sa:
        st = sa.open()
        for v in VARS:
            data, bound = st.reconstruct(v, 1e-15)
            assert np.isfinite(data).all()
            # all 48 planes consumed: only the quantization floor remains
            assert bound < 1e-10
