"""Seeded chaos suite: the retrieval plane under injected faults.

Two contracts (ISSUE 6 tentpole):

  * HEALING schedules — every injected fault is transient (the per-range
    budget of ``FaultPlan.max_faults_per_range`` is below the RetryPolicy's
    attempt budget) — must be INVISIBLE: the retrieval result is
    bit-identical to the fault-free run, for all four archive methods, with
    identical byte accounting.

  * PERMANENT loss must DEGRADE, not lie: the result is flagged, the lost
    variable reports its availability floor, the loop terminates without
    spinning, and the reported error bound still upper-bounds the true QoI
    error measured against ground truth.

Every schedule is a pure function of its seed (repro.store.faults); on
failure the seed is printed so the run reproduces exactly.
"""
import json

import numpy as np
import pytest

from repro.core import ge
from repro.core.refactor import METHODS, refactor_variables
from repro.core.retrieval import QoIRequest, retrieve_qoi_controlled
from repro.data.synthetic import ge_like_fields
from repro.store import (
    BlobQuarantine,
    FaultInjectingByteStore,
    FaultPlan,
    MemoryByteStore,
    RetryPolicy,
)
from repro.store.container import StoreArchive, build_sharded_container

pytestmark = pytest.mark.chaos

SEEDS = (1, 2)

# every fault kind at once; the per-range cap of 2 stays below the retry
# policy's 4 attempts, so every schedule is guaranteed to heal
HEALING_PLAN = FaultPlan(rate=0.3, error_weight=1.0, timeout_weight=1.0,
                         truncate_weight=1.0, flip_weight=1.0,
                         slow_weight=0.5, slow_s=1e-4,
                         max_faults_per_range=2)
POLICY = RetryPolicy(max_attempts=4, backoff_s=1e-3, backoff_cap_s=5e-3)


def _vel(n=1 << 10):
    fields = ge_like_fields(n=n, seed=0)
    return {k: fields[k] for k in ("Vx", "Vy", "Vz")}


_ARCHIVES = {}


def _archive(method):
    if method not in _ARCHIVES:
        _ARCHIVES[method] = refactor_variables(_vel(), method=method)
    return _ARCHIVES[method]


def _chaos_archive(arch, seed, plan=HEALING_PLAN, shard_by="single",
                   dead_blobs=(), **kw):
    """StoreArchive whose every blob sits behind a seeded fault injector;
    blobs named in ``dead_blobs`` never deliver (permanent loss)."""
    manifest, payloads = build_sharded_container(arch, shard_by=shard_by)
    manifest = json.loads(json.dumps(manifest))
    stores = {}
    for blob, data in payloads.items():
        p = FaultPlan(rate=0.0, dead_ranges=((0, len(data)),)) \
            if blob in dead_blobs else plan
        stores[blob] = FaultInjectingByteStore(MemoryByteStore(data), p,
                                               seed=seed)
    spec = stores if shard_by != "single" else stores[""]
    kw.setdefault("retry_policy", POLICY)
    kw.setdefault("quarantine", BlobQuarantine(threshold=8, cooldown_s=0.01))
    return StoreArchive(manifest, spec, prefetch_workers=2, **kw), stores


def _reseed(seed, fn):
    """Run ``fn``; on assertion failure print the reproducing seed."""
    try:
        fn()
    except AssertionError:
        print(f"\n[chaos] FAILING SEED: {seed} — rerun with "
              f"FaultInjectingByteStore(seed={seed}) to reproduce")
        raise


@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("method", METHODS)
def test_healing_faults_are_bit_identical(method, seed):
    """A fully-healing fault schedule changes NOTHING: values, achieved
    bounds, error estimates and byte accounting all match the fault-free
    run exactly, for every archive method."""
    arch = _archive(method)
    reqs = [QoIRequest("VTOT", ge.v_total(), 1e-3)]

    clean = retrieve_qoi_controlled(arch.open(), reqs)
    sa, stores = _chaos_archive(arch, seed)
    try:
        res = retrieve_qoi_controlled(sa.open(), reqs)

        def check():
            injected = sum(s.stats.total for s in stores.values())
            assert injected > 0, "schedule fired no faults — vacuous run"
            assert not res.degraded and res.converged == clean.converged
            for v in clean.values:
                np.testing.assert_array_equal(res.values[v], clean.values[v])
                assert res.achieved_eb[v] == clean.achieved_eb[v]
            assert res.est_errors == clean.est_errors
            assert res.bytes_retrieved == clean.bytes_retrieved
            st = sa.fetcher.stats
            assert st.faults_absorbed > 0    # the faults were real, and hidden
        _reseed(seed, check)
    finally:
        sa.close()


@pytest.mark.slow  # ~26s schedule; nightly -m chaos still runs it (budget)
@pytest.mark.parametrize("seed", (0,))
def test_permanent_loss_degrades_with_certified_bound(seed):
    """Losing a whole variable shard yields a flagged degraded result whose
    reported bound still upper-bounds the TRUE QoI error, and the loop
    terminates instead of re-requesting the missing planes forever."""
    vel = _vel()
    arch = _archive("hb")
    reqs = [QoIRequest("VTOT", ge.v_total(), 1e-4)]
    sa, _ = _chaos_archive(arch, seed, shard_by="variable",
                           dead_blobs=("Vz.seg",))
    try:
        res = retrieve_qoi_controlled(sa.open(), reqs)

        def check():
            assert res.degraded and not res.converged
            assert set(res.availability) == {"Vz"}
            a = res.availability["Vz"]
            assert a.pinned and np.isfinite(a.floor) and a.floor > 0
            # no infinite reassign spin on the pinned variable
            assert len(res.iterations) < 25
            # per-variable certification against ground truth ...
            for v in vel:
                err = float(np.max(np.abs(vel[v] - res.values[v])))
                assert err <= res.achieved_eb[v] * (1 + 1e-12)
            # ... and the derived QoI's reported bound holds too
            true_q = np.sqrt(sum(vel[v] ** 2 for v in ("Vx", "Vy", "Vz")))
            rec_q = np.sqrt(sum(res.values[v] ** 2
                                for v in ("Vx", "Vy", "Vz")))
            q_err = float(np.max(np.abs(true_q - rec_q)))
            assert q_err <= res.est_errors["VTOT"] * (1 + 1e-12)
        _reseed(seed, check)
    finally:
        sa.close()


@pytest.mark.slow  # ~10s schedule; nightly -m chaos still runs it (budget)
@pytest.mark.parametrize("seed", (3,))
def test_faults_then_loss_compose(seed):
    """Transient faults on the surviving shards + permanent loss of one:
    the healthy variables still land bit-identical to fault-free, the lost
    one degrades."""
    arch = _archive("hb")
    mem = arch.open()
    sa, _ = _chaos_archive(arch, seed, shard_by="variable",
                           dead_blobs=("Vy.seg",))
    try:
        st = sa.open()

        def check():
            for v in ("Vx", "Vz"):
                a, ba = mem.reconstruct(v, 1e-6)
                b, bb = st.reconstruct(v, 1e-6)
                np.testing.assert_array_equal(a, b)
                assert ba == bb
            _, bound = st.reconstruct("Vy", 1e-6)
            assert st.degraded and set(st.availability()) == {"Vy"}
            assert bound >= st.availability()["Vy"].floor
        _reseed(seed, check)
    finally:
        sa.close()
