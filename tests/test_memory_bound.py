"""Memory-bounded retrieval: contribution-cache budgets and the
depth-weighted, archive-aware SegmentCache.

The budget contract is *bit-identity*: a bounded reader may spend extra
recompute but must reconstruct exactly what the unbounded reader does, at
every budget including zero.  The cache contract is *isolation + skew*:
MSB/low-depth segments out-live LSB segments at equal recency, and a hot
archive can never evict another archive below its residency floor.
"""
import numpy as np
import pytest

from tests._hypothesis_shim import given, settings, strategies as st

from repro.core.refactor import METHODS, refactor_variables
from repro.data.synthetic import ge_like_fields
from repro.options import OpenOptions, SessionOptions
from repro.store import SegmentCache, memory_store_archive, segment_depth
from repro.store.cache import _MAX_BAND


def _vel_fields(n=1 << 12, seed=0):
    fields = ge_like_fields(n=n, seed=seed)
    return {k: fields[k] for k in ("Vx", "Vy", "Vz")}


EPS_LADDER = (1e-1, 1e-3, 1e-5, 1e-7)


# ----------------------------------------------- bounded reader bit-identity --


@pytest.mark.parametrize("method", METHODS)
def test_quarter_budget_bit_identical_all_methods(method):
    """0.25x budget: bit-identical values AND achieved bounds for all four
    methods, through the store-backed path (exercises the open_reader
    budget plumbing of bitplane and snapshot variables alike)."""
    vel = _vel_fields()
    arch = refactor_variables(vel, method=method)
    unbounded = arch.open()
    if method in ("hb", "ob"):
        full = max((var.levels + 1) * int(np.prod(var.padded_shape)) * 8
                   for var in arch.variables.values())
        budget = full // 4
    else:
        budget = 1 << 20     # snapshot readers: knob accepted, unused
    with memory_store_archive(arch) as sa:
        bounded = sa.open(SessionOptions.memory_bounded(budget))
        for eps in EPS_LADDER:
            for v in vel:
                a, ba = unbounded.reconstruct(v, eps)
                b, bb = bounded.reconstruct(v, eps)
                assert np.array_equal(a, b), (method, v, eps)
                assert ba == bb


def test_zero_budget_degrades_to_recompute_always():
    """Budget 0 retains nothing — every refresh rebuilds every level — yet
    outputs stay bit-identical and a repeat request is still served from
    the cached reconstruction without touching the streams."""
    vel = {"Vx": _vel_fields()["Vx"]}
    arch = refactor_variables(vel, method="hb")
    ref, zero = arch.open(), arch.open(SessionOptions.memory_bounded(0))
    for eps in EPS_LADDER:
        a, _ = ref.reconstruct("Vx", eps)
        b, _ = zero.reconstruct("Vx", eps)
        assert np.array_equal(a, b)
    st_ = zero.contrib_stats()
    assert st_.contrib_resident_bytes == 0
    assert st_.contrib_peak_bytes == 0
    assert st_.contrib_spills > 0
    # repeat at an already-satisfied eps: no stream moves, no rebuild
    before = zero.contrib_stats()
    zero.reconstruct("Vx", EPS_LADDER[-1])
    assert zero.contrib_stats() == before


def test_tiny_budget_bounds_peak_and_counts_recomputes():
    """Peak retained bytes never exceed the budget; a refresh where only
    one level moved charges budget-induced recomputes for the spilled,
    unmoved levels (an unbounded reader would have served them cached)."""
    vel = {"Vx": _vel_fields()["Vx"]}
    arch = refactor_variables(vel, method="hb")
    var = arch.variables["Vx"]
    field = int(np.prod(var.padded_shape)) * 8
    session = arch.open(SessionOptions.memory_bounded(2 * field))
    for eps in EPS_LADDER:
        session.reconstruct("Vx", eps)
    reader = session.readers["Vx"]
    st_ = session.contrib_stats()
    assert st_.contrib_peak_bytes <= 2 * field
    assert st_.contrib_resident_bytes == 2 * field
    assert reader.contrib_resident_levels == [0, 1]    # finest stay resident
    # move ONE coarse stream by hand, then re-request the same eps: the
    # moved level is stale, the other spilled levels are pure recompute
    before = st_.contrib_recomputes
    base = var.levels
    reader.streams[base].fetch_to_planes(reader.streams[base].fetched + 1)
    session.reconstruct("Vx", EPS_LADDER[-1])
    after = session.contrib_stats().contrib_recomputes
    assert after - before == var.levels - 2   # all spilled but the moved one


def test_budget_full_requirement_never_spills():
    vel = {"Vx": _vel_fields()["Vx"]}
    arch = refactor_variables(vel, method="hb")
    var = arch.variables["Vx"]
    full = (var.levels + 1) * int(np.prod(var.padded_shape)) * 8
    session = arch.open(SessionOptions.memory_bounded(full))
    for eps in EPS_LADDER:
        session.reconstruct("Vx", eps)
    st_ = session.contrib_stats()
    assert st_.contrib_spills == 0 and st_.contrib_recomputes == 0
    assert st_.contrib_peak_bytes == full


def test_store_backed_counters_land_in_fetch_stats():
    """Store-backed readers sink their ContribStats into the fetcher's
    FetchStats, so the serving layer reads transport and residency off one
    object."""
    arch = refactor_variables(_vel_fields(), method="hb")
    with memory_store_archive(arch) as sa:
        session = sa.open(SessionOptions.memory_bounded(0))
        for v in ("Vx", "Vy"):
            session.reconstruct(v, 1e-4)
        assert sa.fetcher.stats.contrib_spills > 0
        assert sa.fetcher.stats.contrib_resident_bytes == 0
        assert session.contrib_stats().contrib_spills == \
            sa.fetcher.stats.contrib_spills     # one shared sink, counted once


def test_resolution_progression_unaffected_by_budget():
    vel = {"Vx": _vel_fields()["Vx"]}
    arch = refactor_variables(vel, method="hb")
    a, ba = arch.open().reconstruct_at_resolution("Vx", 2, 1e-4)
    b, bb = arch.open(SessionOptions.memory_bounded(0)) \
        .reconstruct_at_resolution("Vx", 2, 1e-4)
    assert np.array_equal(a, b) and ba == bb


def test_pipeline_config_server_kwargs_match_server_signature():
    """The config's memory knobs must stay constructible into a
    RetrievalServer — catches field/signature drift."""
    import inspect

    from repro.configs.progressive_retrieval import memory_bounded_config
    from repro.launch.serve import RetrievalServer

    kwargs = memory_bounded_config().server_kwargs()
    params = inspect.signature(RetrievalServer.__init__).parameters
    assert set(kwargs) <= set(params) - {"self"}


def test_link_checker_disambiguates_duplicate_headings(tmp_path):
    from tools.check_links import check_file, headings
    doc = tmp_path / "dup.md"
    doc.write_text("# Example\n\ntext\n\n# Example\n\n"
                   "[first](#example) [second](#example-1) "
                   "[gone](#example-2)\n")
    assert headings(str(doc)) == ["example", "example-1"]
    errors = check_file(str(doc))
    assert len(errors) == 1 and "#example-2" in errors[0]


# ---------------------------------------------------------- segment depth --


def test_segment_depth_parsing():
    assert segment_depth("Vx/g0/p0") == 0
    assert segment_depth("Vx/g3/p17") == 17
    assert segment_depth("Vx/g2/signs") == 0
    assert segment_depth("Vx/s4/b1") == 4
    assert segment_depth("Vx/mask/bitmap") == 0
    assert segment_depth("Vx/mask/values") == 0


# ----------------------------------------------- depth-weighted eviction --


def test_plain_lru_when_depth_weight_zero():
    """depth_weight=0 recovers byte-LRU exactly (the legacy contract)."""
    cache = SegmentCache(max_bytes=1000, depth_weight=0.0)
    for i in range(20):
        cache.put(("k", i), bytes(100), depth=i % 7)
    assert cache.nbytes <= 1000
    assert len(cache) == 10
    assert cache.stats.evictions == 10
    assert all((("k", i) in cache) == (i >= 10) for i in range(20))


def test_msb_outlives_lsb_at_equal_recency():
    """Older MSB entries survive newer LSB entries once the weighted age
    difference exceeds depth_weight * depth."""
    cache = SegmentCache(max_bytes=1000, depth_weight=100.0)
    for i in range(10):
        cache.put(("msb", i), bytes(100), depth=0)
    for i in range(5):
        cache.put(("lsb", i), bytes(100), depth=40)
    assert all(("msb", i) in cache for i in range(10))
    assert not any(("lsb", i) in cache for i in range(5))


def test_get_refreshes_recency():
    cache = SegmentCache(max_bytes=300, depth_weight=0.0)
    cache.put("a", bytes(100))
    cache.put("b", bytes(100))
    cache.put("c", bytes(100))
    assert cache.get("a") is not None      # a is now the most recent
    cache.put("d", bytes(100))             # evicts b, not a
    assert "a" in cache and "b" not in cache


@settings(max_examples=15, deadline=None)
@given(data=st.data())
def test_depth_weighted_eviction_dominance_property(data):
    """No surviving entry is strictly dominated by an evicted one: if s was
    inserted no later than e AND sits at least as deep, s's score is <= e's
    score, so min-score eviction must have taken s first.  Holds for any
    weight, any depth mix, any sizes (single archive, put-only workload,
    unique keys — ticks equal insertion order)."""
    weight = data.draw(st.floats(min_value=0.0, max_value=64.0))
    n = data.draw(st.integers(min_value=4, max_value=40))
    cache = SegmentCache(max_bytes=600, depth_weight=weight)
    log = []
    for i in range(n):
        depth = data.draw(st.integers(min_value=0, max_value=_MAX_BAND))
        size = data.draw(st.integers(min_value=1, max_value=200))
        cache.put(i, bytes(size), depth=depth)
        log.append((i, depth))             # tick == i + 1 (puts only)
    survivors = [(i, d) for i, d in log if i in cache]
    evicted = [(i, d) for i, d in log if i not in cache]
    for ei, ed in evicted:
        for si, sd in survivors:
            if si < ei:                     # s already present at eviction
                s_score = (si + 1) - weight * sd
                e_score = (ei + 1) - weight * ed
                assert s_score >= e_score, (
                    f"survivor {si}(d={sd}) strictly better victim than "
                    f"evicted {ei}(d={ed}) at weight {weight}")


# --------------------------------------------------------- archive budgets --


def test_hot_archive_cannot_evict_other_below_floor():
    cache = SegmentCache(max_bytes=1000, depth_weight=0.0,
                         archive_floor_bytes=300)
    for i in range(3):
        cache.put(("B", i), bytes(100), archive="B")
    for i in range(50):                     # hot archive hammers the cache
        cache.put(("A", i), bytes(100), archive="A")
    assert cache.archive_nbytes("B") == 300
    assert cache.archive_nbytes("A") == 700
    assert cache.stats.floor_protected > 0


def test_archive_may_evict_itself_below_floor():
    """Floors protect against *other* archives' pressure only: an archive
    whose own insertions overflow the cache evicts its own entries."""
    cache = SegmentCache(max_bytes=500, depth_weight=0.0,
                         archive_floor_bytes=400)
    for i in range(10):
        cache.put(("A", i), bytes(100), archive="A")
    assert cache.archive_nbytes("A") == 500
    assert cache.stats.evictions == 5


def test_archive_max_bytes_caps_one_archive():
    cache = SegmentCache(max_bytes=10_000, archive_max_bytes=300)
    for i in range(10):
        cache.put(("A", i), bytes(100), archive="A")
    cache.put(("B", 0), bytes(100), archive="B")
    assert cache.archive_nbytes("A") == 300
    assert cache.archive_nbytes("B") == 100
    assert cache.nbytes == 400


def test_floor_never_breaks_global_bound():
    """Floors are protection, not reservation: with every archive at its
    floor the global byte bound still holds (self-eviction)."""
    cache = SegmentCache(max_bytes=400, depth_weight=0.0,
                         archive_floor_bytes=400)
    for a in ("A", "B", "C"):
        for i in range(3):
            cache.put((a, i), bytes(100), archive=a)
    assert cache.nbytes <= 400


def test_distinct_archives_isolated_through_fetcher():
    """Two archives sharing one cache get distinct derived ids, and the
    floor keeps the first archive's working set resident while the second
    floods the cache."""
    f1 = {"Vx": _vel_fields(seed=1)["Vx"]}
    f2 = {"Vy": _vel_fields(n=1 << 13, seed=2)["Vy"]}
    a1 = refactor_variables(f1, method="hb")
    a2 = refactor_variables(f2, method="hb")
    floor = 4 << 10
    cache = SegmentCache(max_bytes=48 << 10, depth_weight=0.0,
                         archive_floor_bytes=floor)
    with memory_store_archive(a1, OpenOptions(cache=cache)) as s1, \
            memory_store_archive(a2, OpenOptions(cache=cache)) as s2:
        assert s1.archive_id != s2.archive_id
        s1.open().reconstruct("Vx", 1e-6)
        assert cache.archive_nbytes(s1.archive_id) > floor
        s2.open().reconstruct("Vy", 1e-12)  # flood from the second archive
        assert cache.stats.evictions > 0
        assert cache.archive_nbytes(s1.archive_id) >= floor
