"""Fallback for ``hypothesis`` when it is not installed.

The property tests only need a small slice of the API (``given`` /
``settings`` / ``strategies.integers|floats|booleans|data``).  When the real
package is available it is re-exported unchanged; otherwise a deterministic
seeded sampler stands in so the suite still exercises each property over a
spread of values (including the range endpoints) instead of being skipped.
"""
from __future__ import annotations

import importlib.util

if importlib.util.find_spec("hypothesis") is not None:
    from hypothesis import given, settings, strategies  # noqa: F401
else:
    import functools
    import math
    import zlib

    import numpy as np

    _MAX_EXAMPLES_CAP = 50  # keep the fallback sweep cheap

    class _Strategy:
        def __init__(self, draw_fn):
            self._draw_fn = draw_fn

        def draw(self, rng):
            return self._draw_fn(rng)

    class _DataObject:
        """Stand-in for hypothesis' interactive data() draws."""

        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy, label=None):
            return strategy.draw(self._rng)

    class _DataStrategy(_Strategy):
        def __init__(self):
            super().__init__(lambda rng: _DataObject(rng))

    def _draw_float(rng, lo, hi):
        # Mix uniform and log-magnitude draws plus endpoints so wide ranges
        # like [1e-12, 1e12] are covered across scales, as hypothesis does.
        mode = rng.integers(0, 4)
        if mode == 0:
            return float(lo)
        if mode == 1:
            return float(hi)
        if mode == 2 or lo == hi:
            return float(rng.uniform(lo, hi))
        # log-magnitude draw within [lo, hi]
        amax = max(abs(lo), abs(hi))
        if amax == 0.0:
            return 0.0
        if lo <= 0.0 <= hi:
            # range spans zero: sweep magnitudes down to a small floor so
            # near-zero values are actually exercised
            amin = min(1e-12, amax)
        else:
            amin = max(min(abs(lo), abs(hi)), 1e-300)
        mag = math.exp(rng.uniform(math.log(amin), math.log(amax)))
        sign = -1.0 if (lo < 0 and (hi <= 0 or rng.integers(0, 2))) else 1.0
        return float(np.clip(sign * mag, lo, hi))

    class _StrategiesModule:
        @staticmethod
        def integers(min_value=0, max_value=2 ** 31 - 1):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value=-1e9, max_value=1e9, allow_nan=False,
                   allow_infinity=False, width=64):
            return _Strategy(lambda rng: _draw_float(rng, float(min_value),
                                                     float(max_value)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(
                lambda rng: elements[int(rng.integers(0, len(elements)))])

        @staticmethod
        def data():
            return _DataStrategy()

    strategies = _StrategiesModule()

    class settings:
        """Decorator recording max_examples on the (already-wrapped) test."""

        def __init__(self, max_examples=20, deadline=None, **_ignored):
            self.max_examples = max_examples

        def __call__(self, fn):
            fn._shim_max_examples = self.max_examples
            return fn

    import inspect

    def given(**strats):
        def decorate(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = min(getattr(wrapper, "_shim_max_examples", 20),
                        _MAX_EXAMPLES_CAP)
                for i in range(n):
                    # crc32, not hash(): stable across processes so a failing
                    # draw reproduces under any PYTHONHASHSEED
                    key = f"{fn.__module__}.{fn.__name__}:{i}".encode()
                    rng = np.random.default_rng(zlib.crc32(key))
                    drawn = {k: s.draw(rng) for k, s in strats.items()}
                    fn(*args, **drawn, **kwargs)
            # hide the property args from pytest's fixture resolution (the
            # shim supplies them); keep any remaining params visible
            params = [p for name, p in
                      inspect.signature(fn).parameters.items()
                      if name not in strats]
            wrapper.__signature__ = inspect.Signature(params)
            del wrapper.__wrapped__
            return wrapper
        return decorate
