import numpy as np
import pytest

# NOTE: no XLA_FLAGS / device-count manipulation here — smoke tests and
# benches must see the real single-device CPU. The multi-pod dry-run sets
# --xla_force_host_platform_device_count=512 in its own entry point only.


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
