"""Live append-only archives (manifest v4) and the unified options API.

The contract under test, end to end:

  * ``ArchiveWriter.create → append → seal`` journals every timestep —
    blobs first, journal line second — so a concurrent reader can
    ``refresh()`` at any moment and only ever sees complete segments;
  * a follow-mode session (``session.follow(var)``) observing timesteps
    as they land is bit- AND byte-identical to a one-shot session reading
    the same timesteps from the finished archive;
  * rolling retention drops whole keyframe→delta chains, readers get a
    clear KeyError for dropped history, and the dropped blobs leave disk;
  * ``seal()`` consolidates the journal into the manifest without changing
    a single reconstructed bit;
  * the ``OpenOptions``/``SessionOptions`` surface replaces the legacy
    kwarg sprawl: old kwargs still work but warn exactly once, unknown or
    mixed kwargs raise, and no src/ module trips the deprecation shim
    (the pytest filter promotes it to an error).
"""
import json
import os
import threading

import numpy as np
import pytest

import repro
from repro.core.refactor import FollowStream
from repro.data.synthetic import ge_like_fields
from repro.options import (
    OpenOptions,
    ReproDeprecationWarning,
    SessionOptions,
    _reset_deprecation_warnings,
)
from repro.store import JOURNAL_NAME, open_archive
from repro.store.cache import SegmentCache
from repro.store.httpd import StoreHTTPServer
from repro.store.writer import ArchiveWriter

EPS = 1e-3
T_TOTAL = 6


def _frames(n=1 << 9, t=T_TOTAL, seed=0):
    base = ge_like_fields(n=n, seed=seed)["Vx"]
    return [np.asarray(base * (1.0 + 0.05 * k) + 0.01 * np.sin(3.0 * k),
                       dtype=base.dtype)
            for k in range(t)]


def _write_all(directory, frames, name="T", keyframe_interval=3, **kw):
    with ArchiveWriter.create(directory, keyframe_interval=keyframe_interval,
                              **kw) as w:
        for f in frames:
            w.append({name: f}, eps=EPS)
    return directory


# ---------------------------------------------------------------------------
# follow-mode vs one-shot bit identity
# ---------------------------------------------------------------------------


def test_follow_mode_bit_identical_to_one_shot(tmp_path):
    """The acceptance criterion: append while a session is open, poll the
    new timesteps in, and the followed reads must match a one-shot session
    over the finished archive — values, bounds, AND byte accounting."""
    frames = _frames()
    live = str(tmp_path / "live")
    with ArchiveWriter.create(live, keyframe_interval=3) as w:
        for f in frames[:2]:
            w.append({"T": f}, eps=EPS)

        sa = open_archive(live)
        st = sa.open()
        stream = st.follow("T")
        assert isinstance(stream, FollowStream)
        assert stream.poll() == [0, 1]

        followed = [stream.read(t) for t in (0, 1)]

        # appends land AFTER the session opened — no reopening anything
        for f in frames[2:]:
            w.append({"T": f}, eps=EPS)

        assert stream.poll() == [2, 3, 4, 5]
        assert stream.poll() == []          # never re-reports
        assert stream.latest == 5
        followed += [stream.read(t) for t in range(2, T_TOTAL)]
        followed_bytes = st.bytes_retrieved

    # one-shot reference: a fresh open of the (same, now complete) archive
    sb = open_archive(live)
    sb_session = sb.open()
    reader = sb_session.reader("T")
    for t in range(T_TOTAL):
        data, bound = reader.read(t)
        np.testing.assert_array_equal(data, followed[t][0])
        assert bound == followed[t][1]
        err = float(np.max(np.abs(data - frames[t])))
        assert err <= bound
    assert sb_session.bytes_retrieved == followed_bytes


def test_refresh_surfaces_new_variables_and_timesteps(tmp_path):
    frames = _frames(t=3)
    live = str(tmp_path / "live")
    with ArchiveWriter.create(live) as w:
        w.append({"A": frames[0]}, eps=EPS)
        sa = open_archive(live)
        st = sa.open()
        assert sa.variables["A"].latest_t == 0

        w.append({"A": frames[1], "B": frames[2]}, eps=EPS)
        applied = sa.refresh()
        assert applied > 0
        assert sa.refresh() == 0            # idempotent: nothing new
        assert sa.variables["A"].latest_t == 1
        # variable journaled after open: session.reader resolves it lazily
        # (each variable has its own timestep counter — B starts at t=0)
        data, bound = st.reader("B").read(0)
        assert float(np.max(np.abs(data - frames[2]))) <= bound


def test_journal_write_order_never_exposes_partial_state(tmp_path):
    """Truncate the journal mid-line (a crashed writer): replay must stop
    at the last complete record instead of erroring or half-applying."""
    frames = _frames(t=3)
    live = _write_all(str(tmp_path / "live"), frames)
    jpath = os.path.join(live, JOURNAL_NAME)
    raw = open(jpath, "rb").read()
    cut = raw.rfind(b"\n", 0, len(raw) - 1) + 1
    with open(jpath, "wb") as fh:
        fh.write(raw[:cut + 10])            # torn final record
    sa = open_archive(live)
    # last full record was t=1's... depends on record layout; the invariant
    # is simply: opening succeeds and whatever is visible decodes
    latest = sa.variables["T"].latest_t
    assert latest is not None and latest >= 1
    st = sa.open()
    data, bound = st.reader("T").read(latest)
    assert float(np.max(np.abs(data - frames[latest]))) <= bound


# ---------------------------------------------------------------------------
# HTTP follow mode
# ---------------------------------------------------------------------------


def test_http_follow_mode_with_conditional_get(tmp_path):
    frames = _frames()
    live = str(tmp_path / "live")
    with ArchiveWriter.create(live, keyframe_interval=3) as w:
        for f in frames[:3]:
            w.append({"T": f}, eps=EPS)
        with StoreHTTPServer(live) as srv:
            sa = open_archive(srv.url_for("manifest.json"))
            st = sa.open()
            stream = st.follow("T")
            assert stream.poll() == [0, 1, 2]
            d0, b0 = stream.read(2)

            # no new appends: polling again must ride the 304 path
            stream.poll()
            stream.poll()
            assert srv.stats["not_modified"] > 0

            for f in frames[3:]:
                w.append({"T": f}, eps=EPS)
            assert stream.poll() == [3, 4, 5]
            d5, b5 = stream.read(5)
            assert float(np.max(np.abs(d5 - frames[5]))) <= b5

    # bit-identity across transports: local one-shot == followed HTTP
    st_local = open_archive(live).open()
    r = st_local.reader("T")
    np.testing.assert_array_equal(r.read(2)[0], d0)
    np.testing.assert_array_equal(r.read(5)[0], d5)


# ---------------------------------------------------------------------------
# retention
# ---------------------------------------------------------------------------


def test_retention_drops_head_chains(tmp_path):
    frames = _frames(t=8)
    live = str(tmp_path / "live")
    with ArchiveWriter.create(live, keyframe_interval=3,
                              retain_timesteps=4) as w:
        sa = None
        for i, f in enumerate(frames):
            w.append({"T": f}, eps=EPS)
            if i == 2:
                sa = open_archive(live)     # open while history still full

        sa.refresh()
        var = sa.variables["T"]
        # retention target = 8 - 4 = 4, snapped DOWN to keyframe t=3
        assert var.base_t == 3
        assert var.handle(3).keyframe
        with pytest.raises(KeyError, match="retention"):
            var.handle(2)
        # dropped blobs left disk; retained ones are still there
        assert not os.path.exists(os.path.join(live, "T.t0.seg"))
        assert not os.path.exists(os.path.join(live, "T.t2.seg"))
        assert os.path.exists(os.path.join(live, "T.t3.seg"))
        # retained range decodes fine
        st = sa.open()
        reader = st.reader("T")
        for t in range(3, 8):
            data, bound = reader.read(t)
            assert float(np.max(np.abs(data - frames[t]))) <= bound


def test_retention_boundary_always_a_keyframe(tmp_path):
    frames = _frames(t=7)
    live = str(tmp_path / "live")
    with ArchiveWriter.create(live, keyframe_interval=4,
                              retain_timesteps=2) as w:
        for f in frames:
            w.append({"T": f}, eps=EPS)
    var = open_archive(live).variables["T"]
    # target would be 7-2=5, but t=5 is a delta — snap down to keyframe 4
    assert var.base_t == 4
    assert var.timesteps[0].keyframe


def test_retention_keyframe_interval_one(tmp_path):
    """``keyframe_interval=1``: every step is a keyframe, so the snap-down
    is the identity — the boundary must land EXACTLY on the target (no
    off-by-one widening the window), no delta chain can anchor past it,
    and the dropped steps' blobs must not linger on disk."""
    frames = _frames(t=7)
    live = str(tmp_path / "live")
    with ArchiveWriter.create(live, keyframe_interval=1,
                              retain_timesteps=3) as w:
        for f in frames:
            w.append({"T": f}, eps=EPS)
    sa = open_archive(live)
    var = sa.variables["T"]
    assert var.base_t == 4                      # 7 - 3, no snapping slack
    for t in range(4, 7):
        assert var.handle(t).keyframe
    with pytest.raises(KeyError, match="retention"):
        var.handle(3)
    for t in range(4):                          # no orphaned segment blobs
        assert not os.path.exists(os.path.join(live, f"T.t{t}.seg"))
    st = sa.open()
    reader = st.reader("T")
    for t in range(4, 7):
        data, bound = reader.read(t)
        assert float(np.max(np.abs(data - frames[t]))) <= bound


def test_retention_window_covering_all_steps_drops_nothing(tmp_path):
    """``retain >= appended steps``: the retention target is <= 0, which
    must behave as "keep everything" — base stays at t=0, the live head
    chain survives intact, and no blob is unlinked."""
    frames = _frames(t=4)
    live = str(tmp_path / "live")
    with ArchiveWriter.create(live, keyframe_interval=2,
                              retain_timesteps=9) as w:
        for f in frames:
            w.append({"T": f}, eps=EPS)
        sa = open_archive(live)
        var = sa.variables["T"]
        assert var.base_t == 0
        assert len(var.timesteps) == 4
        for t in range(4):
            assert os.path.exists(os.path.join(live, f"T.t{t}.seg"))
        st = sa.open()
        reader = st.reader("T")
        for t in range(4):
            data, bound = reader.read(t)
            assert float(np.max(np.abs(data - frames[t]))) <= bound
        # the exact-equality edge (retain == appended) also keeps it all
        w.append({"T": frames[0]}, eps=EPS)     # now 5 appended, retain 9
        sa.refresh()
        assert sa.variables["T"].base_t == 0


# ---------------------------------------------------------------------------
# sealing
# ---------------------------------------------------------------------------


def test_seal_preserves_bits_and_skips_journal(tmp_path):
    frames = _frames()
    live = str(tmp_path / "live")
    w = ArchiveWriter.create(live, keyframe_interval=3)
    for f in frames:
        w.append({"T": f}, eps=EPS)

    live_session = open_archive(live).open()
    live_reads = [live_session.reader("T").read(t) for t in range(T_TOTAL)]
    live_bytes = live_session.bytes_retrieved

    w.seal()
    with pytest.raises(ValueError, match="sealed"):
        w.seal()
    with pytest.raises(ValueError, match="sealed"):
        w.append({"T": frames[0]}, eps=EPS)

    manifest = json.loads(open(os.path.join(live, "manifest.json"),
                               "rb").read())
    assert manifest["sealed"] is True

    sealed = open_archive(live)
    assert sealed.refresh() == 0            # consolidated: replay is a no-op
    st = sealed.open()
    reader = st.reader("T")
    for t in range(T_TOTAL):
        data, bound = reader.read(t)
        np.testing.assert_array_equal(data, live_reads[t][0])
        assert bound == live_reads[t][1]
    assert st.bytes_retrieved == live_bytes


def test_writer_validation(tmp_path):
    live = str(tmp_path / "live")
    frames = _frames(t=2)
    with ArchiveWriter.create(live) as w:
        w.append({"T": frames[0]}, eps=EPS)
        with pytest.raises(ValueError, match="shape"):
            w.append({"T": frames[0][:17]}, eps=EPS)
    with pytest.raises(FileExistsError):
        ArchiveWriter.create(live)
    with pytest.raises(ValueError):
        ArchiveWriter.create(str(tmp_path / "x"), keyframe_interval=0)


def test_writer_over_static_base(tmp_path):
    """create(base=...) journals on top of a static archive: the base's
    bitplane variables and appended timeseries coexist in one manifest."""
    from repro.core.refactor import refactor_variables
    fields = ge_like_fields(n=1 << 9, seed=1)
    base_arch = refactor_variables({"Vx": fields["Vx"]}, method="hb")
    frames = _frames(t=2, seed=1)
    live = str(tmp_path / "live")
    with ArchiveWriter.create(live, base=base_arch) as w:
        with pytest.raises(ValueError, match="exist"):
            w.append({"Vx": frames[0]}, eps=EPS)   # name collision
        w.append({"T": frames[0]}, eps=EPS)
    sa = open_archive(live)
    st = sa.open()
    data, bound = st.reconstruct("Vx", 1e-4)
    assert float(np.max(np.abs(data - fields["Vx"]))) <= bound
    data, bound = st.reader("T").read(0)
    assert float(np.max(np.abs(data - frames[0]))) <= bound


def test_follow_rejects_non_timeseries(tmp_path):
    from repro.core.refactor import refactor_variables
    fields = ge_like_fields(n=1 << 9, seed=0)
    arch = refactor_variables({"Vx": fields["Vx"]}, method="hb")
    with pytest.raises(ValueError, match="timeseries"):
        arch.open().follow("Vx")


def test_concurrent_refresh_during_reads(tmp_path):
    """A reader hammering read() while another thread applies journal
    refreshes must never crash or mis-decode — the growing-archive
    thread-safety claim."""
    frames = _frames(t=8)
    live = str(tmp_path / "live")
    with ArchiveWriter.create(live, keyframe_interval=3) as w:
        w.append({"T": frames[0]}, eps=EPS)
        sa = open_archive(live, OpenOptions(cache=SegmentCache()))
        st = sa.open()
        errors = []

        def refresher():
            for f in frames[1:]:
                w.append({"T": f}, eps=EPS)
                sa.refresh()

        thr = threading.Thread(target=refresher)
        thr.start()
        try:
            while thr.is_alive():
                latest = sa.variables["T"].latest_t
                data, bound = st.reader("T").read(latest)
                want = frames[latest]
                if float(np.max(np.abs(data - want))) > bound:
                    errors.append(latest)
        finally:
            thr.join()
        assert not errors
        sa.refresh()
        data, bound = st.reader("T").read(7)
        assert float(np.max(np.abs(data - frames[7]))) <= bound


# ---------------------------------------------------------------------------
# options surface: presets, deprecation shim, top-level API
# ---------------------------------------------------------------------------


def _tiny_archive():
    from repro.core.refactor import refactor_variables
    fields = ge_like_fields(n=1 << 8, seed=0)
    return refactor_variables({"Vx": fields["Vx"]}, method="hb")


def test_open_options_presets():
    cache = SegmentCache()
    from repro.store.retry import BlobQuarantine, RetryPolicy
    mt = OpenOptions.multi_tenant(cache, retry_policy=RetryPolicy.none(),
                                  quarantine=BlobQuarantine())
    assert mt.cache is cache and mt.retry_policy is not None
    assert OpenOptions.unverified().verify is False
    assert OpenOptions.default().prefetch_workers == 2
    assert mt.with_(prefetch_workers=7).prefetch_workers == 7
    assert mt.with_(prefetch_workers=7).cache is cache
    with pytest.raises(TypeError):
        OpenOptions(bogus=1)


def test_session_options_presets():
    assert SessionOptions.memory_bounded(123).contrib_budget_bytes == 123
    assert SessionOptions.default().prefetch_depth == 1
    with pytest.raises(TypeError):
        SessionOptions(bogus=1)


def test_legacy_kwargs_warn_once_then_stay_quiet(tmp_path):
    _reset_deprecation_warnings()
    arch = _tiny_archive()
    path = str(tmp_path / "a.prs")
    repro.save_archive(arch, path)
    with pytest.warns(ReproDeprecationWarning, match="OpenOptions"):
        sa = open_archive(path, verify=False)
    assert sa.fetcher.verify is False
    # second use of the SAME legacy signature: silent (warn-once), and the
    # session-level error filter would have failed the test otherwise
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error", ReproDeprecationWarning)
        open_archive(path, verify=False)
    _reset_deprecation_warnings()


def test_legacy_session_kwargs_route_through_shim(tmp_path):
    _reset_deprecation_warnings()
    arch = _tiny_archive()
    with pytest.warns(ReproDeprecationWarning, match="SessionOptions"):
        st = arch.open(contrib_budget_bytes=1 << 16)
    assert st.options.contrib_budget_bytes == 1 << 16
    _reset_deprecation_warnings()


def test_mixing_options_and_legacy_kwargs_raises(tmp_path):
    arch = _tiny_archive()
    path = str(tmp_path / "a.prs")
    repro.save_archive(arch, path)
    with pytest.raises(TypeError, match="both"):
        open_archive(path, OpenOptions.default(), verify=False)
    with pytest.raises(TypeError, match="both"):
        arch.open(SessionOptions.default(), prefetch_depth=0)
    with pytest.raises(TypeError):
        open_archive(path, definitely_not_a_kwarg=1)


def test_top_level_api_resolves():
    """Every name repro.__all__ promises must lazily resolve, and the
    canonical spellings must be the same objects as the deep imports."""
    for name in repro.__all__:
        assert getattr(repro, name) is not None
    from repro.store.container import open_archive as deep_open
    assert repro.open is deep_open and repro.open_archive is deep_open
    assert repro.ArchiveWriter is ArchiveWriter
    assert repro.OpenOptions is OpenOptions
    with pytest.raises(AttributeError):
        repro.not_a_thing
