"""Dry-run machinery: sharding rules cover every arch, specs sanitize, and
one real 512-device lower+compile runs in a subprocess (the XLA fake-device
flag must not leak into this test process)."""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro import configs
from repro.launch.mesh import make_mesh
from repro.models import transformer as T
from repro.models.config import SHAPES, cell_is_runnable
from repro.train.sharding import (
    batch_pspecs, decode_state_pspecs, param_pspecs, sanitize_pspecs,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def mesh():
    return make_mesh((1, 1), ("data", "model"))


@pytest.mark.parametrize("arch", configs.names())
def test_param_pspecs_cover_all_leaves(arch, mesh):
    cfg = configs.get(arch)
    shapes = jax.eval_shape(lambda k: T.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    specs = param_pspecs(cfg, shapes, mesh)
    flat_shapes = jax.tree_util.tree_leaves(shapes)
    flat_specs = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    assert len(flat_shapes) == len(flat_specs)
    for sh, sp in zip(flat_shapes, flat_specs):
        assert len(sp) <= len(sh.shape), (sh.shape, sp)


@pytest.mark.parametrize("arch", configs.names())
def test_decode_state_pspecs_match_state(arch, mesh):
    cfg = configs.get(arch)
    state = jax.eval_shape(lambda: T.init_decode_state(cfg, 8, 64))
    specs = {k: decode_state_pspecs(cfg, mesh)[k] for k in state}
    fixed = sanitize_pspecs(specs, state, mesh)
    assert set(fixed) == set(state)


def test_sanitize_drops_nondivisible():
    from jax.sharding import PartitionSpec as P
    mesh = make_mesh((1, 1), ("data", "model"))
    mesh16 = None
    # simulate a 16-wide axis via a fake mesh-shape lookup
    class FakeMesh:
        shape = {"data": 16, "model": 16}
    spec = P(None, "data", None, "model")
    shaped = jax.ShapeDtypeStruct((48, 1, 3, 3328), np.float32)
    out = sanitize_pspecs(spec, shaped, FakeMesh())
    assert out == P(None, None, None, "model")


def test_skip_rules():
    assert cell_is_runnable(configs.get("mamba2-780m"),
                            SHAPES["long_500k"])[0]
    assert cell_is_runnable(configs.get("zamba2-2.7b"),
                            SHAPES["long_500k"])[0]
    ok, why = cell_is_runnable(configs.get("qwen2.5-14b"),
                               SHAPES["long_500k"])
    assert not ok and "sub-quadratic" in why
    for shape in ("train_4k", "prefill_32k", "decode_32k"):
        for arch in configs.names():
            assert cell_is_runnable(configs.get(arch), SHAPES[shape])[0]


@pytest.mark.slow
def test_one_cell_compiles_on_512_devices(tmp_path):
    """Full production-mesh lower+compile for one fast cell, in a subprocess
    (device count is locked at first jax init, so it cannot run in-process).
    """
    out = tmp_path / "dryrun.json"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "internlm2-1.8b", "--shape", "decode_32k",
         "--mesh", "single", "--out", str(out)],
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
        capture_output=True, text=True, timeout=1200, cwd=REPO)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    results = json.loads(out.read_text())
    cell = results["internlm2-1.8b__decode_32k__single"]
    assert cell["status"] == "ok"
    assert cell["n_devices"] == 256
    assert cell["hlo"]["dot_flops"] > 0
    assert cell["memory"]["argument_bytes"] > 0
