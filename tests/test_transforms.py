"""Multilevel transform invariants: exact round trips, level maps, and the
HB/OB L-inf error-composition bounds under per-level coefficient noise."""
import numpy as np
import pytest
from _hypothesis_shim import given, settings, strategies as st

from repro.transform.hierarchical import (
    decompose_hb, grid_levels, level_map, pad_to_grid, recompose_hb, unpad,
)
from repro.transform.orthogonal import decompose_ob, ob_kappa, recompose_ob

SHAPES = [(65,), (100,), (33, 17), (9, 9, 9), (20, 13, 7)]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("method", ["hb", "ob"])
def test_round_trip_exact(shape, method):
    x = np.random.default_rng(42).standard_normal(shape) * 100
    padded, orig = pad_to_grid(x)
    L = grid_levels(padded.shape)
    dec = decompose_hb if method == "hb" else decompose_ob
    rec = recompose_hb if method == "hb" else recompose_ob
    c = dec(padded, L)
    r = np.asarray(rec(c, L))
    np.testing.assert_allclose(unpad(r, orig), x, atol=1e-10, rtol=0)


@pytest.mark.parametrize("shape", SHAPES)
def test_level_map_partitions_grid(shape):
    padded, _ = pad_to_grid(np.zeros(shape))
    L = grid_levels(padded.shape)
    lm = level_map(padded.shape, L)
    assert lm.shape == padded.shape
    assert lm.min() == 0 and lm.max() == L
    # base grid nodes = stride-2^L lattice
    base = np.zeros(padded.shape, dtype=bool)
    base[tuple(slice(None, None, 1 << L) for _ in padded.shape)] = True
    np.testing.assert_array_equal(lm == L, base)


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 10_000), ndim=st.integers(1, 3))
def test_hb_linf_bound_composition(seed, ndim):
    """Perturb each level's coefficients by e_l; reconstruction error must
    stay below Σ_l e_l (the HB bound the retrieval budgeting relies on)."""
    rng = np.random.default_rng(seed)
    shape = tuple([17] * ndim)
    x = rng.standard_normal(shape) * 10
    padded, orig = pad_to_grid(x)
    L = grid_levels(padded.shape)
    c = np.asarray(decompose_hb(padded, L))
    lm = level_map(padded.shape, L)
    e_levels = 10.0 ** rng.uniform(-6, -1, size=L + 1)
    noise = rng.uniform(-1, 1, size=c.shape)
    for l in range(L + 1):
        noise[lm == l] *= e_levels[l]
    r_noisy = np.asarray(recompose_hb(c + noise, L))
    r_clean = np.asarray(recompose_hb(c, L))
    err = np.abs(r_noisy - r_clean).max()
    assert err <= e_levels.sum() * (1 + 1e-9)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), ndim=st.integers(1, 2))
def test_ob_linf_bound_composition(seed, ndim):
    """Same for OB with the (1+κ) amplification (κ = 3^d)."""
    rng = np.random.default_rng(seed)
    shape = tuple([17] * ndim)
    x = rng.standard_normal(shape) * 10
    padded, orig = pad_to_grid(x)
    L = grid_levels(padded.shape)
    c = np.asarray(decompose_ob(padded, L))
    lm = level_map(padded.shape, L)
    e_levels = 10.0 ** rng.uniform(-6, -2, size=L + 1)
    noise = rng.uniform(-1, 1, size=c.shape)
    for l in range(L + 1):
        noise[lm == l] *= e_levels[l]
    r_noisy = np.asarray(recompose_ob(c + noise, L))
    r_clean = np.asarray(recompose_ob(c, L))
    err = np.abs(r_noisy - r_clean).max()
    kappa = ob_kappa(ndim)
    bound = (1 + kappa) * e_levels[:-1].sum() + e_levels[-1]
    assert err <= bound * (1 + 1e-9)


def test_hb_levels_independent():
    """HB surpluses depend only on original data — levels are parallel
    (the TPU-adaptation claim in DESIGN.md)."""
    rng = np.random.default_rng(0)
    x = rng.standard_normal(65)
    padded, _ = pad_to_grid(x)
    L = grid_levels(padded.shape)
    c_full = np.asarray(decompose_hb(padded, L))
    # computing only the finest level must give identical finest surpluses
    c_one = np.asarray(decompose_hb(padded, 1))
    lm = level_map(padded.shape, L)
    np.testing.assert_allclose(c_full[lm == 0], c_one[level_map(padded.shape, 1) == 0])
