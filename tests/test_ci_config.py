"""CI pipeline: workflow structure (the `act`-less dry-run equivalent), the
bench-regression gate's comparison logic, and the docs link checker."""
import os
import subprocess
import sys

import pytest

try:
    import yaml
except ImportError:                      # pragma: no cover
    yaml = None

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
WORKFLOW = os.path.join(REPO, ".github", "workflows", "ci.yml")

sys.path.insert(0, REPO)
from benchmarks.check_regression import (  # noqa: E402
    OK,
    REGRESSION,
    SKIPPED,
    STALE,
    compare,
    format_table,
)
from tools.check_links import (  # noqa: E402
    check_file,
    collect_markdown,
    iter_links,
    slugify,
)


def _row(us):
    return {"us_per_call": us, "derived": ""}


# ------------------------------------------------------------- gate logic --


def test_gate_passes_within_tolerance():
    base = {"a": _row(1000.0), "b": _row(500.0)}
    cur = {"a": _row(1400.0), "b": _row(400.0)}
    rows, failures = compare(base, cur, tolerance=1.5)
    assert failures == []
    assert all(r[4] == OK for r in rows)


def test_gate_fails_on_regression():
    base = {"a": _row(1000.0), "b": _row(500.0)}
    cur = {"a": _row(1600.0), "b": _row(500.0)}
    rows, failures = compare(base, cur, tolerance=1.5)
    assert failures == ["a"]
    assert dict((r[0], r[4]) for r in rows) == {"a": REGRESSION, "b": OK}


def test_gate_fails_on_artificially_inflated_baseline():
    """An inflated baseline entry would mask future regressions up to its
    inflation factor — the two-sided default catches it as stale."""
    base = {"a": _row(1000.0), "b": _row(500.0)}
    cur = {"a": _row(1000.0), "b": _row(500.0)}
    base["a"] = _row(10_000.0)          # the artificial inflation
    rows, failures = compare(base, cur, tolerance=1.5)
    assert failures == ["a"]
    assert rows[0][4] == STALE
    # --one-sided turns the stale check off
    _, failures = compare(base, cur, tolerance=1.5, two_sided=False)
    assert failures == []


def test_gate_skips_noise_floor_and_intersects_rows():
    base = {"tiny": _row(3.0), "only_base": _row(100.0), "a": _row(100.0)}
    cur = {"tiny": _row(9.0), "only_cur": _row(100.0), "a": _row(110.0)}
    rows, failures = compare(base, cur, tolerance=1.5, min_us=50.0)
    names = [r[0] for r in rows]
    assert names == ["a", "tiny"]       # intersection only
    assert dict((r[0], r[4]) for r in rows)["tiny"] == SKIPPED
    assert failures == []
    assert "tiny" in format_table(rows)


def test_gate_prefix_filter_and_bad_tolerance():
    base = {"store/x": _row(100.0), "kernels/y": _row(100.0)}
    cur = {"store/x": _row(100.0), "kernels/y": _row(1000.0)}
    _, failures = compare(base, cur, tolerance=1.5, prefixes=["store/"])
    assert failures == []
    with pytest.raises(ValueError):
        compare(base, cur, tolerance=0.9)


def test_gate_cli_fails_on_inflated_baseline(tmp_path):
    """End-to-end CLI check: exit code 1 + printed table on drift."""
    import json
    base = tmp_path / "base.json"
    cur = tmp_path / "cur.json"
    base.write_text(json.dumps({"store/x": _row(10_000.0)}))
    cur.write_text(json.dumps({"store/x": _row(100.0)}))
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.check_regression",
         "--baseline", str(base), "--current", str(cur)],
        capture_output=True, text=True, cwd=REPO)
    assert proc.returncode == 1
    assert "STALE-BASELINE" in proc.stdout
    cur.write_text(json.dumps({"store/x": _row(11_000.0)}))
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.check_regression",
         "--baseline", str(base), "--current", str(cur)],
        capture_output=True, text=True, cwd=REPO)
    assert proc.returncode == 0


# ------------------------------------------------------- docs link checker --


def test_slugify_matches_github_anchors():
    assert slugify("Quickstart") == "quickstart"
    assert slugify("5. Memory budgets (bounded retrieval)") == \
        "5-memory-budgets-bounded-retrieval"
    assert slugify("Store format: containers, manifests, segments") == \
        "store-format-containers-manifests-segments"
    assert slugify("`code` in a heading") == "code-in-a-heading"


def test_iter_links_skips_code_blocks():
    text = ("see [a](x.md) here\n"
            "```\n[not](a-link.md)\n```\n"
            "inline `[also not](skipped.md)` but [b](y.md#sec)\n")
    assert [t for _, t in iter_links(text)] == ["x.md", "y.md#sec"]


def test_check_file_reports_broken_and_passes_good(tmp_path):
    good = tmp_path / "good.md"
    good.write_text("# A Section\n\nlink [self](#a-section) and "
                    "[other](other.md#real-heading) and "
                    "[ext](https://example.com/404)\n")
    (tmp_path / "other.md").write_text("# Real heading\n")
    assert check_file(str(good)) == []
    bad = tmp_path / "bad.md"
    bad.write_text("[gone](missing.md)\n[anchor](other.md#nope)\n")
    errors = check_file(str(bad))
    assert len(errors) == 2
    assert "missing.md" in errors[0] and "#nope" in errors[1]


def test_collect_markdown_walks_directories(tmp_path):
    (tmp_path / "sub").mkdir()
    (tmp_path / "a.md").write_text("x")
    (tmp_path / "sub" / "b.md").write_text("x")
    (tmp_path / "sub" / "c.txt").write_text("x")
    found = collect_markdown([str(tmp_path)])
    assert [os.path.basename(f) for f in found] == ["a.md", "b.md"]


def test_check_links_cli_on_this_repo_and_on_breakage(tmp_path):
    """The committed README + docs must pass, and the CLI must exit 1 with
    a pointed report when a link breaks."""
    proc = subprocess.run(
        [sys.executable, "-m", "tools.check_links", "README.md", "docs"],
        capture_output=True, text=True, cwd=REPO)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "0 broken link(s)" in proc.stdout
    bad = tmp_path / "bad.md"
    bad.write_text("[dead](nowhere.md)\n")
    proc = subprocess.run(
        [sys.executable, "-m", "tools.check_links", str(bad)],
        capture_output=True, text=True, cwd=REPO)
    assert proc.returncode == 1
    assert "bad.md:1" in proc.stdout and "nowhere.md" in proc.stdout


def test_docs_guides_exist_and_are_linked_from_readme():
    """The docs tree is the contract: three guides, all reachable from the
    README."""
    for name in ("architecture.md", "store-format.md",
                 "qoi-error-control.md"):
        assert os.path.exists(os.path.join(REPO, "docs", name)), name
    with open(os.path.join(REPO, "README.md"), encoding="utf-8") as fh:
        targets = {t.split("#")[0] for _, t in iter_links(fh.read())}
    assert {"docs/architecture.md", "docs/store-format.md",
            "docs/qoi-error-control.md"} <= targets


# ------------------------------------------------------ workflow structure --


@pytest.mark.skipif(yaml is None, reason="pyyaml unavailable")
def test_workflow_parses_and_has_required_jobs():
    with open(WORKFLOW) as fh:
        wf = yaml.safe_load(fh)
    jobs = wf["jobs"]
    assert set(jobs) == {"lint", "docs", "test", "bench-gate", "nightly-slow"}
    # triggers: pushes/PRs plus the nightly schedule
    on = wf[True] if True in wf else wf["on"]   # yaml 1.1 parses `on:` as True
    assert "pull_request" in on and "schedule" in on
    # the test matrix covers both supported minors with pip caching
    matrix = jobs["test"]["strategy"]["matrix"]["python-version"]
    assert matrix == ["3.10", "3.11"]
    for job in jobs.values():
        setup = [s for s in job["steps"]
                 if "setup-python" in str(s.get("uses", ""))]
        assert setup
        # jobs that install deps must cache pip; dep-less jobs (docs link
        # check is stdlib-only) must NOT pay the cache save/restore
        installs = any("pip install" in s.get("run", "")
                       for s in job["steps"])
        assert (setup[0]["with"].get("cache") == "pip") == installs


@pytest.mark.skipif(yaml is None, reason="pyyaml unavailable")
def test_workflow_commands_are_runnable_here():
    """Dry-run equivalent of `act`: every `run` command the workflow executes
    against the repo must reference files/modules that exist, and the tier-1
    invocation must match ROADMAP's contract."""
    with open(WORKFLOW) as fh:
        wf = yaml.safe_load(fh)
    runs = [step["run"]
            for job in wf["jobs"].values()
            for step in job["steps"] if "run" in step]
    joined = "\n".join(runs)
    assert "PYTHONPATH=src python -m pytest -x -q" in joined
    assert "python -m benchmarks.run --only store,entropy" in joined
    assert "python -m benchmarks.check_regression" in joined
    assert "--baseline BENCH_kernels.json" in joined
    # the entropy-stage bench rows are part of the regression gate
    assert "--prefix entropy/" in joined
    # ... and so are the robustness rows (retry/fault-injection overhead)
    assert "--only store,entropy,robust" in joined
    assert "--prefix robust/" in joined
    # ... and the concurrent serve-plane rows (worker pool + coalescing
    # speedup, tail amplification) ride the same gate
    assert "--only store,entropy,robust,serve" in joined
    assert "--prefix serve/" in joined
    # ... and the ip-vs-hb bytes-at-equal-bound rows (the interpolation-
    # predicted representation's scoreboard) are diffed on every PR
    assert "--only store,entropy,robust,serve,rate_distortion" in joined
    assert "--prefix rate_distortion/ip_vs_hb" in joined
    assert "python -m tools.check_links README.md docs" in joined
    # CI must stay one-sided/loose: the committed baseline is not recorded
    # on the runner class (two-sided 1.5x is the local invocation)
    assert "--one-sided" in joined
    assert os.path.exists(os.path.join(REPO, "BENCH_kernels.json"))
    assert os.path.exists(os.path.join(REPO, "ruff.toml"))
    # every python -m module named in the workflow resolves in this checkout
    import importlib.util
    for mod in ("benchmarks.run", "benchmarks.check_regression",
                "tools.check_links", "pytest"):
        assert importlib.util.find_spec(mod) is not None, mod


def test_serve_bench_and_smoke_ride_the_pipeline():
    """The serve-plane bench is part of the full harness run (its rows land
    in BENCH_kernels.json) and the concurrent-serve suite — including the
    /health + /metrics HTTP smoke test — runs on every tier-1 leg."""
    from benchmarks.run import MODULES
    assert "bench_serve_concurrent" in MODULES
    assert os.path.exists(
        os.path.join(REPO, "benchmarks", "bench_serve_concurrent.py"))
    path = os.path.join(REPO, "tests", "test_serve_concurrent.py")
    assert os.path.exists(path)
    with open(path, encoding="utf-8") as fh:
        src = fh.read()
    assert "mark.slow" not in src, \
        "test_serve_concurrent.py must stay in the tier-1 (not-slow) " \
        "selection"
    assert "def test_health_and_metrics_endpoints_under_concurrency" in src


def test_codec_conformance_suite_rides_in_tier1():
    """The plane-codec conformance suite and the golden-archive tests run
    on every tier-1 matrix leg: they carry no `slow` marker (the nightly
    job is the only place slow tests run) and the fixtures they pin are
    committed."""
    for fname in ("test_entropy_codecs.py", "test_golden_archives.py"):
        path = os.path.join(REPO, "tests", fname)
        assert os.path.exists(path), fname
        with open(path, encoding="utf-8") as fh:
            assert "mark.slow" not in fh.read(), \
                f"{fname} must stay in the tier-1 (not-slow) selection"
    for fixture in ("golden_v1.prs", "golden_expected.npz",
                    "golden_v34_expected.npz", "golden_ip_expected.npz",
                    os.path.join("golden_v2", "manifest.json"),
                    os.path.join("golden_v3", "manifest.json"),
                    os.path.join("golden_v4", "manifest.json"),
                    os.path.join("golden_v4", "journal.jsonl"),
                    os.path.join("golden_ip", "manifest.json")):
        assert os.path.exists(
            os.path.join(REPO, "tests", "fixtures", fixture)), fixture


def test_live_archive_bench_rows_ride_the_gate():
    """The append-throughput / follow-latency / delta-wire-bytes rows are
    part of the committed baseline (the bench gate's --prefix store/ pulls
    them in), and the recorded delta economics actually show the win the
    journal exists for."""
    import json
    with open(os.path.join(REPO, "BENCH_kernels.json")) as fh:
        baseline = json.load(fh)
    for name in ("store/append_throughput", "store/append_delta_bytes",
                 "store/follow_latency"):
        assert name in baseline, name
    derived = dict(kv.split("=", 1) for kv in
                   baseline["store/append_delta_bytes"]["derived"].split(";"))
    assert float(derived["ratio"]) < 0.9, \
        "recorded delta timesteps are not measurably smaller than keyframes"


def test_ip_bench_rows_ride_the_gate():
    """The ip-vs-hb bytes-at-equal-QoI-bound rows are part of the committed
    baseline (the bench gate's --prefix rate_distortion/ip_vs_hb pulls
    them in), and the recorded economics show the win the predictor exists
    for: ip <= hb wire bytes at every recorded point, strictly smaller at
    the mid bitrates."""
    import json
    with open(os.path.join(REPO, "BENCH_kernels.json")) as fh:
        baseline = json.load(fh)
    rows = [n for n in baseline
            if n.startswith("rate_distortion/ip_vs_hb/")]
    assert len(rows) >= 3, "ip_vs_hb rows missing from baseline"
    ratios = []
    for name in rows:
        derived = dict(kv.split("=", 1) for kv in
                       baseline[name]["derived"].split(";"))
        assert int(derived["ip_bytes"]) <= int(derived["hb_bytes"]), name
        ratios.append(float(derived["ratio"]))
    assert min(ratios) < 1.0, \
        "recorded ip rows show no byte win over hb at any bitrate"


def test_device_decode_rows_ride_the_gate():
    """The fused device-decode kernel row and the serve-plane batched-tick
    row are part of the committed baseline (the bench gate's --prefix
    kernels/ and serve/ pulls pull them in), the recorded fused decode is
    bit-exact against the host pair, and the recorded batched tick really
    shows the dispatch collapse the batcher exists for (>= 2 decode items
    per device dispatch at 64 clients)."""
    import json
    with open(os.path.join(REPO, "BENCH_kernels.json")) as fh:
        baseline = json.load(fh)
    dd = [n for n in baseline if n.startswith("kernels/device_decode")]
    bt = [n for n in baseline if n.startswith("serve/batched_tick")]
    assert dd, "kernels/device_decode row missing from baseline"
    assert bt, "serve/batched_tick row missing from baseline"
    derived = dict(kv.split("=", 1) for kv in
                   baseline[dd[0]]["derived"].split(";"))
    assert derived["exact"] == "True", \
        "recorded fused device decode is not bit-exact vs the host pair"
    derived = dict(kv.split("=", 1) for kv in
                   baseline[bt[0]]["derived"].split(";"))
    assert float(derived["dispatch_ratio"]) >= 2.0, \
        "recorded batched tick shows no dispatch collapse (< 2 decode " \
        "items per device dispatch)"


def test_decode_conformance_suite_rides_in_tier1():
    """The differential decode-conformance suite (host / kernel / fused
    paths bit-identical across methods and plane counts) runs on every
    tier-1 matrix leg: no `slow` marker."""
    path = os.path.join(REPO, "tests", "test_decode_conformance.py")
    assert os.path.exists(path)
    with open(path, encoding="utf-8") as fh:
        assert "mark.slow" not in fh.read(), \
            "test_decode_conformance.py must stay in the tier-1 " \
            "(not-slow) selection"


def test_tier1_time_budget_structure():
    """Tier-1 must fit the CI matrix job's ~5-minute budget.  Wall-clock
    itself is machine-dependent, so the budget is asserted structurally:

    * the named heavyweights (a ~60s train-convergence run, the largest
      reduced-config model smokes, the two long single-seed chaos
      schedules) carry `slow` marks and run nightly instead;
    * every hypothesis `max_examples` setting stays at or below the
      deterministic shim's cap (tests/_hypothesis_shim.py), so a
      real-hypothesis environment never runs a property test longer than
      the shim-backed CI leg does.
    """
    import re
    src_train = open(os.path.join(REPO, "tests", "test_train_substrate.py"),
                     encoding="utf-8").read()
    m = re.search(r"(@pytest\.mark\.slow[^\n]*\n)+"
                  r"def test_grad_compression_convergence_parity",
                  src_train)
    assert m, "grad-compression convergence run must be slow-marked"

    src_models = open(os.path.join(REPO, "tests", "test_models_smoke.py"),
                      encoding="utf-8").read()
    assert "_HEAVY_TRAIN" in src_models and \
        "marks=pytest.mark.slow" in src_models, \
        "heaviest model-smoke params must carry slow marks"
    for arch in ("zamba2-2.7b", "seamless-m4t-medium", "mamba2-780m",
                 "llama4-maverick-400b-a17b"):
        assert arch in src_models, arch

    src_chaos = open(os.path.join(REPO, "tests", "test_chaos.py"),
                     encoding="utf-8").read()
    for fn in ("test_permanent_loss_degrades_with_certified_bound",
               "test_faults_then_loss_compose"):
        m = re.search(r"@pytest\.mark\.slow[^\n]*\n"
                      r"(@pytest\.[^\n]*\n)*def " + fn, src_chaos)
        assert m, f"{fn} must be slow-marked (still nightly via -m chaos)"

    shim = open(os.path.join(REPO, "tests", "_hypothesis_shim.py"),
                encoding="utf-8").read()
    m = re.search(r"_MAX_EXAMPLES_CAP\s*=\s*(\d+)", shim)
    assert m, "_hypothesis_shim.py must declare _MAX_EXAMPLES_CAP"
    cap = int(m.group(1))
    for fname in sorted(os.listdir(os.path.join(REPO, "tests"))):
        if not fname.endswith(".py"):
            continue
        with open(os.path.join(REPO, "tests", fname), encoding="utf-8") as fh:
            for n in re.findall(r"max_examples=(\d+)", fh.read()):
                assert int(n) <= cap, \
                    f"{fname}: max_examples={n} exceeds the shim cap {cap}" \
                    " — property tests must not outgrow the tier-1 budget"


def test_opener_deprecation_warning_is_an_error_in_ci():
    """pytest.ini must promote ReproDeprecationWarning to an error: with
    that filter active, ANY src/-internal call through the legacy kwarg
    surface fails whichever test exercises it — the whole tier-1 suite is
    the no-deprecated-internal-callers check.  Pin the filter, then sweep
    every repro module import under the error filter so even import-time
    legacy use can't hide in a module no test touches."""
    import configparser
    cp = configparser.ConfigParser()
    cp.read(os.path.join(REPO, "pytest.ini"))
    filters = [ln.strip() for ln in
               cp.get("pytest", "filterwarnings").strip().splitlines()]
    assert "error::repro.options.ReproDeprecationWarning" in filters

    import importlib
    import pkgutil
    import warnings

    import repro
    from repro.options import ReproDeprecationWarning
    failed = []
    with warnings.catch_warnings():
        warnings.simplefilter("error", ReproDeprecationWarning)
        for info in pkgutil.walk_packages(repro.__path__, "repro."):
            try:
                importlib.import_module(info.name)
            except ReproDeprecationWarning:        # pragma: no cover
                failed.append(info.name)
            except ImportError:
                pass       # optional heavy deps (jax extras) may be absent
    assert not failed, f"deprecated opener usage at import time: {failed}"


@pytest.mark.skipif(yaml is None, reason="pyyaml unavailable")
def test_nightly_job_is_schedule_gated():
    with open(WORKFLOW) as fh:
        wf = yaml.safe_load(fh)
    jobs = wf["jobs"]
    assert jobs["nightly-slow"]["if"] == "github.event_name == 'schedule'"
    for name in ("lint", "docs", "test", "bench-gate"):
        assert "schedule" in jobs[name]["if"]
    assert "-m slow" in jobs["nightly-slow"]["steps"][-1]["run"]
    # the seeded chaos suite rides the nightly schedule, unbuffered so a
    # failing schedule's reproducing seed lands in the job log
    chaos_runs = [s["run"] for s in jobs["nightly-slow"]["steps"]
                  if "-m chaos" in s.get("run", "")]
    assert chaos_runs and all("-s" in r for r in chaos_runs)
