"""SZ3-like compressor + snapshot/delta progressive schemes."""
import numpy as np
import pytest
from _hypothesis_shim import given, settings, strategies as st

from repro.compressors.snapshots import (
    DeltaSnapshotArchive, SnapshotArchive, default_snapshot_eps,
)
from repro.compressors.szlike import sz_compress, sz_decompress
from repro.data.synthetic import smooth_field


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000),
       eps_exp=st.integers(-8, 0),
       ndim=st.integers(1, 3))
def test_sz_error_bound(seed, eps_exp, ndim):
    # realistic sizes: per-level zlib headers dominate sub-KB toys
    shape = {1: (1025,), 2: (65, 33), 3: (17, 9, 9)}[ndim]
    x = smooth_field(shape, seed, lo=-40.0, hi=75.0)
    eps = 10.0 ** eps_exp
    c = sz_compress(x, eps)
    y = sz_decompress(c)
    # the REPORTED bound (safe_eps) covers f64 dequant rounding ulps
    assert np.abs(y - x).max() <= c.safe_eps
    assert c.nbytes < x.nbytes  # smooth data must actually compress


def test_sz_compresses_smooth_data_well():
    x = smooth_field((4097,), 5, lo=0.0, hi=1.0)
    c = sz_compress(x, 1e-4)
    assert c.nbytes < 0.35 * x.nbytes


def test_snapshot_reader_bytes_and_bounds():
    x = smooth_field((2049,), 7, lo=-1.0, hi=1.0)
    ladder = default_snapshot_eps(2.0, n=6)
    arch = SnapshotArchive.build(x, ladder)
    r = arch.open()
    y, ach = r.request(1e-3)
    assert np.abs(y - x).max() <= ach <= 1e-3 * (1 + 1e-6)
    b1 = r.bytes_fetched
    # a looser later request must not refetch or lose precision
    y2, ach2 = r.request(1e-1)
    assert r.bytes_fetched == b1 and ach2 <= 1e-3 * (1 + 1e-6)
    # a tighter request fetches a whole new snapshot (the PSZ3 redundancy)
    r.request(1e-5)
    assert r.bytes_fetched > b1


def test_delta_reader_accumulates():
    x = smooth_field((2049,), 9, lo=-5.0, hi=5.0)
    ladder = default_snapshot_eps(10.0, n=6)
    arch = DeltaSnapshotArchive.build(x, ladder)
    r = arch.open()
    bytes_seen = 0
    for eps in [1e-1, 1e-2, 1e-4, 1e-5]:
        y, ach = r.request(eps)
        assert np.abs(y - x).max() <= ach * (1 + 1e-9)
        assert ach <= eps * (1 + 1e-6)
        assert r.bytes_fetched >= bytes_seen  # monotone, incremental
        bytes_seen = r.bytes_fetched
    # delta total for the whole ladder ≈ its archive size, not n× like PSZ3
    assert r.bytes_fetched <= arch.total_nbytes
