"""Device-codec and incremental-reconstruction invariants.

1. The Pallas pack/unpack kernels round-trip and match a pure-NumPy oracle
   of the archived word format (bit i of word w = coefficient 32*w + i).
2. ``encode_level``'s batched kernel path produces exactly the magnitudes a
   scalar per-plane NumPy encoder would.
3. Incremental reconstruction (per-level contribution caching under HB
   linearity) is *bit-identical* to a from-scratch session across
   randomized fetch schedules, for all four progressive methods.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.bitplane.encoder import decode_magnitudes, decode_values, encode_level
from repro.core.refactor import refactor_variables
from repro.kernels import ops
from repro.kernels.bitplane_unpack import bitplane_unpack
from repro.transform.hierarchical import recompose_hb, recompose_hb_from

METHODS = ("hb", "ob", "psz3", "psz3_delta")


# ------------------------------------------------------------------ oracle --


def _pack_oracle(mag: np.ndarray, nbits: int) -> np.ndarray:
    """Scalar-loop NumPy packer: the ground truth for the archived format."""
    n = mag.size
    nwords = (n + 31) // 32
    out = np.zeros((nbits, nwords), dtype=np.uint32)
    mag = np.asarray(mag, dtype=np.uint64)
    for b in range(nbits):
        bits = ((mag >> np.uint64(nbits - 1 - b)) & np.uint64(1)).astype(np.uint32)
        padded = np.zeros(nwords * 32, dtype=np.uint32)
        padded[:n] = bits
        out[b] = (padded.reshape(nwords, 32)
                  << np.arange(32, dtype=np.uint32)[None, :]).sum(
                      axis=1, dtype=np.uint32)
    return out


def pack_magnitude_planes(mag: np.ndarray, nbits: int) -> np.ndarray:
    """(N,) uint64 magnitudes -> (nbits, ceil32(N)) uint32 packed planes
    via the pack kernel wrapper, hi/lo uint32 split for nbits > 32 (mirrors
    the fused encode path's split convention)."""
    mag = np.asarray(mag, dtype=np.uint64)
    lo = (mag & np.uint64(0xFFFFFFFF)).astype(np.uint32).view(np.int32)
    if nbits <= 32:
        return np.asarray(ops.pack_bitplanes(lo, nbits=nbits))
    hi = (mag >> np.uint64(32)).astype(np.uint32).view(np.int32)
    return np.concatenate([np.asarray(ops.pack_bitplanes(hi, nbits=nbits - 32)),
                           np.asarray(ops.pack_bitplanes(lo, nbits=32))],
                          axis=0)


def _unpack_oracle(words: np.ndarray, shifts, count: int) -> np.ndarray:
    out = np.zeros(count, dtype=np.uint64)
    for row, sh in zip(words, shifts):
        bits = ((row[:, None] >> np.arange(32, dtype=np.uint32)) &
                np.uint32(1)).ravel()[:count]
        out |= bits.astype(np.uint64) << np.uint64(sh)
    return out


# ----------------------------------------------------------- kernel round --


@pytest.mark.parametrize("n,nbits", [(1024, 8), (2048, 30), (4096, 32)])
def test_pack_unpack_kernel_roundtrip(n, nbits):
    rng = np.random.default_rng(n + nbits)
    mag = rng.integers(0, 2 ** nbits, size=n, dtype=np.uint64)
    packed = np.asarray(ops.pack_bitplanes(
        jnp.asarray(mag & np.uint64(0xFFFFFFFF), jnp.uint32).view(jnp.int32),
        nbits=nbits))
    np.testing.assert_array_equal(packed, _pack_oracle(mag, nbits))
    shifts = np.array([nbits - 1 - b for b in range(nbits)]) % 32
    pad = (-packed.shape[1]) % (8 * 4)
    w = np.pad(packed, ((0, 0), (0, pad)))
    out = np.asarray(bitplane_unpack(jnp.asarray(w),
                                     jnp.asarray(shifts, jnp.uint32),
                                     rows=8, interpret=True))[:n]
    expect = _unpack_oracle(packed, shifts, n)
    np.testing.assert_array_equal(out.astype(np.uint64), expect)


def test_unpack_dispatch_matches_kernel_hi_lo_split():
    """ops.unpack_bitplanes' NumPy path == the hi/lo-split kernel path for
    shifts spanning the full 48-bit range."""
    rng = np.random.default_rng(5)
    n, nbits = 1536, 48
    mag = rng.integers(0, 2 ** 48, size=n, dtype=np.uint64)
    words = pack_magnitude_planes(mag, nbits)
    shifts = np.array([nbits - 1 - b for b in range(nbits)])
    via_np = ops.unpack_bitplanes(words, shifts, n)
    via_kernel = ops._unpack_kernel_u64(np.asarray(words, np.uint32),
                                        shifts, n)
    np.testing.assert_array_equal(via_np, via_kernel)
    np.testing.assert_array_equal(via_np, mag)


def test_pack_magnitude_planes_matches_oracle_48bit():
    rng = np.random.default_rng(11)
    n = 777
    mag = rng.integers(0, 2 ** 48, size=n, dtype=np.uint64)
    np.testing.assert_array_equal(pack_magnitude_planes(mag, 48),
                                  _pack_oracle(mag, 48))


def test_encode_level_matches_scalar_oracle():
    """Batched encoder == an independent scalar fixed-point encoder."""
    rng = np.random.default_rng(3)
    c = rng.standard_normal(513) * 7.3
    lbp = encode_level(c, nbits=48)
    # oracle magnitudes straight from the definition
    e = lbp.exponent
    mag = np.minimum(np.floor(np.abs(c) * 2.0 ** (48 - e)).astype(np.uint64),
                     np.uint64(2 ** 48 - 1))
    np.testing.assert_array_equal(decode_magnitudes(lbp, 48), mag)
    # prefix decode equals oracle truncation for a few ks
    for k in (1, 7, 19, 33, 47):
        trunc = (mag >> np.uint64(48 - k)) << np.uint64(48 - k)
        np.testing.assert_array_equal(decode_magnitudes(lbp, k), trunc)
    v = decode_values(lbp, decode_magnitudes(lbp, 48))
    assert np.abs(v - c).max() <= 2.0 ** (e - 48) * (1 + 1e-12)


# ------------------------------------------------- partial recompose ------


@pytest.mark.parametrize("shape", [(257,), (65, 33)])
def test_partial_recompose_identity_on_level_support(shape):
    """recompose_hb_from(start=l) is bitwise recompose_hb for fields
    supported on levels <= l (the skipped coarse steps are exact no-ops)."""
    from repro.transform.hierarchical import grid_levels, level_map
    rng = np.random.default_rng(1)
    levels = grid_levels(shape)
    lmap = level_map(shape, levels)
    for l in range(levels + 1):
        field = rng.standard_normal(shape)
        field[lmap != min(l, levels)] = 0.0
        full = np.asarray(recompose_hb(jnp.asarray(field), levels))
        part = np.asarray(recompose_hb_from(jnp.asarray(field), levels,
                                            min(l, levels - 1)))
        np.testing.assert_array_equal(full, part)


# ------------------------------------------- incremental bit-identity -----


@pytest.mark.parametrize("method", METHODS)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_incremental_equals_from_scratch(method, seed):
    """Randomized decreasing fetch schedules end bit-identical to a fresh
    session that jumps straight to the final bound (Definition 1(2))."""
    rng = np.random.default_rng(seed)
    fields = {"Vx": rng.standard_normal(1200) * 3,
              "Vy": rng.standard_normal(1200)}
    arch = refactor_variables(fields, method=method, n_snapshots=6,
                              mask_zero_velocity=False)
    n_steps = int(rng.integers(2, 6))
    eps = np.sort(10.0 ** rng.uniform(-7, -0.5, size=n_steps))[::-1]
    inc = arch.open()
    for e in eps:
        for name in fields:
            da, ba = inc.reconstruct(name, e)
    scratch = arch.open()
    for name in fields:
        da, ba = inc.reconstruct(name, eps[-1])
        db, bb = scratch.reconstruct(name, eps[-1])
        assert np.array_equal(da, db), (method, name)
        assert ba == bb
        assert np.abs(da - fields[name]).max() <= ba * (1 + 1e-9)


def test_incremental_2d_hb_with_resolution_interleave():
    """Resolution-progression fetches interleaved with full requests must be
    picked up by the contribution cache (plane counts, not dirty flags)."""
    rng = np.random.default_rng(4)
    fields = {"W": rng.standard_normal((33, 33)).cumsum(axis=0)}
    arch = refactor_variables(fields, method="hb", mask_zero_velocity=False)
    inc = arch.open()
    inc.reconstruct("W", 1e-1)
    inc.reconstruct_at_resolution("W", coarsen=1, eps=1e-4)
    da, ba = inc.reconstruct("W", 1e-6)
    scratch = arch.open()
    scratch.reconstruct_at_resolution("W", coarsen=1, eps=1e-4)
    db, bb = scratch.reconstruct("W", 1e-6)
    assert np.array_equal(da, db)
    assert ba == bb


def test_ladder_reassign_matches_sequential_reference():
    """The batched Alg-4 ladder picks exactly the state the sequential
    reduce-check loop would."""
    from repro.core.retrieval import (LADDER_STEPS, REDUCTION_FACTOR,
                                      _estimate)
    from repro.core import ge
    expr = ge.v_total()
    pt_vals = {"Vx": np.float64(2.0), "Vy": np.float64(-1.0),
               "Vz": np.float64(0.5)}
    floors = {v: 1e-9 for v in pt_vals}
    for tau in (1e-1, 1e-3, 1e-6):
        pt = {v: 0.5 for v in pt_vals}
        # sequential reference (the legacy loop)
        seq = dict(pt)
        for _ in range(LADDER_STEPS):
            _, pb = _estimate(expr, pt_vals,
                              {v: np.asarray(seq[v]) for v in seq})
            if float(pb) <= tau:
                break
            progressed = False
            for v in seq:
                if seq[v] > floors[v]:
                    seq[v] = max(seq[v] / REDUCTION_FACTOR, floors[v])
                    progressed = True
            if not progressed:
                break
        # batched ladder (mirrors core.retrieval)
        ladders = {}
        for v in pt:
            lad = np.empty(LADDER_STEPS + 1)
            cur = pt[v]
            lad[0] = cur
            for t in range(1, LADDER_STEPS + 1):
                if cur > floors[v]:
                    cur = max(cur / REDUCTION_FACTOR, floors[v])
                lad[t] = cur
            ladders[v] = lad
        _, pb = _estimate(expr,
                          {v: np.full(LADDER_STEPS, pt_vals[v]) for v in pt},
                          {v: ladders[v][:LADDER_STEPS] for v in pt})
        ok = np.asarray(pb) <= tau
        prog = np.zeros(LADDER_STEPS, dtype=bool)
        for v in pt:
            prog |= ladders[v][:LADDER_STEPS] > floors[v]
        if ok.any():
            t_star = int(np.argmax(ok))
        elif (~prog).any():
            t_star = int(np.argmax(~prog))
        else:
            t_star = LADDER_STEPS
        for v in pt:
            assert ladders[v][t_star] == seq[v], (tau, v)
