"""Progressive-retrieval service demo with batched client requests
(the paper-kind end-to-end driver; see src/repro/launch/serve.py).

    PYTHONPATH=src python examples/serve_retrieval.py
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main(["--n", str(1 << 15), "--requests", "12"])
