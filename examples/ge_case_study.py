"""GE CFD case study (paper §VI): all six QoIs Eq.(1)-(6) across a ladder of
tolerances, comparing the three progressive representations.

    PYTHONPATH=src python examples/ge_case_study.py
"""
import numpy as np

from repro.core import ge
from repro.core.refactor import refactor_variables
from repro.core.retrieval import QoIRequest, retrieve_qoi_controlled
from repro.data.synthetic import ge_like_fields


def main():
    fields = ge_like_fields(n=1 << 15, seed=0)
    orig = {k: np.asarray(v) for k, v in fields.items()}
    qois = ge.all_qois()

    for method in ("hb", "psz3_delta", "psz3"):
        archive = refactor_variables(fields, method=method)
        print(f"\n=== {method} (archive "
              f"{archive.total_nbytes / 2**20:.2f} MiB) ===")
        session = archive.open()   # one progressive session, tau tightening
        for tau in (1e-2, 1e-4, 1e-6):
            reqs = [QoIRequest(k, e, tau) for k, e in qois.items()]
            res = retrieve_qoi_controlled(session, reqs)
            worst = 0.0
            for k, e in qois.items():
                actual = np.abs(np.asarray(e.value(orig))
                                - np.asarray(e.value(res.values))).max()
                worst = max(worst, actual / res.tau_abs[k])
            print(f"tau={tau:.0e}: bitrate={res.bitrate:6.2f} b/elem "
                  f"bytes={res.bytes_retrieved:>9d} "
                  f"worst actual/tau={worst:.3f} "
                  f"guaranteed={res.converged}")


if __name__ == "__main__":
    main()
