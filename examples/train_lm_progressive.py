"""Train a small LM end-to-end with the paper's technique in the training
stack: bitplane gradient compression (error feedback) + progressive
QoI-bounded checkpointing, then a warm restart from a *partial* checkpoint.

    PYTHONPATH=src python examples/train_lm_progressive.py
"""
import os
import tempfile

import jax
import numpy as np

from repro.launch.train import main as train_main


def main():
    ckpt_dir = os.path.join(tempfile.mkdtemp(), "ckpt")
    print("== phase 1: train 120 steps with grad compression + progressive "
          "checkpoints ==")
    train_main(["--arch", "internlm2-1.8b", "--reduced",
                "--steps", "120", "--batch", "4", "--seq", "64",
                "--grad-compress", "8",
                "--progressive-ckpt", ckpt_dir, "--ckpt-every", "40",
                "--log-every", "20"])

    print("\n== phase 2: warm restart from a PARTIAL restore "
          "(tau=1e-3 — only the top bitplanes move) ==")
    train_main(["--arch", "internlm2-1.8b", "--reduced",
                "--steps", "160", "--batch", "4", "--seq", "64",
                "--progressive-ckpt", ckpt_dir, "--resume",
                "--restore-tau", "1e-3", "--log-every", "20"])


if __name__ == "__main__":
    main()
