"""Quickstart: refactor scientific data once, retrieve progressively with a
guaranteed QoI error bound.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import ge
from repro.core.refactor import refactor_variables
from repro.core.retrieval import QoIRequest, retrieve_qoi_controlled
from repro.data.synthetic import ge_like_fields


def main():
    # 1. "simulation output": velocity + pressure + density fields
    fields = ge_like_fields(n=1 << 15, seed=0)
    raw_mib = sum(v.nbytes for v in fields.values()) / 2 ** 20

    # 2. refactor once into progressive bitplane segments (PMGARD-HB)
    archive = refactor_variables(fields, method="hb")
    print(f"raw {raw_mib:.2f} MiB -> archive "
          f"{archive.total_nbytes / 2**20:.2f} MiB (full precision)")

    # 3. progressive, QoI-error-controlled retrieval: total velocity and
    #    Mach number to 1e-4 relative error — guaranteed, without ever
    #    seeing the original data
    session = archive.open()
    result = retrieve_qoi_controlled(
        session,
        [QoIRequest("VTOT", ge.v_total(), tau_rel=1e-4),
         QoIRequest("Mach", ge.mach(), tau_rel=1e-4)])
    print(f"retrieved {result.bytes_retrieved / 2**20:.2f} MiB "
          f"({result.bitrate:.2f} bits/elem) in "
          f"{len(result.iterations)} round(s)")
    for name in ("VTOT", "Mach"):
        print(f"  {name}: estimated error {result.est_errors[name]:.3e} "
              f"<= tolerance {result.tau_abs[name]:.3e}")

    # 4. verify against the original (possible offline only)
    for name, expr in (("VTOT", ge.v_total()), ("Mach", ge.mach())):
        truth = np.asarray(expr.value({k: np.asarray(v)
                                       for k, v in fields.items()}))
        approx = np.asarray(expr.value(result.values))
        actual = np.abs(truth - approx).max()
        ok = actual <= result.est_errors[name]
        print(f"  {name}: actual error {actual:.3e} "
              f"(within estimate: {ok})")

    # 5. tighten the tolerance — only NEW segments move (progressive!)
    before = session.bytes_retrieved
    result2 = retrieve_qoi_controlled(
        session, [QoIRequest("VTOT", ge.v_total(), tau_rel=1e-6)])
    print(f"tightening VTOT to 1e-6 moved only "
          f"{(session.bytes_retrieved - before) / 2**20:.2f} MiB more")


if __name__ == "__main__":
    main()
