"""Fault tolerance: checkpoint/restart, elastic re-meshing, stragglers.

* restart: launch/train.py checkpoints asynchronously every N steps
  (AsyncCheckpointer); on any step failure the loop restores the latest
  checkpoint (exact restore) and resumes — run_with_failures() demonstrates
  and tests this with injected faults.
* elastic re-mesh: checkpoints are mesh-agnostic (host numpy + treedef), so
  elastic_restore() can place the same state on ANY mesh — scaling a job
  from 16 to 8 hosts (or 256 to 512 chips) is a restore with a different
  NamedSharding tree, no format conversion.
* stragglers: StragglerPolicy implements bounded-staleness dispatch — the
  host pipeline skips a slow shard's contribution after a deadline and
  rescales the gradient mean (the compressed-psum path makes the sync
  payload small enough that the deadline is rarely hit in practice).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.train.checkpoint import restore_checkpoint

Pytree = Any


def elastic_restore(path: str, mesh: Mesh, pspecs: Pytree,
                    tau_rel: float = 0.0):
    """Restore a checkpoint onto an arbitrary mesh (elastic scaling)."""
    params, report = restore_checkpoint(path, tau_rel=tau_rel)
    shardings = jax.tree.map(lambda ps: NamedSharding(mesh, ps), pspecs)
    placed = jax.tree.map(
        lambda x, s: jax.device_put(np.asarray(x), s), params, shardings)
    return placed, report


@dataclass
class FailureInjector:
    """Deterministic fault injection for the restart test: raises at the
    given steps (once each)."""
    fail_at: List[int] = field(default_factory=list)
    _fired: set = field(default_factory=set)

    def check(self, step: int) -> None:
        if step in self.fail_at and step not in self._fired:
            self._fired.add(step)
            raise RuntimeError(f"injected node failure at step {step}")


@dataclass
class StragglerPolicy:
    """Bounded-staleness dispatch: wait at most ``deadline_s`` for a shard's
    batch; a shard that misses contributes nothing this step and the mean is
    rescaled by the number of arrivals."""
    deadline_s: float = 1.0
    skipped: int = 0

    def gather(self, fetchers: List[Callable[[], np.ndarray]]
               ) -> List[np.ndarray]:
        out = []
        start = time.monotonic()
        for fetch in fetchers:
            remaining = self.deadline_s - (time.monotonic() - start)
            try:
                if remaining <= 0:
                    raise TimeoutError
                out.append(fetch())
            except TimeoutError:
                self.skipped += 1
        return out


def run_with_failures(train_loop: Callable[[int, Pytree], tuple],
                      init_state: Pytree, n_steps: int, ckpt,
                      injector: FailureInjector, ckpt_every: int = 5):
    """Generic restart harness over one training-state pytree (params +
    optimizer state packed together): run step-by-step; on an injected/real
    failure restore the latest checkpoint and replay from there.
    Returns (state, log)."""
    state = init_state
    log: Dict[str, Any] = {"losses": {}, "restarts": 0}
    step = 0
    while step < n_steps:
        try:
            injector.check(step)
            state, loss = train_loop(step, state)
            log["losses"][step] = float(loss)
            if step % ckpt_every == 0:
                ckpt.save(state, step)
                ckpt.wait()  # publish before advancing (simple + safe)
            step += 1
        except RuntimeError:
            ckpt.wait()
            restored, report = restore_checkpoint(ckpt.path)
            state = jax.tree.map(
                lambda a, b: np.asarray(a, dtype=np.asarray(b).dtype)
                .reshape(np.asarray(b).shape), restored, state)
            step = report.step + 1
            log["restarts"] += 1
    return state, log
