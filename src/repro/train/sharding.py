"""Sharding rules: parameter/optimizer/cache PartitionSpec trees.

Axis convention (launch/mesh.py):
  single-pod mesh (16, 16)        -> ("data", "model")
  multi-pod  mesh (2, 16, 16)     -> ("pod", "data", "model")

Rules (DESIGN.md §5):
  * batch dims           -> dp axes ("pod","data")
  * attention heads, ffn hidden, vocab, MoE experts -> "model"
  * FSDP (cfg.fsdp): the non-"model" weight dim additionally -> "data"
  * KV cache: kv-heads on "model" when divisible, else cache seq on "model"
    (XLA inserts the softmax reductions across the sharded seq dim)
  * optimizer moments shard exactly like their parameters
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

Pytree = Any


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _leaf_pspec(cfg: ModelConfig, path: str, shape: Tuple[int, ...],
                model_size: int, data_size: int) -> P:
    """PartitionSpec for one parameter leaf, identified by its tree path."""
    fsdp = cfg.fsdp
    nd = len(shape)

    def ok(dim: int, axis_size: int) -> bool:
        return 0 <= dim < nd and shape[dim] % axis_size == 0

    def spec(model_dim: Optional[int], data_dim: Optional[int]) -> P:
        entries = [None] * nd
        if model_dim is not None and ok(model_dim, model_size):
            entries[model_dim] = "model"
        if fsdp and data_dim is not None and ok(data_dim, data_size) \
                and entries[data_dim] is None:
            entries[data_dim] = "data"
        return P(*entries)

    # embeddings / heads
    if path.endswith("embed/table"):
        return spec(model_dim=0, data_dim=1)          # (V, D)
    if path.endswith("lm_head"):
        return spec(model_dim=nd - 1, data_dim=nd - 2)  # (D, V)
    if path.endswith("patch_proj") or path.endswith("frame_proj") \
            or path.endswith("fuse"):
        return spec(model_dim=nd - 1, data_dim=nd - 2)

    # attention projections (maybe layer-stacked: leading L dim).
    # When kv heads don't divide the model axis, the (.., H*hd) -> (H, hd)
    # reshape cannot preserve head sharding (40 heads % 16 != 0) and XLA
    # replicates via full-tensor gathers — so these archs replicate the
    # (small) attention weights over "model" and shard the attention
    # *compute* by batch/sequence instead (models/layers.py §Perf).
    if "/attn/" in path or "/cross/" in path:
        attn_model_ok = cfg.n_kv_heads % model_size == 0 or \
            not cfg.attn_param_replication
        if path.endswith("wo"):
            return spec(model_dim=nd - 2 if attn_model_ok else None,
                        data_dim=nd - 1)
        if path[-2:] in ("wq", "wk", "wv"):
            return spec(model_dim=nd - 1 if attn_model_ok else None,
                        data_dim=nd - 2)
        if path[-2:] in ("bq", "bk", "bv"):
            return spec(model_dim=nd - 1 if attn_model_ok else None,
                        data_dim=None)

    # dense/shared MLP
    if "/mlp/" in path or "shared_w" in path:
        if path.endswith("wd") or path.endswith("w2") \
                or path.endswith("shared_wd"):
            return spec(model_dim=nd - 2, data_dim=nd - 1)
        return spec(model_dim=nd - 1, data_dim=nd - 2)

    # MoE experts: expert dim -> model
    if "/moe/" in path:
        if path.endswith("router"):
            # tiny (D, E): replicate — sharding it drags the full (N, D)
            # token tensor through gathers at every layer (§Perf)
            return P(*([None] * nd))
        if path.endswith("wg") or path.endswith("wu") or path.endswith("wd"):
            # (L, E, D, F) / (L, E, F, D): experts on model, FSDP on dim -2
            return spec(model_dim=nd - 3, data_dim=nd - 2)

    # SSD
    if "/ssd/" in path:
        if path.endswith("in_proj"):
            return spec(model_dim=nd - 1, data_dim=nd - 2)
        if path.endswith("out_proj"):
            return spec(model_dim=nd - 2, data_dim=nd - 1)
        if path.endswith("conv_w") or path.endswith("conv_b"):
            return spec(model_dim=nd - 1, data_dim=None)
        return P(*([None] * nd))  # a_log, dt_bias, d_skip, norm_scale

    # norms / scalars: replicate
    return P(*([None] * nd))


def _path_str(kp) -> str:
    parts = []
    for k in kp:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_pspecs(cfg: ModelConfig, params: Pytree, mesh: Mesh) -> Pytree:
    model_size = mesh.shape["model"]
    data_size = mesh.shape["data"]
    return jax.tree_util.tree_map_with_path(
        lambda kp, p: _leaf_pspec(cfg, _path_str(kp), p.shape,
                                  model_size, data_size),
        params)


def param_shardings(cfg: ModelConfig, params: Pytree, mesh: Mesh) -> Pytree:
    return jax.tree.map(lambda ps: NamedSharding(mesh, ps),
                        param_pspecs(cfg, params, mesh))


def opt_state_pspecs(cfg: ModelConfig, opt_state, param_specs) -> Any:
    """Moments shard like params; factored moments drop the last/second-last
    entry; step scalar replicates."""
    def factored(ps: P, drop_last: bool) -> P:
        entries = list(ps) if len(ps) else []
        if drop_last:
            entries = entries[:-1]
        else:
            entries = entries[:-2] + entries[-1:]
        return P(*entries)

    def map_inner(inner, spec_tree):
        if isinstance(inner, dict) and set(inner) == {"m", "v"}:
            return {"m": spec_tree, "v": spec_tree}
        # adafactor: per-leaf dicts
        def per_leaf(s, ps):
            if isinstance(s, dict) and "vr" in s:
                return {"vr": factored(ps, drop_last=True),
                        "vc": factored(ps, drop_last=False)}
            return {"v": ps}
        return jax.tree.map(per_leaf, inner, spec_tree,
                            is_leaf=lambda x: isinstance(x, dict)
                            and ("vr" in x or "v" in x))

    from repro.train.optimizer import OptState
    return OptState(step=P(), inner=map_inner(opt_state.inner, param_specs))


def sanitize_pspecs(specs: Pytree, shapes: Pytree, mesh: Mesh) -> Pytree:
    """Drop sharding on any dim whose size isn't divisible by its assigned
    mesh axes (e.g. batch=1 decode cells can't shard the batch dim)."""
    def fix(spec: P, shaped) -> P:
        dims = shaped.shape
        entries = list(spec) + [None] * (len(dims) - len(spec))
        out = []
        for dim, entry in zip(dims, entries):
            if entry is None:
                out.append(None)
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            out.append(entry if dim % size == 0 else None)
        return P(*out)

    return jax.tree.map(fix, specs, shapes,
                        is_leaf=lambda x: isinstance(x, P))


def batch_pspecs(cfg: ModelConfig, mesh: Mesh) -> Dict[str, P]:
    dp = dp_axes(mesh)
    specs = {"tokens": P(dp, None), "labels": P(dp, None)}
    if cfg.family == "encdec":
        specs["frames"] = P(dp, None, None)
    if cfg.family == "vlm":
        specs["patches"] = P(dp, None, None)
    return specs


def decode_state_pspecs(cfg: ModelConfig, mesh: Mesh) -> Dict[str, P]:
    dp = dp_axes(mesh)
    model_size = mesh.shape["model"]
    specs: Dict[str, P] = {"pos": P()}
    if cfg.family in ("dense", "moe", "vlm", "encdec", "hybrid"):
        if cfg.n_kv_heads % model_size == 0:
            kv_spec = P(None, dp, None, "model", None)
            sc_spec = P(None, dp, None, "model")
        else:
            kv_spec = P(None, dp, "model", None, None)  # shard cache seq
            sc_spec = P(None, dp, "model", None)
        specs["k"] = kv_spec
        specs["v"] = kv_spec
        specs["k_scale"] = sc_spec
        specs["v_scale"] = sc_spec
    if cfg.family in ("ssm", "hybrid"):
        specs["conv"] = P(None, dp, None, "model")
        if cfg.ssm_heads % model_size == 0:
            specs["ssm"] = P(None, dp, "model", None, None)
        else:
            specs["ssm"] = P(None, dp, None, None, None)
    if cfg.family == "hybrid":
        specs["x0"] = P(dp, None, None)
    if cfg.family == "encdec":
        specs["enc_out"] = P(dp, None, None)
    return specs
