"""Train/serve step builders with distribution annotations.

make_train_step returns a pure function (params, opt_state, batch) ->
(params, opt_state, metrics); jit it with the sharding trees from
sharding.py (the dry-run does exactly that). Optional hooks:

  * grad_compress: bitplane gradient compression with error feedback over
    the data axis (paper technique on the collective path) — see
    train/grad_compress.py; adds a residual pytree to the carried state.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.train.optimizer import clip_by_global_norm, make_optimizer

Pytree = Any


def make_train_step(cfg: ModelConfig, lr: float = 3e-4,
                    max_grad_norm: float = 1.0,
                    grad_transform: Optional[Callable] = None):
    opt_init, opt_update = make_optimizer(cfg.optimizer)

    def train_step(params: Pytree, opt_state, batch: Dict[str, jnp.ndarray]
                   ) -> Tuple[Pytree, Any, Dict[str, jnp.ndarray]]:
        (loss, metrics), grads = jax.value_and_grad(
            T.loss_fn, has_aux=True)(params, cfg, batch)
        if grad_transform is not None:
            grads = grad_transform(grads)
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        params, opt_state = opt_update(params, grads, opt_state, lr=lr)
        out = {"loss": loss, "grad_norm": gnorm, **metrics}
        return params, opt_state, out

    return opt_init, train_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params: Pytree, state: Dict[str, jnp.ndarray],
                   token: jnp.ndarray):
        return T.decode_step(params, cfg, state, token)
    return serve_step
