"""Progressive QoI-bounded checkpointing — the paper's technique as a
first-class training-stack feature.

Checkpoints are refactored into bitplane segments per tensor (Algorithm 1
applied to the training state). Restores are *progressive*: a restart that
tolerates a relative L-inf error tau on every tensor fetches only the top
planes needed (planes_needed bound from bitplane/encoder.py) — e.g. a warm
restart for continued pretraining at tau=1e-4 moves ~35% of the bytes of an
exact restore. tau=0 (or restore_exact) fetches all planes and reproduces
the fp32 state bit-exactly, which is what fault-recovery uses by default.

The QoI theory gives *guaranteed* bounds on derived state quantities: e.g.
per-tensor RMS is a composition sqrt . mean . square, so Thm 1+4+2 bound
|RMS(restored) - RMS(saved)| from tau without reading the original — the
restore report includes this bound per tensor.

Writes are async (a background thread drains a queue) so the training loop
never blocks on the file system — the fault-tolerance path in
launch/train.py checkpoints every N steps at negligible step-time cost.
"""
from __future__ import annotations

import os
import pickle
import queue
import threading
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro.bitplane.encoder import (
    LevelBitplanes, decode_prefix, encode_level,
    plane_bound, planes_needed,
)
from repro.core import estimators as est

Pytree = Any
NBITS = 48


# ---------------------------------------------------------------------------
# Save
# ---------------------------------------------------------------------------


def _flatten(tree: Pytree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(path: str, params: Pytree, step: int,
                    extra: Optional[Dict] = None) -> Dict[str, int]:
    """Refactor the param pytree into per-tensor bitplane archives."""
    os.makedirs(path, exist_ok=True)
    leaves, treedef = _flatten(params)
    blobs = []
    total = 0
    for leaf in leaves:
        arr = np.asarray(leaf, dtype=np.float64).ravel()
        lbp = encode_level(arr, nbits=NBITS)
        blobs.append({"lbp": lbp, "shape": np.asarray(leaf).shape,
                      "dtype": str(np.asarray(leaf).dtype)})
        total += lbp.total_nbytes
    payload = {"blobs": blobs, "treedef": treedef, "step": step,
               "extra": extra or {}}
    tmp = os.path.join(path, f"ckpt-{step}.tmp")
    final = os.path.join(path, f"ckpt-{step}.pkl")
    with open(tmp, "wb") as f:
        pickle.dump(payload, f, protocol=4)
    os.replace(tmp, final)  # atomic publish (crash-safe)
    with open(os.path.join(path, "LATEST"), "w") as f:
        f.write(str(step))
    return {"bytes": total, "step": step}


# ---------------------------------------------------------------------------
# Restore (progressive)
# ---------------------------------------------------------------------------


@dataclass
class RestoreReport:
    step: int
    bytes_moved: int
    bytes_full: int
    tensor_bounds: Dict[int, float]     # achieved L-inf bound per leaf
    rms_bounds: Dict[int, float]        # guaranteed |ΔRMS| bound per leaf


def latest_step(path: str) -> Optional[int]:
    f = os.path.join(path, "LATEST")
    if not os.path.exists(f):
        return None
    return int(open(f).read().strip())


def restore_checkpoint(path: str, tau_rel: float = 0.0,
                       step: Optional[int] = None
                       ) -> Tuple[Pytree, RestoreReport]:
    """Progressive restore: per tensor, fetch the top planes until the
    relative L-inf bound <= tau_rel (0 => exact restore, all planes)."""
    step = step if step is not None else latest_step(path)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {path}")
    with open(os.path.join(path, f"ckpt-{step}.pkl"), "rb") as f:
        payload = pickle.load(f)
    leaves = []
    moved = 0
    full = 0
    tbounds: Dict[int, float] = {}
    rms_bounds: Dict[int, float] = {}
    for i, blob in enumerate(payload["blobs"]):
        lbp: LevelBitplanes = blob["lbp"]
        full += lbp.total_nbytes
        if lbp.exponent is None:
            vals = np.zeros(int(np.prod(blob["shape"])))
            achieved = 0.0
            k = 0
        else:
            scale = 2.0 ** lbp.exponent   # >= max|w|
            eps_abs = tau_rel * scale if tau_rel > 0 else 0.0
            k = planes_needed(lbp, eps_abs) if tau_rel > 0 else lbp.nbits
            # shared decode entry point: honors the device decode-path knob
            # (fused on-device for large tensors) and is bit-identical to
            # the old decode_magnitudes -> decode_values pair on every path
            vals = decode_prefix(lbp, k)
            achieved = plane_bound(lbp, k)
            moved += sum(lbp.plane_nbytes(b) for b in range(k)) \
                + lbp.sign_nbytes
        tbounds[i] = achieved
        # guaranteed bound on the tensor-RMS QoI:
        # RMS = sqrt(mean(w_i^2)); Thm1 per element, Thm4 mean, Thm2 sqrt
        n = max(vals.size, 1)
        mean_sq = float(np.mean(vals ** 2))
        d_mean = float(np.mean(np.asarray(est.bound_intpow(
            np.abs(vals), achieved, 2))))
        rms_bounds[i] = float(est.bound_sqrt(np.float64(mean_sq),
                                             np.float64(d_mean)))
        leaves.append(vals.reshape(blob["shape"]).astype(blob["dtype"]))
    params = jax.tree_util.tree_unflatten(payload["treedef"], leaves)
    return params, RestoreReport(step=step, bytes_moved=moved,
                                 bytes_full=full, tensor_bounds=tbounds,
                                 rms_bounds=rms_bounds)


# ---------------------------------------------------------------------------
# Async writer (fault-tolerance path)
# ---------------------------------------------------------------------------


class AsyncCheckpointer:
    """Background-thread writer: save() enqueues a host copy and returns."""

    def __init__(self, path: str):
        self.path = path
        self._q: "queue.Queue" = queue.Queue()
        self._results: Dict[int, Dict[str, int]] = {}
        self._thread = threading.Thread(target=self._drain, daemon=True)
        self._thread.start()

    def _drain(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            params, step, extra = item
            self._results[step] = save_checkpoint(self.path, params, step,
                                                  extra)
            self._q.task_done()

    def save(self, params: Pytree, step: int,
             extra: Optional[Dict] = None) -> None:
        host = jax.tree.map(lambda x: np.asarray(x), params)  # device->host
        self._q.put((host, step, extra))

    def wait(self) -> None:
        self._q.join()

    def close(self) -> None:
        self.wait()
        self._q.put(None)
        self._thread.join()
