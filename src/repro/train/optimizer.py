"""Optimizers: AdamW (fp32 moments) and Adafactor (factored second moment).

Pure-pytree implementations (no optax dependency). Optimizer state shards
exactly like the parameters (ZeRO-style via the same PartitionSpec tree), so
FSDP configs automatically shard moments too.

Adafactor is the default for the ~0.8T-param llama4-maverick config: Adam
moments at fp32 would need ~24 GB/chip on a 256-chip v5e pod (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


class OptState(NamedTuple):
    step: jnp.ndarray
    inner: Pytree


def clip_by_global_norm(grads: Pytree, max_norm: float) -> Tuple[Pytree, jnp.ndarray]:
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


# ------------------------------------------------------------------ AdamW --

def adamw_init(params: Pytree) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32),
                    inner={"m": zeros, "v": jax.tree.map(jnp.copy, zeros)})


def adamw_update(params: Pytree, grads: Pytree, state: OptState,
                 lr: float, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, wd: float = 0.01
                 ) -> Tuple[Pytree, OptState]:
    step = state.step + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * g32 * g32
        update = (m / c1) / (jnp.sqrt(v / c2) + eps) + wd * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * update).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.inner["m"], state.inner["v"])
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, OptState(step=step, inner={"m": new_m, "v": new_v})


# -------------------------------------------------------------- Adafactor --

def adafactor_init(params: Pytree) -> OptState:
    def per_leaf(p):
        if p.ndim >= 2:
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return OptState(step=jnp.zeros((), jnp.int32),
                    inner=jax.tree.map(per_leaf, params,
                                       is_leaf=lambda x: hasattr(x, "ndim")))


def adafactor_update(params: Pytree, grads: Pytree, state: OptState,
                     lr: float, decay: float = 0.8, eps: float = 1e-30,
                     clip_threshold: float = 1.0
                     ) -> Tuple[Pytree, OptState]:
    step = state.step + 1
    t = step.astype(jnp.float32)
    beta = 1.0 - t ** (-decay)

    def upd(p, g, s):
        g32 = g.astype(jnp.float32)
        g2 = g32 * g32 + eps
        if p.ndim >= 2:
            vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
            vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
            rfac = jax.lax.rsqrt(
                vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps))
            cfac = jax.lax.rsqrt(vc)
            update = g32 * rfac[..., :, None] * cfac[..., None, :]
            new_s = {"vr": vr, "vc": vc}
        else:
            v = beta * s["v"] + (1 - beta) * g2
            update = g32 * jax.lax.rsqrt(v)
            new_s = {"v": v}
        # relative update clipping (Adafactor's RMS clip)
        rms = jnp.sqrt(jnp.mean(update * update) + eps)
        update = update / jnp.maximum(1.0, rms / clip_threshold)
        return (p.astype(jnp.float32) - lr * update).astype(p.dtype), new_s

    out = jax.tree.map(upd, params, grads, state.inner,
                       is_leaf=lambda x: isinstance(x, dict)
                       and ("vr" in x or "v" in x))
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_inner = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
    return new_params, OptState(step=step, inner=new_inner)


def make_optimizer(name: str):
    if name == "adamw":
        return adamw_init, adamw_update
    if name == "adafactor":
        return adafactor_init, adafactor_update
    raise ValueError(f"unknown optimizer {name!r}")
