"""Bitplane gradient compression with error feedback — the paper's
"move fewer bytes under an error contract" idea applied to the gradient
all-reduce (DESIGN.md §3).

Per leaf: gradients are quantised to the top ``k_planes`` bitplanes of a
shared power-of-two exponent (exactly the progressive-precision format of
bitplane/encoder.py, held as int32 on device). The all-reduce then moves
k-bit integers instead of 32-bit floats — collective bytes shrink by
~k/32 — and the quantisation residual is fed back into the next step's
gradient (error feedback), which keeps SGD convergence (the compression
error stays bounded instead of accumulating).

Two entry points:
  * compress_decompress(grads, fb, k): pure pytree transform (single
    process) — used to inject compression into any train step and for the
    convergence-parity tests.
  * compressed_psum(grads, fb, k, axis): shard_map-compatible data-parallel
    mean that psums the quantised integers (what a real multi-host
    deployment runs; the dry-run counts its collective bytes).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


def zeros_like_feedback(grads: Pytree) -> Pytree:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def _quantise(g: jnp.ndarray, k: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """g -> (int32 codes in [-2^k, 2^k], power-of-two scale)."""
    g32 = g.astype(jnp.float32)
    amax = jnp.max(jnp.abs(g32))
    # shared power-of-two exponent: 2^e >= amax (paper's level exponent)
    e = jnp.ceil(jnp.log2(jnp.maximum(amax, 1e-30)))
    scale = jnp.exp2(e)
    q = jnp.round(g32 / scale * (2.0 ** k)).astype(jnp.int32)
    return q, scale


def _dequantise(q: jnp.ndarray, scale: jnp.ndarray, k: int,
                dtype) -> jnp.ndarray:
    return (q.astype(jnp.float32) * (scale / (2.0 ** k))).astype(dtype)


def compress_decompress(grads: Pytree, feedback: Pytree, k_planes: int
                        ) -> Tuple[Pytree, Pytree]:
    """Apply quantise->dequantise with error feedback. Returns
    (compressed grads, new feedback residuals)."""
    def per_leaf(g, fb):
        corrected = g.astype(jnp.float32) + fb
        q, scale = _quantise(corrected, k_planes)
        deq = _dequantise(q, scale, k_planes, jnp.float32)
        return deq.astype(g.dtype), corrected - deq

    out = jax.tree.map(per_leaf, grads, feedback)
    comp = jax.tree.map(lambda o: o[0], out,
                        is_leaf=lambda x: isinstance(x, tuple))
    new_fb = jax.tree.map(lambda o: o[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return comp, new_fb


def sum_safe_int_dtype(k_planes: int, n_ranks: int):
    """Narrowest signed integer that holds Σ_{ranks} q_i without overflow:
    codes span ±2^k, the sum ±(n·2^k) — needs k + ceil(log2 n) + 1 bits."""
    import math
    bits = k_planes + math.ceil(math.log2(max(n_ranks, 2))) + 1
    if bits <= 7:
        return jnp.int8
    if bits <= 15:
        return jnp.int16
    return jnp.int32


def compressed_psum(grads: Pytree, feedback: Pytree, k_planes: int,
                    axis: str, n_ranks: int = 0) -> Tuple[Pytree, Pytree]:
    """Data-parallel mean over ``axis`` (inside shard_map) moving narrow
    integer codes (top-k bitplanes) instead of f32: k=4 over 16 ranks rides
    int8 (4x fewer collective bytes), k<=10 rides int16 (2x); scales
    synchronise with a scalar pmax."""
    n = jax.lax.psum(1, axis)
    wire = sum_safe_int_dtype(k_planes, n_ranks or 64)

    def per_leaf(g, fb):
        corrected = g.astype(jnp.float32) + fb
        # shared scale across replicas so integer sums are exact
        amax = jax.lax.pmax(jnp.max(jnp.abs(corrected)), axis)
        e = jnp.ceil(jnp.log2(jnp.maximum(amax, 1e-30)))
        scale = jnp.exp2(e)
        q = jnp.round(corrected / scale * (2.0 ** k_planes)).astype(wire)
        q_sum = jax.lax.psum(q, axis)                 # the compressed payload
        mean = (q_sum.astype(jnp.float32)
                * (scale / (2.0 ** k_planes)) / n).astype(g.dtype)
        local_deq = (q.astype(jnp.float32)
                     * (scale / (2.0 ** k_planes)))
        return mean, corrected - local_deq

    out = jax.tree.map(per_leaf, grads, feedback)
    mean = jax.tree.map(lambda o: o[0], out,
                        is_leaf=lambda x: isinstance(x, tuple))
    new_fb = jax.tree.map(lambda o: o[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return mean, new_fb


def payload_bytes(grads: Pytree, k_planes: int) -> int:
    """Collective payload of one compressed all-reduce (k+1 bits/element,
    sign included) vs 32-bit floats."""
    n = sum(int(g.size) for g in jax.tree.leaves(grads))
    return (n * (k_planes + 1) + 7) // 8
