"""Progressive segment streams: incremental per-level plane retrieval state.

A LevelStream owns the *decode state* of one coefficient group and tracks how
many planes have been "moved" so far — retrieval cost is charged once per
plane, and recomposition is incremental (newly arrived planes OR into the
magnitude state), matching Definition 1's progressive-compressor contract.

The stream no longer holds the encoded planes themselves: it pulls them
through a ``PlaneSource`` — either an in-memory `LevelBitplanes` wrapper or a
store-backed source that fetches checksum-verified segments through a
`SegmentFetcher` (repro.store).  ``prefetch_to_eps`` forwards a *hint* to the
source: a store-backed source issues background fetches for the planes an
upcoming request will need, so transport overlaps the QoI estimator round
(the in-memory source ignores it).  Decoded results are bit-identical across
sources and across any fetch schedule ending at the same plane counts.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Union

import numpy as np

from repro.bitplane.encoder import (
    LevelBitplanes,
    PlaneGroupMeta,
    accumulate_planes,
    plane_bound,
    planes_needed,
    values_from_planes,
)


@dataclass
class PlaneSegment:
    level: int
    plane: int
    nbytes: int


class PlaneSource:
    """Access to one coefficient group's encoded segments.

    ``meta`` is always resident; payload bytes are produced on demand by
    ``planes``/``signs``.  ``prefetch`` is a non-binding hint that the given
    plane range (plus the sign segment, if plane 0 is included) will be
    requested soon.
    """

    meta: PlaneGroupMeta

    def planes(self, start: int, stop: int) -> Sequence[bytes]:
        raise NotImplementedError

    def signs(self) -> bytes:
        raise NotImplementedError

    def prefetch(self, start: int, stop: int, certain: bool = True) -> None:
        """Hint that planes [start, stop) will be requested; ``certain=False``
        marks a speculative prediction the reader may never follow up on."""
        pass


class InMemoryPlaneSource(PlaneSource):
    """The classic path: planes live in a `LevelBitplanes` in RAM."""

    def __init__(self, lbp: LevelBitplanes):
        self.lbp = lbp
        self.meta = lbp.meta()

    def planes(self, start: int, stop: int) -> Sequence[bytes]:
        return self.lbp.planes[start:stop]

    def signs(self) -> bytes:
        return self.lbp.signs


class LevelStream:
    """Progressive reader state over one group's PlaneSource."""

    def __init__(self, source: Union[PlaneSource, LevelBitplanes]):
        if isinstance(source, LevelBitplanes):
            source = InMemoryPlaneSource(source)
        self.source = source
        self.meta = source.meta
        self.fetched = 0
        self.bytes_fetched = 0
        self._mag: Optional[np.ndarray] = None
        self._signs: Optional[bytes] = None
        self._values: Optional[np.ndarray] = None

    def fetch_to_planes(self, k: int) -> int:
        """Retrieve planes up to k (MSB-first). Returns newly moved bytes."""
        meta = self.meta
        k = int(np.clip(k, 0, meta.nbits))
        if meta.exponent is None or k <= self.fetched:
            return 0
        blobs = self.source.planes(self.fetched, k)
        new_bytes = sum(meta.plane_sizes[self.fetched:k])
        if self.fetched == 0:
            self._signs = self.source.signs()  # signs ride with first plane
            new_bytes += meta.sign_size
        self._mag = accumulate_planes(meta.count, meta.nbits, blobs,
                                      self.fetched, state=self._mag)
        self.fetched = k
        self.bytes_fetched += new_bytes
        self._values = None
        return new_bytes

    def fetch_to_eps(self, eps: float) -> int:
        return self.fetch_to_planes(planes_needed(self.meta, eps))

    def prefetch_to_eps(self, eps: float, certain: bool = True) -> None:
        """Hint the source that a request at ``eps`` is coming; a store-backed
        source starts moving planes [fetched, planes_needed) in the
        background.  Never changes decode state or byte accounting."""
        meta = self.meta
        if meta.exponent is None:
            return
        k = planes_needed(meta, eps)
        if k > self.fetched:
            self.source.prefetch(self.fetched, k, certain=certain)

    def values(self) -> np.ndarray:
        if self._values is None:
            if self.fetched == 0:
                self._values = np.zeros(self.meta.count, dtype=np.float64)
            else:
                self._values = values_from_planes(
                    self.meta.count, self.meta.exponent, self.meta.nbits,
                    self._mag, self._signs)
        return self._values

    @property
    def bound(self) -> float:
        return plane_bound(self.meta, self.fetched)

    def reset(self) -> None:
        self.fetched = 0
        self.bytes_fetched = 0
        self._mag = None
        self._signs = None
        self._values = None
