"""Progressive segment streams: incremental per-level plane retrieval state.

A LevelStream owns the encoded planes of one coefficient group and tracks how
many have been "moved" so far — retrieval cost is charged once per plane, and
recomposition is incremental (newly arrived planes OR into the magnitude
state), matching Definition 1's progressive-compressor contract.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.bitplane.encoder import (
    LevelBitplanes,
    decode_magnitudes,
    decode_values,
    plane_bound,
    planes_needed,
)


@dataclass
class PlaneSegment:
    level: int
    plane: int
    nbytes: int


@dataclass
class LevelStream:
    lbp: LevelBitplanes
    fetched: int = 0
    bytes_fetched: int = 0
    _mag: Optional[np.ndarray] = None
    _values: Optional[np.ndarray] = None

    def fetch_to_planes(self, k: int) -> int:
        """Retrieve planes up to k (MSB-first). Returns newly moved bytes."""
        k = int(np.clip(k, 0, self.lbp.nbits))
        if self.lbp.exponent is None or k <= self.fetched:
            return 0
        new_bytes = sum(self.lbp.plane_nbytes(b) for b in range(self.fetched, k))
        if self.fetched == 0:
            new_bytes += self.lbp.sign_nbytes  # signs ride with first plane
        self._mag = decode_magnitudes(self.lbp, k, state=self._mag,
                                      start=self.fetched)
        self.fetched = k
        self.bytes_fetched += new_bytes
        self._values = None
        return new_bytes

    def fetch_to_eps(self, eps: float) -> int:
        return self.fetch_to_planes(planes_needed(self.lbp, eps))

    def values(self) -> np.ndarray:
        if self._values is None:
            mag = self._mag if self._mag is not None else np.zeros(
                self.lbp.count, dtype=np.uint64)
            self._values = decode_values(self.lbp, mag)
        return self._values

    @property
    def bound(self) -> float:
        return plane_bound(self.lbp, self.fetched)

    def reset(self) -> None:
        self.fetched = 0
        self.bytes_fetched = 0
        self._mag = None
        self._values = None
