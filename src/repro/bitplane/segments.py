"""Progressive segment streams: incremental per-level plane retrieval state.

A LevelStream owns the *decode state* of one coefficient group and tracks how
many planes have been "moved" so far — retrieval cost is charged once per
plane, and recomposition is incremental (newly arrived planes OR into the
magnitude state), matching Definition 1's progressive-compressor contract.

The stream no longer holds the encoded planes themselves: it pulls them
through a ``PlaneSource`` — either an in-memory `LevelBitplanes` wrapper or a
store-backed source that fetches checksum-verified segments through a
`SegmentFetcher` (repro.store).  ``prefetch_to_eps`` forwards a *hint* to the
source: a store-backed source issues background fetches for the planes an
upcoming request will need, so transport overlaps the QoI estimator round
(the in-memory source ignores it).  Decoded results are bit-identical across
sources and across any fetch schedule ending at the same plane counts.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Union

import numpy as np

from repro.bitplane.encoder import (
    LevelBitplanes,
    PlaneGroupMeta,
    accumulate_planes,
    plane_bound,
    planes_needed,
    values_from_planes,
)


@dataclass
class PlaneSegment:
    level: int
    plane: int
    nbytes: int


class PlaneSource:
    """Access to one coefficient group's encoded segments.

    ``meta`` is always resident; payload bytes are produced on demand by
    ``planes``/``signs``.  ``prefetch`` is a non-binding hint that the given
    plane range (plus the sign segment, if plane 0 is included) will be
    requested soon.
    """

    meta: PlaneGroupMeta

    def planes(self, start: int, stop: int) -> Sequence[bytes]:
        raise NotImplementedError

    def planes_available(self, start: int, stop: int):
        """Deliverable prefix of planes [start, stop): ``(buffers, error)``,
        with ``error`` None only when every plane arrived.  A bitplane
        prefix is useful exactly as far as it is contiguous, so a source
        that can fail partially (store-backed) overrides this to return
        what it got; the default is all-or-nothing via ``planes``."""
        try:
            return list(self.planes(start, stop)), None
        except Exception as e:
            return [], e

    def signs(self) -> bytes:
        raise NotImplementedError

    def prefetch(self, start: int, stop: int, certain: bool = True) -> None:
        """Hint that planes [start, stop) will be requested; ``certain=False``
        marks a speculative prediction the reader may never follow up on."""
        pass


class InMemoryPlaneSource(PlaneSource):
    """The classic path: planes live in a `LevelBitplanes` in RAM."""

    def __init__(self, lbp: LevelBitplanes):
        self.lbp = lbp
        self.meta = lbp.meta()

    def planes(self, start: int, stop: int) -> Sequence[bytes]:
        return self.lbp.planes[start:stop]

    def signs(self) -> bytes:
        return self.lbp.signs


class LevelStream:
    """Progressive reader state over one group's PlaneSource."""

    def __init__(self, source: Union[PlaneSource, LevelBitplanes]):
        if isinstance(source, LevelBitplanes):
            source = InMemoryPlaneSource(source)
        self.source = source
        self.meta = source.meta
        self.fetched = 0
        self.bytes_fetched = 0
        # degraded mode: deepest reachable plane count once a segment of
        # this group proved permanently unavailable (None = fully available)
        self.pinned: Optional[int] = None
        self.pin_error: Optional[BaseException] = None
        self._mag: Optional[np.ndarray] = None
        self._signs: Optional[bytes] = None
        self._values: Optional[np.ndarray] = None

    def _pin(self, k: int, err: BaseException) -> None:
        self.pinned = k
        self.pin_error = err

    def fetch_to_planes(self, k: int) -> int:
        """Retrieve planes up to k (MSB-first). Returns newly moved bytes.

        A permanently unavailable segment does not raise: the stream *pins*
        at the deepest contiguous plane prefix it could decode — its bound
        (computed from actually-decoded planes) stays valid, just wider
        than requested — and records the cause in ``pin_error``."""
        meta = self.meta
        k = int(np.clip(k, 0, meta.nbits))
        if self.pinned is not None:
            k = min(k, self.pinned)
        if meta.exponent is None or k <= self.fetched:
            return 0
        if self.fetched == 0 and self._signs is None:
            try:
                self._signs = self.source.signs()
            except Exception as e:       # no signs -> no usable plane 0
                self._pin(0, e)
                return 0
        blobs, err = self.source.planes_available(self.fetched, k)
        got = self.fetched + len(blobs)
        # signs ride with the first plane: their bytes are charged when a
        # plane actually lands, keeping healthy-path accounting unchanged
        new_bytes = sum(meta.plane_sizes[self.fetched:got])
        if self.fetched == 0 and got > 0:
            new_bytes += meta.sign_size
        if blobs:
            self._mag = accumulate_planes(meta.count, meta.nbits, blobs,
                                          self.fetched, state=self._mag)
            self.fetched = got
            self.bytes_fetched += new_bytes
            self._values = None
        if err is not None:
            self._pin(self.fetched, err)
        return new_bytes if blobs else 0

    def fetch_to_eps(self, eps: float) -> int:
        return self.fetch_to_planes(planes_needed(self.meta, eps))

    def prefetch_to_eps(self, eps: float, certain: bool = True) -> None:
        """Hint the source that a request at ``eps`` is coming; a store-backed
        source starts moving planes [fetched, planes_needed) in the
        background.  Never changes decode state or byte accounting."""
        meta = self.meta
        if meta.exponent is None:
            return
        k = planes_needed(meta, eps)
        if self.pinned is not None:
            k = min(k, self.pinned)    # never speculate past the pin
        if k > self.fetched:
            self.source.prefetch(self.fetched, k, certain=certain)

    def values(self) -> np.ndarray:
        if self._values is None:
            if self.fetched == 0:
                self._values = np.zeros(self.meta.count, dtype=np.float64)
            else:
                self._values = values_from_planes(
                    self.meta.count, self.meta.exponent, self.meta.nbits,
                    self._mag, self._signs)
        return self._values

    @property
    def bound(self) -> float:
        return plane_bound(self.meta, self.fetched)

    def reset(self) -> None:
        self.fetched = 0
        self.bytes_fetched = 0
        self.pinned = None            # a re-read may find the blob healed
        self.pin_error = None
        self._mag = None
        self._signs = None
        self._values = None
