"""Progressive segment streams: incremental per-level plane retrieval state.

A LevelStream owns the *decode state* of one coefficient group and tracks how
many planes have been "moved" so far — retrieval cost is charged once per
plane, and recomposition is incremental (newly arrived planes OR into the
magnitude state), matching Definition 1's progressive-compressor contract.

The stream no longer holds the encoded planes themselves: it pulls them
through a ``PlaneSource`` — either an in-memory `LevelBitplanes` wrapper or a
store-backed source that fetches checksum-verified segments through a
`SegmentFetcher` (repro.store).  ``prefetch_to_eps`` forwards a *hint* to the
source: a store-backed source issues background fetches for the planes an
upcoming request will need, so transport overlaps the QoI estimator round
(the in-memory source ignores it).  Decoded results are bit-identical across
sources and across any fetch schedule ending at the same plane counts.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Union

import numpy as np

from repro.bitplane.encoder import (
    LevelBitplanes,
    PlaneGroupMeta,
    accumulate_planes,
    inflate_planes,
    plane_bound,
    planes_needed,
    sign_plane_bytes,
    values_from_planes,
)
from repro.kernels import ops


class _Ready:
    """Trivial ticket for a decode dispatched inline (no batcher)."""

    def __init__(self, res):
        self._res = res

    def result(self):
        return self._res


@dataclass
class PlaneSegment:
    level: int
    plane: int
    nbytes: int


class PlaneSource:
    """Access to one coefficient group's encoded segments.

    ``meta`` is always resident; payload bytes are produced on demand by
    ``planes``/``signs``.  ``prefetch`` is a non-binding hint that the given
    plane range (plus the sign segment, if plane 0 is included) will be
    requested soon.
    """

    meta: PlaneGroupMeta

    def planes(self, start: int, stop: int) -> Sequence[bytes]:
        raise NotImplementedError

    def planes_available(self, start: int, stop: int):
        """Deliverable prefix of planes [start, stop): ``(buffers, error)``,
        with ``error`` None only when every plane arrived.  A bitplane
        prefix is useful exactly as far as it is contiguous, so a source
        that can fail partially (store-backed) overrides this to return
        what it got; the default is all-or-nothing via ``planes``."""
        try:
            return list(self.planes(start, stop)), None
        except Exception as e:
            return [], e

    def signs(self) -> bytes:
        raise NotImplementedError

    def prefetch(self, start: int, stop: int, certain: bool = True) -> None:
        """Hint that planes [start, stop) will be requested; ``certain=False``
        marks a speculative prediction the reader may never follow up on."""
        pass


class InMemoryPlaneSource(PlaneSource):
    """The classic path: planes live in a `LevelBitplanes` in RAM."""

    def __init__(self, lbp: LevelBitplanes):
        self.lbp = lbp
        self.meta = lbp.meta()

    def planes(self, start: int, stop: int) -> Sequence[bytes]:
        return self.lbp.planes[start:stop]

    def signs(self) -> bytes:
        return self.lbp.signs


class LevelStream:
    """Progressive reader state over one group's PlaneSource."""

    def __init__(self, source: Union[PlaneSource, LevelBitplanes],
                 batcher=None):
        if isinstance(source, LevelBitplanes):
            source = InMemoryPlaneSource(source)
        self.source = source
        self.meta = source.meta
        self.batcher = batcher        # serve.DecodeBatcher or None
        self.fetched = 0
        self.bytes_fetched = 0
        # degraded mode: deepest reachable plane count once a segment of
        # this group proved permanently unavailable (None = fully available)
        self.pinned: Optional[int] = None
        self.pin_error: Optional[BaseException] = None
        # _mag is dual-representation: host (count,) uint64 on the host
        # path, or a device-resident full-word-length (W*32,) uint64 array
        # on the fused path (keeps jit cache keys count-independent and the
        # state on device across incremental flushes)
        self._mag = None
        self._signs: Optional[bytes] = None
        self._sign_bytes: Optional[np.ndarray] = None
        self._values: Optional[np.ndarray] = None
        self._values_dev = None
        # fused path defers decode: newly fetched planes pile up here and
        # flush in ONE jit dispatch at the next values()/values_device()
        self._pending_words: list = []
        self._pending_shifts: list = []

    def _pin(self, k: int, err: BaseException) -> None:
        self.pinned = k
        self.pin_error = err

    def fetch_to_planes(self, k: int) -> int:
        """Retrieve planes up to k (MSB-first). Returns newly moved bytes.

        A permanently unavailable segment does not raise: the stream *pins*
        at the deepest contiguous plane prefix it could decode — its bound
        (computed from actually-decoded planes) stays valid, just wider
        than requested — and records the cause in ``pin_error``."""
        meta = self.meta
        k = int(np.clip(k, 0, meta.nbits))
        if self.pinned is not None:
            k = min(k, self.pinned)
        if meta.exponent is None or k <= self.fetched:
            return 0
        if self.fetched == 0 and self._signs is None:
            try:
                self._signs = self.source.signs()
            except Exception as e:       # no signs -> no usable plane 0
                self._pin(0, e)
                return 0
        blobs, err = self.source.planes_available(self.fetched, k)
        got = self.fetched + len(blobs)
        # signs ride with the first plane: their bytes are charged when a
        # plane actually lands, keeping healthy-path accounting unchanged
        new_bytes = sum(meta.plane_sizes[self.fetched:got])
        if self.fetched == 0 and got > 0:
            new_bytes += meta.sign_size
        if blobs:
            if ops.use_fused_decode(meta.count):
                # defer: inflate now (cheap, host) but leave the bit-OR +
                # sign + scale to one fused device dispatch at flush time;
                # byte accounting above is already settled, so deferral
                # never changes FetchStats
                words, shifts = inflate_planes(meta.count, meta.nbits,
                                               blobs, self.fetched)
                self._pending_words.append(words)
                self._pending_shifts.append(shifts)
            else:
                self._mag = accumulate_planes(meta.count, meta.nbits, blobs,
                                              self.fetched,
                                              state=self._host_mag())
            self.fetched = got
            self.bytes_fetched += new_bytes
            self._values = None
            self._values_dev = None
        if err is not None:
            self._pin(self.fetched, err)
        return new_bytes if blobs else 0

    def fetch_to_eps(self, eps: float) -> int:
        return self.fetch_to_planes(planes_needed(self.meta, eps))

    def prefetch_to_planes(self, k: int, certain: bool = True) -> None:
        """Hint the source that planes up to ``k`` will be requested; a
        store-backed source starts moving planes [fetched, k) in the
        background.  Never changes decode state or byte accounting."""
        meta = self.meta
        if meta.exponent is None:
            return
        k = int(np.clip(k, 0, meta.nbits))
        if self.pinned is not None:
            k = min(k, self.pinned)    # never speculate past the pin
        if k > self.fetched:
            self.source.prefetch(self.fetched, k, certain=certain)

    def prefetch_to_eps(self, eps: float, certain: bool = True) -> None:
        """Plane-count hint derived from an upcoming ``eps`` request."""
        self.prefetch_to_planes(planes_needed(self.meta, eps),
                                certain=certain)

    def _host_mag(self) -> Optional[np.ndarray]:
        """Normalize the magnitude state to host (count,) uint64, folding any
        deferred planes through the host unpack (integer-exact, so the value
        is independent of which path folds them)."""
        count = self.meta.count
        mag = self._mag
        if mag is not None and (not isinstance(mag, np.ndarray)
                                or mag.shape != (count,)):
            mag = np.asarray(mag)[:count].copy()
        for words, shifts in zip(self._pending_words, self._pending_shifts):
            if mag is None:
                mag = np.zeros(count, dtype=np.uint64)
            mag |= ops.unpack_bitplanes(words, shifts, count)
        self._pending_words.clear()
        self._pending_shifts.clear()
        self._mag = mag
        return mag

    def _decoded_signs(self) -> np.ndarray:
        if self._sign_bytes is None:
            self._sign_bytes = sign_plane_bytes(self.meta.count, self._signs)
        return self._sign_bytes

    def flush_submit(self):
        """Phase 1 of the fused flush: hand the deferred planes to the
        decode batcher (or dispatch inline when there is none).  Returns an
        opaque ticket for ``flush_collect``, or None when nothing is
        pending.  Split in two so a caller draining many streams can submit
        them all before collecting — one batched dispatch instead of one
        per stream."""
        if not self._pending_words:
            return None
        meta = self.meta
        words = np.concatenate(self._pending_words, axis=0)
        shifts = np.concatenate(self._pending_shifts)
        self._pending_words.clear()
        self._pending_shifts.clear()
        scale = np.float64(2.0) ** (meta.exponent - meta.nbits)
        sb = self._decoded_signs()
        if self.batcher is not None:
            return self.batcher.submit_decode(words, shifts, self._mag, sb,
                                              scale, meta.count)
        return _Ready(ops.decode_values_fused(words, shifts, self._mag, sb,
                                              scale, meta.count))

    def flush_collect(self, ticket) -> None:
        """Phase 2: adopt the fused decode result (device magnitude state +
        device values)."""
        if ticket is None:
            return
        mag, vals = ticket.result()
        self._mag = mag
        self._values_dev = vals

    def _flush(self) -> None:
        self.flush_collect(self.flush_submit())

    def values_device(self):
        """Device-resident float64 values when the fused path produced them
        (None otherwise) — lets the reader feed ``scatter_recompose_from``
        without a host round-trip."""
        if self.fetched == 0:
            return None
        self._flush()
        return self._values_dev

    def values(self) -> np.ndarray:
        if self._values is None:
            if self.fetched == 0:
                self._values = np.zeros(self.meta.count, dtype=np.float64)
            else:
                self._flush()
                if self._values_dev is not None:
                    self._values = np.asarray(self._values_dev)
                else:
                    self._values = values_from_planes(
                        self.meta.count, self.meta.exponent, self.meta.nbits,
                        self._host_mag(), self._signs)
        return self._values

    @property
    def bound(self) -> float:
        return plane_bound(self.meta, self.fetched)

    def reset(self) -> None:
        self.fetched = 0
        self.bytes_fetched = 0
        self.pinned = None            # a re-read may find the blob healed
        self.pin_error = None
        self._mag = None
        self._signs = None
        self._sign_bytes = None
        self._values = None
        self._values_dev = None
        self._pending_words.clear()
        self._pending_shifts.clear()
