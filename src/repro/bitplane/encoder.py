"""Sign-magnitude fixed-point bitplane encoding (progression in precision).

Per coefficient group (one multilevel level of one variable):
  * shared exponent  E = ceil(log2 max|c|)  so |c| / 2^E in [0, 1);
  * magnitudes quantised to B-bit fixed point: mag = floor(|c| · 2^{B-E});
  * plane b (0 = MSB) is bit (B-1-b) of every magnitude, packed 8/byte and
    zlib-compressed (stands in for the entropy stage — MSB planes of smooth
    data are mostly zero and compress away);
  * one packed+compressed sign plane, charged to the first fetched plane.

Retrieving the first k planes reconstructs magnitudes truncated below bit
B-k, so the coefficient error obeys the *closed-form* bound

    err(k) <= 2^{E-k} + 2^{E-B}          (truncation + quantisation)

which is what the progressive reader reports to the QoI estimator. The
device-side hot loop (extract+pack) is the `kernels/bitplane_pack` Pallas
kernel; this module is the host/archival container.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

DEFAULT_NBITS = 48  # magnitude planes; int64-safe, ~1e-14 relative floor


@dataclass
class LevelBitplanes:
    """Encoded bitplanes of one coefficient group."""
    count: int                      # number of coefficients
    exponent: Optional[int]        # None => group is all zeros
    nbits: int
    planes: List[bytes]            # zlib(packbits(plane)) MSB-first
    plane_raw_bits: int            # uncompressed bits per plane (= count)
    signs: bytes                   # zlib(packbits(c < 0))

    def plane_nbytes(self, b: int) -> int:
        return len(self.planes[b])

    @property
    def sign_nbytes(self) -> int:
        return len(self.signs)

    @property
    def total_nbytes(self) -> int:
        if self.exponent is None:
            return 0
        return sum(len(p) for p in self.planes) + len(self.signs)


def encode_level(coeffs: np.ndarray, nbits: int = DEFAULT_NBITS) -> LevelBitplanes:
    c = np.asarray(coeffs, dtype=np.float64).ravel()
    n = c.size
    amax = float(np.max(np.abs(c))) if n else 0.0
    if amax == 0.0 or n == 0:
        return LevelBitplanes(count=n, exponent=None, nbits=nbits, planes=[],
                              plane_raw_bits=n, signs=b"")
    e = int(np.ceil(np.log2(amax)))
    if 2.0 ** e == amax:  # make |c|/2^E < 1 strict
        e += 1
    # fixed-point magnitudes; scaling by 2^(nbits-e) is exact (power of two)
    mag = np.floor(np.abs(c) * np.float64(2.0) ** (nbits - e)).astype(np.uint64)
    mag = np.minimum(mag, np.uint64(2 ** nbits - 1))
    planes = []
    for b in range(nbits):
        bit = ((mag >> np.uint64(nbits - 1 - b)) & np.uint64(1)).astype(np.uint8)
        planes.append(zlib.compress(np.packbits(bit).tobytes(), 1))
    signs = zlib.compress(np.packbits(c < 0).tobytes(), 1)
    return LevelBitplanes(count=n, exponent=e, nbits=nbits, planes=planes,
                          plane_raw_bits=n, signs=signs)


def decode_magnitudes(lbp: LevelBitplanes, k: int,
                      state: Optional[np.ndarray] = None,
                      start: int = 0) -> np.ndarray:
    """Accumulate planes [start, k) into a uint64 magnitude state (incremental
    recomposition — Definition 1(2))."""
    if lbp.exponent is None:
        return np.zeros(lbp.count, dtype=np.uint64)
    mag = state if state is not None else np.zeros(lbp.count, dtype=np.uint64)
    for b in range(start, min(k, lbp.nbits)):
        bits = np.unpackbits(
            np.frombuffer(zlib.decompress(lbp.planes[b]), dtype=np.uint8),
            count=lbp.count).astype(np.uint64)
        mag |= bits << np.uint64(lbp.nbits - 1 - b)
    return mag


def decode_values(lbp: LevelBitplanes, mag: np.ndarray) -> np.ndarray:
    """Magnitude state + signs -> float64 coefficient values."""
    if lbp.exponent is None:
        return np.zeros(lbp.count, dtype=np.float64)
    signs = np.unpackbits(
        np.frombuffer(zlib.decompress(lbp.signs), dtype=np.uint8),
        count=lbp.count).astype(bool)
    vals = mag.astype(np.float64) * np.float64(2.0) ** (lbp.exponent - lbp.nbits)
    vals[signs] *= -1.0
    return vals


def plane_bound(lbp: LevelBitplanes, k: int) -> float:
    """Guaranteed |c - ĉ|_inf after retrieving the first k planes."""
    if lbp.exponent is None:
        return 0.0
    k = min(k, lbp.nbits)
    trunc = 2.0 ** (lbp.exponent - k) if k < lbp.nbits else 0.0
    return trunc + 2.0 ** (lbp.exponent - lbp.nbits)


def planes_needed(lbp: LevelBitplanes, eps: float) -> int:
    """Smallest k with plane_bound(k) <= eps (nbits if unreachable)."""
    if lbp.exponent is None:
        return 0
    quant = 2.0 ** (lbp.exponent - lbp.nbits)
    if eps <= quant:
        return lbp.nbits
    # 2^{E-k} <= eps - quant  =>  k >= E - log2(eps - quant)
    k = int(np.ceil(lbp.exponent - np.log2(eps - quant)))
    return int(np.clip(k, 0, lbp.nbits))
