"""Sign-magnitude fixed-point bitplane encoding (progression in precision).

Per coefficient group (one multilevel level of one variable):
  * shared exponent  E = ceil(log2 max|c|)  so |c| / 2^E in [0, 1);
  * magnitudes quantised to B-bit fixed point: mag = floor(|c| · 2^{B-E});
  * plane b (0 = MSB) is bit (B-1-b) of every magnitude; 32 coefficients are
    packed into one uint32 word (bit i of word w = coefficient 32·w + i) and
    each packed plane goes through the *entropy stage* — the pluggable codec
    registry of ``repro.bitplane.codecs``: a cost model tries run-length,
    static rANS and zlib candidates on the packed bytes and keeps the
    smallest, tagging the blob with a one-byte codec id (near-0.5-density
    planes are stored raw without trying anything — they cannot compress);
  * one sign plane, routed through the same tagged codec stage and charged
    to the first fetched plane.

Device codec architecture (§Perf)
---------------------------------
Plane extraction + packing is ONE batched Pallas kernel call per group
(``kernels/bitplane_pack``); the archival ``nbits=48`` exceeds the TPU's
32-bit vector registers, so the uint64 magnitudes are split into hi/lo
uint32 words and packed with two kernel launches (planes 0..B-33 from the
hi word, B-32..B-1 from the lo word).  The entropy stage touches only the
packed words — the scalar per-plane ``packbits`` loop of the legacy encoder
is gone.
Decoding mirrors this: ``decode_magnitudes`` inflates the newly fetched
planes and hands them to ``kernels/ops.unpack_bitplanes``, which ORs every
plane into the magnitude state in one vectorized op (the
``bitplane_unpack`` Pallas kernel on TPU, a bit-identical NumPy broadcast
elsewhere).  All codec arithmetic is integer-exact, so any fetch schedule
that ends at the same plane counts yields bit-identical magnitudes.

Retrieving the first k planes reconstructs magnitudes truncated below bit
B-k, so the coefficient error obeys the *closed-form* bound

    err(k) <= 2^{E-k} + 2^{E-B}          (truncation + quantisation)

which is what the progressive reader reports to the QoI estimator.  This
module remains the host/archival container; the hot loops live in
``repro.kernels``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

import repro._x64  # noqa: F401  (exact f64 quantization on device)
from repro.bitplane.codecs import decode_sign_blob, decode_tagged, \
    encode_tagged
from repro.kernels import ops

DEFAULT_NBITS = 48  # magnitude planes; int64-safe, ~1e-14 relative floor


def _popcounts(words: np.ndarray) -> np.ndarray:
    """Per-plane set-bit counts of (P, W) uint32 packed words."""
    if hasattr(np, "bitwise_count"):
        return np.bitwise_count(words).sum(axis=1)
    return np.unpackbits(words.view(np.uint8), axis=1).sum(axis=1,
                                                           dtype=np.int64)


def _inflate_plane(blob: bytes, nwords: int) -> np.ndarray:
    return np.frombuffer(decode_tagged(blob, 4 * nwords), dtype=np.uint32,
                         count=nwords)


@dataclass(frozen=True)
class PlaneGroupMeta:
    """Payload-free description of one encoded coefficient group — everything
    a progressive reader needs to plan fetches (sizes, bounds) and decode
    received segments, without holding the plane bytes themselves.  This is
    what the store container serializes into its manifest; `LevelBitplanes`
    is the in-memory (meta + payload) archival form."""
    count: int
    exponent: Optional[int]        # None => group is all zeros
    nbits: int
    plane_sizes: Tuple[int, ...]   # encoded bytes per plane, MSB-first
    sign_size: int
    pred_planes: Optional[int] = None  # `ip` only: planes folded into the
                                       # encoder's closed-loop prediction


@dataclass
class LevelBitplanes:
    """Encoded bitplanes of one coefficient group."""
    count: int                      # number of coefficients
    exponent: Optional[int]        # None => group is all zeros
    nbits: int
    planes: List[bytes]            # tagged packed-word planes, MSB-first:
                                   #   codec-id byte + payload (see codecs.py)
    plane_raw_bits: int            # uncompressed bits per plane (= count)
    signs: bytes                   # codec-tagged packbits(c < 0)
    pred_planes: Optional[int] = None  # see PlaneGroupMeta.pred_planes
    _crcs: Optional[Tuple[Tuple[int, ...], int]] = None

    def plane_nbytes(self, b: int) -> int:
        return len(self.planes[b])

    @property
    def sign_nbytes(self) -> int:
        return len(self.signs)

    @property
    def total_nbytes(self) -> int:
        if self.exponent is None:
            return 0
        return sum(len(p) for p in self.planes) + len(self.signs)

    def meta(self) -> PlaneGroupMeta:
        return PlaneGroupMeta(count=self.count, exponent=self.exponent,
                              nbits=self.nbits,
                              plane_sizes=tuple(len(p) for p in self.planes),
                              sign_size=len(self.signs),
                              pred_planes=self.pred_planes)

    def segment_crcs(self) -> Tuple[Tuple[int, ...], int]:
        """(per-plane crc32c, sign crc32c) — computed lazily so the encode
        hot path pays nothing; the store manifest records these and the
        fetcher re-verifies every segment it delivers."""
        if self._crcs is None:
            from repro.store.crc import crc32c
            self._crcs = (tuple(crc32c(p) for p in self.planes),
                          crc32c(self.signs))
        return self._crcs


def encode_level(coeffs: np.ndarray, nbits: int = DEFAULT_NBITS) -> LevelBitplanes:
    c = np.asarray(coeffs, dtype=np.float64).ravel()
    n = c.size
    amax = float(np.max(np.abs(c))) if n else 0.0
    if amax == 0.0 or n == 0:
        return LevelBitplanes(count=n, exponent=None, nbits=nbits, planes=[],
                              plane_raw_bits=n, signs=b"")
    e = int(np.ceil(np.log2(amax)))
    if 2.0 ** e == amax:  # make |c|/2^E < 1 strict
        e += 1
    # quantization + hi/lo split + per-plane pack: ONE fused device dispatch
    # (scaling by 2^(nbits-e) is exact — a power of two)
    scale = np.float64(2.0) ** (nbits - e)
    words = ops.encode_magnitude_planes(c, scale, nbits)
    density = _popcounts(words) / float(n)
    planes = [encode_tagged(words[b].tobytes(), density=float(density[b]))
              for b in range(nbits)]
    signs = encode_tagged(np.packbits(c < 0).tobytes())
    return LevelBitplanes(count=n, exponent=e, nbits=nbits, planes=planes,
                          plane_raw_bits=n, signs=signs)


def accumulate_planes(count: int, nbits: int, blobs: Sequence[bytes],
                      start: int,
                      state: Optional[np.ndarray] = None) -> np.ndarray:
    """OR encoded plane blobs (planes ``start .. start+len(blobs)``, MSB
    numbering) into a uint64 magnitude state.  Blob-level entry point: the
    planes may come from a `LevelBitplanes` or straight off a byte store —
    the decode is identical, so any transport yields bit-identical
    magnitudes.  All blobs are inflated and combined in ONE vectorized
    unpack (ops.unpack_bitplanes) instead of a per-plane unpackbits loop."""
    mag = state if state is not None else np.zeros(count, dtype=np.uint64)
    if not blobs:
        return mag
    words, shifts = inflate_planes(count, nbits, blobs, start)
    mag |= ops.unpack_bitplanes(words, shifts, count)
    return mag


def decode_magnitudes(lbp: LevelBitplanes, k: int,
                      state: Optional[np.ndarray] = None,
                      start: int = 0) -> np.ndarray:
    """Accumulate planes [start, k) into a uint64 magnitude state (incremental
    recomposition — Definition 1(2))."""
    if lbp.exponent is None:
        return np.zeros(lbp.count, dtype=np.uint64)
    k = min(k, lbp.nbits)
    if start >= k:
        return state if state is not None \
            else np.zeros(lbp.count, dtype=np.uint64)
    return accumulate_planes(lbp.count, lbp.nbits, lbp.planes[start:k],
                             start, state)


def values_from_planes(count: int, exponent: Optional[int], nbits: int,
                       mag: np.ndarray, signs_blob: bytes) -> np.ndarray:
    """Magnitude state + encoded sign segment -> float64 coefficient values
    (blob-level counterpart of ``decode_values``)."""
    if exponent is None:
        return np.zeros(count, dtype=np.float64)
    signs = np.unpackbits(
        np.frombuffer(decode_sign_blob(signs_blob, (count + 7) // 8),
                      dtype=np.uint8),
        count=count).astype(bool)
    vals = mag.astype(np.float64) * np.float64(2.0) ** (exponent - nbits)
    vals[signs] *= -1.0
    return vals


def decode_values(lbp: LevelBitplanes, mag: np.ndarray) -> np.ndarray:
    """Magnitude state + signs -> float64 coefficient values."""
    return values_from_planes(lbp.count, lbp.exponent, lbp.nbits, mag,
                              lbp.signs)


def inflate_planes(count: int, nbits: int, blobs: Sequence[bytes],
                   start: int) -> Tuple[np.ndarray, np.ndarray]:
    """Encoded plane blobs -> ((P, W) uint32 packed words, (P,) shifts) ready
    for the device decode paths (``ops.unpack_bitplanes`` /
    ``ops.decode_values_fused``).  Pure inflation — no bit arithmetic — so
    both paths consume the exact same words."""
    nwords = (count + 31) // 32
    words = np.empty((len(blobs), nwords), dtype=np.uint32)
    for i, blob in enumerate(blobs):
        words[i] = _inflate_plane(blob, nwords)
    shifts = np.asarray([nbits - 1 - b
                         for b in range(start, start + len(blobs))],
                        dtype=np.int64)
    return words, shifts


def sign_plane_bytes(count: int, signs_blob: bytes) -> np.ndarray:
    """Decoded packbits(c < 0) bytes for the fused device decode."""
    return np.frombuffer(decode_sign_blob(signs_blob, (count + 7) // 8),
                         dtype=np.uint8)


def decode_prefix(lbp: LevelBitplanes, k: int) -> np.ndarray:
    """First-k-planes decode: the ONE entry every non-streaming consumer
    (checkpoint restore, tests, tools) should call.  Honors the decode-path
    knob (``ops.set_decode_path``): under "fused"/"auto" the unpack, sign
    application and value scaling run as a single jit dispatch on device;
    otherwise it routes through the host/kernel ``decode_magnitudes`` →
    ``decode_values`` pair.  All paths are integer-exact and the scale is a
    power of two, so the result is bit-identical regardless of path."""
    if lbp.exponent is None:
        return np.zeros(lbp.count, dtype=np.float64)
    k = min(k, lbp.nbits)
    if ops.use_fused_decode(lbp.count):
        words, shifts = inflate_planes(lbp.count, lbp.nbits,
                                       lbp.planes[:k], 0)
        scale = np.float64(2.0) ** (lbp.exponent - lbp.nbits)
        _, vals = ops.decode_values_fused(
            words, shifts, None, sign_plane_bytes(lbp.count, lbp.signs),
            scale, lbp.count)
        return np.asarray(vals)
    return decode_values(lbp, decode_magnitudes(lbp, k))


def plane_bound(lbp: LevelBitplanes, k: int) -> float:
    """Guaranteed |c - ĉ|_inf after retrieving the first k planes."""
    if lbp.exponent is None:
        return 0.0
    k = min(k, lbp.nbits)
    trunc = 2.0 ** (lbp.exponent - k) if k < lbp.nbits else 0.0
    return trunc + 2.0 ** (lbp.exponent - lbp.nbits)


def planes_needed(lbp: LevelBitplanes, eps: float) -> int:
    """Smallest k with plane_bound(k) <= eps (nbits if unreachable)."""
    if lbp.exponent is None:
        return 0
    quant = 2.0 ** (lbp.exponent - lbp.nbits)
    if eps <= quant:
        return lbp.nbits
    # 2^{E-k} <= eps - quant  =>  k >= E - log2(eps - quant)
    k = int(np.ceil(lbp.exponent - np.log2(eps - quant)))
    return int(np.clip(k, 0, lbp.nbits))
