from repro.bitplane.codecs import (
    CodecError,
    PlaneCodec,
    codec_name,
    decode_tagged,
    encode_tagged,
    get_codec,
    register,
    registered_codecs,
)
from repro.bitplane.encoder import (
    LevelBitplanes,
    PlaneGroupMeta,
    accumulate_planes,
    decode_magnitudes,
    encode_level,
    plane_bound,
    values_from_planes,
)
from repro.bitplane.segments import (
    InMemoryPlaneSource,
    LevelStream,
    PlaneSegment,
    PlaneSource,
)

__all__ = [
    "LevelBitplanes", "PlaneGroupMeta", "encode_level", "decode_magnitudes",
    "accumulate_planes", "values_from_planes", "plane_bound",
    "LevelStream", "PlaneSegment", "PlaneSource", "InMemoryPlaneSource",
    "CodecError", "PlaneCodec", "codec_name", "decode_tagged",
    "encode_tagged", "get_codec", "register", "registered_codecs",
]
