from repro.bitplane.encoder import (
    LevelBitplanes,
    decode_magnitudes,
    encode_level,
    plane_bound,
)
from repro.bitplane.segments import LevelStream, PlaneSegment

__all__ = [
    "LevelBitplanes", "encode_level", "decode_magnitudes", "plane_bound",
    "LevelStream", "PlaneSegment",
]
