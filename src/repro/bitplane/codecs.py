"""Pluggable plane codecs: the real entropy stage behind the bitplane coder.

Every encoded plane (and sign plane) is a *tagged* blob: one codec-id byte
followed by that codec's payload.  ``encode_tagged`` is the cost model — it
tries candidate codecs on the packed plane bytes and keeps the smallest
encoding (so a plane never costs more than ``1 + len(raw)`` bytes), and
``decode_tagged`` dispatches on the id byte and hands back exactly
``out_len`` bytes or raises `CodecError`.  Registered codecs:

    id 0  raw    the bytes themselves (incompressible ~0.5-density planes)
    id 1  zlib   deflate level 1 (the former stand-in, kept as a candidate)
    id 2  rle    zero-run/literal run-length coding — near-empty MSB planes
                 of smooth data collapse to a handful of bytes
    id 3  rans   static order-0 rANS over plane bytes (lane-interleaved so
                 encode/decode vectorize with numpy) — skewed-but-not-empty
                 byte distributions that deflate's LZ window wastes bits on

The id byte doubles as the on-disk format: container manifests (format v3,
repro.store.container) record it per segment so transport stats can break
bytes down per codec without touching payloads, but decode never *needs*
the manifest — blobs are self-describing.  Legacy archives (format v1/v2)
tagged planes with ``b"R"`` (raw) / ``b"Z"`` (zlib) and stored sign planes
as bare zlib streams; ``decode_tagged`` / ``decode_sign_blob`` keep both
decoding bit-identically, and the numeric id space deliberately avoids
0x52/0x5A/0x78 so old and new blobs can never be confused.

The registry is open: ``register(codec)`` adds an experiment's coder and the
cost model picks it up automatically; unknown ids on decode raise
`CodecError` — garbage must never be silently interpreted as plane data.
"""
from __future__ import annotations

import struct
import zlib
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

import numpy as np


class CodecError(IOError):
    """A codec payload failed to decode (truncated, corrupt, or tagged with
    an unknown codec id) — same integrity family as the store's
    ChecksumError: the decoder must raise, never return garbage planes."""


# Legacy single-character tags written by the pre-registry entropy stage and
# still present in v1/v2 archives; kept out of the numeric id space.
_LEGACY_RAW = 0x52     # b"R"
_LEGACY_ZLIB = 0x5A    # b"Z"
_LEGACY_SIGN = 0x78    # zlib CMF byte: bare (untagged) legacy sign streams

# Density band in which a plane is at ~maximum entropy and stored raw
# without trying any candidate (same gate as the legacy stand-in).
RAW_DENSITY_BAND = (0.45, 0.55)


# Decoders accept and may return any bytes-like buffer (bytes or a
# memoryview into a fetched segment): raw planes dominate an archive by
# bytes, and forcing a copy per plane would put a memcpy back on the
# retrieval hot path the old zero-copy `_inflate_plane` never paid.
BytesLike = Union[bytes, memoryview]


class PlaneCodec:
    """One entropy coder over packed plane bytes.

    ``encode`` returns the payload (no tag byte); ``decode`` must return a
    bytes-like buffer of exactly ``out_len`` bytes or raise `CodecError`.
    ``estimate`` may return a cheap projected payload size (from the byte
    histogram) so the cost model can skip encoding candidates that cannot
    win; ``None`` means "encode to find out".
    """

    codec_id: int
    name: str

    def encode(self, data: bytes) -> bytes:
        raise NotImplementedError

    def decode(self, payload: BytesLike, out_len: int) -> BytesLike:
        raise NotImplementedError

    def estimate(self, data: bytes, counts: np.ndarray) -> Optional[int]:
        return None


class RawCodec(PlaneCodec):
    codec_id = 0
    name = "raw"

    def encode(self, data: bytes) -> bytes:
        return data

    def decode(self, payload: BytesLike, out_len: int) -> BytesLike:
        if len(payload) != out_len:
            raise CodecError(f"raw payload is {len(payload)} bytes, "
                             f"expected {out_len}")
        return payload                    # zero-copy: the dominant codec

    def estimate(self, data: bytes, counts: np.ndarray) -> Optional[int]:
        return len(data)


class ZlibCodec(PlaneCodec):
    codec_id = 1
    name = "zlib"

    def encode(self, data: bytes) -> bytes:
        return zlib.compress(data, 1)

    def decode(self, payload: BytesLike, out_len: int) -> BytesLike:
        try:
            out = zlib.decompress(payload)
        except zlib.error as e:
            raise CodecError(f"zlib payload failed to inflate: {e}") from e
        if len(out) != out_len:
            raise CodecError(f"zlib payload inflated to {len(out)} bytes, "
                             f"expected {out_len}")
        return out


def _write_varint(out: bytearray, v: int) -> None:
    while v >= 0x80:
        out.append((v & 0x7F) | 0x80)
        v >>= 7
    out.append(v)


def _read_varint(buf, pos: int) -> Tuple[int, int]:
    v = shift = 0
    while True:
        if pos >= len(buf):
            raise CodecError("rle payload: truncated varint")
        b = buf[pos]
        pos += 1
        v |= (b & 0x7F) << shift
        if not b & 0x80:
            return v, pos
        shift += 7
        if shift > 63:
            raise CodecError("rle payload: varint overflow")


class RleCodec(PlaneCodec):
    """Zero-run / literal-run coding for near-empty planes.

    Payload is a sequence of ``(zero_run varint, literal_len varint,
    literal bytes)`` records; the output is complete when the running total
    reaches ``out_len``.  Zero runs shorter than ``_MIN_RUN`` are folded
    into the surrounding literal — a 2-byte record header to skip 2 zero
    bytes is a loss, and folding bounds the record count on adversarial
    alternating input."""

    codec_id = 2
    name = "rle"
    _MIN_RUN = 4

    def encode(self, data: bytes) -> bytes:
        a = np.frombuffer(data, dtype=np.uint8)
        out = bytearray()
        n = a.size
        if n == 0:
            return bytes(out)
        nz = a != 0
        # run boundaries: starts[i]..starts[i+1] is one homogeneous run
        starts = [0] + (np.flatnonzero(np.diff(nz)) + 1).tolist() + [n]
        pend_zero = 0
        lit_start = lit_stop = 0          # current literal span [start, stop)
        for i in range(len(starts) - 1):
            s, e = starts[i], starts[i + 1]
            if nz[s] or e - s < self._MIN_RUN:
                # literal run, or a short zero run folded into the literal
                if lit_stop == lit_start:
                    lit_start = lit_stop = s
                lit_stop = e
            else:
                # a zero run worth a record: flush the open record first
                if pend_zero or lit_stop > lit_start:
                    _write_varint(out, pend_zero)
                    _write_varint(out, lit_stop - lit_start)
                    out += data[lit_start:lit_stop]
                pend_zero = e - s
                lit_start = lit_stop = e
        if pend_zero or lit_stop > lit_start:
            _write_varint(out, pend_zero)
            _write_varint(out, lit_stop - lit_start)
            out += data[lit_start:lit_stop]
        return bytes(out)

    def decode(self, payload: BytesLike, out_len: int) -> BytesLike:
        buf = payload if isinstance(payload, memoryview) \
            else memoryview(payload)
        out = bytearray()
        pos = 0
        while pos < len(buf):
            zrun, pos = _read_varint(buf, pos)
            lit, pos = _read_varint(buf, pos)
            # bound BOTH lengths before materialising anything: a corrupt
            # varint must raise CodecError, not attempt a huge allocation
            if zrun > out_len - len(out):
                raise CodecError(f"rle payload decodes past {out_len} bytes")
            if pos + lit > len(buf):
                raise CodecError("rle payload: literal run overruns payload")
            out += bytes(zrun)
            out += buf[pos:pos + lit]
            pos += lit
            if len(out) > out_len:
                raise CodecError(f"rle payload decodes past {out_len} bytes")
        if len(out) != out_len:
            raise CodecError(f"rle payload decoded {len(out)} bytes, "
                             f"expected {out_len}")
        return bytes(out)

    def estimate(self, data: bytes, counts: np.ndarray) -> Optional[int]:
        n = len(data)
        zeros = int(counts[0]) if counts.size else 0
        # run-length only earns its keep on mostly-zero planes; below that
        # the run scan is wasted work on a plane zlib/rans handle better —
        # report "no better than raw" so the cost model skips the encode
        if zeros < 0.6 * n:
            return n
        # cheap lower bound: every non-zero byte is a literal, zero bytes
        # are (optimistically) free
        return n - zeros


class RansCodec(PlaneCodec):
    """Static order-0 rANS over plane bytes, lane-interleaved.

    32-bit states with 16-bit renormalisation (the "rans word" variant:
    state invariant ``[L, L<<16)`` with ``L = 2^16`` guarantees at most one
    renorm per symbol), ``scale_bits = 12``.  ``lanes`` independent states
    encode strided sub-sequences so every per-symbol step is a handful of
    numpy ops over a ``(lanes,)`` vector instead of a Python byte loop;
    renorm words from all lanes share ONE stream in deterministic
    (step, ascending-lane) order, so the only per-lane overhead is the
    4-byte final state.

    Payload: ``u16 lanes | u16 n_sym | n_sym * (u8 sym, u16 freq) |
    lanes * u32 state | 16-bit stream words to end of payload`` (all
    little-endian; the stream length is implied by the payload size).
    Decode re-derives everything else from ``out_len`` and checks that
    every lane's state lands back on ``L`` with the stream fully consumed —
    corrupt payloads fail loudly.
    """

    codec_id = 3
    name = "rans"
    _L = 1 << 16
    _SCALE = 12
    _M = 1 << _SCALE

    @staticmethod
    def _lanes_for(n: int) -> int:
        # more lanes = fewer (vectorized) steps = faster encode AND decode,
        # but 4 bytes of final-state overhead per lane.  Lean toward speed:
        # the cost model charges the states against the payload size, so
        # rANS only gets selected when it wins *despite* the overhead — and
        # then decodes at the wide-lane rate on the retrieval hot path.
        if n >= 1 << 16:
            return 256
        if n >= 1 << 13:
            return 128
        if n >= 1 << 11:
            return 64
        if n >= 1 << 8:
            return 16
        return 4 if n >= 64 else 1

    def _normalize(self, counts: np.ndarray, total: int
                   ) -> Tuple[np.ndarray, np.ndarray]:
        syms = np.flatnonzero(counts)
        f = np.maximum(1, np.round(
            counts[syms] * (self._M / total)).astype(np.int64))
        diff = int(self._M - f.sum())
        while diff != 0:
            if diff > 0:
                f[int(np.argmax(f))] += diff
                diff = 0
            else:
                i = int(np.argmax(np.where(f > 1, f, -1)))
                step = max(diff, 1 - int(f[i]))
                f[i] += step
                diff -= step
        return syms, f

    def encode(self, data: bytes) -> bytes:
        a = np.frombuffer(data, dtype=np.uint8)
        n = a.size
        if n == 0:
            return struct.pack("<HH", 1, 0)
        counts = np.bincount(a, minlength=256)
        syms, f = self._normalize(counts, n)
        freq = np.zeros(256, dtype=np.uint64)
        cum = np.zeros(256, dtype=np.uint64)
        freq[syms] = f
        cum[syms] = np.cumsum(f) - f
        lanes = self._lanes_for(n)
        T = -(-n // lanes)
        if T * lanes != n:                # pad tail with a present symbol
            a = np.concatenate([a, np.full(T * lanes - n, syms[0],
                                           dtype=np.uint8)])
        m = a.reshape(T, lanes)
        x = np.full(lanes, self._L, dtype=np.uint64)
        chunks: List[np.ndarray] = []
        thresh = np.uint64((self._L >> self._SCALE) << 16)
        shift = np.uint64(16)
        scale = np.uint64(self._SCALE)
        for t in range(T - 1, -1, -1):
            fs = freq[m[t]]
            mask = x >= thresh * fs
            if mask.any():
                # decoder reads these words in ascending-lane order at the
                # matching step; chunk order is reversed below
                chunks.append((x[mask] & np.uint64(0xFFFF)
                               ).astype(np.uint16))
                x = np.where(mask, x >> shift, x)
            x = ((x // fs) << scale) + (x % fs) + cum[m[t]]
        stream = (np.concatenate(chunks[::-1]) if chunks
                  else np.empty(0, dtype=np.uint16))
        out = bytearray(struct.pack("<HH", lanes, len(syms)))
        out += np.rec.fromarrays(
            [syms.astype(np.uint8), f.astype(np.uint16)],
            dtype=[("s", "u1"), ("f", "<u2")]).tobytes()
        out += x.astype("<u4").tobytes()
        out += stream.astype("<u2").tobytes()
        return bytes(out)

    def decode(self, payload: BytesLike, out_len: int) -> BytesLike:
        buf = payload if isinstance(payload, memoryview) \
            else memoryview(payload)
        if len(buf) < 4:
            raise CodecError("rans payload: truncated header")
        lanes, n_sym = struct.unpack_from("<HH", buf, 0)
        if out_len == 0:
            return b""
        if lanes == 0 or n_sym == 0:
            raise CodecError("rans payload: empty model for non-empty output")
        pos = 4
        table_len = 3 * n_sym
        states_len = 4 * lanes
        if len(buf) < pos + table_len + states_len:
            raise CodecError("rans payload: truncated symbol table / states")
        rec = np.frombuffer(buf, dtype=[("s", "u1"), ("f", "<u2")],
                            count=n_sym, offset=pos)
        pos += table_len
        syms = rec["s"].astype(np.int64)
        f = rec["f"].astype(np.int64)
        if np.unique(syms).size != n_sym or f.min() < 1 \
                or int(f.sum()) != self._M:
            raise CodecError("rans payload: invalid symbol table")
        freq = np.zeros(256, dtype=np.uint64)
        cum = np.zeros(256, dtype=np.uint64)
        freq[syms] = f
        cum[syms] = np.cumsum(f) - f
        lut = np.repeat(syms.astype(np.uint8), f)
        x = np.frombuffer(buf, dtype="<u4", count=lanes,
                          offset=pos).astype(np.uint64)
        pos += states_len
        if (len(buf) - pos) % 2:
            raise CodecError("rans payload: odd stream length")
        stream = np.frombuffer(buf, dtype="<u2",
                               count=(len(buf) - pos) // 2, offset=pos)
        T = -(-out_len // lanes)
        out = np.empty((T, lanes), dtype=np.uint8)
        spos = 0
        mask_slot = np.uint64(self._M - 1)
        scale = np.uint64(self._SCALE)
        shift = np.uint64(16)
        low = np.uint64(self._L)
        for t in range(T):
            slot = x & mask_slot
            s = lut[slot]
            out[t] = s
            x = freq[s] * (x >> scale) + slot - cum[s]
            need = x < low
            k = int(need.sum())
            if k:
                if spos + k > stream.size:
                    raise CodecError("rans payload: stream underrun")
                x[need] = (x[need] << shift) | stream[spos:spos + k
                                                      ].astype(np.uint64)
                spos += k
        if spos != stream.size:
            raise CodecError("rans payload: trailing stream words")
        if not bool(np.all(x == low)):
            raise CodecError("rans payload: final state mismatch")
        return out.reshape(-1)[:out_len].tobytes()

    def estimate(self, data: bytes, counts: np.ndarray) -> Optional[int]:
        n = len(data)
        if n == 0:
            return 4
        syms = np.flatnonzero(counts)
        p = counts[syms] / n
        bits = float(n * -(p * np.log2(p)).sum())
        lanes = self._lanes_for(n)
        return int(np.ceil(bits / 8)) + 4 + 3 * syms.size + 4 * lanes


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_BY_ID: Dict[int, PlaneCodec] = {}
_BY_NAME: Dict[str, PlaneCodec] = {}


def register(codec: PlaneCodec) -> PlaneCodec:
    """Add a codec to the registry (and the cost model's candidate pool)."""
    cid = codec.codec_id
    if not 0 <= cid < 0x40:
        # ids must stay clear of the legacy tag bytes (0x52/0x5A) and the
        # bare-zlib sign sentinel (0x78)
        raise ValueError(f"codec id {cid} outside the reserved range [0, 64)")
    if cid in _BY_ID or codec.name in _BY_NAME:
        raise ValueError(f"codec id {cid} / name {codec.name!r} "
                         f"already registered")
    _BY_ID[cid] = codec
    _BY_NAME[codec.name] = codec
    return codec


def get_codec(codec_id: int) -> PlaneCodec:
    codec = _BY_ID.get(codec_id)
    if codec is None:
        raise CodecError(f"unknown codec id {codec_id}")
    return codec


def codec_name(codec_id: Optional[int]) -> str:
    """Human label for stats output; tolerates unregistered/None ids."""
    if codec_id is None:
        return "untagged"
    if codec_id == _LEGACY_RAW:
        return "raw(legacy)"
    if codec_id == _LEGACY_ZLIB:
        return "zlib(legacy)"
    codec = _BY_ID.get(codec_id)
    return codec.name if codec is not None else f"id{codec_id}"


def registered_codecs() -> Dict[str, PlaneCodec]:
    return dict(_BY_NAME)


RAW = register(RawCodec())
ZLIB = register(ZlibCodec())
RLE = register(RleCodec())
RANS = register(RansCodec())

# The cost model's default candidate pool, overridable per process (e.g.
# `repro.launch.serve --codecs raw,zlib` pins the encoder to the legacy
# pair).  Order matters twice: earlier wins ties, and cheap encoders come
# first so their actual sizes tighten the estimate gate before the
# expensive ones (rANS) decide whether to run at all.
DEFAULT_CANDIDATES: Tuple[str, ...] = ("rle", "zlib", "rans")


def set_default_candidates(names: Iterable[str]) -> Tuple[str, ...]:
    """Set the process-wide candidate pool; returns the previous one.
    ``raw`` is always implied (the fallback that caps any plane's cost at
    1 + len(data) bytes) and need not be listed."""
    global DEFAULT_CANDIDATES
    prev = DEFAULT_CANDIDATES
    pool = tuple(n for n in names if n != "raw")
    for n in pool:
        if n not in _BY_NAME:
            raise ValueError(f"unknown codec {n!r}; registered: "
                             f"{sorted(_BY_NAME)}")
    DEFAULT_CANDIDATES = pool
    return prev


# ---------------------------------------------------------------------------
# Tagged encode / decode (the cost model)
# ---------------------------------------------------------------------------


def encode_tagged(data: bytes, density: Optional[float] = None,
                  candidates: Optional[Sequence[str]] = None) -> bytes:
    """Encode ``data`` under the smallest candidate codec; returns the
    one-byte codec id + payload.

    ``density`` is the plane's set-bit density when known: planes inside
    ``RAW_DENSITY_BAND`` are at ~maximum entropy and are stored raw without
    trying any candidate (skipping both compress and later decompress work,
    exactly like the legacy stand-in's gate).  The cost model computes each
    candidate's cheap size *estimate* first and only runs encoders that
    could still beat the current best, so e.g. rANS is never paid for on a
    plane RLE already collapsed."""
    names = DEFAULT_CANDIDATES if candidates is None else candidates
    best_id, best_payload = RawCodec.codec_id, data
    if density is not None and \
            RAW_DENSITY_BAND[0] <= density <= RAW_DENSITY_BAND[1]:
        return bytes([best_id]) + best_payload
    counts = np.bincount(np.frombuffer(data, dtype=np.uint8), minlength=256)
    for name in names:
        codec = _BY_NAME[name]
        est = codec.estimate(data, counts)
        if est is not None and est >= len(best_payload):
            continue                      # cannot win even in the best case
        payload = codec.encode(data)
        if len(payload) < len(best_payload):
            best_id, best_payload = codec.codec_id, payload
    return bytes([best_id]) + best_payload


def decode_tagged(blob: BytesLike, out_len: int) -> BytesLike:
    """Inverse of ``encode_tagged``; also decodes the legacy ``b"R"`` /
    ``b"Z"`` tags of v1/v2 archives.  Returns a bytes-like buffer (raw
    planes decode zero-copy as a view into ``blob``).  Raises `CodecError`
    on unknown ids or payloads that do not decode to exactly ``out_len``
    bytes."""
    if len(blob) == 0:
        raise CodecError("empty tagged blob")
    tag = blob[0]
    payload = memoryview(blob)[1:]
    if tag == _LEGACY_RAW:
        return RAW.decode(payload, out_len)
    if tag == _LEGACY_ZLIB:
        return ZLIB.decode(payload, out_len)
    return get_codec(tag).decode(payload, out_len)


def decode_sign_blob(blob: BytesLike, out_len: int) -> BytesLike:
    """Decode a sign-plane blob: codec-tagged (current archives) or a bare
    zlib stream (v1/v2 archives, whose CMF first byte 0x78 can never be a
    codec id)."""
    if len(blob) > 0 and blob[0] == _LEGACY_SIGN:
        return ZLIB.decode(blob, out_len)
    return decode_tagged(blob, out_len)


def blob_codec_id(blob: bytes) -> Optional[int]:
    """The codec id byte of a tagged blob (manifest metadata); None for
    empty blobs."""
    return blob[0] if blob else None
