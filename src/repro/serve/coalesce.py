"""Cross-session request coalescing (single-flight reconstruction).

N clients tightening the same variable to the same eps from the same
decode state would each fetch the same plane segments and re-run the same
recompose.  The coalescer collapses them: one *leader* performs the real
``reader.request(eps)``; every concurrent duplicate *waits*, then adopts
the leader's reconstruction after advancing its own (cache-hot) streams.

Correctness leans on the decode invariant the incremental-recompose layer
already asserts (core/refactor.py module docstring): decoded values — and
therefore the reconstruction — are a pure function of the per-group
fetched-plane counts.  The flight key therefore includes the caller's
*state signature* (the tuple of per-stream fetched counts): two sessions
only share a flight when they start from identical decode states, and a
waiter only adopts when its post-advance signature equals the leader's
end signature.  Any mismatch (a concurrent request at a different eps
moved the waiter's streams in between, a degraded stream pinned early)
falls back to a plain ``request`` — strictly correct, merely uncoalesced.

The waiter's ``advance_to`` moves its own streams through the shared
SegmentCache — the leader's fetch already inserted every segment, so the
advance is byte-cheap and performs NO recompose; ``adopt_reconstruction``
then installs the shared field.  Results are bit-identical to a
sequential single-client run at the same tolerances (asserted in
tests/test_serve_concurrent.py).

Interplay with decode batching (repro.serve.batch): the coalescer merges
*identical* requests into one flight; the DecodeBatcher merges the device
work of *distinct* flights.  Leaders of different (variable, eps) flights
running on different worker threads flush their fused decodes within the
same batching window, so one vmapped dispatch covers every flight of a
serve tick — the two layers compose without knowing about each other
(flights interact only through pure decode dispatches, never through
shared mutable state).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Dict, Optional, Tuple


@dataclass
class CoalesceStats:
    """Counters for one ReconstructCoalescer (mutated under its lock)."""
    leaders: int = 0          # flights executed for real
    hits: int = 0             # duplicate requests that joined a flight
    adoptions: int = 0        # waiters that adopted the leader's result
    fallbacks: int = 0        # waiters that re-requested (sig mismatch/error)
    uncoalescable: int = 0    # readers without signature/adopt support

    def snapshot(self) -> Dict[str, float]:
        return {
            "leaders_total": float(self.leaders),
            "hits_total": float(self.hits),
            "adoptions_total": float(self.adoptions),
            "fallbacks_total": float(self.fallbacks),
            "uncoalescable_total": float(self.uncoalescable),
        }


class _Flight:
    """One in-progress leader request; waiters block on ``done``."""

    __slots__ = ("done", "result", "error")

    def __init__(self) -> None:
        self.done = threading.Event()
        self.result: Optional[Tuple] = None   # (recon, end_signature)
        self.error: Optional[BaseException] = None

    def set(self, result: Tuple) -> None:
        self.result = result
        self.done.set()

    def set_error(self, exc: BaseException) -> None:
        self.error = exc
        self.done.set()


class ReconstructCoalescer:
    """Single-flight map over (variable, eps, decode-state) keys.

    One coalescer serves ONE archive (the serve plane builds one per
    ``RetrievalServer``); sessions opt in via ``session.coalescer``.
    ``wait_timeout_s`` bounds how long a waiter blocks on a stuck leader
    before falling back to its own request (fail-open, never fail-stuck).
    """

    def __init__(self, wait_timeout_s: float = 120.0):
        self.wait_timeout_s = float(wait_timeout_s)
        self._mu = threading.Lock()
        self._inflight: Dict[Tuple, _Flight] = {}
        self.stats = CoalesceStats()

    def reconstruct(self, session, name: str, eps: float):
        """Drop-in for ``session.readers[name].request(eps)`` with
        cross-session sharing; returns ``(data, achieved_bound)``."""
        reader = session.readers[name]
        sig_fn = getattr(reader, "state_signature", None)
        if sig_fn is None or not hasattr(reader, "adopt_reconstruction"):
            with self._mu:
                self.stats.uncoalescable += 1
            return reader.request(eps)
        key = (name, float(eps), sig_fn())
        with self._mu:
            flight = self._inflight.get(key)
            if flight is None:
                flight = _Flight()
                self._inflight[key] = flight
                self.stats.leaders += 1
                is_leader = True
            else:
                self.stats.hits += 1
                is_leader = False
        if is_leader:
            try:
                data, achieved = reader.request(eps)
                flight.set((data, sig_fn()))
            except BaseException as exc:
                flight.set_error(exc)
                raise
            finally:
                with self._mu:
                    self._inflight.pop(key, None)
            return data, achieved
        return self._join(flight, reader, eps)

    def _join(self, flight: _Flight, reader, eps: float):
        if not flight.done.wait(self.wait_timeout_s) or \
                flight.error is not None:
            with self._mu:
                self.stats.fallbacks += 1
            return reader.request(eps)
        data, end_sig = flight.result
        # advance this session's own streams (cache-hot: the leader's fetch
        # already populated the SegmentCache) WITHOUT recomposing, then
        # adopt the shared field if the decode states really converged
        reader.advance_to(eps)
        if reader.state_signature() == end_sig:
            reader.adopt_reconstruction(data)
            with self._mu:
                self.stats.adoptions += 1
            return data, reader.achieved_bound()
        with self._mu:
            self.stats.fallbacks += 1
        return reader.request(eps)

    def metrics(self) -> Dict[str, float]:
        with self._mu:
            out = self.stats.snapshot()
            out["inflight"] = float(len(self._inflight))
        return out
