"""Cross-session decode batching: one fused dispatch per serve-plane tick.

With the fused device decode (kernels/ops.decode_values_fused) each reader
still pays one jit dispatch per group flush.  Under the concurrent serve
plane many readers flush at the same moment — the coalescer already merges
*identical* requests, but distinct sessions tightening distinct variables
each dispatch alone.  ``DecodeBatcher`` closes that gap:

  * readers ``submit_decode`` / ``submit_recompose`` work items and block
    on ``Ticket.result()``;
  * the FIRST waiter sleeps one batching window (``window_ms``) and then
    drains everything pending, bucketing by dispatch shape —
    ``("decode", P_pad, W)`` for plane flushes and
    ``("recompose", shape, levels, start, n_idx, is_ip)`` for
    contributions (hb and `ip` items recompose through different graphs,
    so they never share a bucket; an ip item's quantum is a traced operand
    and does not split buckets);
  * buckets with >= 2 items go through ONE vmapped dispatch
    (``ops.decode_values_fused_batch`` / ``scatter_recompose_from_batch``);
    singletons — stragglers whose shape matched nobody — fall back to the
    ordinary per-reader dispatch inside the same drain.

vmap adds a leading batch axis and nothing else: every slice runs the same
elementwise graph as a solo dispatch, so batched results are bit-identical
to per-reader results (the conformance suite and
``tests/test_serve_concurrent.py`` pin this).

Decode is a pure function of (plane words, state), so the scheme needs no
rollback path: if a waiter's window expires without anyone flushing it, it
simply flushes itself — worst case the batch is smaller, never wrong.
The batcher is shared across sessions (it lives on the server and rides
into readers via ``SessionOptions.decode_batcher``); all entry points are
thread-safe.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.kernels import ops


@dataclass
class BatcherStats:
    """Dispatch accounting — the serve bench's ``dispatch_ratio`` (items per
    dispatch) comes straight from these counters."""
    decode_items: int = 0
    decode_dispatches: int = 0
    decode_batched: int = 0        # items that rode a vmapped dispatch
    recompose_items: int = 0
    recompose_dispatches: int = 0
    recompose_batched: int = 0
    flushes: int = 0
    _mu: threading.Lock = field(default_factory=threading.Lock,
                                repr=False, compare=False)

    def as_dict(self) -> Dict[str, float]:
        with self._mu:
            items = self.decode_items + self.recompose_items
            disp = self.decode_dispatches + self.recompose_dispatches
            return {
                "decode_items": float(self.decode_items),
                "decode_dispatches": float(self.decode_dispatches),
                "decode_batched": float(self.decode_batched),
                "recompose_items": float(self.recompose_items),
                "recompose_dispatches": float(self.recompose_dispatches),
                "recompose_batched": float(self.recompose_batched),
                "flushes": float(self.flushes),
                "dispatch_ratio": float(items) / disp if disp else 0.0,
            }


class Ticket:
    """One submitted work item; ``result()`` blocks until a flush ran it."""

    def __init__(self, batcher: "DecodeBatcher", kind: str, key: Tuple,
                 payload: Tuple):
        self._batcher = batcher
        self.kind = kind
        self.key = key
        self.payload = payload
        self._done = threading.Event()
        self._result = None
        self._error: Optional[BaseException] = None

    def _finish(self, result=None, error: Optional[BaseException] = None):
        self._result = result
        self._error = error
        self._done.set()

    def result(self):
        # first waiter gives the window a chance to fill, then drains the
        # whole pending set itself; later waiters usually find _done set
        if not self._done.wait(self._batcher.window_s):
            self._batcher.flush()
            self._done.wait()
        if self._error is not None:
            raise self._error
        return self._result


class DecodeBatcher:
    """Shape-bucketed batching front for fused decode + device recompose."""

    def __init__(self, window_ms: float = 2.0,
                 batch_recompose: bool = True, plane_slots: int = 64):
        self.window_s = max(0.0, float(window_ms)) / 1e3
        self.batch_recompose = bool(batch_recompose)
        # decode items are padded to this many plane slots (host-side, zero
        # no-op planes) so every same-width group lands in ONE bucket and
        # the vmapped graph set stays tiny; archives with more planes than
        # this keep their natural power-of-two padded length
        self.plane_slots = int(plane_slots)
        self.stats = BatcherStats()
        self._mu = threading.Lock()
        self._pending: List[Ticket] = []

    # -- submission -------------------------------------------------------
    def submit_decode(self, words: np.ndarray, shifts: np.ndarray, state,
                      sign_bytes: np.ndarray, scale: float,
                      count: int) -> Ticket:
        """Queue one group flush.  Arguments mirror
        ``ops.decode_values_fused``; padding to the bucketable full-word,
        uniform-plane-slot layout happens here so the key is exact — items
        with different fetched-plane counts still merge (``plane_slots``
        pads the shorter ones with zero planes, exact no-ops)."""
        w, sh, st, sb = ops.prepare_fused_decode(words, shifts, state,
                                                 sign_bytes, count,
                                                 self.plane_slots)
        key = ("decode", w.shape[0], w.shape[1])
        t = Ticket(self, "decode", key, (w, sh, st, sb, scale, count))
        with self._mu:
            self._pending.append(t)
        return t

    def submit_recompose(self, idx, vals, shape: Tuple[int, ...],
                         levels: int, start: int,
                         quantum: Optional[float] = None) -> Ticket:
        """Queue one contribution scatter+recompose
        (``transform.hierarchical.scatter_recompose_from``).  A non-None
        ``quantum`` routes through the `ip` variant
        (``scatter_recompose_ip_from``) — the quantum itself is a traced
        operand, so ip items with different quanta still share a bucket;
        only the hb/ip graph split keys the bucket."""
        key = ("recompose", tuple(shape), int(levels), int(start),
               int(len(idx)), quantum is not None)
        t = Ticket(self, "recompose", key,
                   (idx, vals, tuple(shape), int(levels), int(start),
                    quantum))
        with self._mu:
            self._pending.append(t)
        return t

    # -- draining ---------------------------------------------------------
    def flush(self) -> int:
        """Drain everything pending in shape buckets.  Returns the number
        of device dispatches issued.  Safe to call from any thread at any
        time (decode is pure; an extra flush only shrinks batches)."""
        with self._mu:
            batch, self._pending = self._pending, []
        if not batch:
            return 0
        buckets: Dict[Tuple, List[Ticket]] = {}
        for t in batch:
            buckets.setdefault(t.key, []).append(t)
        dispatches = 0
        for key, tickets in buckets.items():
            try:
                if key[0] == "decode":
                    dispatches += self._run_decode(tickets)
                else:
                    dispatches += self._run_recompose(tickets)
            except BaseException as e:   # propagate to every waiter
                for t in tickets:
                    t._finish(error=e)
        with self.stats._mu:
            self.stats.flushes += 1
        return dispatches

    @staticmethod
    def _pad_pow2(items: List) -> List:
        """Repeat the last item up to the next power-of-two batch size, so
        vmapped graphs compile for O(log B) distinct batch shapes instead
        of one per observed bucket size (padding lanes are computed and
        discarded — decode is pure, so they cost a little device work and
        change nothing)."""
        b = 1
        while b < len(items):
            b <<= 1
        return items + [items[-1]] * (b - len(items))

    def _run_decode(self, tickets: List[Ticket]) -> int:
        import jax.numpy as jnp
        n = len(tickets)
        with self.stats._mu:
            self.stats.decode_items += n
            self.stats.decode_dispatches += 1
            if n > 1:
                self.stats.decode_batched += n
        if n == 1:
            w, sh, st, sb, scale, count = tickets[0].payload
            mag, vals = ops._decode_fused(w, sh, st, sb, jnp.float64(scale))
            tickets[0]._finish((mag, vals[:count]))
            return 1
        padded = self._pad_pow2(tickets)
        stack = lambda i: jnp.stack([t.payload[i] for t in padded])
        scales = jnp.asarray([t.payload[4] for t in padded],
                             dtype=jnp.float64)
        mag_b, vals_b = ops._decode_fused_batch(stack(0), stack(1), stack(2),
                                                stack(3), scales)
        for i, t in enumerate(tickets):
            t._finish((mag_b[i], vals_b[i][: t.payload[5]]))
        return 1

    def _run_recompose(self, tickets: List[Ticket]) -> int:
        import jax.numpy as jnp

        from repro.transform.hierarchical import (
            scatter_recompose_from, scatter_recompose_from_batch,
            scatter_recompose_ip_from, scatter_recompose_ip_from_batch)
        n = len(tickets)
        batched = n > 1 and self.batch_recompose
        with self.stats._mu:
            self.stats.recompose_items += n
            self.stats.recompose_dispatches += 1 if batched else n
            if batched:
                self.stats.recompose_batched += n
        if not batched:
            for t in tickets:
                idx, vals, shape, levels, start, quantum = t.payload
                if quantum is None:
                    t._finish(scatter_recompose_from(jnp.asarray(idx),
                                                     jnp.asarray(vals),
                                                     shape, levels, start))
                else:
                    t._finish(scatter_recompose_ip_from(
                        jnp.asarray(idx), jnp.asarray(vals), shape, levels,
                        start, jnp.float64(quantum)))
            return n
        _, _, shape, levels, start, quantum = tickets[0].payload
        padded = self._pad_pow2(tickets)
        idx_b = jnp.stack([jnp.asarray(t.payload[0]) for t in padded])
        vals_b = jnp.stack([jnp.asarray(t.payload[1]) for t in padded])
        if quantum is None:
            out = scatter_recompose_from_batch(idx_b, vals_b, shape, levels,
                                               start)
        else:
            q_b = jnp.asarray([t.payload[5] for t in padded],
                              dtype=jnp.float64)
            out = scatter_recompose_ip_from_batch(idx_b, vals_b, shape,
                                                  levels, start, q_b)
        for i, t in enumerate(tickets):
            t._finish(out[i])
        return 1
