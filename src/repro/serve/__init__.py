"""Concurrent multi-tenant serve plane.

The paper's deployment shape (Fig. 1) is many analysis clients pulling
on-demand-precision reconstructions from ONE progressive archive.  This
package turns ``repro.launch.serve`` from a sequential for-loop into a
real service:

  * :mod:`repro.serve.pool`     — bounded worker pool with per-session
    locking, load shedding (503 + Retry-After past the high-water mark)
    and handle-latency histograms.
  * :mod:`repro.serve.coalesce` — cross-session request coalescing: N
    clients tightening the same variable to the same eps from the same
    decode state share one fetch + one recompose; the result is fanned
    out to every waiter (bit-identical by the plane-count invariant).
  * :mod:`repro.serve.batch`    — cross-session decode batching: one
    vmapped fused decode + recompose dispatch per serve tick covering
    every reader's newly fetched planes, with a per-reader fallback for
    stragglers whose shape matches nobody.
  * :mod:`repro.serve.budget`   — server-level pooled contribution
    budget replacing the per-variable ``contrib_budget_bytes``: readers
    borrow/return field-sized leases against one pool so the hottest
    variables win.
  * :mod:`repro.serve.metrics`  — plaintext counter dump + log-bucketed
    latency histogram backing the ``/health`` and ``/metrics`` endpoints
    on :mod:`repro.store.httpd`.

Everything here is pure stdlib + numpy; the decode/recompose layers are
untouched except for the borrow/adopt hooks in ``core/refactor.py``.
"""
from repro.serve.batch import BatcherStats, DecodeBatcher
from repro.serve.budget import ContribBudgetPool, PoolStats
from repro.serve.coalesce import CoalesceStats, ReconstructCoalescer
from repro.serve.metrics import (LatencyHistogram, MetricsRegistry,
                                 render_metrics)
from repro.serve.pool import ServePlane, ServerOverloadedError

__all__ = [
    "BatcherStats",
    "DecodeBatcher",
    "ContribBudgetPool",
    "PoolStats",
    "CoalesceStats",
    "ReconstructCoalescer",
    "LatencyHistogram",
    "MetricsRegistry",
    "render_metrics",
    "ServePlane",
    "ServerOverloadedError",
]
