"""Server-level pooled contribution budget (borrow/return leases).

The per-variable ``contrib_budget_bytes`` cap (PR 4) splits the server's
memory statically: a cold variable hoards its share while a hot one
recomputes contributions every refresh.  ``ContribBudgetPool`` replaces
that with ONE server-wide pool that every bitplane reader borrows
field-sized leases from, so residency follows demand — the hottest
variables win.

Protocol (see ``_BitplaneVarReader._retain_pooled`` in core/refactor.py):

  * ``retain(owner, slot, level, nbytes, value)`` — atomically grant or
    refresh a lease and *deposit* the contribution field into the owner's
    slot.  If the pool is full, holdings with a strictly worse
    depth-weighted recency score are reclaimed first (their owners' slots
    are cleared under the pool lock via ``owner._pool_set_contrib``); if
    not enough reclaimable bytes exist, the request is denied and the
    caller spills (recompute-on-demand keeps outputs bit-identical).
  * ``release_owner(owner)`` — return every lease of a closing reader.

Victim scoring mirrors the SegmentCache: ``score = tick − depth_weight ·
level``.  Fine levels (low ``level``) are the hottest (size-weighted eps
splits give them the most planes in flight, and their rebuild is the
cheapest to skip), so a *positive* depth weight ages coarse holdings
faster.  All slot mutations for pooled readers happen under the pool
lock, which is what makes cross-session reclaim safe: a reader never
observes a half-cleared slot, and the accounting in ``ContribStats``
moves in the same critical section.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Dict, Tuple


@dataclass
class PoolStats:
    """Counters for one ContribBudgetPool (all mutated under its lock)."""
    borrowed_bytes: int = 0
    peak_borrowed_bytes: int = 0
    leases: int = 0
    grants: int = 0
    touches: int = 0
    denials: int = 0
    reclaims: int = 0

    def snapshot(self) -> Dict[str, float]:
        return {
            "borrowed_bytes": float(self.borrowed_bytes),
            "peak_borrowed_bytes": float(self.peak_borrowed_bytes),
            "leases": float(self.leases),
            "grants_total": float(self.grants),
            "touches_total": float(self.touches),
            "denials_total": float(self.denials),
            "reclaims_total": float(self.reclaims),
        }


@dataclass
class _Lease:
    owner: object
    slot: int
    level: int
    nbytes: int
    tick: int


class ContribBudgetPool:
    """One server-wide contribution-memory pool shared by all sessions.

    ``total_bytes`` caps the sum of outstanding leases; ``depth_weight``
    tunes how aggressively coarse-level holdings are reclaimed in favour
    of fine-level ones (0 = pure LRU across the server).
    """

    def __init__(self, total_bytes: int, depth_weight: float = 4.0):
        if total_bytes < 0:
            raise ValueError("total_bytes must be >= 0")
        self.total_bytes = int(total_bytes)
        self.depth_weight = float(depth_weight)
        self._mu = threading.Lock()
        self._leases: Dict[Tuple[int, int], _Lease] = {}
        self._tick = 0
        self.stats = PoolStats()

    # -- scoring ----------------------------------------------------------
    def _score(self, tick: int, level: int) -> float:
        return tick - self.depth_weight * level

    # -- lease surface ----------------------------------------------------
    def retain(self, owner, slot: int, level: int, nbytes: int,
               value) -> bool:
        """Grant/refresh a lease for ``owner``'s contribution ``slot`` and
        deposit ``value`` there; returns False (and leaves the slot empty)
        when the pool cannot make room without reclaiming hotter holdings.
        """
        nbytes = int(nbytes)
        key = (id(owner), slot)
        with self._mu:
            self._tick += 1
            lease = self._leases.get(key)
            if lease is not None:
                lease.tick = self._tick
                self.stats.touches += 1
                owner._pool_set_contrib(slot, value)
                return True
            if nbytes > self.total_bytes:
                self.stats.denials += 1
                return False
            if not self._make_room(nbytes, self._score(self._tick, level)):
                self.stats.denials += 1
                return False
            self._leases[key] = _Lease(owner=owner, slot=slot, level=level,
                                       nbytes=nbytes, tick=self._tick)
            self.stats.borrowed_bytes += nbytes
            if self.stats.borrowed_bytes > self.stats.peak_borrowed_bytes:
                self.stats.peak_borrowed_bytes = self.stats.borrowed_bytes
            self.stats.leases = len(self._leases)
            self.stats.grants += 1
            owner._pool_set_contrib(slot, value)
            return True

    def _make_room(self, nbytes: int, requester_score: float) -> bool:
        """Reclaim strictly-worse-scored leases until ``nbytes`` fit.

        Returns False (reclaiming nothing) when even evicting every
        worse-scored holding would not free enough — an all-or-nothing
        plan keeps a denied request from churning other readers' caches.
        """
        need = self.stats.borrowed_bytes + nbytes - self.total_bytes
        if need <= 0:
            return True
        victims = sorted(
            (l for l in self._leases.values()
             if self._score(l.tick, l.level) < requester_score),
            key=lambda l: self._score(l.tick, l.level))
        freed, plan = 0, []
        for lease in victims:
            plan.append(lease)
            freed += lease.nbytes
            if freed >= need:
                break
        if freed < need:
            return False
        for lease in plan:
            self._drop(lease)
            self.stats.reclaims += 1
        return True

    def _drop(self, lease: _Lease) -> None:
        del self._leases[(id(lease.owner), lease.slot)]
        self.stats.borrowed_bytes -= lease.nbytes
        self.stats.leases = len(self._leases)
        lease.owner._pool_set_contrib(lease.slot, None)

    def release(self, owner, slot: int) -> None:
        """Return one lease (no-op when not held)."""
        with self._mu:
            lease = self._leases.get((id(owner), slot))
            if lease is not None:
                self._drop(lease)

    def release_owner(self, owner) -> None:
        """Return every lease held by ``owner`` (reader/session close)."""
        with self._mu:
            for lease in [l for l in self._leases.values()
                          if l.owner is owner]:
                self._drop(lease)

    def holds(self, owner, slot: int) -> bool:
        with self._mu:
            return (id(owner), slot) in self._leases

    @property
    def borrowed_bytes(self) -> int:
        with self._mu:
            return self.stats.borrowed_bytes

    def metrics(self) -> Dict[str, float]:
        with self._mu:
            out = self.stats.snapshot()
        out["total_bytes"] = float(self.total_bytes)
        return out
