"""Worker pool + per-session locking + load shedding for the serve plane.

``ServePlane`` fronts a request handler (``RetrievalServer.handle``) with:

  * a bounded thread pool — progressive retrieval is I/O-bound on the
    segment store, so threads overlap fetch latency across sessions even
    under the GIL (the recompose math releases it inside numpy);
  * per-session locks — sessions are stateful progressive readers; two
    in-flight requests for the same client must serialize, requests for
    different clients must not;
  * load shedding — admission control at submit: past ``queue_depth``
    outstanding requests the submit raises :class:`ServerOverloadedError`
    carrying a Retry-After estimate (queue drain time at the observed
    service rate), which the HTTP front maps to ``503 Retry-After: n``.
    Shedding at the door keeps tail latency bounded instead of letting
    the queue grow without limit;
  * handle-latency histograms (queue wait + service time) feeding the
    /metrics endpoint's p50/p99 and tail-amplification rows.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Dict, Optional

from repro.serve.metrics import LatencyHistogram


class ServerOverloadedError(RuntimeError):
    """Raised at submit when the pending queue is past the high-water mark.

    ``retry_after_s`` is the server's drain-time estimate — the HTTP front
    sends it as ``Retry-After`` so well-behaved clients back off instead
    of hammering a saturated pool.
    """

    def __init__(self, pending: int, queue_depth: int, retry_after_s: float):
        super().__init__(
            f"serve queue full ({pending}/{queue_depth} outstanding); "
            f"retry after {retry_after_s:.1f}s")
        self.pending = pending
        self.queue_depth = queue_depth
        self.retry_after_s = retry_after_s


class ServePlane:
    """Concurrent front for a request handler with per-session locking.

    ``handler(request)`` runs on a worker thread; ``session_key(request)``
    names the sticky session a request belongs to (requests with equal
    keys serialize in submission order, everything else runs in
    parallel).  ``submit`` never blocks: it either enqueues and returns a
    Future or sheds with :class:`ServerOverloadedError`.
    """

    def __init__(self, handler: Callable, workers: int = 8,
                 queue_depth: int = 64,
                 session_key: Optional[Callable] = None,
                 decode_batcher=None):
        if workers < 1:
            raise ValueError("workers must be >= 1")
        if queue_depth < 1:
            raise ValueError("queue_depth must be >= 1")
        self.workers = int(workers)
        self.queue_depth = int(queue_depth)
        # optional serve.DecodeBatcher the handlers share: worker threads
        # flushing fused decodes within one window merge into a single
        # vmapped dispatch (the "batched tick"); kept here so the pool's
        # metrics() reports dispatch coalescing next to queue pressure
        self.decode_batcher = decode_batcher
        self._handler = handler
        self._session_key = session_key or (
            lambda req: getattr(req, "client", None))
        self._executor = ThreadPoolExecutor(
            max_workers=self.workers, thread_name_prefix="serve-worker")
        self._mu = threading.Lock()
        self._pending = 0           # submitted, not yet finished
        self._busy = 0              # currently inside a handler
        self._session_locks: Dict[object, threading.Lock] = {}
        self._requests = 0
        self._shed = 0
        self._errors = 0
        self._closed = False
        self.queue_wait = LatencyHistogram()
        self.handle_latency = LatencyHistogram()   # wait + service

    # -- admission --------------------------------------------------------
    def _retry_after(self) -> float:
        """Drain-time estimate: outstanding work / observed service rate."""
        snap = self.handle_latency.snapshot()
        per_req_s = (snap["mean_ms"] / 1e3) if snap["count"] else 0.25
        return max(1.0, self._pending * per_req_s / self.workers)

    def submit(self, request) -> Future:
        with self._mu:
            if self._closed:
                raise RuntimeError("ServePlane is shut down")
            if self._pending >= self.queue_depth:
                self._shed += 1
                raise ServerOverloadedError(self._pending, self.queue_depth,
                                            self._retry_after())
            self._pending += 1
            self._requests += 1
            lock = self._session_locks.setdefault(
                self._session_key(request), threading.Lock())
        submitted = time.perf_counter()
        return self._executor.submit(self._run, request, lock, submitted)

    def handle(self, request):
        """Synchronous convenience: submit + wait (sheds like submit)."""
        return self.submit(request).result()

    # -- worker body ------------------------------------------------------
    def _run(self, request, lock: threading.Lock, submitted: float):
        with lock:          # per-session serialization
            started = time.perf_counter()
            self.queue_wait.observe(started - submitted)
            with self._mu:
                self._busy += 1
            try:
                return self._handler(request)
            except BaseException:
                with self._mu:
                    self._errors += 1
                raise
            finally:
                done = time.perf_counter()
                self.handle_latency.observe(done - submitted)
                with self._mu:
                    self._busy -= 1
                    self._pending -= 1

    # -- observability ----------------------------------------------------
    def health(self) -> Dict[str, object]:
        """Liveness/pressure summary for the /health endpoint."""
        with self._mu:
            pending, shedding = self._pending, \
                self._pending >= self.queue_depth
        return {
            "ok": not shedding,
            "pending": pending,
            "queue_depth": self.queue_depth,
            "retry_after_s": self._retry_after() if shedding else 0.0,
        }

    def metrics(self) -> Dict[str, float]:
        with self._mu:
            out = {
                "workers": float(self.workers),
                "workers_busy": float(self._busy),
                "queue_depth_limit": float(self.queue_depth),
                "queue_depth": float(max(0, self._pending - self._busy)),
                "inflight": float(self._pending),
                "requests_total": float(self._requests),
                "shed_total": float(self._shed),
                "errors_total": float(self._errors),
                "sessions": float(len(self._session_locks)),
            }
        for name, value in self.queue_wait.snapshot().items():
            out[f"queue_wait_{name}"] = value
        for name, value in self.handle_latency.snapshot().items():
            out[f"latency_{name}"] = value
        if self.decode_batcher is not None:
            for name, value in self.decode_batcher.stats.as_dict().items():
                out[f"batch_{name}"] = value
        return out

    def shutdown(self, wait: bool = True) -> None:
        with self._mu:
            self._closed = True
        self._executor.shutdown(wait=wait)

    def __enter__(self) -> "ServePlane":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()
