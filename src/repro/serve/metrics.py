"""Serve-plane observability: counters + latency quantiles, plaintext dump.

The /metrics endpoint is a plaintext ``name value`` dump (one counter per
line, sorted) — the lowest-common-denominator format every scraper can
ingest and every human can ``curl``.  Latency quantiles come from a
log-bucketed histogram rather than a reservoir: fixed memory, lock-cheap
increments, and the p50/p99 estimates stay within one bucket width (~7%)
of the true quantile, which is plenty for tail-amplification reporting.
"""
from __future__ import annotations

import math
import threading
from typing import Callable, Dict, List, Tuple

# Buckets span 10us .. ~167s at x1.25 steps: 1.25^72 ~= 9.3e6, i.e. enough
# resolution for sub-ms cache hits and patience for WAN-bound tail requests.
_BUCKET_BASE_S = 10e-6
_BUCKET_GROWTH = 1.25
_N_BUCKETS = 72


class LatencyHistogram:
    """Fixed-size log-bucketed latency histogram with quantile estimates.

    ``observe`` is O(1) under one lock; ``quantile`` walks the buckets and
    returns the upper edge of the bucket containing the requested rank —
    a <= one-bucket-width overestimate, monotone in q.
    """

    def __init__(self) -> None:
        self._mu = threading.Lock()
        self._counts = [0] * (_N_BUCKETS + 1)   # last bucket = overflow
        self._n = 0
        self._sum_s = 0.0
        self._max_s = 0.0

    @staticmethod
    def _bucket(seconds: float) -> int:
        if seconds <= _BUCKET_BASE_S:
            return 0
        b = int(math.log(seconds / _BUCKET_BASE_S) / math.log(_BUCKET_GROWTH))
        return min(b + 1, _N_BUCKETS)

    @staticmethod
    def _edge(bucket: int) -> float:
        return _BUCKET_BASE_S * (_BUCKET_GROWTH ** bucket)

    def observe(self, seconds: float) -> None:
        b = self._bucket(max(0.0, float(seconds)))
        with self._mu:
            self._counts[b] += 1
            self._n += 1
            self._sum_s += seconds
            if seconds > self._max_s:
                self._max_s = seconds

    @property
    def count(self) -> int:
        with self._mu:
            return self._n

    def quantile(self, q: float) -> float:
        """Estimated q-quantile in seconds (0.0 when empty)."""
        q = min(1.0, max(0.0, q))
        with self._mu:
            if self._n == 0:
                return 0.0
            rank = q * self._n      # nearest-rank: p99 of 10 = the max
            seen = 0
            for b, c in enumerate(self._counts):
                seen += c
                if seen > rank:
                    return min(self._edge(b), self._max_s)
            return self._max_s

    def snapshot(self) -> Dict[str, float]:
        with self._mu:
            n, total, mx = self._n, self._sum_s, self._max_s
        return {
            "count": float(n),
            "mean_ms": (total / n * 1e3) if n else 0.0,
            "p50_ms": self.quantile(0.50) * 1e3,
            "p99_ms": self.quantile(0.99) * 1e3,
            "max_ms": mx * 1e3,
        }


class MetricsRegistry:
    """Aggregates counter *sources* into one flat ``/metrics`` view.

    A source is a zero-arg callable returning ``{name: number}``; the serve
    plane registers one per subsystem (pool, coalescer, budget pool, cache,
    fetcher, httpd) so the endpoint needs no knowledge of any of them.
    Collisions are a programming error and raise at render time — silent
    last-writer-wins would corrupt dashboards invisibly.
    """

    def __init__(self) -> None:
        self._mu = threading.Lock()
        self._sources: List[Tuple[str, Callable[[], Dict[str, float]]]] = []

    def register(self, prefix: str,
                 source: Callable[[], Dict[str, float]]) -> None:
        with self._mu:
            self._sources.append((prefix, source))

    def collect(self) -> Dict[str, float]:
        with self._mu:
            sources = list(self._sources)
        out: Dict[str, float] = {}
        for prefix, source in sources:
            for name, value in source().items():
                key = f"{prefix}_{name}" if prefix else name
                if key in out:
                    raise ValueError(f"duplicate metric {key!r}")
                out[key] = float(value)
        return out

    def render(self) -> str:
        """Plaintext dump: one ``name value`` per line, sorted by name."""
        return render_metrics(self.collect())


def render_metrics(values: Dict[str, float]) -> str:
    """Render a flat counter dict as the plaintext /metrics body."""
    return "".join(f"{name} {values[name]:g}\n" for name in sorted(values))
