"""olmoe-1b-7b [moe]: 16L d_model=2048 16H (GQA kv=16) d_ff=1024
vocab=50304, MoE 64 experts top-8 [arXiv:2409.02060]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b", family="moe",
        n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1024,
        vocab=50_304, head_dim=128,
        n_experts=64, top_k=8, capacity_factor=1.25,
    )


def reduced_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=32, vocab=256, n_experts=8, top_k=2,
        dtype="float32", param_dtype="float32", remat=False)
