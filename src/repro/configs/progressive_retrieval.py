"""The paper's own "architecture": the progressive-retrieval pipeline
configuration (companion to the 10 assigned LM archs — this is what the
paper itself deploys).

Defaults follow §V/§VI: PMGARD-HB refactoring, 48 magnitude bitplanes,
c=1.5 tightening, zero-velocity outlier masks, and the PSZ3 ladders
ε_i = range · 10^-i used for the comparison baselines.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class PipelineConfig:
    method: str = "hb"                  # hb | ob | psz3 | psz3_delta
    nbits: int = 48                     # magnitude bitplanes
    reduction_factor: float = 1.5       # Alg 4's c
    mask_zero_velocity: bool = True     # §V-A outlier bitmap
    n_snapshots: int = 10               # PSZ3(-delta) ladder depth
    snapshot_base: float = 10.0         # ε_i = range · base^-i
    max_iters: int = 100
    tight_estimators: bool = False      # beyond-paper exact-sup √ bound


def config() -> PipelineConfig:
    return PipelineConfig()


def reduced_config() -> PipelineConfig:
    return PipelineConfig(nbits=32, n_snapshots=4, max_iters=20)
