"""The paper's own "architecture": the progressive-retrieval pipeline
configuration (companion to the 10 assigned LM archs — this is what the
paper itself deploys).

Defaults follow §V/§VI: PMGARD-HB refactoring, 48 magnitude bitplanes,
c=1.5 tightening, zero-velocity outlier masks, and the PSZ3 ladders
ε_i = range · 10^-i used for the comparison baselines.

Beyond-paper serving knobs (memory-bounded retrieval, see
docs/architecture.md): ``contrib_budget_bytes`` caps each bitplane
reader's retained per-level contribution fields (None = unbounded —
the paper's assumption that full-precision state fits in RAM);
``segment_cache_bytes`` / ``cache_depth_weight`` / ``archive_floor_bytes``
shape the cross-session segment cache's depth-weighted eviction and
per-archive isolation (repro.store.cache).

Concurrent-serve knobs (docs/serving.md): ``serve_workers`` /
``serve_queue_depth`` size the worker pool and its load-shedding
high-water mark; ``contrib_pool_bytes`` replaces the per-variable
contribution budget with one server-wide borrow/return pool;
``cache_admission`` enables the segment cache's churn-avoiding
admission check under multi-tenant pressure.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class PipelineConfig:
    method: str = "hb"                  # hb | ob | psz3 | psz3_delta
    nbits: int = 48                     # magnitude bitplanes
    reduction_factor: float = 1.5       # Alg 4's c
    mask_zero_velocity: bool = True     # §V-A outlier bitmap
    n_snapshots: int = 10               # PSZ3(-delta) ladder depth
    snapshot_base: float = 10.0         # ε_i = range · base^-i
    max_iters: int = 100
    tight_estimators: bool = False      # beyond-paper exact-sup √ bound
    # memory-bounded retrieval (beyond paper):
    contrib_budget_bytes: Optional[int] = None  # per-variable reader budget
    segment_cache_bytes: int = 256 << 20        # cross-session cache total
    cache_depth_weight: float = 64.0            # MSB-over-LSB eviction bias
    archive_floor_bytes: int = 0                # per-archive residency floor
    # concurrent multi-tenant serving (beyond paper, docs/serving.md):
    serve_workers: int = 8                      # worker-pool threads
    serve_queue_depth: int = 64                 # shed past this many pending
    contrib_pool_bytes: Optional[int] = None    # server-wide pooled budget
    cache_admission: bool = False               # churn-avoiding insert gate

    def server_kwargs(self) -> dict:
        """The memory + serving knobs as `repro.launch.serve.RetrievalServer`
        kwargs — `RetrievalServer(fields, **cfg.server_kwargs())`.  Kept in
        one place so the config fields and the server signature cannot drift
        apart (asserted in tests/test_memory_bound.py)."""
        return {"method": self.method,
                "cache_bytes": self.segment_cache_bytes,
                "cache_depth_weight": self.cache_depth_weight,
                "archive_floor_bytes": self.archive_floor_bytes,
                "contrib_budget_bytes": self.contrib_budget_bytes,
                "workers": self.serve_workers,
                "queue_depth": self.serve_queue_depth,
                "contrib_pool_bytes": self.contrib_pool_bytes,
                "cache_admission": self.cache_admission}


def config() -> PipelineConfig:
    return PipelineConfig()


def reduced_config() -> PipelineConfig:
    return PipelineConfig(nbits=32, n_snapshots=4, max_iters=20)


def memory_bounded_config(contrib_budget_bytes: int = 32 << 20,
                          segment_cache_bytes: int = 64 << 20,
                          archive_floor_bytes: int = 8 << 20
                          ) -> PipelineConfig:
    """A serving profile for many concurrent sessions/variables per host:
    coarse contribution fields spill (bit-identical recompute on touch) and
    the segment cache keeps shared MSB prefixes while isolating archives."""
    return PipelineConfig(contrib_budget_bytes=contrib_budget_bytes,
                          segment_cache_bytes=segment_cache_bytes,
                          archive_floor_bytes=archive_floor_bytes)


def multi_tenant_config(contrib_pool_bytes: int = 64 << 20,
                        segment_cache_bytes: int = 128 << 20,
                        workers: int = 8,
                        queue_depth: int = 64) -> PipelineConfig:
    """A concurrent-serving profile (docs/serving.md): worker pool with
    load shedding, one pooled contribution budget shared by every session
    (hottest variables stay resident), and cache admission control so one
    deep-descending tenant cannot churn the shared MSB prefix."""
    return PipelineConfig(contrib_pool_bytes=contrib_pool_bytes,
                          segment_cache_bytes=segment_cache_bytes,
                          serve_workers=workers,
                          serve_queue_depth=queue_depth,
                          cache_admission=True)
