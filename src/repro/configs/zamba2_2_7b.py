"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64 — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242]. One shared attn+MLP block applied every 6 Mamba2
layers (DESIGN.md §7 simplification of the two-alternating-blocks scheme).
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b", family="hybrid",
        n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10_240,
        vocab=32_000, head_dim=80,
        ssm_state=64, ssm_headdim=64, ssm_expand=2, ssm_conv=4,
        ssm_chunk=256, ssm_groups=1,
        shared_attn_period=6,
        sub_quadratic=True,
    )


def reduced_config() -> ModelConfig:
    return config().replace(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=256, ssm_state=16, ssm_headdim=16, ssm_chunk=16,
        shared_attn_period=2,
        dtype="float32", param_dtype="float32", remat=False)
