"""phi-3-vision-4.2b [vlm]: 32L d_model=3072 32H (kv=32) d_ff=8192
vocab=32064 — phi3-mini backbone + CLIP frontend
[hf:microsoft/Phi-3-vision-128k-instruct].
The CLIP frontend is a STUB: input_specs() provides precomputed patch
embeddings (B, n_patches, d_model) prepended to the text sequence."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b", family="vlm",
        n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, d_ff=8192,
        vocab=32_064, head_dim=96,
        frontend="patches", n_frontend_tokens=256,
        fsdp=True,
    )


def reduced_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=256, n_frontend_tokens=8, fsdp=False,
        dtype="float32", param_dtype="float32", remat=False)
