"""qwen2.5-14b [dense]: 48L d_model=5120 40H (GQA kv=8) d_ff=13824
vocab=152064 — GQA with QKV bias [hf:Qwen/Qwen2.5 family]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-14b", family="dense",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=13_824,
        vocab=152_064, head_dim=128,
        qkv_bias=True, rope_theta=1_000_000.0,
        fsdp=True,
    )


def reduced_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, fsdp=False,
        dtype="float32", param_dtype="float32", remat=False)
