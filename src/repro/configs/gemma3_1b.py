"""gemma3-1b [dense]: 26L d_model=1152 4H (GQA kv=1) d_ff=6912
vocab=262144 — 5:1 local:global sliding window, 128k context
[hf:google/gemma-3-1b-pt]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b", family="dense",
        n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, d_ff=6912,
        vocab=262_144, head_dim=256,
        local_window=512, local_global_period=6,   # 5 local : 1 global
        rope_theta=1_000_000.0,
        tied_embeddings=True, act="gelu",
    )


def reduced_config() -> ModelConfig:
    return config().replace(
        n_layers=6, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab=256, local_window=8,
        dtype="float32", param_dtype="float32", remat=False)
