"""glm4-9b [dense]: 40L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=151552 — partial RoPE, GQA [hf:THUDM/glm-4-9b]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="glm4-9b", family="dense",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2, d_ff=13_696,
        vocab=151_552, head_dim=128,
        partial_rotary=0.5, qkv_bias=True,
        fsdp=True,
    )


def reduced_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, fsdp=False,
        dtype="float32", param_dtype="float32", remat=False)
