"""mamba2-780m [ssm]: 48L d_model=1536 (attn-free) vocab=50280,
ssm_state=128 — SSD (state-space duality) [arXiv:2405.21060]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m", family="ssm",
        n_layers=48, d_model=1536, n_heads=0, n_kv_heads=0, d_ff=0,
        vocab=50_280,
        ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_conv=4,
        ssm_chunk=256, ssm_groups=1,
        tied_embeddings=True,
        sub_quadratic=True,
    )


def reduced_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, vocab=256, ssm_state=16, ssm_headdim=16,
        ssm_chunk=16, dtype="float32", param_dtype="float32", remat=False)
