"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128 experts top-1 (+1 shared expert, early
fusion) [hf:meta-llama/Llama-4 family].

Assigned config is used verbatim (all layers MoE at d_ff=8192 per expert);
optimizer defaults to Adafactor (factored second moment) — Adam moments for
~0.8T params do not fit a 256-chip v5e pod (DESIGN.md §5)."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b", family="moe",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
        vocab=202_048, head_dim=128,
        n_experts=128, top_k=1, n_shared_experts=1, capacity_factor=1.25,
        rope_theta=500_000.0,
        fsdp=True, optimizer="adafactor",
    )


def reduced_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=64, vocab=256, n_experts=8, top_k=1, fsdp=False,
        dtype="float32", param_dtype="float32", remat=False)
