"""seamless-m4t-medium [audio]: 12L d_model=1024 16H (kv=16) d_ff=4096
vocab=256206 — encoder-decoder, multimodal [arXiv:2308.11596].
The speech frontend is a STUB: input_specs() provides precomputed frame
embeddings (B, T, d_model); the assigned numbers describe the transformer
backbone (12 encoder + 12 decoder layers)."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium", family="encdec",
        n_layers=12, n_encoder_layers=12,
        d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
        vocab=256_206, head_dim=64,
        act="gelu", frontend="frames",
    )


def reduced_config() -> ModelConfig:
    return config().replace(
        n_layers=2, n_encoder_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=128, vocab=256,
        dtype="float32", param_dtype="float32", remat=False)
