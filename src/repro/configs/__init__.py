"""Architecture registry: one module per assigned arch, exact public configs
+ reduced smoke configs (same family, tiny dims) for CPU tests.

Usage: repro.configs.get("qwen2.5-14b") / get_reduced("qwen2.5-14b").
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

_MODULES = {
    "mamba2-780m": "mamba2_780m",
    "gemma3-1b": "gemma3_1b",
    "qwen2.5-14b": "qwen2_5_14b",
    "internlm2-1.8b": "internlm2_1_8b",
    "glm4-9b": "glm4_9b",
    "llama4-maverick-400b-a17b": "llama4_maverick",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "zamba2-2.7b": "zamba2_2_7b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "phi-3-vision-4.2b": "phi3_vision",
}


def names() -> List[str]:
    return list(_MODULES)


def get(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {names()}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.config()


def get_reduced(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.reduced_config()


def all_configs() -> Dict[str, ModelConfig]:
    return {n: get(n) for n in names()}
