"""Unified open/session option objects for the public archive API.

Before manifest v4 the opener surface had grown three parallel kwarg
sprawls: ``open_archive`` took seven transport knobs, ``StoreArchive.open``
three session knobs, and every variable archive's ``open_reader`` its own
divergent pair.  This module collapses them into two frozen dataclasses —
:class:`OpenOptions` (how an archive is *opened*: transport, verification,
caching, fault tolerance) and :class:`SessionOptions` (how one session
*reads*: prefetch depth, contribution budget/pool) — with
``multi_tenant_config()``-style presets for the common deployments.

The old kwargs keep working through a deprecation shim that warns ONCE per
call-site pattern (:class:`ReproDeprecationWarning`); the test suite turns
the warning into an error (see pytest.ini), so no first-party module can
quietly regress onto the legacy spelling.

This module deliberately imports nothing from ``repro.store`` or
``repro.core`` — both shim layers import it, so it must sit below them.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, fields, replace
from typing import Any, Callable, Optional

__all__ = [
    "OpenOptions",
    "SessionOptions",
    "ReproDeprecationWarning",
    "warn_deprecated_once",
]


class ReproDeprecationWarning(DeprecationWarning):
    """A deprecated repro API spelling (legacy kwargs, shimmed signatures).

    Subclasses DeprecationWarning so standard tooling recognises it, but
    has its own type so the test suite can escalate exactly these to
    errors without fighting third-party deprecation noise."""


_warned: set = set()


def warn_deprecated_once(key: str, message: str, stacklevel: int = 3) -> None:
    """Emit ``message`` as a ReproDeprecationWarning the FIRST time ``key``
    is seen this process; later identical call sites stay silent.  A serve
    loop calling a shimmed API per-request must not flood stderr."""
    if key in _warned:
        return
    _warned.add(key)
    warnings.warn(message, ReproDeprecationWarning, stacklevel=stacklevel)


def _reset_deprecation_warnings() -> None:
    """Test hook: make every deprecation warn again (compat tests assert
    both the warning AND the warn-once suppression)."""
    _warned.clear()


def _from_legacy(cls, legacy: dict, where: str):
    """Build an options object from legacy kwargs, warning once.  Unknown
    names raise TypeError exactly like a real signature mismatch would."""
    valid = {f.name for f in fields(cls)}
    unknown = set(legacy) - valid
    if unknown:
        raise TypeError(f"{where}: unexpected keyword argument(s) "
                        f"{sorted(unknown)}")
    warn_deprecated_once(
        f"{where}:{','.join(sorted(legacy))}",
        f"{where}: passing {sorted(legacy)} as loose keyword arguments is "
        f"deprecated; pass {cls.__name__}(...) instead",
    )
    return cls(**legacy)


@dataclass(frozen=True)
class OpenOptions:
    """How an archive container is opened (transport + integrity layer).

    Fields mirror the archive-wide knobs that used to sprawl across
    ``open_archive``'s signature:

      * ``prefetch_workers`` — background segment-fetch threads (0 disables
        async prefetch);
      * ``verify`` — crc32c-check every delivered segment (disable only for
        forensics on a known-damaged container);
      * ``blob_resolver`` — override blob-name -> ByteStore lookup so shards
        can mix backends;
      * ``cache`` — cross-session ``SegmentCache``;
      * ``archive_id`` — cache budget-group override (default: manifest
        hash);
      * ``retry_policy`` / ``quarantine`` — fault-tolerance layer
        (``repro.store.retry``); None enables the hardened defaults;
      * ``follow`` — replay the manifest v4 journal on open and allow
        ``StoreArchive.refresh()`` to tail it afterwards (live archives);
        False pins the session to the base manifest.
    """
    prefetch_workers: int = 2
    verify: bool = True
    blob_resolver: Optional[Callable[[str], Any]] = None
    cache: Optional[Any] = None
    archive_id: Optional[str] = None
    retry_policy: Optional[Any] = None
    quarantine: Optional[Any] = None
    follow: bool = True

    @classmethod
    def default(cls) -> "OpenOptions":
        """Single-client defaults: verified reads, light prefetch."""
        return cls()

    @classmethod
    def multi_tenant(cls, cache, retry_policy=None,
                     quarantine=None) -> "OpenOptions":
        """Serve-plane preset: a shared cross-session cache plus the
        hardened retry/quarantine defaults (None keeps them enabled)."""
        return cls(cache=cache, retry_policy=retry_policy,
                   quarantine=quarantine)

    @classmethod
    def unverified(cls) -> "OpenOptions":
        """Forensics preset: skip crc32c so a damaged container can still
        be inspected; never publishes bytes to a shared cache."""
        return cls(verify=False)

    def with_(self, **changes) -> "OpenOptions":
        return replace(self, **changes)


@dataclass(frozen=True)
class SessionOptions:
    """How one retrieval session reads (per-session memory/prefetch policy).

      * ``prefetch_depth`` — how many ``reassign_eb`` reduction steps ahead
        the retrieval loop may hint to the fetcher;
      * ``contrib_budget_bytes`` — per-variable cap on each bitplane
        reader's retained contribution cache (None = unbounded; bit
        -identical outputs at any budget);
      * ``contrib_pool`` — server-wide
        :class:`repro.serve.budget.ContribBudgetPool` replacing the static
        cap (takes precedence when both are set);
      * ``decode_batcher`` — shared :class:`repro.serve.batch.DecodeBatcher`
        merging this session's fused decode / recompose dispatches with
        every other session's into one vmapped device call per serve tick
        (None = per-reader dispatch; results are bit-identical either way).
    """
    prefetch_depth: int = 1
    contrib_budget_bytes: Optional[int] = None
    contrib_pool: Optional[Any] = None
    decode_batcher: Optional[Any] = None

    @classmethod
    def default(cls) -> "SessionOptions":
        return cls()

    @classmethod
    def memory_bounded(cls, budget_bytes: int) -> "SessionOptions":
        """Cap each variable's resident recompose state; spilled levels are
        rebuilt on demand (outputs stay bit-identical)."""
        return cls(contrib_budget_bytes=int(budget_bytes))

    @classmethod
    def pooled(cls, pool) -> "SessionOptions":
        """Serve-plane preset: retention borrows from one shared pool."""
        return cls(contrib_pool=pool)

    def with_(self, **changes) -> "SessionOptions":
        return replace(self, **changes)
