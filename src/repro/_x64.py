"""Enable float64 for the compression/retrieval stack.

Imported by repro.core / repro.transform / repro.bitplane / repro.compressors.
Scientific data is f64 (paper Table III); the error-bound math must not be
polluted by f32 rounding. Model code (repro.models) is explicitly dtyped and
unaffected by this flag.
"""
import jax

jax.config.update("jax_enable_x64", True)
