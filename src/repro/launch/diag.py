import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")
"""Collective profiler for one dry-run cell: groups trip-scaled collective
bytes by (kind, shape) so the dominant contributor is obvious.

    PYTHONPATH=src python -m repro.launch.diag --arch X --shape Y [--save f]
"""
import argparse
import re
import sys

from repro.launch.hlo_analysis import (
    _build_multipliers, _shape_bytes, _split_computations, COLLECTIVE_OPS,
)


def profile_collectives(hlo: str, top: int = 15):
    comps = _split_computations(hlo)
    mult = _build_multipliers(comps)
    rows = []
    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m == 0.0:
            continue
        for ins in comp.instrs:
            op = ins.op[:-6] if ins.op.endswith("-start") else ins.op
            if op in COLLECTIVE_OPS:
                b = _shape_bytes(ins.shape) * m
                rows.append((b, m, op, ins.shape[:70], comp.name[:40]))
    rows.sort(reverse=True)
    total = sum(r[0] for r in rows)
    print(f"total collective bytes/dev: {total:.3e}")
    for b, m, op, shape, comp in rows[:top]:
        print(f"  {b:10.3e}B ({b / max(total, 1):5.1%}) x{m:<5.0f} {op:20s} "
              f"{shape} in {comp}")
    return rows


def profile_dots(hlo: str, top: int = 10):
    from repro.launch.hlo_analysis import _parse_shape
    comps = _split_computations(hlo)
    mult = _build_multipliers(comps)
    name_shape = {}
    for comp in comps.values():
        for ins in comp.instrs:
            name_shape[ins.name] = ins.shape
    rows = []
    op_re = re.compile(r"\(([^)]*)\)")
    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if not m:
            continue
        for ins in comp.instrs:
            if ins.op != "dot":
                continue
            _, out_dims = _parse_shape(ins.shape)
            out_prod = 1
            for d in out_dims:
                out_prod *= d
            ops_m = op_re.search(ins.line[ins.line.find("dot("):])
            lm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
            contract = 1
            if ops_m and lm and lm.group(1):
                lhs = name_shape.get(
                    ops_m.group(1).split(",")[0].strip().lstrip("%"), "")
                _, ld = _parse_shape(lhs)
                for idx in lm.group(1).split(","):
                    if int(idx) < len(ld):
                        contract *= ld[int(idx)]
            rows.append((m * 2 * out_prod * contract, m, ins.shape[:60],
                         comp.name[:40]))
    rows.sort(reverse=True)
    total = sum(r[0] for r in rows)
    print(f"total dot flops/dev: {total:.3e}")
    for f, m, shape, comp in rows[:top]:
        print(f"  {f:10.3e} ({f / max(total, 1):5.1%}) x{m:<5.0f} {shape} "
              f"in {comp}")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--save", default="")
    ap.add_argument("--moe-dispatch", default="scatter")
    args = ap.parse_args(argv)

    from repro.launch.dryrun import lower_cell  # after XLA_FLAGS
    from repro.launch import mesh as mesh_mod
    import repro.launch.dryrun as dr

    mesh = mesh_mod.make_production_mesh(multi_pod=(args.mesh == "multipod"))
    # reuse lower_cell's plumbing but capture the compiled HLO text
    hlo_holder = {}
    orig_analyze = dr.analyze_hlo

    def capture(hlo):
        hlo_holder["hlo"] = hlo
        return orig_analyze(hlo)

    dr.analyze_hlo = capture
    stats = lower_cell(args.arch, args.shape, mesh,
                       moe_dispatch=args.moe_dispatch)
    dr.analyze_hlo = orig_analyze
    print(f"status={stats['status']} compile={stats.get('compile_s')}s")
    hlo = hlo_holder.get("hlo", "")
    if args.save:
        open(args.save, "w").write(hlo)
    print("== collectives ==")
    profile_collectives(hlo)
    print("== dots ==")
    profile_dots(hlo)
    return 0


if __name__ == "__main__":
    sys.exit(main())
