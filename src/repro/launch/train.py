"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --reduced --steps 200 --batch 4 --seq 128 \
        --progressive-ckpt out/ckpt --ckpt-every 25 --grad-compress 8

Runs the real train loop on the local device(s): model from configs/,
AdamW/Adafactor, gradient clipping, optional bitplane gradient compression
(error feedback), async progressive checkpointing, fault-tolerant restart
(--resume), and deterministic synthetic data. On a TPU cluster the same
driver runs under the production mesh (launch/mesh.py); flags documented
for latency hiding on real backends:
  LIBTPU_INIT_ARGS=--xla_tpu_enable_async_collective_fusion=true
  --xla_tpu_enable_async_collective_fusion_fuse_all_gather=true
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.data.batches import make_train_batch
from repro.models import transformer as T
from repro.train.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.train.grad_compress import compress_decompress, zeros_like_feedback
from repro.train.optimizer import clip_by_global_norm, make_optimizer


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced smoke config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--grad-compress", type=int, default=0,
                    help="bitplanes for gradient compression (0 = off)")
    ap.add_argument("--progressive-ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--restore-tau", type=float, default=0.0,
                    help="QoI-bounded warm restore tolerance (0 = exact)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = configs.get_reduced(args.arch) if args.reduced \
        else configs.get(args.arch)
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    opt_init, opt_update = make_optimizer(cfg.optimizer)
    opt_state = opt_init(params)
    fb = None
    start_step = 0

    ckpt = AsyncCheckpointer(args.progressive_ckpt) \
        if args.progressive_ckpt else None
    if args.resume and ckpt and latest_step(args.progressive_ckpt) is not None:
        restored, report = restore_checkpoint(args.progressive_ckpt,
                                              tau_rel=args.restore_tau)
        params = jax.tree.map(
            lambda a, b: jnp.asarray(np.asarray(a), np.asarray(b).dtype),
            restored, params)
        start_step = report.step + 1
        print(f"[restore] step={report.step} moved="
              f"{report.bytes_moved / 2**20:.1f}MiB "
              f"({report.bytes_moved / max(report.bytes_full, 1):.0%} of full)")

    @jax.jit
    def step_fn(params, opt_state, fb, batch):
        (loss, metrics), grads = jax.value_and_grad(
            T.loss_fn, has_aux=True)(params, cfg, batch)
        if args.grad_compress:
            grads, fb = compress_decompress(grads, fb, args.grad_compress)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        params, opt_state = opt_update(params, grads, opt_state, lr=args.lr)
        return params, opt_state, fb, loss, gnorm

    if args.grad_compress:
        fb = zeros_like_feedback(params)

    t0 = time.time()
    tokens_per_step = args.batch * args.seq
    for step in range(start_step, args.steps):
        batch = make_train_batch(cfg, args.batch, args.seq, seed=step)
        params, opt_state, fb, loss, gnorm = step_fn(params, opt_state, fb,
                                                     batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t0
            done = step - start_step + 1
            print(f"step={step} loss={float(loss):.4f} "
                  f"gnorm={float(gnorm):.3f} "
                  f"tok/s={tokens_per_step * done / max(dt, 1e-9):.0f}")
        if ckpt and step % args.ckpt_every == 0:
            ckpt.save(params, step)
    if ckpt:
        ckpt.close()
    print(f"done: {args.steps - start_step} steps in {time.time() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
