"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module must never
touch jax device state (the dry-run sets the fake-device XLA flag before
anything else touches jax).

Hardware model (roofline constants for TPU v5e): 197 TFLOP/s bf16/chip,
819 GB/s HBM/chip, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType

# v5e roofline constants (per chip)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_mesh_for(n_devices: int, model_parallel: int = 1):
    """Small-mesh helper for tests/examples on real local devices."""
    data = n_devices // model_parallel
    return jax.make_mesh((data, model_parallel), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
