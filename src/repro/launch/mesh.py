"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module must never
touch jax device state (the dry-run sets the fake-device XLA flag before
anything else touches jax).

Hardware model (roofline constants for TPU v5e): 197 TFLOP/s bf16/chip,
819 GB/s HBM/chip, ~50 GB/s/link ICI.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax

# v5e roofline constants (per chip)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link

# jax >= 0.5 moved explicit/auto axis semantics into make_mesh(axis_types=);
# on 0.4.x the kwarg (and jax.sharding.AxisType) does not exist and every
# axis is implicitly Auto — which is the only type this codebase uses.
_AXIS_TYPE_AUTO = getattr(jax.sharding, "AxisType", None)
_AXIS_TYPE_AUTO = getattr(_AXIS_TYPE_AUTO, "Auto", None)


def make_mesh(shape: Sequence[int], axes: Sequence[str], *,
              devices: Optional[Sequence] = None) -> jax.sharding.Mesh:
    """Version-portable ``jax.make_mesh`` with all axes of type Auto."""
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if _AXIS_TYPE_AUTO is not None:
        kwargs["axis_types"] = (_AXIS_TYPE_AUTO,) * len(axes)
    return jax.make_mesh(tuple(shape), tuple(axes), **kwargs)


def make_production_mesh(*, multi_pod: bool = False):
    shape: Tuple[int, ...] = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh_for(n_devices: int, model_parallel: int = 1):
    """Small-mesh helper for tests/examples on real local devices."""
    data = n_devices // model_parallel
    return make_mesh((data, model_parallel), ("data", "model"))
