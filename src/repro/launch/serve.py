"""Batched progressive-retrieval service — the paper's serving shape.

    PYTHONPATH=src python -m repro.launch.serve --requests 16

Simulates the production deployment of Fig 1: data is refactored once into
progressive archives ("storage"); a stream of analysis requests arrives,
each naming QoIs + tolerances; the server runs Algorithm 2 per session and
answers with guaranteed-error reconstructions. Sessions are sticky, so a
client tightening its tolerance pays only for the new segments (the
incremental-recomposition contract).
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass
from typing import Dict, List

import numpy as np

from repro.core import ge
from repro.core.refactor import refactor_variables
from repro.core.retrieval import QoIRequest, retrieve_qoi_controlled
from repro.data.synthetic import ge_like_fields


@dataclass
class Request:
    client: str
    qois: List[str]
    tau: float


class RetrievalServer:
    def __init__(self, fields, method: str = "hb"):
        t0 = time.time()
        self.archive = refactor_variables(fields, method=method)
        self.sessions: Dict[str, object] = {}
        self.refactor_s = time.time() - t0
        self.qois = ge.all_qois()

    def handle(self, req: Request):
        if req.client not in self.sessions:
            self.sessions[req.client] = self.archive.open()
        session = self.sessions[req.client]
        before = session.bytes_retrieved
        reqs = [QoIRequest(q, self.qois[q], req.tau) for q in req.qois]
        t0 = time.time()
        res = retrieve_qoi_controlled(session, reqs)
        return {"client": req.client, "tau": req.tau,
                "bytes_moved": session.bytes_retrieved - before,
                "bitrate": res.bitrate, "latency_s": time.time() - t0,
                "guaranteed": res.converged,
                "est_errors": res.est_errors}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1 << 15)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--method", default="hb")
    args = ap.parse_args(argv)

    fields = ge_like_fields(n=args.n, seed=0)
    server = RetrievalServer(fields, method=args.method)
    print(f"[server] refactored {args.n} pts x5 vars in "
          f"{server.refactor_s:.2f}s "
          f"(archive {server.archive.total_nbytes / 2**20:.2f} MiB)")

    rng = np.random.default_rng(0)
    clients = [f"client{i}" for i in range(4)]
    qoi_names = list(ge.all_qois())
    total_bytes = 0
    for i in range(args.requests):
        req = Request(client=str(rng.choice(clients)),
                      qois=list(rng.choice(qoi_names,
                                           size=rng.integers(1, 4),
                                           replace=False)),
                      tau=float(10.0 ** -rng.integers(1, 6)))
        out = server.handle(req)
        total_bytes += out["bytes_moved"]
        print(f"[req {i:02d}] {req.client} qois={','.join(req.qois):18s} "
              f"tau={req.tau:.0e} moved={out['bytes_moved']:>9d}B "
              f"lat={out['latency_s'] * 1e3:7.1f}ms ok={out['guaranteed']}")
    raw = sum(v.nbytes for v in fields.values())
    print(f"[server] total moved {total_bytes / 2**20:.2f} MiB vs raw "
          f"{raw / 2**20:.2f} MiB ({total_bytes / raw:.0%})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
