"""Concurrent progressive-retrieval service — the paper's serving shape.

    PYTHONPATH=src python -m repro.launch.serve --requests 16
    PYTHONPATH=src python -m repro.launch.serve --store /data/ge.prs
    PYTHONPATH=src python -m repro.launch.serve --store /data/ge_dir --shard-by variable
    PYTHONPATH=src python -m repro.launch.serve --store http://host:8000/manifest.json
    PYTHONPATH=src python -m repro.launch.serve --store /data/ge.prs \
        --workers 8 --queue-depth 64 --pool-mb 64 --metrics-port 9100

The production deployment of Fig 1: data is refactored once into
progressive archives ("storage"); many analysis clients pull
guaranteed-error reconstructions concurrently.  Sessions are sticky, so a
client tightening its tolerance pays only for the new segments (the
incremental-recomposition contract).

Requests run on a bounded worker pool (``repro.serve.pool``) with
per-session locking and load shedding; concurrent duplicate tighten
requests coalesce across sessions into one fetch + one recompose
(``repro.serve.coalesce`` — bit-identical fan-out by the plane-count
invariant); and ``--pool-mb`` replaces the per-variable contribution
budget with ONE server-wide borrow/return pool (``repro.serve.budget``)
so the hottest variables keep their recompose state resident.
``--metrics-port`` exposes /health and /metrics (plaintext counters:
queue depth, p50/p99 handle latency, coalesce hits, cache/fetch/
quarantine counters, pool occupancy) on ``repro.store.httpd``.

With ``--store`` the server serves from an archive container (repro.store)
instead of holding the refactored archive in RAM — a local ``.prs`` file
(refactored + saved on first run if missing, exactly once even when two
servers start on the same path: creation is serialized behind a lockfile
and published by atomic rename), a sharded directory (``--shard-by
variable|group``), or an ``http(s)://`` URL of a container / sharded
manifest published by ``repro.store.httpd``.  Segments stream
checksum-verified through the SegmentFetcher (ranged reads + async
prefetch), and a cross-session `SegmentCache` sits under all client
sessions: planes one client already pulled are served from RAM to every
other client instead of re-fetched from the store (``--cache-admission``
additionally skips *inserting* deep-LSB segments under pressure instead
of evicting hot MSB prefixes moments before they are needed again).
"""
from __future__ import annotations

import argparse
import os
import tempfile
import time
from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from repro.bitplane import codecs as plane_codecs
from repro.core import ge
from repro.core.refactor import ContribStats, refactor_variables
from repro.core.retrieval import QoIRequest, retrieve_qoi_controlled
from repro.data.synthetic import ge_like_fields
from repro.options import OpenOptions, SessionOptions
from repro.serve import (ContribBudgetPool, DecodeBatcher,
                         ReconstructCoalescer, ServePlane,
                         ServerOverloadedError)
from repro.store import (BlobQuarantine, RetryPolicy, SegmentCache,
                         open_archive)
from repro.store.container import is_url
from repro.store.httpd import StoreHTTPServer
from repro.store.writer import ensure_archive   # noqa: F401  (re-export: the
# create-once lockfile dance now lives with the writer API, but this module
# remains its historical import path for embedders and tests)


@dataclass
class Request:
    client: str
    qois: List[str]
    tau: float


class RetrievalServer:
    """Multi-tenant progressive-retrieval server.

    ``contrib_budget_bytes`` caps each session's per-variable contribution
    cache (None = unbounded); ``contrib_pool_bytes`` replaces it with one
    server-wide borrow/return pool (``repro.serve.budget`` — takes
    precedence when both are given).  ``cache_depth_weight`` /
    ``archive_floor_bytes`` tune the cross-session SegmentCache's
    depth-weighted eviction and per-archive working-set floor
    (repro.store.cache); ``cache_admission`` skips inserting colder-than-
    everything segments under pressure instead of churning the cache.
    ``workers`` / ``queue_depth`` size the worker pool and its shedding
    high-water mark; ``coalesce=False`` disables cross-session
    single-flight (benchmark baseline)."""

    def __init__(self, fields, method: str = "hb",
                 store_path: Optional[str] = None,
                 shard_by: Optional[str] = None,
                 cache_bytes: int = 256 << 20,
                 cache_depth_weight: float = 64.0,
                 archive_floor_bytes: int = 0,
                 contrib_budget_bytes: Optional[int] = None,
                 retry_policy: Optional[RetryPolicy] = None,
                 quarantine: Optional[BlobQuarantine] = None,
                 workers: int = 8,
                 queue_depth: int = 64,
                 contrib_pool_bytes: Optional[int] = None,
                 cache_admission: bool = False,
                 coalesce: bool = True,
                 decode_batch_ms: Optional[float] = None):
        import threading
        t0 = time.time()
        self.cache: Optional[SegmentCache] = None
        self.contrib_budget_bytes = contrib_budget_bytes
        self.contrib_pool = ContribBudgetPool(contrib_pool_bytes) \
            if contrib_pool_bytes is not None else None
        self.coalescer = ReconstructCoalescer() if coalesce else None
        # one DecodeBatcher shared by every session: concurrent readers'
        # fused decode / recompose dispatches merge into one vmapped device
        # call per tick (None = classic per-reader dispatch)
        self.decode_batcher = DecodeBatcher(window_ms=decode_batch_ms) \
            if decode_batch_ms is not None else None
        if store_path is not None:
            ensure_archive(store_path,
                           lambda: refactor_variables(fields, method=method),
                           shard_by=shard_by)
            self.cache = SegmentCache(max_bytes=cache_bytes,
                                      depth_weight=cache_depth_weight,
                                      archive_floor_bytes=archive_floor_bytes,
                                      admission_control=cache_admission)
            self.archive = open_archive(
                store_path, OpenOptions.multi_tenant(
                    self.cache, retry_policy=retry_policy,
                    quarantine=quarantine))
            shapes = {k: np.asarray(v).shape for k, v in fields.items()}
            if self.archive.method != method or self.archive.shapes != shapes:
                raise SystemExit(
                    f"store {store_path} holds method="
                    f"{self.archive.method!r} shapes="
                    f"{dict(self.archive.shapes)} but the server was asked "
                    f"for method={method!r} shapes={shapes} — delete the "
                    f"file to re-refactor, or match the flags")
        else:
            self.archive = refactor_variables(fields, method=method)
        self.sessions: Dict[str, object] = {}
        self._sessions_mu = threading.Lock()
        self.refactor_s = time.time() - t0
        self.qois = ge.all_qois()
        self.plane = ServePlane(self._handle, workers=workers,
                                queue_depth=queue_depth,
                                session_key=lambda req: req.client,
                                decode_batcher=self.decode_batcher)

    # -- request path --------------------------------------------------------

    def _session(self, client: str):
        """Sticky per-client session, created under a lock (two first
        requests of one client may race through the pool)."""
        with self._sessions_mu:
            session = self.sessions.get(client)
            if session is None:
                session = self.archive.open(SessionOptions(
                    contrib_budget_bytes=self.contrib_budget_bytes,
                    contrib_pool=self.contrib_pool,
                    decode_batcher=self.decode_batcher))
                session.coalescer = self.coalescer
                self.sessions[client] = session
        return session

    def _handle(self, req: Request):
        """One request, run inline on the calling thread (the worker body;
        also the sequential baseline the concurrency bench compares
        against).  Per-session serialization is the ServePlane's job."""
        session = self._session(req.client)
        before = session.bytes_retrieved
        reqs = [QoIRequest(q, self.qois[q], req.tau) for q in req.qois]
        t0 = time.time()
        res = retrieve_qoi_controlled(session, reqs)
        return {"client": req.client, "tau": req.tau,
                "bytes_moved": session.bytes_retrieved - before,
                "bitrate": res.bitrate, "latency_s": time.time() - t0,
                "guaranteed": res.converged,
                "est_errors": res.est_errors,
                "degraded": res.degraded,
                "availability": res.availability}

    # kept as the documented single-threaded entry point: the concurrency
    # benchmark's sequential baseline, and any embedder that wants to own
    # its own threading
    handle_inline = _handle

    def handle(self, req: Request):
        """Concurrent entry point: submit to the worker pool and wait.
        Raises :class:`repro.serve.ServerOverloadedError` when shedding."""
        return self.plane.handle(req)

    def submit(self, req: Request):
        """Async entry point: a Future, or ServerOverloadedError at the
        door when the pending queue is past the high-water mark."""
        return self.plane.submit(req)

    # -- observability -------------------------------------------------------

    def health(self) -> Dict[str, object]:
        return self.plane.health()

    def metrics(self) -> Dict[str, float]:
        """One flat counter dict for /metrics: pool, coalescer, budget
        pool, segment cache, fetcher (transport + contrib + fault
        counters) — everything a dashboard needs to see a multi-tenant
        server breathe."""
        out = {f"serve_{k}": v for k, v in self.plane.metrics().items()}
        with self._sessions_mu:
            out["serve_sessions_sticky"] = float(len(self.sessions))
        if self.coalescer is not None:
            for k, v in self.coalescer.metrics().items():
                out[f"coalesce_{k}"] = v
        if self.contrib_pool is not None:
            for k, v in self.contrib_pool.metrics().items():
                out[f"pool_{k}"] = v
        if self.decode_batcher is not None:
            for k, v in self.decode_batcher.stats.as_dict().items():
                out[f"batch_{k}"] = v
        if self.cache is not None:
            cs = self.cache.stats
            out.update({
                "cache_hits_total": float(cs.hits),
                "cache_misses_total": float(cs.misses),
                "cache_insertions_total": float(cs.insertions),
                "cache_evictions_total": float(cs.evictions),
                "cache_floor_protected_total": float(cs.floor_protected),
                "cache_admission_skips_total": float(cs.admission_skips),
                "cache_resident_bytes": float(self.cache.nbytes),
            })
        fetcher = getattr(self.archive, "fetcher", None)
        if fetcher is not None:
            st = fetcher.stats
            out.update({
                "fetch_store_reads_total": float(st.store_reads),
                "fetch_cache_hits_total": float(st.cache_hits),
                "fetch_bytes_total": float(st.bytes_fetched),
                "fetch_demand_total": float(st.demand_fetches),
                "fetch_prefetch_hits_total": float(st.prefetch_hits),
                "fetch_retries_total": float(st.retries),
                "fetch_faults_absorbed_total": float(st.faults_absorbed),
                "fetch_quarantined_blobs_total": float(st.quarantined_blobs),
                "contrib_resident_bytes": float(st.contrib_resident_bytes),
                "contrib_peak_bytes": float(st.contrib_peak_bytes),
                "contrib_spills_total": float(st.contrib_spills),
                "contrib_recomputes_total": float(st.contrib_recomputes),
            })
        return out

    def close(self) -> None:
        """Drain the pool, release pooled leases, close the store."""
        self.plane.shutdown(wait=True)
        with self._sessions_mu:
            sessions, self.sessions = dict(self.sessions), {}
        for s in sessions.values():
            close = getattr(s, "close", None)
            if close is not None:
                close()
        if getattr(self.archive, "fetcher", None) is not None:
            self.archive.close()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1 << 15)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--method", default="hb")
    ap.add_argument("--store", default=None, metavar="PATH_OR_URL",
                    help="serve from an archive container: a .prs path "
                         "(refactor+save first if it does not exist), a "
                         "sharded directory, or an http(s):// URL")
    ap.add_argument("--shard-by", default=None,
                    choices=("variable", "group"),
                    help="when creating a missing --store, write a sharded "
                         "directory (one payload blob per variable / level "
                         "group) instead of a single file")
    ap.add_argument("--workers", type=int, default=8,
                    help="serve-plane worker threads (requests for "
                         "different clients run concurrently; 1 recovers "
                         "the sequential server)")
    ap.add_argument("--queue-depth", type=int, default=64,
                    help="max outstanding requests before the server sheds "
                         "load (503 + Retry-After past the high-water mark)")
    ap.add_argument("--pool-mb", type=float, default=None,
                    help="server-wide pooled contribution budget (MiB) "
                         "shared by ALL sessions — replaces --contrib-mb; "
                         "the hottest variables keep their recompose state "
                         "resident (default: off)")
    ap.add_argument("--batch-window-ms", type=float, default=None,
                    help="cross-session decode batching window (ms): fused "
                         "decode/recompose dispatches arriving within one "
                         "window merge into a single vmapped device call "
                         "(bit-identical results; default: off = one "
                         "dispatch per reader)")
    ap.add_argument("--cache-admission", action="store_true",
                    help="under cache pressure, skip inserting segments "
                         "colder than everything resident (deep-LSB churn "
                         "control) instead of evicting hot MSB prefixes")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="expose /health and /metrics (plaintext counters) "
                         "on this port")
    ap.add_argument("--cache-mb", type=int, default=256,
                    help="cross-session segment cache budget (MiB)")
    ap.add_argument("--cache-depth-weight", type=float, default=64.0,
                    help="segment-cache eviction bias: recency ticks an MSB "
                         "plane out-lives an LSB plane, per plane of depth "
                         "(0 = plain byte-LRU)")
    ap.add_argument("--archive-floor-mb", type=int, default=0,
                    help="per-archive residency floor (MiB) a hot archive "
                         "cannot evict another archive below")
    ap.add_argument("--contrib-mb", type=float, default=None,
                    help="per-variable contribution-cache budget (MiB) for "
                         "each session's bitplane readers; coarse-level "
                         "fields spill and are recomputed on demand "
                         "(default: unbounded; see --pool-mb for the "
                         "server-wide pooled alternative)")
    ap.add_argument("--retry-attempts", type=int, default=None,
                    help="max fetch attempts per segment, counting the "
                         "first try (default: RetryPolicy's 4; 1 disables "
                         "retries)")
    ap.add_argument("--retry-backoff-ms", type=float, default=None,
                    help="base of the exponential retry backoff, in ms "
                         "(full jitter, capped; default 50)")
    ap.add_argument("--fetch-deadline-s", type=float, default=None,
                    help="wall-clock budget for one segment fetch, all "
                         "attempts included (default 30)")
    ap.add_argument("--quarantine-after", type=int, default=None,
                    help="consecutive failures that quarantine a blob "
                         "(circuit breaker; default: 2x retry attempts)")
    ap.add_argument("--codecs", default=None, metavar="NAME[,NAME...]",
                    help="entropy-stage candidate codecs for refactoring "
                         "(e.g. 'zlib' pins the legacy stand-in; default: "
                         f"{','.join(plane_codecs.DEFAULT_CANDIDATES)}; "
                         "raw is always implied)")
    args = ap.parse_args(argv)
    if args.codecs is not None:
        plane_codecs.set_default_candidates(
            n for n in args.codecs.split(",") if n)

    fields = ge_like_fields(n=args.n, seed=0)
    contrib_budget = None if args.contrib_mb is None \
        else int(args.contrib_mb * (1 << 20))
    contrib_pool = None if args.pool_mb is None \
        else int(args.pool_mb * (1 << 20))
    retry_policy = None
    if (args.retry_attempts is not None or args.retry_backoff_ms is not None
            or args.fetch_deadline_s is not None):
        base = RetryPolicy()
        retry_policy = RetryPolicy(
            max_attempts=base.max_attempts if args.retry_attempts is None
            else max(1, args.retry_attempts),
            backoff_s=base.backoff_s if args.retry_backoff_ms is None
            else args.retry_backoff_ms / 1e3,
            deadline_s=base.deadline_s if args.fetch_deadline_s is None
            else args.fetch_deadline_s)
    quarantine = None if args.quarantine_after is None \
        else BlobQuarantine(threshold=max(1, args.quarantine_after))
    server = RetrievalServer(fields, method=args.method,
                             store_path=args.store, shard_by=args.shard_by,
                             cache_bytes=args.cache_mb << 20,
                             cache_depth_weight=args.cache_depth_weight,
                             archive_floor_bytes=args.archive_floor_mb << 20,
                             contrib_budget_bytes=contrib_budget,
                             retry_policy=retry_policy,
                             quarantine=quarantine,
                             workers=args.workers,
                             queue_depth=args.queue_depth,
                             contrib_pool_bytes=contrib_pool,
                             cache_admission=args.cache_admission,
                             decode_batch_ms=args.batch_window_ms)
    src = f"store {args.store}" if args.store else "in-memory archive"
    print(f"[server] {src} ready for {args.n} pts x5 vars in "
          f"{server.refactor_s:.2f}s "
          f"(archive {server.archive.total_nbytes / 2**20:.2f} MiB); "
          f"{args.workers} workers, queue depth {args.queue_depth}")
    if args.store:
        at_rest = server.archive.codec_bytes()
        print("[server] archive codecs: " + ", ".join(
            f"{name}={nb}B" for name, nb in
            sorted(at_rest.items(), key=lambda kv: -kv[1])))
    httpd = None
    if args.metrics_port is not None:
        root = args.store if args.store and not is_url(args.store) \
            and os.path.exists(args.store) \
            else tempfile.mkdtemp(prefix="repro-metrics-")
        httpd = StoreHTTPServer(os.path.abspath(root),
                                port=args.metrics_port,
                                metrics_source=server.metrics,
                                health_source=server.health).start()
        print(f"[server] /health + /metrics at {httpd.url}")

    rng = np.random.default_rng(0)
    clients = [f"client{i}" for i in range(4)]
    qoi_names = list(ge.all_qois())
    requests = [Request(client=str(rng.choice(clients)),
                        qois=list(rng.choice(qoi_names,
                                             size=rng.integers(1, 4),
                                             replace=False)),
                        tau=float(10.0 ** -rng.integers(1, 6)))
                for _ in range(args.requests)]
    # submit the whole stream through the worker pool, backing off when the
    # server sheds — the shape a well-behaved client fleet has
    futures = []
    for i, req in enumerate(requests):
        while True:
            try:
                futures.append((i, req, server.submit(req)))
                break
            except ServerOverloadedError as e:
                time.sleep(min(e.retry_after_s, 0.25))
    total_bytes = 0
    degraded_vars: Dict[str, object] = {}
    for i, req, fut in futures:
        out = fut.result()
        total_bytes += out["bytes_moved"]
        flag = " DEGRADED" if out["degraded"] else ""
        print(f"[req {i:02d}] {req.client} qois={','.join(req.qois):18s} "
              f"tau={req.tau:.0e} moved={out['bytes_moved']:>9d}B "
              f"lat={out['latency_s'] * 1e3:7.1f}ms ok={out['guaranteed']}"
              f"{flag}")
        if out["degraded"]:
            degraded_vars.update(out["availability"])
    raw = sum(v.nbytes for v in fields.values())
    print(f"[server] total moved {total_bytes / 2**20:.2f} MiB vs raw "
          f"{raw / 2**20:.2f} MiB ({total_bytes / raw:.0%})")
    pm = server.plane.metrics()
    print(f"[server] plane: {pm['requests_total']:.0f} requests on "
          f"{args.workers} workers, p50={pm['latency_p50_ms']:.1f}ms "
          f"p99={pm['latency_p99_ms']:.1f}ms, {pm['shed_total']:.0f} shed")
    if server.coalescer is not None:
        cm = server.coalescer.metrics()
        if cm["hits_total"]:
            print(f"[server] coalesce: {cm['hits_total']:.0f} duplicate "
                  f"requests shared {cm['leaders_total']:.0f} flights "
                  f"({cm['adoptions_total']:.0f} adoptions, "
                  f"{cm['fallbacks_total']:.0f} fallbacks)")
    if degraded_vars:
        print("[server] DEGRADED — some variables are pinned at the deepest "
              "available plane prefix; reported bounds stay certified:")
        for v, a in sorted(degraded_vars.items()):
            print(f"[server]   {v}: achievable eps floor={a.floor:.3e}"
                  + (f" ({a.detail})" if a.detail else ""))
    if args.store:
        fq = server.archive.fetcher
        st = fq.stats
        if st.retries or st.faults_absorbed or st.quarantined_blobs:
            print(f"[server] faults: {st.faults_absorbed} absorbed over "
                  f"{st.retries} retries, "
                  f"{st.quarantined_blobs} blob quarantine trips")
    if args.store:
        st = server.archive.fetcher.stats
        print(f"[server] store: {st.bytes_fetched} segment bytes fetched in "
              f"{st.store_reads} reads, "
              f"{st.demand_fetches} demand / {st.pipelined_hits} pipelined / "
              f"{st.prefetch_hits} predicted (hit rate {st.hit_rate:.0%}), "
              f"blocked {st.demand_wait_s * 1e3:.1f}ms")
        if st.codec_bytes:
            print("[server] wire codecs: " + ", ".join(
                f"{name}={nb}B" for name, nb in
                sorted(st.codec_bytes.items(), key=lambda kv: -kv[1])))
        if server.cache is not None:
            cs = server.cache.stats
            print(f"[server] cache: {st.cache_hits} segment reads served "
                  f"from RAM ({cs.hits} hits / {cs.misses} misses, "
                  f"{server.cache.nbytes / 2**20:.2f} MiB resident, "
                  f"{cs.evictions} evicted, "
                  f"{cs.floor_protected} floor-protected, "
                  f"{cs.admission_skips} admission-skipped)")
    if server.contrib_pool is not None:
        ps = server.contrib_pool.metrics()
        print(f"[server] contrib pool: "
              f"{ps['borrowed_bytes'] / 2**20:.2f} MiB borrowed "
              f"(peak {ps['peak_borrowed_bytes'] / 2**20:.2f} MiB) over "
              f"{ps['leases']:.0f} leases, {ps['denials_total']:.0f} denials"
              f", {ps['reclaims_total']:.0f} reclaims")
    if server.decode_batcher is not None:
        bs = server.decode_batcher.stats.as_dict()
        print(f"[server] decode batching: {bs['decode_items']:.0f} decode + "
              f"{bs['recompose_items']:.0f} recompose items in "
              f"{bs['decode_dispatches'] + bs['recompose_dispatches']:.0f} "
              f"dispatches ({bs['dispatch_ratio']:.1f} items/dispatch)")
    if args.contrib_mb is not None or args.pool_mb is not None:
        if args.store:
            cst = server.archive.fetcher.stats
        else:                       # in-memory sessions: one sink per reader
            cst = ContribStats()
            for s in server.sessions.values():
                cst.merge(s.contrib_stats())
        print(f"[server] contrib cache: "
              f"{cst.contrib_resident_bytes / 2**20:.2f} MiB resident "
              f"(peak {cst.contrib_peak_bytes / 2**20:.2f} MiB), "
              f"{cst.contrib_spills} spills, "
              f"{cst.contrib_recomputes} recomputes")
    if httpd is not None:
        httpd.stop()
    server.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
