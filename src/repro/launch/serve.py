"""Batched progressive-retrieval service — the paper's serving shape.

    PYTHONPATH=src python -m repro.launch.serve --requests 16
    PYTHONPATH=src python -m repro.launch.serve --store /data/ge.prs
    PYTHONPATH=src python -m repro.launch.serve --store /data/ge_dir --shard-by variable
    PYTHONPATH=src python -m repro.launch.serve --store http://host:8000/manifest.json

Simulates the production deployment of Fig 1: data is refactored once into
progressive archives ("storage"); a stream of analysis requests arrives,
each naming QoIs + tolerances; the server runs Algorithm 2 per session and
answers with guaranteed-error reconstructions. Sessions are sticky, so a
client tightening its tolerance pays only for the new segments (the
incremental-recomposition contract).

With ``--store`` the server serves from an archive container (repro.store)
instead of holding the refactored archive in RAM — a local ``.prs`` file
(refactored + saved on first run if missing), a sharded directory
(``--shard-by variable|group``), or an ``http(s)://`` URL of a container /
sharded manifest published by ``repro.store.httpd``.  Segments stream
checksum-verified through the SegmentFetcher (ranged reads + async
prefetch), and a cross-session `SegmentCache` sits under all client
sessions: planes one client already pulled are served from RAM to every
other client instead of re-fetched from the store.
"""
from __future__ import annotations

import argparse
import os
import time
from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from repro.bitplane import codecs as plane_codecs
from repro.core import ge
from repro.core.refactor import ContribStats, refactor_variables
from repro.core.retrieval import QoIRequest, retrieve_qoi_controlled
from repro.data.synthetic import ge_like_fields
from repro.store import (BlobQuarantine, RetryPolicy, SegmentCache,
                         open_archive, save_archive, save_sharded_archive)
from repro.store.container import is_url


@dataclass
class Request:
    client: str
    qois: List[str]
    tau: float


class RetrievalServer:
    """``contrib_budget_bytes`` caps each session's per-variable contribution
    cache (None = unbounded); ``cache_depth_weight`` / ``archive_floor_bytes``
    tune the cross-session SegmentCache's depth-weighted eviction and
    per-archive working-set floor (see repro.store.cache)."""

    def __init__(self, fields, method: str = "hb",
                 store_path: Optional[str] = None,
                 shard_by: Optional[str] = None,
                 cache_bytes: int = 256 << 20,
                 cache_depth_weight: float = 64.0,
                 archive_floor_bytes: int = 0,
                 contrib_budget_bytes: Optional[int] = None,
                 retry_policy: Optional[RetryPolicy] = None,
                 quarantine: Optional[BlobQuarantine] = None):
        t0 = time.time()
        self.cache: Optional[SegmentCache] = None
        self.contrib_budget_bytes = contrib_budget_bytes
        if store_path is not None:
            if not is_url(store_path) and not os.path.exists(store_path):
                if shard_by:
                    save_sharded_archive(
                        refactor_variables(fields, method=method),
                        store_path, shard_by=shard_by)
                else:
                    save_archive(refactor_variables(fields, method=method),
                                 store_path)
            self.cache = SegmentCache(max_bytes=cache_bytes,
                                      depth_weight=cache_depth_weight,
                                      archive_floor_bytes=archive_floor_bytes)
            self.archive = open_archive(store_path, cache=self.cache,
                                        retry_policy=retry_policy,
                                        quarantine=quarantine)
            shapes = {k: np.asarray(v).shape for k, v in fields.items()}
            if self.archive.method != method or self.archive.shapes != shapes:
                raise SystemExit(
                    f"store {store_path} holds method="
                    f"{self.archive.method!r} shapes="
                    f"{dict(self.archive.shapes)} but the server was asked "
                    f"for method={method!r} shapes={shapes} — delete the "
                    f"file to re-refactor, or match the flags")
        else:
            self.archive = refactor_variables(fields, method=method)
        self.sessions: Dict[str, object] = {}
        self.refactor_s = time.time() - t0
        self.qois = ge.all_qois()

    def handle(self, req: Request):
        if req.client not in self.sessions:
            self.sessions[req.client] = self.archive.open(
                contrib_budget_bytes=self.contrib_budget_bytes)
        session = self.sessions[req.client]
        before = session.bytes_retrieved
        reqs = [QoIRequest(q, self.qois[q], req.tau) for q in req.qois]
        t0 = time.time()
        res = retrieve_qoi_controlled(session, reqs)
        return {"client": req.client, "tau": req.tau,
                "bytes_moved": session.bytes_retrieved - before,
                "bitrate": res.bitrate, "latency_s": time.time() - t0,
                "guaranteed": res.converged,
                "est_errors": res.est_errors,
                "degraded": res.degraded,
                "availability": res.availability}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1 << 15)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--method", default="hb")
    ap.add_argument("--store", default=None, metavar="PATH_OR_URL",
                    help="serve from an archive container: a .prs path "
                         "(refactor+save first if it does not exist), a "
                         "sharded directory, or an http(s):// URL")
    ap.add_argument("--shard-by", default=None,
                    choices=("variable", "group"),
                    help="when creating a missing --store, write a sharded "
                         "directory (one payload blob per variable / level "
                         "group) instead of a single file")
    ap.add_argument("--cache-mb", type=int, default=256,
                    help="cross-session segment cache budget (MiB)")
    ap.add_argument("--cache-depth-weight", type=float, default=64.0,
                    help="segment-cache eviction bias: recency ticks an MSB "
                         "plane out-lives an LSB plane, per plane of depth "
                         "(0 = plain byte-LRU)")
    ap.add_argument("--archive-floor-mb", type=int, default=0,
                    help="per-archive residency floor (MiB) a hot archive "
                         "cannot evict another archive below")
    ap.add_argument("--contrib-mb", type=float, default=None,
                    help="per-variable contribution-cache budget (MiB) for "
                         "each session's bitplane readers; coarse-level "
                         "fields spill and are recomputed on demand "
                         "(default: unbounded)")
    ap.add_argument("--retry-attempts", type=int, default=None,
                    help="max fetch attempts per segment, counting the "
                         "first try (default: RetryPolicy's 4; 1 disables "
                         "retries)")
    ap.add_argument("--retry-backoff-ms", type=float, default=None,
                    help="base of the exponential retry backoff, in ms "
                         "(full jitter, capped; default 50)")
    ap.add_argument("--fetch-deadline-s", type=float, default=None,
                    help="wall-clock budget for one segment fetch, all "
                         "attempts included (default 30)")
    ap.add_argument("--quarantine-after", type=int, default=None,
                    help="consecutive failures that quarantine a blob "
                         "(circuit breaker; default: 2x retry attempts)")
    ap.add_argument("--codecs", default=None, metavar="NAME[,NAME...]",
                    help="entropy-stage candidate codecs for refactoring "
                         "(e.g. 'zlib' pins the legacy stand-in; default: "
                         f"{','.join(plane_codecs.DEFAULT_CANDIDATES)}; "
                         "raw is always implied)")
    args = ap.parse_args(argv)
    if args.codecs is not None:
        plane_codecs.set_default_candidates(
            n for n in args.codecs.split(",") if n)

    fields = ge_like_fields(n=args.n, seed=0)
    contrib_budget = None if args.contrib_mb is None \
        else int(args.contrib_mb * (1 << 20))
    retry_policy = None
    if (args.retry_attempts is not None or args.retry_backoff_ms is not None
            or args.fetch_deadline_s is not None):
        base = RetryPolicy()
        retry_policy = RetryPolicy(
            max_attempts=base.max_attempts if args.retry_attempts is None
            else max(1, args.retry_attempts),
            backoff_s=base.backoff_s if args.retry_backoff_ms is None
            else args.retry_backoff_ms / 1e3,
            deadline_s=base.deadline_s if args.fetch_deadline_s is None
            else args.fetch_deadline_s)
    quarantine = None if args.quarantine_after is None \
        else BlobQuarantine(threshold=max(1, args.quarantine_after))
    server = RetrievalServer(fields, method=args.method,
                             store_path=args.store, shard_by=args.shard_by,
                             cache_bytes=args.cache_mb << 20,
                             cache_depth_weight=args.cache_depth_weight,
                             archive_floor_bytes=args.archive_floor_mb << 20,
                             contrib_budget_bytes=contrib_budget,
                             retry_policy=retry_policy,
                             quarantine=quarantine)
    src = f"store {args.store}" if args.store else "in-memory archive"
    print(f"[server] {src} ready for {args.n} pts x5 vars in "
          f"{server.refactor_s:.2f}s "
          f"(archive {server.archive.total_nbytes / 2**20:.2f} MiB)")
    if args.store:
        at_rest = server.archive.codec_bytes()
        print("[server] archive codecs: " + ", ".join(
            f"{name}={nb}B" for name, nb in
            sorted(at_rest.items(), key=lambda kv: -kv[1])))

    rng = np.random.default_rng(0)
    clients = [f"client{i}" for i in range(4)]
    qoi_names = list(ge.all_qois())
    total_bytes = 0
    degraded_vars: Dict[str, object] = {}
    for i in range(args.requests):
        req = Request(client=str(rng.choice(clients)),
                      qois=list(rng.choice(qoi_names,
                                           size=rng.integers(1, 4),
                                           replace=False)),
                      tau=float(10.0 ** -rng.integers(1, 6)))
        out = server.handle(req)
        total_bytes += out["bytes_moved"]
        flag = " DEGRADED" if out["degraded"] else ""
        print(f"[req {i:02d}] {req.client} qois={','.join(req.qois):18s} "
              f"tau={req.tau:.0e} moved={out['bytes_moved']:>9d}B "
              f"lat={out['latency_s'] * 1e3:7.1f}ms ok={out['guaranteed']}"
              f"{flag}")
        if out["degraded"]:
            degraded_vars.update(out["availability"])
    raw = sum(v.nbytes for v in fields.values())
    print(f"[server] total moved {total_bytes / 2**20:.2f} MiB vs raw "
          f"{raw / 2**20:.2f} MiB ({total_bytes / raw:.0%})")
    if degraded_vars:
        print("[server] DEGRADED — some variables are pinned at the deepest "
              "available plane prefix; reported bounds stay certified:")
        for v, a in sorted(degraded_vars.items()):
            print(f"[server]   {v}: achievable eps floor={a.floor:.3e}"
                  + (f" ({a.detail})" if a.detail else ""))
    if args.store:
        fq = server.archive.fetcher
        st = fq.stats
        if st.retries or st.faults_absorbed or st.quarantined_blobs:
            print(f"[server] faults: {st.faults_absorbed} absorbed over "
                  f"{st.retries} retries, "
                  f"{st.quarantined_blobs} blob quarantine trips")
    if args.store:
        st = server.archive.fetcher.stats
        print(f"[server] store: {st.bytes_fetched} segment bytes fetched in "
              f"{st.store_reads} reads, "
              f"{st.demand_fetches} demand / {st.pipelined_hits} pipelined / "
              f"{st.prefetch_hits} predicted (hit rate {st.hit_rate:.0%}), "
              f"blocked {st.demand_wait_s * 1e3:.1f}ms")
        if st.codec_bytes:
            print("[server] wire codecs: " + ", ".join(
                f"{name}={nb}B" for name, nb in
                sorted(st.codec_bytes.items(), key=lambda kv: -kv[1])))
        if server.cache is not None:
            cs = server.cache.stats
            print(f"[server] cache: {st.cache_hits} segment reads served "
                  f"from RAM ({cs.hits} hits / {cs.misses} misses, "
                  f"{server.cache.nbytes / 2**20:.2f} MiB resident, "
                  f"{cs.evictions} evicted, "
                  f"{cs.floor_protected} floor-protected)")
    if args.contrib_mb is not None:
        if args.store:
            cst = server.archive.fetcher.stats
        else:                       # in-memory sessions: one sink per reader
            cst = ContribStats()
            for s in server.sessions.values():
                cst.merge(s.contrib_stats())
        print(f"[server] contrib cache: "
              f"{cst.contrib_resident_bytes / 2**20:.2f} MiB resident "
              f"(peak {cst.contrib_peak_bytes / 2**20:.2f} MiB), "
              f"{cst.contrib_spills} spills, "
              f"{cst.contrib_recomputes} recomputes")
    if args.store:
        server.archive.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
