"""Batched progressive-retrieval service — the paper's serving shape.

    PYTHONPATH=src python -m repro.launch.serve --requests 16
    PYTHONPATH=src python -m repro.launch.serve --store /data/ge.prs

Simulates the production deployment of Fig 1: data is refactored once into
progressive archives ("storage"); a stream of analysis requests arrives,
each naming QoIs + tolerances; the server runs Algorithm 2 per session and
answers with guaranteed-error reconstructions. Sessions are sticky, so a
client tightening its tolerance pays only for the new segments (the
incremental-recomposition contract).

With ``--store PATH`` the server serves from an on-disk archive container
(repro.store): if PATH is missing it refactors once and saves it, then — in
either case — reopens the container and streams checksum-verified segments
through the SegmentFetcher (mmap'd range reads + async prefetch) instead of
holding the refactored archive in RAM.
"""
from __future__ import annotations

import argparse
import os
import time
from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from repro.core import ge
from repro.core.refactor import refactor_variables
from repro.core.retrieval import QoIRequest, retrieve_qoi_controlled
from repro.data.synthetic import ge_like_fields
from repro.store import open_archive, save_archive


@dataclass
class Request:
    client: str
    qois: List[str]
    tau: float


class RetrievalServer:
    def __init__(self, fields, method: str = "hb",
                 store_path: Optional[str] = None):
        t0 = time.time()
        if store_path is not None:
            if not os.path.exists(store_path):
                save_archive(refactor_variables(fields, method=method),
                             store_path)
            self.archive = open_archive(store_path)
            shapes = {k: np.asarray(v).shape for k, v in fields.items()}
            if self.archive.method != method or self.archive.shapes != shapes:
                raise SystemExit(
                    f"store {store_path} holds method="
                    f"{self.archive.method!r} shapes="
                    f"{dict(self.archive.shapes)} but the server was asked "
                    f"for method={method!r} shapes={shapes} — delete the "
                    f"file to re-refactor, or match the flags")
        else:
            self.archive = refactor_variables(fields, method=method)
        self.sessions: Dict[str, object] = {}
        self.refactor_s = time.time() - t0
        self.qois = ge.all_qois()

    def handle(self, req: Request):
        if req.client not in self.sessions:
            self.sessions[req.client] = self.archive.open()
        session = self.sessions[req.client]
        before = session.bytes_retrieved
        reqs = [QoIRequest(q, self.qois[q], req.tau) for q in req.qois]
        t0 = time.time()
        res = retrieve_qoi_controlled(session, reqs)
        return {"client": req.client, "tau": req.tau,
                "bytes_moved": session.bytes_retrieved - before,
                "bitrate": res.bitrate, "latency_s": time.time() - t0,
                "guaranteed": res.converged,
                "est_errors": res.est_errors}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1 << 15)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--method", default="hb")
    ap.add_argument("--store", default=None, metavar="PATH",
                    help="serve from an archive container at PATH "
                         "(refactor+save first if it does not exist)")
    args = ap.parse_args(argv)

    fields = ge_like_fields(n=args.n, seed=0)
    server = RetrievalServer(fields, method=args.method,
                             store_path=args.store)
    src = f"store {args.store}" if args.store else "in-memory archive"
    print(f"[server] {src} ready for {args.n} pts x5 vars in "
          f"{server.refactor_s:.2f}s "
          f"(archive {server.archive.total_nbytes / 2**20:.2f} MiB)")

    rng = np.random.default_rng(0)
    clients = [f"client{i}" for i in range(4)]
    qoi_names = list(ge.all_qois())
    total_bytes = 0
    for i in range(args.requests):
        req = Request(client=str(rng.choice(clients)),
                      qois=list(rng.choice(qoi_names,
                                           size=rng.integers(1, 4),
                                           replace=False)),
                      tau=float(10.0 ** -rng.integers(1, 6)))
        out = server.handle(req)
        total_bytes += out["bytes_moved"]
        print(f"[req {i:02d}] {req.client} qois={','.join(req.qois):18s} "
              f"tau={req.tau:.0e} moved={out['bytes_moved']:>9d}B "
              f"lat={out['latency_s'] * 1e3:7.1f}ms ok={out['guaranteed']}")
    raw = sum(v.nbytes for v in fields.values())
    print(f"[server] total moved {total_bytes / 2**20:.2f} MiB vs raw "
          f"{raw / 2**20:.2f} MiB ({total_bytes / raw:.0%})")
    if args.store:
        st = server.archive.fetcher.stats
        print(f"[server] store: {st.bytes_fetched} segment bytes fetched, "
              f"{st.demand_fetches} demand / {st.pipelined_hits} pipelined / "
              f"{st.prefetch_hits} predicted (hit rate {st.hit_rate:.0%}), "
              f"blocked {st.demand_wait_s * 1e3:.1f}ms")
        server.archive.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
