import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first lines: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (architecture × input shape ×
mesh) cell and dump memory / cost / collective statistics for the roofline.

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-14b \
      --shape train_4k --mesh single            # one cell
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out results/dryrun                      # the full 40-cell matrix

Each cell produces JSON: per-device HLO flops / bytes (cost_analysis),
per-device argument/output/temp bytes (memory_analysis), and per-device
collective bytes by op kind parsed from the post-SPMD optimized HLO.
Results are cached by (arch, shape, mesh, tag) — reruns skip built cells.
"""
import argparse
import json
import re
import sys
import time
import traceback
from typing import Any, Dict

import jax
import numpy as np
from jax.sharding import NamedSharding

from repro import configs
from repro.data.batches import decode_token_spec, train_input_specs
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.models.config import SHAPES, cell_is_runnable
from repro.train.sharding import (
    batch_pspecs, decode_state_pspecs, opt_state_pspecs,
    param_pspecs, sanitize_pspecs,
)
from repro.train.train_step import make_serve_step, make_train_step

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[16,256,320]' -> bytes."""
    m = _SHAPE_RE.match(shape_str.strip())
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def parse_collective_bytes(hlo: str) -> Dict[str, Any]:
    """Sum the output-shape bytes of every collective op in post-SPMD HLO.
    Shapes in the partitioned module are PER-DEVICE."""
    out: Dict[str, Any] = {k: {"count": 0, "bytes": 0} for k in COLLECTIVE_OPS}
    # lines look like:  %x = bf16[8,128]{1,0} all-reduce(...), replica_groups=
    pat = re.compile(
        r"=\s+((?:\([^)]*\))|(?:\S+))\s+(" + "|".join(COLLECTIVE_OPS) +
        r")(?:-start|-done)?\(")
    for line in hlo.splitlines():
        m = pat.search(line)
        if not m:
            continue
        shape_str, kind = m.groups()
        if kind + "-done" in line and "-start" not in line:
            continue  # avoid double counting start/done pairs
        total = 0
        if shape_str.startswith("("):
            for part in shape_str.strip("()").split(", "):
                total += _shape_bytes(part)
        else:
            total += _shape_bytes(shape_str)
        out[kind]["count"] += 1
        out[kind]["bytes"] += total
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


def _shardings(mesh, pspecs):
    return jax.tree.map(lambda ps: NamedSharding(mesh, ps), pspecs)


def lower_cell(arch: str, shape_name: str, mesh, *,
               moe_dispatch: str = "scatter") -> Dict[str, Any]:
    """Lower + compile one (arch, shape) on a mesh; return stats dict."""
    cfg = configs.get(arch)
    if cfg.n_experts and moe_dispatch != cfg.moe_dispatch:
        cfg = cfg.replace(moe_dispatch=moe_dispatch)
    shape = SHAPES[shape_name]
    runnable, why = cell_is_runnable(cfg, shape)
    if not runnable:
        return {"status": "skipped", "reason": why}
    from repro.models import dist
    dist.set_mesh(mesh)   # model-internal sharding hints (models/dist.py)

    key = jax.random.PRNGKey(0)
    t0 = time.time()
    if shape.kind in ("train", "prefill"):
        if shape.kind == "train" and \
                cfg.n_kv_heads % int(mesh.shape["model"]) != 0:
            cfg = cfg.replace(attn_param_replication=True)  # §Perf
        params_shape = jax.eval_shape(lambda k: T.init_params(k, cfg), key)
        pspecs = param_pspecs(cfg, params_shape, mesh)
        if shape.kind == "train":
            opt_init, step = make_train_step(cfg)
            opt_shape = jax.eval_shape(opt_init, params_shape)
            ospecs = opt_state_pspecs(cfg, opt_shape, pspecs)
            bspecs = {k: batch_pspecs(cfg, mesh)[k]
                      for k in train_input_specs(cfg, shape)}
            jitted = jax.jit(step, in_shardings=(
                _shardings(mesh, pspecs), _shardings(mesh, ospecs),
                _shardings(mesh, bspecs)))
            args = (params_shape, opt_shape, train_input_specs(cfg, shape))
        else:  # prefill: forward only
            def prefill(params, batch):
                return T.forward(params, cfg, batch)[0]
            bspecs = {k: batch_pspecs(cfg, mesh)[k]
                      for k in train_input_specs(cfg, shape)}
            jitted = jax.jit(prefill, in_shardings=(
                _shardings(mesh, pspecs), _shardings(mesh, bspecs)))
            args = (params_shape, train_input_specs(cfg, shape))
    else:  # decode
        # serving shards params model-only when they fit (FSDP's data-dim
        # weight sharding exists for optimizer memory, which decode doesn't
        # have — keeping it would gather weights inside the layer loop every
        # token, §Perf). The ~0.8T llama4 keeps FSDP: 1.55 TB of bf16
        # weights / 16 model shards would not fit a 16 GB chip.
        if cfg.fsdp:
            from repro.launch.analytic import param_counts
            per_chip = param_counts(cfg)["total"] * 2 / 16
            if per_chip < 12e9:
                cfg = cfg.replace(fsdp=False)
        params_shape = jax.eval_shape(lambda k: T.init_params(k, cfg), key)
        pspecs = param_pspecs(cfg, params_shape, mesh)
        state_shape = jax.eval_shape(
            lambda: T.init_decode_state(cfg, shape.global_batch,
                                        shape.seq_len))
        sspecs = {k: decode_state_pspecs(cfg, mesh)[k] for k in state_shape}
        sspecs = sanitize_pspecs(sspecs, state_shape, mesh)
        token_spec = decode_token_spec(cfg, shape)
        tspec = sanitize_pspecs(batch_pspecs(cfg, mesh)["tokens"],
                                token_spec, mesh)
        serve = make_serve_step(cfg)
        jitted = jax.jit(serve, in_shardings=(
            _shardings(mesh, pspecs), _shardings(mesh, sspecs),
            NamedSharding(mesh, tspec)))
        args = (params_shape, state_shape, token_spec)

    with mesh:
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1

    stats: Dict[str, Any] = {
        "status": "ok", "arch": arch, "shape": shape_name,
        "kind": shape.kind, "mesh": list(mesh.devices.shape),
        "n_devices": int(np.prod(mesh.devices.shape)),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
    }
    try:
        ma = compiled.memory_analysis()
        stats["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
        }
    except Exception as e:  # pragma: no cover
        stats["memory"] = {"error": str(e)}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        stats["cost"] = {"flops": float(ca.get("flops", -1)),
                         "bytes_accessed": float(ca.get("bytes accessed", -1))}
    except Exception as e:  # pragma: no cover
        stats["cost"] = {"error": str(e)}
    hlo = compiled.as_text()
    h = analyze_hlo(hlo)   # trip-count-aware (see hlo_analysis.py)
    stats["hlo"] = {
        "dot_flops": h.flops,
        "memory_bytes_proxy": h.memory_bytes,
        "collective_bytes": h.collective_bytes,
        "collectives": {k: v for k, v in h.collectives.items()
                        if v["count"]},
        "n_dots": h.n_dots,
        "n_collectives": h.n_collectives,
    }
    stats["collectives"] = parse_collective_bytes(hlo)  # raw (untripped)
    stats["hlo_bytes"] = len(hlo)
    return stats


def cell_key(arch: str, shape: str, mesh_name: str, tag: str = "") -> str:
    return f"{arch}__{shape}__{mesh_name}" + (f"__{tag}" if tag else "")


def run_cells(archs, shapes, mesh_names, out_path: str, tag: str = "",
              moe_dispatch: str = "scatter", force: bool = False):
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    results: Dict[str, Any] = {}
    if os.path.exists(out_path):
        with open(out_path) as f:
            results = json.load(f)
    meshes = {}
    for mn in mesh_names:
        meshes[mn] = make_production_mesh(multi_pod=(mn == "multipod"))
    for arch in archs:
        for shape in shapes:
            for mn in mesh_names:
                keyname = cell_key(arch, shape, mn, tag)
                if not force and keyname in results and \
                        results[keyname].get("status") in ("ok", "skipped"):
                    print(f"[cache] {keyname}")
                    continue
                print(f"[run]   {keyname} ...", flush=True)
                try:
                    stats = lower_cell(arch, shape, meshes[mn],
                                       moe_dispatch=moe_dispatch)
                except Exception as e:
                    stats = {"status": "error", "error": str(e),
                             "traceback": traceback.format_exc()[-2000:]}
                    print(f"[ERROR] {keyname}: {e}")
                results[keyname] = stats
                with open(out_path, "w") as f:
                    json.dump(results, f, indent=1)
                if stats.get("status") == "ok":
                    print(f"[ok]    {keyname} compile={stats['compile_s']}s "
                          f"dotflops/dev={stats['hlo']['dot_flops']:.3e} "
                          f"coll/dev={stats['hlo']['collective_bytes']:.3e}B")
                elif stats.get("status") == "skipped":
                    print(f"[skip]  {keyname}: {stats['reason']}")
    return results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multipod", "both"])
    ap.add_argument("--all", action="store_true",
                    help="run the full arch × shape matrix")
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--tag", default="")
    ap.add_argument("--moe-dispatch", default="scatter",
                    choices=["scatter", "onehot", "sort"])
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    archs = configs.names() if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    mesh_names = {"single": ["single"], "multipod": ["multipod"],
                  "both": ["single", "multipod"]}[args.mesh]
    results = run_cells(archs, shapes, mesh_names, args.out, tag=args.tag,
                        moe_dispatch=args.moe_dispatch, force=args.force)
    bad = {k: v for k, v in results.items() if v.get("status") == "error"}
    print(f"\n{len(results)} cells recorded, {len(bad)} errors")
    for k in bad:
        print(f"  ERROR {k}: {bad[k]['error'][:200]}")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
