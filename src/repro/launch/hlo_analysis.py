"""Post-SPMD HLO analysis: trip-count-aware FLOPs, bytes, and collectives.

XLA:CPU's ``compiled.cost_analysis()`` counts while-loop bodies ONCE,
ignoring trip counts — with scan-over-layers that undercounts a 48-layer
model by ~48x. This module parses the optimized HLO text instead:

  * builds the computation call graph (while bodies/conditions, fusions,
    calls, conditional branches) with multipliers from the
    ``known_trip_count`` backend configs XLA attaches to canonical loops;
  * FLOPs: every ``dot`` contributes 2·prod(output)·prod(contracted dims),
    scaled by its computation's total trip multiplier (convolutions are not
    emitted by this codebase — conv1d is expressed as shifted multiplies);
  * collective bytes: output-shape bytes of every all-reduce / all-gather /
    reduce-scatter / all-to-all / collective-permute, trip-scaled (shapes in
    the partitioned module are per-device);
  * memory traffic estimate: Σ output bytes over compute instructions
    (bookkeeping ops excluded), trip-scaled — a written-bytes proxy that is
    consistent across cells and optimisation steps.

Everything is per-device (the partitioned module is the per-device program).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
}

_BOOKKEEPING = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "copy", "copy-start", "copy-done", "after-all", "partition-id",
    "replica-id", "iota", "broadcast", "reshape",
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
# `%name = shape op-name(operands...), attrs` — shape may be a tuple with
# /*index=N*/ comments, so match lazily up to the op name before a '('
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s+=\s+(.*?)\s*([\w\-]+)\(")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")


def _parse_shape(s: str) -> Tuple[Optional[str], List[int]]:
    m = _SHAPE_RE.match(s.strip().lstrip("("))
    if not m:
        return None, []
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",")] if dims else []


def _split_operands(s: str) -> List[str]:
    """Split an HLO operand list on top-level commas only — operands carry
    inline shapes like ``f32[64,64]{1,0} %name``, so a naive split breaks
    inside the brackets."""
    out, depth, start = [], 0, 0
    for i, ch in enumerate(s):
        if ch in "[{(":
            depth += 1
        elif ch in "]})":
            depth -= 1
        elif ch == "," and depth == 0:
            out.append(s[start:i])
            start = i + 1
    out.append(s[start:])
    return [o.strip() for o in out if o.strip()]


def _shape_bytes(s: str) -> int:
    """bytes of a shape string; tuples sum their elements."""
    total = 0
    for m in _SHAPE_RE.finditer(s):
        dt, dims = m.groups()
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


@dataclass
class Instr:
    name: str
    shape: str
    op: str
    line: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    is_entry: bool = False


def _split_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        if not line.strip():
            continue
        if not line.startswith(" ") and ("->" in line) and line.rstrip().endswith("{"):
            m = _COMP_HDR_RE.match(line.strip())
            if m:
                cur = Computation(name=m.group(1),
                                  is_entry=line.strip().startswith("ENTRY"))
                comps[cur.name] = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if m:
            name, shape, op = m.groups()
            cur.instrs.append(Instr(name=name, shape=shape, op=op, line=line))
    return comps


def _build_multipliers(comps: Dict[str, Computation]) -> Dict[str, float]:
    """Total execution multiplier per computation, from ENTRY down."""
    edges: Dict[str, List[Tuple[str, float]]] = {c: [] for c in comps}
    trip_re = re.compile(r'known_trip_count\\?":\{\\?"n\\?":\\?"(\d+)')
    for cname, comp in comps.items():
        for ins in comp.instrs:
            line = ins.line
            if ins.op == "while":
                trip = 1.0
                tm = trip_re.search(line)
                if tm:
                    trip = float(tm.group(1))
                for key in ("body=", "condition="):
                    m = re.search(key + r"%?([\w\.\-]+)", line)
                    if m and m.group(1) in comps:
                        edges[cname].append((m.group(1), trip))
            else:
                for key in ("calls=", "to_apply="):
                    m = re.search(key + r"%?([\w\.\-]+)", line)
                    if m and m.group(1) in comps:
                        edges[cname].append((m.group(1), 1.0))
                m = re.search(r"branch_computations=\{([^}]*)\}", line)
                if m:
                    for b in m.group(1).split(","):
                        b = b.strip().lstrip("%")
                        if b in comps:
                            edges[cname].append((b, 1.0))

    # HLO computation graphs are DAGs (no recursion). Propagate multipliers
    # from ENTRY; a computation referenced from several sites takes the
    # dominant path (XLA clones computations per call site, so collisions
    # are rare — max avoids double counting shared helpers).
    mult: Dict[str, float] = {c: 0.0 for c in comps}
    entry = next((c for c, comp in comps.items() if comp.is_entry), None)
    if entry is None:
        entry = next(iter(comps))
    mult[entry] = 1.0
    for _ in range(len(comps)):
        progressed = False
        for cname in comps:
            if mult[cname] == 0.0:
                continue
            for child, w in edges[cname]:
                want = mult[cname] * w
                if want > mult[child]:
                    mult[child] = want
                    progressed = True
        if not progressed:
            break
    return mult


@dataclass
class HloStats:
    flops: float
    dot_flops: float
    memory_bytes: float
    collectives: Dict[str, Dict[str, float]]
    collective_bytes: float
    n_dots: int
    n_collectives: int


def analyze_hlo(hlo: str) -> HloStats:
    comps = _split_computations(hlo)
    mult = _build_multipliers(comps)
    name_shape: Dict[str, str] = {}
    for comp in comps.values():
        for ins in comp.instrs:
            name_shape[ins.name] = ins.shape

    dot_flops = 0.0
    mem_bytes = 0.0
    colls = {k: {"count": 0.0, "bytes": 0.0} for k in COLLECTIVE_OPS}
    n_dots = 0

    operand_re = re.compile(r"\(([^)]*)\)")
    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m == 0.0:
            continue
        for ins in comp.instrs:
            if ins.op == "dot":
                _, out_dims = _parse_shape(ins.shape)
                out_prod = 1
                for d in out_dims:
                    out_prod *= d
                # contracted size from the lhs operand's shape: modern HLO
                # prints it inline (``dot(f32[64,64]{1,0} %lhs, ...)``);
                # fall back to the defining instruction's shape otherwise
                ops_m = operand_re.search(ins.line[ins.line.find("dot("):])
                contract = 1
                lm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
                if ops_m and lm and lm.group(1):
                    lhs = _split_operands(ops_m.group(1))[0]
                    _, lhs_dims = _parse_shape(lhs)
                    if not lhs_dims:
                        lhs_name = lhs.split()[-1].lstrip("%")
                        _, lhs_dims = _parse_shape(name_shape.get(lhs_name, ""))
                    for idx in lm.group(1).split(","):
                        i = int(idx)
                        if i < len(lhs_dims):
                            contract *= lhs_dims[i]
                dot_flops += m * 2.0 * out_prod * contract
                n_dots += 1
            base_op = ins.op
            if base_op.endswith("-start"):
                base_op = base_op[:-6]
            if base_op in COLLECTIVE_OPS:
                colls[base_op]["count"] += m
                colls[base_op]["bytes"] += m * _shape_bytes(ins.shape)
            if ins.op not in _BOOKKEEPING and not ins.op.endswith("-done"):
                mem_bytes += m * _shape_bytes(ins.shape)

    total_coll = sum(v["bytes"] for v in colls.values())
    n_coll = int(sum(v["count"] for v in colls.values()))
    return HloStats(flops=dot_flops, dot_flops=dot_flops,
                    memory_bytes=mem_bytes, collectives=colls,
                    collective_bytes=total_coll, n_dots=n_dots,
                    n_collectives=n_coll)
