import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")
"""Dry-run of the gradient-synchronisation collective, uncompressed vs
bitplane-compressed (§Perf hillclimb cell 3 — the paper's technique on the
collective path).

Lowers a shard_map program over the production mesh's "data" axis that
psums one full gradient pytree for the given arch, and counts per-device
collective bytes in the compiled HLO — once with f32 gradients, once with
top-k-bitplane integer codes (error feedback carried).

    PYTHONPATH=src python -m repro.launch.grad_sync_dryrun \
        --arch internlm2-1.8b --k 4 8
"""
import argparse
import sys

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:
    from jax.experimental.shard_map import shard_map
except ImportError:  # pragma: no cover
    shard_map = jax.shard_map

from repro import configs
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.train.grad_compress import compressed_psum


def lower_grad_sync(arch: str, k_planes: int = 0):
    """Returns per-device collective bytes of one gradient sync."""
    mesh = make_production_mesh()
    cfg = configs.get(arch)
    params_shape = jax.eval_shape(lambda key: T.init_params(key, cfg),
                                  jax.random.PRNGKey(0))
    grads_shape = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params_shape)
    n_data = int(mesh.shape["data"])

    if k_planes == 0:
        def sync(grads):
            return jax.tree.map(
                lambda g: jax.lax.psum(g, "data") / n_data, grads)
        args = (grads_shape,)
    else:
        def sync(grads, fb):
            return compressed_psum(grads, fb, k_planes, "data",
                                   n_ranks=n_data)
        args = (grads_shape, grads_shape)

    # grads replicated over "model" (each model shard owns its slice; the
    # data-axis sync is what we're measuring), sharded over nothing else:
    specs = jax.tree.map(lambda _: P(), grads_shape)
    smapped = shard_map(sync, mesh=mesh,
                        in_specs=tuple(specs for _ in args),
                        out_specs=specs if k_planes == 0
                        else (specs, specs),
                        check_rep=False)
    with mesh:
        compiled = jax.jit(smapped).lower(*args).compile()
    st = analyze_hlo(compiled.as_text())
    return st


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--k", type=int, nargs="*", default=[8, 4])
    args = ap.parse_args(argv)
    base = lower_grad_sync(args.arch, 0)
    print(f"{args.arch} grad sync, f32 baseline: "
          f"{base.collective_bytes:.4e} B/dev")
    for k in args.k:
        st = lower_grad_sync(args.arch, k)
        print(f"  k={k:2d} bitplanes: {st.collective_bytes:.4e} B/dev "
              f"({base.collective_bytes / st.collective_bytes:.2f}x fewer)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
