"""Analytic FLOPs / HBM-traffic model per (arch × shape) cell.

Two uses in the roofline (EXPERIMENTS.md §Roofline):
  * MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) — the "useful" flops;
    the ratio MODEL_FLOPS / HLO_dot_flops exposes remat/attention/dispatch
    overheads in the compiled program.
  * memory term: the HLO output-bytes proxy from hlo_analysis.py counts
    every instruction output — on TPU most elementwise chains fuse, so that
    proxy overstates HBM traffic badly. This module provides the standard
    napkin model instead: weights/optimizer traffic + activation
    checkpoint traffic + logits + KV-cache traffic, per device.

Parameter counts are EXACT (jax.eval_shape over init_params); only the
traffic model is analytic.
"""
from __future__ import annotations

from typing import Dict

import jax
import numpy as np

from repro.models import transformer as T
from repro.models.config import ModelConfig, ShapeSpec

_BYTES = {"bfloat16": 2, "float32": 4, "float16": 2}


def param_counts(cfg: ModelConfig) -> Dict[str, float]:
    """Exact parameter counts: total, embedding, expert, active."""
    shapes = jax.eval_shape(lambda k: T.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    total = 0
    embed = 0
    expert = 0
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    for kp, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        n = int(np.prod(leaf.shape))
        total += n
        if "embed/table" in path or path.endswith("lm_head"):
            embed += n
        if "/moe/" in path and ("wg" in path or "wu" in path or "wd" in path) \
                and "shared" not in path:
            expert += n
    active = total - embed - expert
    if cfg.n_experts:
        active += expert * cfg.top_k / cfg.n_experts
    # lm_head matmul does participate per token
    head = cfg.d_model * cfg.vocab
    return {"total": float(total), "embed": float(embed),
            "expert": float(expert), "active": float(active),
            "head": float(head)}


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """6·N_active·D + lm_head (decode counts one token per sequence)."""
    counts = param_counts(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        mult = 6.0
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mult = 2.0
    else:  # decode: one token per sequence per step
        tokens = shape.global_batch
        mult = 2.0
    return mult * (counts["active"] + counts["head"]) * tokens


def attention_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """Quadratic attention term (full-T computation incl. causal waste)."""
    if cfg.family == "ssm":
        # SSD: intra-chunk quadratic + state updates
        q = cfg.ssm_chunk
        if shape.kind == "decode":
            return 2.0 * shape.global_batch * cfg.n_layers * \
                cfg.ssm_heads * cfg.ssm_state * cfg.ssm_headdim * 3
        tokens = shape.global_batch * shape.seq_len
        per_tok = 2 * q * cfg.ssm_heads * cfg.ssm_headdim \
            + 4 * cfg.ssm_heads * cfg.ssm_state * cfg.ssm_headdim
        f = tokens * cfg.n_layers * per_tok
        return f * (3 if shape.kind == "train" else 1)
    n_attn_layers = cfg.n_layers if cfg.family != "hybrid" else \
        (cfg.n_layers // max(cfg.shared_attn_period, 1))
    if cfg.family == "encdec":
        n_attn_layers = cfg.n_layers * 2 + cfg.n_encoder_layers
    hd, h = cfg.hd, max(cfg.n_heads, 1)
    if shape.kind == "decode":
        # one token attends to the full cache
        f = 4.0 * shape.global_batch * shape.seq_len * h * hd * n_attn_layers
        if cfg.family == "hybrid":
            f += 2.0 * shape.global_batch * cfg.n_layers * \
                cfg.ssm_heads * cfg.ssm_state * cfg.ssm_headdim * 3
        return f
    tokens = shape.global_batch * shape.seq_len
    f = 4.0 * tokens * shape.seq_len * h * hd * n_attn_layers
    if cfg.family in ("hybrid",):
        q = cfg.ssm_chunk
        per_tok = 2 * q * cfg.ssm_heads * cfg.ssm_headdim \
            + 4 * cfg.ssm_heads * cfg.ssm_state * cfg.ssm_headdim
        f += tokens * cfg.n_layers * per_tok
    return f * (3 if shape.kind == "train" else 1)


def hbm_bytes(cfg: ModelConfig, shape: ShapeSpec, n_devices: int,
              kv_cache_gb: float = 0.0) -> Dict[str, float]:
    """Per-device HBM traffic model for one step."""
    counts = param_counts(cfg)
    wbytes = _BYTES.get(cfg.param_dtype, 2)
    p_dev = counts["total"] * wbytes / n_devices
    d = cfg.d_model
    out: Dict[str, float] = {}
    if shape.kind == "train":
        # weights: fwd read + remat re-read + bwd read; grads write+read;
        # optimizer: m,v read+write (f32) + param write
        opt_mult = 16 if cfg.optimizer == "adamw" else 4
        out["weights"] = p_dev * 3 + counts["total"] / n_devices * \
            (4 * 2 + opt_mult + wbytes)
        # activations: layer-boundary checkpoints write (fwd) + read (bwd)
        tokens_dev = shape.global_batch * shape.seq_len / \
            max(n_devices / _model_axis(n_devices), 1)
        act = cfg.n_layers * tokens_dev * d * 2 * 2  # write+read, bf16
        out["activations"] = act * 2.0  # qkv/ffn extras under remat
        out["logits"] = tokens_dev * cfg.vocab / _model_axis(n_devices) * 4 * 2
    elif shape.kind == "prefill":
        tokens_dev = shape.global_batch * shape.seq_len / \
            max(n_devices / _model_axis(n_devices), 1)
        out["weights"] = p_dev
        out["activations"] = cfg.n_layers * tokens_dev * d * 2 * 2
        out["logits"] = tokens_dev * cfg.vocab / _model_axis(n_devices) * 4
    else:  # decode: weights once per token + cache read/write
        out["weights"] = counts["active" if cfg.n_experts else "total"] \
            * wbytes / n_devices
        kv, hd = max(cfg.n_kv_heads, 1), cfg.hd
        n_attn = cfg.n_layers if cfg.family != "hybrid" else \
            cfg.n_layers // max(cfg.shared_attn_period, 1)
        if cfg.family == "ssm":
            cache = cfg.n_layers * shape.global_batch * cfg.ssm_heads * \
                cfg.ssm_state * cfg.ssm_headdim * 2 * 2
        else:
            kv_bytes = (1.0 + 4.0 / hd) if cfg.kv_cache_dtype == "int8" \
                else 2.0  # int8 + per-(token,head) f32 scale vs bf16
            cache = n_attn * shape.global_batch * shape.seq_len * kv * hd \
                * kv_bytes  # read the full cache
            if cfg.family == "hybrid":
                cache += cfg.n_layers * shape.global_batch * cfg.ssm_heads \
                    * cfg.ssm_state * cfg.ssm_headdim * 2 * 2
        out["kv_cache"] = cache / n_devices
    out["total"] = float(sum(out.values()))
    return out


def _model_axis(n_devices: int) -> int:
    return 16 if n_devices % 16 == 0 else 1
