"""SZ3-like error-bounded lossy compressor (interpolation predictor).

Mirrors SZ3's interpolation-based pipeline: multilevel linear-interpolation
prediction with *decoded-value feedback* (the decoder reproduces the encoder's
predictions exactly), uniform quantisation with bin width 2ε, and an entropy
stage (zlib over adaptively-narrowed integer codes). Guarantees
|x - decode|_inf <= ε by construction of the quantiser.

Used as the underlying compressor for the PSZ3 / PSZ3-delta progressive
schemes (paper §V-B) — the paper picks SZ3 for the same role because it has
the tightest L-inf control among snapshot compressors.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import List, Tuple

import numpy as np

from repro.transform.hierarchical import (
    _new_node_mask,
    grid_levels,
    interp_up,
    pad_to_grid,
    unpad,
)


@dataclass
class SZCompressed:
    eps: float
    orig_shape: Tuple[int, ...]
    padded_shape: Tuple[int, ...]
    levels: int
    blobs: List[bytes]          # [base_codes, level L-1 codes, ..., level 0]
    dtypes: List[str]
    amax: float = 0.0           # max |x| (for the rounding-safe bound)

    @property
    def nbytes(self) -> int:
        return sum(len(b) for b in self.blobs) + 64  # + header

    @property
    def safe_eps(self) -> float:
        """The quantiser guarantees eps in exact arithmetic; f64 dequant
        rounding can exceed it by a few ulps of the value scale — the
        REPORTED bound (what the QoI estimator consumes) includes that."""
        import numpy as _np
        return self.eps + 8 * _np.finfo(_np.float64).eps * self.amax


def _quantise(resid: np.ndarray, eps: float) -> np.ndarray:
    return np.round(resid / (2.0 * eps)).astype(np.int64)


def _pack_codes(codes: np.ndarray) -> Tuple[bytes, str]:
    amax = int(np.max(np.abs(codes))) if codes.size else 0
    if amax < 2 ** 7:
        arr = codes.astype(np.int8)
    elif amax < 2 ** 15:
        arr = codes.astype(np.int16)
    elif amax < 2 ** 31:
        arr = codes.astype(np.int32)
    else:
        arr = codes
    return zlib.compress(arr.tobytes(), 1), str(arr.dtype)


def _unpack_codes(blob: bytes, dtype: str, count: int) -> np.ndarray:
    return np.frombuffer(zlib.decompress(blob), dtype=np.dtype(dtype),
                         count=count).astype(np.int64)


def sz_compress(x: np.ndarray, eps: float, max_levels: int = 32) -> SZCompressed:
    if eps <= 0:
        raise ValueError("eps must be positive")
    padded, orig_shape = pad_to_grid(np.asarray(x, dtype=np.float64))
    levels = grid_levels(padded.shape, max_levels)
    blobs: List[bytes] = []
    dtypes: List[str] = []

    # Base grid: predict 0, quantise absolute values.
    stride = 1 << levels
    base_sl = tuple(slice(None, None, stride) for _ in padded.shape)
    base = padded[base_sl]
    codes = _quantise(base, eps)
    blob, dt = _pack_codes(codes)
    blobs.append(blob)
    dtypes.append(dt)
    decoded = np.zeros_like(padded)
    decoded[base_sl] = codes.astype(np.float64) * (2.0 * eps)

    # Fine levels, coarse -> fine, predicting from *decoded* values.
    for l in range(levels - 1, -1, -1):
        s = 1 << l
        sl = tuple(slice(None, None, s) for _ in padded.shape)
        view = padded[sl]
        dec_view = decoded[sl]
        pred = np.asarray(interp_up(dec_view[tuple(slice(None, None, 2)
                                                   for _ in padded.shape)]))
        mask = _new_node_mask(view.shape)
        resid = np.where(mask, view - pred, 0.0)
        codes = _quantise(resid[mask], eps)
        blob, dt = _pack_codes(codes)
        blobs.append(blob)
        dtypes.append(dt)
        dec_new = pred[mask] + codes.astype(np.float64) * (2.0 * eps)
        dec_view = dec_view.copy()
        dec_view[mask] = dec_new
        decoded[sl] = dec_view

    return SZCompressed(eps=float(eps), orig_shape=orig_shape,
                        padded_shape=padded.shape, levels=levels,
                        blobs=blobs, dtypes=dtypes,
                        amax=float(np.max(np.abs(padded))))


def sz_decompress(c: SZCompressed) -> np.ndarray:
    decoded = np.zeros(c.padded_shape, dtype=np.float64)
    stride = 1 << c.levels
    base_sl = tuple(slice(None, None, stride) for _ in c.padded_shape)
    base_count = int(np.prod(decoded[base_sl].shape))
    codes = _unpack_codes(c.blobs[0], c.dtypes[0], base_count)
    decoded[base_sl] = codes.reshape(decoded[base_sl].shape).astype(np.float64) \
        * (2.0 * c.eps)
    for i, l in enumerate(range(c.levels - 1, -1, -1)):
        s = 1 << l
        sl = tuple(slice(None, None, s) for _ in c.padded_shape)
        dec_view = decoded[sl]
        pred = np.asarray(interp_up(dec_view[tuple(slice(None, None, 2)
                                                   for _ in c.padded_shape)]))
        mask = _new_node_mask(dec_view.shape)
        codes = _unpack_codes(c.blobs[i + 1], c.dtypes[i + 1], int(mask.sum()))
        dec_view = dec_view.copy()
        dec_view[mask] = pred[mask] + codes.astype(np.float64) * (2.0 * c.eps)
        decoded[sl] = dec_view
    return unpad(decoded, c.orig_shape)
