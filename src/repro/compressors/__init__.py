from repro.compressors.szlike import SZCompressed, sz_compress, sz_decompress
from repro.compressors.snapshots import (
    DeltaSnapshotArchive,
    SnapshotArchive,
    default_snapshot_eps,
)

__all__ = [
    "SZCompressed", "sz_compress", "sz_decompress",
    "SnapshotArchive", "DeltaSnapshotArchive", "default_snapshot_eps",
]
