"""Snapshot-based progressive schemes (paper §V-B categories 1 and 2).

SnapshotArchive (PSZ3): the data compressed independently at a ladder of
error bounds ε_1 > ε_2 > ... A request for ε* fetches the smallest snapshot
with ε_i <= ε*; under *progressive* request sequences every newly-needed
snapshot is fetched in full — the cross-snapshot redundancy the paper
penalises in Figs 2/7/8.

DeltaSnapshotArchive (PSZ3-delta, after Magri & Lindstrom): snapshot i
compresses the *residual* against the reconstruction from snapshots < i, so
a request for ε* fetches all first i snapshots but shares bytes across
requests. decoded_i = Σ_{j<=i} decode_j, with |x - decoded_i|_inf <= ε_i.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.compressors.szlike import SZCompressed, sz_compress, sz_decompress


def default_snapshot_eps(value_range: float, n: int = 10,
                         base: float = 10.0) -> List[float]:
    """Paper's ladder: ε_i = range · base^{-i}, i = 1..n."""
    return [value_range * base ** (-(i + 1)) for i in range(n)]


def select_snapshot(snapshots: Sequence, eps: float) -> int:
    """Index of the coarsest snapshot with eps_i <= eps (the ladder is
    sorted loosest-first); the tightest available if none reaches eps."""
    for i, s in enumerate(snapshots):
        if s.eps <= eps:
            return i
    return len(snapshots) - 1


@dataclass
class SnapshotArchive:
    """PSZ3: independent snapshots at decreasing error bounds."""
    snapshots: List[SZCompressed]          # eps strictly decreasing

    @classmethod
    def build(cls, x: np.ndarray, eps_ladder: Sequence[float]) -> "SnapshotArchive":
        eps_sorted = sorted(set(float(e) for e in eps_ladder), reverse=True)
        return cls(snapshots=[sz_compress(x, e) for e in eps_sorted])

    @property
    def total_nbytes(self) -> int:
        return sum(s.nbytes for s in self.snapshots)

    def open(self) -> "SnapshotReader":
        return SnapshotReader(self)


class SnapshotReader:
    def __init__(self, archive: SnapshotArchive):
        self.archive = archive
        self.fetched = [False] * len(archive.snapshots)
        self.bytes_fetched = 0
        self._cache: Optional[Tuple[int, np.ndarray]] = None

    def _select(self, eps: float) -> int:
        return select_snapshot(self.archive.snapshots, eps)

    def _decode(self, idx: int) -> np.ndarray:
        """Decode snapshot ``idx`` — overridden by store-backed readers that
        must fetch the blobs (checksum-verified) before decompressing."""
        return sz_decompress(self.archive.snapshots[idx])

    def request(self, eps: float) -> Tuple[np.ndarray, float]:
        snaps = self.archive.snapshots
        idx = self._select(eps)
        # never go backwards: reuse an already-fetched tighter snapshot
        if self._cache is not None and self._cache[0] >= idx:
            idx = self._cache[0]
        # decode BEFORE charging bytes: a store-backed _decode may fail, and
        # a failed fetch must not leave the snapshot marked fetched/charged
        if self._cache is None or self._cache[0] != idx:
            self._cache = (idx, self._decode(idx))
        if not self.fetched[idx]:
            self.bytes_fetched += snaps[idx].nbytes
            self.fetched[idx] = True
        return self._cache[1], snaps[idx].safe_eps


@dataclass
class DeltaSnapshotArchive:
    """PSZ3-delta: residual ladder; request(ε) needs all snapshots with
    ε_j >= smallest satisfying ε_i."""
    snapshots: List[SZCompressed]
    eps_ladder: List[float]

    @classmethod
    def build(cls, x: np.ndarray,
              eps_ladder: Sequence[float]) -> "DeltaSnapshotArchive":
        eps_sorted = sorted(set(float(e) for e in eps_ladder), reverse=True)
        x = np.asarray(x, dtype=np.float64)
        snaps: List[SZCompressed] = []
        decoded = np.zeros_like(x)
        for e in eps_sorted:
            snap = sz_compress(x - decoded, e)
            snaps.append(snap)
            decoded = decoded + sz_decompress(snap)
        return cls(snapshots=snaps, eps_ladder=eps_sorted)

    @property
    def total_nbytes(self) -> int:
        return sum(s.nbytes for s in self.snapshots)

    def open(self) -> "DeltaSnapshotReader":
        return DeltaSnapshotReader(self)


class DeltaSnapshotReader:
    def __init__(self, archive: DeltaSnapshotArchive):
        self.archive = archive
        self.n_fetched = 0
        self.bytes_fetched = 0
        self._decoded: Optional[np.ndarray] = None

    def _select(self, eps: float) -> int:
        return select_snapshot(self.archive.snapshots, eps)

    def _decode(self, idx: int) -> np.ndarray:
        return sz_decompress(self.archive.snapshots[idx])

    def request(self, eps: float) -> Tuple[np.ndarray, float]:
        snaps = self.archive.snapshots
        idx = self._select(eps)
        while self.n_fetched <= idx:
            snap = snaps[self.n_fetched]
            # decode BEFORE charging: a store-backed _decode may fail, and a
            # failed rung must not be charged or counted as applied
            delta = self._decode(self.n_fetched)
            self.bytes_fetched += snap.nbytes
            self._decoded = delta if self._decoded is None \
                else self._decoded + delta
            self.n_fetched += 1
        return self._decoded, self.achieved_bound()

    def achieved_bound(self) -> float:
        """Bound certified by the rungs applied so far: tightest applied
        snapshot's eps + accumulation rounding slack."""
        base = self.archive.snapshots[self.n_fetched - 1]
        slack = 8 * np.finfo(np.float64).eps * base.amax * self.n_fetched
        return base.eps + slack
