"""Snapshot-based progressive schemes (paper §V-B categories 1 and 2).

SnapshotArchive (PSZ3): the data compressed independently at a ladder of
error bounds ε_1 > ε_2 > ... A request for ε* fetches the smallest snapshot
with ε_i <= ε*; under *progressive* request sequences every newly-needed
snapshot is fetched in full — the cross-snapshot redundancy the paper
penalises in Figs 2/7/8.

DeltaSnapshotArchive (PSZ3-delta, after Magri & Lindstrom): snapshot i
compresses the *residual* against the reconstruction from snapshots < i, so
a request for ε* fetches all first i snapshots but shares bytes across
requests. decoded_i = Σ_{j<=i} decode_j, with |x - decoded_i|_inf <= ε_i.

Timestep deltas (manifest v4 live archives): ``encode_timestep`` /
``decode_timestep`` apply the same residual idea along the TIME axis.  A
keyframe compresses the field independently; a delta timestep compresses
x_k − rec_{k−1} against the previous timestep's *reconstruction* (not its
raw values), so the per-timestep bound is ε_k plus float accumulation
slack — independent of chain length — and temporal sparsity between
adjacent snapshots is what the entropy stage sees.  Rolling retention can
drop any keyframe-aligned prefix without touching later timesteps'
decodability.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.compressors.szlike import SZCompressed, sz_compress, sz_decompress


def default_snapshot_eps(value_range: float, n: int = 10,
                         base: float = 10.0) -> List[float]:
    """Paper's ladder: ε_i = range · base^{-i}, i = 1..n."""
    return [value_range * base ** (-(i + 1)) for i in range(n)]


def select_snapshot(snapshots: Sequence, eps: float) -> int:
    """Index of the coarsest snapshot with eps_i <= eps (the ladder is
    sorted loosest-first); the tightest available if none reaches eps."""
    for i, s in enumerate(snapshots):
        if s.eps <= eps:
            return i
    return len(snapshots) - 1


def encode_timestep(x: np.ndarray, eps: float,
                    prev_recon: Optional[np.ndarray] = None
                    ) -> Tuple[SZCompressed, np.ndarray]:
    """Encode one appended timestep; returns ``(snap, recon)``.

    With ``prev_recon=None`` this is a KEYFRAME — the field compressed
    independently.  Otherwise the residual ``x - prev_recon`` is compressed
    (the delta path), and ``recon = prev_recon + decode(snap)`` satisfies
    ``|x - recon|_inf <= eps`` by the SZ quantiser guarantee on the
    residual — the error does not compound along the chain because each
    delta is taken against the previous *reconstruction*.  The returned
    ``recon`` is the writer's decode-side state for the next delta, bitwise
    what any reader decodes for this timestep."""
    x = np.asarray(x, dtype=np.float64)
    if prev_recon is None:
        snap = sz_compress(x, eps)
        return snap, sz_decompress(snap)
    snap = sz_compress(x - prev_recon, eps)
    return snap, prev_recon + sz_decompress(snap)


def decode_timestep(snap: SZCompressed,
                    prev_recon: Optional[np.ndarray] = None) -> np.ndarray:
    """Decode one timestep: keyframes stand alone, deltas add onto the
    previous timestep's reconstruction (must be the chain predecessor)."""
    delta = sz_decompress(snap)
    return delta if prev_recon is None else prev_recon + delta


def timestep_bound(eps: float, amax_chain: Sequence[float]) -> float:
    """Certified L-inf bound for a timestep decoded through a keyframe→delta
    chain: the timestep's own eps plus float accumulation slack — one
    rounding allowance per chain link, mirroring
    ``DeltaSnapshotReader.achieved_bound``."""
    amax = max(amax_chain) if len(amax_chain) else 0.0
    return eps + 8 * np.finfo(np.float64).eps * amax * len(amax_chain)


@dataclass
class SnapshotArchive:
    """PSZ3: independent snapshots at decreasing error bounds."""
    snapshots: List[SZCompressed]          # eps strictly decreasing

    @classmethod
    def build(cls, x: np.ndarray, eps_ladder: Sequence[float]) -> "SnapshotArchive":
        eps_sorted = sorted(set(float(e) for e in eps_ladder), reverse=True)
        return cls(snapshots=[sz_compress(x, e) for e in eps_sorted])

    @property
    def total_nbytes(self) -> int:
        return sum(s.nbytes for s in self.snapshots)

    def open(self) -> "SnapshotReader":
        return SnapshotReader(self)


class SnapshotReader:
    def __init__(self, archive: SnapshotArchive):
        self.archive = archive
        self.fetched = [False] * len(archive.snapshots)
        self.bytes_fetched = 0
        self._cache: Optional[Tuple[int, np.ndarray]] = None

    def _select(self, eps: float) -> int:
        return select_snapshot(self.archive.snapshots, eps)

    def _decode(self, idx: int) -> np.ndarray:
        """Decode snapshot ``idx`` — overridden by store-backed readers that
        must fetch the blobs (checksum-verified) before decompressing."""
        return sz_decompress(self.archive.snapshots[idx])

    def request(self, eps: float) -> Tuple[np.ndarray, float]:
        snaps = self.archive.snapshots
        idx = self._select(eps)
        # never go backwards: reuse an already-fetched tighter snapshot
        if self._cache is not None and self._cache[0] >= idx:
            idx = self._cache[0]
        # decode BEFORE charging bytes: a store-backed _decode may fail, and
        # a failed fetch must not leave the snapshot marked fetched/charged
        if self._cache is None or self._cache[0] != idx:
            self._cache = (idx, self._decode(idx))
        if not self.fetched[idx]:
            self.bytes_fetched += snaps[idx].nbytes
            self.fetched[idx] = True
        return self._cache[1], snaps[idx].safe_eps


@dataclass
class DeltaSnapshotArchive:
    """PSZ3-delta: residual ladder; request(ε) needs all snapshots with
    ε_j >= smallest satisfying ε_i."""
    snapshots: List[SZCompressed]
    eps_ladder: List[float]

    @classmethod
    def build(cls, x: np.ndarray,
              eps_ladder: Sequence[float]) -> "DeltaSnapshotArchive":
        eps_sorted = sorted(set(float(e) for e in eps_ladder), reverse=True)
        x = np.asarray(x, dtype=np.float64)
        snaps: List[SZCompressed] = []
        decoded = np.zeros_like(x)
        for e in eps_sorted:
            snap = sz_compress(x - decoded, e)
            snaps.append(snap)
            decoded = decoded + sz_decompress(snap)
        return cls(snapshots=snaps, eps_ladder=eps_sorted)

    @property
    def total_nbytes(self) -> int:
        return sum(s.nbytes for s in self.snapshots)

    def open(self) -> "DeltaSnapshotReader":
        return DeltaSnapshotReader(self)


class DeltaSnapshotReader:
    def __init__(self, archive: DeltaSnapshotArchive):
        self.archive = archive
        self.n_fetched = 0
        self.bytes_fetched = 0
        self._decoded: Optional[np.ndarray] = None

    def _select(self, eps: float) -> int:
        return select_snapshot(self.archive.snapshots, eps)

    def _decode(self, idx: int) -> np.ndarray:
        return sz_decompress(self.archive.snapshots[idx])

    def request(self, eps: float) -> Tuple[np.ndarray, float]:
        snaps = self.archive.snapshots
        idx = self._select(eps)
        while self.n_fetched <= idx:
            snap = snaps[self.n_fetched]
            # decode BEFORE charging: a store-backed _decode may fail, and a
            # failed rung must not be charged or counted as applied
            delta = self._decode(self.n_fetched)
            self.bytes_fetched += snap.nbytes
            self._decoded = delta if self._decoded is None \
                else self._decoded + delta
            self.n_fetched += 1
        return self._decoded, self.achieved_bound()

    def achieved_bound(self) -> float:
        """Bound certified by the rungs applied so far: tightest applied
        snapshot's eps + accumulation rounding slack."""
        base = self.archive.snapshots[self.n_fetched - 1]
        slack = 8 * np.finfo(np.float64).eps * base.amax * self.n_fetched
        return base.eps + slack
