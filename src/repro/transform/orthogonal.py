"""PMGARD-OB: multilevel decomposition with MGARD's L² projection.

This is the baseline the paper *improves on* (Fig 3): after computing the
hierarchical surplus at each level, the coarse nodal values receive an L²
projection correction z = M⁻¹ b of the detail, which optimises L² error but
couples levels — so the L-inf bound must amplify surplus errors through the
projection operator, giving the loose bound

    |x - x̂|_inf <= Σ_l (1 + κ) e_l + e_base,   κ = (||M⁻¹||_inf ||W||_inf)^d

with ||M⁻¹||_inf <= 3/2 (diagonal dominance of the coarse mass matrix) and
||W||_inf <= 2 (the load-vector weights), so κ = 3^d for d-dimensional data.
The looseness (vs. HB's Σ_l e_l) is exactly the over-retrieval the paper
eliminates. The projection also serialises levels (each level sees corrected
coarser values) — the refactor-time cost reproduced in Table IV.

Weights (uniform fine spacing h=1, coarse H=2, piecewise-linear elements):
  load    b_i = 5/12 v_{2i}·(interior ×2) + 1/2 (v_{2i±1}) + 1/12 (v_{2i±2})
  mass    M = tridiag(1/3, 4/3, 1/3), boundary diagonal 2/3.
Applied separably along each axis (tensor-product projection).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

import repro._x64  # noqa: F401  (f64 for the compression stack)

from repro.transform.hierarchical import (
    _new_node_mask,
    _view_slices,
    interp_up,
)

Array = jnp.ndarray

# Per-axis amplification of surplus error through the projection (see above).
KAPPA_PER_AXIS = 3.0


def ob_kappa(ndim: int) -> float:
    return KAPPA_PER_AXIS ** ndim


# ---------------------------------------------------------------------------
# L² projection along one axis: fine (2m+1) -> coarse (m+1)
# ---------------------------------------------------------------------------


def _load_axis(v: Array, ax: int) -> Array:
    """b_i = Σ_j w_{ij} v_j with the piecewise-linear overlap weights."""
    v = jnp.moveaxis(v, ax, -1)
    n = v.shape[-1]               # 2m + 1
    m = (n - 1) // 2
    even = v[..., 0::2]           # m+1 values at coarse positions
    odd = v[..., 1::2]            # m midpoint values
    b = jnp.zeros(v.shape[:-1] + (m + 1,), v.dtype)
    # own-node contribution: 5/12 per side (interior nodes have two sides)
    side_counts = jnp.concatenate([
        jnp.ones((1,), v.dtype), 2 * jnp.ones((m - 1,), v.dtype),
        jnp.ones((1,), v.dtype)]) if m >= 1 else jnp.ones((1,), v.dtype)
    b = b + (5.0 / 12.0) * even * side_counts
    if m >= 1:
        # midpoints: 1/2 to each neighbouring coarse node
        b = b.at[..., :-1].add(0.5 * odd)
        b = b.at[..., 1:].add(0.5 * odd)
        # next-nearest fine nodes (the coarse-position values): 1/12 across
        b = b.at[..., :-1].add((1.0 / 12.0) * even[..., 1:])
        b = b.at[..., 1:].add((1.0 / 12.0) * even[..., :-1])
    return jnp.moveaxis(b, -1, ax)


def _thomas_axis(b: Array, ax: int) -> Array:
    """Solve M z = b along ``ax`` with M = tridiag(1/3, diag, 1/3),
    diag = 4/3 interior / 2/3 boundary. Batched Thomas via lax.scan."""
    b = jnp.moveaxis(b, ax, 0)
    n = b.shape[0]
    if n == 1:
        return jnp.moveaxis(b / (2.0 / 3.0), 0, ax)
    diag = jnp.full((n,), 4.0 / 3.0, b.dtype).at[0].set(2.0 / 3.0).at[-1].set(2.0 / 3.0)
    off = 1.0 / 3.0

    def fwd(carry, inp):
        cp_prev, dp_prev = carry
        d_i, b_i = inp
        denom = d_i - off * cp_prev
        cp = off / denom
        dp = (b_i - off * dp_prev) / denom
        return (cp, dp), (cp, dp)

    zeros = jnp.zeros(b.shape[1:], b.dtype)
    (_, _), (cps, dps) = jax.lax.scan(
        fwd, (jnp.zeros((), b.dtype), zeros), (diag, b))

    def back(z_next, inp):
        cp, dp = inp
        z = dp - cp * z_next
        return z, z

    _, zs = jax.lax.scan(back, zeros, (cps, dps), reverse=True)
    return jnp.moveaxis(zs, 0, ax)


def project_detail(detail: Array) -> Array:
    """Tensor-product L² projection of the fine-grid detail onto the coarse
    grid: apply (load -> mass-solve) along every axis."""
    z = detail
    for ax in range(detail.ndim):
        z = _thomas_axis(_load_axis(z, ax), ax)
    return z


# ---------------------------------------------------------------------------
# OB decompose / recompose (levels are coupled: fine -> coarse order)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnums=(1,))
def decompose_ob(x: Array, levels: int) -> Array:
    for l in range(levels):
        s = 1 << l
        sl = _view_slices(x.ndim, s)
        view = x[sl]
        coarse = view[_view_slices(x.ndim, 2)]
        pred = interp_up(coarse)
        mask = jnp.asarray(_new_node_mask(view.shape))
        detail = jnp.where(mask, view - pred, 0.0)
        z = project_detail(detail)
        new_view = jnp.where(mask, detail, view)
        new_view = new_view.at[_view_slices(x.ndim, 2)].set(coarse + z)
        x = x.at[sl].set(new_view)
    return x


@functools.partial(jax.jit, static_argnums=(1,))
def recompose_ob(c: Array, levels: int) -> Array:
    for l in range(levels - 1, -1, -1):
        s = 1 << l
        sl = _view_slices(c.ndim, s)
        view = c[sl]
        mask = jnp.asarray(_new_node_mask(view.shape))
        detail = jnp.where(mask, view, 0.0)
        z = project_detail(detail)
        corrected = view[_view_slices(c.ndim, 2)]
        coarse = corrected - z
        pred = interp_up(coarse)
        new_view = jnp.where(mask, detail + pred, view)
        new_view = new_view.at[_view_slices(c.ndim, 2)].set(coarse)
        c = c.at[sl].set(new_view)
    return c


def ob_error_bound(level_bounds, base_bound: float, ndim: int) -> float:
    """OB L-inf bound: Σ_l (1+κ) e_l + e_base (see module docstring)."""
    kappa = ob_kappa(ndim)
    return float((1.0 + kappa) * np.sum(level_bounds) + base_bound)
