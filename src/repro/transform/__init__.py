import repro._x64  # noqa: F401  (f64 for the compression stack)

from repro.transform.hierarchical import (
    decompose_hb,
    grid_levels,
    level_map,
    pad_to_grid,
    recompose_hb,
    recompose_hb_from,
    unpad,
)
from repro.transform.orthogonal import decompose_ob, recompose_ob

__all__ = [
    "pad_to_grid", "unpad", "grid_levels", "level_map",
    "decompose_hb", "recompose_hb", "recompose_hb_from",
    "decompose_ob", "recompose_ob",
]
