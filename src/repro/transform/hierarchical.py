"""PMGARD-HB multilevel decomposition (paper §V-B).

Hierarchical-basis (HB) surplus transform: at each level, "new" nodes (those
not on the next-coarser grid) store their *interpolation surplus*
``x - I(coarse x)``; coarse-node values are left untouched. Because coarse
values never change, (a) every level's surplus depends only on the original
data — the transform is embarrassingly parallel across levels (the TPU-native
win over MGARD's sequential L² projection), and (b) the L-inf reconstruction
error composes exactly as the *sum of per-level coefficient bounds*:

    |x - x̂|_inf  <=  Σ_l  e_l                                   (HB bound)

since a node's error is its own surplus error plus a convex (multilinear)
interpolation of strictly-coarser node errors. This is the tight bound the
paper exploits to fix PMGARD's over-retrieval (Fig 3).

Grids are padded per-dimension to 2^k + 1 (edge-replicate); the padded
surpluses are ~0 and compress away.
"""
from __future__ import annotations

import functools
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

import repro._x64  # noqa: F401  (f64 for the compression stack)

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# Grid geometry
# ---------------------------------------------------------------------------


def _pad_dim(n: int) -> int:
    """Smallest 2^k + 1 >= n (k >= 0)."""
    if n <= 2:
        return 2 if n == 1 else 3  # degenerate dims get a tiny valid grid
    k = int(np.ceil(np.log2(n - 1)))
    return (1 << k) + 1


def pad_to_grid(x: np.ndarray) -> Tuple[np.ndarray, Tuple[int, ...]]:
    """Edge-replicate pad every dim to 2^k + 1. Returns (padded, orig_shape)."""
    orig = x.shape
    target = tuple(_pad_dim(n) for n in orig)
    pads = tuple((0, t - n) for t, n in zip(target, orig))
    return np.pad(x, pads, mode="edge"), orig


def unpad(x: np.ndarray, orig_shape: Tuple[int, ...]) -> np.ndarray:
    return x[tuple(slice(0, n) for n in orig_shape)]


def grid_levels(shape: Tuple[int, ...], max_levels: int = 32) -> int:
    """Number of detail levels supported by a padded (2^k+1, ...) grid."""
    ks = []
    for n in shape:
        k = int(np.round(np.log2(n - 1))) if n > 2 else 0
        ks.append(k)
    return min(min(ks), max_levels)


def level_map(shape: Tuple[int, ...], levels: int) -> np.ndarray:
    """Per-node detail level: l in [0, levels) for detail nodes (finest = 0),
    ``levels`` for base-grid nodes. Level of node i = min over dims of the
    2-adic valuation of its coordinates, clipped to the base grid."""
    val = np.full(shape, levels, dtype=np.int32)
    for ax, n in enumerate(shape):
        idx = np.arange(n)
        v2 = np.full(n, levels, dtype=np.int32)
        nz = idx != 0
        v2[nz] = np.minimum(_v2(idx[nz]), levels)
        sl = [None] * len(shape)
        sl[ax] = slice(None)
        val = np.minimum(val, v2[tuple(sl)])
    return val


def _v2(idx: np.ndarray) -> np.ndarray:
    """2-adic valuation of positive ints, vectorised."""
    out = np.zeros_like(idx)
    x = idx.copy()
    while np.any(x % 2 == 0):
        even = x % 2 == 0
        out[even] += 1
        x[even] //= 2
    return out.astype(np.int32)


# ---------------------------------------------------------------------------
# Multilinear upsampling (coarse grid -> fine grid prediction)
# ---------------------------------------------------------------------------


def _up_axis(c: Array, ax: int) -> Array:
    """Linear-interpolate a (2m+1 -> from m+1) refinement along one axis."""
    n = c.shape[ax]
    out_shape = c.shape[:ax] + (2 * n - 1,) + c.shape[ax + 1:]
    lo = jax.lax.slice_in_dim(c, 0, n - 1, axis=ax)
    hi = jax.lax.slice_in_dim(c, 1, n, axis=ax)
    mid = 0.5 * (lo + hi)
    out = jnp.zeros(out_shape, c.dtype)
    even = tuple(slice(None) if i != ax else slice(0, None, 2) for i in range(c.ndim))
    odd = tuple(slice(None) if i != ax else slice(1, None, 2) for i in range(c.ndim))
    return out.at[even].set(c).at[odd].set(mid)


def interp_up(coarse: Array) -> Array:
    """Multilinear prediction of the fine grid from the coarse grid."""
    out = coarse
    for ax in range(coarse.ndim):
        out = _up_axis(out, ax)
    return out


def _new_node_mask(shape: Tuple[int, ...]) -> np.ndarray:
    """Nodes of the fine view NOT on the 2-strided coarse grid."""
    m = np.zeros(shape, dtype=bool)
    for ax, n in enumerate(shape):
        odd = (np.arange(n) % 2).astype(bool)
        sl = [None] * len(shape)
        sl[ax] = slice(None)
        m |= odd[tuple(sl)]
    return m


def _view_slices(ndim: int, stride: int):
    return tuple(slice(None, None, stride) for _ in range(ndim))


# ---------------------------------------------------------------------------
# HB decompose / recompose (pure jnp; per-level shapes are static)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnums=(1,))
def decompose_hb(x: Array, levels: int) -> Array:
    """In-place-layout HB transform: detail nodes hold surpluses, base nodes
    hold original values. Levels are independent (no cross-level coupling)."""
    for l in range(levels):
        s = 1 << l
        view = x[_view_slices(x.ndim, s)]
        pred = interp_up(view[_view_slices(x.ndim, 2)])
        mask = jnp.asarray(_new_node_mask(view.shape))
        x = x.at[_view_slices(x.ndim, s)].set(jnp.where(mask, view - pred, view))
    return x


def _recompose_steps(c: Array, start: int) -> Array:
    """Recompose steps start..0 (coarse -> fine), shared by the full and
    partial entry points so both produce bitwise-identical op graphs."""
    for l in range(start, -1, -1):
        s = 1 << l
        view = c[_view_slices(c.ndim, s)]
        pred = interp_up(view[_view_slices(c.ndim, 2)])
        mask = jnp.asarray(_new_node_mask(view.shape))
        c = c.at[_view_slices(c.ndim, s)].set(jnp.where(mask, view + pred, view))
    return c


@functools.partial(jax.jit, static_argnums=(1,))
def recompose_hb(c: Array, levels: int) -> Array:
    """Inverse of decompose_hb; must run coarse -> fine."""
    return _recompose_steps(c, levels - 1)


@functools.partial(jax.jit, static_argnums=(1, 2))
def recompose_hb_from(c: Array, levels: int, start: int) -> Array:
    """Partial recompose: only steps start..0.  For a coefficient field
    supported on levels <= start (zero on all strictly-coarser grids) this
    is *bitwise* identical to the full recompose — the skipped coarse steps
    see an all-zero view and are exact no-ops — while costing only the fine
    half of the step ladder.  This is what makes per-level incremental
    reconstruction (core/refactor.py) both cheap and reproducible."""
    return _recompose_steps(c, min(start, levels - 1))


@functools.partial(jax.jit, static_argnums=(2, 3, 4))
def scatter_recompose_from(idx: Array, vals: Array,
                           shape: Tuple[int, ...], levels: int,
                           start: int) -> Array:
    """Scatter one level's coefficient values into a zero field and partially
    recompose it — the device-resident form of the reader's per-level
    contribution (core/refactor.py::_compute_contrib).  ``idx`` holds flat
    node indices, ``vals`` the decoded coefficients (straight off the fused
    decode, no host round-trip).  The scatter is exact placement and the
    recompose graph is shared with ``recompose_hb_from``, so the result is
    bit-identical to the host scatter + recompose pair."""
    field = jnp.zeros(int(np.prod(shape)), dtype=vals.dtype)
    field = field.at[idx].set(vals).reshape(shape)
    return _recompose_steps(field, min(start, levels - 1))


@functools.partial(jax.jit, static_argnums=(2, 3, 4))
def scatter_recompose_from_batch(idx: Array, vals: Array,
                                 shape: Tuple[int, ...], levels: int,
                                 start: int) -> Array:
    """vmapped ``scatter_recompose_from`` over a leading batch axis: one
    dispatch recomposes the same-shaped contribution of B readers (the serve
    plane's batched tick).  vmap only adds the batch dimension — each slice
    runs the identical elementwise graph, so results match the per-reader
    dispatch bit-for-bit."""
    return jax.vmap(
        lambda i, v: scatter_recompose_from(i, v, shape, levels, start)
    )(idx, vals)


def hb_error_bound(level_bounds: List[float]) -> float:
    """HB L-inf bound: Σ_l e_l (+ base bound, passed as last entry)."""
    return float(np.sum(level_bounds))


# ---------------------------------------------------------------------------
# Interpolation-predicted (`ip`) representation
# ---------------------------------------------------------------------------
#
# The `ip` method closes the prediction loop that HB leaves open: instead of
# coding each level's interpolation surplus against the ORIGINAL data, it
# codes the residual against the decoder's own truncated reconstruction of
# all coarser groups.  Each group g records `pred_planes` (kp_g) — the plane
# depth the encoder folded into its prediction.  The decoder's per-group
# contribution is then
#
#     C_g = recompose_hb_from(scatter(T_g), levels, start=g)      (truncated
#     C_g.ravel()[idx_g] += v̂_g - T_g                              + tail)
#
# with T_g = trunc(v̂_g, 2^{E_g - kp_g}).  Truncation to a power-of-two
# quantum is EXACT in f64 (magnitudes are < 2^53 integer multiples of the
# quantum), and for fetched depth k <= kp it is the identity, so the tail is
# zero and C_g degenerates to the plain HB contribution.  When every group
# is fetched at k_g >= kp_g the decoder's prediction replays the encoder's
# bit-for-bit and per-node errors no longer sum across levels:
#
#     |x - x̂|_inf  <=  max_g e_g            (matched regime — the ip win)
#
# Under-fetched groups (k < kp) perturb the prediction of strictly finer
# groups by at most δ_g = 2^{E-k} - 2^{E-kp}; multilinear interpolation is a
# convex combination, so δ propagates without amplification and the exact
# composition is `ip_error_bound` below — always <= the HB sum.


def trunc_to_quantum(v: np.ndarray, quantum: float) -> np.ndarray:
    """sign(v)·floor(|v|/q)·q — truncate toward zero to multiples of the
    power-of-two quantum ``q``.  Exact in f64: |v| is an integer multiple
    m·q with m < 2^53, the division recovers m exactly, and m·q is exact."""
    v = np.asarray(v, dtype=np.float64)
    if quantum == 0.0:
        return v
    return np.sign(v) * np.floor(np.abs(v) / quantum) * quantum


@functools.partial(jax.jit, static_argnums=(2, 3, 4))
def scatter_recompose_ip_from(idx: Array, vals: Array,
                              shape: Tuple[int, ...], levels: int,
                              start: int, quantum: Array) -> Array:
    """`ip` counterpart of ``scatter_recompose_from``: truncate the decoded
    values to the group's prediction quantum, scatter + partially recompose
    the truncated part (the closed-loop prediction seed for finer groups),
    then add the truncation tail back at the group's own nodes.  ``quantum``
    is a traced operand (2^{E-kp}, or 0.0 for no truncation) so one compiled
    graph serves every group of a given geometry."""
    q = jnp.asarray(quantum, dtype=vals.dtype)
    safe = jnp.where(q == 0.0, jnp.asarray(1.0, vals.dtype), q)
    t = jnp.where(q == 0.0, vals,
                  jnp.sign(vals) * jnp.floor(jnp.abs(vals) / safe) * safe)
    field = jnp.zeros(int(np.prod(shape)), dtype=vals.dtype)
    field = field.at[idx].set(t).reshape(shape)
    out = _recompose_steps(field, min(start, levels - 1))
    return out.reshape(-1).at[idx].add(vals - t).reshape(shape)


@functools.partial(jax.jit, static_argnums=(2, 3, 4))
def scatter_recompose_ip_from_batch(idx: Array, vals: Array,
                                    shape: Tuple[int, ...], levels: int,
                                    start: int, quantum: Array) -> Array:
    """vmapped ``scatter_recompose_ip_from`` over a leading batch axis —
    the serve plane's batched tick for `ip` readers.  ``quantum`` carries
    one entry per batch item."""
    return jax.vmap(
        lambda i, v, q: scatter_recompose_ip_from(i, v, shape, levels,
                                                  start, q)
    )(idx, vals, quantum)


def ip_error_bound(level_bounds: List[float],
                   mismatches: List[float]) -> float:
    """`ip` L-inf bound.  Lists are finest-first (index 0 = finest detail,
    last entry = base group), matching the reader's stream order.  Walking
    coarse -> fine with a running prediction-mismatch accumulator m:

        bound = max_g (e_g + m_g),   m_g = Σ_{g' coarser than g} δ_{g'}

    where e_g is the group's own plane bound and δ_g its truncation-depth
    mismatch (0 once fetched depth reaches the recorded ``pred_planes``).
    Always <= hb_error_bound(level_bounds) and monotone under deeper
    fetches."""
    out = 0.0
    m = 0.0
    for e, d in zip(reversed(level_bounds), reversed(mismatches)):
        out = max(out, float(e) + m)
        m += float(d)
    return float(out)
