"""Pallas TPU kernel: one hierarchical-surplus lifting level (1D lines).

Computes  d = x_odd - 0.5 * (x_even[:, :-1] + x_even[:, 1:])  for a batch of
lines — the per-level inner loop of decompose_hb applied along one axis.

TPU layout choice (DESIGN.md §3): levels are stored *deinterleaved*
(struct-of-arrays: even/coarse nodes and odd/new nodes in separate dense
buffers) so the kernel sees only contiguous, 128-lane-aligned loads — the
strided gathers of the CPU formulation do not map to TPU vector memory.

Tile: x_even (ROWS, M+1) and x_odd (ROWS, M) in VMEM, rows tiled by the
grid; M is padded to a multiple of 128 by the wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_ROWS = 8


def _kernel(even_ref, odd_ref, out_ref):
    even = even_ref[...]          # (ROWS, M+P) — last P cols are pad
    odd = odd_ref[...]            # (ROWS, M)
    m = odd.shape[1]
    pred = 0.5 * (even[:, :m] + even[:, 1:m + 1])
    out_ref[...] = odd - pred


@functools.partial(jax.jit, static_argnames=("rows", "interpret"))
def hier_level_surplus(x_even: jnp.ndarray, x_odd: jnp.ndarray,
                       rows: int = DEFAULT_ROWS,
                       interpret: bool = True) -> jnp.ndarray:
    """x_even: (B, M+1) coarse nodes, x_odd: (B, M) new nodes, B % rows == 0.
    Returns (B, M) surpluses."""
    b, m = x_odd.shape
    if x_even.shape != (b, m + 1):
        raise ValueError(f"even {x_even.shape} vs odd {x_odd.shape}")
    if b % rows:
        raise ValueError(f"batch {b} must be a multiple of rows={rows}")
    tiles = b // rows
    return pl.pallas_call(
        _kernel,
        grid=(tiles,),
        in_specs=[pl.BlockSpec((rows, m + 1), lambda i: (i, 0)),
                  pl.BlockSpec((rows, m), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rows, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, m), x_odd.dtype),
        interpret=interpret,
    )(x_even, x_odd)
