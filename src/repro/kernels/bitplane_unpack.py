"""Pallas TPU kernel: bitplane unpack — the inverse of ``bitplane_pack``.

Accumulates P packed planes into 32-bit magnitude words in a single pass:

    out[i] = OR_j  bit_i(plane_j) << shift_j

Per-plane shifts are a *dynamic* input (uint32, broadcast across the 128
lanes) rather than a static tuple, so one compiled kernel serves every fetch
window ``[start, k)`` of the progressive reader — only the plane count and
tile geometry are compile-time constants.  Shifts must be < 32; magnitudes
wider than 32 bits (the archival default is 48) are handled by the caller as
a hi/lo uint32 split (see ``ops.unpack_bitplanes``).

Tile layout mirrors the pack kernel: packed words (P, ROWS, 4) uint32 in
VMEM per tile; output (ROWS, 128) uint32.  Unpacking is a dense broadcast
shift-and-mask over the 32 bit positions of each word — no data-dependent
control flow, VPU-friendly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bitplane_pack import interpret_default

LANES = 128
WORDS_PER_ROW = LANES // 32   # 4 uint32 words per 128-lane row
DEFAULT_ROWS = 8


def _kernel(nplanes, words_ref, shift_ref, out_ref):
    rows = out_ref.shape[0]
    bit_idx = jnp.arange(32, dtype=jnp.uint32)
    acc = jnp.zeros((rows, LANES), jnp.uint32)
    for j in range(nplanes):                             # static unroll
        w = words_ref[j]                                 # (ROWS, 4) uint32
        bits = (w[:, :, None] >> bit_idx[None, None, :]) & jnp.uint32(1)
        acc = acc | (bits.reshape(rows, LANES) << shift_ref[j][None, :])
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("rows", "interpret"))
def _unpack(words: jnp.ndarray, shifts: jnp.ndarray, rows: int,
            interpret: bool) -> jnp.ndarray:
    p, w = words.shape
    if w % (rows * WORDS_PER_ROW):
        raise ValueError(
            f"W={w} must be a multiple of rows*{WORDS_PER_ROW}="
            f"{rows * WORDS_PER_ROW}")
    tiles = w // (rows * WORDS_PER_ROW)
    words3 = words.reshape(p, tiles * rows, WORDS_PER_ROW)
    shift_b = jnp.broadcast_to(shifts.astype(jnp.uint32)[:, None], (p, LANES))
    out = pl.pallas_call(
        functools.partial(_kernel, p),
        grid=(tiles,),
        in_specs=[pl.BlockSpec((p, rows, WORDS_PER_ROW), lambda i: (0, i, 0)),
                  pl.BlockSpec((p, LANES), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((tiles * rows, LANES), jnp.uint32),
        interpret=interpret,
    )(words3, shift_b)
    return out.reshape(tiles * rows * LANES)


def bitplane_unpack(words: jnp.ndarray, shifts: jnp.ndarray,
                    rows: int = DEFAULT_ROWS,
                    interpret: bool | None = None) -> jnp.ndarray:
    """words: (P, W) uint32 packed planes, W % (rows*4) == 0; shifts: (P,)
    uint32 < 32.  Returns (W*32,) uint32 = OR_j(bits of plane j << shifts[j]).
    ``interpret=None`` auto-detects the backend (compile on TPU)."""
    if interpret is None:
        interpret = interpret_default()
    return _unpack(jnp.asarray(words, jnp.uint32),
                   jnp.asarray(shifts), rows=rows, interpret=bool(interpret))
