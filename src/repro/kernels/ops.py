"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the kernel
body runs in Python for correctness validation. On a real TPU backend
``interpret`` flips to False automatically and the same BlockSpecs drive
Mosaic compilation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.bitplane_pack import bitplane_pack
from repro.kernels.hier_level import hier_level_surplus
from repro.kernels.qoi_vtotal import qoi_vtotal_fused

LANES = 128


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jnp.ndarray, mult: int, value=0):
    n = x.shape[0]
    rem = (-n) % mult
    if rem == 0:
        return x, n
    return jnp.pad(x, (0, rem), constant_values=value), n


def pack_bitplanes(mag: jnp.ndarray, nbits: int = 30,
                   rows: int = 8) -> jnp.ndarray:
    """Arbitrary-length (N,) int32 -> (nbits, ceil32(N)) packed planes.
    Pads with zeros (zero magnitudes contribute zero bits)."""
    mag = jnp.asarray(mag, jnp.int32)
    padded, n = _pad_to(mag, rows * LANES)
    out = bitplane_pack(padded, nbits=nbits, rows=rows,
                        interpret=_interpret())
    return out[:, : (n + 31) // 32]


def level_surplus(x_even: jnp.ndarray, x_odd: jnp.ndarray,
                  rows: int = 8) -> jnp.ndarray:
    """Batched 1D surplus with automatic row padding."""
    b = x_odd.shape[0]
    rem = (-b) % rows
    if rem:
        x_even = jnp.pad(x_even, ((0, rem), (0, 0)))
        x_odd = jnp.pad(x_odd, ((0, rem), (0, 0)))
    out = hier_level_surplus(x_even, x_odd, rows=rows,
                             interpret=_interpret())
    return out[:b]


def vtotal_with_bound(vx: jnp.ndarray, vy: jnp.ndarray, vz: jnp.ndarray,
                      eps: jnp.ndarray, rows: int = 8):
    """Fused Vtotal (value, Thm-2 bound) for flat arrays of any length."""
    n = vx.shape[0]
    vx, _ = _pad_to(vx, rows * LANES)
    vy, _ = _pad_to(vy, rows * LANES)
    vz, _ = _pad_to(vz, rows * LANES)
    val, bound = qoi_vtotal_fused(vx, vy, vz, jnp.asarray(eps), rows=rows,
                                  interpret=_interpret())
    return val[:n], bound[:n]
