"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the kernel
body runs in Python for correctness validation. On a real TPU backend
``interpret`` flips to False automatically and the same BlockSpecs drive
Mosaic compilation.

Codec dispatch policy: the pack/unpack wrappers pick geometry per backend —
on TPU the canonical 8-row tiles (VMEM-sized, grid-parallel); in interpret
mode a single whole-array tile, so the traced kernel body appears once in
the XLA graph instead of once per grid step (compile time, not VMEM, is the
binding constraint off-TPU).  ``unpack_bitplanes`` additionally falls back
to a bit-identical vectorized NumPy unpack off-TPU: all codec ops are exact
integer ops, so kernel and fallback produce equal words — asserted by
tests/test_incremental_recompose.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bitplane_pack import (
    bitplane_pack,
    interpret_default as _interpret,
    pack_planes_traced,
)
from repro.kernels.bitplane_unpack import WORDS_PER_ROW, bitplane_unpack
from repro.kernels.hier_level import hier_level_surplus
from repro.kernels.qoi_vtotal import qoi_vtotal_fused

LANES = 128

# -- decode-path dispatch ---------------------------------------------------
#
# Three independent decode implementations must agree bit-for-bit (asserted
# by tests/test_decode_conformance.py):
#
#   * "host"   — the vectorized NumPy byte-plane fallback (pure integer ops);
#   * "kernel" — the ``bitplane_unpack`` Pallas kernel (interpret mode off-
#                TPU), magnitudes finished on host;
#   * "fused"  — unpack + sign application + value scaling traced as ONE jit
#                dispatch (``decode_values_fused``), mirroring the fused
#                encode; magnitudes/values stay device-resident so they can
#                feed recompose without a host round-trip.
#
# "auto" (the default) picks "fused" for groups of at least
# ``FUSED_MIN_COUNT`` coefficients and "host" below it: every path is exact,
# so the cutover is purely a dispatch-overhead / jit-compile-cache tradeoff
# (tiny test groups would pay a trace per shape for nothing).

DECODE_PATHS = ("auto", "fused", "kernel", "host")
FUSED_MIN_COUNT = 4096
_decode_path = "auto"


def decode_path() -> str:
    """The active decode-path policy (see DECODE_PATHS)."""
    return _decode_path


def set_decode_path(path: str) -> str:
    """Select the decode path globally; returns the previous policy so tests
    can restore it.  All paths are bit-identical — this is a dispatch knob,
    not a semantics knob."""
    global _decode_path
    if path not in DECODE_PATHS:
        raise ValueError(f"unknown decode path {path!r}; "
                         f"expected one of {DECODE_PATHS}")
    prev, _decode_path = _decode_path, path
    return prev


def use_fused_decode(count: int) -> bool:
    """Whether a group of ``count`` coefficients decodes through the fused
    device path under the active policy."""
    if _decode_path == "fused":
        return True
    if _decode_path == "auto":
        return count >= FUSED_MIN_COUNT
    return False


def _plane_pad(p: int) -> int:
    """Pad plane counts to the next power of two so the fused decode's jit
    cache sees a bounded set of plane-axis shapes (zero planes OR nothing
    into the magnitudes — padding is exact)."""
    n = 1
    while n < p:
        n <<= 1
    return n


def _pad_to(x: jnp.ndarray, mult: int, value=0):
    n = x.shape[0]
    rem = (-n) % mult
    if rem == 0:
        return x, n
    return jnp.pad(x, (0, rem), constant_values=value), n


def pack_bitplanes(mag: jnp.ndarray, nbits: int = 30,
                   rows: int | None = None) -> jnp.ndarray:
    """Arbitrary-length (N,) int32 -> (nbits, ceil32(N)) packed planes.
    Pads with zeros (zero magnitudes contribute zero bits).  ``rows=None``
    picks the backend-appropriate tile geometry (see module docstring)."""
    mag = jnp.asarray(mag, jnp.int32)
    interp = _interpret()
    if rows is None:
        if interp:
            padded, n = _pad_to(mag, LANES)
            rows = padded.shape[0] // LANES      # one whole-array tile
        else:
            rows = 8
            padded, n = _pad_to(mag, rows * LANES)
    else:
        padded, n = _pad_to(mag, rows * LANES)
    out = bitplane_pack(padded, nbits=nbits, rows=rows, interpret=interp)
    return out[:, : (n + 31) // 32]


@functools.partial(jax.jit, static_argnames=("nbits", "rows", "interpret"))
def _encode_planes_fused(c: jnp.ndarray, scale: jnp.ndarray, nbits: int,
                         rows: int, interpret: bool) -> jnp.ndarray:
    """Quantize f64 coefficients to nbits fixed point and pack every plane,
    all in ONE device dispatch (hi/lo uint32 split for nbits > 32)."""
    mag = jnp.floor(jnp.abs(c) * scale)
    mag = jnp.minimum(mag, np.float64(2.0 ** nbits - 1)).astype(jnp.uint64)
    lo = (mag & jnp.uint64(0xFFFFFFFF)).astype(jnp.uint32)
    if nbits <= 32:
        return pack_planes_traced(lo, nbits, rows, interpret)
    hi = (mag >> jnp.uint64(32)).astype(jnp.uint32)
    hi_planes = pack_planes_traced(hi, nbits - 32, rows, interpret)
    lo_planes = pack_planes_traced(lo, 32, rows, interpret)
    return jnp.concatenate([hi_planes, lo_planes], axis=0)


def encode_magnitude_planes(c: np.ndarray, scale: float,
                            nbits: int) -> np.ndarray:
    """(N,) float64 coefficients -> (nbits, ceil32(N)) uint32 packed planes
    of mag = min(floor(|c|*scale), 2^nbits - 1), MSB plane first.  The whole
    refactor hot loop — quantization, hi/lo split and per-plane packing —
    runs as a single fused jit dispatch; only zlib stays on the host."""
    c = jnp.asarray(c, jnp.float64)
    interp = _interpret()
    if interp:
        padded, n = _pad_to(c, LANES)
        rows = padded.shape[0] // LANES      # one whole-array tile
    else:
        rows = 8
        padded, n = _pad_to(c, rows * LANES)
    out = _encode_planes_fused(padded, jnp.float64(scale), nbits=nbits,
                               rows=rows, interpret=interp)
    return np.asarray(out)[:, : (n + 31) // 32]


def unpack_bitplanes(words, shifts, count: int) -> np.ndarray:
    """(P, ceil32(count)) uint32 packed planes + per-plane left shifts (< 64)
    -> (count,) uint64: OR over planes of (unpacked bits << shift).

    One vectorized call replaces the per-plane unpackbits loop of the legacy
    decoder.  On TPU this drives the ``bitplane_unpack`` Pallas kernel
    (shifts >= 32 via a hi/lo uint32 split); off-TPU a byte-plane NumPy
    accumulation — integer ops only, so both paths agree exactly.  The
    decode-path knob forces one implementation for conformance testing
    ("kernel" runs the Pallas kernel in interpret mode off-TPU).
    """
    words = np.ascontiguousarray(words, dtype=np.uint32)
    shifts = np.asarray(shifts, dtype=np.int64)
    if count == 0 or words.shape[0] == 0:
        return np.zeros(count, dtype=np.uint64)
    if _decode_path == "kernel" or not _interpret():
        return _unpack_kernel_u64(words, shifts, count)
    # Byte-plane accumulation (little-endian hosts): OR each plane into byte
    # column shift//8 of the uint64 output at sub-shift shift%8 — cheap uint8
    # passes, integer-exact by construction.  Bits are inflated per byte
    # column (<= 8 planes at a time), bounding the transient to ~8 planes'
    # bits even for archival-scale fields.
    nwords = words.shape[1]
    out = np.zeros(nwords * 32, dtype=np.uint64)
    out_bytes = out.view(np.uint8).reshape(-1, 8)
    q = shifts >> 3
    r = (shifts & 7).astype(np.uint8)
    for col in np.unique(q):
        sel = q == col
        bits = np.unpackbits(words[sel].view(np.uint8), axis=1,
                             bitorder="little")
        out_bytes[:, col] = np.bitwise_or.reduce(bits << r[sel, None], axis=0)
    return out[:count]


def _unpack_kernel_u64(words: np.ndarray, shifts: np.ndarray,
                       count: int, rows: int = 8) -> np.ndarray:
    """TPU path: split planes into hi (shift >= 32) / lo words, one kernel
    call each, recombine into uint64 magnitudes."""
    out = np.zeros(count, dtype=np.uint64)
    hi = shifts >= 32
    for sel, base in ((hi, 32), (~hi, 0)):
        if not np.any(sel):
            continue
        w = words[sel]
        pad = (-w.shape[1]) % (rows * WORDS_PER_ROW)
        if pad:
            w = np.pad(w, ((0, 0), (0, pad)))
        grp = bitplane_unpack(jnp.asarray(w),
                              jnp.asarray(shifts[sel] - base, jnp.uint32),
                              rows=rows)
        out |= np.asarray(grp, dtype=np.uint64)[:count] << np.uint64(base)
    return out


def _decode_fused_body(words, shifts, state, sign_bytes, scale):
    """Traced fused decode: unpack + OR-accumulate + sign + scale.

    words (P, W) uint32, shifts (P,) uint64, state (W*32,) uint64 magnitude
    carry-in, sign_bytes (W*4,) uint8 (packbits big-endian), scale f64 — all
    full-word-length so the jit cache keys only on (P, W).  Every op is
    integer-exact or an exact f64 op (scale is a power of two; sign flip is
    negation), which is what makes this path bit-identical to the host
    decoder.
    """
    nplanes, nwords = words.shape
    mag = state
    bit_idx = jnp.arange(32, dtype=jnp.uint32)
    for j in range(nplanes):                               # static unroll
        bits = (words[j][:, None] >> bit_idx) & jnp.uint32(1)
        mag = mag | (bits.reshape(nwords * 32).astype(jnp.uint64)
                     << shifts[j])
    sbits = (sign_bytes[:, None]
             >> jnp.arange(7, -1, -1, dtype=jnp.uint8)) & jnp.uint8(1)
    signs = sbits.reshape(nwords * 32).astype(bool)
    vals = mag.astype(jnp.float64) * scale
    vals = jnp.where(signs, -vals, vals)
    return mag, vals


@jax.jit
def _decode_fused(words, shifts, state, sign_bytes, scale):
    return _decode_fused_body(words, shifts, state, sign_bytes, scale)


@jax.jit
def _decode_fused_batch(words, shifts, state, sign_bytes, scale):
    """vmapped fused decode over a (B, P, W) stack of same-shape groups —
    one device dispatch per serve-plane tick bucket instead of one per
    reader (see repro.serve.batch)."""
    return jax.vmap(_decode_fused_body)(words, shifts, state, sign_bytes,
                                        scale)


def prepare_fused_decode(words: np.ndarray, shifts, state, sign_bytes,
                         count: int, plane_slots: int = 0):
    """Normalize decode inputs to the fused dispatch's full-word-length,
    plane-padded layout.  Returns ``(words, shifts, state, sign_bytes)``
    ready for ``_decode_fused`` (or for stacking into a batch): planes
    padded to a power of two with zero planes (exact no-ops), state and
    sign bytes padded to W*32 bits.  ``plane_slots`` forces at least that
    many plane slots — the decode batcher pads every item to one uniform
    plane count so same-width groups share a bucket (and a compiled batch
    graph) regardless of how many planes each actually fetched.  The pad
    happens host-side before the device transfer, so extra slots cost
    zero-word no-ops on device, not extra dispatches.
    """
    words = np.ascontiguousarray(words, dtype=np.uint32)
    if words.size == 0:
        # zero-plane flush (e.g. a follow-mode refresh that moved nothing):
        # normalize the degenerate (0,)/(0, 0) layouts to (0, W) so the
        # no-op plane padding below keeps the group's true word width —
        # otherwise the state/sign arrays (and any batch bucket keyed on W)
        # would be mis-shaped
        words = words.reshape(0, (int(count) + 31) // 32)
    nplanes, nwords = words.shape
    p_pad = _plane_pad(max(nplanes, 1, int(plane_slots)))
    if p_pad != nplanes:
        words = np.pad(words, ((0, p_pad - nplanes), (0, 0)))
    sh = np.zeros(p_pad, dtype=np.uint64)
    sh[:nplanes] = np.asarray(shifts, dtype=np.uint64)
    if state is None:
        st = jnp.zeros(nwords * 32, dtype=jnp.uint64)
    else:
        st = jnp.asarray(state, dtype=jnp.uint64)
        if st.shape[0] != nwords * 32:      # host-length carry-in
            st = jnp.pad(st, (0, nwords * 32 - st.shape[0]))
    sb = np.zeros(nwords * 4, dtype=np.uint8)
    raw = np.asarray(sign_bytes, dtype=np.uint8)
    sb[: raw.shape[0]] = raw
    return words, sh, st, sb


def decode_values_fused(words: np.ndarray, shifts, state, sign_bytes,
                        scale: float, count: int):
    """One fused jit dispatch from packed plane words to signed f64 values.

    ``words`` (P, ceil32(count)) uint32, ``shifts`` per-plane left shifts,
    ``state`` an optional uint64 magnitude carry-in ((count,) host array or
    a previous dispatch's full-length device array), ``sign_bytes`` the
    decoded (entropy-stage-inflated) packbits sign plane, ``scale`` =
    2^(E-B).  Returns device arrays ``(mag_full, values)`` where
    ``mag_full`` is the full-word-length magnitude state (feed it back as
    ``state``) and ``values`` is sliced to ``count`` — still on device, so
    it can feed scatter/recompose without a host round-trip.
    """
    w, sh, st, sb = prepare_fused_decode(words, shifts, state, sign_bytes,
                                         count)
    mag, vals = _decode_fused(w, sh, st, sb, jnp.float64(scale))
    return mag, vals[:count]


def level_surplus(x_even: jnp.ndarray, x_odd: jnp.ndarray,
                  rows: int = 8) -> jnp.ndarray:
    """Batched 1D surplus with automatic row padding."""
    b = x_odd.shape[0]
    rem = (-b) % rows
    if rem:
        x_even = jnp.pad(x_even, ((0, rem), (0, 0)))
        x_odd = jnp.pad(x_odd, ((0, rem), (0, 0)))
    out = hier_level_surplus(x_even, x_odd, rows=rows,
                             interpret=_interpret())
    return out[:b]


def vtotal_with_bound(vx: jnp.ndarray, vy: jnp.ndarray, vz: jnp.ndarray,
                      eps: jnp.ndarray, rows: int = 8):
    """Fused Vtotal (value, Thm-2 bound) for flat arrays of any length."""
    n = vx.shape[0]
    vx, _ = _pad_to(vx, rows * LANES)
    vy, _ = _pad_to(vy, rows * LANES)
    vz, _ = _pad_to(vz, rows * LANES)
    val, bound = qoi_vtotal_fused(vx, vy, vz, jnp.asarray(eps), rows=rows,
                                  interpret=_interpret())
    return val[:n], bound[:n]
