# Pallas TPU kernels for the compute hot-spots of the paper's pipeline:
#   bitplane_pack   - refactor hot loop: extract+pack bitplanes (VPU/MXU)
#   hier_level      - one deinterleaved hierarchical-surplus lifting level
#   qoi_vtotal      - retrieval hot loop: fused Vtotal value+bound evaluation
#
# Each kernel is written for TPU (pl.pallas_call + explicit BlockSpec VMEM
# tiling, 128-lane aligned) and validated on CPU in interpret mode against
# the pure-jnp oracles in ref.py via the jit wrappers in ops.py.
