"""Pallas TPU kernel: fused Vtotal value + error-bound evaluation.

The retrieval hot loop evaluates (value, bound) for every QoI each round
(Alg 2 lines 13-24). For Vtotal = sqrt(Vx²+Vy²+Vz²) the unfused jnp graph
materialises 6+ intermediates; this kernel fuses the whole
Thm 1 -> Thm 4 -> Thm 2 chain into one VMEM pass:

    s       = vx² + vy² + vz²
    eps_s   = Σ_i (2|v_i| ε_i + ε_i²)        (intpow + sum bounds)
    val     = sqrt(max(s, 0))
    bound   = eps_s / (sqrt(max(s - eps_s, 0)) + sqrt(s))   (paper Thm 2)

Per-variable ε are scalars (prefetched to SMEM-like (1,1) blocks); masked
points are handled by the caller zeroing ε at exact points is not needed
here because ε is uniform per variable — the wrapper applies the mask after.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
DEFAULT_ROWS = 8


def _kernel(vx_ref, vy_ref, vz_ref, eps_ref, val_ref, bound_ref):
    vx, vy, vz = vx_ref[...], vy_ref[...], vz_ref[...]
    ex, ey, ez = eps_ref[0, 0], eps_ref[0, 1], eps_ref[0, 2]
    s = vx * vx + vy * vy + vz * vz
    eps_s = (2.0 * jnp.abs(vx) * ex + ex * ex
             + 2.0 * jnp.abs(vy) * ey + ey * ey
             + 2.0 * jnp.abs(vz) * ez + ez * ez)
    s = jnp.maximum(s, 0.0)
    val = jnp.sqrt(s)
    denom = jnp.sqrt(jnp.maximum(s - eps_s, 0.0)) + val
    safe = jnp.where(denom > 0, denom, 1.0)
    bound = jnp.where(denom > 0, eps_s / safe, jnp.inf)
    val_ref[...] = val
    bound_ref[...] = bound


@functools.partial(jax.jit, static_argnames=("rows", "interpret"))
def qoi_vtotal_fused(vx: jnp.ndarray, vy: jnp.ndarray, vz: jnp.ndarray,
                     eps: jnp.ndarray, rows: int = DEFAULT_ROWS,
                     interpret: bool = True):
    """vx/vy/vz: (N,) with N % (rows*128) == 0; eps: (3,) per-variable bounds.
    Returns (val, bound), each (N,)."""
    n = vx.shape[0]
    if n % (rows * LANES):
        raise ValueError(f"N={n} must be a multiple of rows*128={rows * LANES}")
    tiles = n // (rows * LANES)
    shape2d = (tiles * rows, LANES)
    eps2d = eps.reshape(1, 3).astype(vx.dtype)
    val, bound = pl.pallas_call(
        _kernel,
        grid=(tiles,),
        in_specs=[pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
                  pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
                  pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
                  pl.BlockSpec((1, 3), lambda i: (0, 0))],
        out_specs=[pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
                   pl.BlockSpec((rows, LANES), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct(shape2d, vx.dtype),
                   jax.ShapeDtypeStruct(shape2d, vx.dtype)],
        interpret=interpret,
    )(vx.reshape(shape2d), vy.reshape(shape2d), vz.reshape(shape2d), eps2d)
    return val.reshape(n), bound.reshape(n)
