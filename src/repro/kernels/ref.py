"""Pure-jnp oracles for the Pallas kernels (allclose targets in tests)."""
from __future__ import annotations

import jax.numpy as jnp


def bitplane_pack_ref(mag: jnp.ndarray, nbits: int = 30) -> jnp.ndarray:
    """(N,) int32 -> (nbits, N//32) uint32 packed planes, MSB first."""
    n = mag.shape[0]
    planes = []
    pow2 = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    for b in range(nbits):
        bits = ((mag >> (nbits - 1 - b)) & 1).astype(jnp.uint32)
        packed = jnp.sum(bits.reshape(n // 32, 32) * pow2[None, :], axis=-1,
                         dtype=jnp.uint32)
        planes.append(packed)
    return jnp.stack(planes)


def bitplane_unpack_ref(words: jnp.ndarray, shifts) -> jnp.ndarray:
    """(P, W) uint32 packed planes + (P,) left shifts (< 64) ->
    (W*32,) uint64 OR-accumulated magnitudes (inverse of pack)."""
    p, w = words.shape
    bit_idx = jnp.arange(32, dtype=jnp.uint32)
    bits = ((jnp.asarray(words, jnp.uint32)[:, :, None] >> bit_idx)
            & jnp.uint32(1)).reshape(p, w * 32).astype(jnp.uint64)
    shifted = bits << jnp.asarray(shifts, jnp.uint64)[:, None]
    out = jnp.zeros(w * 32, jnp.uint64)
    for j in range(p):
        out = out | shifted[j]
    return out


def hier_level_surplus_ref(x_even: jnp.ndarray,
                           x_odd: jnp.ndarray) -> jnp.ndarray:
    return x_odd - 0.5 * (x_even[:, :-1] + x_even[:, 1:])


def qoi_vtotal_ref(vx, vy, vz, eps):
    ex, ey, ez = eps[0], eps[1], eps[2]
    s = vx * vx + vy * vy + vz * vz
    eps_s = (2.0 * jnp.abs(vx) * ex + ex * ex
             + 2.0 * jnp.abs(vy) * ey + ey * ey
             + 2.0 * jnp.abs(vz) * ez + ez * ez)
    s = jnp.maximum(s, 0.0)
    val = jnp.sqrt(s)
    denom = jnp.sqrt(jnp.maximum(s - eps_s, 0.0)) + val
    safe = jnp.where(denom > 0, denom, 1.0)
    bound = jnp.where(denom > 0, eps_s / safe, jnp.inf)
    return val, bound
