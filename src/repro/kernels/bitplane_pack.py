"""Pallas TPU kernel: bitplane extraction + packing (the refactor hot loop).

TPU adaptation of the paper's scalar bit loop (DESIGN.md §3): magnitudes are
int32 fixed point; plane b of a tile is ``(mag >> (B-1-b)) & 1``; 32 lanes
are packed into one uint32 by a dot with the power-of-two vector — a dense
VPU/MXU-friendly formulation with no data-dependent control flow.

Tile layout: input (ROWS, 128) int32 in VMEM; output (B, ROWS, 4) uint32
(4 packed words per 128-lane row). ROWS=8 keeps the working set at
8·128·4B (in) + B·8·4·4B (out) « 16 MiB VMEM, and both dims are
(8, 128)-register aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
WORDS_PER_ROW = LANES // 32   # 4 uint32 words per 128-lane row
DEFAULT_ROWS = 8


def _kernel(nbits: int, mag_ref, out_ref):
    mag = mag_ref[...]                                  # (ROWS, 128) int32
    rows = mag.shape[0]
    pow2 = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))  # (32,)
    grouped_shape = (rows, WORDS_PER_ROW, 32)
    for b in range(nbits):                               # static unroll
        bits = (mag >> (nbits - 1 - b)) & 1              # (ROWS, 128) int32
        g = bits.astype(jnp.uint32).reshape(grouped_shape)
        packed = jnp.sum(g * pow2[None, None, :], axis=-1,
                         dtype=jnp.uint32)               # (ROWS, 4)
        out_ref[b, :, :] = packed


def interpret_default() -> bool:
    """True off-TPU: run Pallas kernels through the interpreter.  Single
    source of the backend-dispatch policy for the whole kernels package."""
    return jax.default_backend() != "tpu"


def pack_planes_traced(mag: jnp.ndarray, nbits: int, rows: int,
                       interpret: bool) -> jnp.ndarray:
    """Traceable pack body (no jit wrapper): lets callers fuse the pallas
    call into a larger jitted graph (see ops.encode_magnitude_planes).
    ``mag`` may be any 32-bit integer dtype — only bit extraction happens."""
    n = mag.shape[0]
    if n % (rows * LANES):
        raise ValueError(f"N={n} must be a multiple of rows*128={rows * LANES}")
    tiles = n // (rows * LANES)
    mag2d = mag.reshape(tiles * rows, LANES)
    out = pl.pallas_call(
        functools.partial(_kernel, nbits),
        grid=(tiles,),
        in_specs=[pl.BlockSpec((rows, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((nbits, rows, WORDS_PER_ROW),
                               lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((nbits, tiles * rows, WORDS_PER_ROW),
                                       jnp.uint32),
        interpret=interpret,
    )(mag2d)
    return out.reshape(nbits, n // 32)


@functools.partial(jax.jit, static_argnames=("nbits", "rows", "interpret"))
def _pack(mag: jnp.ndarray, nbits: int, rows: int,
          interpret: bool) -> jnp.ndarray:
    return pack_planes_traced(mag, nbits, rows, interpret)


def bitplane_pack(mag: jnp.ndarray, nbits: int = 30,
                  rows: int = DEFAULT_ROWS,
                  interpret: bool | None = None) -> jnp.ndarray:
    """mag: (N,) int32 magnitude words (the low 32 bits may be reinterpreted
    sign bits — only bit extraction is performed), N % (rows*128) == 0.
    Returns (nbits, N // 32) uint32 packed planes, MSB plane first.
    ``interpret=None`` auto-detects the backend so direct callers compile on
    TPU instead of silently interpreting."""
    if interpret is None:
        interpret = interpret_default()
    return _pack(mag, nbits=nbits, rows=rows, interpret=bool(interpret))
