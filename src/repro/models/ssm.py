"""Mamba2 / SSD (state-space duality) blocks, arXiv:2405.21060.

Training path uses the chunked SSD algorithm: the sequence is split into
chunks of Q tokens; within a chunk the recurrence is computed as a masked
attention-like quadratic form (MXU-friendly), and chunk summary states are
passed through a lax.scan (the only sequential dependency, length S/Q).

Decode path is the O(1) recurrence: h' = exp(A·dt)·h + dt·B⊗x, y = C·h.

Layout: x (B,S,D) -> in_proj -> [z | xc | B | C | dt]; xc passes a short
causal conv1d; heads H = d_inner / headdim P; state N = cfg.ssm_state;
gated RMSNorm on output (y · silu(z)) then out_proj.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import _pdt, rmsnorm

Array = jnp.ndarray
Params = Dict[str, Array]


def init_ssd(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    di = cfg.d_inner
    h = cfg.ssm_heads
    n = cfg.ssm_state
    g = cfg.ssm_groups
    conv_dim = di + 2 * g * n
    keys = jax.random.split(key, 6)
    s = d ** -0.5
    proj_out = 2 * di + 2 * g * n + h   # z, xc, B, C, dt
    return {
        "in_proj": jax.random.normal(keys[0], (d, proj_out), _pdt(cfg)) * s,
        "conv_w": jax.random.normal(keys[1], (cfg.ssm_conv, conv_dim),
                                    _pdt(cfg)) * 0.2,
        "conv_b": jnp.zeros((conv_dim,), _pdt(cfg)),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm_scale": jnp.ones((di,), _pdt(cfg)),
        "out_proj": jax.random.normal(keys[2], (di, d), _pdt(cfg))
        * (di ** -0.5),
    }


def _split_proj(cfg: ModelConfig, proj: Array):
    di, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xc = proj[..., di:2 * di]
    bmat = proj[..., 2 * di:2 * di + g * n]
    cmat = proj[..., 2 * di + g * n:2 * di + 2 * g * n]
    dt = proj[..., 2 * di + 2 * g * n:]
    return z, xc, bmat, cmat, dt


def _conv1d(cfg: ModelConfig, w: Array, b: Array, x: Array,
            state: Array = None):
    """Causal depthwise conv over (B, S, C). state: (B, K-1, C) history for
    decode; returns (out, new_state)."""
    k = cfg.ssm_conv
    if state is None:
        pad = jnp.zeros(x.shape[:1] + (k - 1,) + x.shape[2:], x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i].astype(x.dtype)
              for i in range(k))
    out = jax.nn.silu(out + b.astype(x.dtype))
    new_state = xp[:, -(k - 1):, :] if k > 1 else pad
    return out, new_state


def _ssd_chunked(cfg: ModelConfig, xh: Array, dt: Array, a: Array,
                 bmat: Array, cmat: Array,
                 init_state: Array = None) -> Tuple[Array, Array]:
    """Chunked SSD scan.
    xh:   (B, S, H, P)    inputs per head
    dt:   (B, S, H)       positive step sizes
    a:    (H,)            positive decay rates (A = -a)
    bmat: (B, S, G, N), cmat: (B, S, G, N); heads map to groups H/G each.
    Returns y (B, S, H, P), final_state (B, H, N, P).
    """
    b, s, h, p = xh.shape
    g, n = bmat.shape[2], bmat.shape[3]
    q = min(cfg.ssm_chunk, s)
    assert s % q == 0, f"seq {s} not divisible by chunk {q}"
    nc = s // q
    hg = h // g
    mask = jnp.tril(jnp.ones((q, q), bool))[None, :, :, None]  # (1,Q,Q,1)

    # one chunk per scan step: only (B,Q,Q,H)-sized intermediates are ever
    # alive (materialising all NC chunks at once is O(B·S·Q·H) — hopeless at
    # 32k+ sequence lengths)
    xc = jnp.moveaxis(xh.reshape(b, nc, q, h, p), 1, 0)        # (NC,B,Q,H,P)
    dtc = jnp.moveaxis(dt.reshape(b, nc, q, h), 1, 0)          # (NC,B,Q,H)
    bc = jnp.moveaxis(bmat.reshape(b, nc, q, g, n), 1, 0)
    cc = jnp.moveaxis(cmat.reshape(b, nc, q, g, n), 1, 0)

    if init_state is None:
        init_state = jnp.zeros((b, h, n, p), xh.dtype)

    def step(state, inp):
        xcb, dtcb, bcb, ccb = inp          # (B,Q,H,P) (B,Q,H) (B,Q,G,N) x2
        ldec = dtcb * a[None, None, :]                       # (B,Q,H)
        cum = jnp.cumsum(ldec, axis=1)                       # inclusive
        li = cum[:, :, None, :]                              # (B,Q,1,H)
        lj = cum[:, None, :, :]                              # (B,1,Q,H)
        # double-where: keep exp() finite on the masked branch or its inf
        # poisons gradients through the where
        diff = jnp.where(mask, li - lj, 0.0)
        decay = jnp.where(mask, jnp.exp(-diff), 0.0)         # (B,Q,Q,H)
        cb = jnp.einsum("bqgn,bkgn->bqkg", ccb, bcb)         # (B,Q,Q,G)
        cbh = jnp.repeat(cb, hg, axis=-1)                    # (B,Q,Q,H)
        w = cbh.astype(jnp.float32) * decay * dtcb[:, None, :, :]
        y_intra = jnp.einsum("bqkh,bkhp->bqhp", w.astype(xh.dtype), xcb)

        # chunk summary: S_c = Σ_j exp(cum_Q - cum_j) dt_j B_j ⊗ x_j
        tail = jnp.exp(-(cum[:, -1:, :] - cum))              # (B,Q,H)
        bh = jnp.repeat(bcb, hg, axis=2)                     # (B,Q,H,N)
        wb = ((tail * dtcb)[..., None] * bh).astype(xh.dtype)  # (B,Q,H,N)
        s_c = jnp.einsum("bqhn,bqhp->bhnp", wb, xcb)         # (B,H,N,P)

        # inter-chunk: y += exp(-cum_i) C_i · state_in
        ch = jnp.repeat(ccb, hg, axis=2)                     # (B,Q,H,N)
        pref = jnp.exp(-cum)
        y_inter = jnp.einsum("bqhn,bhnp->bqhp", ch, state) \
            * pref[..., None].astype(xh.dtype)

        chunk_decay = jnp.exp(-cum[:, -1, :])                # (B,H)
        new_state = state * chunk_decay[..., None, None].astype(state.dtype) \
            + s_c
        return new_state, y_intra + y_inter

    final, ys = jax.lax.scan(step, init_state, (xc, dtc, bc, cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, p)
    return y, final


def ssd_block(p: Params, cfg: ModelConfig, x: Array) -> Array:
    """Full Mamba2 block (training/prefill): x (B,S,D) -> (B,S,D)."""
    proj = x @ p["in_proj"].astype(x.dtype)
    z, xc, bmat, cmat, dt = _split_proj(cfg, proj)
    conv_in = jnp.concatenate([xc, bmat, cmat], axis=-1)
    conv_out, _ = _conv1d(cfg, p["conv_w"], p["conv_b"], conv_in)
    di, g, n = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state
    xc = conv_out[..., :di]
    bmat = conv_out[..., di:di + g * n]
    cmat = conv_out[..., di + g * n:]
    b_, s_ = x.shape[0], x.shape[1]
    h, pd = cfg.ssm_heads, cfg.ssm_headdim
    xh = xc.reshape(b_, s_, h, pd)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = jnp.exp(p["a_log"])
    y, _ = _ssd_chunked(cfg, xh,
                        dt, a,
                        bmat.reshape(b_, s_, g, n),
                        cmat.reshape(b_, s_, g, n))
    y = y + xh * p["d_skip"][None, None, :, None].astype(xh.dtype)
    y = y.reshape(b_, s_, di)
    y = rmsnorm({"scale": p["norm_scale"]}, y * jax.nn.silu(z))
    return y @ p["out_proj"].astype(x.dtype)


def ssd_decode(p: Params, cfg: ModelConfig, x: Array,
               conv_state: Array, ssm_state: Array
               ) -> Tuple[Array, Array, Array]:
    """O(1) single-token decode. x: (B,1,D);
    conv_state (B, K-1, conv_dim); ssm_state (B,H,N,P)."""
    proj = x @ p["in_proj"].astype(x.dtype)
    z, xc, bmat, cmat, dt = _split_proj(cfg, proj)
    conv_in = jnp.concatenate([xc, bmat, cmat], axis=-1)
    conv_out, new_conv = _conv1d(cfg, p["conv_w"], p["conv_b"], conv_in,
                                 state=conv_state)
    di, g, n = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state
    xc = conv_out[..., :di]
    bmat = conv_out[..., di:di + g * n].reshape(-1, g, n)
    cmat = conv_out[..., di + g * n:].reshape(-1, g, n)
    b_ = x.shape[0]
    h, pd = cfg.ssm_heads, cfg.ssm_headdim
    hg = h // g
    xh = xc.reshape(b_, h, pd)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0, :]
    a = jnp.exp(p["a_log"])
    dec = jnp.exp(-dt * a[None, :])                          # (B,H)
    bh = jnp.repeat(bmat, hg, axis=1)                        # (B,H,N)
    ch = jnp.repeat(cmat, hg, axis=1)
    new_state = ssm_state * dec[..., None, None].astype(ssm_state.dtype) \
        + (dt[..., None, None].astype(xh.dtype)
           * bh[..., :, None] * xh[..., None, :])            # (B,H,N,P)
    y = jnp.einsum("bhn,bhnp->bhp", ch, new_state)
    y = y + xh * p["d_skip"][None, :, None].astype(xh.dtype)
    y = y.reshape(b_, 1, di)
    y = rmsnorm({"scale": p["norm_scale"]}, y * jax.nn.silu(z))
    return y @ p["out_proj"].astype(x.dtype), new_conv, new_state
