"""Mixture-of-Experts layer: top-k router + capacity-bounded dispatch.

Baseline dispatch is the Switch-style one-hot einsum (dense dispatch masks):
TPU-friendly (all matmuls, no gathers), deterministic, capacity-dropped.
A sort-based dispatch variant is provided for the §Perf hillclimb — it
replaces the (tokens × experts × capacity) dispatch einsums with argsort +
one-hot-free segment matmuls at lower HLO FLOPs.

Expert weights are stacked (E, D, F) so the expert dim shards over the
"model" mesh axis (expert parallelism); the combine path composes with a
shared expert (Llama-4 style) when cfg.n_shared_experts > 0.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import dist
from repro.models.config import ModelConfig
from repro.models.layers import _pdt

Array = jnp.ndarray
Params = Dict[str, Array]


def init_moe(key, cfg: ModelConfig) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    keys = jax.random.split(key, 5)
    s = d ** -0.5
    p = {
        "router": jax.random.normal(keys[0], (d, e), jnp.float32) * s,
        "wg": jax.random.normal(keys[1], (e, d, f), _pdt(cfg)) * s,
        "wu": jax.random.normal(keys[2], (e, d, f), _pdt(cfg)) * s,
        "wd": jax.random.normal(keys[3], (e, f, d), _pdt(cfg)) * (f ** -0.5),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        k1, k2, k3 = jax.random.split(keys[4], 3)
        p["shared_wg"] = jax.random.normal(k1, (d, fs), _pdt(cfg)) * s
        p["shared_wu"] = jax.random.normal(k2, (d, fs), _pdt(cfg)) * s
        p["shared_wd"] = jax.random.normal(k3, (fs, d), _pdt(cfg)) * (fs ** -0.5)
    return p


def _capacity(cfg: ModelConfig, n_tokens: int) -> int:
    cap = int(cfg.capacity_factor * n_tokens * cfg.top_k / cfg.n_experts)
    return max(cap - cap % -8 if cap % 8 else cap, 8)  # round up to 8


def moe_block(p: Params, cfg: ModelConfig, x: Array,
              dispatch: str = "scatter") -> Tuple[Array, Array]:
    """x: (B, S, D) -> (out, aux_loss). Dispatch: scatter | onehot | sort.

    scatter (default): cumsum-based queue positions + direct scatter/gather;
      memory O(N·E) ints + O(E·C·D) queues — the only SPMD-feasible option
      at production token counts.
    onehot: Switch/GShard dense dispatch masks — O(N·E·C); reference
      implementation, small shapes only.
    sort: argsort-based (§Perf variant, avoids the (N,E) cumsum).
    """
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    n = b * s
    # route in the compute dtype (softmax in f32): casting the full (N, D)
    # token tensor to f32 doubled the dominant dispatch collectives (§Perf)
    logits = (xt @ p["router"].astype(xt.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, cfg.top_k)    # (N, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # load-balancing aux loss (Switch): E * Σ_e f_e · p_e
    # (explicit f32: one_hot's default dtype follows jax_enable_x64 and a
    # f64 aux would poison the scan carry dtype)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(gate_idx[:, 0], cfg.n_experts,
                                 dtype=jnp.float32), axis=0)
    aux = jnp.float32(cfg.n_experts) * jnp.sum(me * ce)

    cap = _capacity(cfg, n)
    if dispatch == "onehot":
        out = _dispatch_onehot(p, cfg, xt, gate_vals, gate_idx, cap)
    elif dispatch == "scatter":
        out = _dispatch_scatter(p, cfg, xt, gate_vals, gate_idx, cap)
    else:
        out = _dispatch_sort(p, cfg, xt, gate_vals, gate_idx, cap)

    if cfg.n_shared_experts:
        g = jax.nn.silu(xt @ p["shared_wg"].astype(xt.dtype))
        u = xt @ p["shared_wu"].astype(xt.dtype)
        out = out + (g * u) @ p["shared_wd"].astype(xt.dtype)
    return out.reshape(b, s, d), aux


def _expert_ffn(p: Params, xe: Array) -> Array:
    """xe: (E, C, D) -> (E, C, D) via per-expert SwiGLU.

    Sharding hints (§Perf): expert queues live (E->"model", C->"data") so
    the expert matmuls run fully sharded — without the hints XLA leaves the
    scattered queues replicated and all-reduces (E,C,F)-sized partials."""
    xe = dist.hint(xe, "model", "data", None)
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["wg"].astype(xe.dtype)))
    u = jnp.einsum("ecd,edf->ecf", xe, p["wu"].astype(xe.dtype))
    g = dist.hint(g, "model", "data", None)
    u = dist.hint(u, "model", "data", None)
    out = jnp.einsum("ecf,efd->ecd", g * u, p["wd"].astype(xe.dtype))
    return dist.hint(out, "model", "data", None)


def _dispatch_onehot(p: Params, cfg: ModelConfig, xt: Array,
                     gate_vals: Array, gate_idx: Array, cap: int) -> Array:
    """Switch-style dense dispatch: build (N, E, C) one-hot dispatch/combine
    tensors and einsum. Baseline; HLO cost ~ 2·N·E·C·D extra FLOPs."""
    n, d = xt.shape
    e = cfg.n_experts
    expert_onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)  # (N,k,E)
    # position of each (token, slot) within its expert queue
    pos_in_expert = jnp.cumsum(expert_onehot.reshape(n * cfg.top_k, e),
                               axis=0).reshape(n, cfg.top_k, e) - 1.0
    keep = (pos_in_expert < cap) & (expert_onehot > 0)
    pos_clipped = jnp.clip(pos_in_expert, 0, cap - 1).astype(jnp.int32)
    cap_onehot = jax.nn.one_hot(pos_clipped, cap, dtype=jnp.float32)  # (N,k,E,C)
    dispatch = jnp.einsum("nke,nkec->nec",
                          expert_onehot * keep.astype(jnp.float32),
                          cap_onehot)                                 # (N,E,C)
    combine = jnp.einsum("nk,nke,nkec->nec",
                         gate_vals.astype(jnp.float32),
                         expert_onehot * keep.astype(jnp.float32),
                         cap_onehot)
    xe = jnp.einsum("nec,nd->ecd", dispatch.astype(xt.dtype), xt)
    ye = _expert_ffn(p, xe)
    return jnp.einsum("nec,ecd->nd", combine.astype(xt.dtype), ye)


def _dispatch_scatter(p: Params, cfg: ModelConfig, xt: Array,
                      gate_vals: Array, gate_idx: Array, cap: int) -> Array:
    """Cumsum queue positions + expert-space scatter/gather.

    §Perf-critical property: every cross-space data movement targets the
    (E·C, D) EXPERT space — dispatch is a scatter whose destination is
    expert-space (bwd: gather), combine is a gather whose source is
    expert-space (bwd: scatter-add, again expert-space). Token-space (N, D)
    scatter-adds never occur: `repeat`'s transpose is a *local* segment sum
    (and for top-1 it is the identity). The naive combine
    ``zeros(N,D).at[token].add(...)`` instead all-reduced an f32 (N, D)
    buffer per layer per pass — the dominant collective of the llama4
    baseline (EXPERIMENTS.md §Perf)."""
    n, d = xt.shape
    e, k = cfg.n_experts, cfg.top_k
    flat_expert = gate_idx.reshape(-1)                       # (N*k,)
    onehot = jax.nn.one_hot(flat_expert, e, dtype=jnp.int32)  # (N*k, E)
    pos = jnp.cumsum(onehot, axis=0) - onehot                 # exclusive
    pos_in_e = jnp.take_along_axis(pos, flat_expert[:, None],
                                   axis=1)[:, 0]              # (N*k,)
    keep = pos_in_e < cap
    slot = jnp.where(keep, flat_expert * cap + pos_in_e, e * cap)
    xt_rep = jnp.repeat(xt, k, axis=0) if k > 1 else xt      # (N·k, D)
    xq = jnp.zeros((e * cap + 1, d), xt.dtype).at[slot].set(xt_rep)
    ye = _expert_ffn(p, xq[:-1].reshape(e, cap, d)).reshape(e * cap, d)
    gathered = ye[jnp.minimum(slot, e * cap - 1)]             # (N·k, D)
    contrib = jnp.where(keep[:, None], gathered, 0.0) \
        * gate_vals.reshape(-1)[:, None].astype(xt.dtype)
    if k == 1:
        return contrib
    return jnp.sum(contrib.reshape(n, k, d), axis=1)          # local sum


def _dispatch_sort(p: Params, cfg: ModelConfig, xt: Array,
                   gate_vals: Array, gate_idx: Array, cap: int) -> Array:
    """Sort-based dispatch (§Perf variant): argsort (token,slot) pairs by
    expert id, gather tokens into (E, C) queues, run expert FFNs, scatter
    back. Replaces the O(N·E·C) dispatch einsums with O(N log N) sort +
    O(N·D) gathers."""
    n, d = xt.shape
    e, k = cfg.n_experts, cfg.top_k
    flat_expert = gate_idx.reshape(-1)                       # (N*k,)
    flat_gate = gate_vals.reshape(-1)
    flat_token = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)
    order = jnp.argsort(flat_expert, stable=True)
    sorted_expert = flat_expert[order]
    sorted_token = flat_token[order]
    sorted_gate = flat_gate[order]
    # position within expert queue
    same = jnp.concatenate([jnp.zeros(1, jnp.int32),
                            (sorted_expert[1:] == sorted_expert[:-1])
                            .astype(jnp.int32)])
    seg_start = jax.lax.cummax(
        jnp.where(same == 0, jnp.arange(n * k, dtype=jnp.int32), 0), axis=0)
    pos = jnp.arange(n * k, dtype=jnp.int32) - seg_start
    keep = pos < cap
    slot = jnp.where(keep, sorted_expert * cap + pos, e * cap)  # drop -> pad
    xq = jnp.zeros((e * cap + 1, d), xt.dtype).at[slot].set(
        xt[sorted_token])                                     # (E*C+1, D)
    ye = _expert_ffn(p, xq[:-1].reshape(e, cap, d)).reshape(e * cap, d)
    contrib = jnp.where(keep[:, None],
                        ye[jnp.minimum(slot, e * cap - 1)]
                        * sorted_gate[:, None].astype(xt.dtype), 0.0)
    out = jnp.zeros((n, d), xt.dtype).at[sorted_token].add(contrib)
    return out
