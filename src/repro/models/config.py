"""Model configuration for the assigned architecture zoo."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 => d_model // n_heads

    # attention
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    partial_rotary: float = 1.0   # fraction of head_dim rotated
    local_window: int = 0         # sliding-window size for local layers
    local_global_period: int = 0  # e.g. 6 => layers 0..4 local, 5 global
    tied_embeddings: bool = False
    act: str = "swiglu"           # swiglu | gelu

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    moe_dispatch: str = "scatter"   # scatter | onehot | sort

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 128
    ssm_groups: int = 1

    # hybrid (Zamba2-style shared attention block)
    shared_attn_period: int = 0   # apply shared attn after every N ssm layers

    # encoder-decoder
    n_encoder_layers: int = 0

    # modality frontend stubs
    frontend: str = ""            # "" | "patches" | "frames"
    n_frontend_tokens: int = 0    # prepended embedding tokens (vlm)

    # numerics / distribution
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    fsdp: bool = False            # shard params over the data axis too
    # training-phase layout for kv-nondivisible GQA archs: replicate attn
    # weights over "model" + batch-parallel attention compute (§Perf);
    # prefill/decode keep head-sharded weights (forward-only replication is
    # mild and backward score all-reduces don't exist there)
    attn_param_replication: bool = False
    remat: bool = True
    optimizer: str = "adamw"      # adamw | adafactor
    # long-context capability: decode beyond ~128k is only claimed for
    # sub-quadratic (SSM/hybrid) families
    sub_quadratic: bool = False
    # serving: "int8" stores the KV cache quantised (per-token-per-head
    # scales) — halves decode's weight/cache memory-streaming term (§Perf)
    kv_cache_dtype: str = ""

    @property
    def hd(self) -> int:
        if self.n_heads == 0:           # attention-free (pure SSM) archs
            return self.head_dim or 1
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""
    name: str                 # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                 # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def cell_is_runnable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """40-cell matrix skip rules (DESIGN.md §4)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("skip: long_500k requires sub-quadratic attention; "
                       f"{cfg.name} is a full-attention arch")
    return True, ""
