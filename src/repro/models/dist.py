"""Distribution context for model-internal sharding hints.

Model code is mesh-agnostic by default; the launcher (dryrun/train/serve)
registers the active mesh here, and layers consult it to place
with_sharding_constraint hints whose *need* depends on mesh geometry (e.g.
context-parallel attention only when kv_heads don't divide the model axis).
All entries besides the hinted dims stay UNCONSTRAINED so XLA keeps
propagating batch/data shardings.
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Optional

import jax
from jax.sharding import Mesh, PartitionSpec as P

_CTX = {"mesh": None}

# hint() entry sentinel: force this dim replicated (vs None = unconstrained)
REP = "__replicated__"


def set_mesh(mesh: Optional[Mesh]) -> None:
    _CTX["mesh"] = mesh


@contextmanager
def use_mesh(mesh: Optional[Mesh]):
    prev = _CTX["mesh"]
    _CTX["mesh"] = mesh
    try:
        yield
    finally:
        _CTX["mesh"] = prev


def axis_size(name: str) -> int:
    mesh = _CTX["mesh"]
    if mesh is None or name not in mesh.axis_names:
        return 1
    return int(mesh.shape[name])


def hint(x, *entries):
    """with_sharding_constraint with UNCONSTRAINED for None entries; no-op
    when no mesh is registered (pure-CPU tests) or dims don't divide."""
    mesh = _CTX["mesh"]
    if mesh is None:
        return x
    fixed = []
    for dim, e in zip(x.shape, entries):
        if e is None:
            fixed.append(P.UNCONSTRAINED)
            continue
        if e == REP:
            fixed.append(None)          # replicated
            continue
        names = e if isinstance(e, tuple) else (e,)
        size = 1
        for n in names:
            if n not in mesh.axis_names:
                return x
            size *= int(mesh.shape[n])
        fixed.append(e if dim % size == 0 else P.UNCONSTRAINED)
    fixed += [P.UNCONSTRAINED] * (x.ndim - len(fixed))
    return jax.lax.with_sharding_constraint(x, P(*fixed))
