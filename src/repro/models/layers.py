"""Common transformer layers: RMSNorm, RoPE, GQA attention, MLP.

Pure-JAX (no framework): parameters are plain dict pytrees created by
``init_*`` functions; every function takes explicit params and is
shard_map/pjit-agnostic (sharding is annotated at the train/serve step
level via PartitionSpec trees built in repro/train/sharding.py).

All math is explicitly dtyped: params in cfg.param_dtype, activations in
cfg.dtype, softmax/normalisation accumulation in float32.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import dist
from repro.models.config import ModelConfig

Array = jnp.ndarray
Params = Dict[str, Array]


def _dt(cfg: ModelConfig) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def _pdt(cfg: ModelConfig) -> jnp.dtype:
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------- RMSNorm --

def init_rmsnorm(key, d: int, cfg: ModelConfig) -> Params:
    return {"scale": jnp.ones((d,), _pdt(cfg))}


def rmsnorm(p: Params, x: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    return (normed * p["scale"].astype(jnp.float32)).astype(x.dtype)


# ------------------------------------------------------------------- RoPE --

def rope_frequencies(cfg: ModelConfig) -> Array:
    rot = int(cfg.hd * cfg.partial_rotary)
    rot -= rot % 2
    inv = 1.0 / (cfg.rope_theta ** (np.arange(0, rot, 2, dtype=np.float32)
                                    / rot))
    return jnp.asarray(inv, jnp.float32)  # (rot/2,)


def apply_rope(x: Array, positions: Array, inv_freq: Array) -> Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    rot2 = inv_freq.shape[0]
    angles = positions[..., :, None].astype(jnp.float32) * inv_freq  # (...,S,rot/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x_rot = x[..., : 2 * rot2]
    x_pass = x[..., 2 * rot2:]
    x1 = x_rot[..., 0::2]
    x2 = x_rot[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x1 * sin + x2 * cos
    y = jnp.stack([y1, y2], axis=-1).reshape(x_rot.shape)
    return jnp.concatenate([y.astype(x.dtype), x_pass], axis=-1)


# -------------------------------------------------------------- Attention --

def init_attention(key, cfg: ModelConfig) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    s = d ** -0.5
    p = {
        "wq": (jax.random.normal(k1, (d, h * hd), _pdt(cfg)) * s),
        "wk": (jax.random.normal(k2, (d, kv * hd), _pdt(cfg)) * s),
        "wv": (jax.random.normal(k3, (d, kv * hd), _pdt(cfg)) * s),
        "wo": (jax.random.normal(k4, (h * hd, d), _pdt(cfg)) * s),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), _pdt(cfg))
        p["bk"] = jnp.zeros((kv * hd,), _pdt(cfg))
        p["bv"] = jnp.zeros((kv * hd,), _pdt(cfg))
    return p


def _qkv(p: Params, cfg: ModelConfig, x: Array,
         positions: Array, inv_freq: Array, shard_cb=None):
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kv, hd)
    v = v.reshape(b, s, kv, hd)
    if shard_cb is not None:
        # reshard BEFORE RoPE: the post-rope tensors are f32 pairs and the
        # reshard would move twice the bytes (§Perf)
        q, k, v = shard_cb(q, k, v)
    if inv_freq.shape[0]:
        q = apply_rope(q, positions, inv_freq)
        k = apply_rope(k, positions, inv_freq)
    return q, k, v


def gqa_scores_mask(q_pos: Array, k_pos: Array, is_local: Array,
                    window: int) -> Array:
    """Causal mask, optionally restricted to a sliding window when
    ``is_local`` (a traced scalar bool — layers are scanned)."""
    causal = k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        local = causal & (q_pos[:, None] - k_pos[None, :] < window)
        return jnp.where(is_local, local, causal)
    return causal


def gqa_attend(q: Array, k: Array, v: Array, mask: Array) -> Array:
    """q: (B,S,H,hd), k/v: (B,T,K,hd), mask: (S,T) or (B,S,T)."""
    b, s, h, hd = q.shape
    t, kv = k.shape[1], k.shape[2]
    g = h // kv
    q = q.reshape(b, s, kv, g, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k).astype(jnp.float32)
    scores = scores / np.sqrt(hd)
    if mask.ndim == 2:
        mask_b = mask[None, None, None, :, :]
    else:
        mask_b = mask[:, None, None, :, :]
    scores = jnp.where(mask_b, scores, jnp.float32(-1e30))
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, s, h, hd)


# query-chunked attention: score tensors are O(B·H·Qc·T) instead of
# O(B·H·S·T) — mandatory at 4k+ training / 32k prefill sequence lengths
QUERY_CHUNK = 512


def gqa_attend_chunked(q: Array, k: Array, v: Array, q_pos: Array,
                       k_pos: Array, is_local: Array, window: int,
                       chunk: int = QUERY_CHUNK, ctx_mode: str = "") -> Array:
    b, s, h, hd = q.shape
    if s <= chunk:
        return gqa_attend(q, k, v,
                          gqa_scores_mask(q_pos, k_pos, is_local, window))
    pad = (-s) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, pad), constant_values=0)
    nq = (s + pad) // chunk
    qs = jnp.moveaxis(q.reshape(b, nq, chunk, h, hd), 1, 0)
    qp = q_pos.reshape(nq, chunk)
    if ctx_mode == "seq":
        # reshard ONCE outside the scan (a per-iteration hint gets hoisted
        # by XLA into a full-tensor all-gather — §Perf): within-chunk rows
        # shard over "model", heads replicated
        qs = dist.hint(qs, None, None, "model", dist.REP, dist.REP)

    def step(_, inp):
        qc, qpc = inp
        mask = gqa_scores_mask(qpc, k_pos, is_local, window)
        return None, gqa_attend(qc, k, v, mask)

    _, outs = jax.lax.scan(step, None, (qs, qp))
    if ctx_mode == "seq":
        outs = dist.hint(outs, None, None, "model", dist.REP, dist.REP)
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s + pad, h, hd)
    return out[:, :s]


def attention(p: Params, cfg: ModelConfig, x: Array, positions: Array,
              inv_freq: Array, is_local: Array) -> Array:
    b, s, _ = x.shape
    mode = _attn_shard_mode(cfg, b)

    def shard_cb(q, k, v):
        if mode == "batch":
            # batch-parallel attention (§Perf): when kv heads don't divide
            # the model axis, shard the whole attention block over
            # (data, model) on batch — scores AND their gradients stay
            # device-local; only the qkv/out reshards move bytes.
            spec = _full_batch_axes(b)
            q = dist.hint(q, spec, dist.REP, dist.REP, dist.REP)
            k = dist.hint(k, spec, dist.REP, dist.REP, dist.REP)
            v = dist.hint(v, spec, dist.REP, dist.REP, dist.REP)
        elif mode == "seq":
            # context parallelism for forward-only paths (prefill): K/V
            # gathered, query chunks seq-sharded (no dk/dv reduction exists)
            k = dist.hint(k, None, None, dist.REP, dist.REP)
            v = dist.hint(v, None, None, dist.REP, dist.REP)
        return q, k, v

    q, k, v = _qkv(p, cfg, x, positions, inv_freq,
                   shard_cb=shard_cb if mode else None)
    pos1d = positions[0] if positions.ndim > 1 else positions
    out = gqa_attend_chunked(q, k, v, pos1d, pos1d, is_local,
                             cfg.local_window, ctx_mode=mode)
    if mode == "batch":
        out = dist.hint(out, _full_batch_axes(b), dist.REP, dist.REP,
                        dist.REP)
    return out.reshape(b, s, -1) @ p["wo"].astype(x.dtype)


def _full_batch_axes(b: int):
    # data/model first: on the multipod mesh batch (256) divides data*model
    # (256) but not *512 — attention then replicates over "pod", which only
    # costs the 2x pod redundancy inside this block
    axes = []
    size = 1
    for a in ("data", "model", "pod"):
        sz = dist.axis_size(a)
        if sz > 1 and b % (size * sz) == 0:
            axes.append(a)
            size *= sz
    return tuple(axes)


def _attn_shard_mode(cfg: ModelConfig, b: int) -> str:
    """'' (plain: kv heads divide the model axis OR batch too small) |
    'batch' (shard the attention block on batch over data×model).

    A 'seq' (context-parallel) mode was tried and REFUTED for this code
    shape (EXPERIMENTS.md §Perf): scan-over-query-chunks forces either
    full-tensor gathers or per-iteration broadcasts when the within-chunk
    rows are model-sharded. With attention weights replicated over "model"
    (sharding.py) the plain mode has zero attention collectives at the cost
    of model-axis-replicated attention compute — the right trade at
    prefill batch sizes."""
    msize = dist.axis_size("model")
    if msize <= 1 or cfg.n_kv_heads % msize == 0:
        return ""
    if not cfg.attn_param_replication:
        return ""   # head-sharded weights: hints would fight the layout
    if b % (dist.axis_size("data") * msize) == 0:
        return "batch"
    return ""


def _attend_full_mask_chunked(q: Array, k: Array, v: Array,
                              chunk: int = 0) -> Array:
    """Unmasked attention with query chunking (encoders / cross-attn)."""
    b, s, h, hd = q.shape
    chunk = chunk or QUERY_CHUNK
    if s <= chunk:
        return gqa_attend(q, k, v, jnp.ones((s, k.shape[1]), bool))
    pad = (-s) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nq = (s + pad) // chunk
    qs = jnp.moveaxis(q.reshape(b, nq, chunk, h, hd), 1, 0)
    mask = jnp.ones((chunk, k.shape[1]), bool)

    def step(_, qc):
        return None, gqa_attend(qc, k, v, mask)

    _, outs = jax.lax.scan(step, None, qs)
    return jnp.moveaxis(outs, 0, 1).reshape(b, s + pad, h, hd)[:, :s]


def attention_bidir(p: Params, cfg: ModelConfig, x: Array, positions: Array,
                    inv_freq: Array) -> Array:
    """Bidirectional (encoder) attention — no causal mask."""
    b, s, _ = x.shape
    q, k, v = _qkv(p, cfg, x, positions, inv_freq)
    out = _attend_full_mask_chunked(q, k, v)
    return out.reshape(b, s, -1) @ p["wo"].astype(x.dtype)


def cross_attention(p: Params, cfg: ModelConfig, x: Array, enc_out: Array,
                    positions: Array, enc_positions: Array,
                    inv_freq: Array) -> Array:
    """Decoder cross-attention: queries from x, keys/values from enc_out."""
    b, s, _ = x.shape
    t = enc_out.shape[1]
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, s, h, hd)
    k = (enc_out @ p["wk"].astype(x.dtype)).reshape(b, t, kv, hd)
    v = (enc_out @ p["wv"].astype(x.dtype)).reshape(b, t, kv, hd)
    out = _attend_full_mask_chunked(q, k, v)
    return out.reshape(b, s, -1) @ p["wo"].astype(x.dtype)


def attention_decode(p: Params, cfg: ModelConfig, x: Array,
                     cache_k: Array, cache_v: Array, pos: Array,
                     inv_freq: Array, is_local: Array,
                     scales: Optional[Tuple[Array, Array]] = None):
    """Single-token decode: x (B,1,D); cache_k/v (B,T,K,hd); pos scalar.
    Returns (out, new_cache_k, new_cache_v[, new_scales]).

    With cfg.kv_cache_dtype == "int8", cache_k/v are int8 and ``scales``
    carries (k_scale, v_scale) of shape (B,T,K) — per-token-per-head
    symmetric quantisation. Memory streamed per decoded token drops ~2x
    (the dominant term of the decode roofline, §Perf)."""
    b = x.shape[0]
    t = cache_k.shape[1]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k, v = _qkv(p, cfg, x, positions, inv_freq)
    q8 = cfg.kv_cache_dtype == "int8"
    if q8:
        k_s, v_s = scales
        ks_new = jnp.max(jnp.abs(k), axis=-1) / 127.0        # (B,1,K)
        vs_new = jnp.max(jnp.abs(v), axis=-1) / 127.0
        k_q = jnp.round(k / jnp.maximum(ks_new, 1e-12)[..., None]
                        ).astype(jnp.int8)
        v_q = jnp.round(v / jnp.maximum(vs_new, 1e-12)[..., None]
                        ).astype(jnp.int8)
        cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k_q, pos,
                                                      axis=1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v_q, pos,
                                                      axis=1)
        k_s = jax.lax.dynamic_update_slice_in_dim(
            k_s, ks_new.astype(k_s.dtype), pos, axis=1)
        v_s = jax.lax.dynamic_update_slice_in_dim(
            v_s, vs_new.astype(v_s.dtype), pos, axis=1)
        kf = cache_k.astype(x.dtype) * k_s[..., None].astype(x.dtype)
        vf = cache_v.astype(x.dtype) * v_s[..., None].astype(x.dtype)
    else:
        cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k, pos,
                                                      axis=1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v, pos,
                                                      axis=1)
        kf, vf = cache_k, cache_v
    k_pos = jnp.arange(t, dtype=jnp.int32)
    mask = gqa_scores_mask(jnp.full((1,), pos, jnp.int32), k_pos,
                           is_local, cfg.local_window)
    out = gqa_attend(q, kf, vf, mask)
    out = out.reshape(b, 1, -1) @ p["wo"].astype(x.dtype)
    if q8:
        return out, cache_k, cache_v, (k_s, v_s)
    return out, cache_k, cache_v


# -------------------------------------------------------------------- MLP --

def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    s = d ** -0.5
    if cfg.act == "swiglu":
        k1, k2, k3 = jax.random.split(key, 3)
        return {"wg": jax.random.normal(k1, (d, f), _pdt(cfg)) * s,
                "wu": jax.random.normal(k2, (d, f), _pdt(cfg)) * s,
                "wd": jax.random.normal(k3, (f, d), _pdt(cfg)) * (f ** -0.5)}
    k1, k2 = jax.random.split(key, 2)
    return {"w1": jax.random.normal(k1, (d, f), _pdt(cfg)) * s,
            "w2": jax.random.normal(k2, (f, d), _pdt(cfg)) * (f ** -0.5)}


def mlp(p: Params, cfg: ModelConfig, x: Array) -> Array:
    if cfg.act == "swiglu":
        g = jax.nn.silu(x @ p["wg"].astype(x.dtype))
        u = x @ p["wu"].astype(x.dtype)
        return (g * u) @ p["wd"].astype(x.dtype)
    h = jax.nn.gelu(x @ p["w1"].astype(x.dtype))
    return h @ p["w2"].astype(x.dtype)


# ------------------------------------------------------------- Embeddings --

def init_embedding(key, cfg: ModelConfig) -> Params:
    p = {"table": jax.random.normal(key, (cfg.vocab, cfg.d_model),
                                    _pdt(cfg))}
    return p


def embed(p: Params, cfg: ModelConfig, tokens: Array) -> Array:
    return p["table"].astype(_dt(cfg))[tokens]


def unembed(p: Params, head: Optional[Array], cfg: ModelConfig,
            x: Array) -> Array:
    if cfg.tied_embeddings or head is None:
        return x @ p["table"].astype(x.dtype).T
    return x @ head.astype(x.dtype)
