"""Model assembly for all assigned families.

One module builds: parameter pytrees (layer-stacked for lax.scan), the
training forward/loss, and the single-token decode step with KV-cache /
SSM-state, for families:

  dense   pre-norm GQA transformer (gemma3/qwen2.5/internlm2/glm4)
  moe     dense attention + top-k MoE FFN (llama4-maverick, olmoe)
  ssm     Mamba2 / SSD stack (mamba2-780m)
  hybrid  Mamba2 backbone + shared attention block every K layers (zamba2)
  encdec  encoder-decoder with cross attention (seamless-m4t; audio frontend
          stubbed as precomputed frame embeddings)
  vlm     dense decoder with prepended patch embeddings (phi-3-vision; CLIP
          frontend stubbed)

Everything is scan-over-layers (compile-time O(1) in depth) with optional
jax.checkpoint remat around the layer body.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import dist
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models.config import ModelConfig

Array = jnp.ndarray
Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_block(key, cfg: ModelConfig) -> Params:
    """One transformer block's params (unstacked)."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {"norm1": L.init_rmsnorm(k1, cfg.d_model, cfg),
         "norm2": L.init_rmsnorm(k2, cfg.d_model, cfg)}
    if cfg.family == "ssm" or (cfg.family == "hybrid"):
        p["ssd"] = S.init_ssd(k3, cfg)
        return p
    p["attn"] = L.init_attention(k3, cfg)
    if cfg.family == "moe":
        p["moe"] = M.init_moe(k4, cfg)
    else:
        p["mlp"] = L.init_mlp(k4, cfg)
    return p


def _init_stacked(key, cfg: ModelConfig, n: int) -> Params:
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: _init_block(k, cfg))(keys)


def _init_cross_block(key, cfg: ModelConfig) -> Params:
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    return {"norm1": L.init_rmsnorm(k1, cfg.d_model, cfg),
            "norm2": L.init_rmsnorm(k2, cfg.d_model, cfg),
            "norm3": L.init_rmsnorm(k3, cfg.d_model, cfg),
            "attn": L.init_attention(k4, cfg),
            "cross": L.init_attention(k5, cfg),
            "mlp": L.init_mlp(k6, cfg)}


def init_params(key, cfg: ModelConfig) -> Params:
    keys = jax.random.split(key, 8)
    params: Params = {"embed": L.init_embedding(keys[0], cfg),
                      "final_norm": L.init_rmsnorm(keys[1], cfg.d_model, cfg)}
    if not cfg.tied_embeddings:
        params["lm_head"] = jax.random.normal(
            keys[2], (cfg.d_model, cfg.vocab),
            jnp.dtype(cfg.param_dtype)) * cfg.d_model ** -0.5

    if cfg.family == "encdec":
        params["encoder"] = {
            "layers": _init_stacked(keys[3], cfg.replace(family="dense"),
                                    cfg.n_encoder_layers),
            "final_norm": L.init_rmsnorm(keys[4], cfg.d_model, cfg)}
        dec_keys = jax.random.split(keys[5], cfg.n_layers)
        params["layers"] = jax.vmap(
            lambda k: _init_cross_block(k, cfg))(dec_keys)
        params["frame_proj"] = jax.random.normal(
            keys[7], (cfg.d_model, cfg.d_model),
            jnp.dtype(cfg.param_dtype)) * cfg.d_model ** -0.5
        return params

    params["layers"] = _init_stacked(keys[3], cfg, cfg.n_layers)

    if cfg.family == "hybrid":
        k1, k2, k3 = jax.random.split(keys[6], 3)
        d = cfg.d_model
        params["shared"] = {
            "norm1": L.init_rmsnorm(k1, d, cfg),
            "norm2": L.init_rmsnorm(k2, d, cfg),
            "attn": L.init_attention(k3, cfg),
            "mlp": L.init_mlp(jax.random.fold_in(k3, 1), cfg),
            # Zamba2: shared-block input = Linear(concat(h, embeddings))
            "fuse": jax.random.normal(jax.random.fold_in(keys[6], 2),
                                      (2 * d, d), jnp.dtype(cfg.param_dtype))
            * (2 * d) ** -0.5,
        }
    if cfg.family == "vlm":
        # projection of precomputed patch embeddings into d_model
        params["patch_proj"] = jax.random.normal(
            keys[7], (cfg.d_model, cfg.d_model),
            jnp.dtype(cfg.param_dtype)) * cfg.d_model ** -0.5
    if cfg.family == "encdec" or cfg.frontend == "frames":
        params["frame_proj"] = jax.random.normal(
            keys[7], (cfg.d_model, cfg.d_model),
            jnp.dtype(cfg.param_dtype)) * cfg.d_model ** -0.5
    return params


# ---------------------------------------------------------------------------
# Layer-type metadata (local/global pattern, shared-attn positions)
# ---------------------------------------------------------------------------


def layer_flags(cfg: ModelConfig) -> Dict[str, Array]:
    idx = np.arange(cfg.n_layers)
    if cfg.local_global_period > 0:
        is_local = (idx % cfg.local_global_period) != \
            (cfg.local_global_period - 1)
    else:
        is_local = np.zeros(cfg.n_layers, bool)
    if cfg.shared_attn_period > 0:
        shared_here = (idx % cfg.shared_attn_period) == \
            (cfg.shared_attn_period - 1)
    else:
        shared_here = np.zeros(cfg.n_layers, bool)
    return {"is_local": jnp.asarray(is_local),
            "shared_here": jnp.asarray(shared_here),
            "shared_idx": jnp.asarray(np.cumsum(shared_here) - 1)}


def n_shared_applications(cfg: ModelConfig) -> int:
    if cfg.shared_attn_period <= 0:
        return 0
    return cfg.n_layers // cfg.shared_attn_period


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


def _dense_block(bp: Params, cfg: ModelConfig, x: Array, positions: Array,
                 inv_freq: Array, is_local: Array) -> Tuple[Array, Array]:
    h = x + L.attention(bp["attn"], cfg, L.rmsnorm(bp["norm1"], x),
                        positions, inv_freq, is_local)
    if cfg.family == "moe":
        y, aux = M.moe_block(bp["moe"], cfg, L.rmsnorm(bp["norm2"], h),
                             dispatch=cfg.moe_dispatch)
        return h + y, aux
    return h + L.mlp(bp["mlp"], cfg, L.rmsnorm(bp["norm2"], h)), jnp.float32(0)


def _ssm_block(bp: Params, cfg: ModelConfig, x: Array) -> Array:
    return x + S.ssd_block(bp["ssd"], cfg, L.rmsnorm(bp["norm1"], x))


def _shared_attn(sp: Params, cfg: ModelConfig, x: Array, x0: Array,
                 positions: Array, inv_freq: Array) -> Array:
    fused = jnp.concatenate([x, x0], axis=-1) @ sp["fuse"].astype(x.dtype)
    h = fused + L.attention(sp["attn"], cfg,
                            L.rmsnorm(sp["norm1"], fused), positions,
                            inv_freq, jnp.asarray(False))
    return x + h + L.mlp(sp["mlp"], cfg, L.rmsnorm(sp["norm2"], h))


def _stack(cfg: ModelConfig, params: Params, x: Array, positions: Array,
           causal: bool = True) -> Tuple[Array, Array]:
    """Run the scanned layer stack. Returns (hidden, aux_loss_sum)."""
    inv_freq = L.rope_frequencies(cfg)
    flags = layer_flags(cfg)
    x0 = x
    shared = params.get("shared")

    def body(carry, inp):
        h, aux = carry
        bp, is_local = inp
        if cfg.family in ("ssm", "hybrid"):
            h = _ssm_block(bp, cfg, h)
            return (h, aux), None
        h, a = _dense_block(bp, cfg, h, positions, inv_freq,
                            is_local if causal else jnp.asarray(False))
        if seq_parallel_carry:
            # sequence-parallel residual stream (§Perf/memory): the scan
            # carry is the remat-saved layer boundary — storing it
            # seq-sharded over "model" cuts saved-activation HBM by the
            # model-axis size (48 layers x (B,S,D) does not fit otherwise
            # at 4k seq). Only with batch-parallel attention (replicated
            # attn weights): against head-sharded weights the per-layer
            # reshard degenerates into gathers (§Perf, refuted variant).
            h = dist.hint(h, None, "model", None)
        return (h, aux + a), None

    seq_parallel_carry = (
        cfg.attn_param_replication and dist.axis_size("model") > 1
        and cfg.n_kv_heads % dist.axis_size("model") != 0)
    body_fn = jax.checkpoint(body) if cfg.remat else body

    if cfg.family == "hybrid":
        # grouped: scan `period` Mamba2 layers, then the shared attn block
        # (static unroll over the n_apps groups keeps cache slices per-app)
        period = cfg.shared_attn_period
        napp = n_shared_applications(cfg)
        h, aux = x, jnp.float32(0)
        done = 0
        for g in range(napp):
            grp = jax.tree.map(lambda a: a[done:done + period],
                               params["layers"])
            (h, aux), _ = jax.lax.scan(
                body_fn, (h, aux), (grp, flags["is_local"][done:done + period]))
            h = _shared_attn(shared, cfg, h, x0, positions, inv_freq)
            done += period
        if done < cfg.n_layers:
            grp = jax.tree.map(lambda a: a[done:], params["layers"])
            (h, aux), _ = jax.lax.scan(
                body_fn, (h, aux), (grp, flags["is_local"][done:]))
        return h, aux

    (h, aux), _ = jax.lax.scan(
        body_fn, (x, jnp.float32(0)),
        (params["layers"], flags["is_local"]))
    return h, aux


def _encoder_stack(cfg: ModelConfig, params: Params, frames: Array) -> Array:
    """Bidirectional encoder over precomputed frame embeddings (stub
    frontend): frames (B, T, D)."""
    enc_cfg = cfg.replace(family="dense", remat=cfg.remat)
    x = frames @ params["frame_proj"].astype(frames.dtype)
    b, t, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
    inv_freq = L.rope_frequencies(enc_cfg)

    def body(h, bp):
        hh = h + L.attention_bidir(bp["attn"], enc_cfg, L.rmsnorm(bp["norm1"], h),
                                   positions, inv_freq)
        hh = hh + L.mlp(bp["mlp"], enc_cfg, L.rmsnorm(bp["norm2"], hh))
        return hh, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    h, _ = jax.lax.scan(body_fn, x, params["encoder"]["layers"])
    return L.rmsnorm(params["encoder"]["final_norm"], h)


def _decoder_stack_cross(cfg: ModelConfig, params: Params, x: Array,
                         enc_out: Array, positions: Array) -> Array:
    inv_freq = L.rope_frequencies(cfg)
    b, t_enc = enc_out.shape[0], enc_out.shape[1]
    enc_pos = jnp.broadcast_to(jnp.arange(t_enc, dtype=jnp.int32),
                               (b, t_enc))

    def body(h, bp):
        hh = h + L.attention(bp["attn"], cfg, L.rmsnorm(bp["norm1"], h),
                             positions, inv_freq, jnp.asarray(False))
        hh = hh + L.cross_attention(bp["cross"], cfg,
                                    L.rmsnorm(bp["norm2"], hh), enc_out,
                                    positions, enc_pos, inv_freq)
        hh = hh + L.mlp(bp["mlp"], cfg, L.rmsnorm(bp["norm3"], hh))
        return hh, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    h, _ = jax.lax.scan(body_fn, x, params["layers"])
    return h


def forward(params: Params, cfg: ModelConfig,
            batch: Dict[str, Array]) -> Tuple[Array, Array]:
    """-> (logits (B,S,V) over the *text* positions, aux_loss)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = L.embed(params["embed"], cfg, tokens)
    aux = jnp.float32(0)

    if cfg.family == "encdec":
        enc_out = _encoder_stack(cfg, params, batch["frames"])
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        h = _decoder_stack_cross(cfg, params, x, enc_out, positions)
    elif cfg.family == "vlm":
        patches = batch["patches"] @ params["patch_proj"].astype(x.dtype)
        x = jnp.concatenate([patches, x], axis=1)
        st = x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(st, dtype=jnp.int32), (b, st))
        h, aux = _stack(cfg, params, x, positions)
        h = h[:, patches.shape[1]:, :]   # logits over text positions only
    else:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        h, aux = _stack(cfg, params, x, positions)

    h = L.rmsnorm(params["final_norm"], h)
    logits = L.unembed(params["embed"], params.get("lm_head"), cfg, h)
    return logits, aux


def loss_fn(params: Params, cfg: ModelConfig,
            batch: Dict[str, Array]) -> Tuple[Array, Dict[str, Array]]:
    logits, aux = forward(params, cfg, batch)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    safe_labels_nll = (logz - gold) * mask
    ce = jnp.sum(safe_labels_nll) / jnp.maximum(jnp.sum(mask), 1.0)
    loss = ce + 0.01 * aux
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Decode (single token with cache)
# ---------------------------------------------------------------------------


def init_decode_state(cfg: ModelConfig, batch: int, max_seq: int,
                      dtype: Optional[str] = None) -> Dict[str, Array]:
    dt = jnp.dtype(dtype or cfg.dtype)
    kv, hd = cfg.n_kv_heads, cfg.hd
    state: Dict[str, Array] = {"pos": jnp.zeros((), jnp.int32)}
    if cfg.family in ("dense", "moe", "vlm"):
        if cfg.kv_cache_dtype == "int8":
            state["k"] = jnp.zeros((cfg.n_layers, batch, max_seq, kv, hd),
                                   jnp.int8)
            state["v"] = jnp.zeros((cfg.n_layers, batch, max_seq, kv, hd),
                                   jnp.int8)
            state["k_scale"] = jnp.zeros((cfg.n_layers, batch, max_seq, kv),
                                         jnp.float32)
            state["v_scale"] = jnp.zeros((cfg.n_layers, batch, max_seq, kv),
                                         jnp.float32)
        else:
            state["k"] = jnp.zeros((cfg.n_layers, batch, max_seq, kv, hd), dt)
            state["v"] = jnp.zeros((cfg.n_layers, batch, max_seq, kv, hd), dt)
    elif cfg.family == "ssm":
        conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
        state["conv"] = jnp.zeros((cfg.n_layers, batch, cfg.ssm_conv - 1,
                                   conv_dim), dt)
        state["ssm"] = jnp.zeros((cfg.n_layers, batch, cfg.ssm_heads,
                                  cfg.ssm_state, cfg.ssm_headdim), dt)
    elif cfg.family == "hybrid":
        conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
        state["conv"] = jnp.zeros((cfg.n_layers, batch, cfg.ssm_conv - 1,
                                   conv_dim), dt)
        state["ssm"] = jnp.zeros((cfg.n_layers, batch, cfg.ssm_heads,
                                  cfg.ssm_state, cfg.ssm_headdim), dt)
        napp = n_shared_applications(cfg)
        state["k"] = jnp.zeros((napp, batch, max_seq, kv, hd), dt)
        state["v"] = jnp.zeros((napp, batch, max_seq, kv, hd), dt)
        state["x0"] = jnp.zeros((batch, 1, cfg.d_model), dt)
    elif cfg.family == "encdec":
        state["k"] = jnp.zeros((cfg.n_layers, batch, max_seq, kv, hd), dt)
        state["v"] = jnp.zeros((cfg.n_layers, batch, max_seq, kv, hd), dt)
        # cached encoder output for cross-attention
        state["enc_out"] = jnp.zeros((batch, max_seq, cfg.d_model), dt)
    return state


def decode_step(params: Params, cfg: ModelConfig, state: Dict[str, Array],
                token: Array) -> Tuple[Array, Dict[str, Array]]:
    """token: (B, 1) int32 -> (logits (B, 1, V), new state)."""
    inv_freq = L.rope_frequencies(cfg)
    flags = layer_flags(cfg)
    x = L.embed(params["embed"], cfg, token)
    pos = state["pos"]

    if cfg.family in ("dense", "moe", "vlm"):
        q8 = cfg.kv_cache_dtype == "int8"

        def body(carry, inp):
            h = carry
            if q8:
                bp, ck, cv, ks, vs, is_local = inp
                a, nk, nv, (nks, nvs) = L.attention_decode(
                    bp["attn"], cfg, L.rmsnorm(bp["norm1"], h), ck, cv,
                    pos, inv_freq, is_local, scales=(ks, vs))
            else:
                bp, ck, cv, is_local = inp
                a, nk, nv = L.attention_decode(bp["attn"], cfg,
                                               L.rmsnorm(bp["norm1"], h),
                                               ck, cv, pos, inv_freq,
                                               is_local)
            h = h + a
            if cfg.family == "moe":
                y, _ = M.moe_block(bp["moe"], cfg, L.rmsnorm(bp["norm2"], h),
                                   dispatch=cfg.moe_dispatch)
                h = h + y
            else:
                h = h + L.mlp(bp["mlp"], cfg, L.rmsnorm(bp["norm2"], h))
            if q8:
                return h, (nk, nv, nks, nvs)
            return h, (nk, nv)

        if q8:
            h, (nk, nv, nks, nvs) = jax.lax.scan(
                body, x, (params["layers"], state["k"], state["v"],
                          state["k_scale"], state["v_scale"],
                          flags["is_local"]))
            new_state = dict(state, k=nk, v=nv, k_scale=nks, v_scale=nvs,
                             pos=pos + 1)
        else:
            h, (nk, nv) = jax.lax.scan(
                body, x, (params["layers"], state["k"], state["v"],
                          flags["is_local"]))
            new_state = dict(state, k=nk, v=nv, pos=pos + 1)

    elif cfg.family == "ssm":
        def body(carry, inp):
            h = carry
            bp, conv, ssm_s = inp
            y, nc, ns = S.ssd_decode(bp["ssd"], cfg,
                                     L.rmsnorm(bp["norm1"], h), conv, ssm_s)
            return h + y, (nc, ns)

        h, (nc, ns) = jax.lax.scan(
            body, x, (params["layers"], state["conv"], state["ssm"]))
        new_state = dict(state, conv=nc, ssm=ns, pos=pos + 1)

    elif cfg.family == "hybrid":
        shared = params["shared"]
        x0 = x

        def body(carry, inp):
            h = carry
            bp, conv, ssm_s = inp
            y, nc, ns = S.ssd_decode(bp["ssd"], cfg,
                                     L.rmsnorm(bp["norm1"], h), conv, ssm_s)
            return h + y, (nc, ns)

        period = cfg.shared_attn_period
        napp = n_shared_applications(cfg)
        h = x
        convs, ssms, ks, vs = [], [], [], []
        done = 0
        for g in range(napp):
            sl = slice(done, done + period)
            grp = jax.tree.map(lambda a: a[sl], params["layers"])
            h, (nc, ns) = jax.lax.scan(
                body, h, (grp, state["conv"][sl], state["ssm"][sl]))
            convs.append(nc)
            ssms.append(ns)
            fused = jnp.concatenate([h, x0], axis=-1) \
                @ shared["fuse"].astype(h.dtype)
            a, nk, nv = L.attention_decode(
                shared["attn"], cfg, L.rmsnorm(shared["norm1"], fused),
                state["k"][g], state["v"][g], pos, inv_freq,
                jnp.asarray(False))
            hh = fused + a
            h = h + hh + L.mlp(shared["mlp"], cfg,
                               L.rmsnorm(shared["norm2"], hh))
            ks.append(nk)
            vs.append(nv)
            done += period
        if done < cfg.n_layers:
            sl = slice(done, cfg.n_layers)
            grp = jax.tree.map(lambda a: a[sl], params["layers"])
            h, (nc, ns) = jax.lax.scan(
                body, h, (grp, state["conv"][sl], state["ssm"][sl]))
            convs.append(nc)
            ssms.append(ns)
        new_state = dict(state,
                         conv=jnp.concatenate(convs, axis=0),
                         ssm=jnp.concatenate(ssms, axis=0),
                         k=jnp.stack(ks, axis=0),
                         v=jnp.stack(vs, axis=0),
                         pos=pos + 1)

    elif cfg.family == "encdec":
        enc_out = state["enc_out"]
        b, t_enc = enc_out.shape[0], enc_out.shape[1]
        enc_pos = jnp.broadcast_to(jnp.arange(t_enc, dtype=jnp.int32),
                                   (b, t_enc))

        def body(carry, inp):
            h = carry
            bp, ck, cv = inp
            a, nk, nv = L.attention_decode(bp["attn"], cfg,
                                           L.rmsnorm(bp["norm1"], h),
                                           ck, cv, pos, inv_freq,
                                           jnp.asarray(False))
            h = h + a
            h = h + L.cross_attention(bp["cross"], cfg,
                                      L.rmsnorm(bp["norm2"], h), enc_out,
                                      jnp.full((b, 1), pos, jnp.int32),
                                      enc_pos, inv_freq)
            h = h + L.mlp(bp["mlp"], cfg, L.rmsnorm(bp["norm3"], h))
            return h, (nk, nv)

        h, (nk, nv) = jax.lax.scan(body, x,
                                   (params["layers"], state["k"], state["v"]))
        new_state = dict(state, k=nk, v=nv, pos=pos + 1)
    else:
        raise ValueError(cfg.family)

    h = L.rmsnorm(params["final_norm"], h)
    logits = L.unembed(params["embed"], params.get("lm_head"), cfg, h)
    return logits, new_state
