"""Synthetic stand-ins for the paper's datasets (Table III).

The real GE/NYX/Hurricane/S3D files are not available offline, so we generate
fields with the structural properties the experiments depend on: smooth
multi-scale variation (so multilevel coefficients decay and bitplanes
compress), physically-plausible positive pressure/density/temperature, a
fraction of exact-zero velocity nodes (wall boundaries — exercising the
outlier mask), and species concentrations spanning decades (S3D).
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np


def smooth_field(shape: Tuple[int, ...], seed: int, octaves: int = 5,
                 lo: float = -1.0, hi: float = 1.0,
                 roughness: float = 0.55) -> np.ndarray:
    """Sum of random low-frequency separable cosines — a cheap multi-scale
    'simulation-like' field with spectral decay."""
    rng = np.random.default_rng(seed)
    coords = [np.linspace(0.0, 1.0, n) for n in shape]
    out = np.zeros(shape, dtype=np.float64)
    amp = 1.0
    for o in range(octaves):
        freq = 2.0 ** o
        term = amp * np.ones(shape)
        for ax, c in enumerate(coords):
            phase = rng.uniform(0, 2 * np.pi)
            f = freq * rng.uniform(0.6, 1.4)
            wave = np.cos(2 * np.pi * f * c + phase)
            sl = [None] * len(shape)
            sl[ax] = slice(None)
            term = term * wave[tuple(sl)]
        out += term
        amp *= roughness
    out += 0.002 * rng.standard_normal(shape)  # measurement-scale noise
    omin, omax = out.min(), out.max()
    return lo + (hi - lo) * (out - omin) / (omax - omin)


def ge_like_fields(n: int = 1 << 16, seed: int = 0,
                   zero_fraction: float = 0.02) -> Dict[str, np.ndarray]:
    """GE CFD-like: Vx, Vy, Vz, P, D on a linearised (1D) unstructured mesh.
    A contiguous 'wall' region has exactly-zero velocity (outlier-mask case).
    """
    rng = np.random.default_rng(seed + 1000)
    fields = {
        "Vx": smooth_field((n,), seed + 1, lo=-250.0, hi=320.0),
        "Vy": smooth_field((n,), seed + 2, lo=-180.0, hi=260.0),
        "Vz": smooth_field((n,), seed + 3, lo=-90.0, hi=140.0),
        # pressure ~ [3e4, 1.2e5] Pa, density ~ [0.4, 1.6] kg/m3
        "P": smooth_field((n,), seed + 4, lo=3.0e4, hi=1.2e5),
        "D": smooth_field((n,), seed + 5, lo=0.4, hi=1.6),
    }
    n_zero = int(zero_fraction * n)
    if n_zero:
        start = int(rng.integers(0, n - n_zero))
        for v in ("Vx", "Vy", "Vz"):
            fields[v][start:start + n_zero] = 0.0
    return fields


def nyx_like_fields(shape: Tuple[int, int, int] = (33, 33, 33),
                    seed: int = 7) -> Dict[str, np.ndarray]:
    """NYX/Hurricane-like: 3D velocity components for total-velocity QoI."""
    return {
        "Vx": smooth_field(shape, seed + 1, lo=-3.2e7, hi=3.4e7),
        "Vy": smooth_field(shape, seed + 2, lo=-2.8e7, hi=3.1e7),
        "Vz": smooth_field(shape, seed + 3, lo=-3.0e7, hi=2.9e7),
    }


def s3d_like_fields(shape: Tuple[int, int, int] = (33, 33, 17),
                    seed: int = 13) -> Dict[str, np.ndarray]:
    """S3D-like: 8 species molar concentrations (positive, decades of scale);
    QoIs are pairwise multiplications (rate-of-progress intermediates)."""
    names = ["H2", "O2", "H2O", "H", "O", "OH", "HO2", "H2O2"]
    out = {}
    for i, nm in enumerate(names):
        base = smooth_field(shape, seed + i, lo=0.0, hi=1.0)
        scale = 10.0 ** (-2.0 * (i % 4))  # decades of magnitude
        out[f"x{i}"] = (1e-8 + base) * scale
        out[nm] = out[f"x{i}"]  # alias by species name too
    return out
