from repro.data.synthetic import (
    ge_like_fields,
    nyx_like_fields,
    s3d_like_fields,
    smooth_field,
)

__all__ = ["smooth_field", "ge_like_fields", "nyx_like_fields", "s3d_like_fields"]
