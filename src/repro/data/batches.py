"""Batch construction for the model zoo (synthetic token pipeline) and
ShapeDtypeStruct input_specs for the dry-run (no allocation)."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig, ShapeSpec


def make_train_batch(cfg: ModelConfig, batch: int, seq: int,
                     seed: int = 0) -> Dict[str, jnp.ndarray]:
    """Real (allocated) batch for smoke tests / the small trainer."""
    rng = np.random.default_rng(seed)
    tokens = rng.integers(0, cfg.vocab, size=(batch, seq), dtype=np.int32)
    labels = np.roll(tokens, -1, axis=1).astype(np.int32)
    labels[:, -1] = -1  # no target for final position
    out = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
    if cfg.family == "encdec":
        out["frames"] = jnp.asarray(
            rng.standard_normal((batch, seq, cfg.d_model)),
            jnp.dtype(cfg.dtype))
    if cfg.family == "vlm":
        out["patches"] = jnp.asarray(
            rng.standard_normal((batch, cfg.n_frontend_tokens, cfg.d_model)),
            jnp.dtype(cfg.dtype))
    return out


def train_input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every train/prefill input — weak-type
    correct, shardable, no device allocation."""
    b, s = shape.global_batch, shape.seq_len
    specs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
             "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if cfg.family == "encdec":
        specs["frames"] = jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                               jnp.dtype(cfg.dtype))
    if cfg.family == "vlm":
        specs["patches"] = jax.ShapeDtypeStruct(
            (b, cfg.n_frontend_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
    return specs


def decode_token_spec(cfg: ModelConfig, shape: ShapeSpec) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
