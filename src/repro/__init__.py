"""repro: error-controlled progressive retrieval of scientific data under
derivable QoIs (Wu et al., 2024), as a production JAX framework.

Subpackages:
  core / transform / bitplane / compressors   the paper
  models / configs / data                     architecture zoo + pipelines
  train / launch                              distributed substrate
  kernels                                     Pallas TPU kernels

Top-level API (lazily resolved, so ``import repro`` stays cheap):

    archive = repro.refactor(fields, method="hb")       # Algorithm 1
    repro.save_archive(archive, "ge.prs")               # one-shot container

    a = repro.open("ge.prs", repro.OpenOptions.default())
    with a.open(repro.SessionOptions.memory_bounded(64 << 20)) as s: ...

    w = repro.ArchiveWriter.create("live_dir")          # live v4 archive
    w.append({"Vx": frame}, eps=1e-3); ...; w.seal()

``repro.open`` is ``repro.store.open_archive``; the option objects are the
unified opener/session surface (see ``repro.options``).
"""
__version__ = "1.0.0"

__all__ = [
    "open",
    "open_archive",
    "refactor",
    "ArchiveWriter",
    "ensure_archive",
    "save_archive",
    "save_sharded_archive",
    "memory_store_archive",
    "OpenOptions",
    "SessionOptions",
    "ReproDeprecationWarning",
    "StoreArchive",
    "RetrievalSession",
    "FollowStream",
    "SegmentCache",
    "RetryPolicy",
    "BlobQuarantine",
]

# name -> "module:attr"; resolved on first attribute access (PEP 562) so the
# bare package import pulls in neither numpy-heavy codec modules nor jax
_LAZY = {
    "open": "repro.store.container:open_archive",
    "open_archive": "repro.store.container:open_archive",
    "refactor": "repro.core.refactor:refactor_variables",
    "ArchiveWriter": "repro.store.writer:ArchiveWriter",
    "ensure_archive": "repro.store.writer:ensure_archive",
    "save_archive": "repro.store.container:save_archive",
    "save_sharded_archive": "repro.store.container:save_sharded_archive",
    "memory_store_archive": "repro.store.container:memory_store_archive",
    "OpenOptions": "repro.options:OpenOptions",
    "SessionOptions": "repro.options:SessionOptions",
    "ReproDeprecationWarning": "repro.options:ReproDeprecationWarning",
    "StoreArchive": "repro.store.container:StoreArchive",
    "RetrievalSession": "repro.core.refactor:RetrievalSession",
    "FollowStream": "repro.core.refactor:FollowStream",
    "SegmentCache": "repro.store.cache:SegmentCache",
    "RetryPolicy": "repro.store.retry:RetryPolicy",
    "BlobQuarantine": "repro.store.retry:BlobQuarantine",
}


def __getattr__(name):
    target = _LAZY.get(name)
    if target is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib
    modname, attr = target.split(":")
    value = getattr(importlib.import_module(modname), attr)
    globals()[name] = value          # cache: resolve each name once
    return value


def __dir__():
    return sorted(set(globals()) | set(_LAZY))
