"""repro: error-controlled progressive retrieval of scientific data under
derivable QoIs (Wu et al., 2024), as a production JAX framework.

Subpackages:
  core / transform / bitplane / compressors   the paper
  models / configs / data                     architecture zoo + pipelines
  train / launch                              distributed substrate
  kernels                                     Pallas TPU kernels
"""
__version__ = "1.0.0"
