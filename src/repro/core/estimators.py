"""Vectorised QoI error-bound estimators (paper §IV, Theorems 1-6).

Every function maps (reconstructed value(s), L-inf error bound(s)) to an upper
bound on the error of the QoI evaluated at the *original* (unknown) values.
All functions are elementwise over arrays, pure jnp, and jit/vmap-safe.

Guard violations (Thm 3 / Thm 6 preconditions) return +inf, signalling the
retrieval loop (Alg 4) that the primary-data bound must be tightened before
the QoI error can be bounded at all.
"""
from __future__ import annotations

import math

import jax.numpy as jnp

Array = jnp.ndarray


def _inf_guard(eps_terms, finite_bound: Array) -> Array:
    """Propagate +inf child bounds without generating 0·inf = NaN: if any
    input bound is infinite the composite bound is infinite."""
    any_inf = jnp.zeros_like(finite_bound, dtype=bool)
    for e in eps_terms:
        any_inf = any_inf | jnp.isinf(e)
    return jnp.where(any_inf, jnp.inf, finite_bound)


# ---------------------------------------------------------------------------
# Univariate bases (Theorems 1-3)
# ---------------------------------------------------------------------------


def bound_intpow(x: Array, eps: Array, n: int) -> Array:
    """Theorem 1: f(x)=x^n, Δ ≤ Σ_{i=1..n} C(n,i) |x|^{n-i} ε^i  (n static)."""
    if n < 1:
        raise ValueError(f"intpow requires n >= 1, got {n}")
    ax = jnp.abs(x)
    total = jnp.zeros(jnp.broadcast_shapes(jnp.shape(x), jnp.shape(eps)),
                      dtype=jnp.result_type(x, eps, float))
    safe_eps = jnp.where(jnp.isinf(eps), 0.0, eps)
    eps_pow = safe_eps * jnp.ones_like(total)
    for i in range(1, n + 1):
        total = total + math.comb(n, i) * ax ** (n - i) * eps_pow
        eps_pow = eps_pow * safe_eps
    return _inf_guard([eps], total)


def bound_sqrt(x: Array, eps: Array, tight: bool = False) -> Array:
    """Theorem 2: f(x)=√x, Δ ≤ ε / (√max(x-ε, 0) + √x).

    ``tight=True`` uses the exact supremum over [max(x-ε,0), x+ε] instead of
    the paper's relaxation — a beyond-paper refinement that is finite at x=0
    (the paper handles x=0 through outlier masks instead).
    """
    xc = jnp.maximum(x, 0.0)
    safe_eps = jnp.where(jnp.isinf(eps), 0.0, eps)
    lo = jnp.sqrt(jnp.maximum(xc - safe_eps, 0.0))
    if tight:
        hi = jnp.sqrt(xc + jnp.maximum(safe_eps, 0.0))
        sx = jnp.sqrt(xc)
        return _inf_guard([eps], jnp.maximum(sx - lo, hi - sx))
    denom = lo + jnp.sqrt(xc)
    out = jnp.where(denom > 0, safe_eps / jnp.where(denom > 0, denom, 1.0),
                    jnp.inf)
    # exact inputs (ε = 0) have exactly zero QoI error even at x = 0
    return _inf_guard([eps], jnp.where(eps <= 0, 0.0, out))


def bound_radical(x: Array, eps: Array, c: float) -> Array:
    """Theorem 3: f(x)=1/(x+c), Δ ≤ ε / { min(|x+c-ε|, |x+c+ε|) · |x+c| }.

    Requires ε < |x+c|; +inf otherwise (retrieval must tighten ε first).
    """
    xc = x + c
    safe_eps = jnp.where(jnp.isinf(eps), 0.0, eps)
    ok = safe_eps < jnp.abs(xc)
    denom = jnp.minimum(jnp.abs(xc - safe_eps), jnp.abs(xc + safe_eps)) \
        * jnp.abs(xc)
    safe = jnp.where(ok & (denom > 0), denom, 1.0)
    out = jnp.where(ok & (denom > 0), safe_eps / safe, jnp.inf)
    return _inf_guard([eps], out)


def bound_log(x: Array, eps: Array) -> Array:
    """Beyond-paper basis: f(x)=ln(x), Δ ≤ ln(x / (x-ε)) for ε < x
    (the left edge dominates by concavity); +inf when ε >= x.

    Extends Table II for entropy/log-density QoIs; composes through
    Thms 7-9 like any other univariate basis."""
    safe_eps = jnp.where(jnp.isinf(eps), 0.0, eps)
    ok = (x > 0) & (safe_eps < x)
    denom = jnp.where(ok, x - safe_eps, 1.0)
    out = jnp.where(ok, jnp.log(jnp.where(ok, x, 1.0) / denom), jnp.inf)
    return _inf_guard([eps], jnp.where(eps <= 0, jnp.where(ok, 0.0, jnp.inf),
                                       out))


# ---------------------------------------------------------------------------
# Multivariate bases (Theorems 4-6)
# ---------------------------------------------------------------------------


def bound_sum(coeffs, eps_list) -> Array:
    """Theorem 4: g(x)=Σ a_i x_i, Δ ≤ Σ |a_i| ε_i."""
    total = 0.0
    for a, e in zip(coeffs, eps_list):
        total = total + abs(a) * e
    return jnp.asarray(total)


def bound_prod(x1: Array, eps1: Array, x2: Array, eps2: Array) -> Array:
    """Theorem 5: g=x1·x2, Δ ≤ |x1|ε2 + |x2|ε1 + ε1ε2."""
    e1 = jnp.where(jnp.isinf(eps1), 0.0, eps1)
    e2 = jnp.where(jnp.isinf(eps2), 0.0, eps2)
    return _inf_guard([eps1, eps2],
                      jnp.abs(x1) * e2 + jnp.abs(x2) * e1 + e1 * e2)


def bound_quot(x1: Array, eps1: Array, x2: Array, eps2: Array) -> Array:
    """Theorem 6: g=x1/x2, Δ ≤ (|x1|ε2 + |x2|ε1) / {|x2| min(|x2-ε2|,|x2+ε2|)}.

    Requires ε2 < |x2|; +inf otherwise.
    """
    e1 = jnp.where(jnp.isinf(eps1), 0.0, eps1)
    e2 = jnp.where(jnp.isinf(eps2), 0.0, eps2)
    ok = e2 < jnp.abs(x2)
    denom = jnp.abs(x2) * jnp.minimum(jnp.abs(x2 - e2), jnp.abs(x2 + e2))
    safe = jnp.where(ok & (denom > 0), denom, 1.0)
    num = jnp.abs(x1) * e2 + jnp.abs(x2) * e1
    return _inf_guard([eps1, eps2],
                      jnp.where(ok & (denom > 0), num / safe, jnp.inf))
