# The paper's primary contribution: error-controlled progressive retrieval
# under derivable quantities of interest (QoIs).
#
# The compression/retrieval pipeline operates on float64 scientific data, so
# importing repro.core enables x64. Model code (repro.models) is explicitly
# dtyped everywhere and is unaffected.
import repro._x64  # noqa: F401

from repro.core import estimators  # noqa: E402
from repro.core.qoi import (  # noqa: E402
    Const,
    Expr,
    IntPow,
    Prod,
    Quot,
    Radical,
    Sqrt,
    Sum,
    Var,
    frac_pow,
    magnitude,
    scale,
    square,
)
from repro.core.retrieval import (  # noqa: E402
    QoIRequest,
    RetrievalResult,
    assign_eb,
    retrieve_qoi_controlled,
)
from repro.core.refactor import refactor_variables  # noqa: E402

__all__ = [
    "estimators",
    "Expr", "Var", "Const", "Sum", "Prod", "Quot", "IntPow", "Sqrt", "Radical",
    "scale", "square", "magnitude", "frac_pow",
    "QoIRequest", "RetrievalResult", "assign_eb", "retrieve_qoi_controlled",
    "refactor_variables",
]
