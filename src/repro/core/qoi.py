"""Composable QoI expressions with guaranteed error-bound propagation.

Each node evaluates to a pair ``(value, bound)`` where ``value`` is the QoI
computed on the *reconstructed* data and ``bound`` is a guaranteed upper bound
on ``|QoI(original) - QoI(reconstructed)|`` given per-variable L-inf bounds.

Composition implements paper Theorems 7-9 and Lemmas 1-2 structurally: a
parent node applies its base estimator (estimators.py) treating each child's
``bound`` as the ε of a virtual input variable. This is exactly the paper's
derivation for e.g. total pressure PT (§IV-D), and remains a valid upper bound
even when children share primary variables (it may then be conservative,
never unsafe).

Expressions are plain Python trees of jnp ops: jit-able by closure, vmap-safe.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Sequence, Tuple

import jax.numpy as jnp

from repro.core import estimators as est

Array = jnp.ndarray
ValueBound = Tuple[Array, Array]


class Expr:
    """Base class of derivable-QoI expression nodes."""

    def eval(self, values: Dict[str, Array], ebs: Dict[str, Array]) -> ValueBound:
        raise NotImplementedError

    def variables(self) -> frozenset:
        raise NotImplementedError

    def value(self, values: Dict[str, Array]) -> Array:
        """Ground-truth evaluation (no error bounds) — used for oracles."""
        zeros = {k: jnp.zeros_like(jnp.asarray(v)) for k, v in values.items()}
        return self.eval(values, zeros)[0]

    # Operator sugar -------------------------------------------------------
    def __add__(self, other):
        return Sum([self, _lift(other)])

    def __radd__(self, other):
        return Sum([_lift(other), self])

    def __mul__(self, other):
        other = _lift(other)
        if isinstance(other, Const):
            return Sum([self], coeffs=[other.c])
        return Prod(self, other)

    def __rmul__(self, other):
        return self.__mul__(other)

    def __sub__(self, other):
        other = _lift(other)
        if isinstance(other, Const):
            return Sum([self, Const(-other.c)])
        return Sum([self, other], coeffs=[1.0, -1.0])

    def __truediv__(self, other):
        other = _lift(other)
        if isinstance(other, Const):
            return Sum([self], coeffs=[1.0 / other.c])
        return Quot(self, other)


def _lift(x) -> "Expr":
    if isinstance(x, Expr):
        return x
    return Const(float(x))


@dataclass(frozen=True)
class Var(Expr):
    """A primary data field; (value, bound) come straight from retrieval."""
    name: str

    def eval(self, values, ebs):
        v = jnp.asarray(values[self.name])
        e = jnp.broadcast_to(jnp.asarray(ebs[self.name]), v.shape)
        return v, e

    def variables(self):
        return frozenset({self.name})


@dataclass(frozen=True)
class Const(Expr):
    c: float

    def eval(self, values, ebs):
        return jnp.asarray(self.c), jnp.asarray(0.0)

    def variables(self):
        return frozenset()


@dataclass(frozen=True)
class Sum(Expr):
    """Weighted sum Σ a_i child_i + const  (Thms 4, 7, 8)."""
    children: Sequence[Expr]
    coeffs: Sequence[float] = None
    const: float = 0.0

    def __post_init__(self):
        # tuples so expressions hash structurally (the retrieval loop caches
        # jitted estimators per expression)
        object.__setattr__(self, "children", tuple(self.children))
        if self.coeffs is not None:
            object.__setattr__(self, "coeffs", tuple(self.coeffs))

    def eval(self, values, ebs):
        coeffs = self.coeffs if self.coeffs is not None else [1.0] * len(self.children)
        val = jnp.asarray(self.const)
        bnd = jnp.asarray(0.0)
        for a, ch in zip(coeffs, self.children):
            cv, cb = ch.eval(values, ebs)
            val = val + a * cv
            bnd = bnd + abs(a) * cb
        return val, bnd

    def variables(self):
        out = frozenset()
        for ch in self.children:
            out |= ch.variables()
        return out


@dataclass(frozen=True)
class Prod(Expr):
    """Binary product (Thm 5). Use repeated Prod for Π x_i (Thm 5 + Thm 9)."""
    a: Expr
    b: Expr

    def eval(self, values, ebs):
        av, ab = self.a.eval(values, ebs)
        bv, bb = self.b.eval(values, ebs)
        return av * bv, est.bound_prod(av, ab, bv, bb)

    def variables(self):
        return self.a.variables() | self.b.variables()


@dataclass(frozen=True)
class Quot(Expr):
    """Quotient a/b (Thm 6); bound is +inf until ε_b < |b|."""
    a: Expr
    b: Expr

    def eval(self, values, ebs):
        av, ab = self.a.eval(values, ebs)
        bv, bb = self.b.eval(values, ebs)
        safe = jnp.where(bv == 0, 1.0, bv)
        val = jnp.where(bv == 0, 0.0, av / safe)
        return val, est.bound_quot(av, ab, bv, bb)

    def variables(self):
        return self.a.variables() | self.b.variables()


@dataclass(frozen=True)
class IntPow(Expr):
    """child^n for integer n >= 1 (Thm 1 composed via Thm 9)."""
    child: Expr
    n: int

    def eval(self, values, ebs):
        cv, cb = self.child.eval(values, ebs)
        return cv ** self.n, est.bound_intpow(cv, cb, self.n)

    def variables(self):
        return self.child.variables()


@dataclass(frozen=True)
class Sqrt(Expr):
    """√child (Thm 2 composed via Thm 9). Values are clamped to [0, inf) —
    sqrt arguments in derivable QoIs are physically non-negative; a
    reconstruction dipping below zero is an artefact the clamp removes
    without weakening the bound (the true value is in [0, v+ε])."""
    child: Expr
    tight: bool = False

    def eval(self, values, ebs):
        cv, cb = self.child.eval(values, ebs)
        cv = jnp.maximum(cv, 0.0)
        return jnp.sqrt(cv), est.bound_sqrt(cv, cb, tight=self.tight)

    def variables(self):
        return self.child.variables()


@dataclass(frozen=True)
class Radical(Expr):
    """1/(child + c) (Thm 3 composed via Thm 9)."""
    child: Expr
    c: float = 0.0

    def eval(self, values, ebs):
        cv, cb = self.child.eval(values, ebs)
        xc = cv + self.c
        safe = jnp.where(xc == 0, 1.0, xc)
        val = jnp.where(xc == 0, 0.0, 1.0 / safe)
        return val, est.bound_radical(cv, cb, self.c)

    def variables(self):
        return self.child.variables()


@dataclass(frozen=True)
class Log(Expr):
    """ln(child) — beyond-paper basis (estimators.bound_log); +inf bound
    until ε < x, so the retrieval loop tightens near the domain edge just
    like the Thm 3/6 guards."""
    child: Expr

    def eval(self, values, ebs):
        cv, cb = self.child.eval(values, ebs)
        safe = jnp.maximum(cv, 1e-300)
        return jnp.log(safe), est.bound_log(cv, cb)

    def variables(self):
        return self.child.variables()


# ---------------------------------------------------------------------------
# Convenience builders
# ---------------------------------------------------------------------------


def scale(e: Expr, a: float, const: float = 0.0) -> Expr:
    return Sum([e], coeffs=[a], const=const)


def square(e: Expr) -> Expr:
    return IntPow(e, 2)


def magnitude(parts: Sequence[Expr], tight: bool = False) -> Expr:
    """sqrt(Σ e_i²) — e.g. total velocity (paper Eq. 1 / §IV-D)."""
    return Sqrt(Sum([square(p) for p in parts]), tight=tight)


def frac_pow(e: Expr, p: float, tight: bool = False) -> Expr:
    """e^p for p = k + m/2 (k int >= 0, m in {0, 1}), via x^k·√x compositions.

    Covers the paper's exponents: 1.5 (mu, Eq 6) and 3.5 (PT, Eq 5).
    """
    k = int(p)
    frac = p - k
    if abs(frac) < 1e-12:
        return IntPow(e, k) if k != 1 else e
    if abs(frac - 0.5) > 1e-12:
        raise ValueError(f"frac_pow supports half-integer exponents, got {p}")
    root = Sqrt(e, tight=tight)
    if k == 0:
        return root
    return Prod(IntPow(e, k) if k > 1 else e, root)
