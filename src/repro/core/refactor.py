"""Algorithm 1: GENERAL DATA REFACTOR — variables -> progressive archives.

Supported progressive representations (paper §V-B):
  * "hb"         PMGARD-HB: hierarchical-basis multilevel + bitplanes (paper's
                 preferred method — tight Σ_l e_l bound)
  * "ob"         PMGARD (orthogonal basis): + L² projection, loose bound
  * "ip"         interpolation-predicted: closed-loop residuals against the
                 decoder's truncated reconstruction; max_g e_g bound once
                 every group reaches its recorded prediction depth (see
                 transform/hierarchical.py `ip` section)
  * "psz3"       multi-snapshot SZ3-like ladder
  * "psz3_delta" residual-ladder SZ3-like

Every representation satisfies Definition 1: refactor into segments, then
reconstruct from a prefix with a *guaranteed, reported* L-inf bound. The
retrieval session gives a uniform interface to the QoI-preserved retrieval
loop (core/retrieval.py).

Incremental recomposition (§Perf, HB linearity)
-----------------------------------------------
``recompose_hb`` is linear, and a coefficient field supported on levels
<= l is untouched by the recompose steps coarser than l.  The HB reader
therefore represents the reconstruction as the fixed-order sum of
*per-level contribution fields*

    x̂ = Σ_{l = L..0}  recompose_hb_from(scatter(values_l), start=l)

and caches each contribution keyed by the level's fetched-plane count.
When a retrieval iteration moves planes of only a few levels, only those
levels' contributions are recomputed (a partial recompose from level l
down — for the finest level a pure scatter, no interpolation at all)
instead of re-running the full multilevel recompose on every iteration.
Because each contribution is a pure function of that level's decoded
values, and the codec's integer arithmetic makes decoded values depend
only on the final plane counts, *any* fetch schedule ending in the same
plane counts yields a bit-identical reconstruction — asserted against
from-scratch sessions in tests/test_incremental_recompose.py.

Bounded contribution cache (memory-budgeted retrieval)
------------------------------------------------------
Unbounded, the contribution cache holds one full-grid f64 field per
coefficient group — (L+1)·n·8 bytes per variable — which becomes the
server's scaling wall long before the segment bytes do.  Passing
``contrib_budget_bytes`` to ``open_reader`` / ``RetrievalSession`` /
``Archive.open`` caps the *retained* cache: the reader keeps at most
``budget // (n·8)`` contribution fields resident, finest levels first
(level 0 is the hottest — size-weighted budgets give it the most planes
in flight, and its rebuild skips every interpolation step but the last),
and spills the coarsest fields.  A spilled contribution is transparently
rebuilt through ``recompose_hb_from`` on the next refresh that needs it;
because contributions are pure functions of decoded values and the
summation order is fixed (coarse -> fine), a bounded reader reconstructs
*bit-identically* to an unbounded one at every requested eps — a zero
budget simply degrades to recompute-always.  The refresh streams the sum
(compute one contribution, add, then retain or drop it), so transient
working memory is two fields regardless of budget.  Spill/recompute/
residency counters land in ``ContribStats`` — store-backed readers share
their fetcher's ``FetchStats``, which carries the same fields (see
repro.store.fetcher).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.bitplane.encoder import (
    LevelBitplanes,
    decode_prefix,
    encode_level,
    plane_bound,
    planes_needed,
)
from repro.bitplane.segments import InMemoryPlaneSource, LevelStream
from repro.compressors.snapshots import (
    DeltaSnapshotArchive,
    SnapshotArchive,
    default_snapshot_eps,
)
from repro.core.masks import OutlierMask, build_zero_velocity_mask
from repro.options import SessionOptions, _from_legacy
from repro.transform.hierarchical import (
    decompose_hb,
    grid_levels,
    ip_error_bound,
    level_map,
    pad_to_grid,
    recompose_hb,
    recompose_hb_from,
    scatter_recompose_from,
    scatter_recompose_ip_from,
    trunc_to_quantum,
    unpad,
)
from repro.transform.orthogonal import decompose_ob, ob_kappa, recompose_ob

METHODS = ("hb", "ob", "ip", "psz3", "psz3_delta")


def _pred_planes(meta) -> int:
    """Recorded `ip` prediction depth of a group; archives written before
    the field existed (or non-ip groups) default to full depth — the
    truncation becomes the identity and the contribution degenerates to
    the plain HB form."""
    return meta.pred_planes if meta.pred_planes is not None else meta.nbits


def _resolve_session_options(options: Optional[SessionOptions],
                             legacy: dict, where: str) -> SessionOptions:
    """Shared shim: an explicit SessionOptions wins; loose legacy kwargs
    build one through the once-warning deprecation path; neither means the
    defaults.  Mixing the two spellings is a hard error — silently merging
    them would make the options object lie about what the session uses."""
    if legacy:
        if options is not None:
            raise TypeError(f"{where}: pass either a SessionOptions object "
                            f"or legacy keyword arguments, not both")
        return _from_legacy(SessionOptions, legacy, where)
    return options if options is not None else SessionOptions()


@dataclass(frozen=True)
class VarAvailability:
    """Availability report for one variable of a degraded session.

    ``floor`` is the tightest L-inf bound the variable can still certify
    from the segments that *are* deliverable (for a healthy variable: the
    codec's own floor at full plane depth).  ``pinned`` marks variables the
    retrieval loop must stop tightening — requesting a smaller eps cannot
    move more bytes.  ``detail`` carries the first underlying cause
    (human-readable, for the serve-plane degradation report)."""
    pinned: bool
    floor: float
    detail: str = ""


@dataclass
class ContribStats:
    """Contribution-cache accounting for one (or more) bitplane readers.

    Field names deliberately match the ``contrib_*`` counters on
    ``repro.store.fetcher.FetchStats`` so a store-backed reader can bump its
    fetcher's stats object directly and a server sees one aggregate:

      * ``contrib_resident_bytes`` — contribution fields currently retained.
      * ``contrib_peak_bytes``     — high-water mark of the above (the
        RSS-proxy the memory-bound bench tracks; transient working fields
        during a refresh are not counted — they are bounded by two fields).
      * ``contrib_spills``         — contribution fields computed for a
        refresh and then dropped instead of retained (budget pressure);
        each may have to be rebuilt by a later refresh.
      * ``contrib_recomputes``     — budget-induced rebuilds: refreshes of a
        level whose plane count had NOT moved (an unbounded reader would
        have served it from cache).

    A sink is often SHARED — store-backed readers across every concurrent
    session of one archive aggregate into their fetcher's FetchStats — so
    all mutation funnels through ``contrib_note`` (one lock-guarded
    read-modify-write; the peak update must see its own delta, which bare
    ``+=`` from racing threads cannot guarantee).
    """
    contrib_resident_bytes: int = 0
    contrib_peak_bytes: int = 0
    contrib_spills: int = 0
    contrib_recomputes: int = 0

    def __post_init__(self) -> None:
        self._mu = threading.Lock()

    def contrib_note(self, delta_bytes: int = 0, spills: int = 0,
                     recomputes: int = 0) -> None:
        """Atomically apply a residency delta / spill / recompute event."""
        with self._mu:
            self.contrib_resident_bytes += delta_bytes
            if self.contrib_resident_bytes > self.contrib_peak_bytes:
                self.contrib_peak_bytes = self.contrib_resident_bytes
            self.contrib_spills += spills
            self.contrib_recomputes += recomputes

    def contrib_snapshot(self) -> Tuple[int, int, int, int]:
        with self._mu:
            return (self.contrib_resident_bytes, self.contrib_peak_bytes,
                    self.contrib_spills, self.contrib_recomputes)

    def merge(self, other) -> "ContribStats":
        """Accumulate another carrier of the ``contrib_*`` counters
        (another ContribStats, or a store fetcher's FetchStats)."""
        snap = other.contrib_snapshot() if hasattr(other, "contrib_snapshot") \
            else (other.contrib_resident_bytes, other.contrib_peak_bytes,
                  other.contrib_spills, other.contrib_recomputes)
        with self._mu:
            self.contrib_resident_bytes += snap[0]
            self.contrib_peak_bytes += snap[1]
            self.contrib_spills += snap[2]
            self.contrib_recomputes += snap[3]
        return self


# ---------------------------------------------------------------------------
# Per-variable archives
# ---------------------------------------------------------------------------


@dataclass
class BitplaneVarArchive:
    """PMGARD-HB/OB: per-level bitplane groups over the multilevel transform."""
    method: str                    # "hb" | "ob" | "ip"
    orig_shape: Tuple[int, ...]
    padded_shape: Tuple[int, ...]
    levels: int
    groups: List[LevelBitplanes]   # detail levels 0..L-1, then base (index L)
    group_indices: List[np.ndarray]

    @property
    def total_nbytes(self) -> int:
        return sum(g.total_nbytes for g in self.groups)

    def plane_sources(self) -> List[InMemoryPlaneSource]:
        """One PlaneSource per coefficient group — the uniform segment-access
        surface shared with store-backed variables (repro.store)."""
        return [InMemoryPlaneSource(g) for g in self.groups]

    def open_reader(self, options: Optional[SessionOptions] = None,
                    **legacy) -> "_BitplaneVarReader":
        opts = _resolve_session_options(options, legacy,
                                        "BitplaneVarArchive.open_reader")
        return _BitplaneVarReader(
            self, contrib_budget_bytes=opts.contrib_budget_bytes,
            contrib_pool=opts.contrib_pool,
            decode_batcher=opts.decode_batcher)


@dataclass
class SnapshotVarArchive:
    archive: object                # SnapshotArchive | DeltaSnapshotArchive

    @property
    def total_nbytes(self) -> int:
        return self.archive.total_nbytes

    def open_reader(self, options: Optional[SessionOptions] = None,
                    **legacy) -> "_SnapshotVarReader":
        # snapshot readers hold at most one decoded field; the contribution
        # budget/pool is a bitplane-reader concept and is accepted (and
        # validated) for interface uniformity only
        _resolve_session_options(options, legacy,
                                 "SnapshotVarArchive.open_reader")
        return _SnapshotVarReader(self)


@dataclass
class Archive:
    """Refactored multi-precision segments + metadata for all variables."""
    method: str
    variables: Dict[str, object]
    masks: Dict[str, OutlierMask]
    ranges: Dict[str, float]
    shapes: Dict[str, Tuple[int, ...]]

    @property
    def total_nbytes(self) -> int:
        n = sum(v.total_nbytes for v in self.variables.values())
        n += sum(m.nbytes for m in self.masks.values())
        return n

    def open(self, options: Optional[SessionOptions] = None,
             **legacy) -> "RetrievalSession":
        opts = _resolve_session_options(options, legacy, "Archive.open")
        return RetrievalSession(self, opts)

    def n_elements(self, name: str) -> int:
        return int(np.prod(self.shapes[name]))


def refactor_variables(fields: Dict[str, np.ndarray],
                       method: str = "hb",
                       nbits: int = 48,
                       max_levels: int = 32,
                       snapshot_eps: Optional[Sequence[float]] = None,
                       n_snapshots: int = 10,
                       mask_zero_velocity: bool = True) -> Archive:
    if method not in METHODS:
        raise ValueError(f"unknown method {method!r}; expected one of {METHODS}")
    masks = build_zero_velocity_mask(fields) if mask_zero_velocity else {}
    variables: Dict[str, object] = {}
    ranges: Dict[str, float] = {}
    shapes: Dict[str, Tuple[int, ...]] = {}
    for name, data in fields.items():
        data = np.asarray(data, dtype=np.float64)
        shapes[name] = data.shape
        rng = float(np.max(data) - np.min(data))
        ranges[name] = rng if rng > 0 else 1.0
        if method in ("hb", "ob", "ip"):
            variables[name] = _build_bitplane_var(data, method, nbits, max_levels)
        else:
            ladder = list(snapshot_eps) if snapshot_eps is not None else \
                default_snapshot_eps(ranges[name], n=n_snapshots)
            if method == "psz3":
                variables[name] = SnapshotVarArchive(
                    SnapshotArchive.build(data, ladder))
            else:
                variables[name] = SnapshotVarArchive(
                    DeltaSnapshotArchive.build(data, ladder))
    return Archive(method=method, variables=variables, masks=masks,
                   ranges=ranges, shapes=shapes)


def _build_bitplane_var(data: np.ndarray, method: str, nbits: int,
                        max_levels: int) -> BitplaneVarArchive:
    padded, orig_shape = pad_to_grid(data)
    levels = grid_levels(padded.shape, max_levels)
    if method == "ip":
        groups, indices = _encode_ip_groups(padded, levels, nbits)
    else:
        transform = decompose_hb if method == "hb" else decompose_ob
        coeffs = np.asarray(transform(padded, levels))
        lmap = level_map(padded.shape, levels).ravel()
        flat = coeffs.ravel()
        groups, indices = [], []
        for l in range(levels + 1):      # details 0..L-1, base = L
            idx = np.flatnonzero(lmap == l)
            groups.append(encode_level(flat[idx], nbits=nbits))
            indices.append(idx)
    return BitplaneVarArchive(method=method, orig_shape=orig_shape,
                              padded_shape=padded.shape, levels=levels,
                              groups=groups, group_indices=indices)


def _encode_ip_groups(padded: np.ndarray, levels: int,
                      nbits: int) -> Tuple[List[LevelBitplanes],
                                           List[np.ndarray]]:
    """Closed-loop interpolation-predicted encoding (method "ip").

    Groups are encoded base-first: each group's coefficients are the
    residual of the original nodal values against the running sum of the
    coarser groups' *decoder* contributions — the exact fixed-order sum
    ``_refresh_hb_incremental`` replays (same prefix decode, same jit'd
    scatter+recompose, same f64 accumulation order), so in the matched
    regime (every group fetched to at least its recorded ``pred_planes``)
    the decoder's prediction reproduces the encoder's bit-for-bit and the
    error bound composes as max_g e_g instead of Σ_g e_g.  Computing the
    prediction any other way (e.g. one joint recompose of the truncated
    coefficient field) drifts from the decoder by ulps, which for fine
    groups — whose residual exponents sit far below the field scale —
    can exceed the codec's 2^{E_g-nbits} slack and break the certified
    bound.

    ``pred_planes`` per group is chosen against a single absolute
    truncation target θ = amax_min / (2·(levels+1)) (amax_min = smallest
    nonzero per-group HB surplus scale): kp = ceil(E_g - log2 θ), so every
    group's prediction truncation error is <= θ and the total mismatch
    budget across the ladder stays below amax_min/2 — residuals keep the
    open-loop surplus scale, and the matched regime becomes reachable
    right where the finest level starts being resolved (mid bitrates)."""
    import jax.numpy as jnp
    shape = padded.shape
    lmap = level_map(shape, levels).ravel()
    indices = [np.flatnonzero(lmap == l) for l in range(levels + 1)]
    hb = np.asarray(decompose_hb(padded, levels)).ravel()
    amaxes = [float(np.max(np.abs(hb[idx]))) if idx.size else 0.0
              for idx in indices]
    nonzero = [a for a in amaxes if a > 0.0]
    theta = min(nonzero) / (2.0 * (levels + 1)) if nonzero else 0.0
    x_flat = padded.ravel()
    total = np.zeros(shape, dtype=np.float64)
    groups: List[LevelBitplanes] = [None] * (levels + 1)
    for l in range(levels, -1, -1):      # base first — prediction order
        idx = indices[l]
        resid = x_flat[idx] - total.ravel()[idx]
        lbp = encode_level(resid, nbits=nbits)
        if lbp.exponent is not None:
            kp = nbits
            if theta > 0.0:
                kp = int(np.clip(int(np.ceil(lbp.exponent - np.log2(theta))),
                                 0, nbits))
            lbp.pred_planes = kp
            if l > 0 and kp > 0:
                u = decode_prefix(lbp, kp)
                q = 2.0 ** (lbp.exponent - kp)
                c = scatter_recompose_ip_from(
                    jnp.asarray(idx), jnp.asarray(u), shape, levels,
                    min(l, levels - 1), q)
                total += np.asarray(c)
        groups[l] = lbp
    return groups, indices


# ---------------------------------------------------------------------------
# Retrieval session (uniform progressive-reader interface)
# ---------------------------------------------------------------------------


class _BitplaneVarReader:
    """Progressive reader over a bitplane variable — in-memory
    `BitplaneVarArchive` or store-backed `repro.store.StoreBitplaneVar`
    (same surface: method/shapes/levels/groups/group_indices/plane_sources);
    planes arrive through each group's PlaneSource.

    ``contrib_budget_bytes`` bounds the retained HB contribution cache (see
    module docstring): None keeps every level resident (the classic path);
    any other value keeps the ``budget // field_nbytes`` finest levels and
    spills the rest, rebuilding them on demand — bit-identical outputs at
    any budget, including zero.  ``contrib_stats`` is an optional external
    sink carrying the ``contrib_*`` counters (store-backed readers pass
    their fetcher's FetchStats so several readers aggregate into one view).

    ``contrib_pool`` replaces the static cap with a server-wide
    :class:`repro.serve.budget.ContribBudgetPool`: retention becomes a
    borrow against one shared pool (hottest variables win), and slot
    mutation moves under the pool's lock so cross-session reclaim is
    race-free.  Spill/recompute semantics — and bit-identical outputs —
    are unchanged; only WHICH levels stay resident becomes dynamic.
    """

    def __init__(self, var, contrib_budget_bytes: Optional[int] = None,
                 contrib_stats=None, contrib_pool=None, decode_batcher=None):
        self.var = var
        self._batcher = decode_batcher
        self.streams = [LevelStream(src, batcher=decode_batcher)
                        for src in var.plane_sources()]
        self._idx_dev: Dict[int, object] = {}   # device group_indices cache
        self._recon: Optional[np.ndarray] = None
        self._dirty = True
        # HB incremental recomposition state (see module docstring): one
        # cached contribution field per coefficient group, keyed by the
        # fetched-plane count it was computed at (-1 = never computed).
        ngroups = var.levels + 1
        self._contribs: List[Optional[np.ndarray]] = [None] * ngroups
        self._contrib_fetched: List[int] = [-1] * ngroups
        self._field_nbytes = int(np.prod(var.padded_shape)) * 8
        self.contrib_stats = contrib_stats if contrib_stats is not None \
            else ContribStats()
        self._pool = contrib_pool
        if contrib_pool is not None:
            self._resident_cap = ngroups    # the pool arbitrates dynamically
        elif contrib_budget_bytes is None:
            self._resident_cap = ngroups
        else:
            self._resident_cap = min(
                ngroups, max(0, int(contrib_budget_bytes)) //
                self._field_nbytes)

    @property
    def contrib_resident_levels(self) -> List[int]:
        """Levels whose contribution field is currently retained."""
        return [l for l, c in enumerate(self._contribs) if c is not None]

    def _note_resident(self, delta_fields: int) -> None:
        self.contrib_stats.contrib_note(
            delta_bytes=delta_fields * self._field_nbytes)

    def _pool_set_contrib(self, slot: int, value) -> None:
        """Slot mutation for POOLED readers — called only by the pool, under
        the pool's lock (deposit on retain, clear on reclaim/release), so a
        refresh on one session and a reclaim driven by another can never
        interleave half-way.  Residency accounting moves with the slot."""
        had = self._contribs[slot] is not None
        self._contribs[slot] = value
        has = value is not None
        if has and not had:
            self._note_resident(+1)
        elif had and not has:
            self._note_resident(-1)

    def reconstruct_at_resolution(self, coarsen: int,
                                  eps: float) -> Tuple[np.ndarray, float]:
        """Progression in RESOLUTION (paper §II): reconstruct the 2^coarsen-
        strided sub-grid by fetching only the coarser level groups — detail
        levels 0..coarsen-1 (the finest) are never moved. Returns the
        coarse field (strided shape) and its achieved L-inf bound relative
        to the true coarse-grid values."""
        if self.var.method not in ("hb", "ip"):
            # OB's L² corrections mix finer details into coarse nodal
            # values, so a truncated reconstruction is not the nodal
            # sub-grid — HB's level independence (which `ip` inherits: a
            # group's contribution never touches coarser nodes) is what
            # enables this.
            raise ValueError("resolution progression requires method='hb' "
                             "or method='ip'")
        levels = self.var.levels
        coarsen = int(np.clip(coarsen, 0, levels))
        active = list(range(coarsen, levels + 1))   # coarser details + base
        targets = self._plane_targets(eps)
        for l in active:
            if self.streams[l].fetch_to_planes(targets[l]):
                self._dirty = True
        if self.var.method == "ip":
            # `ip` semantics are defined by the fixed-order contribution
            # sum (a joint recompose of truncated coefficients drifts by
            # ulps from what the encoder's residuals were closed against)
            rec = np.zeros(self.var.padded_shape, dtype=np.float64)
            for l in range(levels, coarsen - 1, -1):
                rec += self._compute_contrib(l)
        else:
            flat = np.zeros(int(np.prod(self.var.padded_shape)),
                            dtype=np.float64)
            for l in active:
                flat[self.var.group_indices[l]] = self.streams[l].values()
            rec = np.asarray(recompose_hb(
                flat.reshape(self.var.padded_shape), levels))
        full = unpad(rec, self.var.orig_shape)
        coarse = full[tuple(slice(None, None, 1 << coarsen)
                            for _ in self.var.orig_shape)]
        # bound on the sub-grid: HB/ip coarse nodes never receive finer-
        # level contributions, so only the active groups' bounds apply
        if self.var.method == "ip":
            mism = self._ip_mismatches([s.fetched for s in self.streams])
            achieved = ip_error_bound([self.streams[l].bound for l in active],
                                      [mism[l] for l in active])
        else:
            achieved = float(np.sum([self.streams[l].bound for l in active]))
        return coarse, achieved

    @property
    def bytes_fetched(self) -> int:
        return sum(s.bytes_fetched for s in self.streams)

    def _budgets(self, eps: float) -> List[float]:
        """Split the variable's L-inf budget across coefficient groups so the
        method's composition bound meets eps.

        The split is *size-weighted* (§Perf): minimising total plane bits
        Σ_l n_l·(E_l − log2 e_l) subject to Σ_l e_l <= eps gives
        e_l ∝ n_l — the finest level (half the elements) deserves ~half the
        budget; the equal split overspends ~log2(L/2) planes on it.
        OB additionally divides detail budgets by (1+κ) per its bound."""
        counts = np.asarray([g.count for g in self.var.groups], dtype=float)
        weights = counts / counts.sum()
        if self.var.method in ("hb", "ip"):
            return [eps * w for w in weights]
        kappa = ob_kappa(len(self.var.padded_shape))
        out = [eps * w / (1.0 + kappa) for w in weights[:-1]]
        return out + [eps * weights[-1]]

    def _ip_quantum(self, l: int) -> float:
        """Group ``l``'s prediction quantum 2^{E-kp} (0.0 for an all-zero
        group — no truncation)."""
        m = self.streams[l].meta
        if m.exponent is None:
            return 0.0
        return 2.0 ** (m.exponent - _pred_planes(m))

    def _ip_mismatches(self, depths: List[int]) -> List[float]:
        """Per-group prediction mismatch δ_g at the given plane depths:
        how far the decoder's truncated contribution can sit from the one
        the encoder closed its residuals against (0 once the depth reaches
        the recorded ``pred_planes``)."""
        out = []
        for s, k in zip(self.streams, depths):
            m = s.meta
            kp = _pred_planes(m)
            if m.exponent is None or k >= kp:
                out.append(0.0)
            else:
                out.append(2.0 ** (m.exponent - k) - 2.0 ** (m.exponent - kp))
        return out

    def _plane_targets(self, eps: float) -> List[int]:
        """Per-group plane targets for a request at ``eps`` — a pure
        function of (eps, static group metadata), never of fetch state, so
        coalesced sessions compute identical targets.  hb/ob: exactly the
        size-weighted eps split (``planes_needed`` per budget).  ip picks
        the cheaper of two sound plans by predicted from-zero bytes:

          A. the hb-style split — bound Σ_g e_g <= eps without ever
             reaching the prediction depths (shallow requests);
          B. matched — every group to max(pred_planes, planes_needed(eps)),
             where the bound collapses to max_g e_g <= eps (the mid/deep-
             bitrate win).
        """
        metas = [s.meta for s in self.streams]
        ka = [planes_needed(m, b)
              for m, b in zip(metas, self._budgets(eps))]
        if self.var.method != "ip":
            return ka
        kb = [max(_pred_planes(m), planes_needed(m, eps))
              if m.exponent is not None else 0 for m in metas]

        def cost(ks):
            return sum(sum(m.plane_sizes[:k]) + (m.sign_size if k else 0)
                       for m, k in zip(metas, ks))

        return kb if cost(kb) <= cost(ka) else ka

    def achieved_bound(self) -> float:
        bounds = [s.bound for s in self.streams]
        if self.var.method == "hb":
            return float(np.sum(bounds))
        if self.var.method == "ip":
            return ip_error_bound(
                bounds, self._ip_mismatches([s.fetched
                                             for s in self.streams]))
        kappa = ob_kappa(len(self.var.padded_shape))
        return float((1.0 + kappa) * np.sum(bounds[:-1]) + bounds[-1])

    @property
    def is_degraded(self) -> bool:
        """True once any coefficient group pinned at a partial plane prefix
        (a segment of it is permanently unavailable this session)."""
        return any(s.pinned is not None for s in self.streams)

    def availability_floor(self) -> float:
        """Tightest bound certifiable from the deliverable plane prefixes:
        each group contributes its bound at the deepest reachable plane
        (the pin for degraded groups, full depth otherwise), composed
        exactly like ``achieved_bound``."""
        depths = [s.pinned if s.pinned is not None else s.meta.nbits
                  for s in self.streams]
        bounds = [plane_bound(s.meta, d)
                  for s, d in zip(self.streams, depths)]
        if self.var.method == "hb":
            return float(np.sum(bounds))
        if self.var.method == "ip":
            return ip_error_bound(bounds, self._ip_mismatches(depths))
        kappa = ob_kappa(len(self.var.padded_shape))
        return float((1.0 + kappa) * np.sum(bounds[:-1]) + bounds[-1])

    def availability(self) -> VarAvailability:
        detail = ""
        if self.is_degraded:
            errs = [s.pin_error for s in self.streams
                    if s.pin_error is not None]
            detail = str(errs[0]) if errs else ""
        return VarAvailability(pinned=self.is_degraded,
                               floor=self.availability_floor(),
                               detail=detail)

    def request(self, eps: float) -> Tuple[np.ndarray, float]:
        for s, k in zip(self.streams, self._plane_targets(eps)):
            if s.fetch_to_planes(k):
                self._dirty = True
        if self.var.method in ("hb", "ip"):
            self._refresh_hb_incremental()
        else:
            self._refresh_full()
        return self._recon, self.achieved_bound()

    def prefetch_eps(self, eps: float, certain: bool = True) -> None:
        """Hint that a request at ``eps`` is coming: split the budget exactly
        as ``request`` will and forward per-group plane ranges to the
        sources.  Store-backed sources start background fetches; in-memory
        sources ignore it.  No decode state or byte accounting changes.
        ``certain=False`` (a speculative prediction) is byte-safe here —
        plane fetches are monotone prefixes, so a too-shallow prediction is
        always a subset of whatever is eventually consumed — but the flag is
        forwarded so the fetcher knows which cache entries it may evict."""
        for s, k in zip(self.streams, self._plane_targets(eps)):
            s.prefetch_to_planes(k, certain=certain)

    def _group_idx_dev(self, l: int):
        idx = self._idx_dev.get(l)
        if idx is None:
            import jax.numpy as jnp
            idx = self._idx_dev[l] = jnp.asarray(self.var.group_indices[l])
        return idx

    def _contrib_submit(self, l: int):
        """Phase 1 of a contribution rebuild: route the scatter+recompose to
        the device when the stream holds device-resident decoded values
        (fused path), queueing on the shared DecodeBatcher when one is
        attached so same-shape rebuilds across readers merge into one
        vmapped dispatch.  Returns an opaque handle for
        ``_contrib_collect``."""
        shape, levels = self.var.padded_shape, self.var.levels
        start = min(l, levels - 1)       # base group (index L) needs all steps
        vals_dev = self.streams[l].values_device()
        if vals_dev is None:
            return ("host", None)
        idx = self._group_idx_dev(l)
        if self.var.method == "ip":
            q = self._ip_quantum(l)
            if self._batcher is not None:
                return ("ticket", self._batcher.submit_recompose(
                    idx, vals_dev, shape, levels, start, quantum=q))
            return ("array", scatter_recompose_ip_from(idx, vals_dev, shape,
                                                       levels, start, q))
        if self._batcher is not None:
            return ("ticket", self._batcher.submit_recompose(
                idx, vals_dev, shape, levels, start))
        return ("array", scatter_recompose_from(idx, vals_dev, shape,
                                                levels, start))

    def _contrib_collect(self, l: int, handle) -> np.ndarray:
        kind, h = handle
        if kind == "ticket":
            return np.asarray(h.result())
        if kind == "array":
            return np.asarray(h)
        # host route: scatter on host, partial recompose on device — the
        # recompose graph is shared with the device route, so both are
        # bit-identical (pinned by tests/test_decode_conformance.py)
        shape, levels = self.var.padded_shape, self.var.levels
        idx = self.var.group_indices[l]
        vals = self.streams[l].values()
        start = min(l, levels - 1)
        flat = np.zeros(int(np.prod(shape)), dtype=np.float64)
        if self.var.method == "ip":
            # truncated part seeds the finer groups' prediction; the tail
            # rides back in at this group's own nodes — the host mirror of
            # ``scatter_recompose_ip_from``
            t = trunc_to_quantum(vals, self._ip_quantum(l))
            flat[idx] = t
            out = np.array(recompose_hb_from(flat.reshape(shape), levels,
                                             start))
            out.ravel()[idx] += vals - t
            return out
        flat[idx] = vals
        return np.asarray(recompose_hb_from(flat.reshape(shape), levels,
                                            start))

    def _compute_contrib(self, l: int) -> np.ndarray:
        """Contribution of group ``l``: its decoded values scattered onto the
        padded grid, partially recomposed from its own level down.  A pure
        function of the level's decoded values — bitwise reproducible."""
        return self._contrib_collect(l, self._contrib_submit(l))

    def _refresh_hb_incremental(self) -> None:
        """HB linearity: recompute only the per-level contributions whose
        plane counts moved (partial recompose from that level down), then
        re-sum in a fixed coarse->fine order.  The `ip` method rides the
        same machinery — its contribution adds a truncation before the
        recompose and a tail after (see ``_contrib_collect``), but remains
        a pure function of the group's decoded values, and the fixed
        summation order here is exactly what its encoder closed the
        residual loop against.  Contributions are pure
        functions of each level's decoded values, so any fetch schedule
        ending at the same plane counts reconstructs bit-identically.

        Under a contribution budget the sum is *streamed*: each level's
        field is produced (from cache, or rebuilt if spilled/moved), added
        into the running total in the same fixed order, then retained only
        if the level sits inside the resident set — the finest
        ``_resident_cap`` levels.  The streamed path performs the exact same
        additions in the exact same order as the unbounded path, so outputs
        are bit-identical at any budget."""
        levels = self.var.levels
        stale = [self._contrib_fetched[l] != self.streams[l].fetched
                 for l in range(levels + 1)]
        # the early-out keys on plane counts, not residency: a repeat request
        # at an already-satisfied eps serves the cached reconstruction even
        # at budget 0 (where no contribution is ever retained)
        if not any(stale) and self._recon is not None:
            return
        st = self.contrib_stats
        # phase 1: flush every stream's deferred fused decode, submitting
        # them all before collecting so a shared DecodeBatcher can merge
        # this reader's flushes — and concurrent sessions' — into one
        # vmapped dispatch per shape bucket
        flushes = [(s, s.flush_submit()) for s in self.streams]
        for s, t in flushes:
            s.flush_collect(t)
        # phase 2: same submit-then-collect for the contribution rebuilds
        # this refresh needs (collection happens inside the fixed-order sum)
        pending = {}
        for l in range(levels, -1, -1):
            if self._contribs[l] is None or stale[l]:
                pending[l] = self._contrib_submit(l)
        total = np.zeros(self.var.padded_shape, dtype=np.float64)
        for l in range(levels, -1, -1):       # fixed summation order
            c = self._contribs[l]
            if l in pending:
                if c is None and not stale[l]:
                    # planes did not move — an unbounded reader would have a
                    # cached field here; this rebuild is pure budget cost
                    st.contrib_note(recomputes=1)
                c = self._contrib_collect(l, pending[l])
                self._contrib_fetched[l] = self.streams[l].fetched
            total += c
            if self._pool is not None:
                # pooled retention: borrow a field-sized lease against the
                # server-wide pool.  The pool deposits into the slot under
                # its own lock (reclaiming colder holdings of ANY session
                # first); a denial means this field is hot enough to keep
                # only at someone hotter's expense — spill it instead.
                if not self._pool.retain(self, slot=l, level=l,
                                         nbytes=self._field_nbytes, value=c):
                    st.contrib_note(spills=1)
            # resident policy: keep the finest levels (low l), spill coarse
            elif l < self._resident_cap:
                if self._contribs[l] is None:
                    self._note_resident(+1)
                self._contribs[l] = c
            else:
                # computed for this refresh, dropped instead of retained —
                # the next refresh that finds this level stale-free will
                # charge a contrib_recompute to rebuild it
                if self._contribs[l] is not None:   # defensive: cap is static
                    self._note_resident(-1)
                    self._contribs[l] = None
                st.contrib_note(spills=1)
        self._recon = unpad(total, self.var.orig_shape)
        self._dirty = False

    def _refresh_full(self) -> None:
        """OB path: the L² corrections couple levels, so reconstruction is
        from-scratch whenever any stream moved (cached otherwise)."""
        if self._dirty or self._recon is None:
            flat = np.zeros(int(np.prod(self.var.padded_shape)), dtype=np.float64)
            for s, idx in zip(self.streams, self.var.group_indices):
                flat[idx] = s.values()
            recompose = recompose_hb if self.var.method == "hb" else recompose_ob
            rec = np.asarray(recompose(flat.reshape(self.var.padded_shape),
                                       self.var.levels))
            self._recon = unpad(rec, self.var.orig_shape)
            self._dirty = False

    # -- serve-plane hooks (repro.serve.coalesce / budget) -------------------

    def state_signature(self) -> Tuple[int, ...]:
        """Decode state as the tuple of per-group fetched-plane counts.
        Decoded values — and hence the reconstruction — are a pure function
        of this signature (the invariant tests/test_incremental_recompose.py
        asserts), which is what makes cross-session coalescing sound: two
        readers with equal signatures reconstruct bit-identically."""
        return tuple(s.fetched for s in self.streams)

    def advance_to(self, eps: float) -> bool:
        """Move every stream exactly as ``request(eps)`` would WITHOUT
        recomposing — the coalescer's waiter path (the leader's fetch made
        these planes cache-hot).  Returns True if any stream moved."""
        moved = False
        for s, k in zip(self.streams, self._plane_targets(eps)):
            if s.fetch_to_planes(k):
                moved = True
                self._dirty = True
        return moved

    def adopt_reconstruction(self, recon: np.ndarray) -> None:
        """Install an externally computed reconstruction for the CURRENT
        decode state (coalescing fan-out).  Contribution slots whose plane
        counts moved since they were cached are dropped — serving them from
        a later refresh would desynchronize cache and decode state; the
        slots that did not move stay valid (pure functions of unchanged
        values)."""
        for l in range(self.var.levels + 1):
            if self._contrib_fetched[l] != self.streams[l].fetched:
                if self._contribs[l] is not None:
                    if self._pool is not None:
                        self._pool.release(self, l)   # clears slot + counts
                    else:
                        self._note_resident(-1)
                        self._contribs[l] = None
                self._contrib_fetched[l] = self.streams[l].fetched
        self._recon = recon
        self._dirty = False

    def close(self) -> None:
        """Return pooled leases (the serve plane closes sessions; a reader
        without a pool has nothing to give back)."""
        if self._pool is not None:
            self._pool.release_owner(self)


class _SnapshotVarReader:
    def __init__(self, var: SnapshotVarArchive):
        self.reader = var.archive.open()

    @property
    def bytes_fetched(self) -> int:
        return self.reader.bytes_fetched

    def request(self, eps: float) -> Tuple[np.ndarray, float]:
        return self.reader.request(eps)


class RetrievalSession:
    """Progressive, stateful reader over all variables of an Archive (the
    in-memory `Archive` or a store-backed `repro.store.StoreArchive` — every
    variable builds its own reader via ``open_reader``).

    Session policy comes from a :class:`repro.options.SessionOptions`
    (prefetch depth, per-variable contribution budget, shared contribution
    pool — see its docstring); the pre-v4 loose kwargs still work through
    the once-warning deprecation shim.  ``coalescer`` (assignable after
    construction) routes ``reconstruct`` through cross-session
    single-flight."""

    def __init__(self, archive, options: Optional[SessionOptions] = None,
                 **legacy):
        opts = _resolve_session_options(options, legacy, "RetrievalSession")
        self.archive = archive
        self.options = opts
        self.contrib_budget_bytes = opts.contrib_budget_bytes
        self.contrib_pool = opts.contrib_pool
        self.coalescer = None
        self.readers: Dict[str, object] = {}
        self._mask_charged: Dict[str, bool] = {}
        for name, var in archive.variables.items():
            self.readers[name] = var.open_reader(opts)
            self._mask_charged[name] = False
        self._mask_bytes = 0
        # How many reassign_eb reduction steps ahead the retrieval loop may
        # hint to the fetcher (depth 1 is always a prefix of the next
        # round's fetch, so nothing speculative is ever wasted).
        self.prefetch_depth = opts.prefetch_depth

    @property
    def bytes_retrieved(self) -> int:
        return sum(r.bytes_fetched for r in self.readers.values()) \
            + self._mask_bytes

    def contrib_stats(self) -> ContribStats:
        """Aggregate contribution-cache counters over this session's bitplane
        readers.  Distinct sink objects are summed once — store-backed
        readers all share their fetcher's FetchStats, so the aggregate never
        double-counts (note that shared sink also carries other sessions of
        the same archive)."""
        agg = ContribStats()
        seen = set()
        for r in self.readers.values():
            st = getattr(r, "contrib_stats", None)
            if st is not None and id(st) not in seen:
                seen.add(id(st))
                agg.merge(st)
        return agg

    def availability(self) -> Dict[str, VarAvailability]:
        """Per-variable availability for variables pinned by missing
        segments — empty on a healthy session.  The retrieval loop uses the
        reported floors to stop tightening pinned variables (see
        core/retrieval.py); the serve plane prints them."""
        out: Dict[str, VarAvailability] = {}
        for name, r in self.readers.items():
            get = getattr(r, "availability", None)
            if get is not None:
                a = get()
                if a.pinned:
                    out[name] = a
        return out

    @property
    def degraded(self) -> bool:
        return bool(self.availability())

    def reader(self, name: str):
        """The per-variable reader, opening one lazily for variables that
        appeared AFTER this session did (live archives: a journal replay on
        ``refresh()`` can add timeseries variables to an open archive)."""
        r = self.readers.get(name)
        if r is None:
            var = self.archive.variables.get(name)
            if var is None:
                refresh = getattr(self.archive, "refresh", None)
                if refresh is not None:
                    refresh()          # maybe it was journaled since open
                var = self.archive.variables.get(name)
            if var is None:
                raise KeyError(name)
            r = var.open_reader(self.options)
            self.readers[name] = r
            self._mask_charged.setdefault(name, False)
        return r

    def follow(self, name: str) -> "FollowStream":
        """Follow-mode view over a live timeseries variable: ``poll()``
        surfaces newly appended timesteps (refreshing the archive's journal
        first), ``read(t)`` decodes them — without reopening anything, and
        bit-identical to a one-shot session over the same data."""
        return FollowStream(self, name)

    def prefetch(self, name: str, eps: float, certain: bool = True) -> None:
        """Non-binding hint that ``reconstruct(name, eps)`` is coming —
        forwarded to readers that support background segment fetch
        (store-backed bitplane and snapshot readers); a no-op otherwise.
        ``certain=False`` marks a *predicted* eps the retrieval loop may
        overshoot; readers whose fetch granularity is not prefix-monotone
        (independent psz3 snapshots) skip those to avoid moving bytes that
        are never consumed."""
        reader = self.readers.get(name)
        prefetch = getattr(reader, "prefetch_eps", None)
        if prefetch is not None:
            prefetch(eps, certain=certain)

    def reconstruct(self, name: str, eps: float) -> Tuple[np.ndarray, float]:
        """Reconstruct variable to L-inf bound <= eps; returns the data (with
        outlier-masked points exact) and the achieved bound.  With a
        ``coalescer`` attached (serve plane), concurrent duplicate requests
        across sessions collapse into one fetch + recompose — bit-identical
        results by the plane-count invariant."""
        if self.coalescer is not None:
            data, achieved = self.coalescer.reconstruct(self, name, eps)
        else:
            data, achieved = self.reader(name).request(eps)
        mask = self.archive.masks.get(name)
        if mask is not None:
            if not self._mask_charged[name]:
                self._mask_bytes += mask.nbytes
                self._mask_charged[name] = True
            data = mask.apply(data)
        return data, achieved

    def reconstruct_at_resolution(self, name: str, coarsen: int,
                                  eps: float) -> Tuple[np.ndarray, float]:
        """Progression in resolution (paper §II): the 2^coarsen-strided
        sub-grid with an L-inf guarantee, moving only coarse-level segments
        (hb/ip bitplane archives only)."""
        reader = self.readers[name]
        if not isinstance(reader, _BitplaneVarReader):
            raise ValueError("resolution progression requires a bitplane "
                             "(hb/ip) archive")
        data, achieved = reader.reconstruct_at_resolution(coarsen, eps)
        return data, achieved

    def eb_array(self, name: str, achieved: float) -> np.ndarray:
        """Per-point error-bound array: achieved everywhere, 0 at exact
        (masked) points."""
        eb = np.full(self.archive.shapes[name], achieved, dtype=np.float64)
        mask = self.archive.masks.get(name)
        if mask is not None:
            eb[mask.mask] = 0.0
        return eb

    def close(self) -> None:
        """Release per-reader resources (pooled contribution leases).  The
        serve plane calls this when it retires a sticky session; in-memory
        sessions without a pool have nothing to release."""
        for r in self.readers.values():
            close = getattr(r, "close", None)
            if close is not None:
                close()

    def bitrate(self, names: Optional[Sequence[str]] = None) -> float:
        """Bits per element over the referenced variables (paper §III-C)."""
        names = list(names) if names is not None else list(self.readers)
        n_elems = sum(self.archive.n_elements(n) for n in names)
        rbytes = sum(self.readers[n].bytes_fetched for n in names) \
            + self._mask_bytes
        return 8.0 * rbytes / max(n_elems, 1)


class FollowStream:
    """Live view over one timeseries variable of an open session.

    ``poll()`` refreshes the archive's journal and returns the timestep
    indices that became visible since the previous poll (never re-reporting
    one); ``read(t)`` decodes any retained timestep through the session's
    chain-caching reader, so walking the stream in order pays exactly one
    delta decode per step — the property that makes a followed session
    bit- AND byte-identical to a one-shot session over the same timesteps.
    """

    def __init__(self, session: RetrievalSession, name: str):
        reader = session.reader(name)
        var = getattr(reader, "var", None)
        if var is None or not hasattr(var, "timesteps"):
            raise ValueError(f"variable {name!r} is not a timeseries — "
                             f"follow() needs a journaled (v4) live archive")
        self.session = session
        self.name = name
        self._reader = reader
        self._var = var
        # report everything already visible on the first poll
        self._next_t = var.base_t

    @property
    def latest(self) -> Optional[int]:
        """Newest visible timestep index (None before the first append)."""
        return self._var.latest_t

    def poll(self) -> List[int]:
        """Refresh the journal; return newly visible timestep indices."""
        refresh = getattr(self.session.archive, "refresh", None)
        if refresh is not None:
            refresh()
        latest = self._var.latest_t
        if latest is None:
            return []
        start = max(self._next_t, self._var.base_t)
        if start > latest:
            return []
        self._next_t = latest + 1
        return list(range(start, latest + 1))

    def read(self, t: int) -> Tuple[np.ndarray, float]:
        """Decode timestep ``t``; returns ``(data, certified bound)``."""
        return self._reader.read(t)
