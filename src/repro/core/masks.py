"""Mask-based outlier management (paper §V-A).

Points whose values make QoI bounds blow up (e.g. Vx=Vy=Vz=0 under the sqrt
in Vtotal, or zero divisors under Thm 3/6 guards) are recorded in a bitmap at
refactor time, stored losslessly (they are exact), and excluded from both the
progressive encoding and the error estimation. Bitmap storage cost is
accounted at 1 bit/element plus the raw values of the masked points.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Sequence

import numpy as np


@dataclass
class OutlierMask:
    """Bitmap of exactly-stored points for one variable."""
    mask: np.ndarray            # bool, True = outlier (stored exactly)
    values: np.ndarray          # the exact values at masked positions

    @property
    def nbytes(self) -> int:
        # 1 bit per element for the bitmap + exact values.
        return (self.mask.size + 7) // 8 + self.values.nbytes

    def apply(self, data: np.ndarray) -> np.ndarray:
        """Overwrite masked positions of ``data`` with the exact values."""
        out = np.array(data, copy=True)
        out[self.mask] = self.values
        return out


def build_zero_velocity_mask(fields: Dict[str, np.ndarray],
                             names: Sequence[str] = ("Vx", "Vy", "Vz"),
                             atol: float = 0.0) -> Dict[str, OutlierMask]:
    """Mask points where all velocity components are (near) zero — these are
    wall/boundary nodes in the GE data whose tiny reconstructed values would
    make the sqrt bound (Thm 2) arbitrarily loose."""
    present = [n for n in names if n in fields]
    if not present:
        return {}
    zero = np.ones_like(np.asarray(fields[present[0]], dtype=bool))
    for n in present:
        zero &= np.abs(np.asarray(fields[n])) <= atol
    return {n: OutlierMask(mask=zero.copy(), values=np.asarray(fields[n])[zero])
            for n in present}
