"""Algorithms 2-4: QoI-preserved progressive data retrieval.

The loop iteratively refines the reconstruction until the *estimated* QoI
error bounds (Section IV theory — no ground truth needed) drop below the
requested tolerances:

  1. assign_eb (Alg 3): initial per-variable bounds from the requested
     relative QoI tolerances and the variables' value ranges.
  2. reconstruct every involved variable to its current bound (progressive —
     only new segments move).
  3. estimate each QoI's error upper bound on the reconstruction; done when
     all max bounds <= τ_abs.
  4. reassign_eb (Alg 4): at the worst point of the worst QoI, tighten the
     involved variables' bounds by c=1.5 until the *point* estimate clears
     the tolerance, then loop.

τ is relative to the QoI's value range (paper §III-C); the range is taken
from the current reconstruction and refreshed every round (ground truth is
unattainable mid-retrieval).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.qoi import Expr
from repro.core.refactor import VarAvailability

REDUCTION_FACTOR = 1.5          # c in Alg 4
MIN_REL_EPS = 2.0 ** -60        # full-fidelity floor
LADDER_STEPS = 200              # max Alg-4 tightening steps per iteration


@dataclass
class QoIRequest:
    name: str
    expr: Expr
    tau_rel: float


@dataclass
class IterationLog:
    iteration: int
    eps: Dict[str, float]
    est_errors: Dict[str, float]
    tau_abs: Dict[str, float]
    bytes_retrieved: int


@dataclass
class RetrievalResult:
    values: Dict[str, np.ndarray]
    achieved_eb: Dict[str, float]
    est_errors: Dict[str, float]
    tau_abs: Dict[str, float]
    bytes_retrieved: int
    bitrate: float
    iterations: List[IterationLog]
    converged: bool
    # certified degraded mode: True when any variable was availability-
    # pinned (permanently missing segments).  ``est_errors`` remain valid
    # upper bounds — computed from what actually decoded — they just may
    # exceed ``tau_abs``; ``availability`` reports the pinned variables.
    degraded: bool = False
    availability: Dict[str, VarAvailability] = field(default_factory=dict)


def assign_eb(requests: Sequence[QoIRequest],
              ranges: Dict[str, float]) -> Dict[str, float]:
    """Algorithm 3: per-variable initial bound = min relative tolerance among
    the QoIs involving the variable, times the variable's range."""
    eps: Dict[str, float] = {}
    for req in requests:
        for v in req.expr.variables():
            rel = min(1.0, req.tau_rel)
            eps[v] = min(eps.get(v, 1.0), rel)
    return {v: e * ranges[v] for v, e in eps.items()}


_JIT_CACHE: Dict[tuple, "jax.stages.Wrapped"] = {}


def _estimate(expr: Expr, values: Dict[str, np.ndarray],
              ebs: Dict[str, np.ndarray]) -> Tuple[np.ndarray, np.ndarray]:
    """Jit-compiled (value, bound) evaluation, cached per (expr, shapes) —
    eager dispatch of the estimator graph dominated retrieval wall time
    (§Perf: ~2x end-to-end on the GE pipeline)."""
    names = tuple(sorted(values))
    shapes = tuple(np.shape(values[k]) for k in names)
    key = (expr, names, shapes)   # Expr nodes hash structurally
    fn = _JIT_CACHE.get(key)
    if fn is None:
        fn = jax.jit(lambda vals, eb: expr.eval(vals, eb))
        _JIT_CACHE[key] = fn
    val, bound = fn({k: jnp.asarray(values[k]) for k in names},
                    {k: jnp.asarray(ebs[k]) for k in names})
    return np.asarray(val), np.asarray(bound)


def retrieve_qoi_controlled(session,
                            requests: Sequence[QoIRequest],
                            max_iters: int = 100,
                            reduction: float = REDUCTION_FACTOR,
                            verbose: bool = False) -> RetrievalResult:
    """Algorithm 2 main loop over a RetrievalSession."""
    ranges = session.archive.ranges
    needed = sorted(set().union(*[r.expr.variables() for r in requests]))
    for v in needed:
        if v not in session.readers:
            raise KeyError(f"QoI references unknown variable {v!r}")
    eps = assign_eb(requests, ranges)
    floors = {v: MIN_REL_EPS * ranges[v] for v in needed}
    prefetch = getattr(session, "prefetch", None)
    # Certain hints already forwarded, keyed by their eps: reassign only
    # tightens the involved variables, so re-hinting an unchanged variable
    # every round is pure reader/fetcher-lock churn (it resolves to planes
    # the session has already consumed) — worth skipping now that hints may
    # cross a real wire's submission path.  Speculative (certain=False)
    # predictions stay unconditional: their eps varies per round.
    hinted: Dict[str, float] = {}

    def hint(v: str, e: float) -> None:
        if prefetch is not None and hinted.get(v) != e:
            prefetch(v, e)
            hinted[v] = e
    logs: List[IterationLog] = []
    values: Dict[str, np.ndarray] = {}
    eb_arrays: Dict[str, np.ndarray] = {}
    achieved: Dict[str, float] = {}
    pinned_vars: set = set()       # availability-pinned (degraded) variables
    converged = False

    for it in range(max_iters):
        # -- progressive reconstruction at current bounds (lines 9-11).
        # Hint every variable's fetch up front: the store fetcher starts
        # moving later variables' segments while earlier variables decode.
        for v in needed:
            hint(v, eps[v])
        for v in needed:
            data, ach = session.reconstruct(v, eps[v])
            values[v] = data
            achieved[v] = ach
            eb_arrays[v] = session.eb_array(v, ach)

        # -- availability-pinned variables (certified degraded mode): a
        # variable whose segments are permanently unavailable cannot be
        # tightened past its achievable floor — raise its ladder floor so
        # reassign_eb freezes it there instead of re-requesting the same
        # missing planes forever (the frozen/at_floor machinery below then
        # guarantees termination exactly as for codec floors).
        get_avail = getattr(session, "availability", None)
        if get_avail is not None:
            for v, a in get_avail().items():
                if v in floors and np.isfinite(a.floor):
                    floors[v] = max(floors[v], a.floor)
                    pinned_vars.add(v)

        # -- QoI error estimation (lines 12-24)
        est_errors: Dict[str, float] = {}
        tau_abs: Dict[str, float] = {}
        worst: Optional[Tuple[str, int, float]] = None  # (qoi, flat idx, excess)
        bounds_cache: Dict[str, np.ndarray] = {}
        for req in requests:
            val, bound = _estimate(req.expr, values, eb_arrays)
            rng = float(np.max(val) - np.min(val))
            t_abs = req.tau_rel * (rng if rng > 0 else 1.0)
            max_err = float(np.max(bound))
            est_errors[req.name] = max_err
            tau_abs[req.name] = t_abs
            bounds_cache[req.name] = bound
            if max_err > t_abs:
                idx = int(np.argmax(bound))
                excess = max_err / t_abs if np.isfinite(max_err) else np.inf
                if worst is None or excess > worst[2]:
                    worst = (req.name, idx, excess)

        logs.append(IterationLog(iteration=it, eps=dict(eps),
                                 est_errors=dict(est_errors),
                                 tau_abs=dict(tau_abs),
                                 bytes_retrieved=session.bytes_retrieved))
        if verbose:
            print(f"[retrieve] iter={it} bytes={session.bytes_retrieved} "
                  f"est={ {k: f'{v:.3e}' for k, v in est_errors.items()} }")

        if worst is None:
            converged = True
            break

        # -- reassign_eb (Alg 4): tighten on the worst point
        qname, idx, _ = worst
        req = next(r for r in requests if r.name == qname)
        involved = sorted(req.expr.variables())
        pt_vals = {v: values[v].ravel()[idx] for v in involved}
        # a pinned variable's bound cannot drop below what it achieved —
        # seeding its ladder with the (unreachable) requested eps would
        # predict tightenings the reconstruct pass can never deliver and
        # spin the reassign loop until max_iters
        pt_ebs = {v: achieved[v] if v in pinned_vars
                  else min(achieved[v], eps[v]) for v in involved}
        # honour exact (masked) points
        for v in involved:
            pt_ebs[v] = float(eb_arrays[v].ravel()[idx]) if \
                eb_arrays[v].ravel()[idx] == 0.0 else pt_ebs[v]
        # Evaluate the whole geometric eps-ladder of candidate bound states
        # in ONE batched _estimate call (§Perf) — the legacy loop dispatched
        # up to LADDER_STEPS sequential scalar-jit evaluations.  State t is
        # exactly what t reduction rounds of the sequential loop produce
        # (cumulative division, per-variable floor clamp, frozen once at or
        # below the floor — masked points enter at 0 and stay there).
        ladders: Dict[str, np.ndarray] = {}
        for v in involved:
            lad = np.empty(LADDER_STEPS + 1, dtype=np.float64)
            cur = pt_ebs[v]
            lad[0] = cur
            for t in range(1, LADDER_STEPS + 1):
                if cur > floors[v]:
                    cur = max(cur / reduction, floors[v])
                lad[t] = cur
            ladders[v] = lad
        # -- async segment prefetch: reassign always lands at ladder state
        # t_star >= 1 (state 0 is the current, still-violating bound), so the
        # planes for ladder[depth=1] are a guaranteed prefix of the next
        # round's fetch.  Hand these predicted next-eps to the fetcher NOW so
        # store-backed sessions move segments in the background while the
        # batched ladder estimate below (and the next estimator round) run.
        # Depths > 1 hide more latency but may speculate past t_star.
        depth = int(np.clip(getattr(session, "prefetch_depth", 1),
                            1, LADDER_STEPS))
        if prefetch is not None:
            for v in involved:
                predicted = float(ladders[v][depth])
                if predicted > 0.0:
                    prefetch(v, min(eps[v], predicted), certain=False)
        _, pb = _estimate(
            req.expr,
            {v: np.full(LADDER_STEPS, pt_vals[v]) for v in involved},
            {v: ladders[v][:LADDER_STEPS] for v in involved})
        ok = np.asarray(pb) <= tau_abs[qname]
        progressable = np.zeros(LADDER_STEPS, dtype=bool)
        for v in involved:
            progressable |= ladders[v][:LADDER_STEPS] > floors[v]
        frozen = ~progressable
        at_floor = False
        if ok.any():
            t_star = int(np.argmax(ok))       # first state meeting tau
        elif frozen.any():
            t_star = int(np.argmax(frozen))   # sequential loop stops here
            at_floor = True
        else:
            t_star = LADDER_STEPS             # exhausted without converging
        pt_ebs = {v: float(ladders[v][t_star]) for v in involved}
        for v in involved:
            eps[v] = min(eps[v], pt_ebs[v]) if pt_ebs[v] > 0 else eps[v]
        # -- the landing state is now exact: prefetch the full next-round
        # plane set so transport overlaps the remaining bookkeeping and the
        # per-variable decode/recompose of the next reconstruct pass.
        for v in involved:
            hint(v, eps[v])
        if at_floor:
            # full fidelity reached and still unbounded -> retrieve all and stop
            for v in involved:
                eps[v] = floors[v]
            for v in needed:
                data, ach = session.reconstruct(v, eps[v])
                values[v], achieved[v] = data, ach
                eb_arrays[v] = session.eb_array(v, ach)
            break

    bitrate = session.bitrate(needed)
    get_avail = getattr(session, "availability", None)
    availability = get_avail() if get_avail is not None else {}
    return RetrievalResult(values=values, achieved_eb=achieved,
                           est_errors=est_errors, tau_abs=tau_abs,
                           bytes_retrieved=session.bytes_retrieved,
                           bitrate=bitrate, iterations=logs,
                           converged=converged,
                           degraded=bool(availability),
                           availability=availability)
