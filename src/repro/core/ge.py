"""The GE CFD case-study QoIs, paper Eq. (1)-(6), built from derivable bases.

Variables: velocity Vx, Vy, Vz, pressure P, density D (paper §III-A).
The decompositions mirror §IV-D: e.g. PT = P · (1 + γ/2·Mach²)^3.5 becomes
Prod(P, frac_pow(...)) with frac_pow composed as x³·√x.
"""
from __future__ import annotations

from typing import Dict

from repro.core.qoi import (
    Expr,
    Prod,
    Quot,
    Radical,
    Sqrt,
    Var,
    frac_pow,
    magnitude,
    scale,
    square,
)

# Physical constants (paper §III-A).
R = 287.1
GAMMA = 1.4
MI = 3.5
MU_R = 1.716e-5
T_R = 273.15
S = 110.4


def v_total(tight: bool = False) -> Expr:
    """Eq. (1): Vtotal = sqrt(Vx² + Vy² + Vz²)."""
    return magnitude([Var("Vx"), Var("Vy"), Var("Vz")], tight=tight)


def temperature() -> Expr:
    """Eq. (2): T = P / (D·R)."""
    return Quot(Var("P"), scale(Var("D"), R))


def sound_speed(tight: bool = False) -> Expr:
    """Eq. (3): C = sqrt(γ·R·T)."""
    return Sqrt(scale(temperature(), GAMMA * R), tight=tight)


def mach(tight: bool = False) -> Expr:
    """Eq. (4): Mach = Vtotal / C."""
    return Quot(v_total(tight=tight), sound_speed(tight=tight))


def total_pressure(tight: bool = False) -> Expr:
    """Eq. (5): PT = P · (1 + γ/2 · Mach²)^3.5."""
    inner = scale(square(mach(tight=tight)), GAMMA / 2.0, const=1.0)
    return Prod(Var("P"), frac_pow(inner, MI, tight=tight))


def viscosity(tight: bool = False) -> Expr:
    """Eq. (6): mu = mu_r (T/Tr)^1.5 (Tr+S)/(T+S)
              = [mu_r (Tr+S) / Tr^1.5] · T^1.5 · 1/(T+S)."""
    t = temperature()
    const = MU_R * (T_R + S) / (T_R ** 1.5)
    return scale(Prod(frac_pow(t, 1.5, tight=tight), Radical(t, c=S)), const)


def all_qois(tight: bool = False) -> Dict[str, Expr]:
    """The six GE QoIs keyed by short name (paper Table II examples)."""
    return {
        "VTOT": v_total(tight=tight),
        "T": temperature(),
        "C": sound_speed(tight=tight),
        "Mach": mach(tight=tight),
        "PT": total_pressure(tight=tight),
        "mu": viscosity(tight=tight),
    }
