"""Archive containers: manifest + segment payload(s), single-file or sharded.

Layout of a single-file ``.prs`` container::

    magic  b"PRSTORE1"                          (8 bytes)
    manifest length, uint64 little-endian       (8 bytes)
    manifest JSON (utf-8)
    payload: concatenated segments

A *sharded* container (introduced with format v2) is a directory (or URL prefix, or any set
of ByteStores) holding ``manifest.json`` plus one payload blob per shard —
per variable (``Vx.seg``) or per level group (``Vx.g0.seg``) — so shards can
be written in parallel, fetched from independent keys/URLs, mixed across
backends via a blob resolver, and dropped per variable without rewriting
the rest of the archive.

The manifest carries everything *about* the archive — method, per-variable
group metadata (counts, exponents, nbits, per-plane sizes), snapshot ladder
metadata, outlier-mask shapes, value ranges — plus a segment index mapping
``key -> (blob, offset, size, crc32c, codec)`` into the payload blobs
(format v3; the codec field is the plane-codec id chosen by the entropy
stage's cost model, ``null`` for non-plane segments).  v2 manifests carry
``(blob, offset, size, crc32c)`` and v1 manifests ``(offset, size,
crc32c)`` with an implicit single blob — all three parse, and v1/v2 plane
payloads (legacy ``b"R"``/``b"Z"`` tags, bare-zlib signs) decode
bit-identically through the codec registry's legacy paths.  The payload
carries only opaque segment bytes: one segment per bitplane, per sign
plane, per snapshot blob, per mask bitmap / mask value array.  Offsets are
relative to each blob's start, so payloads can be re-hosted on any
ByteStore (file, memory, HTTP, behind a simulated WAN) without rewriting
the manifest.

``save_archive`` serializes any `core.refactor.Archive` (all four methods);
``save_sharded_archive`` writes the directory form; ``open_archive`` yields
a `StoreArchive` whose ``open()`` returns a regular `RetrievalSession` —
readers stream checksum-verified segments through a `SegmentFetcher`
instead of holding the encoded bytes, and reconstruction is bit-identical
to an in-memory session at every requested bound.

Live archives (format v4): a sharded directory may additionally carry an
append-only ``journal.jsonl`` next to ``manifest.json``.  The manifest
stays the v3-compatible base; every appended timestep adds one immutable
``V.t<k>.seg`` blob plus journal records describing its segments — nothing
already written is ever rewritten, so readers and the writer never race on
shared bytes.  ``StoreArchive.refresh()`` re-reads the journal (over HTTP:
a conditional GET that costs one 304 when nothing changed) and applies only
the *complete* trailing records, making new timesteps retrievable in an
already-open session; ``repro.store.writer.ArchiveWriter`` is the producing
side.  Timeseries segments ``V/t<k>/b<j>`` decode through keyframe→delta
chains (repro.compressors.snapshots.decode_timestep), and a retention
record drops a keyframe-aligned prefix of timesteps without invalidating
anything that remains.

JSON is a deliberate choice for the manifest: Python's float repr
round-trips IEEE-754 doubles exactly, so eps ladders / ranges / amax survive
save->open bit-identically.
"""
from __future__ import annotations

import json
import os
import struct
import threading
import urllib.parse
import zlib
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.bitplane.codecs import blob_codec_id
from repro.bitplane.encoder import PlaneGroupMeta
from repro.bitplane.segments import PlaneSource
from repro.compressors.snapshots import (
    DeltaSnapshotArchive,
    DeltaSnapshotReader,
    SnapshotReader,
    decode_timestep,
    timestep_bound,
)
from repro.compressors.szlike import SZCompressed, sz_decompress
from repro.core.masks import OutlierMask
from repro.core.refactor import (
    Archive,
    BitplaneVarArchive,
    RetrievalSession,
    SnapshotVarArchive,
    VarAvailability,
    _BitplaneVarReader,
    _resolve_session_options,
)
from repro.options import OpenOptions, SessionOptions, _from_legacy
from repro.store.bytestore import ByteStore, FileByteStore, HTTPByteStore, \
    MemoryByteStore
from repro.store.cache import SegmentCache
from repro.store.crc import crc32c
from repro.store.fetcher import SegmentEntry, SegmentFetcher
from repro.store.retry import BlobQuarantine, RetryPolicy
from repro.transform.hierarchical import level_map

MAGIC = b"PRSTORE1"
FORMAT_VERSION = 4          # newest readable container format
STATIC_FORMAT_VERSION = 3   # written for archives without v4 features
MANIFEST_NAME = "manifest.json"
JOURNAL_NAME = "journal.jsonl"

SHARD_POLICIES = ("single", "variable", "group")


def segment_depth(key: str) -> int:
    """Progressive depth of a segment key — cache-eviction metadata.

    Bitplane segments ``V/g<l>/p<b>`` map to their plane index ``b`` (0 =
    MSB, consumed by every client; large = LSB, consumed by few).  Snapshot
    blobs ``V/s<i>/b<j>`` map to the snapshot index ``i`` (ladder position:
    later snapshots serve only tight tolerances), and timestep blobs
    ``V/t<k>/b<j>`` to the timestep index ``k`` (a follow-mode session
    consumes the newest timesteps; deep history is cold).  Sign planes,
    masks and anything unrecognised map to 0 — they ride with the first
    plane and are as shared as the MSB prefix."""
    parts = key.split("/")
    last = parts[-1]
    if last[:1] == "p" and last[1:].isdigit():
        return int(last[1:])
    if len(parts) == 3 and parts[1][:1] in ("s", "t") \
            and parts[1][1:].isdigit() and last[:1] == "b":
        return int(parts[1][1:])
    return 0


def _shard_of(key: str, shard_by: str) -> str:
    """Map a segment key to its payload blob name under a shard policy.

    Keys look like ``Vx/g0/p3``, ``Vx/g0/signs``, ``Vx/s1/b0``,
    ``Vx/mask/bitmap`` — the first component is always the variable.
    """
    if shard_by == "single":
        return ""
    parts = key.split("/")
    var = parts[0]
    if shard_by == "variable":
        return f"{var}.seg"
    if shard_by == "group":
        if parts[1] == "mask":
            return f"{var}.meta.seg"
        return f"{var}.{parts[1]}.seg"      # g<l> (bitplane) / s<i> (snapshot)
    raise ValueError(f"unknown shard policy {shard_by!r}; "
                     f"choose from {SHARD_POLICIES}")


# ---------------------------------------------------------------------------
# Serialization
# ---------------------------------------------------------------------------


class _SegmentWriter:
    """Routes segments into per-shard payload blobs; builds the v3 index."""

    def __init__(self, shard_by: str = "single"):
        self.shard_by = shard_by
        self.index: Dict[str, List] = {}
        self._chunks: Dict[str, List[bytes]] = {}
        self._offsets: Dict[str, int] = {}

    def add(self, key: str, data: bytes, crc: Optional[int] = None,
            codec: Optional[int] = None) -> None:
        if key in self.index:
            raise ValueError(f"duplicate segment key {key!r}")
        blob = _shard_of(key, self.shard_by)
        off = self._offsets.get(blob, 0)
        self.index[key] = [blob, off, len(data),
                           crc32c(data) if crc is None else crc, codec]
        self._chunks.setdefault(blob, []).append(data)
        self._offsets[blob] = off + len(data)

    def payloads(self) -> Dict[str, bytes]:
        return {blob: b"".join(chunks)
                for blob, chunks in self._chunks.items()}


def _bitplane_var_manifest(name: str, var: BitplaneVarArchive,
                           w: _SegmentWriter) -> dict:
    groups = []
    for l, g in enumerate(var.groups):
        plane_crcs, sign_crc = g.segment_crcs()
        for b, blob in enumerate(g.planes):
            w.add(f"{name}/g{l}/p{b}", blob, crc=plane_crcs[b],
                  codec=blob_codec_id(blob))
        if g.exponent is not None:
            w.add(f"{name}/g{l}/signs", g.signs, crc=sign_crc,
                  codec=blob_codec_id(g.signs))
        spec = {"count": g.count, "exponent": g.exponent,
                "nbits": g.nbits,
                "plane_sizes": [len(p) for p in g.planes],
                "sign_size": len(g.signs)}
        if g.pred_planes is not None:       # `ip` prediction depth
            spec["pred_planes"] = g.pred_planes
        groups.append(spec)
    return {"kind": "bitplane", "method": var.method,
            "orig_shape": list(var.orig_shape),
            "padded_shape": list(var.padded_shape),
            "levels": var.levels, "groups": groups}


def _snapshot_var_manifest(name: str, var: SnapshotVarArchive,
                           w: _SegmentWriter) -> dict:
    arch = var.archive
    delta = isinstance(arch, DeltaSnapshotArchive)
    snaps = []
    for i, s in enumerate(arch.snapshots):
        for j, blob in enumerate(s.blobs):
            w.add(f"{name}/s{i}/b{j}", blob)
        snaps.append({"eps": s.eps, "orig_shape": list(s.orig_shape),
                      "padded_shape": list(s.padded_shape),
                      "levels": s.levels, "dtypes": list(s.dtypes),
                      "amax": s.amax,
                      "blob_sizes": [len(b) for b in s.blobs]})
    out = {"kind": "snapshot", "delta": delta, "snapshots": snaps}
    if delta:
        out["eps_ladder"] = list(arch.eps_ladder)
    return out


def build_sharded_container(archive: Archive,
                            shard_by: str = "variable"
                            ) -> Tuple[dict, Dict[str, bytes]]:
    """Archive -> (manifest dict, payload blobs keyed by blob name)."""
    w = _SegmentWriter(shard_by=shard_by)
    variables: Dict[str, dict] = {}
    for name, var in archive.variables.items():
        if "/" in name:
            raise ValueError(f"variable name {name!r} may not contain '/'")
        if isinstance(var, BitplaneVarArchive):
            variables[name] = _bitplane_var_manifest(name, var, w)
        elif isinstance(var, SnapshotVarArchive):
            variables[name] = _snapshot_var_manifest(name, var, w)
        else:
            raise TypeError(f"cannot serialize variable of type {type(var)}")
    masks: Dict[str, dict] = {}
    for name, m in archive.masks.items():
        w.add(f"{name}/mask/bitmap", np.packbits(m.mask.ravel()).tobytes())
        w.add(f"{name}/mask/values",
              np.ascontiguousarray(m.values, dtype=np.float64).tobytes())
        masks[name] = {"shape": list(m.mask.shape),
                       "n_true": int(m.mask.sum())}
    payloads = w.payloads()
    manifest = {
        "format": "prstore", "version": STATIC_FORMAT_VERSION,
        "method": archive.method,
        "ranges": dict(archive.ranges),
        "shapes": {k: list(v) for k, v in archive.shapes.items()},
        "masks": masks,
        "variables": variables,
        "segments": w.index,
        "blobs": {blob: len(data) for blob, data in payloads.items()},
    }
    return manifest, payloads


def build_container(archive: Archive) -> Tuple[dict, bytes]:
    """Archive -> (manifest dict, single payload bytes)."""
    manifest, payloads = build_sharded_container(archive, shard_by="single")
    return manifest, payloads.get("", b"")


def save_archive(archive: Archive, path: str) -> int:
    """Serialize ``archive`` into a container file; returns bytes written."""
    manifest, payload = build_container(archive)
    blob = json.dumps(manifest, sort_keys=True).encode("utf-8")
    with open(path, "wb") as fh:
        fh.write(MAGIC)
        fh.write(struct.pack("<Q", len(blob)))
        fh.write(blob)
        fh.write(payload)
    return len(MAGIC) + 8 + len(blob) + len(payload)


def save_sharded_archive(archive: Archive, directory: str,
                         shard_by: str = "variable") -> int:
    """Serialize ``archive`` as ``directory/manifest.json`` + one payload
    file per shard; returns total bytes written.  Shards are independent
    files, so they can be uploaded to independent object-store keys and a
    variable can be dropped by deleting its blob(s) — sessions that never
    touch it keep working."""
    if shard_by == "single":
        raise ValueError("use save_archive for single-payload containers")
    manifest, payloads = build_sharded_container(archive, shard_by=shard_by)
    os.makedirs(directory, exist_ok=True)
    total = 0
    for blob, data in payloads.items():
        with open(os.path.join(directory, blob), "wb") as fh:
            fh.write(data)
        total += len(data)
    mblob = json.dumps(manifest, sort_keys=True, indent=1).encode("utf-8")
    with open(os.path.join(directory, MANIFEST_NAME), "wb") as fh:
        fh.write(mblob)
    return total + len(mblob)


# ---------------------------------------------------------------------------
# Store-backed variables (mirror the in-memory archive interfaces)
# ---------------------------------------------------------------------------


class FetcherPlaneSource(PlaneSource):
    """PlaneSource streaming one group's segments through a SegmentFetcher."""

    def __init__(self, fetcher: SegmentFetcher, prefix: str,
                 meta: PlaneGroupMeta):
        self.fetcher = fetcher
        self.prefix = prefix
        self.meta = meta

    def planes(self, start: int, stop: int) -> Sequence[bytes]:
        return self.fetcher.fetch_many(
            f"{self.prefix}/p{b}" for b in range(start, stop))

    def planes_available(self, start: int, stop: int):
        # degraded-mode path: deliver the longest contiguous plane prefix
        # instead of all-or-nothing (see SegmentFetcher.fetch_prefix)
        return self.fetcher.fetch_prefix(
            f"{self.prefix}/p{b}" for b in range(start, stop))

    def signs(self) -> bytes:
        return self.fetcher.fetch(f"{self.prefix}/signs")

    def prefetch(self, start: int, stop: int, certain: bool = True) -> None:
        keys = [f"{self.prefix}/p{b}" for b in range(start, stop)]
        if start == 0:               # signs ride with the first plane
            keys.append(f"{self.prefix}/signs")
        self.fetcher.prefetch(keys, certain=certain)


class StoreBitplaneVar:
    """Store-backed PMGARD variable: same reader-facing surface as
    `BitplaneVarArchive` (method/shapes/levels/groups/group_indices/
    plane_sources), with plane payloads left on the ByteStore."""

    def __init__(self, name: str, spec: dict, fetcher: SegmentFetcher):
        self.name = name
        self.method: str = spec["method"]
        self.orig_shape = tuple(spec["orig_shape"])
        self.padded_shape = tuple(spec["padded_shape"])
        self.levels: int = spec["levels"]
        self.groups: List[PlaneGroupMeta] = [
            PlaneGroupMeta(count=g["count"], exponent=g["exponent"],
                           nbits=g["nbits"],
                           plane_sizes=tuple(g["plane_sizes"]),
                           sign_size=g["sign_size"],
                           pred_planes=g.get("pred_planes"))
            for g in spec["groups"]]
        self._fetcher = fetcher
        self._indices: Optional[List[np.ndarray]] = None

    @property
    def group_indices(self) -> List[np.ndarray]:
        # Deterministic function of (padded_shape, levels) — recomputed
        # instead of stored, exactly as the refactor computed it.
        if self._indices is None:
            lmap = level_map(self.padded_shape, self.levels).ravel()
            self._indices = [np.flatnonzero(lmap == l)
                             for l in range(self.levels + 1)]
        return self._indices

    @property
    def total_nbytes(self) -> int:
        return sum(sum(g.plane_sizes) + g.sign_size for g in self.groups)

    def plane_sources(self) -> List[PlaneSource]:
        return [FetcherPlaneSource(self._fetcher, f"{self.name}/g{l}", meta)
                for l, meta in enumerate(self.groups)]

    def open_reader(self, options: Optional[SessionOptions] = None,
                    **legacy) -> _BitplaneVarReader:
        opts = _resolve_session_options(options, legacy,
                                        "StoreBitplaneVar.open_reader")
        # the fetcher's FetchStats doubles as the ContribStats sink so one
        # object reports transport traffic AND reader residency/spills
        return _BitplaneVarReader(
            self, contrib_budget_bytes=opts.contrib_budget_bytes,
            contrib_stats=self._fetcher.stats,
            contrib_pool=opts.contrib_pool,
            decode_batcher=opts.decode_batcher)


class _SnapshotHandle:
    """Manifest-only view of one SZ snapshot: selection metadata resident,
    blobs fetched (verified) on load."""

    def __init__(self, name: str, idx: int, spec: dict,
                 fetcher: SegmentFetcher):
        self.eps: float = spec["eps"]
        self.amax: float = spec["amax"]
        self._spec = spec
        self._keys = [f"{name}/s{idx}/b{j}"
                      for j in range(len(spec["blob_sizes"]))]
        self._fetcher = fetcher
        self._loaded: Optional[SZCompressed] = None

    @property
    def nbytes(self) -> int:
        return sum(self._spec["blob_sizes"]) + 64  # + header, as SZCompressed

    @property
    def safe_eps(self) -> float:
        return self.eps + 8 * np.finfo(np.float64).eps * self.amax

    def prefetch(self, certain: bool = True) -> None:
        self._fetcher.prefetch(self._keys, certain=certain)

    def load(self) -> SZCompressed:
        if self._loaded is None:
            blobs = self._fetcher.fetch_many(self._keys)
            s = self._spec
            self._loaded = SZCompressed(
                eps=s["eps"], orig_shape=tuple(s["orig_shape"]),
                padded_shape=tuple(s["padded_shape"]), levels=s["levels"],
                blobs=blobs, dtypes=list(s["dtypes"]), amax=s["amax"])
        return self._loaded


class _StoreSnapshotReader(SnapshotReader):
    def __init__(self, archive):
        super().__init__(archive)
        self._pin_error: Optional[BaseException] = None

    def _decode(self, idx: int) -> np.ndarray:
        return sz_decompress(self.archive.snapshots[idx].load())

    @property
    def is_degraded(self) -> bool:
        return self._pin_error is not None

    def availability(self) -> VarAvailability:
        if self._pin_error is None:
            return VarAvailability(
                pinned=False, floor=self.archive.snapshots[-1].safe_eps)
        floor = self.archive.snapshots[self._cache[0]].safe_eps \
            if self._cache is not None else float("inf")
        return VarAvailability(pinned=True, floor=floor,
                               detail=str(self._pin_error))

    def request(self, eps: float) -> Tuple[np.ndarray, float]:
        if self._pin_error is not None and self._cache is not None:
            # availability-pinned: serve the deepest decoded snapshot —
            # its bound is still a valid certificate, just wider
            idx = self._cache[0]
            return self._cache[1], self.archive.snapshots[idx].safe_eps
        try:
            return super().request(eps)
        except Exception as e:
            if self._cache is None:
                raise          # nothing decoded yet: nothing to certify
            self._pin_error = e
            idx = self._cache[0]
            return self._cache[1], self.archive.snapshots[idx].safe_eps

    def prefetch_eps(self, eps: float, certain: bool = True) -> None:
        # Independent snapshots are NOT prefix-monotone: a *predicted* eps
        # that undershoots the landing state would move a whole snapshot
        # that is never decoded.  Only act on certain hints.
        if not certain:
            return
        idx = self._select(eps)
        # mirror request()'s never-go-backwards rule: a request at or below
        # an already-decoded snapshot reuses it and decodes nothing new
        if self._cache is not None and self._cache[0] >= idx:
            return
        if not self.fetched[idx]:
            self.archive.snapshots[idx].prefetch()


class _StoreDeltaSnapshotReader(DeltaSnapshotReader):
    def __init__(self, archive):
        super().__init__(archive)
        self._pin_error: Optional[BaseException] = None

    def _decode(self, idx: int) -> np.ndarray:
        return sz_decompress(self.archive.snapshots[idx].load())

    @property
    def is_degraded(self) -> bool:
        return self._pin_error is not None

    def availability(self) -> VarAvailability:
        if self._pin_error is None:
            snaps = self.archive.snapshots
            tight = snaps[-1]
            slack = 8 * np.finfo(np.float64).eps * tight.amax * len(snaps)
            return VarAvailability(pinned=False, floor=tight.eps + slack)
        floor = self.achieved_bound() if self.n_fetched else float("inf")
        return VarAvailability(pinned=True, floor=floor,
                               detail=str(self._pin_error))

    def request(self, eps: float) -> Tuple[np.ndarray, float]:
        if self._pin_error is not None and self.n_fetched:
            # pinned: the residual ladder ends at the deepest applied rung
            return self._decoded, self.achieved_bound()
        try:
            return super().request(eps)
        except Exception as e:
            if self.n_fetched == 0:
                raise          # no rung applied: nothing to certify
            self._pin_error = e
            return self._decoded, self.achieved_bound()

    def prefetch_eps(self, eps: float, certain: bool = True) -> None:
        # The residual ladder is cumulative (request(eps) consumes ALL
        # snapshots up to the selected index), so even a speculative
        # prediction prefetches a prefix of what any tighter landing state
        # will consume — byte-safe either way.
        idx = self._select(eps)
        for i in range(self.n_fetched, idx + 1):
            self.archive.snapshots[i].prefetch(certain=certain)


class StoreSnapshotVar:
    """Store-backed PSZ3 / PSZ3-delta variable."""

    def __init__(self, name: str, spec: dict, fetcher: SegmentFetcher):
        self.name = name
        self.delta: bool = spec["delta"]
        self.snapshots = [_SnapshotHandle(name, i, s, fetcher)
                          for i, s in enumerate(spec["snapshots"])]
        self.eps_ladder = list(spec.get("eps_ladder", []))

    @property
    def total_nbytes(self) -> int:
        return sum(h.nbytes for h in self.snapshots)

    def open_reader(self, options: Optional[SessionOptions] = None,
                    **legacy):
        # contribution budgets/pools are bitplane-reader state; the options
        # object is accepted (and validated) for interface uniformity
        _resolve_session_options(options, legacy,
                                 "StoreSnapshotVar.open_reader")
        cls = _StoreDeltaSnapshotReader if self.delta else _StoreSnapshotReader
        return cls(self)


# ---------------------------------------------------------------------------
# Timeseries variables (format v4: journaled, append-only)
# ---------------------------------------------------------------------------


class _TimestepHandle:
    """Manifest/journal-only view of one appended timestep: chain metadata
    resident, payload blobs fetched (verified) on decode."""

    def __init__(self, name: str, spec: dict, fetcher: SegmentFetcher):
        self.t: int = spec["t"]
        self.keyframe: bool = spec["keyframe"]
        self.eps: float = spec["eps"]
        self.amax: float = spec["amax"]
        self._spec = spec
        self._keys = [f"{name}/t{self.t}/b{j}"
                      for j in range(len(spec["blob_sizes"]))]
        self._fetcher = fetcher
        self._loaded: Optional[SZCompressed] = None

    @property
    def nbytes(self) -> int:
        return sum(self._spec["blob_sizes"]) + 64  # + header, as SZCompressed

    @property
    def segment_keys(self) -> List[str]:
        return list(self._keys)

    def prefetch(self, certain: bool = True) -> None:
        self._fetcher.prefetch(self._keys, certain=certain)

    def load(self) -> SZCompressed:
        if self._loaded is None:
            blobs = self._fetcher.fetch_many(self._keys)
            s = self._spec
            self._loaded = SZCompressed(
                eps=s["eps"], orig_shape=tuple(s["orig_shape"]),
                padded_shape=tuple(s["padded_shape"]), levels=s["levels"],
                blobs=blobs, dtypes=list(s["dtypes"]), amax=s["amax"])
        return self._loaded


class StoreTimeseriesVar:
    """Store-backed live timeseries variable (format v4).

    Timesteps arrive through journal replay: each is either a keyframe
    (independently decodable) or a delta against its predecessor's
    reconstruction.  ``base_t`` is the oldest retained timestep — always a
    keyframe, advanced by retention records.  The timestep list only ever
    grows at the tail / shrinks at the head, so a reader holding an index
    into it stays valid across concurrent ``refresh()`` calls."""

    kind = "timeseries"

    def __init__(self, name: str, spec: dict, fetcher: SegmentFetcher):
        self.name = name
        self._fetcher = fetcher
        self.base_t: int = spec.get("base_t", 0)
        self.timesteps: List[_TimestepHandle] = [
            _TimestepHandle(name, ts, fetcher)
            for ts in spec.get("timesteps", [])]

    @property
    def total_nbytes(self) -> int:
        return sum(h.nbytes for h in self.timesteps)

    @property
    def latest_t(self) -> Optional[int]:
        return self.timesteps[-1].t if self.timesteps else None

    def handle(self, t: int) -> _TimestepHandle:
        i = t - self.base_t
        if i < 0:
            raise KeyError(f"{self.name}: timestep {t} dropped by retention "
                           f"(oldest retained is {self.base_t})")
        if i >= len(self.timesteps):
            raise KeyError(f"{self.name}: timestep {t} not (yet) in the "
                           f"journal — latest is {self.latest_t}")
        return self.timesteps[i]

    def add_timestep(self, spec: dict) -> None:
        expect = self.base_t + len(self.timesteps)
        if spec["t"] != expect:
            raise ValueError(f"{self.name}: journal timestep {spec['t']} "
                             f"out of order (expected {expect})")
        if not spec["keyframe"] and not self.timesteps:
            raise ValueError(f"{self.name}: delta timestep {spec['t']} "
                             f"has no retained predecessor")
        self.timesteps.append(_TimestepHandle(self.name, spec, self._fetcher))

    def drop_before(self, t: int) -> List[str]:
        """Apply a retention record: forget timesteps ``< t`` and return
        their segment keys so the caller can drop them from the fetch
        index.  ``t`` must land on a keyframe — the chain invariant."""
        if t <= self.base_t:
            return []
        n = min(t - self.base_t, len(self.timesteps))
        if n < len(self.timesteps) and not self.timesteps[n].keyframe:
            raise ValueError(f"{self.name}: retention boundary t={t} is not "
                             f"a keyframe — remaining chain would dangle")
        dropped: List[str] = []
        for h in self.timesteps[:n]:
            dropped.extend(h.segment_keys)
        del self.timesteps[:n]
        self.base_t += n
        return dropped

    def open_reader(self, options: Optional[SessionOptions] = None,
                    **legacy) -> "_TimeseriesReader":
        _resolve_session_options(options, legacy,
                                 "StoreTimeseriesVar.open_reader")
        return _TimeseriesReader(self)


class _TimeseriesReader:
    """Chain-decoding reader over a (possibly growing) timeseries variable.

    ``read(t)`` decodes timestep ``t`` through its keyframe→delta chain,
    reusing the previous reconstruction when ``t`` continues the cached
    chain — a follow-mode session walking t, t+1, t+2 pays exactly one new
    delta decode per step, which is what makes it bit-identical AND
    byte-identical to a one-shot session reading the same timesteps.
    ``request(eps)`` serves the uniform session interface by decoding the
    latest visible timestep (the live-dashboard semantics)."""

    def __init__(self, var: StoreTimeseriesVar):
        self.var = var
        self.bytes_fetched = 0
        self._charged: set = set()                     # timestep indices
        self._chain: Optional[Tuple[int, np.ndarray]] = None  # (t, recon)

    def _charge(self, h: _TimestepHandle) -> None:
        if h.t not in self._charged:
            self.bytes_fetched += h.nbytes
            self._charged.add(h.t)

    def read(self, t: int) -> Tuple[np.ndarray, float]:
        """Decode timestep ``t``; returns ``(data, certified L-inf bound)``."""
        h = self.var.handle(t)
        # find the chain start: the latest keyframe at or before t, or the
        # cached reconstruction if it is an ancestor on the same chain
        start = t
        while not self.var.handle(start).keyframe:
            start -= 1
        prev: Optional[np.ndarray] = None
        begin = start
        if self._chain is not None and start <= self._chain[0] <= t:
            begin, prev = self._chain[0] + 1, self._chain[1]
        for k in range(begin, t + 1):
            hk = self.var.handle(k)
            snap = hk.load()            # fetches (verified) on first touch
            prev = decode_timestep(snap, None if hk.keyframe else prev)
            self._charge(hk)
        self._chain = (t, prev)
        amaxes = [self.var.handle(k).amax for k in range(start, t + 1)]
        return prev, timestep_bound(h.eps, amaxes)

    def request(self, eps: float) -> Tuple[np.ndarray, float]:
        latest = self.var.latest_t
        if latest is None:
            raise KeyError(f"{self.var.name}: no timesteps appended yet "
                           f"(refresh() the archive or append first)")
        return self.read(latest)


# ---------------------------------------------------------------------------
# StoreArchive
# ---------------------------------------------------------------------------


class _LazyMasks:
    """Mapping-like mask access that fetches (and verifies) mask segments on
    first use — a session that never touches a variable never moves its
    mask."""

    def __init__(self, specs: Dict[str, dict], fetcher: SegmentFetcher):
        self._specs = specs
        self._fetcher = fetcher
        self._cache: Dict[str, OutlierMask] = {}
        # variable -> first fetch failure: a permanently missing mask
        # degrades to "no mask" — masked points are fully present in the
        # progressive encoding (the mask only overlays their exact values),
        # so serving the un-patched reconstruction under the plane bound
        # stays certified; only the eb_array's exact-point zeros are lost
        self._pinned: Dict[str, BaseException] = {}

    def get(self, name: str) -> Optional[OutlierMask]:
        if name not in self._specs or name in self._pinned:
            return None
        if name not in self._cache:
            spec = self._specs[name]
            shape = tuple(spec["shape"])
            try:
                bitmap = self._fetcher.fetch(f"{name}/mask/bitmap")
                values = np.frombuffer(
                    self._fetcher.fetch(f"{name}/mask/values"),
                    dtype=np.float64, count=spec["n_true"])
            except Exception as e:
                self._pinned[name] = e
                return None
            mask = np.unpackbits(
                np.frombuffer(bitmap, dtype=np.uint8),
                count=int(np.prod(shape))).astype(bool).reshape(shape)
            self._cache[name] = OutlierMask(mask=mask, values=values)
        return self._cache[name]

    def __contains__(self, name: str) -> bool:
        return name in self._specs

    def __getitem__(self, name: str) -> OutlierMask:
        m = self.get(name)
        if m is None:
            raise KeyError(name)
        return m

    def keys(self):
        return self._specs.keys()

    def values(self):
        return [self[k] for k in self._specs]


StoreSpec = Union[ByteStore, Dict[str, ByteStore],
                  Callable[[str], ByteStore]]


def _parse_segment_index(manifest: dict, payload_offset: int,
                         with_depth: bool = True
                         ) -> Dict[str, SegmentEntry]:
    """v3 entries are (blob, offset, size, crc, codec); v2 drop the codec
    field; v1 are (offset, size, crc) with an implicit single blob ``""``
    — all three parse (codec stays None on v1/v2, whose payloads are
    self-describing through the legacy tag bytes).  ``payload_offset``
    shifts only the single-file blob (whose payload follows the in-file
    manifest).  ``with_depth=False`` skips the per-key depth parse — depth
    is cache eviction metadata, dead weight on a cache-less open."""
    index: Dict[str, SegmentEntry] = {}
    for key, entry in manifest["segments"].items():
        codec = None
        if len(entry) == 5:
            blob, off, size, crc, codec = entry
        elif len(entry) == 4:
            blob, off, size, crc = entry
        else:
            blob, (off, size, crc) = "", entry
        index[key] = SegmentEntry(
            offset=off + (payload_offset if blob == "" else 0),
            size=size, crc=crc, blob=blob,
            depth=segment_depth(key) if with_depth else 0,
            codec=codec)
    return index


def manifest_archive_id(manifest: dict) -> str:
    """Stable id grouping one archive's cache entries for per-archive
    budgets: a hash of the canonical manifest JSON, so every session over
    the same container (local, re-opened, or remote) lands in the same
    budget group while distinct archives never collide on id *and* crc."""
    blob = json.dumps(manifest, sort_keys=True).encode("utf-8")
    return f"prs-{zlib.crc32(blob):08x}-{len(blob)}"


class StoreArchive:
    """An archive whose segments live on one or more ByteStores; ``open()``
    returns a regular RetrievalSession streaming through the SegmentFetcher.

    ``store`` may be a single ByteStore (single-blob containers), a mapping
    ``blob name -> ByteStore`` (sharded, backends may differ per shard), or
    a resolver callable ``blob name -> ByteStore`` invoked lazily on first
    touch — sessions that never read a shard never open (or require) it.

    ``cache`` is an optional cross-session `SegmentCache`: sessions opened
    from this archive (or any archive sharing the cache object) serve
    repeat segment reads from RAM instead of the backing store.  Entries
    are tagged with this archive's ``archive_id`` (derived from the
    manifest unless overridden) and each segment's plane depth, so a shared
    cache can evict depth-weighted and hold per-archive floors/caps.

    ``journal_source`` (live v4 archives) is a zero-argument callable
    returning the CURRENT full journal bytes — re-read on every
    ``refresh()``.  Local opens re-read the file; HTTP opens go through
    ``HTTPByteStore.read_all``'s conditional GET, so an unchanged journal
    costs one 304 header exchange.
    """

    def __init__(self, manifest: dict, store: StoreSpec,
                 payload_offset: int = 0, prefetch_workers: int = 2,
                 verify: bool = True,
                 cache: Optional[SegmentCache] = None,
                 archive_id: Optional[str] = None,
                 retry_policy: Optional[RetryPolicy] = None,
                 quarantine: Optional[BlobQuarantine] = None,
                 journal_source: Optional[Callable[[], bytes]] = None):
        if manifest.get("format") != "prstore":
            raise ValueError("not a prstore manifest")
        if manifest.get("version", 0) > FORMAT_VERSION:
            raise ValueError(f"container version {manifest.get('version')} "
                             f"newer than supported {FORMAT_VERSION}")
        self.manifest = manifest
        self.method: str = manifest["method"]
        self.ranges: Dict[str, float] = dict(manifest["ranges"])
        self.shapes: Dict[str, Tuple[int, ...]] = {
            k: tuple(v) for k, v in manifest["shapes"].items()}
        # the id only matters as a cache grouping key, and hashing a big
        # manifest costs ~ms per open — derive it eagerly only when a cache
        # will consume it (the property below derives on demand otherwise).
        # Live archives also pin it now: journal replay mutates the manifest
        # dict (blob sizes), and the grouping id must not drift with growth.
        if archive_id is None and (cache is not None
                                   or journal_source is not None):
            archive_id = manifest_archive_id(manifest)
        self._archive_id = archive_id
        index = _parse_segment_index(manifest, payload_offset,
                                     with_depth=cache is not None)
        # store-backed sessions get the unified fault-tolerance defaults:
        # retries with jittered backoff, and a circuit breaker whose
        # threshold sits above one segment's full retry budget (a single
        # persistently-corrupt segment must not quarantine a healthy blob)
        if retry_policy is None:
            retry_policy = RetryPolicy()
        if quarantine is None:
            quarantine = BlobQuarantine(
                threshold=2 * retry_policy.max_attempts)
        self.retry_policy = retry_policy
        self.quarantine = quarantine
        self.fetcher = SegmentFetcher(index, store,
                                      prefetch_workers=prefetch_workers,
                                      verify=verify, cache=cache,
                                      archive_id=archive_id or "",
                                      retry_policy=retry_policy,
                                      quarantine=quarantine)
        self.masks = _LazyMasks(manifest["masks"], self.fetcher)
        self.variables: Dict[str, object] = {}
        for name, spec in manifest["variables"].items():
            if spec["kind"] == "bitplane":
                self.variables[name] = StoreBitplaneVar(name, spec,
                                                        self.fetcher)
            elif spec["kind"] == "timeseries":
                self.variables[name] = StoreTimeseriesVar(name, spec,
                                                          self.fetcher)
            else:
                self.variables[name] = StoreSnapshotVar(name, spec,
                                                        self.fetcher)
        # -- live-archive (v4 journal) state --------------------------------
        self.sealed: bool = bool(manifest.get("sealed", False))
        self._journal_source = journal_source
        # a consolidated manifest records how many leading journal records
        # it already folded in; replay starts past them
        self._journal_skip: int = int(manifest.get("journal_records", 0))
        self._refresh_mu = threading.Lock()
        if journal_source is not None and not self.sealed:
            self.refresh()

    # -- live archives (journal replay) --------------------------------------

    def refresh(self) -> int:
        """Re-read the journal and apply any records appended since the
        last refresh (or open); returns how many were applied.  Only
        *complete* lines are consumed — a partially-written tail record
        (the writer mid-append) waits for the next refresh.  Static and
        sealed archives return 0 without touching the store."""
        if self._journal_source is None or self.sealed:
            return 0
        with self._refresh_mu:
            raw = self._journal_source()
            lines = raw.split(b"\n")[:-1]   # drop the unterminated tail
            records = lines[self._journal_skip:]
            applied = 0
            for line in records:
                line = line.strip()
                if line:
                    self._apply_journal_record(json.loads(line))
                applied += 1
            self._journal_skip += applied
            return applied

    def _apply_journal_record(self, rec: dict) -> None:
        op = rec.get("op")
        if op == "segment":
            key = rec["key"]
            self.fetcher.add_segments({key: SegmentEntry(
                offset=rec["offset"], size=rec["size"], crc=rec["crc"],
                blob=rec["blob"], depth=segment_depth(key),
                codec=rec.get("codec"))})
            # keep the manifest's blob-size registry current: the lazy HTTP
            # blob resolver reads it to skip per-blob HEAD probes
            blobs = self.manifest.setdefault("blobs", {})
            blobs[rec["blob"]] = max(blobs.get(rec["blob"], 0),
                                     rec["offset"] + rec["size"])
        elif op == "var":
            name = rec["name"]
            if name not in self.variables:
                self.variables[name] = StoreTimeseriesVar(
                    name, {"kind": "timeseries"}, self.fetcher)
                self.shapes[name] = tuple(rec["shape"])
                self.ranges[name] = rec["range"]
        elif op == "timestep":
            var = self.variables[rec["var"]]
            if not isinstance(var, StoreTimeseriesVar):
                raise ValueError(f"journal timestep for non-timeseries "
                                 f"variable {rec['var']!r}")
            var.add_timestep(rec)
        elif op == "retention":
            var = self.variables[rec["var"]]
            self.fetcher.remove_segments(var.drop_before(rec["base_t"]))
        elif op == "seal":
            self.sealed = True
        else:
            raise ValueError(f"unknown journal op {op!r}")

    @property
    def archive_id(self) -> str:
        if self._archive_id is None:
            self._archive_id = manifest_archive_id(self.manifest)
        return self._archive_id

    @property
    def cache(self) -> Optional[SegmentCache]:
        return self.fetcher.cache

    @property
    def total_nbytes(self) -> int:
        return sum(e.size for e in self.fetcher.index.values())

    def codec_bytes(self) -> Dict[str, int]:
        """Encoder-side codec choice: archived bytes per entropy codec,
        straight from the manifest (no payload reads).  v1/v2 archives
        report everything as ``untagged`` — their manifests predate the
        codec field."""
        from repro.bitplane.codecs import codec_name
        out: Dict[str, int] = {}
        for e in self.fetcher.index.values():
            name = codec_name(e.codec)
            out[name] = out.get(name, 0) + e.size
        return out

    def n_elements(self, name: str) -> int:
        return int(np.prod(self.shapes[name]))

    def open(self, options: Optional[SessionOptions] = None,
             **legacy) -> RetrievalSession:
        opts = _resolve_session_options(options, legacy, "StoreArchive.open")
        return RetrievalSession(self, opts)

    def close(self) -> None:
        self.fetcher.close()
        self.fetcher.close_stores()

    def __enter__(self) -> "StoreArchive":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def is_url(source: str) -> bool:
    return source.startswith(("http://", "https://"))


def _resolve_open_options(options: Optional[OpenOptions],
                          legacy: dict, where: str) -> OpenOptions:
    if legacy:
        if options is not None:
            raise TypeError(f"{where}: pass either an OpenOptions object or "
                            f"legacy keyword arguments, not both")
        return _from_legacy(OpenOptions, legacy, where)
    return options if options is not None else OpenOptions()


def _journal_manifest(manifest: dict) -> bool:
    """Does this manifest advertise a live journal worth tailing?"""
    return bool(manifest.get("journal")) and not manifest.get("sealed")


def open_archive(source, options: Optional[OpenOptions] = None,
                 **legacy) -> StoreArchive:
    """Open a container — single-file, sharded, local, or over HTTP.

    ``source`` may be:

      * a ``.prs`` file path — manifest parsed from the file head, segment
        reads through a mmap'd FileByteStore;
      * a directory (or explicit ``manifest.json`` path) — sharded archive;
        blobs default to FileByteStores next to the manifest;
      * an ``http(s)://`` URL — of a ``manifest.json`` (sharded; blobs
        default to HTTPByteStores resolved relative to the manifest URL) or
        of a single ``.prs`` resource (ranged GETs through HTTPByteStore);
      * a manifest dict — blobs come from ``options.blob_resolver``;
      * an already-constructed ByteStore (e.g. a RemoteByteStore) — the
        container header is read *through* the store, so header/manifest
        transfer is accounted like any other read.

    ``options`` is an :class:`repro.options.OpenOptions` bundling the
    transport/integrity knobs (prefetch workers, crc verification, blob
    resolver, segment cache, cache-group id, retry policy, quarantine,
    journal following) — see its docstring and presets.  The pre-v4 loose
    keyword arguments still work through a once-warning deprecation shim.

    A live (journaled, unsealed) sharded archive is opened at its current
    journal tail; ``StoreArchive.refresh()`` picks up later appends —
    locally by re-reading ``journal.jsonl``, over HTTP via a conditional
    GET that costs one 304 when nothing changed.
    """
    opts = _resolve_open_options(options, legacy, "open_archive")
    blob_resolver = opts.blob_resolver

    def build(manifest: dict, default: Optional[StoreSpec],
              payload_offset: int = 0,
              journal_source: Optional[Callable[[], bytes]] = None
              ) -> StoreArchive:
        return StoreArchive(manifest, blob_resolver or default,
                            payload_offset=payload_offset,
                            prefetch_workers=opts.prefetch_workers,
                            verify=opts.verify, cache=opts.cache,
                            archive_id=opts.archive_id,
                            retry_policy=opts.retry_policy,
                            quarantine=opts.quarantine,
                            journal_source=journal_source)

    def http_store(url: str, **kw) -> HTTPByteStore:
        if opts.retry_policy is not None:
            kw["retry_policy"] = opts.retry_policy
        return HTTPByteStore(url, **kw)

    if isinstance(source, dict):
        if blob_resolver is None:
            raise ValueError("a manifest dict needs a blob_resolver")
        return build(source, None)

    if isinstance(source, str) and is_url(source):
        # detect on the parsed path, not the raw string — signed /
        # parameterized URLs carry query strings after the filename
        if urllib.parse.urlsplit(source).path.endswith(".json"):
            with http_store(source) as ms:
                manifest = json.loads(ms.read_all().decode("utf-8"))
            journal_source = None
            if opts.follow and _journal_manifest(manifest):
                # a persistent store: read_all's ETag makes every poll of an
                # unchanged journal a 304 header exchange
                js = http_store(urllib.parse.urljoin(source, JOURNAL_NAME))
                journal_source = js.read_all
            # blob sizes are recorded in the manifest (and kept current by
            # journal replay), so shard stores skip their HEAD probe
            # entirely (one GET per first-touched shard)
            blob_sizes = manifest.get("blobs", {})
            return build(manifest, lambda blob: http_store(
                urllib.parse.urljoin(source, blob),
                size=blob_sizes.get(blob)),
                journal_source=journal_source)
        source = http_store(source)

    if isinstance(source, str):
        if os.path.isdir(source) or source.endswith(".json"):
            mpath = source if source.endswith(".json") \
                else os.path.join(source, MANIFEST_NAME)
            with open(mpath, "rb") as fh:
                manifest = json.loads(fh.read().decode("utf-8"))
            root = os.path.dirname(os.path.abspath(mpath))
            journal_source = None
            if opts.follow and _journal_manifest(manifest):
                jpath = os.path.join(root, JOURNAL_NAME)

                def journal_source() -> bytes:
                    try:
                        with open(jpath, "rb") as jf:
                            return jf.read()
                    except FileNotFoundError:
                        return b""
            return build(manifest, lambda blob: FileByteStore(
                os.path.join(root, blob)), journal_source=journal_source)
        source = FileByteStore(source)

    # single-blob container: parse the header through the store itself
    store = source
    head = store.read(0, len(MAGIC) + 8)
    if head[:len(MAGIC)] != MAGIC:
        store.close()
        raise ValueError("bad magic: not a PRSTORE container")
    (mlen,) = struct.unpack("<Q", head[len(MAGIC):])
    manifest = json.loads(store.read(len(MAGIC) + 8, mlen).decode("utf-8"))
    if blob_resolver is not None:
        spec: StoreSpec = (lambda blob: store if blob == ""
                           else blob_resolver(blob))
        return StoreArchive(manifest, spec,
                            payload_offset=len(MAGIC) + 8 + mlen,
                            prefetch_workers=opts.prefetch_workers,
                            verify=opts.verify, cache=opts.cache,
                            archive_id=opts.archive_id,
                            retry_policy=opts.retry_policy,
                            quarantine=opts.quarantine)
    return StoreArchive(manifest, store,
                        payload_offset=len(MAGIC) + 8 + mlen,
                        prefetch_workers=opts.prefetch_workers,
                        verify=opts.verify, cache=opts.cache,
                        archive_id=opts.archive_id,
                        retry_policy=opts.retry_policy,
                        quarantine=opts.quarantine)


def memory_store_archive(archive: Archive,
                         options: Optional[OpenOptions] = None,
                         shard_by: str = "single",
                         **legacy) -> StoreArchive:
    """Round an in-memory Archive through the container format without
    touching disk (tests, benchmarks).  ``shard_by`` exercises the sharded
    manifest with one MemoryByteStore per blob."""
    opts = _resolve_open_options(options, legacy, "memory_store_archive")
    manifest, payloads = build_sharded_container(archive, shard_by=shard_by)
    manifest = json.loads(json.dumps(manifest))   # exact same path as disk
    stores = {blob: MemoryByteStore(data) for blob, data in payloads.items()}
    spec: StoreSpec = stores if shard_by != "single" else stores.get(
        "", MemoryByteStore(b""))
    return StoreArchive(manifest, spec,
                        prefetch_workers=opts.prefetch_workers,
                        verify=opts.verify, cache=opts.cache,
                        archive_id=opts.archive_id,
                        retry_policy=opts.retry_policy,
                        quarantine=opts.quarantine)
