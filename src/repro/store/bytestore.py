"""Pluggable byte stores: where an archive container's bytes physically live.

One interface — ``read(offset, length)`` over a flat address space — with
four backends:

  * MemoryByteStore   bytes in RAM (tests, and the write target of
                      ``save_archive`` before flushing to disk);
  * FileByteStore     a local file, mmap'd so range reads are zero-copy page
                      faults instead of seek+read syscalls;
  * HTTPByteStore     a real network backend: HTTP ranged GETs
                      (``Range: bytes=a-b``) over persistent per-thread
                      connections, with retry/exponential-backoff on
                      5xx/timeouts and adjacent-range coalescing in
                      ``read_batch``;
  * RemoteByteStore   wraps another store behind a modelled network link
                      (per-request latency + bandwidth, single shared link),
                      so benchmarks measure real end-to-end *time*, not just
                      byte counts — and so prefetch has actual latency to
                      hide.  The model is validated against HTTPByteStore
                      over loopback in benchmarks/bench_store.py.

All backends are thread-safe: the SegmentFetcher issues background reads
from its prefetch executor while the caller decodes on the main thread.
"""
from __future__ import annotations

import http.client
import mmap
import os
import socket
import threading
import time
import urllib.parse
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

from repro.store.retry import RetryPolicy


def _check_range(offset: int, length: int, size: int, label: str) -> None:
    """Uniform range validation for every backend: a negative length is a
    caller bug (not an EOF condition) and must never silently truncate."""
    if length < 0:
        raise ValueError(f"negative read length {length} on {label}")
    if offset < 0 or offset + length > size:
        raise EOFError(f"read [{offset}, {offset + length}) outside "
                       f"{label} of {size} bytes")


class ByteStore:
    """Range-readable byte container."""

    def read(self, offset: int, length: int) -> bytes:
        raise NotImplementedError

    def read_batch(self, ranges: Sequence[Tuple[int, int]]) -> List[bytes]:
        """Read several ``(offset, length)`` ranges; results in call order.
        Backends with per-request overhead override this to coalesce
        adjacent ranges into fewer wire requests."""
        return [self.read(off, ln) for off, ln in ranges]

    @property
    def size(self) -> int:
        raise NotImplementedError

    def close(self) -> None:
        pass

    def __enter__(self) -> "ByteStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class MemoryByteStore(ByteStore):
    def __init__(self, data: bytes):
        self._data = data

    def read(self, offset: int, length: int) -> bytes:
        _check_range(offset, length, len(self._data), "memory store")
        return bytes(self._data[offset:offset + length])

    @property
    def size(self) -> int:
        return len(self._data)


class FileByteStore(ByteStore):
    """mmap-backed local file store (read-only)."""

    def __init__(self, path: str):
        self.path = path
        self._fh = open(path, "rb")
        self._size = os.fstat(self._fh.fileno()).st_size
        self._mm = mmap.mmap(self._fh.fileno(), 0, access=mmap.ACCESS_READ) \
            if self._size else None

    def read(self, offset: int, length: int) -> bytes:
        _check_range(offset, length, self._size, self.path)
        return self._mm[offset:offset + length] if length else b""

    @property
    def size(self) -> int:
        return self._size

    def close(self) -> None:
        if self._mm is not None:
            self._mm.close()
            self._mm = None
        self._fh.close()


@dataclass
class HTTPStats:
    """Accounting for a real HTTP link."""
    requests: int = 0          # HTTP requests that returned a usable response
    retries: int = 0           # attempts repeated after a 5xx/transport error
    bytes_moved: int = 0       # payload bytes received (incl. coalescing gaps)
    coalesced_ranges: int = 0  # ranges merged into a neighbour's request
    wasted_bytes: int = 0      # gap bytes transferred only to merge ranges
    not_modified: int = 0      # conditional GETs answered 304 from our cache


class HTTPByteStore(ByteStore):
    """Ranged-GET byte store over HTTP(S) — the archive's real wire path.

    * connection reuse: one persistent ``http.client`` connection per thread
      (the SegmentFetcher reads from its prefetch pool and the main thread
      concurrently), re-established transparently after errors;
    * ``read_batch`` coalesces ranges whose gap is <= ``coalesce_gap`` bytes
      into a single ranged GET — per-request latency dominates small segment
      reads, so paying a few wasted gap bytes for one round-trip is the same
      trade HTTP/2 clients make — and ``prefers_batch`` advertises this to
      the fetcher;
    * transient failures (HTTP 5xx, timeouts, connection resets) retry with
      exponential backoff; 4xx are caller errors and raise immediately.
    """

    prefers_batch = True

    def __init__(self, url: str, timeout_s: float = 10.0,
                 max_retries: int = 4, backoff_s: float = 0.05,
                 coalesce_gap: int = 4096, size: Optional[int] = None,
                 retry_policy: Optional[RetryPolicy] = None):
        parts = urllib.parse.urlsplit(url)
        if parts.scheme not in ("http", "https"):
            raise ValueError(f"HTTPByteStore needs an http(s) URL, got {url!r}")
        self.url = url
        self._host = parts.netloc
        self._path = parts.path or "/"
        if parts.query:
            self._path += "?" + parts.query
        self._conn_cls = (http.client.HTTPSConnection
                          if parts.scheme == "https"
                          else http.client.HTTPConnection)
        self.timeout_s = float(timeout_s)
        # the unified policy subsumes the legacy (max_retries, backoff_s)
        # knobs, which stay as a convenience spelling of the same thing
        self.retry_policy = retry_policy if retry_policy is not None \
            else RetryPolicy(max_attempts=int(max_retries) + 1,
                             backoff_s=float(backoff_s))
        self.max_retries = self.retry_policy.max_attempts - 1
        self.backoff_s = self.retry_policy.backoff_s
        self.coalesce_gap = int(coalesce_gap)
        self.stats = HTTPStats()
        self._stats_lock = threading.Lock()
        self._local = threading.local()
        # every thread's persistent connection, so close() can close them
        # all — threading.local alone would leak the pool threads' sockets
        self._conns_lock = threading.Lock()
        self._conns: set = set()
        self._closed = False
        # probed lazily on first use: opening a store must not cost a HEAD
        # round-trip when the caller already knows the size (sharded
        # manifests record every blob's size) or only wants read_all()
        self._size: Optional[int] = None if size is None else int(size)
        # conditional-GET state for read_all: the last full body plus the
        # validator it arrived under (None until a server sends an ETag)
        self._etag: Optional[str] = None
        self._body_cache: Optional[bytes] = None

    # -- connection management ----------------------------------------------

    def _conn(self) -> http.client.HTTPConnection:
        conn = getattr(self._local, "conn", None)
        if conn is None:
            conn = self._conn_cls(self._host, timeout=self.timeout_s)
            conn.connect()
            # mirror the server's disable_nagle_algorithm: request headers
            # go out in small writes, and Nagle would hold them hostage to
            # the server's delayed ACK (~40ms per exchange)
            conn.sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            self._local.conn = conn
            with self._conns_lock:
                self._conns.add(conn)
        return conn

    def _drop_conn(self) -> None:
        conn = getattr(self._local, "conn", None)
        if conn is not None:
            with self._conns_lock:
                self._conns.discard(conn)
            conn.close()
            self._local.conn = None

    def _request(self, method: str, headers: dict) -> Tuple[int, dict, bytes]:
        """One HTTP exchange with retry/backoff; returns (status, headers,
        body).  Retries 5xx and transport-level failures; anything else is
        returned to the caller for interpretation."""
        if self._closed:
            raise ValueError(f"I/O on closed HTTPByteStore {self.url}")
        last_err: Optional[Exception] = None
        policy = self.retry_policy
        deadline = policy.deadline_from(time.monotonic())
        attempts = 0
        for attempt in range(policy.max_attempts):
            if attempt:
                sleep = policy.backoff(attempt)
                if time.monotonic() + sleep > deadline:
                    break                 # out of wall-clock budget
                with self._stats_lock:
                    self.stats.retries += 1
                time.sleep(sleep)
            attempts += 1
            try:
                conn = self._conn()
                conn.request(method, self._path, headers=headers)
                resp = conn.getresponse()
                body = resp.read()
                if resp.will_close:
                    self._drop_conn()
                if resp.status >= 500:
                    last_err = IOError(f"HTTP {resp.status} {resp.reason}")
                    continue
                with self._stats_lock:
                    self.stats.requests += 1
                return resp.status, dict(resp.getheaders()), body
            except (socket.timeout, ConnectionError, OSError,
                    http.client.HTTPException) as e:
                last_err = e
                self._drop_conn()
        raise IOError(f"{method} {self.url}: giving up after "
                      f"{attempts} attempts: {last_err}")

    def _probe_size(self) -> int:
        status, headers, _ = self._request("HEAD", {})
        if status != 200:
            raise IOError(f"HEAD {self.url}: HTTP {status}")
        clen = {k.lower(): v for k, v in headers.items()}.get("content-length")
        if clen is None:
            raise IOError(f"HEAD {self.url}: no Content-Length")
        return int(clen)

    # -- reads ---------------------------------------------------------------

    def _ranged_get(self, offset: int, length: int) -> bytes:
        status, _, body = self._request(
            "GET", {"Range": f"bytes={offset}-{offset + length - 1}"})
        if status == 206:
            data = body
        elif status == 200:
            # server ignored Range and sent the whole resource
            data = body[offset:offset + length]
        else:
            raise IOError(f"GET {self.url} [{offset}:+{length}]: "
                          f"HTTP {status}")
        if len(data) != length:
            raise IOError(f"GET {self.url} [{offset}:+{length}]: got "
                          f"{len(data)} bytes")
        with self._stats_lock:
            self.stats.bytes_moved += len(body)
        return data

    def read_all(self) -> bytes:
        """One plain GET of the whole resource (no size probe, no Range) —
        the cheap path for small metadata like a sharded manifest.

        Conditional on re-read: when the first GET carried an ``ETag``, the
        body is kept and every later ``read_all`` revalidates with
        ``If-None-Match`` — a ``304`` serves the cached body for the cost
        of a header exchange.  This is the polling primitive a live
        append-only archive needs: manifest unchanged -> no transfer,
        manifest rewritten -> new ETag -> fresh body, never a stale mix."""
        headers = {}
        with self._stats_lock:
            etag, cached = self._etag, self._body_cache
        if etag is not None and cached is not None:
            headers["If-None-Match"] = etag
        status, resp_headers, body = self._request("GET", headers)
        if status == 304:
            with self._stats_lock:
                self.stats.not_modified += 1
            return cached
        if status != 200:
            raise IOError(f"GET {self.url}: HTTP {status}")
        new_etag = {k.lower(): v for k, v in resp_headers.items()}.get("etag")
        with self._stats_lock:
            self.stats.bytes_moved += len(body)
            self._etag = new_etag
            self._body_cache = body if new_etag is not None else None
        if self._size is None:
            self._size = len(body)
        return body

    def read(self, offset: int, length: int) -> bytes:
        _check_range(offset, length, self.size, self.url)
        if length == 0:
            return b""
        return self._ranged_get(offset, length)

    def read_batch(self, ranges: Sequence[Tuple[int, int]]) -> List[bytes]:
        ranges = list(ranges)
        size = self.size
        for off, ln in ranges:
            _check_range(off, ln, size, self.url)
        # coalesce in offset order, then slice results back into call order
        order = sorted((r for r in ranges if r[1] > 0),
                       key=lambda r: r[0])
        spans: List[Tuple[int, int]] = []          # (start, end) merged GETs
        for off, ln in order:
            if spans and off <= spans[-1][1] + self.coalesce_gap:
                if off + ln > spans[-1][1]:
                    spans[-1] = (spans[-1][0], off + ln)
                with self._stats_lock:
                    self.stats.coalesced_ranges += 1
            else:
                spans.append((off, off + ln))
        data = {start: self._ranged_get(start, end - start)
                for start, end in spans}
        # gap bytes moved only to merge requests (segments never overlap)
        wasted = max(0, sum(e - s for s, e in spans)
                     - sum(ln for _, ln in order))
        with self._stats_lock:
            self.stats.wasted_bytes += wasted
        out: List[bytes] = []
        for off, ln in ranges:
            if ln == 0:
                out.append(b"")
                continue
            start = next(s for s, e in spans if s <= off and off + ln <= e)
            buf = data[start]
            out.append(buf[off - start:off - start + ln])
        return out

    @property
    def size(self) -> int:
        if self._size is None:
            self._size = self._probe_size()   # benign race: both probes agree
        return self._size

    def close(self) -> None:
        self._closed = True
        self._drop_conn()
        with self._conns_lock:
            conns, self._conns = set(self._conns), set()
        for conn in conns:            # other threads' persistent connections
            conn.close()


@dataclass
class LinkStats:
    """Accounting for a simulated network link."""
    requests: int = 0
    bytes_moved: int = 0
    busy_s: float = 0.0        # time the link spent transferring


class RemoteByteStore(ByteStore):
    """A store on the far side of a modelled network link.

    Every read pays ``latency_s`` of request round-trip (propagation —
    concurrent requests overlap it, like pipelined HTTP range reads) plus
    ``length / bandwidth_bps`` of wire time serialized FIFO over one shared
    link (a lock — bandwidth is not multiplied by issuing requests in
    parallel).  The delay is *real wall time* (``time.sleep``), so overlap
    with compute on other threads is physically measured, not estimated.
    """

    def __init__(self, inner: ByteStore, latency_s: float = 1e-3,
                 bandwidth_bps: float = 400e6):
        self.inner = inner
        self.latency_s = float(latency_s)
        self.bandwidth_bps = float(bandwidth_bps)
        self.stats = LinkStats()
        self._link = threading.Lock()

    def transfer_time(self, length: int) -> float:
        return self.latency_s + length / self.bandwidth_bps

    def read(self, offset: int, length: int) -> bytes:
        _check_range(offset, length, self.inner.size, "remote store")
        time.sleep(self.latency_s)       # round-trip; overlaps across threads
        wire = length / self.bandwidth_bps
        with self._link:                 # one transfer on the wire at a time
            time.sleep(wire)
            self.stats.requests += 1
            self.stats.bytes_moved += length
            self.stats.busy_s += self.latency_s + wire
        return self.inner.read(offset, length)

    @property
    def size(self) -> int:
        return self.inner.size

    def close(self) -> None:
        self.inner.close()
