"""Pluggable byte stores: where an archive container's bytes physically live.

One interface — ``read(offset, length)`` over a flat address space — with
three backends:

  * MemoryByteStore   bytes in RAM (tests, and the write target of
                      ``save_archive`` before flushing to disk);
  * FileByteStore     a local file, mmap'd so range reads are zero-copy page
                      faults instead of seek+read syscalls;
  * RemoteByteStore   wraps another store behind a modelled network link
                      (per-request latency + bandwidth, single shared link),
                      so benchmarks measure real end-to-end *time*, not just
                      byte counts — and so prefetch has actual latency to
                      hide.

All backends are thread-safe: the SegmentFetcher issues background reads
from its prefetch executor while the caller decodes on the main thread.
"""
from __future__ import annotations

import mmap
import os
import threading
import time
from dataclasses import dataclass, field
from typing import Optional


class ByteStore:
    """Range-readable byte container."""

    def read(self, offset: int, length: int) -> bytes:
        raise NotImplementedError

    @property
    def size(self) -> int:
        raise NotImplementedError

    def close(self) -> None:
        pass

    def __enter__(self) -> "ByteStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class MemoryByteStore(ByteStore):
    def __init__(self, data: bytes):
        self._data = data

    def read(self, offset: int, length: int) -> bytes:
        if offset < 0 or offset + length > len(self._data):
            raise EOFError(f"read [{offset}, {offset + length}) outside "
                           f"store of {len(self._data)} bytes")
        return bytes(self._data[offset:offset + length])

    @property
    def size(self) -> int:
        return len(self._data)


class FileByteStore(ByteStore):
    """mmap-backed local file store (read-only)."""

    def __init__(self, path: str):
        self.path = path
        self._fh = open(path, "rb")
        self._size = os.fstat(self._fh.fileno()).st_size
        self._mm = mmap.mmap(self._fh.fileno(), 0, access=mmap.ACCESS_READ) \
            if self._size else None

    def read(self, offset: int, length: int) -> bytes:
        if offset < 0 or offset + length > self._size:
            raise EOFError(f"read [{offset}, {offset + length}) outside "
                           f"{self.path} of {self._size} bytes")
        return self._mm[offset:offset + length]

    @property
    def size(self) -> int:
        return self._size

    def close(self) -> None:
        if self._mm is not None:
            self._mm.close()
            self._mm = None
        self._fh.close()


@dataclass
class LinkStats:
    """Accounting for a simulated network link."""
    requests: int = 0
    bytes_moved: int = 0
    busy_s: float = 0.0        # time the link spent transferring


class RemoteByteStore(ByteStore):
    """A store on the far side of a modelled network link.

    Every read pays ``latency_s`` of request round-trip (propagation —
    concurrent requests overlap it, like pipelined HTTP range reads) plus
    ``length / bandwidth_bps`` of wire time serialized FIFO over one shared
    link (a lock — bandwidth is not multiplied by issuing requests in
    parallel).  The delay is *real wall time* (``time.sleep``), so overlap
    with compute on other threads is physically measured, not estimated.
    """

    def __init__(self, inner: ByteStore, latency_s: float = 1e-3,
                 bandwidth_bps: float = 400e6):
        self.inner = inner
        self.latency_s = float(latency_s)
        self.bandwidth_bps = float(bandwidth_bps)
        self.stats = LinkStats()
        self._link = threading.Lock()

    def transfer_time(self, length: int) -> float:
        return self.latency_s + length / self.bandwidth_bps

    def read(self, offset: int, length: int) -> bytes:
        time.sleep(self.latency_s)       # round-trip; overlaps across threads
        wire = length / self.bandwidth_bps
        with self._link:                 # one transfer on the wire at a time
            time.sleep(wire)
            self.stats.requests += 1
            self.stats.bytes_moved += length
            self.stats.busy_s += self.latency_s + wire
        return self.inner.read(offset, length)

    @property
    def size(self) -> int:
        return self.inner.size

    def close(self) -> None:
        self.inner.close()
