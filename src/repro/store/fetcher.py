"""SegmentFetcher: checksum-verified segment delivery with async prefetch.

The fetcher sits between progressive readers and one or more ByteStores.
Demand ``fetch(key)`` blocks; ``prefetch(keys)`` submits background reads to
a small thread pool so transport overlaps compute (the QoI estimator round
of Algorithm 2 — see core/retrieval.py, which hands ``reassign_eb``'s
predicted next-eps down here via the readers' prefetch hints).

Segments are addressed by ``SegmentEntry`` — ``(blob, offset, size, crc)``.
A single-blob container maps every entry to blob ``""``; a sharded container
(repro.store.container, format v2+) routes each entry to its shard's
ByteStore.  Stores may be handed in directly (one ByteStore, or a mapping
``blob -> ByteStore``) or produced lazily by a resolver callable — a shard
whose variable is never touched is never opened, so dropping a variable's
blob from an object store only breaks sessions that ask for that variable.

Every delivered segment is re-hashed (crc32c) against the manifest before the
decoder sees it; a mismatch raises ChecksumError — a "guaranteed error bound"
computed from silently corrupted planes would be worthless.

Cache discipline: segments are consumed at most once per session (plane
fetches are a monotone prefix per group), so a completed future is *popped*
on fetch — the in-flight map holds only not-yet-consumed prefetches.
Speculative hints the caller never follows up on would otherwise pin their
payloads until close, so ``prefetch`` evicts the oldest completed
*speculative* entries beyond ``max_inflight``.  Non-speculative entries
(exact predictions and fetch_many pipelining) are never evicted — every
internal caller consumes them within a round, and evicting one would force
a duplicate transfer, breaking the equal-bytes-moved property the transfer
benches assert.

An optional cross-session `SegmentCache` sits under all of this: verified
bytes are inserted after their first store read, and later sessions (or a
re-opened reader) are served from RAM — ``stats.store_reads`` counts actual
ByteStore reads, ``stats.cache_hits`` the reads the cache absorbed.  Cache
insertions carry each segment's *plane depth* (``SegmentEntry.depth`` — the
bitplane index, parsed from the manifest key by ``container.segment_depth``)
and this fetcher's ``archive_id`` so the cache can evict depth-weighted
(shared MSB prefixes out-live rarely-shared LSB tails) and enforce
per-archive floors/caps — see repro.store.cache.

``FetchStats`` also aggregates the *contribution-cache* counters
(``contrib_resident_bytes`` / ``contrib_peak_bytes`` / ``contrib_spills`` /
``contrib_recomputes``): every store-backed `_BitplaneVarReader` opened over
this fetcher uses ``stats`` as its ContribStats sink, so one object reports
both transport traffic and reader memory behaviour under a budget (see
core/refactor.py for the exact counter semantics).

Stores whose ``prefers_batch`` attribute is true (HTTPByteStore) receive
multi-segment submissions as one ``read_batch`` call, letting the store
coalesce adjacent ranges into fewer wire round-trips.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Mapping, Optional, Tuple, \
    Union

from repro.bitplane.codecs import codec_name
from repro.store.bytestore import ByteStore
from repro.store.cache import SegmentCache
from repro.store.crc import crc32c
from repro.store.retry import (
    OPEN,
    PROBE,
    BlobQuarantine,
    BlobQuarantinedError,
    RetryPolicy,
    is_transient,
)


class ChecksumError(IOError):
    """A fetched segment failed crc32c verification."""


@dataclass(frozen=True, slots=True)
class SegmentEntry:
    """Manifest index entry: where a segment lives and what it must hash to.

    ``depth`` is the segment's progressive depth (bitplane index / snapshot
    index; 0 for signs, masks and other always-needed segments) — cache
    eviction metadata, not addressing.  ``codec`` is the plane-codec id the
    entropy stage chose for this segment (manifest v3; None for non-plane
    segments and for v1/v2 archives, whose payloads are self-describing) —
    transport accounting metadata, not decode state."""
    offset: int
    size: int
    crc: int
    blob: str = ""
    depth: int = 0
    codec: Optional[int] = None


StoreSpec = Union[ByteStore, Mapping[str, ByteStore],
                  Callable[[str], ByteStore]]


@dataclass(slots=True)
class FetchStats:
    """Transport accounting for one fetcher."""
    demand_fetches: int = 0    # blocking reads served straight from store
    pipelined_hits: int = 0    # served by fetch_many's own pipelining
    prefetch_issued: int = 0   # *speculative* background reads submitted
    prefetch_hits: int = 0     # demand fetches answered by a prediction
    bytes_fetched: int = 0     # segment bytes actually pulled from stores
    demand_wait_s: float = 0.0  # time the caller spent blocked on reads
    store_reads: int = 0       # segment reads that hit a ByteStore
    cache_hits: int = 0        # segment reads absorbed by a SegmentCache
    # fault-tolerance counters (see repro.store.retry):
    retries: int = 0           # fetcher-level re-attempts after a failure
    faults_absorbed: int = 0   # failed attempts hidden by a later success
    quarantined_blobs: int = 0  # circuit-open events (blob quarantined)
    # contribution-cache counters (ContribStats sink for store-backed
    # bitplane readers — see core/refactor.py for exact semantics):
    contrib_resident_bytes: int = 0  # contribution fields currently retained
    contrib_peak_bytes: int = 0      # high-water mark of the above
    contrib_spills: int = 0          # fields computed then dropped (budget)
    contrib_recomputes: int = 0      # budget-induced rebuilds of unmoved levels
    # bytes pulled from stores per entropy codec (key = codec name, from the
    # manifest v3 codec field; "untagged" covers masks/snapshots and v1/v2
    # archives) — the on-the-wire view of the encoder's codec choices
    codec_bytes: Dict[str, int] = field(default_factory=dict)
    # guards the contrib_* counters above: this object is the shared
    # ContribStats sink for every store-backed reader of the archive, and
    # under the serve plane those readers mutate from many worker threads —
    # a bare += loses counts (and the peak high-water must see its own
    # delta).  Same contrib_note/contrib_snapshot surface as ContribStats.
    _mu: threading.Lock = field(default_factory=threading.Lock,
                                repr=False, compare=False)

    def contrib_note(self, delta_bytes: int = 0, spills: int = 0,
                     recomputes: int = 0) -> None:
        """Atomically apply a residency delta / spill / recompute event."""
        with self._mu:
            self.contrib_resident_bytes += delta_bytes
            if self.contrib_resident_bytes > self.contrib_peak_bytes:
                self.contrib_peak_bytes = self.contrib_resident_bytes
            self.contrib_spills += spills
            self.contrib_recomputes += recomputes

    def contrib_snapshot(self) -> Tuple[int, int, int, int]:
        with self._mu:
            return (self.contrib_resident_bytes, self.contrib_peak_bytes,
                    self.contrib_spills, self.contrib_recomputes)

    @property
    def hit_rate(self) -> float:
        """Fraction of consumed segments that a *predictive* prefetch had
        already started (fetch_many's pipelining of demanded keys does not
        count — that is latency hiding, not prediction)."""
        served = self.demand_fetches + self.pipelined_hits + self.prefetch_hits
        return self.prefetch_hits / served if served else 0.0


class SegmentFetcher:
    """Keyed, verified access to one archive's segments."""

    def __init__(self, index: Dict[str, SegmentEntry], store: StoreSpec,
                 prefetch_workers: int = 2, verify: bool = True,
                 max_inflight: int = 512,
                 cache: Optional[SegmentCache] = None,
                 archive_id: str = "",
                 retry_policy: Optional[RetryPolicy] = None,
                 quarantine: Optional[BlobQuarantine] = None):
        self.index = index
        self.verify = verify
        self.max_inflight = max_inflight
        self.cache = cache
        self.archive_id = archive_id
        # default = legacy behaviour: one attempt, no circuit breaker.
        # open_archive turns both on for store-backed sessions.
        self.retry_policy = retry_policy if retry_policy is not None \
            else RetryPolicy.none()
        self.quarantine = quarantine
        self.stats = FetchStats()
        self._lock = threading.Lock()
        # key -> (future, from_hint, evictable): from_hint buckets the stats
        # (prediction vs fetch_many pipelining); evictable marks entries a
        # caller may never consume (speculative predictions)
        self._inflight: Dict[str, Tuple[Future, bool, bool]] = {}
        self._pool: Optional[ThreadPoolExecutor] = (
            ThreadPoolExecutor(max_workers=prefetch_workers,
                               thread_name_prefix="seg-prefetch")
            if prefetch_workers > 0 else None)
        # blob -> ByteStore, resolved lazily so untouched shards never open;
        # a separate lock because resolution may be slow (e.g. an HTTP HEAD)
        # and must not block fetch()'s bookkeeping
        self._stores_lock = threading.Lock()
        self._stores: Dict[str, ByteStore] = {}
        self._resolver: Optional[Callable[[str], ByteStore]] = None
        if isinstance(store, ByteStore):
            self._stores[""] = store
        elif callable(store):
            self._resolver = store
        else:
            self._stores.update(store)

    # -- stores --------------------------------------------------------------

    def _store_for(self, blob: str) -> ByteStore:
        with self._stores_lock:
            s = self._stores.get(blob)
            if s is None:
                if self._resolver is None:
                    raise KeyError(
                        f"no ByteStore for blob {blob!r} and no resolver")
                s = self._resolver(blob)
                self._stores[blob] = s
            return s

    def _peek_prefers_batch(self, blob: str) -> bool:
        """Batching decision WITHOUT resolving the blob's store on the
        caller's thread — prefetch is fire-and-forget, and resolution may
        be a network round-trip.  Unresolved blobs go down the batch path
        so resolution happens inside the pool worker (``read_batch``
        degrades to a read loop on stores that don't override it)."""
        with self._stores_lock:
            s = self._stores.get(blob)
        if s is None:
            return self._resolver is not None
        return bool(getattr(s, "prefers_batch", False))

    @property
    def store(self) -> ByteStore:
        """The single-blob store (backwards-compatible accessor)."""
        return self._store_for("")

    @property
    def stores(self) -> Dict[str, ByteStore]:
        with self._stores_lock:
            return dict(self._stores)

    # -- transport -----------------------------------------------------------

    def _verify(self, key: str, entry: SegmentEntry, buf: bytes) -> None:
        if len(buf) != entry.size:
            raise IOError(f"segment {key!r}: short read "
                          f"({len(buf)} of {entry.size} bytes)")
        if self.verify and crc32c(buf) != entry.crc:
            raise ChecksumError(
                f"segment {key!r}: crc32c mismatch "
                f"(got {crc32c(buf):#010x}, manifest {entry.crc:#010x})")

    def _cache_key(self, key: str, entry: SegmentEntry):
        return (key, entry.crc)

    def _read_verified(self, key: str) -> bytes:
        entry = self.index[key]
        if self.cache is not None:
            buf = self.cache.get(self._cache_key(key, entry))
            if buf is not None:
                with self._lock:
                    self.stats.cache_hits += 1
                return buf
        buf = self._store_for(entry.blob).read(entry.offset, entry.size)
        self._verify(key, entry, buf)
        cname = codec_name(entry.codec)
        with self._lock:
            self.stats.bytes_fetched += entry.size
            self.stats.store_reads += 1
            self.stats.codec_bytes[cname] = \
                self.stats.codec_bytes.get(cname, 0) + entry.size
        if self.cache is not None and self.verify:
            # a verify=False fetcher must not publish unverified bytes to a
            # shared cache — hits skip re-hashing on the promise that every
            # insert was checked against the manifest
            self.cache.put(self._cache_key(key, entry), buf,
                           depth=entry.depth, archive=self.archive_id)
        return buf

    def _read_retrying(self, key: str, wait_for_probe: bool = True) -> bytes:
        """``_read_verified`` under the fetcher's RetryPolicy and blob
        quarantine.

        Transient failures (timeouts, resets, checksum mismatches — see
        ``retry.is_transient``) retry with capped, jittered backoff inside
        the policy's deadline; permanent ones raise immediately.  Every
        failed attempt feeds the blob's circuit breaker.  On a quarantined
        blob the fetch waits (deadline permitting) for the half-open window
        and makes exactly ONE probe — a failed probe raises immediately
        instead of burning the remaining budget on a blob that is known
        dead; when the wait does not fit the deadline, the fetch fast-fails
        with ``BlobQuarantinedError``.  Retry exhaustion re-raises the last
        *underlying* error, so callers still see ``ChecksumError`` /
        ``FileNotFoundError`` etc. with their original messages.

        ``wait_for_probe=False`` (background pool reads) fast-fails on an
        open circuit instead of sleeping out the cooldown: prefetches queued
        before the circuit opened must not serialize cooldown sleeps on the
        pool — the CONSUMING fetch owns the wait and the single probe (it
        retries on ``BlobQuarantinedError``, see ``fetch``)."""
        policy = self.retry_policy
        q = self.quarantine
        blob = self.index[key].blob
        deadline = policy.deadline_from(time.monotonic())
        last: Optional[BaseException] = None
        failures = 0
        for attempt in range(1, policy.max_attempts + 1):
            if attempt > 1:
                sleep = policy.backoff(attempt - 1)
                if time.monotonic() + sleep > deadline:
                    break                 # out of wall-clock budget
                with self._lock:
                    self.stats.retries += 1
                time.sleep(sleep)
            probing = False
            if q is not None:
                # once a probe token is held the read below MUST run, so its
                # outcome releases the token — no early exits in between
                state, wait = q.check(blob)
                while state == OPEN:
                    if not wait_for_probe \
                            or time.monotonic() + wait > deadline:
                        exc = BlobQuarantinedError(
                            f"segment {key!r}: blob {blob!r} quarantined "
                            f"(next probe in {wait:.3f}s"
                            + ("" if wait_for_probe
                               else "; background read does not wait") + ")")
                        exc.__cause__ = last
                        raise exc
                    time.sleep(wait)
                    state, wait = q.check(blob)
                probing = state == PROBE
            try:
                buf = self._read_verified(key)
            except BaseException as e:
                last = e
                failures += 1
                if q is not None and q.record_failure(blob):
                    with self._lock:
                        self.stats.quarantined_blobs += 1
                if probing or not is_transient(e):
                    raise
                continue
            if q is not None:
                q.record_success(blob)
            if failures:
                with self._lock:
                    self.stats.faults_absorbed += failures
            return buf
        assert last is not None
        raise last                 # budget exhausted: surface the real cause

    def _read_results_many(self, keys: List[str]
                           ) -> Dict[str, object]:
        """Batched read of same-blob keys, letting batch-preferring stores
        (HTTP) coalesce adjacent ranges into fewer round-trips.  Returns
        per-key ``bytes`` or the per-key exception: a transport failure
        fails the whole batch (every miss shares the cause), but a
        verification failure is attributed ONLY to its own segment — the
        other segments in the batch were delivered fine and must not be
        poisoned with a misnamed error."""
        out: Dict[str, object] = {}
        misses: List[str] = []
        for k in keys:
            entry = self.index[k]
            buf = (self.cache.get(self._cache_key(k, entry))
                   if self.cache is not None else None)
            if buf is not None:
                out[k] = buf
                with self._lock:
                    self.stats.cache_hits += 1
            else:
                misses.append(k)
        if not misses:
            return out
        blob = self.index[misses[0]].blob
        try:
            store = self._store_for(blob)
            bufs = store.read_batch([(self.index[k].offset,
                                      self.index[k].size) for k in misses])
        except BaseException as e:          # transport-level: whole batch
            for k in misses:
                out[k] = e
            return out
        ok_bytes = ok_reads = 0
        ok_codec: Dict[str, int] = {}
        for k, buf in zip(misses, bufs):
            entry = self.index[k]
            try:
                self._verify(k, entry, buf)
            except BaseException as e:      # this segment only
                out[k] = e
                continue
            out[k] = buf
            ok_bytes += entry.size
            ok_reads += 1
            cname = codec_name(entry.codec)
            ok_codec[cname] = ok_codec.get(cname, 0) + entry.size
            if self.cache is not None and self.verify:
                self.cache.put(self._cache_key(k, entry), buf,
                               depth=entry.depth, archive=self.archive_id)
        with self._lock:
            self.stats.bytes_fetched += ok_bytes
            self.stats.store_reads += ok_reads
            for cname, nb in ok_codec.items():
                self.stats.codec_bytes[cname] = \
                    self.stats.codec_bytes.get(cname, 0) + nb
        return out

    def _run_single(self, key: str, fut: Future) -> None:
        if not fut.set_running_or_notify_cancel():
            return
        try:
            fut.set_result(self._read_retrying(key, wait_for_probe=False))
        except BaseException as e:        # surfaced at the consuming fetch
            fut.set_exception(e)

    def _run_batch(self, keys: List[str], futs: Dict[str, Future]) -> None:
        live = [k for k in keys if futs[k].set_running_or_notify_cancel()]
        try:
            res = self._read_results_many(live)
        except BaseException as e:          # defensive: bookkeeping bug
            res = {k: e for k in live}
        for k in live:
            r = res[k]
            if isinstance(r, BaseException) \
                    and self.retry_policy.retries_enabled and is_transient(r):
                # the coalesced first attempt missed this key; spend the
                # rest of the policy's budget on per-key reads (retries
                # don't coalesce — the fault may be range-local)
                try:
                    r = self._read_retrying(k, wait_for_probe=False)
                    with self._lock:
                        self.stats.faults_absorbed += 1   # the batched miss
                except BaseException as e2:
                    r = e2
            if isinstance(r, BaseException):
                futs[k].set_exception(r)
            else:
                futs[k].set_result(r)

    # -- index maintenance (live archives: journal replay) -------------------

    def add_segments(self, entries: Dict[str, SegmentEntry]) -> None:
        """Register newly-journaled segments.  Existing keys must not be
        redefined — the journal is append-only, and silently remapping a key
        a reader already consumed would break byte accounting."""
        with self._lock:
            dup = [k for k in entries if k in self.index]
            if dup:
                raise ValueError(f"journal redefines existing segment "
                                 f"key(s) {sorted(dup)}")
            self.index.update(entries)

    def remove_segments(self, keys: Iterable[str]) -> None:
        """Drop retention-expired segments from the index.  In-flight or
        already-delivered bytes are unaffected; later fetches of a dropped
        key raise KeyError like any unknown key."""
        with self._lock:
            for k in keys:
                self.index.pop(k, None)
                self._inflight.pop(k, None)

    # -- public API ----------------------------------------------------------

    def fetch(self, key: str) -> bytes:
        """Blocking, verified read of one segment (prefetch-aware)."""
        with self._lock:
            entry = self._inflight.pop(key, None)
        t0 = time.perf_counter()
        if entry is not None:
            fut, from_hint, _ = entry
            try:
                buf = fut.result()   # raises ChecksumError from the worker
            except BlobQuarantinedError:
                # the worker fast-failed without spending a retry budget on
                # this key; a demand read gets its own deadline (and the
                # half-open probe, if the cooldown has lapsed by now)
                buf = self._read_retrying(key)
            with self._lock:
                if from_hint:
                    self.stats.prefetch_hits += 1
                else:
                    self.stats.pipelined_hits += 1
        else:
            buf = self._read_retrying(key)
            with self._lock:
                self.stats.demand_fetches += 1
        with self._lock:
            self.stats.demand_wait_s += time.perf_counter() - t0
        return buf

    def fetch_many(self, keys: Iterable[str]) -> List[bytes]:
        """Fetch a known list of segments.  With a worker pool the tail keys
        are submitted up front, so per-request latency pipelines instead of
        accumulating serially — these are demanded (not speculative) keys,
        so nothing extra ever moves."""
        keys = list(keys)
        if self._pool is not None and len(keys) > 1:
            self._submit(keys, from_hint=False, evictable=False)
        return [self.fetch(k) for k in keys]

    def fetch_prefix(self, keys: Iterable[str]
                     ) -> Tuple[List[bytes], Optional[BaseException]]:
        """Fetch an ordered list of segments, stopping at the first one that
        cannot be delivered: returns ``(buffers, error)`` where ``buffers``
        is the longest deliverable prefix and ``error`` is ``None`` only
        when every key arrived.  This is degraded mode's workhorse — a
        bitplane prefix is useful exactly as far as it is contiguous, so a
        miss at plane k makes planes >k moot for this session."""
        keys = list(keys)
        if self._pool is not None and len(keys) > 1:
            self._submit(keys, from_hint=False, evictable=False)
        bufs: List[bytes] = []
        for i, k in enumerate(keys):
            try:
                bufs.append(self.fetch(k))
            except Exception as e:
                # the tail is moot: forget its in-flight entries so futures
                # nobody will consume don't pin payloads until close()
                with self._lock:
                    for tail in keys[i + 1:]:
                        self._inflight.pop(tail, None)
                return bufs, e
        return bufs, None

    def prefetch(self, keys: Iterable[str], certain: bool = True) -> None:
        """Start background fetches for hinted keys; no-op without a worker
        pool.  Keys already in flight (or unknown) are skipped.
        ``certain=False`` marks predictions the caller may abandon — those
        entries are eviction-eligible once completed."""
        self._submit(keys, from_hint=True, evictable=not certain)

    def _submit(self, keys: Iterable[str], from_hint: bool,
                evictable: bool) -> None:
        if self._pool is None:
            return
        with self._lock:
            keys = list(keys)
            if not evictable:
                # a certain hint supersedes an earlier speculative one for
                # the same key: the segment WILL be consumed now, so it must
                # no longer be eviction-eligible
                for k in keys:
                    entry = self._inflight.get(k)
                    if entry is not None and entry[2]:
                        self._inflight[k] = (entry[0], entry[1], False)
            fresh = [k for k in keys
                     if k in self.index and k not in self._inflight]
            if from_hint and self.quarantine is not None:
                # speculative reads on a quarantined blob would fill the
                # pool with cooldown sleeps; let demand fetches (which own
                # a deadline) decide whether to wait for the probe
                fresh = [k for k in fresh if not self.quarantine
                         .is_quarantined(self.index[k].blob)]
            # evict oldest completed *evictable* entries (abandoned
            # predictions) so unconsumed speculation cannot pin the archive;
            # certain entries are always consumed by their caller, and
            # evicting one would force a duplicate transfer
            over = len(self._inflight) + len(fresh) - self.max_inflight
            if over > 0:
                for k in [k for k, (f, _, ev) in self._inflight.items()
                          if ev and f.done()][:over]:
                    del self._inflight[k]
            # register manually-fulfilled futures under the lock (so a
            # concurrent _submit cannot double-read a key), then hand the
            # reads to the pool outside it — store resolution may be slow
            futs: Dict[str, Future] = {}
            for k in fresh:
                f: Future = Future()
                self._inflight[k] = (f, from_hint, evictable)
                self.stats.prefetch_issued += from_hint
                futs[k] = f
        if not futs:
            return
        by_blob: Dict[str, List[str]] = {}
        for k in futs:
            by_blob.setdefault(self.index[k].blob, []).append(k)
        submitted = set()
        pool = self._pool
        try:
            if pool is None:
                raise RuntimeError("fetcher closed during submission")
            for blob, ks in by_blob.items():
                if len(ks) > 1 and self._peek_prefers_batch(blob):
                    ks.sort(key=lambda k: self.index[k].offset)
                    pool.submit(self._run_batch, ks, futs)
                    submitted.update(ks)
                else:
                    for k in ks:
                        pool.submit(self._run_single, k, futs[k])
                        submitted.add(k)
        except RuntimeError as e:
            # pool shut down while we were submitting (close() raced a
            # prefetch): fail the unsubmitted futures instead of leaving
            # them pending forever — a later fetch() must not hang
            for k, f in futs.items():
                if k not in submitted and f.set_running_or_notify_cancel():
                    f.set_exception(e)

    def drain(self) -> None:
        """Wait for all in-flight prefetches (tests/benchmarks)."""
        with self._lock:
            futs = [f for f, _, _ in self._inflight.values()]
        for f in futs:
            try:
                f.result()
            except Exception:       # surfaced on the consuming fetch instead
                pass

    @property
    def outstanding(self) -> int:
        with self._lock:
            return len(self._inflight)

    def close(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None

    def close_stores(self) -> None:
        """Close every ByteStore this fetcher resolved or was handed."""
        with self._stores_lock:
            stores, self._stores = dict(self._stores), {}
        for s in stores.values():
            s.close()

    def __enter__(self) -> "SegmentFetcher":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
