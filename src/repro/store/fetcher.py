"""SegmentFetcher: checksum-verified segment delivery with async prefetch.

The fetcher sits between progressive readers and a ByteStore.  Demand
``fetch(key)`` blocks; ``prefetch(keys)`` submits background reads to a small
thread pool so transport overlaps compute (the QoI estimator round of
Algorithm 2 — see core/retrieval.py, which hands ``reassign_eb``'s predicted
next-eps down here via the readers' prefetch hints).

Every delivered segment is re-hashed (crc32c) against the manifest before the
decoder sees it; a mismatch raises ChecksumError — a "guaranteed error bound"
computed from silently corrupted planes would be worthless.

Cache discipline: segments are consumed at most once per session (plane
fetches are a monotone prefix per group), so a completed future is *popped*
on fetch — the cache holds only in-flight or not-yet-consumed prefetches.
Speculative hints the caller never follows up on would otherwise pin their
payloads until close, so ``prefetch`` evicts the oldest completed
*speculative* entries beyond ``max_inflight``.  Non-speculative entries
(exact predictions and fetch_many pipelining) are never evicted — every
internal caller consumes them within a round, and evicting one would force
a duplicate transfer, breaking the equal-bytes-moved property the transfer
benches assert.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple

from repro.store.bytestore import ByteStore
from repro.store.crc import crc32c


class ChecksumError(IOError):
    """A fetched segment failed crc32c verification."""


@dataclass(frozen=True)
class SegmentEntry:
    """Manifest index entry: where a segment lives and what it must hash to."""
    offset: int
    size: int
    crc: int


@dataclass
class FetchStats:
    demand_fetches: int = 0        # blocking reads served straight from store
    pipelined_hits: int = 0        # served by fetch_many's own pipelining
    prefetch_issued: int = 0       # *speculative* background reads submitted
    prefetch_hits: int = 0         # demand fetches answered by a prediction
    bytes_fetched: int = 0         # all segment bytes pulled from the store
    demand_wait_s: float = 0.0     # time the caller spent blocked on reads

    @property
    def hit_rate(self) -> float:
        """Fraction of consumed segments that a *predictive* prefetch had
        already started (fetch_many's pipelining of demanded keys does not
        count — that is latency hiding, not prediction)."""
        served = self.demand_fetches + self.pipelined_hits + self.prefetch_hits
        return self.prefetch_hits / served if served else 0.0


class SegmentFetcher:
    """Keyed, verified access to one archive's segments."""

    def __init__(self, index: Dict[str, SegmentEntry], store: ByteStore,
                 prefetch_workers: int = 2, verify: bool = True,
                 max_inflight: int = 512):
        self.index = index
        self.store = store
        self.verify = verify
        self.max_inflight = max_inflight
        self.stats = FetchStats()
        self._lock = threading.Lock()
        # key -> (future, from_hint, evictable): from_hint buckets the stats
        # (prediction vs fetch_many pipelining); evictable marks entries a
        # caller may never consume (speculative predictions)
        self._inflight: Dict[str, Tuple[Future, bool, bool]] = {}
        self._pool: Optional[ThreadPoolExecutor] = (
            ThreadPoolExecutor(max_workers=prefetch_workers,
                               thread_name_prefix="seg-prefetch")
            if prefetch_workers > 0 else None)

    # -- transport -----------------------------------------------------------

    def _read_verified(self, key: str) -> bytes:
        entry = self.index[key]
        buf = self.store.read(entry.offset, entry.size)
        if self.verify and crc32c(buf) != entry.crc:
            raise ChecksumError(
                f"segment {key!r}: crc32c mismatch "
                f"(got {crc32c(buf):#010x}, manifest {entry.crc:#010x})")
        with self._lock:
            self.stats.bytes_fetched += entry.size
        return buf

    # -- public API ----------------------------------------------------------

    def fetch(self, key: str) -> bytes:
        """Blocking, verified read of one segment (prefetch-aware)."""
        with self._lock:
            entry = self._inflight.pop(key, None)
        t0 = time.perf_counter()
        if entry is not None:
            fut, from_hint, _ = entry
            buf = fut.result()       # raises ChecksumError from the worker
            with self._lock:
                if from_hint:
                    self.stats.prefetch_hits += 1
                else:
                    self.stats.pipelined_hits += 1
        else:
            buf = self._read_verified(key)
            with self._lock:
                self.stats.demand_fetches += 1
        with self._lock:
            self.stats.demand_wait_s += time.perf_counter() - t0
        return buf

    def fetch_many(self, keys: Iterable[str]) -> List[bytes]:
        """Fetch a known list of segments.  With a worker pool the tail keys
        are submitted up front, so per-request latency pipelines instead of
        accumulating serially — these are demanded (not speculative) keys,
        so nothing extra ever moves."""
        keys = list(keys)
        if self._pool is not None and len(keys) > 1:
            self._submit(keys, from_hint=False, evictable=False)
        return [self.fetch(k) for k in keys]

    def prefetch(self, keys: Iterable[str], certain: bool = True) -> None:
        """Start background fetches for hinted keys; no-op without a worker
        pool.  Keys already in flight (or unknown) are skipped.
        ``certain=False`` marks predictions the caller may abandon — those
        entries are eviction-eligible once completed."""
        self._submit(keys, from_hint=True, evictable=not certain)

    def _submit(self, keys: Iterable[str], from_hint: bool,
                evictable: bool) -> None:
        if self._pool is None:
            return
        with self._lock:
            keys = list(keys)
            if not evictable:
                # a certain hint supersedes an earlier speculative one for
                # the same key: the segment WILL be consumed now, so it must
                # no longer be eviction-eligible
                for k in keys:
                    entry = self._inflight.get(k)
                    if entry is not None and entry[2]:
                        self._inflight[k] = (entry[0], entry[1], False)
            fresh = [k for k in keys
                     if k in self.index and k not in self._inflight]
            # evict oldest completed *evictable* entries (abandoned
            # predictions) so unconsumed speculation cannot pin the archive;
            # certain entries are always consumed by their caller, and
            # evicting one would force a duplicate transfer
            over = len(self._inflight) + len(fresh) - self.max_inflight
            if over > 0:
                for k in [k for k, (f, _, ev) in self._inflight.items()
                          if ev and f.done()][:over]:
                    del self._inflight[k]
            for k in fresh:
                self._inflight[k] = (self._pool.submit(self._read_verified, k),
                                     from_hint, evictable)
                self.stats.prefetch_issued += from_hint

    def drain(self) -> None:
        """Wait for all in-flight prefetches (tests/benchmarks)."""
        with self._lock:
            futs = [f for f, _, _ in self._inflight.values()]
        for f in futs:
            try:
                f.result()
            except Exception:       # surfaced on the consuming fetch instead
                pass

    @property
    def outstanding(self) -> int:
        with self._lock:
            return len(self._inflight)

    def close(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None

    def __enter__(self) -> "SegmentFetcher":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
