"""crc32c (Castagnoli) — per-segment transport integrity checksums.

The container stores one crc32c per segment (bitplane, sign plane, mask
bitmap, snapshot blob); every fetch re-hashes the received bytes before they
reach the decoder, so a flipped bit anywhere between `save_archive` and the
reconstruction raises instead of silently corrupting a "guaranteed-error"
answer.  crc32c is the iSCSI/object-store polynomial (reflected 0x82F63B78),
chosen over zlib's crc32 for parity with real storage services.

No compiled crc32c is available in the container, so two paths:

  * scalar slicing-by-8 (8 table lookups per 8 input bytes) for short
    segments and tails;
  * a vectorized tree reduction for buffers >= 1 KiB.  CRC tables are
    GF(2)-linear (``T[a ^ b] == T[a] ^ T[b]``), so one 8-byte step is
    ``crc' = F(crc) ^ G(block)`` with *linear* F.  Per-block G values are
    pure numpy gathers, and the chained prefix ``XOR_i F^(N-1-i)(G_i)``
    folds pairwise with operator doubling (``F^(2^l)`` kept as four
    256-entry lookup tables, squared per level) — log2(N) vectorized
    levels, ~2 orders of magnitude over the scalar loop.
"""
from __future__ import annotations

from typing import List

import numpy as np

_POLY = np.uint32(0x82F63B78)  # reflected CRC-32C polynomial


def _build_tables(n: int = 8) -> List[List[int]]:
    table = np.zeros((n, 256), dtype=np.uint32)
    crc = np.arange(256, dtype=np.uint32)
    for _ in range(8):
        crc = np.where(crc & 1, (crc >> np.uint32(1)) ^ _POLY,
                       crc >> np.uint32(1)).astype(np.uint32)
    table[0] = crc
    for i in range(1, n):
        table[i] = table[0][table[i - 1] & 0xFF] ^ (table[i - 1] >> np.uint32(8))
    return [t.tolist() for t in table]  # python ints: no uint32 boxing in the loop


_T = _build_tables()
_TN = np.asarray(_build_tables(), dtype=np.uint32)     # (8, 256) for gathers
_FAST_THRESHOLD = 1024


def _apply_op(op: np.ndarray, v: np.ndarray) -> np.ndarray:
    """Apply a 32-bit GF(2)-linear operator (four 256-entry uint32 tables,
    one per input byte, low byte first) to an array of uint32."""
    return (op[0][v & 0xFF] ^ op[1][(v >> np.uint32(8)) & 0xFF]
            ^ op[2][(v >> np.uint32(16)) & 0xFF] ^ op[3][v >> np.uint32(24)])


def _build_ops(n_levels: int) -> List[np.ndarray]:
    """Operator ladder for the tree reduction: ops[l] applies F^(2^l), where
    F is the shift-by-one-8-byte-block operator.  Input-independent, built
    once at import by repeated squaring (fully, not on demand — crc32c runs
    concurrently on the SegmentFetcher's prefetch workers, and a lazily
    grown shared ladder would race).  33 levels cover 2^33 blocks = 64 GiB
    buffers, far past anything this code hashes."""
    ops = [np.stack([_TN[7], _TN[6], _TN[5], _TN[4]])]
    for _ in range(n_levels - 1):
        prev = ops[-1]
        ops.append(np.stack([_apply_op(prev, prev[i]) for i in range(4)]))
    return ops


_OPS = _build_ops(33)


def _crc32c_blocks(blocks: np.ndarray, crc: int) -> int:
    """Fold (N, 8) uint8 blocks into ``crc`` (raw register, pre-final-xor)."""
    b = blocks.astype(np.intp)
    # G(block): data-byte contributions of one slicing-by-8 step
    g = (_TN[7][b[:, 0]] ^ _TN[6][b[:, 1]] ^ _TN[5][b[:, 2]]
         ^ _TN[4][b[:, 3]] ^ _TN[3][b[:, 4]] ^ _TN[2][b[:, 5]]
         ^ _TN[1][b[:, 6]] ^ _TN[0][b[:, 7]])
    # fold the incoming register into the first block so the reduction is a
    # pure XOR_i F^(N-1-i)(g_i)
    g[0] ^= _apply_op(_OPS[0], np.asarray([crc], dtype=np.uint32))[0]
    n = 1 << int(np.ceil(np.log2(len(g))))  # leading zero-pad: F(0)=0, G(0)=0
    if n != len(g):
        g = np.concatenate([np.zeros(n - len(g), dtype=np.uint32), g])
    level = 0
    while len(g) > 1:
        g = _apply_op(_OPS[level], g[0::2]) ^ g[1::2]
        level += 1
    return int(g[0])


def crc32c(data: bytes, value: int = 0) -> int:
    """CRC-32C of ``data``; ``value`` chains a previous result."""
    crc = (value ^ 0xFFFFFFFF) & 0xFFFFFFFF
    mv = memoryview(data)
    t0, t1, t2, t3, t4, t5, t6, t7 = _T
    n8 = len(mv) - (len(mv) % 8)
    if n8 >= _FAST_THRESHOLD:
        arr = np.frombuffer(mv[:n8], dtype=np.uint8).reshape(-1, 8)
        crc = _crc32c_blocks(arr, crc)
        n8_start = n8
    else:
        n8_start = 0
    for i in range(n8_start, n8, 8):
        lo = crc ^ int.from_bytes(mv[i:i + 4], "little")
        hi = int.from_bytes(mv[i + 4:i + 8], "little")
        crc = (t7[lo & 0xFF] ^ t6[(lo >> 8) & 0xFF]
               ^ t5[(lo >> 16) & 0xFF] ^ t4[lo >> 24]
               ^ t3[hi & 0xFF] ^ t2[(hi >> 8) & 0xFF]
               ^ t1[(hi >> 16) & 0xFF] ^ t0[hi >> 24])
    for b in mv[n8:]:
        crc = t0[(crc ^ b) & 0xFF] ^ (crc >> 8)
    return crc ^ 0xFFFFFFFF
