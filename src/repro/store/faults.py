"""Deterministic chaos for ByteStores: seeded fault injection on any backend.

``httpd.transient_faults`` can only chaos-test the HTTP path; this wrapper
makes *every* backend chaos-testable by sitting between the fetcher and any
inner ByteStore and injecting faults on a deterministic, seeded schedule:

  * transient errors     IOError raised, later attempts succeed
  * timeouts             socket.timeout (what a stalled link raises)
  * truncated reads      short payloads (fails the fetcher's length check)
  * bit flips            one flipped bit (fails crc32c verification)
  * slow reads           an extra ``slow_s`` sleep, payload intact
  * persistent loss      ranges/blobs that NEVER deliver

Determinism is the point: every decision is a pure hash of ``(seed, offset,
length, k)`` where ``k`` counts the calls made for that exact range, so a
schedule replays identically regardless of thread interleaving across
ranges — a failing chaos test reproduces from its printed seed alone.

"Eventually heals" is a *guarantee*, not a probability: a range injects at
most ``max_faults_per_range`` faults (default 2), so any retry policy with
more attempts than that always converges — the contract the chaos suite's
bit-identical-after-healing assertions lean on.  Set it to ``None`` for
rate-only injection (faults forever, at ``rate``).
"""
from __future__ import annotations

import hashlib
import socket
import struct
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

from repro.store.bytestore import ByteStore


@dataclass(frozen=True)
class FaultPlan:
    """What to inject and how often.  ``rate`` is the per-call probability
    that *some* fault fires; ``weights`` splits it across kinds."""
    rate: float = 0.25
    error_weight: float = 1.0      # plain transient IOError
    timeout_weight: float = 0.0    # socket.timeout
    truncate_weight: float = 0.0   # short read (length check trips)
    flip_weight: float = 0.0      # one bit flipped (crc check trips)
    slow_weight: float = 0.0      # delivered intact, after slow_s
    slow_s: float = 0.01
    # hard healing bound: at most this many faults per distinct range
    # (None = faults keep firing at ``rate`` forever)
    max_faults_per_range: Optional[int] = 2
    # (offset, length-span) windows that NEVER deliver — permanent loss
    dead_ranges: Tuple[Tuple[int, int], ...] = ()

    def _weights(self):
        kinds = (("error", self.error_weight),
                 ("timeout", self.timeout_weight),
                 ("truncate", self.truncate_weight),
                 ("flip", self.flip_weight),
                 ("slow", self.slow_weight))
        total = sum(w for _, w in kinds)
        if total <= 0:
            raise ValueError("FaultPlan needs at least one positive weight")
        return [(k, w / total) for k, w in kinds if w > 0]


@dataclass
class FaultStats:
    injected: Dict[str, int] = field(default_factory=dict)
    reads: int = 0

    @property
    def total(self) -> int:
        return sum(self.injected.values())


class FaultInjectingByteStore(ByteStore):
    """Wrap any ByteStore with a seeded fault schedule (thread-safe).

    Decisions are keyed on ``(seed, offset, length, k)`` — ``k`` is the
    per-range call counter — so schedules are deterministic under any
    thread interleaving.  ``read_batch`` deliberately degrades to per-range
    ``read`` calls: every range gets its own independent fault decision,
    and a batched caller cannot smuggle ranges past the schedule."""

    def __init__(self, inner: ByteStore, plan: FaultPlan = FaultPlan(),
                 seed: int = 0):
        self.inner = inner
        self.plan = plan
        self.seed = int(seed)
        self.stats = FaultStats()
        self._weights = plan._weights() if plan.rate > 0 else []
        self._lock = threading.Lock()
        self._calls: Dict[Tuple[int, int], int] = {}

    # -- deterministic draws -------------------------------------------------

    def _draw(self, offset: int, length: int, k: int, salt: int) -> float:
        h = hashlib.blake2b(
            struct.pack("<qqqqq", self.seed, offset, length, k, salt),
            digest_size=8).digest()
        return struct.unpack("<Q", h)[0] / 2.0 ** 64

    def _decide(self, offset: int, length: int) -> Optional[str]:
        with self._lock:
            k = self._calls.get((offset, length), 0)
            self._calls[(offset, length)] = k + 1
            self.stats.reads += 1
        for start, span in self.plan.dead_ranges:
            if offset < start + span and start < offset + length:
                return "dead"
        if not self._weights:
            return None
        if self.plan.max_faults_per_range is not None \
                and k >= self.plan.max_faults_per_range:
            return None                      # healed: hard per-range cap
        if self._draw(offset, length, k, 0) >= self.plan.rate:
            return None
        u = self._draw(offset, length, k, 1)
        acc = 0.0
        for kind, w in self._weights:
            acc += w
            if u < acc:
                return kind
        return self._weights[-1][0]

    def _note(self, kind: str) -> None:
        with self._lock:
            self.stats.injected[kind] = self.stats.injected.get(kind, 0) + 1

    # -- ByteStore surface ---------------------------------------------------

    def read(self, offset: int, length: int) -> bytes:
        kind = self._decide(offset, length)
        if kind == "dead":
            self._note(kind)
            raise IOError(f"injected permanent loss at "
                          f"[{offset}:+{length}] (seed {self.seed})")
        if kind == "error":
            self._note(kind)
            raise IOError(f"injected transient fault at "
                          f"[{offset}:+{length}] (seed {self.seed})")
        if kind == "timeout":
            self._note(kind)
            raise socket.timeout(f"injected timeout at [{offset}:+{length}] "
                                 f"(seed {self.seed})")
        data = self.inner.read(offset, length)
        if kind == "truncate" and length > 0:
            self._note(kind)
            return data[:max(0, length - 1 - int(
                self._draw(offset, length, 0, 2) * min(length, 16)))]
        if kind == "flip" and length > 0:
            self._note(kind)
            i = int(self._draw(offset, length, 0, 3) * length) % length
            buf = bytearray(data)
            buf[i] ^= 1 << (int(self._draw(offset, length, 0, 4) * 8) % 8)
            return bytes(buf)
        if kind == "slow":
            self._note(kind)
            time.sleep(self.plan.slow_s)
        return data

    def read_batch(self, ranges: Sequence[Tuple[int, int]]):
        # per-range reads on purpose: each range must face the schedule
        return [self.read(off, ln) for off, ln in ranges]

    @property
    def size(self) -> int:
        return self.inner.size

    def close(self) -> None:
        self.inner.close()
