"""Segment store & transport: archive container, byte stores, prefetching.

The paper's headline is a data-*transfer* win; this package is the layer
that actually moves bytes.  ``save_archive`` serializes a refactored
`Archive` (any of the four methods) into a manifest + segment blob
container; ``open_archive`` serves it back through pluggable ByteStore
backends (RAM, mmap'd file, simulated WAN link) with per-segment crc32c
verification and a SegmentFetcher that prefetches predicted planes in the
background while the QoI estimator runs.
"""
from repro.store.bytestore import (
    ByteStore,
    FileByteStore,
    MemoryByteStore,
    RemoteByteStore,
)
from repro.store.container import (
    StoreArchive,
    StoreBitplaneVar,
    StoreSnapshotVar,
    build_container,
    memory_store_archive,
    open_archive,
    save_archive,
)
from repro.store.crc import crc32c
from repro.store.fetcher import (
    ChecksumError,
    FetchStats,
    SegmentEntry,
    SegmentFetcher,
)

__all__ = [
    "ByteStore", "MemoryByteStore", "FileByteStore", "RemoteByteStore",
    "StoreArchive", "StoreBitplaneVar", "StoreSnapshotVar",
    "build_container", "save_archive", "open_archive", "memory_store_archive",
    "crc32c", "SegmentFetcher", "SegmentEntry", "FetchStats", "ChecksumError",
]
