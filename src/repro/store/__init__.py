"""Segment store & transport: archive container, byte stores, prefetching.

The paper's headline is a data-*transfer* win; this package is the layer
that actually moves bytes.  ``save_archive`` / ``save_sharded_archive``
serialize a refactored `Archive` (any of the four methods) into a manifest
+ segment payload container — one blob, or one blob per variable / level
group; ``open_archive`` serves it back through pluggable ByteStore backends
(RAM, mmap'd file, real HTTP ranged GETs, simulated WAN link) with
per-segment crc32c verification, a SegmentFetcher that prefetches predicted
planes in the background while the QoI estimator runs, and an optional
cross-session SegmentCache so concurrent clients don't re-fetch shared
planes.  ``repro.store.httpd`` is the matching stdlib ranged-GET endpoint.
"""
from repro.store.bytestore import (
    ByteStore,
    FileByteStore,
    HTTPByteStore,
    HTTPStats,
    MemoryByteStore,
    RemoteByteStore,
)
from repro.store.cache import CacheStats, SegmentCache
from repro.options import OpenOptions, ReproDeprecationWarning, SessionOptions
from repro.store.container import (
    JOURNAL_NAME,
    StoreArchive,
    StoreBitplaneVar,
    StoreSnapshotVar,
    StoreTimeseriesVar,
    build_container,
    build_sharded_container,
    manifest_archive_id,
    memory_store_archive,
    open_archive,
    save_archive,
    save_sharded_archive,
    segment_depth,
)
from repro.store.crc import crc32c
from repro.store.faults import FaultInjectingByteStore, FaultPlan, FaultStats
from repro.store.fetcher import (
    ChecksumError,
    FetchStats,
    SegmentEntry,
    SegmentFetcher,
)
from repro.store.retry import (
    BlobQuarantine,
    BlobQuarantinedError,
    RetryPolicy,
    SegmentUnavailableError,
    is_transient,
)
from repro.store.writer import ArchiveWriter, ensure_archive

__all__ = [
    "ByteStore", "MemoryByteStore", "FileByteStore", "HTTPByteStore",
    "HTTPStats", "RemoteByteStore",
    "SegmentCache", "CacheStats",
    "StoreArchive", "StoreBitplaneVar", "StoreSnapshotVar",
    "StoreTimeseriesVar",
    "build_container", "build_sharded_container",
    "save_archive", "save_sharded_archive",
    "open_archive", "memory_store_archive",
    "ArchiveWriter", "ensure_archive", "JOURNAL_NAME",
    "OpenOptions", "SessionOptions", "ReproDeprecationWarning",
    "segment_depth", "manifest_archive_id",
    "crc32c", "SegmentFetcher", "SegmentEntry", "FetchStats", "ChecksumError",
    "RetryPolicy", "BlobQuarantine", "BlobQuarantinedError",
    "SegmentUnavailableError", "is_transient",
    "FaultPlan", "FaultInjectingByteStore", "FaultStats",
]
