"""Unified fault-tolerance policy for the retrieval plane.

Two small, reusable pieces shared by every ByteStore backend and by the
SegmentFetcher (which wraps *all* backends, so even stores with no internal
retry — memory, mmap, the WAN model — get one consistent policy):

  * ``RetryPolicy`` — max attempts, exponential backoff with FULL jitter
    (sleep = uniform(0, min(cap, base·2^(attempt-1))); unjittered backoff
    synchronizes clients into retry storms against a shared store), a
    backoff cap, and a per-fetch wall-clock deadline.  The deadline is the
    arbiter of "transient vs permanent": a fault schedule that heals inside
    the deadline is absorbed invisibly; one that does not becomes a
    certified *degraded-mode* result upstream (see core/refactor.py).

  * ``BlobQuarantine`` — a per-blob circuit breaker.  K *consecutive*
    failures open the circuit for that blob: further reads fast-fail with
    ``BlobQuarantinedError`` instead of burning a full retry budget per
    segment against a store that is known-dead.  After a cooldown the
    circuit goes half-open: exactly one probe read is let through (other
    readers keep fast-failing); success closes the circuit, failure
    re-opens it with a doubled (capped) cooldown.

``is_transient`` is the shared error classifier: transport-shaped failures
(timeouts, resets, 5xx-wrapping IOErrors, checksum mismatches — a bit flip
in transit heals on re-read) retry; caller bugs (negative lengths, reads
past EOF) and definitively-missing resources (``FileNotFoundError``) fail
immediately — retrying a file that does not exist only delays the
quarantine that protects the rest of the session.
"""
from __future__ import annotations

import http.client
import random
import socket
import threading
import time
from dataclasses import dataclass
from typing import Dict, Optional, Tuple


class SegmentUnavailableError(IOError):
    """A segment could not be delivered within the retry policy's budget."""


class BlobQuarantinedError(SegmentUnavailableError):
    """Fast-fail: the segment's blob is quarantined (circuit open) and the
    caller's budget cannot cover waiting for the next half-open probe."""


_PERMANENT = (FileNotFoundError, IsADirectoryError, NotADirectoryError,
              PermissionError)


def is_transient(exc: BaseException) -> bool:
    """True when retrying the operation could plausibly succeed."""
    if isinstance(exc, _PERMANENT):
        return False
    if isinstance(exc, (EOFError, ValueError, KeyError, TypeError)):
        return False                       # caller bugs, not store weather
    return isinstance(exc, (OSError, socket.timeout, TimeoutError,
                            ConnectionError, http.client.HTTPException))


@dataclass(frozen=True)
class RetryPolicy:
    """Shared retry/backoff/deadline policy for segment transport.

    ``max_attempts`` counts the first try (``max_attempts=1`` == never
    retry).  ``backoff_s`` is the base of the exponential schedule;
    ``backoff_cap_s`` caps any single sleep; ``jitter`` draws the actual
    sleep uniformly from [0, capped backoff] (AWS "full jitter").
    ``deadline_s`` bounds one *fetch* (all attempts + sleeps) in wall-clock
    seconds; ``None`` leaves only the attempt count as the limit."""
    max_attempts: int = 4
    backoff_s: float = 0.05
    backoff_cap_s: float = 1.0
    deadline_s: Optional[float] = 30.0
    jitter: bool = True

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, "
                             f"got {self.max_attempts}")
        if self.backoff_s < 0 or self.backoff_cap_s < 0:
            raise ValueError("backoff must be non-negative")

    @classmethod
    def none(cls) -> "RetryPolicy":
        """No retries: one attempt, no deadline — the legacy behaviour of
        every non-HTTP backend."""
        return cls(max_attempts=1, backoff_s=0.0, deadline_s=None)

    @property
    def retries_enabled(self) -> bool:
        return self.max_attempts > 1

    def backoff(self, attempt: int,
                rng: Optional[random.Random] = None) -> float:
        """Sleep before retry number ``attempt`` (1-based): capped
        exponential, fully jittered."""
        cap = min(self.backoff_cap_s,
                  self.backoff_s * (2.0 ** max(0, attempt - 1)))
        if not self.jitter:
            return cap
        return (rng.uniform if rng is not None else random.uniform)(0.0, cap)

    def deadline_from(self, t0: float) -> float:
        """Absolute monotonic deadline for a fetch that started at ``t0``."""
        return float("inf") if self.deadline_s is None \
            else t0 + self.deadline_s


# circuit states returned by BlobQuarantine.check()
CLOSED = "closed"      # healthy: read normally
OPEN = "open"          # quarantined: wait ``wait_s`` for the next probe slot
PROBE = "probe"        # half-open: caller holds the single probe token


class BlobQuarantine:
    """Per-blob circuit breaker (thread-safe).

    ``threshold`` consecutive failed read attempts on a blob open its
    circuit for ``cooldown_s``; each failed half-open probe doubles the
    cooldown up to ``cooldown_cap_s``.  Any successful read fully resets
    the blob's state.  ``events`` counts open transitions (exported as
    ``FetchStats.quarantined_blobs``)."""

    def __init__(self, threshold: int = 3, cooldown_s: float = 0.5,
                 cooldown_cap_s: float = 8.0):
        if threshold < 1:
            raise ValueError(f"threshold must be >= 1, got {threshold}")
        self.threshold = int(threshold)
        self.cooldown_s = float(cooldown_s)
        self.cooldown_cap_s = float(cooldown_cap_s)
        self.events = 0
        self._lock = threading.Lock()
        # blob -> [consecutive_failures, open_until (monotonic) | None,
        #          probing, current_cooldown]
        self._state: Dict[str, list] = {}

    def check(self, blob: str) -> Tuple[str, float]:
        """(state, wait_s): CLOSED -> read; PROBE -> read (this caller owns
        the one half-open probe and MUST report the outcome); OPEN -> the
        circuit stays closed to this caller for another ``wait_s``
        seconds."""
        now = time.monotonic()
        with self._lock:
            st = self._state.get(blob)
            if st is None or st[1] is None:
                return CLOSED, 0.0
            if st[2]:                        # someone else holds the probe
                return OPEN, st[3]
            if now >= st[1]:
                st[2] = True
                return PROBE, 0.0
            return OPEN, st[1] - now

    def record_failure(self, blob: str) -> bool:
        """Note one failed read attempt; returns True when this failure
        *opens* the circuit (a quarantine event)."""
        now = time.monotonic()
        with self._lock:
            st = self._state.setdefault(
                blob, [0, None, False, self.cooldown_s])
            st[0] += 1
            if st[1] is not None and st[2]:      # failed half-open probe
                st[2] = False
                st[3] = min(self.cooldown_cap_s, st[3] * 2.0)
                st[1] = now + st[3]
                return False
            if st[1] is None and st[0] >= self.threshold:
                st[1] = now + st[3]
                self.events += 1
                return True
            return False

    def record_success(self, blob: str) -> None:
        with self._lock:
            self._state.pop(blob, None)

    def quarantined(self) -> Tuple[str, ...]:
        """Blobs whose circuit is currently open (cooldown may have lapsed
        — they stay listed until a successful probe closes them)."""
        with self._lock:
            return tuple(sorted(b for b, st in self._state.items()
                                if st[1] is not None))

    def is_quarantined(self, blob: str) -> bool:
        with self._lock:
            st = self._state.get(blob)
            return st is not None and st[1] is not None
