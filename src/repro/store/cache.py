"""Cross-session segment cache.

Within one `RetrievalSession`, segments are consumed at most once (plane
fetches are a monotone prefix per group), so the SegmentFetcher *pops*
completed reads — correct for a single client, but a server running many
sessions over the same archive re-fetches identical planes for every
client.  `SegmentCache` sits under the fetcher: verified segment bytes are
inserted after their first store read and served to every later session
without touching the ByteStore (see ``FetchStats.store_reads`` vs
``cache_hits``).

Keys are ``(segment_key, crc32c)`` pairs: the crc disambiguates segments of
different archives sharing one cache, and means a hit never needs
re-verification — the bytes were hashed against the manifest when inserted.

Eviction is LRU by byte budget.  A progressive workload is front-loaded
(every client wants the MSB planes; only tight-tolerance clients descend),
so LRU keeps exactly the shared prefix hot.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass
from typing import Hashable, Optional


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    insertions: int = 0
    evictions: int = 0


class SegmentCache:
    """Thread-safe LRU byte cache, bounded by total cached bytes."""

    def __init__(self, max_bytes: int = 256 << 20):
        if max_bytes <= 0:
            raise ValueError(f"max_bytes must be positive, got {max_bytes}")
        self.max_bytes = int(max_bytes)
        self.stats = CacheStats()
        self._lock = threading.Lock()
        self._entries: "OrderedDict[Hashable, bytes]" = OrderedDict()
        self._nbytes = 0

    def get(self, key: Hashable) -> Optional[bytes]:
        with self._lock:
            data = self._entries.get(key)
            if data is None:
                self.stats.misses += 1
                return None
            self._entries.move_to_end(key)
            self.stats.hits += 1
            return data

    def put(self, key: Hashable, data: bytes) -> None:
        if len(data) > self.max_bytes:
            return                      # would evict everything for one entry
        with self._lock:
            old = self._entries.pop(key, None)
            if old is not None:
                self._nbytes -= len(old)
            self._entries[key] = data
            self._nbytes += len(data)
            self.stats.insertions += 1
            while self._nbytes > self.max_bytes:
                _, victim = self._entries.popitem(last=False)
                self._nbytes -= len(victim)
                self.stats.evictions += 1

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._nbytes = 0

    @property
    def nbytes(self) -> int:
        with self._lock:
            return self._nbytes

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __contains__(self, key: Hashable) -> bool:
        with self._lock:
            return key in self._entries
