"""Cross-session segment cache with depth-weighted, archive-aware eviction.

Within one `RetrievalSession`, segments are consumed at most once (plane
fetches are a monotone prefix per group), so the SegmentFetcher *pops*
completed reads — correct for a single client, but a server running many
sessions over the same archive re-fetches identical planes for every
client.  `SegmentCache` sits under the fetcher: verified segment bytes are
inserted after their first store read and served to every later session
without touching the ByteStore (see ``FetchStats.store_reads`` vs
``cache_hits``).

Keys are ``(segment_key, crc32c)`` pairs: the crc disambiguates segments of
different archives sharing one cache, and means a hit never needs
re-verification — the bytes were hashed against the manifest when inserted.

Eviction policy
---------------
Progressive workloads are *prefix-heavy*: every client consumes the MSB
planes of the variables it touches, while deep LSB planes serve only the
tightest-tolerance clients.  Pure byte-LRU treats both the same, so one
deep-descending client can flush the shared prefix that every other client
re-reads.  Eviction is therefore **depth-weighted LRU**: each entry carries
a ``depth`` (its bitplane index for plane segments, snapshot index for
snapshot blobs, 0 for signs/masks — see ``repro.store.container
.segment_depth``) and the victim is the entry minimising

    score = last_use_tick − depth_weight · min(depth, _MAX_BAND)

where ``tick`` is a global access counter.  At equal recency a deeper
(LSB) segment always goes first; an MSB segment must be ``depth_weight``
ticks *staler* per plane of depth before it loses to an LSB one.
``depth_weight=0`` recovers plain byte-LRU.

Archive isolation
-----------------
Entries are also tagged with an ``archive`` id (the fetcher passes a hash
of its manifest).  Two knobs keep one hot archive from flushing another's
working set:

  * ``archive_floor_bytes`` — eviction for *global* pressure never takes an
    archive below this many resident bytes unless the pressure comes from
    that archive's own insertions (self-pressure may always self-evict).
  * ``archive_max_bytes`` — optional hard per-archive cap; inserting beyond
    it evicts only within the inserting archive.

Floors are a protection, not a reservation: if every other archive is at
its floor the inserting archive evicts itself, and the global
``max_bytes`` bound always holds.

Depth and archive default to ``0`` / ``""`` on ``put``, so callers that
never learned the new metadata keep plain-LRU semantics unchanged.

Admission control (serve plane)
-------------------------------
With ``admission_control=True`` a ``put`` that would overflow the cache
first compares the incoming entry's score against the stalest resident
entry: when the newcomer scores LOWER (a deep-LSB segment from one
tight-tolerance client, up against a shared MSB prefix), inserting it
would evict hotter bytes only to be evicted moments later itself — so the
insert is *skipped* (``stats.admission_skips``) and the resident set is
left alone.  Correctness is unaffected (the fetcher falls through to the
ByteStore); this is purely churn avoidance under multi-tenant pressure.
Default off: single-session workloads want every verified byte cached.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, Hashable, List, Optional, Tuple

# Depth bands beyond this saturate: a plane 40 deep and one 60 deep are
# both "cold tail" — capping keeps the head-scan per eviction tiny.
_MAX_BAND = 48


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    insertions: int = 0
    evictions: int = 0
    floor_protected: int = 0   # evictions redirected off an at-floor archive
    admission_skips: int = 0   # inserts refused under pressure (colder than
    #                            every resident entry; admission_control only)


@dataclass(slots=True)
class _Entry:
    data: bytes
    depth: int
    band: int
    archive: str
    tick: int


@dataclass(slots=True)
class _ArchiveState:
    """Per-archive residency: byte count + one LRU queue per depth band.

    Within a band, queue order is insertion/touch order, so the queue head
    is the band's minimum-tick (stalest) entry — scanning only the heads of
    every (archive, band) queue finds the global minimum score."""
    nbytes: int = 0
    bands: Dict[int, "OrderedDict[Hashable, _Entry]"] = field(
        default_factory=dict)


class SegmentCache:
    """Thread-safe byte-bounded cache, depth-weighted LRU within and across
    per-archive budgets (see module docstring)."""

    def __init__(self, max_bytes: int = 256 << 20,
                 depth_weight: float = 64.0,
                 archive_floor_bytes: int = 0,
                 archive_max_bytes: Optional[int] = None,
                 admission_control: bool = False):
        if max_bytes <= 0:
            raise ValueError(f"max_bytes must be positive, got {max_bytes}")
        if depth_weight < 0:
            raise ValueError("depth_weight must be >= 0")
        if archive_max_bytes is not None and archive_max_bytes <= 0:
            raise ValueError("archive_max_bytes must be positive or None")
        self.max_bytes = int(max_bytes)
        self.depth_weight = float(depth_weight)
        self.archive_floor_bytes = int(archive_floor_bytes)
        self.archive_max_bytes = archive_max_bytes
        self.admission_control = bool(admission_control)
        self.stats = CacheStats()
        self._lock = threading.Lock()
        self._entries: Dict[Hashable, _Entry] = {}
        self._archives: Dict[str, _ArchiveState] = {}
        self._nbytes = 0
        self._tick = 0

    # -- internals (call with the lock held) ---------------------------------

    def _queue(self, archive: str, band: int
               ) -> "OrderedDict[Hashable, _Entry]":
        st = self._archives.setdefault(archive, _ArchiveState())
        q = st.bands.get(band)
        if q is None:
            q = st.bands[band] = OrderedDict()
        return q

    def _remove(self, key: Hashable, entry: _Entry) -> None:
        st = self._archives[entry.archive]
        del st.bands[entry.band][key]
        if not st.bands[entry.band]:
            del st.bands[entry.band]
        st.nbytes -= len(entry.data)
        if st.nbytes == 0 and not st.bands:
            del self._archives[entry.archive]
        del self._entries[key]
        self._nbytes -= len(entry.data)

    def _score(self, entry: _Entry) -> float:
        return entry.tick - self.depth_weight * entry.band

    def _victim(self, for_archive: str) -> Optional[Tuple[Hashable, _Entry]]:
        """Minimum-score entry among eviction candidates: the inserting
        archive's own entries, plus entries of archives above their floor.
        Falls back to the unrestricted minimum when floors protect
        everything else (the global byte bound must hold regardless)."""
        best: Optional[Tuple[Hashable, _Entry]] = None
        fallback: Optional[Tuple[Hashable, _Entry]] = None
        protected = False
        for name, st in self._archives.items():
            for q in st.bands.values():
                key, entry = next(iter(q.items()))     # band head = stalest
                # exact floor guarantee: external pressure may take this
                # entry only if the archive stays at/above its floor after
                eligible = (name == for_archive
                            or st.nbytes - len(entry.data)
                            >= self.archive_floor_bytes)
                cand = (key, entry)
                if fallback is None or \
                        self._score(entry) < self._score(fallback[1]):
                    fallback = cand
                if not eligible:
                    protected = True
                    continue
                if best is None or \
                        self._score(entry) < self._score(best[1]):
                    best = cand
        if best is None:
            return fallback
        if protected and fallback is not None and fallback[1] is not best[1]:
            self.stats.floor_protected += 1
        return best

    def _evict_one(self, for_archive: str) -> None:
        victim = self._victim(for_archive)
        if victim is None:                  # cache empty — nothing to do
            return
        self._remove(*victim)
        self.stats.evictions += 1

    def _min_resident_score(self) -> Optional[float]:
        """Lowest score among resident entries — scanning only band heads
        (each queue head is its band's minimum tick).  Pure read: unlike
        ``_victim`` it never touches the floor_protected stat, so the
        admission check cannot masquerade as floor pressure."""
        best: Optional[float] = None
        for st in self._archives.values():
            for q in st.bands.values():
                entry = next(iter(q.values()))
                score = self._score(entry)
                if best is None or score < best:
                    best = score
        return best

    def _evict_within(self, archive: str) -> None:
        """Per-archive cap: evict the minimum-score entry of one archive."""
        st = self._archives.get(archive)
        if st is None:
            return
        best: Optional[Tuple[Hashable, _Entry]] = None
        for q in st.bands.values():
            key, entry = next(iter(q.items()))
            if best is None or self._score(entry) < self._score(best[1]):
                best = (key, entry)
        if best is not None:
            self._remove(*best)
            self.stats.evictions += 1

    # -- public API ----------------------------------------------------------

    def get(self, key: Hashable) -> Optional[bytes]:
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                self.stats.misses += 1
                return None
            self._tick += 1
            entry.tick = self._tick
            self._archives[entry.archive].bands[entry.band] \
                .move_to_end(key)
            self.stats.hits += 1
            return entry.data

    def put(self, key: Hashable, data: bytes, depth: int = 0,
            archive: str = "") -> None:
        if len(data) > self.max_bytes:
            return                      # would evict everything for one entry
        with self._lock:
            old = self._entries.get(key)
            if old is None and self.admission_control and \
                    self._nbytes + len(data) > self.max_bytes:
                band = min(max(int(depth), 0), _MAX_BAND)
                floor = self._min_resident_score()
                # the newcomer would enter at tick+1; if even then it scores
                # below the stalest resident entry, inserting means evicting
                # hotter bytes to hold a segment that loses the very next
                # comparison — skip it and keep the resident set intact
                # (a re-put of a resident key is a refresh, never admission)
                if floor is not None and \
                        (self._tick + 1) - self.depth_weight * band < floor:
                    self.stats.admission_skips += 1
                    return
            if old is not None:
                self._remove(key, old)
            self._tick += 1
            entry = _Entry(data=data, depth=int(depth),
                           band=min(max(int(depth), 0), _MAX_BAND),
                           archive=archive, tick=self._tick)
            self._queue(archive, entry.band)[key] = entry
            self._entries[key] = entry
            st = self._archives[archive]
            st.nbytes += len(data)
            self._nbytes += len(data)
            self.stats.insertions += 1
            while self._nbytes > self.max_bytes and self._entries:
                self._evict_one(for_archive=archive)
            if self.archive_max_bytes is not None:
                while self._archives.get(archive) is not None and \
                        self._archives[archive].nbytes > self.archive_max_bytes:
                    self._evict_within(archive)

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._archives.clear()
            self._nbytes = 0

    @property
    def nbytes(self) -> int:
        with self._lock:
            return self._nbytes

    def archive_nbytes(self, archive: str = "") -> int:
        """Resident bytes attributed to one archive id."""
        with self._lock:
            st = self._archives.get(archive)
            return st.nbytes if st is not None else 0

    def archives(self) -> List[str]:
        with self._lock:
            return list(self._archives)

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __contains__(self, key: Hashable) -> bool:
        with self._lock:
            return key in self._entries
