"""ArchiveWriter: the producing side of live append-only archives (v4).

One API unifies the three historical write paths — one-shot
``save_archive`` / ``save_sharded_archive`` and the serve plane's
``ensure_archive`` — behind ``create → append(snapshot) → seal``:

    w = ArchiveWriter.create(dirpath)           # base manifest + journal
    w.append({"Vx": frame0}, eps=1e-3)          # keyframe
    w.append({"Vx": frame1}, eps=1e-3)          # delta vs. recon(frame0)
    ...
    w.seal()                                    # consolidated manifest

Every ``append`` compresses each variable's new timestep through
``repro.compressors.snapshots.encode_timestep`` — a keyframe every
``keyframe_interval`` steps, residuals against the previous timestep's
*reconstruction* in between (temporal deltas are far sparser than the
fields, which is where the entropy stage wins) — writes the payload as one
new immutable ``<var>.t<k>.seg`` blob, and appends the describing records
to ``journal.jsonl``.  Nothing already on disk is ever rewritten: the base
``manifest.json`` stays fixed until ``seal()``, blobs are publish-by-rename,
and the journal only grows, so a concurrent reader (local re-read or HTTP
conditional GET — see ``StoreArchive.refresh``) either sees a record
completely or not yet.

``retain_timesteps`` enables rolling retention: once a variable holds more
than that many timesteps, the oldest keyframe-aligned prefix is dropped —
a ``retention`` record tells readers to forget it, and the dropped blobs
are deleted (per-blob blast radius is already isolated, so a reader racing
the delete simply fails that one stale fetch).

``seal()`` appends the terminal record and atomically rewrites
``manifest.json`` as a consolidated v4 manifest (``"sealed": true``,
``"journal_records": N``) that folds every journaled segment/timestep in —
a sealed archive opens without touching the journal at all.

``ensure_archive`` (re-exported by ``repro.launch.serve``) serializes
create-if-missing across racing processes behind a lockfile; the builder
runs exactly once and the result is published by one atomic rename.
"""
from __future__ import annotations

import json
import os
import shutil
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.compressors.snapshots import encode_timestep
from repro.store.container import (
    FORMAT_VERSION,
    JOURNAL_NAME,
    MANIFEST_NAME,
    build_sharded_container,
    is_url,
    save_archive,
    save_sharded_archive,
)
from repro.store.crc import crc32c

__all__ = ["ArchiveWriter", "ensure_archive"]


def _write_atomic(path: str, data: bytes) -> None:
    """Publish ``data`` at ``path`` by rename — readers see old or new
    bytes, never a prefix."""
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "wb") as fh:
        fh.write(data)
    os.replace(tmp, path)


class _VarState:
    """Writer-side chain state for one timeseries variable."""

    __slots__ = ("shape", "next_t", "since_key", "prev_recon")

    def __init__(self, shape: Tuple[int, ...]):
        self.shape = shape
        self.next_t = 0
        self.since_key = 0                      # deltas since last keyframe
        self.prev_recon: Optional[np.ndarray] = None


class ArchiveWriter:
    """Append-only producer of a live sharded archive (manifest v4).

    Construct through :meth:`create`.  ``append`` adds one timestep per
    supplied variable (all variables advance in lock-step per call is NOT
    required — each keeps its own clock); ``seal`` finalizes.  The writer
    keeps the consolidated manifest in memory, so ``seal()`` is a pure
    local rewrite — no journal re-read.
    """

    def __init__(self, directory: str, manifest: dict,
                 keyframe_interval: int = 8,
                 retain_timesteps: Optional[int] = None,
                 _journal_records: int = 0):
        if keyframe_interval < 1:
            raise ValueError("keyframe_interval must be >= 1")
        if retain_timesteps is not None and retain_timesteps < 1:
            raise ValueError("retain_timesteps must be >= 1 (or None)")
        self.directory = directory
        self.manifest = manifest
        self.keyframe_interval = keyframe_interval
        self.retain_timesteps = retain_timesteps
        self.sealed = bool(manifest.get("sealed", False))
        self.bytes_written = 0
        self._vars: Dict[str, _VarState] = {}
        self._journal_records = _journal_records
        self._jf = open(os.path.join(directory, JOURNAL_NAME), "ab")

    # -- lifecycle -----------------------------------------------------------

    @classmethod
    def create(cls, directory: str, base=None, method: str = "hb",
               shard_by: str = "variable", keyframe_interval: int = 8,
               retain_timesteps: Optional[int] = None) -> "ArchiveWriter":
        """Create a live archive at ``directory``.

        ``base`` (optional, a ``core.refactor.Archive``) seeds the archive
        with a full one-shot refactor — the v3-compatible static content —
        so ``create(d, base=a); seal()`` subsumes ``save_sharded_archive``.
        Without a base the archive starts empty and grows purely by
        appends.  The directory must not already hold a manifest."""
        os.makedirs(directory, exist_ok=True)
        mpath = os.path.join(directory, MANIFEST_NAME)
        if os.path.exists(mpath):
            raise FileExistsError(f"{mpath} exists — ArchiveWriter never "
                                  f"rewrites a published archive")
        if base is not None:
            manifest, payloads = build_sharded_container(base,
                                                         shard_by=shard_by)
            for blob, data in payloads.items():
                _write_atomic(os.path.join(directory, blob), data)
        else:
            manifest = {"format": "prstore", "version": FORMAT_VERSION,
                        "method": method, "ranges": {}, "shapes": {},
                        "masks": {}, "variables": {}, "segments": {},
                        "blobs": {}}
        manifest["version"] = FORMAT_VERSION
        manifest["journal"] = True
        # the journal exists from birth so followers' journal_source always
        # has a file (HTTP followers get an empty 200 rather than a 404)
        open(os.path.join(directory, JOURNAL_NAME), "ab").close()
        _write_atomic(mpath, json.dumps(manifest, sort_keys=True,
                                        indent=1).encode("utf-8"))
        w = cls(directory, manifest, keyframe_interval=keyframe_interval,
                retain_timesteps=retain_timesteps)
        w.bytes_written = sum(manifest["blobs"].values())
        return w

    @staticmethod
    def ensure(store_path: str, builder: Callable[[], object],
               shard_by: Optional[str] = None, **kw) -> bool:
        """Create-if-missing, exactly once across racing processes — see
        :func:`ensure_archive`."""
        return ensure_archive(store_path, builder, shard_by=shard_by, **kw)

    # -- journal -------------------------------------------------------------

    def _journal_append(self, records: List[dict]) -> None:
        data = b"".join(json.dumps(r, sort_keys=True).encode("utf-8") + b"\n"
                        for r in records)
        self._jf.write(data)
        self._jf.flush()
        os.fsync(self._jf.fileno())
        self._journal_records += len(records)

    # -- append --------------------------------------------------------------

    def append(self, fields: Dict[str, np.ndarray], eps: float) -> int:
        """Append one timestep of every supplied variable at error bound
        ``eps``; returns the timestep index assigned (per this append's
        variables — they advance in lock-step when always supplied
        together).  Payload blobs land on disk (publish-by-rename) BEFORE
        their journal records, so a reader can never learn of a segment
        whose bytes are not fully there."""
        if self.sealed:
            raise ValueError("archive is sealed — no further appends")
        if not fields:
            raise ValueError("append needs at least one variable")
        records: List[dict] = []
        t_out = -1
        for name, x in fields.items():
            if "/" in name:
                raise ValueError(f"variable name {name!r} may not "
                                 f"contain '/'")
            x = np.asarray(x, dtype=np.float64)
            st = self._vars.get(name)
            if st is None:
                if name in self.manifest["variables"]:
                    raise ValueError(f"variable {name!r} already exists in "
                                     f"the base archive")
                st = _VarState(x.shape)
                self._vars[name] = st
                rng = float(np.max(x) - np.min(x))
                rng = rng if rng > 0 else 1.0
                self.manifest["variables"][name] = {
                    "kind": "timeseries", "base_t": 0, "timesteps": []}
                self.manifest["shapes"][name] = list(x.shape)
                self.manifest["ranges"][name] = rng
                records.append({"op": "var", "name": name,
                                "kind": "timeseries",
                                "shape": list(x.shape), "range": rng})
            if x.shape != st.shape:
                raise ValueError(f"{name}: timestep shape {x.shape} != "
                                 f"{st.shape}")
            t = st.next_t
            keyframe = st.prev_recon is None \
                or st.since_key >= self.keyframe_interval - 1
            snap, recon = encode_timestep(
                x, eps, None if keyframe else st.prev_recon)
            blob_name = f"{name}.t{t}.seg"
            payload = b"".join(snap.blobs)
            _write_atomic(os.path.join(self.directory, blob_name), payload)
            off = 0
            for j, b in enumerate(snap.blobs):
                key = f"{name}/t{t}/b{j}"
                crc = crc32c(b)
                self.manifest["segments"][key] = \
                    [blob_name, off, len(b), crc, None]
                records.append({"op": "segment", "key": key,
                                "blob": blob_name, "offset": off,
                                "size": len(b), "crc": crc, "codec": None})
                off += len(b)
            self.manifest["blobs"][blob_name] = off
            self.bytes_written += off
            spec = {"t": t, "keyframe": keyframe, "eps": snap.eps,
                    "orig_shape": list(snap.orig_shape),
                    "padded_shape": list(snap.padded_shape),
                    "levels": snap.levels, "dtypes": list(snap.dtypes),
                    "amax": snap.amax,
                    "blob_sizes": [len(b) for b in snap.blobs]}
            self.manifest["variables"][name]["timesteps"].append(spec)
            records.append(dict(spec, op="timestep", var=name))
            st.prev_recon = recon
            st.next_t = t + 1
            st.since_key = 0 if keyframe else st.since_key + 1
            t_out = t
            if self.retain_timesteps is not None:
                records.extend(self._retain(name, st))
        self._journal_append(records)
        return t_out

    def _retain(self, name: str, st: _VarState) -> List[dict]:
        """Rolling retention: drop the oldest keyframe-aligned prefix once
        the variable exceeds ``retain_timesteps``.  The boundary snaps DOWN
        to a keyframe, so what remains always starts decodable."""
        vspec = self.manifest["variables"][name]
        specs = vspec["timesteps"]
        base_t = vspec["base_t"]
        target = st.next_t - self.retain_timesteps
        idx = target - base_t
        if idx <= 0:
            return []
        while idx > 0 and not specs[idx]["keyframe"]:
            idx -= 1
        if idx <= 0:
            return []
        boundary = base_t + idx
        for spec in specs[:idx]:
            t = spec["t"]
            blob_name = f"{name}.t{t}.seg"
            for j in range(len(spec["blob_sizes"])):
                self.manifest["segments"].pop(f"{name}/t{t}/b{j}", None)
            self.manifest["blobs"].pop(blob_name, None)
            try:
                os.unlink(os.path.join(self.directory, blob_name))
            except OSError:
                pass                    # a racing reader holds it: harmless
        del specs[:idx]
        vspec["base_t"] = boundary
        return [{"op": "retention", "var": name, "base_t": boundary}]

    # -- seal / close --------------------------------------------------------

    def seal(self) -> int:
        """Finalize: append the terminal journal record and atomically
        rewrite ``manifest.json`` as a consolidated, sealed v4 manifest
        folding in every journaled segment/timestep.  A sealed archive
        opens without reading the journal.  Returns total payload+manifest
        bytes on disk."""
        if self.sealed:
            raise ValueError("archive already sealed")
        self._journal_append([{"op": "seal"}])
        self.sealed = True
        self.manifest["sealed"] = True
        self.manifest["journal_records"] = self._journal_records
        mblob = json.dumps(self.manifest, sort_keys=True,
                           indent=1).encode("utf-8")
        _write_atomic(os.path.join(self.directory, MANIFEST_NAME), mblob)
        self.close()
        return sum(self.manifest["blobs"].values()) + len(mblob)

    def close(self) -> None:
        """Release the journal handle WITHOUT sealing — the archive stays
        live and another writer (or a later run) may keep appending."""
        if not self._jf.closed:
            self._jf.close()

    def __enter__(self) -> "ArchiveWriter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def ensure_archive(store_path: str, builder: Callable[[], object],
                   shard_by: Optional[str] = None,
                   stale_lock_s: float = 300.0,
                   wait_timeout_s: float = 300.0,
                   poll_s: float = 0.05) -> bool:
    """Create the archive container at ``store_path`` exactly once across
    racing processes; returns True when THIS call created it.

    Two servers starting on the same missing path used to race
    ``save_*_archive`` — each refactoring the fields and interleaving
    writes into one half-written container.  Creation is serialized behind
    ``store_path + ".lock"`` (``O_CREAT|O_EXCL`` — the portable atomic
    claim) and published by writing to a private ``.tmp.<pid>`` target
    followed by one atomic ``os.rename``: every other process either sees
    no container (and waits on the lock) or the complete one, never a
    prefix.  ``builder`` runs only in the winning process, so the refactor
    itself also happens exactly once.  A lock older than ``stale_lock_s``
    is presumed crashed and broken; waiters give up with ``TimeoutError``
    after ``wait_timeout_s`` rather than hang a server boot forever.
    """
    if is_url(store_path) or os.path.exists(store_path):
        return False
    lock_path = store_path + ".lock"
    parent = os.path.dirname(os.path.abspath(store_path))
    os.makedirs(parent, exist_ok=True)
    deadline = time.monotonic() + wait_timeout_s
    while True:
        if os.path.exists(store_path):
            return False                 # someone else finished the job
        try:
            fd = os.open(lock_path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
        except FileExistsError:
            try:
                age = time.time() - os.path.getmtime(lock_path)
            except OSError:
                continue                 # lock released between EXCL and stat
            if age > stale_lock_s:
                # a crashed creator must not wedge every future boot
                try:
                    os.unlink(lock_path)
                except OSError:
                    pass
                continue
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"timed out after {wait_timeout_s:.0f}s waiting for "
                    f"{lock_path} (another process creating the archive?)")
            time.sleep(poll_s)
            continue
        try:
            os.write(fd, f"{os.getpid()}\n".encode())
            os.close(fd)
            if os.path.exists(store_path):
                return False             # raced: winner finished before EXCL
            tmp = f"{store_path}.tmp.{os.getpid()}"
            try:
                archive = builder()      # the refactor happens exactly once
                if shard_by:
                    save_sharded_archive(archive, tmp, shard_by=shard_by)
                else:
                    save_archive(archive, tmp)
                os.rename(tmp, store_path)   # publish atomically
            except BaseException:
                if os.path.isdir(tmp):
                    shutil.rmtree(tmp, ignore_errors=True)
                elif os.path.exists(tmp):
                    os.unlink(tmp)
                raise
            return True
        finally:
            try:
                os.unlink(lock_path)
            except OSError:
                pass
