"""Stdlib archive endpoint: a ranged-GET HTTP server over container files.

    PYTHONPATH=src python -m repro.store.httpd /data/archive_dir --port 8000
    PYTHONPATH=src python -m repro.launch.serve --store http://host:8000/manifest.json

Serves a directory (sharded archive: ``manifest.json`` + ``*.seg`` blobs) or
a single ``.prs`` file with proper ``Range: bytes=a-b`` semantics — 206 +
``Content-Range`` for satisfiable ranges, 416 for unsatisfiable ones, 200
with the whole resource when no Range header is present — over persistent
HTTP/1.1 connections, so `HTTPByteStore`'s connection reuse actually reuses.

`ThreadingHTTPServer` gives one thread per connection: the SegmentFetcher's
prefetch pool and demand path stream concurrently, like any real object
store.  ``fault_injector`` lets tests inject transient failures (e.g. a 500
on the first attempt) to exercise the client's retry/backoff path.

Every file response (GET and HEAD) carries a weak-validator ``ETag``
derived from ``(size, mtime_ns)``; a conditional GET with a matching
``If-None-Match`` short-circuits to ``304 Not Modified`` — the
revalidation primitive live append-only archives need (`HTTPByteStore`
sends the validator on manifest re-reads, see repro.store.bytestore).

When handed a ``metrics_source`` / ``health_source`` (the serve plane
does), the server also answers ``GET /metrics`` with a plaintext counter
dump and ``GET /health`` with 200/ok — or ``503`` plus a ``Retry-After``
header while the serve plane is shedding load.
"""
from __future__ import annotations

import argparse
import os
import re
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable, List, Optional, Tuple

_RANGE_RE = re.compile(r"bytes=(\d*)-(\d*)$")


def parse_range(header: str, size: int) -> Optional[Tuple[int, int]]:
    """``Range`` header -> (start, end) inclusive, or None if malformed /
    multi-range (caller falls back to the full resource).  Raises ValueError
    for a syntactically valid but unsatisfiable range (-> 416)."""
    m = _RANGE_RE.match(header.strip())
    if not m:
        return None
    first, last = m.group(1), m.group(2)
    if first == "" and last == "":
        return None
    if first == "":                      # suffix form: last N bytes
        n = int(last)
        if n == 0 or size == 0:
            # RFC 9110 §14.1.2: a suffix range on an empty resource (or an
            # empty suffix) is unsatisfiable — (0, -1) would slice garbage
            raise ValueError(
                f"unsatisfiable suffix range {header!r} for size {size}")
        return max(0, size - n), size - 1
    start = int(first)
    end = int(last) if last != "" else size - 1
    if start >= size or end < start:
        raise ValueError(f"unsatisfiable range {header!r} for size {size}")
    return start, min(end, size - 1)


class _ArchiveHandler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"       # keep-alive: client connections reuse
    server_version = "prstore-httpd/1"
    # the header write + body write per response is exactly the
    # write-write-read pattern where Nagle + the peer's delayed ACK stall
    # every exchange ~40ms; range GETs are latency-bound, so flush eagerly
    disable_nagle_algorithm = True

    def _resolve(self) -> Optional[str]:
        root = self.server.root          # type: ignore[attr-defined]
        name = os.path.basename(self.path.split("?", 1)[0].rstrip("/"))
        if os.path.isfile(root):
            # single-file mode: any request path serves the file
            return root
        path = os.path.realpath(os.path.join(root, name))
        if os.path.commonpath([path, os.path.realpath(root)]) != \
                os.path.realpath(root) or not os.path.isfile(path):
            return None
        return path

    def _respond(self, status: int, length: int,
                 extra: Optional[dict] = None) -> None:
        self.send_response(status)
        self.send_header("Accept-Ranges", "bytes")
        self.send_header("Content-Length", str(length))
        for k, v in (extra or {}).items():
            self.send_header(k, v)
        self.end_headers()

    def _endpoint(self, head_only: bool) -> bool:
        """Serve /health and /metrics when the server carries sources for
        them; returns True when the request was handled.  Routed before
        file resolution, so an archive file literally named ``metrics``
        is shadowed only on servers that enable the endpoints."""
        route = self.path.split("?", 1)[0].rstrip("/")
        if route == "/metrics":
            source = self.server.metrics_source  # type: ignore[attr-defined]
            if source is None:
                return False
            body = "".join(f"{k} {v:g}\n"
                           for k, v in sorted(source().items()))
            payload = body.encode()
            self._respond(200, len(payload),
                          {"Content-Type": "text/plain; charset=utf-8"})
            if not head_only:
                self.wfile.write(payload)
            return True
        if route == "/health":
            source = self.server.health_source   # type: ignore[attr-defined]
            if source is None:
                return False
            report = source()
            ok = bool(report.get("ok", True))
            extra = {"Content-Type": "text/plain; charset=utf-8"}
            if not ok and report.get("retry_after_s"):
                # shedding: tell well-behaved clients when to come back
                extra["Retry-After"] = \
                    str(max(1, int(report["retry_after_s"])))
            payload = (b"ok\n" if ok else b"overloaded\n")
            self._respond(200 if ok else 503, len(payload), extra)
            if not head_only:
                self.wfile.write(payload)
            return True
        return False

    @staticmethod
    def _etag(path: str) -> str:
        """Weak validator from (size, mtime_ns): changes whenever the file
        is rewritten — exactly the signal a live-archive client needs to
        drop its cached manifest."""
        st = os.stat(path)
        return f'"{st.st_size:x}-{st.st_mtime_ns:x}"'

    def _serve(self, head_only: bool) -> None:
        injector = self.server.fault_injector  # type: ignore[attr-defined]
        if injector is not None:
            status = injector(self)
            if status:
                with self.server.stats_lock:   # type: ignore[attr-defined]
                    self.server.stats["faults"] += 1
                self._respond(status, 0)
                return
        if self._endpoint(head_only):
            return
        path = self._resolve()
        if path is None:
            self._respond(404, 0)
            return
        size = os.path.getsize(path)
        etag = self._etag(path)
        if self._matches(self.headers.get("If-None-Match"), etag):
            with self.server.stats_lock:       # type: ignore[attr-defined]
                self.server.stats["requests"] += 1
                self.server.stats["not_modified"] += 1
            self._respond(304, 0, {"ETag": etag})
            return
        rng_header = self.headers.get("Range")
        rng = None
        if rng_header:
            try:
                rng = parse_range(rng_header, size)
            except ValueError:
                self._respond(416, 0,
                              {"Content-Range": f"bytes */{size}"})
                return
        start, end = rng if rng is not None else (0, size - 1)
        length = end - start + 1 if size else 0
        with self.server.stats_lock:           # type: ignore[attr-defined]
            self.server.stats["requests"] += 1
            self.server.stats["bytes_sent"] += 0 if head_only else length
            if rng is not None:
                self.server.stats["range_requests"] += 1
        extra = {"ETag": etag}
        if rng is not None:
            extra["Content-Range"] = f"bytes {start}-{end}/{size}"
        self._respond(206 if rng is not None else 200, length, extra)
        if head_only or length == 0:
            return
        with open(path, "rb") as fh:
            fh.seek(start)
            remaining = length
            while remaining:
                chunk = fh.read(min(remaining, 1 << 20))
                if not chunk:
                    break
                self.wfile.write(chunk)
                remaining -= len(chunk)

    @staticmethod
    def _parse_etag_list(header: str) -> List[str]:
        """Split an ``If-None-Match`` field value into opaque-tags (quotes
        kept, ``W/`` prefixes stripped).  A naive ``split(",")`` corrupts
        entity-tags that legally contain a comma (RFC 9110 ``etagc``
        permits 0x2C), so the walk is quote-aware: commas only delimit
        between quoted strings."""
        tags, i, n = [], 0, len(header)
        while i < n:
            if header[i] in " \t,":
                i += 1
                continue
            start = i
            if header.startswith("W/", i):
                i += 2
            if i < n and header[i] == '"':
                j = header.find('"', i + 1)
                i = (j + 1) if j != -1 else n
                tags.append(header[start:i])
            else:                        # tolerate unquoted legacy tags
                j = header.find(",", i)
                i = j if j != -1 else n
                tags.append(header[start:i].strip())
        return tags

    @classmethod
    def _matches(cls, if_none_match: Optional[str], etag: str) -> bool:
        """RFC 9110 §13.1.2 weak comparison over a comma-separated
        candidate list; ``*`` matches any current representation.  Weak
        comparison ignores ``W/`` on BOTH sides — a client revalidating
        with a weakened cached tag still gets its 304."""
        if not if_none_match:
            return False
        if if_none_match.strip() == "*":
            return True
        opaque = etag.removeprefix("W/")
        return any(c.removeprefix("W/") == opaque
                   for c in cls._parse_etag_list(if_none_match))

    def do_GET(self) -> None:           # noqa: N802 (http.server API)
        self._serve(head_only=False)

    def do_HEAD(self) -> None:          # noqa: N802
        self._serve(head_only=True)

    def log_message(self, fmt: str, *args) -> None:
        if self.server.verbose:          # type: ignore[attr-defined]
            super().log_message(fmt, *args)


class StoreHTTPServer(ThreadingHTTPServer):
    """Ranged-GET file server for archive containers (tests, demos, and the
    far end of ``serve.py --store http://…``)."""

    daemon_threads = True

    def __init__(self, root: str, host: str = "127.0.0.1", port: int = 0,
                 fault_injector: Optional[
                     Callable[[BaseHTTPRequestHandler], int]] = None,
                 verbose: bool = False,
                 metrics_source: Optional[Callable[[], dict]] = None,
                 health_source: Optional[Callable[[], dict]] = None):
        super().__init__((host, port), _ArchiveHandler)
        self.root = root
        self.fault_injector = fault_injector
        self.verbose = verbose
        # serve-plane observability: /metrics renders the counter dict,
        # /health maps {"ok": bool, "retry_after_s": float} to 200/503
        self.metrics_source = metrics_source
        self.health_source = health_source
        self.stats = {"requests": 0, "range_requests": 0, "bytes_sent": 0,
                      "faults": 0, "not_modified": 0}
        self.stats_lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None

    @property
    def url(self) -> str:
        host, port = self.server_address[:2]
        base = f"http://{host}:{port}/"
        if os.path.isfile(self.root):
            return base + os.path.basename(self.root)
        return base

    def url_for(self, name: str) -> str:
        return f"http://{self.server_address[0]}:{self.server_address[1]}" \
               f"/{name}"

    def start(self) -> "StoreHTTPServer":
        self._thread = threading.Thread(target=self.serve_forever,
                                        name="prstore-httpd", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self.shutdown()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None
        self.server_close()

    def __enter__(self) -> "StoreHTTPServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()


def transient_faults(n: int, status: int = 500,
                     match: str = "") -> Callable:
    """Fault injector failing the first ``n`` matching requests — the shape
    of a flaky object-store frontend; a retrying client must absorb it."""
    remaining = [n]
    lock = threading.Lock()

    def injector(handler: BaseHTTPRequestHandler) -> int:
        if match and match not in handler.path:
            return 0
        with lock:
            if remaining[0] > 0:
                remaining[0] -= 1
                return status
        return 0

    return injector


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="serve an archive container (file or sharded directory) "
                    "with HTTP range support")
    ap.add_argument("root", help=".prs file or sharded-archive directory")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8000)
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)
    srv = StoreHTTPServer(os.path.abspath(args.root), host=args.host,
                          port=args.port, verbose=args.verbose)
    print(f"[httpd] serving {args.root} at {srv.url}")
    try:
        srv.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        srv.server_close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
