"""Internal-link checker for the markdown docs (CI's docs job).

Scans markdown files for inline links/images ``[text](target)`` and fails
on any *internal* target that does not resolve:

  * relative file targets must exist on disk (resolved against the linking
    file's directory);
  * ``target.md#anchor`` (and same-file ``#anchor``) targets must name a
    heading whose GitHub slug matches the anchor;
  * ``http(s)://`` / ``mailto:`` targets are skipped — CI must not depend
    on the network.

Fenced code blocks and inline code spans are stripped before scanning so
example snippets never false-positive.

Usage (what `.github/workflows/ci.yml` runs)::

    python -m tools.check_links README.md docs

Directories are scanned recursively for ``*.md``.  Exit code 1 lists every
broken link as ``file:line: message``.
"""
from __future__ import annotations

import argparse
import os
import re
import sys
from typing import List, Tuple

# inline [text](target) / ![alt](target); target ends at the first ')' or
# space (markdown titles — [t](file "title") — keep only the path part)
_LINK_RE = re.compile(r"!?\[[^\]]*\]\(\s*<?([^)\s>]+)>?(?:\s+\"[^\"]*\")?\s*\)")
_HEADING_RE = re.compile(r"^#{1,6}\s+(.*?)\s*#*\s*$")
_FENCE_RE = re.compile(r"^(```|~~~)")
_EXTERNAL = ("http://", "https://", "mailto:", "ftp://")


def slugify(heading: str) -> str:
    """GitHub's anchor slug: lowercase, spaces to hyphens, drop everything
    but word chars/hyphens (backticks and punctuation vanish)."""
    text = re.sub(r"`([^`]*)`", r"\1", heading).strip().lower()
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def _strip_code(lines: List[str]) -> List[str]:
    """Blank out fenced code blocks and inline code spans, preserving line
    numbering so reports point at the real line."""
    out: List[str] = []
    in_fence = False
    for line in lines:
        if _FENCE_RE.match(line.strip()):
            in_fence = not in_fence
            out.append("")
            continue
        out.append("" if in_fence else re.sub(r"`[^`]*`", "", line))
    return out


def iter_links(text: str) -> List[Tuple[int, str]]:
    """(1-based line number, raw target) for every inline link."""
    links: List[Tuple[int, str]] = []
    for i, line in enumerate(_strip_code(text.splitlines()), start=1):
        for m in _LINK_RE.finditer(line):
            links.append((i, m.group(1)))
    return links


def headings(path: str) -> List[str]:
    """Anchor slugs of every heading, with GitHub's duplicate
    disambiguation: repeated headings get ``-1``, ``-2``, ... suffixes."""
    with open(path, encoding="utf-8") as fh:
        lines = _strip_code(fh.read().splitlines())
    slugs: List[str] = []
    seen: dict = {}
    for line in lines:
        m = _HEADING_RE.match(line)
        if not m:
            continue
        slug = slugify(m.group(1))
        n = seen.get(slug)
        seen[slug] = 0 if n is None else n + 1
        slugs.append(slug if n is None else f"{slug}-{n + 1}")
    return slugs


def check_file(path: str) -> List[str]:
    """Broken-link report for one markdown file (empty = clean)."""
    errors: List[str] = []
    base = os.path.dirname(os.path.abspath(path))
    with open(path, encoding="utf-8") as fh:
        text = fh.read()
    for lineno, target in iter_links(text):
        if target.startswith(_EXTERNAL):
            continue
        fragment = None
        if "#" in target:
            target, fragment = target.split("#", 1)
        dest = os.path.abspath(path) if target == "" \
            else os.path.normpath(os.path.join(base, target))
        if not os.path.exists(dest):
            errors.append(f"{path}:{lineno}: broken link -> {target}")
            continue
        if fragment and dest.endswith(".md"):
            if slugify(fragment) not in headings(dest):
                errors.append(f"{path}:{lineno}: missing anchor "
                              f"#{fragment} in {target or os.path.basename(dest)}")
    return errors


def collect_markdown(paths: List[str]) -> List[str]:
    files: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            for root, _, names in os.walk(p):
                files.extend(os.path.join(root, n)
                             for n in sorted(names) if n.endswith(".md"))
        else:
            files.append(p)
    return files


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="check internal markdown links resolve")
    ap.add_argument("paths", nargs="*", default=["README.md", "docs"],
                    help="markdown files and/or directories "
                         "(default: README.md docs)")
    args = ap.parse_args(argv)
    paths = args.paths or ["README.md", "docs"]
    files = collect_markdown(paths)
    if not files:
        print("check_links: no markdown files found", file=sys.stderr)
        return 1
    errors: List[str] = []
    for f in files:
        errors.extend(check_file(f))
    for e in errors:
        print(e)
    print(f"check_links: {len(files)} files, "
          f"{len(errors)} broken link(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
