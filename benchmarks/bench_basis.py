"""Paper Fig 3: impact of the decomposition basis (PMGARD-OB vs -HB).

OB's L² projection forces a loose L-inf composition bound, so for the same
requested primary-data tolerance it (a) estimates a much larger error than
actually occurs and (b) retrieves more bytes. HB estimates tightly — the
paper's core optimisation. We report the estimate/actual gap and bitrates.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import timed
from repro.core.refactor import refactor_variables
from repro.data.synthetic import ge_like_fields


def run():
    rows = []
    fields = ge_like_fields(n=1 << 14, seed=0)
    data = {"P": fields["P"]}
    for method in ("ob", "hb"):
        dt_ref, arch = timed(refactor_variables, data, method=method,
                             mask_zero_velocity=False)
        session = arch.open()
        rng = arch.ranges["P"]
        gaps, rates = [], []
        for i in range(1, 14, 2):
            eps = 0.1 * 2.0 ** -i * rng
            rec, achieved = session.reconstruct("P", eps)
            actual = np.abs(rec - fields["P"]).max()
            assert actual <= achieved * (1 + 1e-9)
            gaps.append(achieved / max(actual, 1e-300))
            rates.append(session.bitrate(["P"]))
        rows.append((f"basis_impact/fig3/{method}", dt_ref * 1e6,
                     f"median_est/actual={float(np.median(gaps)):.2f};"
                     f"bitrate@tight={rates[-1]:.2f}"))
    return rows
