"""Memory-bounded retrieval bench: contribution-cache budgets.

The per-variable contribution cache of `_BitplaneVarReader` is the serving
path's RSS wall — (L+1)·n·8 bytes per variable unbounded.  These rows pin
down what a budget costs: for budgets of 1x / 0.5x / 0.25x the full
requirement, the bench warms a session down an eps ladder, then times the
*warm tightening* request (the serving steady state: most planes resident,
a few move, spilled coarse contributions must be rebuilt through
``recompose_hb_from``).  Each row reports the peak retained
contribution-cache bytes (the RSS proxy — asserted <= budget), the
spill/recompute counters, and the latency ratio against the unbounded
reader.  Outputs are asserted bit-identical to the unbounded path at every
budget — the budget may only cost time, never accuracy.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import timed
from repro.core.refactor import refactor_variables
from repro.options import SessionOptions
from repro.data.synthetic import ge_like_fields

_N = 1 << 15
_VARS = ("Vx", "Vy")
_WARM_LADDER = (1e-2, 1e-3, 1e-4, 1e-5)
_TIGHTEN_EPS = 1e-6
_REPEAT = 3          # fresh warmed session per repeat; report the min


def _warm_session(arch, budget):
    s = arch.open(SessionOptions.memory_bounded(budget))
    for eps in _WARM_LADDER:
        for v in _VARS:
            s.reconstruct(v, eps)
    return s


def _tighten(session):
    out = {}
    for v in _VARS:
        out[v] = session.reconstruct(v, _TIGHTEN_EPS)[0]
    return out


def run():
    rows = []
    fields = {k: v for k, v in ge_like_fields(n=_N, seed=0).items()
              if k in _VARS}
    arch = refactor_variables(fields, method="hb")
    full_bytes = max(
        (var.levels + 1) * int(np.prod(var.padded_shape)) * 8
        for var in arch.variables.values())

    # unbounded reference: warm ladder, then the timed tightening request
    dt_ref, ref_vals = None, None
    for _ in range(_REPEAT):
        s = _warm_session(arch, None)
        dt, vals = timed(_tighten, s)
        if dt_ref is None or dt < dt_ref:
            dt_ref, ref_vals = dt, vals
    rows.append(("membound/warm_tighten/unbounded", dt_ref * 1e6,
                 f"full_bytes={full_bytes}"))

    for frac in (1.0, 0.5, 0.25):
        budget = int(frac * full_bytes)
        dt_b, stats = None, None
        for _ in range(_REPEAT):
            s = _warm_session(arch, budget)
            dt, vals = timed(_tighten, s)
            for v in _VARS:       # budget may cost time, never accuracy
                assert np.array_equal(vals[v], ref_vals[v]), \
                    f"budget={budget} not bit-identical on {v}"
            st = s.contrib_stats()
            assert st.contrib_peak_bytes <= len(_VARS) * budget, \
                f"peak {st.contrib_peak_bytes} over budget {budget}/var"
            if dt_b is None or dt < dt_b:
                dt_b, stats = dt, st
        rows.append((
            f"membound/warm_tighten/budget={frac:.2f}x", dt_b * 1e6,
            f"peak_bytes={stats.contrib_peak_bytes};"
            f"budget_per_var={budget};"
            f"spills={stats.contrib_spills};"
            f"recomputes={stats.contrib_recomputes};"
            f"vs_unbounded={dt_b / max(dt_ref, 1e-9):.2f}x"))
    return rows
