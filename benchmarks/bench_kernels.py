"""Kernel micro-benchmarks: Pallas (interpret mode on CPU) wrappers vs the
pure-jnp references — on real TPU hardware the same BlockSpecs drive Mosaic.
Wall times on CPU measure the jnp reference path (the honest number here);
interpret-mode kernel timings are correctness artifacts, not perf."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timed
from repro.kernels import ops, ref


def run():
    rows = []
    rng = np.random.default_rng(0)
    n = 1 << 16
    mag = jnp.asarray(rng.integers(0, 2 ** 30, n), jnp.int32)
    f = jax.jit(lambda m: ref.bitplane_pack_ref(m, 30))
    f(mag)[0].block_until_ready()
    dt, _ = timed(lambda: jax.block_until_ready(f(mag)), repeat=5)
    rows.append(("kernels/bitplane_pack_ref_jit/n=65536", dt * 1e6,
                 f"planes=30;GBps={n * 4 / dt / 1e9:.2f}"))

    even = jnp.asarray(rng.standard_normal((64, 513)), jnp.float32)
    odd = jnp.asarray(rng.standard_normal((64, 512)), jnp.float32)
    g = jax.jit(ref.hier_level_surplus_ref)
    g(even, odd).block_until_ready()
    dt, _ = timed(lambda: jax.block_until_ready(g(even, odd)), repeat=20)
    rows.append(("kernels/hier_level_ref_jit/64x512", dt * 1e6,
                 f"GBps={even.size * 4 / dt / 1e9:.2f}"))

    vx, vy, vz = (jnp.asarray(rng.standard_normal(n), jnp.float64)
                  for _ in range(3))
    eps = jnp.asarray([0.1, 0.2, 0.3])
    h = jax.jit(lambda a, b, c, e: ref.qoi_vtotal_ref(a, b, c, e))
    jax.block_until_ready(h(vx, vy, vz, eps))
    dt, _ = timed(lambda: jax.block_until_ready(h(vx, vy, vz, eps)),
                  repeat=10)
    rows.append(("kernels/qoi_vtotal_ref_jit/n=65536", dt * 1e6,
                 f"Melem/s={n / dt / 1e6:.1f}"))

    # correctness cross-check (pallas interpret vs ref) as a derived flag
    out_k = np.asarray(ops.pack_bitplanes(mag[:4096], nbits=16))
    out_r = np.asarray(ref.bitplane_pack_ref(mag[:4096], nbits=16))
    rows.append(("kernels/pallas_vs_ref_allclose", 0.0,
                 f"bitplane_exact={bool((out_k == out_r).all())}"))
    return rows
