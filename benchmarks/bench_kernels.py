"""Kernel micro-benchmarks: Pallas (interpret mode on CPU) wrappers vs the
pure-jnp references — on real TPU hardware the same BlockSpecs drive Mosaic.
Wall times on CPU measure the jnp reference path (the honest number here);
interpret-mode kernel timings are correctness artifacts, not perf.

Also tracks the device-codec hot loops against frozen legacy reference
implementations (the pre-batching scalar per-plane loops), so the encode /
decode / per-iteration-retrieval speedups are recorded per PR in
BENCH_kernels.json (see benchmarks/run.py)."""
from __future__ import annotations

import zlib

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timed
from repro.kernels import ops, ref


# -- frozen legacy codec (the seed's 48-iteration scalar loops) -------------


def _legacy_encode_level(c: np.ndarray, nbits: int = 48):
    amax = float(np.abs(c).max())
    e = int(np.ceil(np.log2(amax)))
    if 2.0 ** e == amax:
        e += 1
    mag = np.minimum(
        np.floor(np.abs(c) * np.float64(2.0) ** (nbits - e)).astype(np.uint64),
        np.uint64(2 ** nbits - 1))
    planes = []
    for b in range(nbits):
        bit = ((mag >> np.uint64(nbits - 1 - b)) & np.uint64(1)).astype(np.uint8)
        planes.append(zlib.compress(np.packbits(bit).tobytes(), 1))
    zlib.compress(np.packbits(c < 0).tobytes(), 1)
    return mag, planes


def _legacy_decode(planes, count: int, nbits: int, k: int):
    mag = np.zeros(count, dtype=np.uint64)
    for b in range(k):
        bits = np.unpackbits(
            np.frombuffer(zlib.decompress(planes[b]), dtype=np.uint8),
            count=count).astype(np.uint64)
        mag |= bits << np.uint64(nbits - 1 - b)
    return mag


def _codec_rows():
    from repro.bitplane.encoder import decode_magnitudes, encode_level
    rows = []
    rng = np.random.default_rng(1)
    n, nbits = 1 << 16, 48
    c = rng.standard_normal(n) * 3.1
    def best_of(fn, *a, trials=3, repeat=8):
        # min-of-trials suppresses scheduler noise on small shared boxes
        return min(timed(fn, *a, repeat=repeat)[0] for _ in range(trials))

    encode_level(c)               # warm-up: jit compile is one-off per shape
    mag, leg_planes = _legacy_encode_level(c)
    lbp = encode_level(c)
    dt_leg = best_of(_legacy_encode_level, c)
    dt_new = best_of(encode_level, c)
    rows.append((f"kernels/encode_level_batched/n={n}", dt_new * 1e6,
                 f"speedup_vs_legacy={dt_leg / dt_new:.2f}x"))
    k = 32
    dt_ld = best_of(_legacy_decode, leg_planes, n, nbits, k)
    dt_nd = best_of(decode_magnitudes, lbp, k)
    rows.append((f"kernels/decode_magnitudes_batched/n={n}/k={k}",
                 dt_nd * 1e6, f"speedup_vs_legacy={dt_ld / dt_nd:.2f}x"))
    exact = bool(np.array_equal(decode_magnitudes(lbp, nbits), mag))
    rows.append(("kernels/codec_vs_legacy_magnitudes_exact", 0.0,
                 f"exact={exact}"))

    # device-resident fused decode: unpack + sign + scale as ONE jit
    # dispatch (vs the host pair decode_magnitudes -> decode_values)
    from repro.bitplane.encoder import (decode_values, inflate_planes,
                                        sign_plane_bytes)
    from repro.kernels import ops as kops
    words, shifts = inflate_planes(n, nbits, lbp.planes[:k], 0)
    sb = sign_plane_bytes(n, lbp.signs)
    scale = np.float64(2.0) ** (lbp.exponent - nbits)

    def host_decode():
        return decode_values(lbp, decode_magnitudes(lbp, k))

    def fused_decode():
        _, vals = kops.decode_values_fused(words, shifts, None, sb, scale, n)
        return np.asarray(vals)      # include the device->host readback

    fused_decode()                   # warm-up: compile is one-off per shape
    dt_host = best_of(host_decode)
    dt_fused = best_of(fused_decode)
    dexact = bool(np.array_equal(host_decode().view(np.uint64),
                                 fused_decode().view(np.uint64)))
    rows.append((f"kernels/device_decode/n={n}/k={k}", dt_fused * 1e6,
                 f"speedup_vs_host={dt_host / dt_fused:.2f}x;"
                 f"exact={dexact}"))
    return rows


def _retrieval_rows():
    from repro.core import ge
    from repro.core.refactor import refactor_variables
    from repro.core.retrieval import QoIRequest, retrieve_qoi_controlled
    from repro.data.synthetic import ge_like_fields
    rows = []
    fields = ge_like_fields(n=1 << 15, seed=0)
    vel = {kk: fields[kk] for kk in ("Vx", "Vy", "Vz")}
    arch = refactor_variables(vel, method="hb")
    # warm-up: jit compiles are one-off per shape
    retrieve_qoi_controlled(arch.open(),
                            [QoIRequest("VTOT", ge.v_total(), 1e-2)])
    session = arch.open()
    dt, res = timed(retrieve_qoi_controlled, session,
                    [QoIRequest("VTOT", ge.v_total(), 1e-5)])
    iters = max(len(res.iterations), 1)
    rows.append(("retrieval/per_iteration/hb_vtotal_tau=1e-5",
                 dt / iters * 1e6, f"iters={iters};total_s={dt:.3f}"))
    # incremental request (only a few levels move) vs a from-scratch session
    # jumping straight to the same bound — the HB-linearity win.  Each warm
    # session is timed on exactly ONE tightening request (repeats would hit
    # the cache and report a no-op); min-of-3 sessions suppresses noise.
    def one_incremental():
        s = arch.open()
        s.reconstruct("Vx", 1e-4)
        return timed(s.reconstruct, "Vx", 0.9e-4, repeat=1)[0]

    dt_inc = min(one_incremental() for _ in range(3))
    dt_cold = min(timed(arch.open().reconstruct, "Vx", 0.9e-4, repeat=1)[0]
                  for _ in range(3))
    rows.append(("retrieval/incremental_request_us", dt_inc * 1e6,
                 f"from_scratch_us={dt_cold * 1e6:.1f};"
                 f"speedup={dt_cold / dt_inc:.2f}x"))
    return rows


def run():
    rows = []
    rng = np.random.default_rng(0)
    n = 1 << 16
    mag = jnp.asarray(rng.integers(0, 2 ** 30, n), jnp.int32)
    f = jax.jit(lambda m: ref.bitplane_pack_ref(m, 30))
    f(mag)[0].block_until_ready()
    dt, _ = timed(lambda: jax.block_until_ready(f(mag)), repeat=5)
    rows.append(("kernels/bitplane_pack_ref_jit/n=65536", dt * 1e6,
                 f"planes=30;GBps={n * 4 / dt / 1e9:.2f}"))

    even = jnp.asarray(rng.standard_normal((64, 513)), jnp.float32)
    odd = jnp.asarray(rng.standard_normal((64, 512)), jnp.float32)
    g = jax.jit(ref.hier_level_surplus_ref)
    g(even, odd).block_until_ready()
    dt, _ = timed(lambda: jax.block_until_ready(g(even, odd)), repeat=20)
    rows.append(("kernels/hier_level_ref_jit/64x512", dt * 1e6,
                 f"GBps={even.size * 4 / dt / 1e9:.2f}"))

    vx, vy, vz = (jnp.asarray(rng.standard_normal(n), jnp.float64)
                  for _ in range(3))
    eps = jnp.asarray([0.1, 0.2, 0.3])
    h = jax.jit(lambda a, b, c, e: ref.qoi_vtotal_ref(a, b, c, e))
    jax.block_until_ready(h(vx, vy, vz, eps))
    dt, _ = timed(lambda: jax.block_until_ready(h(vx, vy, vz, eps)),
                  repeat=10)
    rows.append(("kernels/qoi_vtotal_ref_jit/n=65536", dt * 1e6,
                 f"Melem/s={n / dt / 1e6:.1f}"))

    # correctness cross-check (pallas interpret vs ref) as a derived flag
    out_k = np.asarray(ops.pack_bitplanes(mag[:4096], nbits=16))
    out_r = np.asarray(ref.bitplane_pack_ref(mag[:4096], nbits=16))
    rows.append(("kernels/pallas_vs_ref_allclose", 0.0,
                 f"bitplane_exact={bool((out_k == out_r).all())}"))

    # unpack kernel (interpret) inverts the pack kernel exactly
    from repro.kernels.bitplane_unpack import bitplane_unpack
    shifts = np.arange(15, -1, -1)
    pad = (-out_k.shape[1]) % 32
    w = np.pad(out_k, ((0, 0), (0, pad)))
    un = np.asarray(bitplane_unpack(jnp.asarray(w),
                                    jnp.asarray(shifts, jnp.uint32),
                                    interpret=True))[:4096]
    low16 = np.asarray(mag[:4096]).astype(np.uint32) & 0xFFFF
    rows.append(("kernels/unpack_inverts_pack", 0.0,
                 f"roundtrip_exact={bool(np.array_equal(un, low16))}"))

    rows.extend(_codec_rows())
    rows.extend(_retrieval_rows())
    return rows
