"""Paper Figs 4, 5, 6: max estimated vs max actual QoI error under a ladder
of requested QoI tolerances (PMGARD-HB), on GE-like (6 QoIs), NYX-like
(total velocity, 3D) and S3D-like (molar-concentration products) data.

Validated invariants: actual <= estimated (guarantee) and actual <= τ_abs
(requested tolerance met) at every point of every curve.
"""
from __future__ import annotations


from benchmarks.common import actual_qoi_error, timed
from repro.core import ge
from repro.core.qoi import Prod, Var
from repro.core.refactor import refactor_variables
from repro.core.retrieval import QoIRequest, retrieve_qoi_controlled
from repro.data.synthetic import ge_like_fields, nyx_like_fields, s3d_like_fields

TAUS = [0.1 * 2.0 ** -i for i in range(0, 20, 3)]


def _sweep(fields, qois, mask_zero_velocity=True, label=""):
    arch = refactor_variables(fields, method="hb", nbits=48,
                              mask_zero_velocity=mask_zero_velocity)
    rows = []
    session = arch.open()      # progressive: one session, tightening taus
    for tau in TAUS:
        reqs = [QoIRequest(k, e, tau) for k, e in qois.items()]
        dt, res = timed(retrieve_qoi_controlled, session, reqs)
        ok = True
        worst_est, worst_act = 0.0, 0.0
        for k, e in qois.items():
            act = actual_qoi_error(e, fields, res.values)
            est = res.est_errors[k]
            ok &= act <= est * (1 + 1e-9) and act <= res.tau_abs[k] * (1 + 1e-9)
            worst_est = max(worst_est, est / max(res.tau_abs[k], 1e-300))
            worst_act = max(worst_act, act / max(res.tau_abs[k], 1e-300))
        rows.append((f"qoi_error/{label}/tau={tau:.2e}", dt * 1e6,
                     f"bitrate={res.bitrate:.3f};est/tau={worst_est:.3f};"
                     f"act/tau={worst_act:.3f};guaranteed={ok}"))
        assert ok, f"QoI guarantee violated at {label} tau={tau}"
    return rows


def run():
    rows = []
    ge_fields = ge_like_fields(n=1 << 15, seed=0)
    rows += _sweep(ge_fields, ge.all_qois(), label="GE-small")

    nyx = nyx_like_fields(shape=(33, 33, 33))
    rows += _sweep(nyx, {"VTOT": ge.v_total()}, mask_zero_velocity=False,
                   label="NYX")

    # Hurricane (Table III): non-cubic 3D velocity grid
    hurricane = nyx_like_fields(shape=(17, 33, 33), seed=42)
    rows += _sweep(hurricane, {"VTOT": ge.v_total()},
                   mask_zero_velocity=False, label="Hurricane")

    s3d = s3d_like_fields(shape=(33, 17, 17))
    sub = {k: s3d[k] for k in ("x0", "x1", "x3", "x4", "x5")}
    qois = {"x1x3": Prod(Var("x1"), Var("x3")),
            "x0x4": Prod(Var("x0"), Var("x4")),
            "x1x5": Prod(Var("x1"), Var("x5")),
            "x3x4": Prod(Var("x3"), Var("x4"))}
    rows += _sweep(sub, qois, mask_zero_velocity=False, label="S3D")
    return rows
