"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only substring]

Prints ``name,us_per_call,derived`` CSV (one row per curve point / cell).
Paper mapping:
  bench_qoi_error            Figs 4/5/6   estimated vs actual QoI errors
  bench_rate_distortion      Figs 2/7/8   bitrate vs requested error, 3 methods
  bench_basis                Fig 3        PMGARD-OB vs -HB estimate gap
  bench_refactor_time        Table IV     refactor + retrieval times
  bench_transfer             Fig 9        modelled remote transfer, 2.02x claim
  bench_kernels              (impl)       kernel hot-loop micro-benches
  bench_training_integration (beyond)     progressive ckpt + grad compression
Roofline/dry-run tables are built by benchmarks/roofline.py from
results/dryrun.json (see EXPERIMENTS.md §Roofline).
"""
import argparse
import sys
import time

MODULES = [
    "bench_qoi_error",
    "bench_rate_distortion",
    "bench_basis",
    "bench_refactor_time",
    "bench_transfer",
    "bench_kernels",
    "bench_training_integration",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failures = 0
    for name in MODULES:
        if args.only and args.only not in name:
            continue
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.time()
        try:
            rows = mod.run()
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},ERROR,{type(e).__name__}: {e}", flush=True)
            continue
        for row in rows:
            nm, us, derived = row
            print(f"{nm},{us:.1f},{derived}", flush=True)
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
