"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only substring] [--json PATH]

Prints ``name,us_per_call,derived`` CSV (one row per curve point / cell) and
writes a machine-readable ``BENCH_kernels.json`` (row name -> us_per_call,
plus the derived string) so the perf trajectory is tracked across PRs.
Paper mapping:
  bench_qoi_error            Figs 4/5/6   estimated vs actual QoI errors
  bench_rate_distortion      Figs 2/7/8   bitrate vs requested error, 3 methods
  bench_basis                Fig 3        PMGARD-OB vs -HB estimate gap
  bench_refactor_time        Table IV     refactor + retrieval times
  bench_transfer             Fig 9        modelled remote transfer, 2.02x claim
                                          + real store/WAN prefetch overlap
  bench_store                (impl)       container round-trip, fetch latency,
                                          prefetch hit rate, crc32c
  bench_entropy              (impl)       plane-codec density sweep + cost-
                                          model selection vs zlib stand-in
  bench_robustness           (impl)       retrieval under injected transient
                                          faults: wall time + wire bytes at
                                          0/1/5% per-read fault rates
  bench_memory_bound         (impl)       contribution-cache budgets: peak
                                          bytes + warm latency at 1/.5/.25x
  bench_serve_concurrent     (impl)       serve plane: 64 clients, worker
                                          pool + coalescing vs sequential
                                          (speedup, p50/p99 tail amp)
  bench_kernels              (impl)       kernel hot-loop micro-benches
  bench_training_integration (beyond)     progressive ckpt + grad compression
Roofline/dry-run tables are built by benchmarks/roofline.py from
results/dryrun.json (see EXPERIMENTS.md §Roofline).
"""
import argparse
import json
import sys
import time

MODULES = [
    "bench_qoi_error",
    "bench_rate_distortion",
    "bench_basis",
    "bench_refactor_time",
    "bench_transfer",
    "bench_store",
    "bench_entropy",
    "bench_robustness",
    "bench_memory_bound",
    "bench_serve_concurrent",
    "bench_kernels",
    "bench_training_integration",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="run only modules whose name contains one of "
                         "these comma-separated substrings")
    ap.add_argument("--json", default=None,
                    help="machine-readable output path ('' to disable); "
                         "defaults to BENCH_kernels.json on FULL runs only "
                         "— a --only run would clobber it with partial rows")
    args = ap.parse_args()
    if args.json is None:
        args.json = "" if args.only else "BENCH_kernels.json"
    print("name,us_per_call,derived")
    failures = 0
    results = {}
    only = [s for s in args.only.split(",") if s]
    for name in MODULES:
        if only and not any(s in name for s in only):
            continue
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.time()
        try:
            rows = mod.run()
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},ERROR,{type(e).__name__}: {e}", flush=True)
            continue
        for row in rows:
            nm, us, derived = row
            print(f"{nm},{us:.1f},{derived}", flush=True)
            results[nm] = {"us_per_call": round(us, 1), "derived": derived}
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
    if args.json and results and not failures:
        # never clobber the cross-PR tracking file with a partial row set
        with open(args.json, "w") as fh:
            json.dump(results, fh, indent=1, sort_keys=True)
        print(f"# wrote {args.json} ({len(results)} rows)", flush=True)
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
