"""Robustness-path benches: end-to-end session cost under injected
transient faults (repro.store.faults) at 0% / 1% / 5% per-read rates.

What these rows watch across PRs:

  * the zero-fault row is the retry layer's OVERHEAD — the policy wraps
    every fetch even when nothing fails, so this must track the plain
    store session bench;
  * the faulted rows are the ABSORPTION cost — wall time and wire bytes
    as the retry loop hides a deterministic, seeded fault schedule.  Wire
    bytes only count delivered segments (failed attempts deliver nothing),
    so byte inflation would flag double-charging in the accounting.
"""
from __future__ import annotations

import json
import time

from repro.core.refactor import refactor_variables
from repro.data.synthetic import ge_like_fields
from repro.store import (
    BlobQuarantine,
    FaultInjectingByteStore,
    FaultPlan,
    MemoryByteStore,
    RetryPolicy,
)
from repro.store.container import StoreArchive, build_sharded_container

RATES = (0.0, 0.01, 0.05)
POLICY = RetryPolicy(max_attempts=4, backoff_s=1e-3, backoff_cap_s=5e-3)


def run():
    rows = []
    fields = ge_like_fields(n=1 << 15, seed=0)
    vel = {k: fields[k] for k in ("Vx", "Vy", "Vz")}
    arch = refactor_variables(vel, method="hb")
    manifest, payloads = build_sharded_container(arch, shard_by="single")
    manifest = json.loads(json.dumps(manifest))
    payload = payloads[""]

    # untimed warmup: the first session pays reader jit/codec warmup that
    # would otherwise land entirely on the fault=0% row
    warm = StoreArchive(manifest, MemoryByteStore(payload),
                        prefetch_workers=2)
    try:
        s = warm.open()
        for v in vel:
            s.reconstruct(v, 1e-6)
    finally:
        warm.close()

    baseline_bytes = None
    for rate in RATES:
        # mixed plain-error/bit-flip schedule; the per-range cap of 2 keeps
        # every schedule inside the 4-attempt budget (always heals)
        plan = FaultPlan(rate=rate, error_weight=1.0, flip_weight=1.0,
                         max_faults_per_range=2)
        store = FaultInjectingByteStore(MemoryByteStore(payload), plan,
                                        seed=0)
        sa = StoreArchive(manifest, store, prefetch_workers=2,
                          retry_policy=POLICY,
                          quarantine=BlobQuarantine(threshold=8))
        try:
            t0 = time.perf_counter()
            session = sa.open()
            for eps in (1e-2, 1e-4, 1e-6):
                for v in vel:
                    session.prefetch(v, eps)
                    session.reconstruct(v, eps)
            dt = time.perf_counter() - t0
            st = sa.fetcher.stats
            if baseline_bytes is None:
                baseline_bytes = st.bytes_fetched
            # delivered wire bytes must not inflate with the fault rate:
            # failed attempts deliver nothing and must not be charged
            rows.append((f"robust/session/fault={rate:.0%}", dt * 1e6,
                         f"bytes={st.bytes_fetched};"
                         f"inflation={st.bytes_fetched / baseline_bytes:.3f};"
                         f"injected={store.stats.total};"
                         f"absorbed={st.faults_absorbed};"
                         f"retries={st.retries}"))
        finally:
            sa.close()
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
