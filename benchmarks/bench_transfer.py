"""Paper Fig 9: remote data-transfer performance under QoI error bounds.

The paper measures MCC -> Anvil over Globus (effective WAN throughput
~0.4 GB/s: 4.67 GB baseline in 11.7 s). No WAN exists in this container and
the paper's pipeline is C++, so the reproduction splits the claim into the
part we can measure *faithfully* and the part we must model:

  * bytes_frac  — MEASURED: retrieved bytes / primary bytes. The paper's
    headline rests on moving <27% of the bytes at QoI tolerance 1e-5, which
    makes the transfer 1/0.27 = 3.7x faster; with their retrieval-compute
    overhead included, 2.02x end-to-end.
  * transfer_speedup = 1 / bytes_frac — the transfer-time gain at ANY
    bandwidth (bandwidth cancels).
  * retrieval overhead — MEASURED wall time of our (pure-Python/zlib)
    retrieval per request, reported alongside; the breakeven bandwidth
    BW* = retrieved_bytes·(1/frac - 1)/t_retr tells at which WAN speed the
    end-to-end gain disappears for our implementation.
"""
from __future__ import annotations

from benchmarks.common import timed
from repro.core import ge
from repro.core.refactor import refactor_variables
from repro.core.retrieval import QoIRequest, retrieve_qoi_controlled
from repro.data.synthetic import ge_like_fields

BW_EFF = 400e6  # B/s effective WAN throughput (paper: 4.67GB / 11.7s)
TAUS = (1e-1, 1e-2, 1e-3, 1e-4, 1e-5)


def run():
    rows = []
    fields = ge_like_fields(n=1 << 16, seed=0)
    vel = {k: fields[k] for k in ("Vx", "Vy", "Vz")}
    raw_bytes = sum(v.nbytes for v in vel.values())
    for method in ("hb", "psz3", "psz3_delta"):
        dt_ref, arch = timed(refactor_variables, vel, method=method)
        # warm-up session so jit compilation does not pollute timings
        warm = arch.open()
        retrieve_qoi_controlled(warm, [QoIRequest("VTOT", ge.v_total(),
                                                  1e-1)])
        session = arch.open()
        for tau in TAUS:
            dt_retr, res = timed(retrieve_qoi_controlled, session,
                                 [QoIRequest("VTOT", ge.v_total(), tau)])
            frac = res.bytes_retrieved / raw_bytes
            speedup = 1.0 / frac
            t_transfer = res.bytes_retrieved / BW_EFF
            bw_star = res.bytes_retrieved * (speedup - 1) / max(dt_retr, 1e-9)
            rows.append((f"transfer/fig9/{method}/tau={tau:.0e}",
                         dt_retr * 1e6,
                         f"bytes_frac={frac:.3f};"
                         f"transfer_speedup={speedup:.2f};"
                         f"breakeven_BW={bw_star / 1e6:.0f}MB/s"))
            if method == "hb" and tau == 1e-5:
                # paper headline: 2.02x end-to-end = <27% of the bytes
                rows.append(("transfer/fig9/headline_claim", dt_retr * 1e6,
                             f"bytes_frac={frac:.3f};claim<0.27;"
                             f"bytes_met={frac < 0.27};"
                             f"transfer_speedup={speedup:.2f};"
                             f"claim>=2.02;met={speedup >= 2.02}"))
    return rows
