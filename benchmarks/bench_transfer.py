"""Paper Fig 9: remote data-transfer performance under QoI error bounds.

The paper measures MCC -> Anvil over Globus (effective WAN throughput
~0.4 GB/s: 4.67 GB baseline in 11.7 s). No WAN exists in this container and
the paper's pipeline is C++, so the reproduction splits the claim into the
part we can measure *faithfully* and the part we must model:

  * bytes_frac  — MEASURED: retrieved bytes / primary bytes. The paper's
    headline rests on moving <27% of the bytes at QoI tolerance 1e-5, which
    makes the transfer 1/0.27 = 3.7x faster; with their retrieval-compute
    overhead included, 2.02x end-to-end.
  * transfer_speedup = 1 / bytes_frac — the transfer-time gain at ANY
    bandwidth (bandwidth cancels).
  * retrieval overhead — MEASURED wall time of our (pure-Python/zlib)
    retrieval per request, reported alongside; the breakeven bandwidth
    BW* = retrieved_bytes·(1/frac - 1)/t_retr tells at which WAN speed the
    end-to-end gain disappears for our implementation.

Since the store subsystem (repro.store) the bench also measures REAL
end-to-end transfer time: the archive is saved to a container file and
served through a RemoteByteStore that models the paper's WAN link with
actual wall-clock delays.  The ``store/`` rows compare the synchronous
fetch path against the prefetching SegmentFetcher (predicted planes move
while the QoI estimator runs) at *identical consumed and link bytes* — the
speedup is pure transport/compute overlap, not byte savings.
"""
from __future__ import annotations

import os
import tempfile
import time

from benchmarks.common import timed
from repro.core import ge
from repro.core.refactor import refactor_variables
from repro.core.retrieval import QoIRequest, retrieve_qoi_controlled
from repro.data.synthetic import ge_like_fields
from repro.options import OpenOptions
from repro.store import FileByteStore, HTTPByteStore, RemoteByteStore, \
    open_archive, save_archive
from repro.store.httpd import StoreHTTPServer

BW_EFF = 400e6  # B/s effective WAN throughput (paper: 4.67GB / 11.7s)
TAUS = (1e-1, 1e-2, 1e-3, 1e-4, 1e-5)
LINK_LATENCY = 2e-3  # s per request on the simulated WAN


def run():
    rows = []
    fields = ge_like_fields(n=1 << 16, seed=0)
    vel = {k: fields[k] for k in ("Vx", "Vy", "Vz")}
    raw_bytes = sum(v.nbytes for v in vel.values())
    for method in ("hb", "psz3", "psz3_delta"):
        dt_ref, arch = timed(refactor_variables, vel, method=method)
        # warm-up session so jit compilation does not pollute timings
        warm = arch.open()
        retrieve_qoi_controlled(warm, [QoIRequest("VTOT", ge.v_total(),
                                                  1e-1)])
        session = arch.open()
        for tau in TAUS:
            dt_retr, res = timed(retrieve_qoi_controlled, session,
                                 [QoIRequest("VTOT", ge.v_total(), tau)])
            frac = res.bytes_retrieved / raw_bytes
            speedup = 1.0 / frac
            t_transfer = res.bytes_retrieved / BW_EFF
            bw_star = res.bytes_retrieved * (speedup - 1) / max(dt_retr, 1e-9)
            rows.append((f"transfer/fig9/{method}/tau={tau:.0e}",
                         dt_retr * 1e6,
                         f"bytes_frac={frac:.3f};"
                         f"transfer_speedup={speedup:.2f};"
                         f"breakeven_BW={bw_star / 1e6:.0f}MB/s"))
            if method == "hb" and tau == 1e-5:
                # paper headline: 2.02x end-to-end = <27% of the bytes
                rows.append(("transfer/fig9/headline_claim", dt_retr * 1e6,
                             f"bytes_frac={frac:.3f};claim<0.27;"
                             f"bytes_met={frac < 0.27};"
                             f"transfer_speedup={speedup:.2f};"
                             f"claim>=2.02;met={speedup >= 2.02}"))
    rows.extend(_store_rows())
    return rows


def _remote_retrieval(path, tau, workers):
    remote = RemoteByteStore(FileByteStore(path), latency_s=LINK_LATENCY,
                             bandwidth_bps=BW_EFF)
    with open_archive(remote, OpenOptions(prefetch_workers=workers)) as sa:
        session = sa.open()
        t0 = time.perf_counter()
        res = retrieve_qoi_controlled(session,
                                      [QoIRequest("VTOT", ge.v_total(), tau)])
        dt = time.perf_counter() - t0
        return (dt, res.bytes_retrieved, remote.stats.bytes_moved,
                remote.stats.busy_s, sa.fetcher.stats)


def _store_rows():
    """REAL end-to-end wall time over the simulated WAN: synchronous fetch
    vs prefetching fetcher, same requests, same bytes on the wire."""
    fields = ge_like_fields(n=1 << 14, seed=0)
    vel = {k: fields[k] for k in ("Vx", "Vy", "Vz")}
    arch = refactor_variables(vel, method="hb")
    fd, path = tempfile.mkstemp(suffix=".prs")
    os.close(fd)
    save_archive(arch, path)
    # warm the estimator jit so the sync-vs-prefetch delta is transport-only
    retrieve_qoi_controlled(arch.open(),
                            [QoIRequest("VTOT", ge.v_total(), 1e-5)])
    rows = []
    try:
        for tau in (1e-3, 1e-5):
            dt_s, used_s, wire_s, busy_s, _ = _remote_retrieval(path, tau, 0)
            dt_p, used_p, wire_p, busy_p, st = _remote_retrieval(path, tau, 4)
            rows.append((f"transfer/store/sync/tau={tau:.0e}", dt_s * 1e6,
                         f"consumed={used_s};wire={wire_s};"
                         f"link_busy_s={busy_s:.3f}"))
            rows.append((f"transfer/store/prefetch/tau={tau:.0e}", dt_p * 1e6,
                         f"consumed={used_p};wire={wire_p};"
                         f"bytes_equal={used_s == used_p and wire_s == wire_p};"
                         f"hit_rate={st.hit_rate:.2f};"
                         f"overlap_speedup={dt_s / dt_p:.2f};"
                         f"overlapped={dt_p < dt_s}"))
        # the same session over a REAL wire (loopback HTTP ranged GETs):
        # consumed bytes must match the modelled link exactly — the link
        # model and the HTTP backend disagree only in wall time
        with StoreHTTPServer(path) as srv:
            hs = HTTPByteStore(srv.url)
            with open_archive(hs, OpenOptions(prefetch_workers=4)) as ha:
                session = ha.open()
                t0 = time.perf_counter()
                res = retrieve_qoi_controlled(
                    session, [QoIRequest("VTOT", ge.v_total(), 1e-5)])
                dt_h = time.perf_counter() - t0
                rows.append(("transfer/http/tau=1e-05", dt_h * 1e6,
                             f"consumed={res.bytes_retrieved};"
                             f"bytes_equal={res.bytes_retrieved == used_p};"
                             f"requests={hs.stats.requests};"
                             f"coalesced={hs.stats.coalesced_ranges};"
                             f"hit_rate={ha.fetcher.stats.hit_rate:.2f}"))
    finally:
        os.unlink(path)
    return rows
