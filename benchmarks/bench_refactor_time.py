"""Paper Table IV: refactoring and retrieval time per progressive method.

Reproduced relationships: PMGARD-HB refactors fastest (single decomposition
+ bitplanes — and no L² solves, unlike OB); PSZ3/PSZ3-delta pay the full
compression ladder (one compressor run per preset bound); retrieval times
are the same order across methods.
"""
from __future__ import annotations

from benchmarks.common import timed
from repro.core import ge
from repro.core.refactor import refactor_variables
from repro.core.retrieval import QoIRequest, retrieve_qoi_controlled
from repro.data.synthetic import ge_like_fields

TAUS = (1e-1, 1e-2, 1e-3, 1e-4, 1e-5)


def run():
    rows = []
    fields = ge_like_fields(n=1 << 15, seed=0)
    vel = {k: fields[k] for k in ("Vx", "Vy", "Vz")}
    for method in ("hb", "ob", "psz3", "psz3_delta"):
        # warm-up on identically-shaped data: jit compile time is a one-off
        # per shape, not part of the steady-state refactor cost (Table IV
        # compares algorithmic cost — the paper's C++ has no JIT)
        refactor_variables({"W": vel["Vx"]}, method=method, n_snapshots=2,
                           mask_zero_velocity=False)
        dt_ref, arch = timed(refactor_variables, vel, method=method,
                             n_snapshots=10)
        warm = arch.open()
        retrieve_qoi_controlled(warm, [QoIRequest("VTOT", ge.v_total(),
                                                  TAUS[0])])
        retr = []
        for tau in TAUS:
            session = arch.open()
            dt, res = timed(retrieve_qoi_controlled, session,
                            [QoIRequest("VTOT", ge.v_total(), tau)])
            retr.append(f"{dt:.3f}")
        rows.append((f"refactor_time/tableIV/{method}", dt_ref * 1e6,
                     "retrieval_s@taus=" + "/".join(retr)))
    return rows
