"""Entropy-stage benchmarks: bytes-per-plane and encode/decode throughput
per registered plane codec across a bit-density sweep, plus the headline
comparison — total encoded plane bytes of a smooth synthetic archive under
the cost-model selection vs the old zlib-only stand-in.

Rows (tracked in BENCH_kernels.json, gated by check_regression with the
``entropy/`` prefix):

    entropy/<codec>/density=<d>   encode us_per_call on one packed plane at
                                  set-bit density d; derived carries the
                                  encoded size, compression ratio, and
                                  decode throughput
    entropy/select/smooth         cost-model selection over a refactored
                                  smooth archive: total selected plane
                                  bytes vs the legacy zlib stand-in (the
                                  paper-facing bytes-on-the-wire number)
"""
from __future__ import annotations

import zlib

import numpy as np

from benchmarks.common import timed
from repro.bitplane import codecs as C
from repro.core.refactor import refactor_variables
from repro.data.synthetic import ge_like_fields

PLANE_BITS = 1 << 19            # 64 KiB packed plane
DENSITIES = (0.001, 0.01, 0.1, 0.5)
_RAW_BAND = (0.45, 0.55)


def _legacy_plane_size(words: np.ndarray, count: int) -> int:
    """Byte cost of the pre-registry stand-in: density-gated raw, else
    zlib-if-it-shrinks (tag byte included)."""
    buf = words.tobytes()
    if hasattr(np, "bitwise_count"):
        density = int(np.bitwise_count(words).sum()) / count
    else:
        density = int(np.unpackbits(words.view(np.uint8)).sum()) / count
    if _RAW_BAND[0] <= density <= _RAW_BAND[1]:
        return 1 + len(buf)
    z = zlib.compress(buf, 1)
    return 1 + min(len(z), len(buf))


def run():
    rows = []
    rng = np.random.default_rng(0)

    # -- per-codec density sweep on synthetic packed planes ----------------
    for density in DENSITIES:
        bits = rng.random(PLANE_BITS) < density
        data = np.packbits(bits).tobytes()
        for name in sorted(C.registered_codecs()):
            codec = C.registered_codecs()[name]
            dt_enc, payload = timed(codec.encode, data)
            dt_enc = min(dt_enc, timed(codec.encode, data)[0])
            dt_dec, out = timed(codec.decode, payload, len(data))
            dt_dec = min(dt_dec, timed(codec.decode, payload, len(data))[0])
            assert out == data
            rows.append((
                f"entropy/{name}/density={density}", dt_enc * 1e6,
                f"bytes={len(payload)};ratio={len(payload) / len(data):.3f};"
                f"enc_MBps={len(data) / dt_enc / 1e6:.0f};"
                f"dec_MBps={len(data) / dt_dec / 1e6:.0f}"))

    # -- cost-model selection vs the zlib stand-in on a smooth archive -----
    fields = ge_like_fields(n=1 << 15, seed=0)
    vel = {k: fields[k] for k in ("Vx", "Vy", "Vz")}
    arch = refactor_variables(vel, method="hb")
    # pull every plane back to raw packed words so the row can time the
    # entropy stage ALONE (encode_tagged over all planes) — refactor time
    # would drag jit warm-up into the row and make it depend on which
    # benches ran first
    planes = []                    # (words, count, density)
    selected = legacy = 0
    # the deep planes below the noise floor are raw under BOTH stands —
    # track the compressible (MSB) subset separately: that is where the
    # entropy stage actually earns its keep
    selected_c = legacy_c = 0
    per_codec = {}
    for var in arch.variables.values():
        for g in var.groups:
            if g.exponent is None:
                continue
            nwords = (g.count + 31) // 32
            for blob in g.planes:
                selected += len(blob)
                name = C.codec_name(blob[0])
                per_codec[name] = per_codec.get(name, 0) + len(blob)
                words = np.frombuffer(
                    C.decode_tagged(blob, 4 * nwords), dtype=np.uint32,
                    count=nwords)
                if hasattr(np, "bitwise_count"):
                    density = int(np.bitwise_count(words).sum()) / g.count
                else:
                    density = int(np.unpackbits(
                        words.view(np.uint8)).sum()) / g.count
                planes.append((words.tobytes(), density))
                lsize = _legacy_plane_size(words, g.count)
                legacy += lsize
                if lsize < 1 + 4 * nwords:   # the stand-in could deflate it
                    legacy_c += lsize
                    selected_c += len(blob)

    def select_all():
        for data, density in planes:
            C.encode_tagged(data, density=density)

    dt_select = min(timed(select_all)[0] for _ in range(2))
    share = ";".join(f"{k}={v}" for k, v in
                     sorted(per_codec.items(), key=lambda kv: -kv[1]))
    rows.append((
        "entropy/select/smooth", dt_select * 1e6,
        f"planes={len(planes)};selected_bytes={selected};"
        f"zlib_stand_in_bytes={legacy};"
        f"saving={1.0 - selected / legacy:.1%};"
        f"msb_saving={1.0 - selected_c / legacy_c:.1%};{share}"))
    assert selected < legacy, (
        f"cost-model selection ({selected}B) must beat the zlib stand-in "
        f"({legacy}B) on smooth data")
    return rows
